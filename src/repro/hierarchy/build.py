"""θ → hierarchy forest: the nested dense-subgraph DAG (Sarıyüce's
k-wing / k-tip nuclei) materialized from peel output.

For every distinct level k ≥ 1 the k-subgraph is the set of entities
with θ ≥ k (edges for wing, one-side vertices for tip); its
*butterfly-connected* components are the hierarchy nodes.  Components
only split as k grows, so the nodes form a forest under containment —
we root it with a level-0 node holding the whole graph, making every
query an ancestor problem.

Connectivity is butterfly connectivity, stated on the wedge machinery of
``core.csr``: two entities are connected at level k iff a chain of
butterflies of the k-subgraph joins them.  A butterfly is two wedges of
one U-endpoint *pair*, so the connectivity graph is the bipartite
incidence entity ↔ pair, restricted to pairs holding ≥ 2 alive wedges.
Components are computed levels-batched by min-label propagation over
that incidence — one ``lax.while_loop`` per block of ``level_block``
levels (a single compiled shape; memory stays O(level_block × wedges)
however many θ levels the graph has), each iteration two
``segment_min`` hops vmapped across the block's levels; no Python
per-edge loops anywhere on the device path.

Nodes are *collapsed*: a node exists at level k only if some entity has
θ == k in it (a component whose members all survive to the next level
is the same subgraph there — representing it twice would add chain
nodes that answer no query).  Each entity therefore belongs to exactly
one node (its component at level θ), nodes are created level-ascending
(``parent[x] < x`` always), and member lists partition the entity set.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import csr
from repro.core.graph import BipartiteGraph
from repro.core.peel import PeelResult

__all__ = ["Hierarchy", "build_hierarchy"]

_BIG = jnp.iinfo(jnp.int32).max


# =====================================================================
# Packed forest container
# =====================================================================
@dataclasses.dataclass
class Hierarchy:
    """CSR-packed hierarchy forest (host numpy; see :mod:`query` for the
    device-resident view).

    Node 0 is the level-0 root holding the whole graph; its *own*
    members are the butterfly-free entities (θ = 0).  ``ent_order``
    sorts entities by the preorder stamp of their node, so every node's
    subtree entity set is the contiguous slice
    ``ent_order[estart[x]:eend[x]]`` — the O(1) backbone of
    ``subgraph_at`` and the density stats.
    """

    kind: str                 # "wing" | "tip"
    n_entities: int
    theta: np.ndarray         # (n_entities,) int64 — peel numbers
    node_level: np.ndarray    # (n_nodes,) int64 — k of each node
    parent: np.ndarray        # (n_nodes,) int32 — parent id, -1 at root
    entity_node: np.ndarray   # (n_entities,) int32 — deepest node per entity
    member_off: np.ndarray    # (n_nodes+1,) int64 — own-member CSR
    member_ids: np.ndarray    # (n_entities,) int32
    child_off: np.ndarray     # (n_nodes+1,) int64 — children CSR
    child_ids: np.ndarray     # (n_nodes-1,) int32
    tin: np.ndarray           # (n_nodes,) int32 — preorder stamp
    tout: np.ndarray          # (n_nodes,) int32 — subtree = [tin, tout)
    ent_order: np.ndarray     # (n_entities,) int32 — entities by node tin
    estart: np.ndarray        # (n_nodes,) int64 — subtree slice start
    eend: np.ndarray          # (n_nodes,) int64 — subtree slice end
    node_m: np.ndarray        # (n_nodes,) int64 — induced edge count
    node_nu: np.ndarray       # (n_nodes,) int64 — induced |U| span
    node_nv: np.ndarray       # (n_nodes,) int64 — induced |V| span
    density: np.ndarray       # (n_nodes,) f64 — m / (nu · nv)
    meta: Dict                # provenance: engine tags, PeelStats, ...

    @property
    def n_nodes(self) -> int:
        """Number of forest nodes (dense subgraphs) after chain collapse."""
        return int(self.node_level.shape[0])

    @property
    def levels(self) -> np.ndarray:
        """Distinct θ levels ≥ 1 present in the forest, ascending."""
        lv = np.unique(self.node_level)
        return lv[lv > 0]

    def subtree_entities(self, node: int) -> np.ndarray:
        """All entities of the node's subgraph (own + descendants)."""
        return self.ent_order[int(self.estart[node]):int(self.eend[node])]

    def members(self, node: int) -> np.ndarray:
        """Own members only (entities with θ == node_level[node])."""
        return self.member_ids[
            int(self.member_off[node]):int(self.member_off[node + 1])
        ]

    def children(self, node: int) -> np.ndarray:
        """Child node ids (denser subgraphs nested inside this one)."""
        return self.child_ids[
            int(self.child_off[node]):int(self.child_off[node + 1])
        ]


# =====================================================================
# Batched connected components (device): min-label propagation
# =====================================================================
@partial(jax.jit, static_argnames=("n_entities", "n_groups"))
def _label_components(
    alive_inc: jax.Array,   # (L, n_inc) bool — incidence alive per level
    inc_e: jax.Array,       # (n_inc,) int32 — entity endpoint
    inc_g: jax.Array,       # (n_inc,) int32 — group (pair) endpoint
    lab0: jax.Array,        # (L, n_entities) int32 — entity id | _BIG dead
    n_entities: int,
    n_groups: int,
):
    """Connected components of L level-subgraphs in ONE ``while_loop``.

    Each iteration is two segment_min hops over the entity↔group
    incidence (entity labels → group minima → back), vmapped across
    levels; the loop runs until no label moves in ANY level.  The fixed
    point labels every entity with the minimum entity id of its
    component (``_BIG`` for dead entities), which doubles as a canonical
    component representative.
    """

    def one(lab, alive):
        up = jnp.where(alive, lab[inc_e], _BIG)
        gmin = jax.ops.segment_min(up, inc_g, num_segments=max(n_groups, 1))
        down = jnp.where(alive, gmin[inc_g], _BIG)
        return jnp.minimum(
            lab, jax.ops.segment_min(down, inc_e, num_segments=n_entities)
        )

    def body(state):
        lab, _ = state
        new = jax.vmap(one)(lab, alive_inc)
        return new, jnp.any(new != lab)

    lab, _ = jax.lax.while_loop(
        lambda s: s[1], body, (lab0, jnp.bool_(True))
    )
    return lab


@partial(jax.jit, static_argnames=("n_pairs",))
def _wing_conn_incidence(
    alive_e: jax.Array,     # (L, m) bool
    we1: jax.Array,
    we2: jax.Array,
    wp: jax.Array,
    n_pairs: int,
):
    """Per-level connective-wedge mask: wedge alive (both edges in the
    level subgraph) AND its pair holds ≥ 2 alive wedges — the pair then
    witnesses a butterfly joining every edge incident to it."""

    def one(al):
        alive_w = al[we1] & al[we2]
        W = jax.ops.segment_sum(
            alive_w.astype(jnp.int32), wp, num_segments=max(n_pairs, 1)
        )
        return alive_w & (W[wp] >= 2)

    return jax.vmap(one)(alive_e)


def _pad_block(x: np.ndarray, block: int) -> np.ndarray:
    """Pad the level axis up to ``block`` rows with all-dead levels
    (inert in the propagation) so every chunk shares one compiled
    shape."""
    pad = block - x.shape[0]
    if pad == 0:
        return x
    fill = np.zeros((pad,) + x.shape[1:], dtype=x.dtype)
    return np.concatenate([x, fill], axis=0)


def _component_labels_per_level(
    gg: BipartiteGraph,
    theta: np.ndarray,
    levels: np.ndarray,
    kind: str,
    level_block: int = 32,
) -> np.ndarray:
    """(L, n_entities) int64 component labels, _BIG-marked where dead.

    Levels are processed in fixed chunks of ``level_block`` (all-dead
    padded to one compiled shape): the propagation state is
    O(level_block × incidences), NOT O(L × incidences) — a graph with
    thousands of distinct θ levels must not need thousands of wedge-list
    copies resident at once.  Chunks are independent (each level's
    fixpoint is its own), so this is a pure memory/dispatch trade."""
    n_ent = gg.m if kind == "wing" else gg.n_u
    L = levels.size
    if L == 0 or n_ent == 0:
        return np.zeros((0, n_ent), dtype=np.int64)

    if kind == "wing":
        wed = csr.build_wedges(gg)
        we1 = jnp.asarray(wed.wedge_e1)
        we2 = jnp.asarray(wed.wedge_e2)
        wp = jnp.asarray(wed.wedge_pair)
        inc_e = jnp.concatenate([we1, we2])
        inc_g = jnp.concatenate([wp, wp])
        n_groups = wed.n_pairs
    else:
        wed = csr.build_wedges(gg)
        # pairs with ≥ 2 wedges share a butterfly (V is never peeled, so
        # W0 is the pair's wedge count at every level)
        conn_p = wed.W0 >= 2
        pa = wed.pair_a[conn_p].astype(np.int32)
        pb = wed.pair_b[conn_p].astype(np.int32)
        pid = np.arange(pa.size, dtype=np.int32)
        inc_e = jnp.asarray(np.concatenate([pa, pb]))
        inc_g = jnp.asarray(np.concatenate([pid, pid]))
        n_groups = int(pa.size)

    ids = jnp.arange(n_ent, dtype=jnp.int32)[None, :]
    out = np.empty((L, n_ent), dtype=np.int64)
    for lo in range(0, L, level_block):
        chunk = levels[lo:lo + level_block]
        n = chunk.size
        alive = _pad_block(theta[None, :] >= chunk[:, None], level_block)
        alive_j = jnp.asarray(alive)
        if kind == "wing":
            conn = _wing_conn_incidence(alive_j, we1, we2, wp, n_groups)
            alive_inc = jnp.concatenate([conn, conn], axis=1)
        else:
            ap = alive[:, pa] & alive[:, pb]
            alive_inc = jnp.asarray(np.concatenate([ap, ap], axis=1))
        lab0 = jnp.where(alive_j, ids, _BIG)
        lab = _label_components(
            alive_inc, inc_e, inc_g, lab0, n_ent, n_groups
        )
        out[lo:lo + n] = np.asarray(lab[:n]).astype(np.int64)
    return out


# =====================================================================
# Host assembly: labels → packed forest
# =====================================================================
def _dfs_order(n_nodes: int, child_off, child_ids):
    """Preorder stamps (tin, tout) — iterative, root = node 0."""
    tin = np.zeros(n_nodes, dtype=np.int32)
    tout = np.zeros(n_nodes, dtype=np.int32)
    t = 0
    stack = [(0, False)]
    while stack:
        x, closing = stack.pop()
        if closing:
            tout[x] = t
            continue
        tin[x] = t
        t += 1
        stack.append((x, True))
        kids = child_ids[child_off[x]:child_off[x + 1]]
        for c in kids[::-1]:
            stack.append((int(c), False))
    return tin, tout


def build_hierarchy(
    g: BipartiteGraph,
    result: Union[PeelResult, np.ndarray],
    kind: str = "wing",
    side: str = "u",
    meta: Optional[Dict] = None,
    level_block: int = 32,
) -> Hierarchy:
    """Construct the k-wing / k-tip hierarchy forest from peel output.

    Traced under a ``hierarchy``-cat span (labeling / node creation /
    per-node stats sub-spans) when the obs layer is enabled.

    ``result`` is a :class:`~repro.core.peel.PeelResult` from ANY engine
    (``dense`` / ``beindex`` / ``csr`` — their θ are bit-identical, so
    so are the forests) or a raw θ array.  For ``kind="tip"`` pass the
    same ``side`` the decomposition peeled; entities are that side's
    vertices (the graph is transposed internally for ``side="v"``,
    mirroring :func:`~repro.core.peel.tip_decomposition`).

    ``level_block`` caps how many levels' component labelings are
    device-resident at once (memory = O(level_block × wedges)); the
    forest is identical for any value ≥ 1.
    """
    with obs.span("hierarchy.build", cat="hierarchy", kind=kind):
        return _build_hierarchy_impl(
            g, result, kind, side, meta, level_block)


def _build_hierarchy_impl(g, result, kind, side, meta, level_block):
    if kind not in ("wing", "tip"):
        raise ValueError(kind)
    gg = g if (kind == "wing" or side == "u") else g.transpose()
    if isinstance(result, PeelResult):
        theta = np.asarray(result.theta, dtype=np.int64)
        prov = result.provenance()
    else:
        theta = np.asarray(result, dtype=np.int64)
        prov = {}
    n_ent = gg.m if kind == "wing" else gg.n_u
    if theta.shape != (n_ent,):
        raise ValueError(
            f"theta has shape {theta.shape}, expected ({n_ent},) for "
            f"kind={kind!r}"
        )

    levels = np.unique(theta[theta > 0])
    with obs.span("hierarchy.labels", cat="hierarchy",
                  levels=int(levels.size)):
        labels = _component_labels_per_level(
            gg, theta, levels, kind, level_block=level_block
        )
    return _assemble_from_labels(
        gg, theta, levels, labels, kind, side, prov, meta)


def _assemble_from_labels(
    gg: BipartiteGraph,
    theta: np.ndarray,
    levels: np.ndarray,
    labels: np.ndarray,
    kind: str,
    side: str,
    prov: Dict,
    meta: Optional[Dict],
) -> Hierarchy:
    """Deterministic host assembly: per-level component labels → the
    packed forest.  Split out of :func:`_build_hierarchy_impl` so the
    streaming repair path (:mod:`repro.hierarchy.repair`) can feed it a
    label matrix where only the dirty levels were recomputed — the
    assembly is a pure function of ``(gg, theta, levels, labels)``, so
    identical inputs give a bit-identical forest however the labels were
    obtained."""
    n_ent = gg.m if kind == "wing" else gg.n_u

    # ---- level-ascending node creation (collapsed chains)
    node_level = [0]
    parent = [-1]
    cur = np.zeros(n_ent, dtype=np.int32)       # deepest node so far
    entity_node = np.zeros(n_ent, dtype=np.int32)
    for li, k in enumerate(levels):
        lab = labels[li]
        alive = theta >= k
        own = theta == k
        own_roots = np.unique(lab[own])
        base = len(node_level)
        # parent BEFORE cur is updated: the deepest existing node that
        # contains the component's representative entity
        parent.extend(int(c) for c in cur[own_roots])
        node_level.extend([int(k)] * own_roots.size)
        remap = np.full(n_ent, -1, dtype=np.int64)
        remap[own_roots] = base + np.arange(own_roots.size)
        ali = np.where(alive)[0]
        mapped = remap[lab[ali]]
        hit = mapped >= 0
        cur[ali[hit]] = mapped[hit]
        entity_node[own] = cur[own]

    n_nodes = len(node_level)
    node_level = np.asarray(node_level, dtype=np.int64)
    parent = np.asarray(parent, dtype=np.int32)

    # ---- CSR packings
    member_cnt = np.bincount(entity_node, minlength=n_nodes)
    member_off = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(member_cnt, out=member_off[1:])
    member_ids = np.argsort(entity_node, kind="stable").astype(np.int32)

    child_cnt = np.bincount(parent[1:], minlength=n_nodes)
    child_off = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(child_cnt, out=child_off[1:])
    child_ids = (np.argsort(parent[1:], kind="stable") + 1).astype(np.int32)

    tin, tout = _dfs_order(n_nodes, child_off, child_ids)

    # ---- contiguous subtree slices: entities sorted by their node's tin
    ent_tin = tin[entity_node]
    ent_order = np.argsort(ent_tin, kind="stable").astype(np.int32)
    sorted_tin = ent_tin[ent_order]
    estart = np.searchsorted(sorted_tin, tin).astype(np.int64)
    eend = np.searchsorted(sorted_tin, tout).astype(np.int64)

    # ---- induced-subgraph stats per node
    node_m = np.zeros(n_nodes, dtype=np.int64)
    node_nu = np.zeros(n_nodes, dtype=np.int64)
    node_nv = np.zeros(n_nodes, dtype=np.int64)
    with obs.span("hierarchy.node_stats", cat="hierarchy",
                  n_nodes=int(n_nodes)):
        if kind == "wing":
            eu = gg.edges[:, 0]
            ev = gg.edges[:, 1]
            for x in range(n_nodes):
                ids = ent_order[estart[x]:eend[x]]
                node_m[x] = ids.size
                node_nu[x] = np.unique(eu[ids]).size
                node_nv[x] = np.unique(ev[ids]).size
        else:
            du, _ = gg.degrees()
            offu, nbru, _ = gg.csr_u()  # per-U CSR: neighbors are V ids
            for x in range(n_nodes):
                us = ent_order[estart[x]:eend[x]]
                node_nu[x] = us.size
                node_m[x] = int(du[us].sum())
                if us.size:
                    vs = np.concatenate(
                        [nbru[offu[u]:offu[u + 1]] for u in us]
                    )
                    node_nv[x] = np.unique(vs).size

    span = node_nu * node_nv
    density = np.divide(
        node_m, span, out=np.zeros(n_nodes, dtype=np.float64),
        where=span > 0, casting="unsafe",
    )

    info = dict(kind=kind, side=side, n_entities=int(n_ent))
    info.update(prov)
    if meta:
        info.update(meta)

    return Hierarchy(
        kind=kind,
        n_entities=n_ent,
        theta=theta,
        node_level=node_level,
        parent=parent,
        entity_node=entity_node,
        member_off=member_off,
        member_ids=member_ids,
        child_off=child_off,
        child_ids=child_ids,
        tin=tin,
        tout=tout,
        ent_order=ent_order,
        estart=estart,
        eend=eend,
        node_m=node_m,
        node_nu=node_nu,
        node_nv=node_nv,
        density=density,
        meta=info,
    )
