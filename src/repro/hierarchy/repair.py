"""Dirty-subtree hierarchy repair for streaming updates.

The forest is a pure function of (graph, θ): per-level component
labels → deterministic host assembly (:func:`build._assemble_from_labels`).
Levels are mutually independent fixpoints, so repair recomputes ONLY
the dirty levels' label rows on device and splices them into the
cached label matrix; clean rows are carried over through the monotone
old→new entity id map (min-id component representatives survive a
monotone relabeling).  The assembly then re-runs in full — it is cheap,
host-side, and running it unchanged is what makes the repaired forest
**bit-identical** to a from-scratch build (asserted after every epoch
by ``tests/test_streaming.py``).

Level k is *clean* iff the previous epoch computed it, its member set
(entities with θ ≥ k) is unchanged by key, and no structurally touched
entity is a member on either side — membership gives the same vertex
set, untouchedness gives the same butterfly connectivity, so the
components match.  A θ-changed entity dirties exactly the levels in
(min(θold, θnew), max(θold, θnew)] where its membership flips; a
touched / inserted / deleted entity dirties every level it belongs to
on either side.

:func:`dirty_subtrees` is the serving-side view of the same locality:
preorder stamps make each dirty node's subtree a contiguous
``ent_order[estart:eend)`` slice of the packed forest, so the
stale-but-bounded window during repair is a handful of slices, not the
whole forest.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro import obs
from repro.core.graph import BipartiteGraph
from repro.core.peel import PeelResult
from repro.hierarchy.build import (
    _BIG,
    Hierarchy,
    _assemble_from_labels,
    _component_labels_per_level,
)

__all__ = ["LabelCache", "repair_hierarchy", "dirty_subtrees"]


@dataclasses.dataclass
class LabelCache:
    """Per-level component labels of the previous epoch (the reusable
    half of the forest build)."""

    levels: np.ndarray   # (L,) int64 ascending distinct θ levels ≥ 1
    labels: np.ndarray   # (L, n_entities) int64; _BIG marks dead entities
    theta: np.ndarray    # (n_entities,) int64 — θ the labels were built at


def _dirty_levels(
    levels_new: np.ndarray,
    cache: LabelCache,
    theta_new: np.ndarray,
    old_common: np.ndarray,
    new_common: np.ndarray,
    touched_old: np.ndarray,
    touched_new: np.ndarray,
) -> np.ndarray:
    """Bool mask over ``levels_new``: which levels must recompute."""
    L = levels_new.size
    diff = np.zeros(L + 1, dtype=np.int64)

    def mark(lo_excl: np.ndarray, hi_incl: np.ndarray) -> None:
        # dirty every level k with lo_excl < k <= hi_incl
        a = np.searchsorted(levels_new, lo_excl, side="right")
        b = np.searchsorted(levels_new, hi_incl, side="right")
        keep = a < b
        np.add.at(diff, a[keep], 1)
        np.add.at(diff, b[keep], -1)

    theta_old = cache.theta
    old_only = np.ones(theta_old.size, dtype=bool)
    old_only[old_common] = False
    new_only = np.ones(theta_new.size, dtype=bool)
    new_only[new_common] = False
    # touched / inserted / deleted: dirty every level they belong to
    prefix_hi = np.concatenate([
        theta_old[old_only | touched_old],
        theta_new[new_only | touched_new],
    ])
    if prefix_hi.size:
        mark(np.zeros(1, dtype=np.int64),
             np.asarray([prefix_hi.max()], dtype=np.int64))
    # θ-changed survivors: membership flips in (min, max]
    to = theta_old[old_common]
    tn = theta_new[new_common]
    chg = to != tn
    if chg.any():
        mark(np.minimum(to[chg], tn[chg]), np.maximum(to[chg], tn[chg]))
    dirty = np.cumsum(diff[:L]) > 0
    dirty |= ~np.isin(levels_new, cache.levels)
    return dirty


def repair_hierarchy(
    g: BipartiteGraph,
    result: Union[PeelResult, np.ndarray],
    kind: str = "wing",
    side: str = "u",
    cache: Optional[LabelCache] = None,
    old_common: Optional[np.ndarray] = None,
    new_common: Optional[np.ndarray] = None,
    touched_old: Optional[np.ndarray] = None,
    touched_new: Optional[np.ndarray] = None,
    meta: Optional[Dict] = None,
    level_block: int = 32,
) -> Tuple[Hierarchy, LabelCache, int, int]:
    """Rebuild the forest, recomputing only the dirty levels.

    With ``cache=None`` every level computes fresh (the first epoch /
    the full-build fallback).  Returns ``(hierarchy, new_cache,
    levels_dirty, levels_total)``; the hierarchy is bit-identical to
    ``build_hierarchy(g, result, kind, side)`` however many levels were
    reused."""
    if kind not in ("wing", "tip"):
        raise ValueError(kind)
    gg = g if (kind == "wing" or side == "u") else g.transpose()
    if isinstance(result, PeelResult):
        theta = np.asarray(result.theta, dtype=np.int64)
        prov = result.provenance()
    else:
        theta = np.asarray(result, dtype=np.int64)
        prov = {}
    n_ent = gg.m if kind == "wing" else gg.n_u
    if theta.shape != (n_ent,):
        raise ValueError(
            f"theta has shape {theta.shape}, expected ({n_ent},) for "
            f"kind={kind!r}")

    levels = np.unique(theta[theta > 0])
    L = levels.size
    if cache is None:
        dirty = np.ones(L, dtype=bool)
    else:
        dirty = _dirty_levels(
            levels, cache, theta, old_common, new_common,
            touched_old, touched_new)
    n_dirty = int(dirty.sum())

    with obs.span("hierarchy.repair", cat="hierarchy", kind=kind,
                  levels=L, levels_dirty=n_dirty):
        labels = np.empty((L, n_ent), dtype=np.int64)
        if cache is not None and n_dirty < L:
            # carry clean rows through the monotone old→new id map:
            # label values are member entity ids (all common on a clean
            # level), so translating them preserves the component min
            old2new = np.full(cache.theta.size, _BIG, dtype=np.int64)
            old2new[old_common] = new_common
            old_row = {int(k): i for i, k in enumerate(cache.levels)}
            for i in np.where(~dirty)[0]:
                row_old = cache.labels[old_row[int(levels[i])]]
                row = np.full(n_ent, _BIG, dtype=np.int64)
                vals = row_old[old_common]
                alive = vals != _BIG
                mapped = np.where(alive, old2new[np.where(alive, vals, 0)],
                                  _BIG)
                row[new_common] = mapped
                labels[i] = row
        if n_dirty:
            with obs.span("hierarchy.labels", cat="hierarchy",
                          levels=n_dirty):
                fresh = _component_labels_per_level(
                    gg, theta, levels[dirty], kind,
                    level_block=level_block)
            labels[dirty] = fresh

        h = _assemble_from_labels(
            gg, theta, levels, labels, kind, side, prov, meta)
    return h, LabelCache(levels.copy(), labels, theta.copy()), n_dirty, L


def dirty_subtrees(
    h: Hierarchy, entity_ids: np.ndarray
) -> Tuple[np.ndarray, List[Tuple[int, int]]]:
    """The packed-forest regions an affected entity set can invalidate.

    Returns ``(nodes, slices)``: the affected entities' home nodes and
    the merged ``[estart, eend)`` intervals of their subtrees in
    ``ent_order`` — contiguous by the preorder stamps, so a serving
    layer can bound answer staleness during repair to Σ slice lengths
    entities instead of flagging the whole forest."""
    entity_ids = np.asarray(entity_ids)
    if entity_ids.size == 0:
        return np.zeros(0, dtype=np.int64), []
    nodes = np.unique(h.entity_node[entity_ids]).astype(np.int64)
    ivs = sorted((int(h.estart[x]), int(h.eend[x])) for x in nodes)
    merged: List[Tuple[int, int]] = []
    for lo, hi in ivs:
        if merged and lo <= merged[-1][1]:
            merged[-1] = (merged[-1][0], max(merged[-1][1], hi))
        else:
            merged.append((lo, hi))
    return nodes, merged
