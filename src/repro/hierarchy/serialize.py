"""Versioned save/load of hierarchy forests as flat npz.

A decomposition is computed once (minutes of peeling) and served
forever (microseconds of gathers) — the artifact boundary is this
module.  Layout: every :class:`~repro.hierarchy.build.Hierarchy` array
under its field name, plus a single JSON ``meta`` blob carrying the
format version, kind, and provenance (engine-tagged
:class:`~repro.core.peel.PeelStats` dict, CD partition/ranges arrays
ride along as first-class arrays).  Loading validates the version and
returns a fully reconstructed ``Hierarchy`` — the engine tags survive
the round-trip bit-for-bit (regression-tested).
"""
from __future__ import annotations

import io
import json
import os
from typing import Union

import numpy as np

from .build import Hierarchy

__all__ = ["FORMAT_VERSION", "save_hierarchy", "load_hierarchy"]

FORMAT_VERSION = 1

_ARRAY_FIELDS = (
    "theta", "node_level", "parent", "entity_node",
    "member_off", "member_ids", "child_off", "child_ids",
    "tin", "tout", "ent_order", "estart", "eend",
    "node_m", "node_nu", "node_nv", "density",
)
# provenance arrays that may ride in meta (PeelResult.provenance())
_META_ARRAYS = ("part", "ranges", "support_init")


def save_hierarchy(path: Union[str, os.PathLike, io.IOBase],
                   h: Hierarchy) -> None:
    """Write ``h`` to ``path`` (npz).  Flat arrays only — no pickling,
    so artifacts are portable across python/numpy versions.  The file
    lands at EXACTLY ``path`` (``np.savez`` would silently append
    ``.npz`` to suffix-less string paths, leaving the artifact where
    neither the caller nor ``load_hierarchy`` looks)."""
    meta = dict(h.meta)
    arrays = {f: getattr(h, f) for f in _ARRAY_FIELDS}
    for key in _META_ARRAYS:
        if key in meta:
            arrays[f"meta_{key}"] = np.asarray(meta.pop(key))
    header = dict(
        format_version=FORMAT_VERSION,
        kind=h.kind,
        n_entities=int(h.n_entities),
        meta=meta,
    )
    payload = dict(
        header=np.frombuffer(
            json.dumps(header).encode("utf-8"), dtype=np.uint8
        ),
        **arrays,
    )
    if isinstance(path, (str, os.PathLike)):
        with open(path, "wb") as fh:
            np.savez_compressed(fh, **payload)
    else:
        np.savez_compressed(path, **payload)


def load_hierarchy(path: Union[str, os.PathLike, io.IOBase]) -> Hierarchy:
    """Load a hierarchy artifact; raises ``ValueError`` on a format
    version this code does not understand."""
    with np.load(path) as z:
        header = json.loads(bytes(z["header"].tobytes()).decode("utf-8"))
        version = header.get("format_version")
        if version != FORMAT_VERSION:
            raise ValueError(
                f"hierarchy artifact format {version!r} unsupported "
                f"(this build reads {FORMAT_VERSION})"
            )
        arrays = {f: z[f] for f in _ARRAY_FIELDS}
        meta = header["meta"]
        for key in _META_ARRAYS:
            if f"meta_{key}" in z.files:
                meta[key] = z[f"meta_{key}"]
    return Hierarchy(
        kind=header["kind"],
        n_entities=int(header["n_entities"]),
        meta=meta,
        **arrays,
    )
