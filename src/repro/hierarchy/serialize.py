"""Versioned save/load of hierarchy forests as flat npz.

A decomposition is computed once (minutes of peeling) and served
forever (microseconds of gathers) — the artifact boundary is this
module.  Layout: every :class:`~repro.hierarchy.build.Hierarchy` array
under its field name, plus a single JSON ``meta`` blob carrying the
format version, kind, and provenance (engine-tagged
:class:`~repro.core.peel.PeelStats` dict, CD partition/ranges arrays
ride along as first-class arrays).  Loading validates the version and
returns a fully reconstructed ``Hierarchy`` — the engine tags survive
the round-trip bit-for-bit (regression-tested).

Format history (artifacts outlive the code that wrote them — the
loader keeps a branch per shipped version):

* **v1** — the Hierarchy arrays + meta header.
* **v2** — v1 plus a *pack cache*: the ``depth`` vector and
  binary-lifting ``up`` table that :func:`~repro.hierarchy.query.pack_forest`
  otherwise rebuilds with an O(n_nodes) host walk on every load.  The
  multi-tenant pool reads thousands of cold artifacts off disk into
  live slots, so load time is a serving metric there — v2 makes a cold
  load pure array reads.  v1 files still load (the pack cache is
  simply recomputed); ``save_hierarchy(..., version=1)`` keeps writing
  the old layout for compatibility tests.
"""
from __future__ import annotations

import io
import json
import os
from typing import Union

import numpy as np

from .build import Hierarchy
from .query import depth_and_up

__all__ = ["FORMAT_VERSION", "save_hierarchy", "load_hierarchy"]

FORMAT_VERSION = 2
_SUPPORTED_VERSIONS = (1, 2)

_ARRAY_FIELDS = (
    "theta", "node_level", "parent", "entity_node",
    "member_off", "member_ids", "child_off", "child_ids",
    "tin", "tout", "ent_order", "estart", "eend",
    "node_m", "node_nu", "node_nv", "density",
)
# provenance arrays that may ride in meta (PeelResult.provenance())
_META_ARRAYS = ("part", "ranges", "support_init")
# v2 pack cache: query.pack_forest / the tenant pool read these from
# meta instead of re-walking the parent array on every cold load
_PACK_ARRAYS = ("pack_depth", "pack_up")


def save_hierarchy(path: Union[str, os.PathLike, io.IOBase],
                   h: Hierarchy, version: int = FORMAT_VERSION) -> None:
    """Write ``h`` to ``path`` (npz).  Flat arrays only — no pickling,
    so artifacts are portable across python/numpy versions.  The file
    lands at EXACTLY ``path`` (``np.savez`` would silently append
    ``.npz`` to suffix-less string paths, leaving the artifact where
    neither the caller nor ``load_hierarchy`` looks).  ``version``
    selects the written layout (old versions stay writable so the
    loader branches remain testable against real files)."""
    if version not in _SUPPORTED_VERSIONS:
        raise ValueError(
            f"cannot write hierarchy format {version!r} "
            f"(writable: {_SUPPORTED_VERSIONS})")
    meta = dict(h.meta)
    meta.pop("pack_depth", None)
    meta.pop("pack_up", None)
    arrays = {f: getattr(h, f) for f in _ARRAY_FIELDS}
    for key in _META_ARRAYS:
        if key in meta:
            arrays[f"meta_{key}"] = np.asarray(meta.pop(key))
    if version >= 2:
        depth, up = depth_and_up(np.asarray(h.parent))
        arrays["pack_depth"] = depth
        arrays["pack_up"] = up
    header = dict(
        format_version=version,
        kind=h.kind,
        n_entities=int(h.n_entities),
        meta=meta,
    )
    payload = dict(
        header=np.frombuffer(
            json.dumps(header).encode("utf-8"), dtype=np.uint8
        ),
        **arrays,
    )
    if isinstance(path, (str, os.PathLike)):
        with open(path, "wb") as fh:
            np.savez_compressed(fh, **payload)
    else:
        np.savez_compressed(path, **payload)


def load_hierarchy(path: Union[str, os.PathLike, io.IOBase]) -> Hierarchy:
    """Load a hierarchy artifact; raises ``ValueError`` on a format
    version this code does not understand.  One loader branch per
    shipped version: v1 files lack the pack cache (it is recomputed on
    first ``pack_forest``), v2 files carry it in ``meta``."""
    with np.load(path) as z:
        header = json.loads(bytes(z["header"].tobytes()).decode("utf-8"))
        version = header.get("format_version")
        if version not in _SUPPORTED_VERSIONS:
            raise ValueError(
                f"hierarchy artifact format {version!r} unsupported "
                f"(this build reads {_SUPPORTED_VERSIONS})"
            )
        arrays = {f: z[f] for f in _ARRAY_FIELDS}
        meta = header["meta"]
        for key in _META_ARRAYS:
            if f"meta_{key}" in z.files:
                meta[key] = z[f"meta_{key}"]
        if version >= 2:
            for key in _PACK_ARRAYS:
                meta[key] = z[key]
    return Hierarchy(
        kind=header["kind"],
        n_entities=int(header["n_entities"]),
        meta=meta,
        **arrays,
    )
