"""O(1) / O(log) queries against the packed hierarchy forest.

:class:`PackedForest` is the device-resident view of a
:class:`~repro.hierarchy.build.Hierarchy`: flat int32 arrays (preorder
stamps, entity→node, binary-lifting table) that every query reads with
gathers — no tree walking, no host round-trips inside a batch.

* containment      — an entity's subtree test is one interval check on
  preorder stamps (``tin``/``tout``), so ``subgraph_at`` is a vectorized
  compare over all entities.
* ancestors / LCA  — binary lifting over ``up[:, j] = 2^j``-th ancestor,
  O(log depth) per query and batch-friendly (pure elementwise algebra,
  no data-dependent control flow).

All batched entry points accept arrays and are jit-compiled; scalar use
just passes size-1 arrays.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from .build import Hierarchy

__all__ = [
    "PackedForest",
    "depth_and_up",
    "extend_up",
    "pack_forest",
    "max_k_containing",
    "node_of",
    "subgraph_at",
    "lca_nodes",
    "lca_entities",
    "density_profile",
    "top_densest_leaves",
]


@dataclasses.dataclass(frozen=True)
class PackedForest:
    """Device-resident arrays of one hierarchy (see :func:`pack_forest`)."""

    n_nodes: int
    n_entities: int
    J: int                    # binary-lifting levels (static)
    theta: jax.Array          # (n_entities,) int32
    entity_node: jax.Array    # (n_entities,) int32
    ent_tin: jax.Array        # (n_entities,) int32 — tin of entity's node
    node_level: jax.Array     # (n_nodes,) int32
    depth: jax.Array          # (n_nodes,) int32
    tin: jax.Array            # (n_nodes,) int32
    tout: jax.Array           # (n_nodes,) int32
    node_size: jax.Array      # (n_nodes,) int32 — subtree entity count
    up: jax.Array             # (n_nodes, J) int32 — 2^j-th ancestors


def depth_and_up(parent: np.ndarray, J: int = 0):
    """Host-side depth vector + binary-lifting table from ``parent``.

    ``up[:, j]`` is the ``2^j``-th ancestor (the root lifts to itself).
    ``J`` widens the table to at least that many levels — extra levels
    are identity columns past the root, so any ``J`` ≥ the minimum is
    answer-equivalent (the pool pads all tenants of a shape bucket to
    the bucket's static ``J``).  Returns ``(depth, up)``.
    """
    n = int(parent.shape[0])
    depth = np.zeros(n, dtype=np.int32)
    for x in range(1, n):                      # parent[x] < x always
        depth[x] = depth[parent[x]] + 1
    max_depth = int(depth.max()) if n else 0
    J = max(1, J, int(np.ceil(np.log2(max_depth + 1))) if max_depth else 1)
    up = np.zeros((n, J), dtype=np.int32)
    up[:, 0] = np.maximum(parent, 0)           # root lifts to itself
    for j in range(1, J):
        up[:, j] = up[up[:, j - 1], j - 1]
    return depth, up


def extend_up(up: np.ndarray, J: int) -> np.ndarray:
    """Widen a lifting table to ``J`` levels by repeated squaring —
    lets a v2 artifact's stored table serve a pool bucket whose static
    ``J`` exceeds the tenant's own depth."""
    cols = [up[:, j] for j in range(up.shape[1])]
    while len(cols) < J:
        prev = cols[-1]
        cols.append(prev[prev])
    return np.stack(cols[:max(J, 1)], axis=1).astype(np.int32)


def pack_forest(h: Hierarchy) -> PackedForest:
    """Host → device packing; also materializes depth + lifting table
    (reused from the artifact's pack cache when a v2 file carried
    one — cold loads then skip the O(n) host walk)."""
    n = h.n_nodes
    depth = np.asarray(h.meta.get("pack_depth", ()), dtype=np.int32)
    up = np.asarray(h.meta.get("pack_up", ()), dtype=np.int32)
    if depth.shape != (n,) or up.ndim != 2 or up.shape[0] != n:
        depth, up = depth_and_up(h.parent)
    J = up.shape[1]
    # entity-less hierarchies still pack (node-arg queries remain
    # valid); a single root-pointing sentinel slot keeps the jitted
    # *gathers* (theta[a], entity_node[a]) well-formed — entity queries
    # are rejected host-side before dispatch, and ent_tin stays
    # unpadded because it is only ever broadcast, never indexed.
    theta = h.theta if h.n_entities else np.zeros(1, np.int64)
    ent_node = h.entity_node if h.n_entities else np.zeros(1, np.int32)
    return PackedForest(
        n_nodes=n,
        n_entities=h.n_entities,
        J=J,
        theta=jnp.asarray(theta.astype(np.int32)),
        entity_node=jnp.asarray(ent_node),
        ent_tin=jnp.asarray(h.tin[h.entity_node].astype(np.int32)),
        node_level=jnp.asarray(h.node_level.astype(np.int32)),
        depth=jnp.asarray(depth),
        tin=jnp.asarray(h.tin),
        tout=jnp.asarray(h.tout),
        node_size=jnp.asarray((h.eend - h.estart).astype(np.int32)),
        up=jnp.asarray(up),
    )


# =====================================================================
# Point lookups — O(1) gathers
# =====================================================================
def max_k_containing(f: PackedForest, ids) -> jax.Array:
    """Largest k whose k-subgraph still contains each entity — its θ."""
    return f.theta[jnp.asarray(ids)]


def node_of(f: PackedForest, ids) -> jax.Array:
    """Deepest hierarchy node containing each entity."""
    return f.entity_node[jnp.asarray(ids)]


@partial(jax.jit, static_argnames=())
def _subgraph_masks(ent_tin, tin, tout, nodes):
    lo = tin[nodes]
    hi = tout[nodes]
    return (ent_tin[None, :] >= lo[:, None]) & (ent_tin[None, :] < hi[:, None])


def subgraph_at(f: PackedForest, nodes) -> jax.Array:
    """(len(nodes), n_entities) bool — entity mask of each node's
    subgraph (edges for wing, one-side vertices for tip).  One interval
    compare per entity; no tree traversal."""
    nodes = jnp.atleast_1d(jnp.asarray(nodes))
    return _subgraph_masks(f.ent_tin, f.tin, f.tout, nodes)


# =====================================================================
# LCA — binary lifting, elementwise (batch = array in, array out)
# =====================================================================
@partial(jax.jit, static_argnames=("J",))
def _lca(up, depth, x, y, J: int):
    dx = depth[x]
    dy = depth[y]
    swap = dy > dx
    a = jnp.where(swap, y, x)
    b = jnp.where(swap, x, y)
    diff = depth[a] - depth[b]
    for j in range(J):                     # lift a to b's depth
        a = jnp.where((diff >> j) & 1 > 0, up[a, j], a)
    eq = a == b
    for j in range(J - 1, -1, -1):         # descend to just below LCA
        ne = (up[a, j] != up[b, j]) & ~eq
        a = jnp.where(ne, up[a, j], a)
        b = jnp.where(ne, up[b, j], b)
    return jnp.where(eq, a, up[a, 0])


def lca_nodes(f: PackedForest, x, y) -> jax.Array:
    """Lowest common ancestor node(s) — the smallest dense subgraph in
    the hierarchy containing both."""
    return _lca(f.up, f.depth, jnp.asarray(x), jnp.asarray(y), f.J)


def lca_entities(f: PackedForest, e1, e2) -> jax.Array:
    """Smallest common dense subgraph of two entities (node id); its
    level is ``f.node_level[lca_entities(...)]``."""
    e1 = jnp.asarray(e1)
    e2 = jnp.asarray(e2)
    return _lca(f.up, f.depth, f.entity_node[e1], f.entity_node[e2], f.J)


# =====================================================================
# Aggregates — host-side on the Hierarchy (one-shot analytics)
# =====================================================================
def density_profile(h: Hierarchy, k: int) -> Dict:
    """Components of the k-subgraph (θ ≥ k): the maximal nodes with
    level ≥ k.  Returns their ids, subtree entity counts, induced
    subgraph sizes, and edge densities m/(nu·nv)."""
    if k <= 0:
        sel = np.array([0])
    else:
        plev = np.where(h.parent >= 0, h.node_level[np.maximum(h.parent, 0)],
                        -1)
        sel = np.where((h.node_level >= k) & (plev < k))[0]
    return dict(
        k=int(k),
        nodes=sel,
        n_components=int(sel.size),
        sizes=(h.eend - h.estart)[sel],
        m=h.node_m[sel],
        nu=h.node_nu[sel],
        nv=h.node_nv[sel],
        density=h.density[sel],
    )


def top_densest_leaves(h: Hierarchy, t: int = 10) -> Dict:
    """The t densest leaves — the innermost (undominated) dense
    subgraphs, ranked by induced edge density."""
    leaf = np.diff(h.child_off) == 0
    ids = np.where(leaf)[0]
    order = np.argsort(-h.density[ids], kind="stable")[:t]
    sel = ids[order]
    return dict(
        nodes=sel,
        level=h.node_level[sel],
        density=h.density[sel],
        m=h.node_m[sel],
        nu=h.node_nu[sel],
        nv=h.node_nv[sel],
    )
