"""Hierarchy subsystem — the dense-subgraph DAG the paper actually sells.

``core.peel`` produces entity numbers θ; this package turns them into the
*hierarchy* of butterfly-dense subgraphs they induce (Sarıyüce's k-tip /
k-wing nuclei) and serves it:

* :mod:`build`     — θ → packed forest (batched label-propagation
  connected components per level, one ``lax.while_loop``).
* :mod:`query`     — O(1)/O(log) queries on the packed forest
  (containment, subgraph masks, LCA, density profiles).
* :mod:`serialize` — versioned flat-npz save/load: decompose once,
  serve forever.
* :mod:`serve`     — :class:`HierarchyService`, a batched query engine
  answering vmapped mixed-op batches from device-resident arrays.
"""
from .build import Hierarchy, build_hierarchy
from .query import (
    PackedForest,
    density_profile,
    lca_entities,
    lca_nodes,
    max_k_containing,
    node_of,
    pack_forest,
    subgraph_at,
    top_densest_leaves,
)
from .serialize import FORMAT_VERSION, load_hierarchy, save_hierarchy
from .serve import OPS, HierarchyService, HQuery

__all__ = [
    "Hierarchy",
    "build_hierarchy",
    "PackedForest",
    "pack_forest",
    "max_k_containing",
    "node_of",
    "subgraph_at",
    "lca_nodes",
    "lca_entities",
    "density_profile",
    "top_densest_leaves",
    "FORMAT_VERSION",
    "save_hierarchy",
    "load_hierarchy",
    "HierarchyService",
    "HQuery",
    "OPS",
]
