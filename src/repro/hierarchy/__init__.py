"""Hierarchy subsystem — the dense-subgraph DAG the paper actually sells.

``core.peel`` produces entity numbers θ; this package turns them into the
*hierarchy* of butterfly-dense subgraphs they induce (Sarıyüce's k-tip /
k-wing nuclei) and serves it:

* :mod:`build`     — θ → packed forest (batched label-propagation
  connected components per level, one ``lax.while_loop``).
* :mod:`query`     — O(1)/O(log) queries on the packed forest
  (containment, subgraph masks, LCA, density profiles).
* :mod:`serialize` — versioned flat-npz save/load: decompose once,
  serve forever.
* :mod:`serve`     — :class:`HierarchyService`, a batched query engine
  answering vmapped mixed-op batches from device-resident arrays.
* :mod:`pool`      — :class:`ForestPool`, many tenants' forests stacked
  into shape-bucketed batched arrays behind an LRU artifact cache.
* :mod:`multiserve` — :class:`MultiTenantService`, cross-tenant
  slot-batched mixed-op serving: one jitted dispatch per shape bucket.
"""
from .build import Hierarchy, build_hierarchy
from .multiserve import MTQuery, MultiTenantService
from .pool import ForestPool, PoolFull
from .query import (
    PackedForest,
    density_profile,
    depth_and_up,
    lca_entities,
    lca_nodes,
    max_k_containing,
    node_of,
    pack_forest,
    subgraph_at,
    top_densest_leaves,
)
from .serialize import FORMAT_VERSION, load_hierarchy, save_hierarchy
from .serve import OPS, HierarchyService, HQuery

__all__ = [
    "Hierarchy",
    "build_hierarchy",
    "PackedForest",
    "pack_forest",
    "max_k_containing",
    "node_of",
    "subgraph_at",
    "lca_nodes",
    "lca_entities",
    "density_profile",
    "top_densest_leaves",
    "FORMAT_VERSION",
    "save_hierarchy",
    "load_hierarchy",
    "HierarchyService",
    "HQuery",
    "OPS",
    "depth_and_up",
    "ForestPool",
    "PoolFull",
    "MTQuery",
    "MultiTenantService",
]
