"""Cross-tenant slot-batched hierarchy serving over a ForestPool.

:class:`MultiTenantService` is :class:`~repro.hierarchy.serve.HierarchyService`
lifted to many tenants: every queue entry carries ``(tenant, op, a, b)``,
the engine groups queued slots by the tenant's *shape bucket*, and ONE
jitted dispatch per bucket answers every tenant in it.  The kernel is
``serve._answer_batch`` extended with a leading tenant-gather — each
slot first selects its tenant's row of the bucket's stacked arrays,
then runs the same branchless answer-family select, so a mixed-tenant
mixed-op batch costs exactly one compiled program per bucket shape
(compile-count asserted in tests; answers are bit-identical to a
per-tenant ``HierarchyService``).

Cold tenants are loaded through the pool's LRU artifact cache at
submit time; loading cannot evict any tenant that still has queued
slots, so a batch can never be invalidated by its own admissions.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from .pool import BucketKey, ForestPool
from .serve import _OP_NAMES, OPS

__all__ = ["MTQuery", "MultiTenantService"]


@dataclasses.dataclass
class MTQuery:
    """One query against one tenant; ``result`` is filled by the engine."""

    uid: int
    tenant: str
    op: str
    a: int
    b: int = 0
    result: Optional[int] = None
    done: bool = False


def _lca_multi(up, depth, t, x, y, J: int):
    """Binary-lifting LCA with a leading tenant axis: identical algebra
    to ``query._lca``, every gather routed through tenant row ``t``."""
    dx = depth[t, x]
    dy = depth[t, y]
    swap = dy > dx
    a = jnp.where(swap, y, x)
    b = jnp.where(swap, x, y)
    diff = depth[t, a] - depth[t, b]
    for j in range(J):                     # lift a to b's depth
        a = jnp.where((diff >> j) & 1 > 0, up[t, a, j], a)
    eq = a == b
    for j in range(J - 1, -1, -1):         # descend to just below LCA
        ne = (up[t, a, j] != up[t, b, j]) & ~eq
        a = jnp.where(ne, up[t, a, j], a)
        b = jnp.where(ne, up[t, b, j], b)
    return jnp.where(eq, a, up[t, a, 0])


@partial(jax.jit, static_argnames=("J",))
def _answer_batch_multi(
    theta, entity_node, node_level, depth, node_size, up,
    tenant, ops, a, b, J: int,
):
    """``serve._answer_batch`` with a leading tenant-gather: arrays are
    (slots, …) stacks, ``tenant`` routes each query slot to its row.
    Same op table, same branchless select — the two kernels cannot
    desynchronize because both key through :data:`OPS` by name."""
    ea = entity_node[tenant, a]
    lca = _lca_multi(up, depth, tenant, ea, entity_node[tenant, b], J)
    answers = {
        "max_k": theta[tenant, a],
        "node_of": ea,
        "lca_node": lca,
        "lca_level": node_level[tenant, lca],
        "subtree_size": node_size[tenant, a],
    }
    assert answers.keys() == OPS.keys()
    return jnp.select(
        [ops == OPS[name] for name in answers],
        list(answers.values()),
        default=jnp.int32(-1),
    )


def _tenant_counts(tenants: Sequence[str]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for t in tenants:
        counts[t] = counts.get(t, 0) + 1
    return counts


def compiled_dispatch_count() -> int:
    """Number of compiled multi-tenant dispatch programs — one per
    (bucket shape, batch size) the service has seen.  The zero-retrace
    invariant is stated on this counter: cold-loading a tenant into an
    existing bucket must not change it."""
    return _answer_batch_multi._cache_size()


class MultiTenantService:
    """Slot-batched mixed-op serving across every tenant of a pool.

    ``batch`` is the slot count of each compiled dispatch; queued
    queries are grouped per shape bucket and padded with no-op slots,
    so one XLA program per bucket serves any query/tenant mix.

    Example::

        pool = ForestPool(slots=8, artifact_dir="/data/hierarchies")
        svc = MultiTenantService(pool, batch=256)
        svc.submit(MTQuery(uid=0, tenant="books", op="max_k", a=3))
        svc.submit(MTQuery(uid=1, tenant="games", op="lca_level", a=1, b=7))
        print([q.result for q in svc.run()])
    """

    def __init__(self, pool: ForestPool, batch: int = 1024):
        self.pool = pool
        self.batch = int(batch)
        self.queue: Deque[MTQuery] = deque()
        self.served = 0
        self.dispatches = 0
        # shares the pool's registry: one snapshot covers cache + serve
        self.metrics = pool.metrics

    # ------------------------------------------------------------ admin
    def _validate(self, tenant: str, op: str, a: int, b: int) -> None:
        """Bounds-check against the TENANT's true dims (not the padded
        bucket shape — jitted gathers clamp, so an id past the tenant's
        real range would otherwise read another tenant's padding and
        answer confidently wrong)."""
        if op not in OPS:
            raise ValueError(f"unknown op {op!r} (choose from {set(OPS)})")
        m = self.pool.meta[tenant]
        a_lim = m.n_nodes if op == "subtree_size" else m.n_entities
        bad = not 0 <= a < a_lim
        if op in ("lca_node", "lca_level"):
            bad |= not 0 <= b < m.n_entities
        if bad:
            raise ValueError(
                f"query id out of range: tenant={tenant} op={op} a={a} "
                f"b={b} (n_entities={m.n_entities}, n_nodes={m.n_nodes})"
            )

    def submit(self, q: MTQuery) -> None:
        """Queue one query; the tenant is ensured resident (cold load
        through the LRU cache) and protected from eviction until its
        batch retires."""
        self.pool.ensure(q.tenant)
        self._validate(q.tenant, q.op, q.a, q.b)
        self.pool.note_queued(q.tenant, +1)
        self.queue.append(q)
        self.metrics.set_gauge("serve.queue_depth", len(self.queue))

    def pending(self) -> int:
        """Number of queued queries not yet served by :meth:`run`."""
        return len(self.queue)

    # ------------------------------------------------------------ serve
    def query_batch(
        self, tenants: Sequence[str], ops: np.ndarray, a: np.ndarray,
        b: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Raw batched entry: parallel arrays of tenant ids, op codes
        and args → int32 answers.  Slots are grouped by shape bucket
        and each group dispatches in fixed ``batch``-slot chunks.  Used
        directly by benchmarks; :meth:`run` wraps it."""
        ops = np.asarray(ops, dtype=np.int32)
        a = np.asarray(a, dtype=np.int32)
        b = np.zeros_like(a) if b is None else np.asarray(b, dtype=np.int32)
        tenants = list(tenants)
        if not (len(tenants) == ops.size == a.size == b.size):
            raise ValueError("tenants/ops/a/b must be parallel arrays")
        distinct = list(dict.fromkeys(tenants))
        # pin every already-known tenant against eviction BEFORE any
        # cold load: an admission mid-batch must not drop another
        # tenant whose slots ride in this same batch
        pinned = [t for t in distinct if t in self.pool.meta]
        for t in pinned:
            self.pool.note_queued(t, +1)
        try:
            for t in distinct:
                self.pool.ensure(t)
                if t not in pinned:
                    self.pool.note_queued(t, +1)
                    pinned.append(t)
            for i, t in enumerate(tenants):
                self._validate(t, _OP_NAMES[int(ops[i])], int(a[i]),
                               int(b[i]))
            return self._dispatch_grouped(tenants, ops, a, b)
        finally:
            for t in pinned:
                self.pool.note_queued(t, -1)

    def _dispatch_grouped(self, tenants, ops, a, b) -> np.ndarray:
        """Group validated slots by bucket, dispatch each group in
        fixed-size padded chunks, scatter answers back to slot order."""
        out = np.zeros(len(tenants), np.int32)
        groups: Dict[BucketKey, List[int]] = {}
        slot_of = {t: self.pool.meta[t].slot for t in set(tenants)}
        for i, t in enumerate(tenants):
            groups.setdefault(self.pool.meta[t].bucket, []).append(i)
        for key, idx in groups.items():
            arrs = self.pool.bucket_arrays(key)
            J = self.buckets_J(key)
            for lo in range(0, len(idx), self.batch):
                chunk = idx[lo:lo + self.batch]
                n = len(chunk)
                # pad with subtree_size(node 0) on tenant-slot 0 — the
                # root always exists for a resident tenant, and a free
                # slot 0 is all zeros (answer 0, masked out anyway)
                t_sl = np.zeros(self.batch, np.int32)
                op_c = np.full(self.batch, OPS["subtree_size"], np.int32)
                a_c = np.zeros(self.batch, np.int32)
                b_c = np.zeros(self.batch, np.int32)
                for j, i in enumerate(chunk):
                    t_sl[j] = slot_of[tenants[i]]
                    op_c[j] = ops[i]
                    a_c[j] = a[i]
                    b_c[j] = b[i]
                t0 = time.perf_counter()
                with obs.span("serve.dispatch", cat="serve",
                              bucket=list(key), n=n):
                    res = _answer_batch_multi(
                        arrs["theta"], arrs["entity_node"],
                        arrs["node_level"], arrs["depth"],
                        arrs["node_size"], arrs["up"],
                        jnp.asarray(t_sl), jnp.asarray(op_c),
                        jnp.asarray(a_c), jnp.asarray(b_c), J,
                    )
                    out[chunk] = np.asarray(res)[:n]
                self.metrics.observe("serve.dispatch_ms",
                                     (time.perf_counter() - t0) * 1e3)
                self.metrics.inc("serve.dispatches")
                self.metrics.inc("serve.slots_padded", self.batch - n)
                self.dispatches += 1
                self.served += n
        self.metrics.inc("serve.served", len(tenants))
        for t, cnt in _tenant_counts(tenants).items():
            self.metrics.inc(f"serve.tenant.{t}", cnt)
        return out

    def buckets_J(self, key: BucketKey) -> int:
        """The bucket's static binary-lifting depth (part of the
        compiled dispatch signature)."""
        return self.pool.buckets[key].J

    def run(self) -> List[MTQuery]:
        """Drain the queue; returns completed queries in uid order (the
        ContinuousBatcher contract, like ``HierarchyService.run``)."""
        todo = list(self.queue)
        self.queue.clear()
        self.metrics.set_gauge("serve.queue_depth", 0)
        if todo:
            res = self._dispatch_grouped(
                [q.tenant for q in todo],
                np.asarray([OPS[q.op] for q in todo], np.int32),
                np.asarray([q.a for q in todo], np.int32),
                np.asarray([q.b for q in todo], np.int32),
            )
            for q, r in zip(todo, res):
                q.result = int(r)
                q.done = True
                self.pool.note_queued(q.tenant, -1)
        return sorted(todo, key=lambda q: q.uid)
