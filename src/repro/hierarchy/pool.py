"""Shape-bucketed multi-tenant forest pool with an LRU artifact cache.

One :class:`~repro.hierarchy.serve.HierarchyService` serves ONE forest;
production traffic is thousands of tenant graphs (per-category,
per-region, per-time-window) behind one endpoint.  :class:`ForestPool`
holds many packed forests at once, stacked so that one jitted dispatch
can answer a mixed-tenant batch:

* **Shape buckets** — tenants land in quarter-power-of-two buckets over
  ``(n_nodes, n_entities)`` (the same :func:`~repro.core.peelspec._bucket_pad`
  trick the FD drivers use for partition stacks).  Every tenant of a
  bucket pads to the bucket shape and stacks on a leading *slot* axis,
  so the compiled query program is a function of the bucket, not the
  tenant: admitting a tenant into a free slot changes array *values*,
  never shapes — zero retraces (compile-count asserted in tests).
* **Static lifting depth** — the binary-lifting ``J`` is derived from
  the bucket's padded node count (depth < n_nodes always), not from any
  tenant's actual depth, so it cannot vary within a bucket.  Extra
  levels are identity lifts past the root — answer-equivalent.
* **LRU artifact cache** — cold tenants load from the versioned npz
  artifacts (:mod:`~repro.hierarchy.serialize`) into a free slot;
  when the pool is full the least-recently-used tenant is evicted.
  Eviction is pinned-aware and never drops a tenant with queued slots
  (in-flight queries), so a cold load can never invalidate a batch it
  is part of.  v2 artifacts carry the pack cache (depth + lifting
  table), making a cold load pure array reads + one device upload.

* **Per-slot admission upload** — admitting into a bucket that is
  already device-resident updates just that tenant's slot row with
  ``jax.lax.dynamic_update_slice`` (O(row) transfer) instead of
  dirtying the whole bucket; ``slot_upload=False`` restores the
  whole-bucket re-upload (the bench A/B, row
  ``serve.admit.slot/bucket``).  Timed into the
  ``pool.admission_upload_ms`` / ``pool.bucket_upload_ms`` metrics.

Capacity model: ``slots`` bounds the number of *resident tenants*
across all buckets.  Bucket arrays grow in power-of-two slot-capacity
steps (a one-time recompile per (bucket, capacity) shape) and are
reused for the life of the pool; eviction frees a slot in place.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.peelspec import _bucket_pad

from .build import Hierarchy
from .query import PackedForest, depth_and_up, extend_up, pack_forest
from .serialize import load_hierarchy

__all__ = ["BucketKey", "ForestPool", "PoolFull", "TenantMeta"]

# arrays stacked per bucket, in dispatch-argument order: name →
# (shape kind, dtype); "e" = entity-padded, "n" = node-padded,
# "nJ" = (node-padded, J) lifting table
_STACK_FIELDS = (
    ("theta", "e"),
    ("entity_node", "e"),
    ("node_level", "n"),
    ("depth", "n"),
    ("node_size", "n"),
    ("up", "nJ"),
)

BucketKey = Tuple[int, int]


class PoolFull(RuntimeError):
    """Every resident tenant is pinned or has queued slots — nothing is
    evictable, so a new tenant cannot be admitted."""


@dataclasses.dataclass
class TenantMeta:
    """Dims + bookkeeping for one tenant; survives eviction so bounds
    validation and re-admission never need the artifact header."""

    n_nodes: int
    n_entities: int
    bucket: BucketKey
    resident: bool = False
    slot: int = -1
    last_used: int = 0      # LRU clock tick of the last touch
    pinned: bool = False
    queued: int = 0         # in-flight query slots referencing this tenant


@dataclasses.dataclass
class _Bucket:
    key: BucketKey
    J: int
    cap: int
    host: Dict[str, np.ndarray]
    tenants: List[Optional[str]]
    device: Optional[Dict[str, jnp.ndarray]] = None  # lazy, None = dirty


def _bucket_key(n_nodes: int, n_entities: int) -> BucketKey:
    """Quarter-pow2 bucket over (n_nodes, n_entities) — the compiled
    dispatch shape.  Floors keep degenerate forests in one tiny bucket."""
    return (_bucket_pad(max(n_nodes, 1), floor=8),
            _bucket_pad(max(n_entities, 1), floor=8))


def _bucket_J(n_pad: int) -> int:
    """Static lifting depth of a bucket: tree depth < n_nodes ≤ n_pad,
    so ceil(log2(n_pad)) levels always suffice."""
    return max(1, (int(n_pad) - 1).bit_length())


def _pack_tenant(h: Hierarchy, n_pad: int, e_pad: int, J: int
                 ) -> Dict[str, np.ndarray]:
    """One tenant's slot row: the :func:`pack_forest` arrays padded to
    the bucket shape (zero padding — padded ids are rejected host-side
    before any dispatch, so the values never reach an answer)."""
    n = h.n_nodes
    depth = np.asarray(h.meta.get("pack_depth", ()), dtype=np.int32)
    up = np.asarray(h.meta.get("pack_up", ()), dtype=np.int32)
    if depth.shape != (n,) or up.ndim != 2 or up.shape[0] != n:
        depth, up = depth_and_up(np.asarray(h.parent), J=J)
    up = extend_up(up, J)
    row = dict(
        theta=h.theta.astype(np.int32) if h.n_entities
        else np.zeros(0, np.int32),
        entity_node=h.entity_node.astype(np.int32) if h.n_entities
        else np.zeros(0, np.int32),
        node_level=h.node_level.astype(np.int32),
        depth=depth,
        node_size=(h.eend - h.estart).astype(np.int32),
        up=up,
    )
    out = {}
    for name, kind in _STACK_FIELDS:
        a = row[name]
        if kind == "nJ":
            pad = np.zeros((n_pad, J), np.int32)
            pad[:a.shape[0], :] = a
        else:
            size = e_pad if kind == "e" else n_pad
            pad = np.zeros(size, np.int32)
            pad[:a.shape[0]] = a
        out[name] = pad
    return out


class ForestPool:
    """LRU pool of packed forests, stacked per shape bucket.

    Args: ``slots`` — resident-tenant budget across all buckets;
    ``artifact_dir`` — directory of ``<tenant>.npz`` hierarchy
    artifacts for cold loads (optional: tenants can also be admitted
    in-memory via :meth:`add`).

    Example::

        pool = ForestPool(slots=64, artifact_dir="/data/hierarchies")
        pool.ensure("electronics")        # cold: loads + admits
        pool.ensure("electronics")        # hot: LRU touch only
        pool.pin("electronics")           # never evicted
    """

    def __init__(self, slots: int = 64,
                 artifact_dir: Optional[str] = None,
                 metrics: Optional[obs.MetricsRegistry] = None,
                 slot_upload: bool = True):
        if slots < 1:
            raise ValueError("pool needs at least one slot")
        self.slots = int(slots)
        self.artifact_dir = artifact_dir
        self.buckets: Dict[BucketKey, _Bucket] = {}
        self.meta: Dict[str, TenantMeta] = {}
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.load_seconds = 0.0
        # pool.* serving metrics (shared with MultiTenantService when it
        # wraps this pool); counters mirror the plain-int fields above
        self.metrics = metrics if metrics is not None else obs.MetricsRegistry()
        # per-slot device update on admission (dynamic_update_slice of
        # one slot row) instead of dirtying the whole bucket; False
        # restores the whole-bucket re-upload for the bench A/B
        self.slot_upload = bool(slot_upload)

    # ------------------------------------------------------------ admin
    @property
    def resident_count(self) -> int:
        """Number of tenants currently holding a slot."""
        return sum(m.resident for m in self.meta.values())

    def resident(self, tenant: str) -> bool:
        """Whether ``tenant`` currently holds a pool slot."""
        m = self.meta.get(tenant)
        return bool(m and m.resident)

    def tenants(self) -> List[str]:
        """Resident tenant ids (no particular order)."""
        return [t for t, m in self.meta.items() if m.resident]

    def pin(self, tenant: str) -> None:
        """Exempt ``tenant`` from eviction (loads it if cold)."""
        self.ensure(tenant)
        self.meta[tenant].pinned = True

    def unpin(self, tenant: str) -> None:
        """Re-admit ``tenant`` to the eviction candidate set."""
        if tenant in self.meta:
            self.meta[tenant].pinned = False

    def touch(self, tenant: str) -> None:
        """Mark ``tenant`` most-recently-used (dispatch does this for
        every distinct tenant of a batch)."""
        self._clock += 1
        self.meta[tenant].last_used = self._clock

    def note_queued(self, tenant: str, delta: int) -> None:
        """Track in-flight query slots: a tenant with ``queued > 0`` is
        never an eviction candidate."""
        m = self.meta[tenant]
        m.queued += delta
        assert m.queued >= 0, tenant

    def stats(self) -> Dict:
        """Cache counters: hits/misses/evictions, resident count, and
        cumulative artifact-load seconds."""
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions,
                    resident=self.resident_count,
                    load_seconds=self.load_seconds)

    # ------------------------------------------------------- admission
    def add(self, tenant: str, h: Hierarchy) -> Tuple[BucketKey, int]:
        """Admit an in-memory hierarchy as ``tenant`` (the cold-load
        path calls this after reading the artifact).  Returns the
        ``(bucket, slot)`` the tenant landed in."""
        m = self.meta.get(tenant)
        if m and m.resident:
            raise ValueError(f"tenant {tenant!r} already resident")
        key = _bucket_key(h.n_nodes, h.n_entities)
        slot = self._claim_slot(key)
        bucket = self.buckets[key]
        row = _pack_tenant(h, key[0], key[1], bucket.J)
        for name, _ in _STACK_FIELDS:
            bucket.host[name][slot] = row[name]
        if self.slot_upload and bucket.device is not None:
            # update ONE slot row in place on device — O(row) transfer
            # instead of dirtying the bucket and re-uploading all
            # cap × row bytes on the next dispatch
            t0 = time.perf_counter()
            for name, _ in _STACK_FIELDS:
                dev = bucket.device[name]
                upd = jnp.asarray(row[name][None])
                bucket.device[name] = jax.lax.dynamic_update_slice(
                    dev, upd, (slot,) + (0,) * (dev.ndim - 1))
            jax.block_until_ready(bucket.device["up"])
            self.metrics.observe("pool.admission_upload_ms",
                                 (time.perf_counter() - t0) * 1e3)
        else:
            bucket.device = None                  # dirty: re-upload
        bucket.tenants[slot] = tenant
        self.meta[tenant] = TenantMeta(
            n_nodes=h.n_nodes, n_entities=h.n_entities, bucket=key,
            resident=True, slot=slot,
            pinned=m.pinned if m else False,
            queued=m.queued if m else 0,
        )
        self.touch(tenant)
        return key, slot

    def ensure(self, tenant: str) -> Tuple[BucketKey, int]:
        """Hot path: LRU-touch a resident tenant.  Cold path: load its
        artifact from ``artifact_dir`` into a free slot (evicting the
        LRU evictable tenant if the pool is full).  Returns
        ``(bucket, slot)``."""
        m = self.meta.get(tenant)
        if m and m.resident:
            self.hits += 1
            self.metrics.inc("pool.hits")
            self.touch(tenant)
            return m.bucket, m.slot
        self.misses += 1
        self.metrics.inc("pool.misses")
        if self.artifact_dir is None:
            raise KeyError(
                f"tenant {tenant!r} not resident and the pool has no "
                "artifact_dir to load it from")
        path = os.path.join(self.artifact_dir, f"{tenant}.npz")
        if not os.path.exists(path):
            raise KeyError(f"no artifact for tenant {tenant!r}: {path}")
        t0 = time.perf_counter()
        with obs.span("pool.cold_load", cat="serve", tenant=tenant):
            out = self.add(tenant, load_hierarchy(path))
        dt = time.perf_counter() - t0
        self.load_seconds += dt
        self.metrics.observe("pool.load_ms", dt * 1e3)
        self.metrics.set_gauge("pool.resident", self.resident_count)
        return out

    def evict(self, tenant: str) -> None:
        """Drop ``tenant`` from its slot (explicit eviction; refuses
        pinned tenants and tenants with queued slots)."""
        m = self.meta.get(tenant)
        if not (m and m.resident):
            return
        if m.pinned:
            raise ValueError(f"tenant {tenant!r} is pinned")
        if m.queued:
            raise ValueError(f"tenant {tenant!r} has queued slots")
        self.buckets[m.bucket].tenants[m.slot] = None
        m.resident = False
        m.slot = -1
        self.evictions += 1
        self.metrics.inc("pool.evictions")
        self.metrics.set_gauge("pool.resident", self.resident_count)

    def _claim_slot(self, key: BucketKey) -> int:
        """Find a free slot for a tenant of bucket ``key``: free slot →
        use it; budget left → grow the bucket (one-time new shape);
        else evict the LRU evictable tenant and retry."""
        while True:
            bucket = self.buckets.get(key)
            if bucket is not None:
                for i, t in enumerate(bucket.tenants):
                    if t is None and self.resident_count < self.slots:
                        return i
            if self.resident_count < self.slots:
                return self._grow(key)
            self._evict_lru()

    def _grow(self, key: BucketKey) -> int:
        bucket = self.buckets.get(key)
        if bucket is None:
            cap = min(4, self.slots)
            J = _bucket_J(key[0])
            host = {}
            for name, kind in _STACK_FIELDS:
                shape = ((cap, key[0], J) if kind == "nJ" else
                         (cap, key[1] if kind == "e" else key[0]))
                host[name] = np.zeros(shape, np.int32)
            self.buckets[key] = _Bucket(
                key=key, J=J, cap=cap, host=host, tenants=[None] * cap)
            return 0
        slot = bucket.cap
        new_cap = bucket.cap * 2
        for name in bucket.host:
            old = bucket.host[name]
            grown = np.zeros((new_cap,) + old.shape[1:], old.dtype)
            grown[:bucket.cap] = old
            bucket.host[name] = grown
        bucket.tenants.extend([None] * (new_cap - bucket.cap))
        bucket.cap = new_cap
        bucket.device = None
        return slot

    def _evict_lru(self) -> None:
        candidates = [
            (m.last_used, t) for t, m in self.meta.items()
            if m.resident and not m.pinned and m.queued == 0
        ]
        if not candidates:
            raise PoolFull(
                f"all {self.resident_count} resident tenants are pinned "
                "or have queued slots; raise --pool-slots")
        _, victim = min(candidates)
        self.evict(victim)

    # ------------------------------------------------------- dispatch IO
    def bucket_arrays(self, key: BucketKey) -> Dict[str, jnp.ndarray]:
        """Device view of a bucket's stacked arrays (uploaded lazily,
        re-uploaded only after an admission changed the bucket)."""
        bucket = self.buckets[key]
        if bucket.device is None:
            t0 = time.perf_counter()
            bucket.device = {
                name: jnp.asarray(arr) for name, arr in bucket.host.items()
            }
            jax.block_until_ready(bucket.device["up"])
            self.metrics.observe("pool.bucket_upload_ms",
                                 (time.perf_counter() - t0) * 1e3)
        return bucket.device

    def forest_of(self, tenant: str) -> PackedForest:
        """Single-tenant :class:`PackedForest` rebuilt from the
        tenant's artifact — the per-tenant oracle the parity tests
        compare the pooled dispatch against."""
        self.ensure(tenant)
        path = os.path.join(self.artifact_dir or "", f"{tenant}.npz")
        return pack_forest(load_hierarchy(path))
