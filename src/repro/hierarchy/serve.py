"""Batched hierarchy query engine — decomposition-as-a-service.

:class:`HierarchyService` is the hierarchy twin of
``serve.ContinuousBatcher``: requests join a queue, the engine drains
them in fixed-size *slot batches*, and one jitted dispatch answers the
whole batch from device-resident arrays.  Slot occupancy is data (a
padded tail of no-op queries), not shape, so one XLA program serves any
query mix — exactly the continuous-batching contract of the token
engine, minus the sequential decode loop (hierarchy queries are
single-shot, so every slot retires each step).

Mixed ops ride in one batch: the kernel computes every answer family
(gathers + one binary-lifting LCA) and selects per slot by op code —
branchless, so vmapped batches cost the same as homogeneous ones.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Deque, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .build import Hierarchy
from .query import PackedForest, _lca, pack_forest, subgraph_at

__all__ = ["OPS", "HQuery", "HierarchyService"]

# op code → semantics ("a"/"b" are entity ids unless noted)
OPS = dict(
    max_k=0,          # largest k whose k-subgraph contains entity a
    node_of=1,        # deepest hierarchy node containing entity a
    lca_node=2,       # smallest common dense subgraph of entities a, b
    lca_level=3,      # ... and its level k
    subtree_size=4,   # entity count of node a's subgraph (a = node id)
)
_OP_NAMES = {v: k for k, v in OPS.items()}


@dataclasses.dataclass
class HQuery:
    """One query; ``result`` is filled by the engine."""

    uid: int
    op: str
    a: int
    b: int = 0
    result: Optional[int] = None
    done: bool = False


@partial(jax.jit, static_argnames=("J",))
def _answer_batch(
    theta, entity_node, node_level, depth, node_size, up,
    ops, a, b, J: int,
):
    """All answer families for every slot, then a per-slot select.
    Dispatch is keyed through :data:`OPS` by name, so the op table and
    the kernel cannot silently desynchronize."""
    lca = _lca(up, depth, entity_node[a], entity_node[b], J)
    answers = {
        "max_k": theta[a],
        "node_of": entity_node[a],
        "lca_node": lca,
        "lca_level": node_level[lca],
        "subtree_size": node_size[a],
    }
    assert answers.keys() == OPS.keys()
    return jnp.select(
        [ops == OPS[name] for name in answers],
        list(answers.values()),
        default=jnp.int32(-1),
    )


class HierarchyService:
    """Slot-batched query serving over a :class:`PackedForest`.

    ``batch`` is the slot count of the one compiled program; partially
    full batches pad with no-op slots (masked out on return).  All state
    the kernel reads lives on device once — steady-state service is
    pure dispatch + one small host transfer per batch.

    Args: ``h`` — a built :class:`Hierarchy` (packed on the fly) or an
    already-packed forest; ``batch`` — slots per jitted dispatch.

    Example::

        from repro.core import random_bipartite, wing_decomposition
        from repro.hierarchy import build_hierarchy, HierarchyService, HQuery
        g = random_bipartite(200, 150, 900, seed=0)
        h = build_hierarchy(g, wing_decomposition(g, engine="csr"),
                            kind="wing")
        svc = HierarchyService(h, batch=256)
        svc.submit(HQuery(uid=0, op="max_k", a=3))
        print(svc.run()[0].result)
    """

    def __init__(self, h: Union[Hierarchy, PackedForest], batch: int = 1024):
        self.forest = pack_forest(h) if isinstance(h, Hierarchy) else h
        self.batch = int(batch)
        self.queue: Deque[HQuery] = deque()
        self.served = 0
        self.dispatches = 0

    # ------------------------------------------------------------ admin
    def _check_ids(self, op_codes, a, b) -> None:
        """Host-side bounds check: jitted gathers CLAMP out-of-range
        indices, which would turn a malformed client id into a
        confidently wrong answer instead of an error."""
        node_arg = op_codes == OPS["subtree_size"]
        a_lim = np.where(node_arg, self.forest.n_nodes,
                         self.forest.n_entities)
        bad = (a < 0) | (a >= a_lim)
        pair = (op_codes == OPS["lca_node"]) | (op_codes == OPS["lca_level"])
        bad |= pair & ((b < 0) | (b >= self.forest.n_entities))
        if bad.any():
            i = int(np.argmax(bad))
            raise ValueError(
                f"query id out of range: op={_OP_NAMES[int(op_codes[i])]} "
                f"a={int(a[i])} b={int(b[i])} "
                f"(n_entities={self.forest.n_entities}, "
                f"n_nodes={self.forest.n_nodes})"
            )

    def submit(self, q: HQuery) -> None:
        """Fail fast at the API boundary (scalar checks — run() then
        dispatches queued queries without re-validating them)."""
        if q.op not in OPS:
            raise ValueError(f"unknown op {q.op!r} (choose from {set(OPS)})")
        a_lim = (self.forest.n_nodes if q.op == "subtree_size"
                 else self.forest.n_entities)
        bad = not 0 <= q.a < a_lim
        if q.op in ("lca_node", "lca_level"):
            bad |= not 0 <= q.b < self.forest.n_entities
        if bad:
            raise ValueError(
                f"query id out of range: op={q.op} a={q.a} b={q.b} "
                f"(n_entities={self.forest.n_entities}, "
                f"n_nodes={self.forest.n_nodes})"
            )
        self.queue.append(q)

    def pending(self) -> int:
        """Number of queued queries not yet served by :meth:`run`."""
        return len(self.queue)

    # ------------------------------------------------------------ serve
    def query_batch(
        self, ops: np.ndarray, a: np.ndarray, b: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """Raw batched entry: parallel arrays of op codes and args →
        int32 answers.  Used directly by benchmarks; ``run`` wraps it."""
        ops = np.asarray(ops, dtype=np.int32)
        a = np.asarray(a, dtype=np.int32)
        b = np.zeros_like(a) if b is None else np.asarray(b, dtype=np.int32)
        self._check_ids(ops, a, b)
        return self._dispatch(ops, a, b)

    def _dispatch(self, ops, a, b) -> np.ndarray:
        """One jitted batch dispatch — ids must already be validated
        (submit() checked queued queries; raw callers go through
        :meth:`query_batch`)."""
        f = self.forest
        out = _answer_batch(
            f.theta, f.entity_node, f.node_level, f.depth, f.node_size,
            f.up, jnp.asarray(ops), jnp.asarray(a), jnp.asarray(b), f.J,
        )
        self.served += int(ops.size)
        self.dispatches += 1
        return np.asarray(out)

    def subgraph_masks(self, nodes) -> np.ndarray:
        """Batched ``subgraph_at`` — (len(nodes), n_entities) bool.
        Separate entry point because the answer is a mask, not a
        scalar per slot."""
        nodes = np.asarray(nodes)
        if nodes.size and (
            (nodes < 0) | (nodes >= self.forest.n_nodes)
        ).any():
            raise ValueError(
                f"node id out of range (n_nodes={self.forest.n_nodes})")
        self.dispatches += 1
        out = np.asarray(subgraph_at(self.forest, jnp.asarray(nodes)))
        self.served += out.shape[0]
        return out

    def run(self) -> List[HQuery]:
        """Drain the queue in slot batches; returns completed queries
        in uid order (the ContinuousBatcher contract)."""
        completed: List[HQuery] = []
        while self.queue:
            todo = [
                self.queue.popleft()
                for _ in range(min(self.batch, len(self.queue)))
            ]
            n = len(todo)
            # pad with subtree_size(root): node 0 always exists, even on
            # an entity-less hierarchy where max_k(0) would be invalid
            ops = np.full(self.batch, OPS["subtree_size"], dtype=np.int32)
            a = np.zeros(self.batch, dtype=np.int32)
            b = np.zeros(self.batch, dtype=np.int32)
            for i, q in enumerate(todo):
                ops[i] = OPS[q.op]
                a[i] = q.a
                b[i] = q.b
            # queries were validated at submit; padding is always legal
            res = self._dispatch(ops, a, b)
            self.served -= self.batch - n  # padded slots served nothing
            for i, q in enumerate(todo):
                q.result = int(res[i])
                q.done = True
            completed.extend(todo)
        return sorted(completed, key=lambda q: q.uid)
