"""Micro-epoch edge-event batching (streaming front door).

Production bipartite traffic is a stream of (u, v) edge inserts and
deletes.  The streaming updater consumes them in *micro-epochs*: a
batch of events is coalesced against the current edge set — duplicate
and self-cancelling events collapse, already-present inserts and
absent deletes drop out — leaving the **net** insert/delete sets that
actually change the graph.  Everything downstream (support deltas,
dirty-partition detection, hierarchy repair) reasons about net sets
only, so an epoch whose events cancel out is a structural no-op and
the updater serves the previous decomposition unchanged.

Event traces are JSONL (``{"op": "+", "u": 3, "v": 7}`` per line) so
real traffic logs can be replayed through ``launch/stream.py``;
:func:`make_random_events` synthesizes one epoch's worth against a
live edge set for benchmarks and self-checks.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Iterable, List, Sequence, Tuple

import numpy as np

from repro.core.graph import BipartiteGraph

__all__ = [
    "EdgeEvent",
    "coalesce",
    "apply_events",
    "load_trace",
    "save_trace",
    "make_random_events",
]


@dataclasses.dataclass(frozen=True)
class EdgeEvent:
    """One edge mutation: ``op`` is ``"+"`` (insert) or ``"-"`` (delete)."""

    op: str
    u: int
    v: int

    def __post_init__(self):
        if self.op not in ("+", "-"):
            raise ValueError(f"op must be '+' or '-', got {self.op!r}")


def coalesce(
    events: Sequence[EdgeEvent], g: BipartiteGraph
) -> Tuple[np.ndarray, np.ndarray]:
    """Net ``(inserts, deletes)`` of one micro-epoch against ``g``.

    Events apply in order, so the *last* event per edge key decides its
    desired presence; keys whose desired presence matches the current
    edge set drop out entirely.  Returns two ``(k, 2)`` int64 arrays in
    lexicographic key order (deterministic downstream processing)."""
    desired = {}
    for ev in events:
        if not (0 <= ev.u < g.n_u and 0 <= ev.v < g.n_v):
            raise ValueError(
                f"event ({ev.u}, {ev.v}) outside graph "
                f"({g.n_u} x {g.n_v})")
        desired[(ev.u, ev.v)] = ev.op == "+"
    if not desired:
        z = np.zeros((0, 2), dtype=np.int64)
        return z, z.copy()
    present = set(map(tuple, g.edges.tolist()))
    ins = sorted(k for k, want in desired.items() if want and k not in present)
    dels = sorted(k for k, want in desired.items()
                  if not want and k in present)
    to_arr = lambda ks: (np.asarray(ks, dtype=np.int64).reshape(-1, 2))  # noqa: E731
    return to_arr(ins), to_arr(dels)


def apply_events(
    g: BipartiteGraph, inserts: np.ndarray, deletes: np.ndarray
) -> BipartiteGraph:
    """The materialized graph after one coalesced micro-epoch."""
    if inserts.size == 0 and deletes.size == 0:
        return g
    edges = g.edges
    if deletes.size:
        codes = edges[:, 0].astype(np.int64) * g.n_v + edges[:, 1]
        dcodes = deletes[:, 0] * g.n_v + deletes[:, 1]
        edges = edges[~np.isin(codes, dcodes)]
    if inserts.size:
        edges = np.concatenate([edges, inserts.astype(np.int32)], axis=0)
    return BipartiteGraph.from_edges(g.n_u, g.n_v, edges)


# ---------------------------------------------------------------- trace IO
def load_trace(path: str) -> List[EdgeEvent]:
    """Load a JSONL event trace (one ``{"op", "u", "v"}`` per line)."""
    out: List[EdgeEvent] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            out.append(EdgeEvent(str(d["op"]), int(d["u"]), int(d["v"])))
    return out


def save_trace(path: str, events: Iterable[EdgeEvent]) -> None:
    """Write events as a JSONL trace (inverse of :func:`load_trace`)."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(dict(op=ev.op, u=ev.u, v=ev.v)) + "\n")


def make_random_events(
    g: BipartiteGraph, n: int, seed: int = 0, p_delete: float = 0.3
) -> List[EdgeEvent]:
    """Synthesize one micro-epoch of events against the current graph.

    Deletes sample existing edges; inserts sample uniform (u, v) pairs
    (which may duplicate events or re-insert existing edges — the
    coalescer is expected to handle both).  Deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    out: List[EdgeEvent] = []
    for _ in range(n):
        if g.m and rng.random() < p_delete:
            u, v = g.edges[int(rng.integers(g.m))]
            out.append(EdgeEvent("-", int(u), int(v)))
        else:
            out.append(EdgeEvent(
                "+", int(rng.integers(g.n_u)), int(rng.integers(g.n_v))))
    return out
