"""Streaming edge events: incremental θ maintenance + hierarchy repair.

``events`` batches and coalesces edge inserts/deletes into
micro-epochs; ``delta`` computes exact wedge-local support deltas and
the dirty-partition set; ``update`` owns the live
:class:`~repro.streaming.update.StreamState` whose per-epoch output is
bit-identical to a from-scratch re-peel (the machine-checked claim —
see ``tests/test_streaming.py`` and ``docs/ARCHITECTURE.md``).
"""
from .events import (  # noqa: F401
    EdgeEvent,
    apply_events,
    coalesce,
    load_trace,
    make_random_events,
    save_trace,
)
from .update import EpochReport, StreamConfig, StreamState  # noqa: F401

__all__ = [
    "EdgeEvent",
    "apply_events",
    "coalesce",
    "load_trace",
    "make_random_events",
    "save_trace",
    "EpochReport",
    "StreamConfig",
    "StreamState",
]
