"""Wedge-local support deltas + dirty-partition detection.

A changed edge (u, v) only perturbs butterflies through its wedges:
every butterfly it enters or leaves is {u, u2} x {v, v2} with
u2 ∈ N(v) and v2 ∈ N(u) ∩ N(u2) — so one micro-epoch's exact support
delta is a host-side walk over the event endpoints' neighborhoods,
never a global recount.  :func:`support_delta` performs that walk
sequentially over the coalesced events (deletes first, then inserts,
each against the adjacency state the previous event left behind) and
returns both the per-entity delta and the **touched** set: every
entity whose incident wedge/pair structure changed, which is exactly
the set whose FD behaviour could differ.

:func:`dirty_partitions` turns the touched set plus the fresh Phase-1
output into the set of CD partitions whose FD must re-run.  The rule is
a sound prefix bound: partition j's FD reads the entire ≥j induced
subgraph (``_wing_fd_csr`` folds all ≥j wedges into its pair-count
init; the dense FD re-counts on the ≥j adjacency), so j can reuse the
previous epoch's θ iff **no** affected entity — inserted, deleted,
moved across partitions, ⋈init-changed, or touched — lies in a
partition ≥ j on either side.  Dirty = {0..Jmax} with Jmax the highest
affected partition; everything above Jmax sees a bit-identical input
by entity key and is carried over.  The differential harness
(``tests/test_streaming.py``) machine-checks this soundness argument
after every epoch.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Set, Tuple

import numpy as np

from repro.core.graph import BipartiteGraph

__all__ = [
    "edge_codes",
    "support_delta",
    "wing_sup0_new",
    "common_entities",
    "dirty_partitions",
]


def edge_codes(g: BipartiteGraph) -> np.ndarray:
    """Stable edge keys ``u * n_v + v`` — ascending, because edges are
    lexicographically sorted; the old→new id map over common keys is
    therefore monotone (what keeps min-id component labels mappable)."""
    return g.edges[:, 0].astype(np.int64) * g.n_v + g.edges[:, 1]


def support_delta(
    gg_old: BipartiteGraph,
    inserts: np.ndarray,
    deletes: np.ndarray,
    kind: str,
) -> Tuple[Dict, Set]:
    """Exact butterfly-support delta of one coalesced micro-epoch.

    Returns ``(delta, touched)``: for ``kind="wing"`` keyed by edge
    ``(u, v)`` tuples, for ``kind="tip"`` keyed by U-side vertex ids —
    both in the *internal* (gg) orientation.  ``delta`` sums each
    entity's butterfly-count change; for inserted edges it holds the
    full new-edge count.  ``touched`` contains every entity whose
    incident wedge or pair structure changed — a superset of the keys
    with nonzero delta (a wedge can appear without completing any
    butterfly, yet still change FD's wedge lists and update counts)."""
    if kind not in ("wing", "tip"):
        raise ValueError(kind)
    adj_u: Dict[int, set] = defaultdict(set)
    adj_v: Dict[int, set] = defaultdict(set)
    for u, v in gg_old.edges:
        adj_u[int(u)].add(int(v))
        adj_v[int(v)].add(int(u))
    delta: Dict = defaultdict(int)
    touched: Set = set()

    def one(u: int, v: int, sign: int) -> None:
        # adjacency state EXCLUDES (u, v): counts the butterflies the
        # edge closes with the rest of the current graph
        if kind == "wing":
            touched.add((u, v))
            cnt = 0
            for u2 in adj_v[v]:
                touched.add((u2, v))
                commons = adj_u[u] & adj_u[u2]
                commons.discard(v)
                c = len(commons)
                if c:
                    delta[(u2, v)] += sign * c
                    cnt += c
                    for v2 in commons:
                        delta[(u, v2)] += sign
                        delta[(u2, v2)] += sign
                        touched.add((u, v2))
                        touched.add((u2, v2))
            delta[(u, v)] += sign * cnt
        else:
            touched.add(u)
            for u2 in adj_v[v]:
                touched.add(u2)
                commons = adj_u[u] & adj_u[u2]
                commons.discard(v)
                c = len(commons)
                if c:
                    delta[u] += sign * c
                    delta[u2] += sign * c

    for u, v in deletes.tolist():
        adj_u[u].discard(v)
        adj_v[v].discard(u)
        one(u, v, -1)
    for u, v in inserts.tolist():
        one(u, v, +1)
        adj_u[u].add(v)
        adj_v[v].add(u)
    return dict(delta), touched


def wing_sup0_new(
    gg_old: BipartiteGraph,
    sup0_old: np.ndarray,
    gg_new: BipartiteGraph,
    delta: Dict,
) -> np.ndarray:
    """⋈init for the new edge set: carried counts + delta, by edge key."""
    sup_new = np.zeros(gg_new.m, dtype=np.int64)
    codes_old = edge_codes(gg_old)
    codes_new = edge_codes(gg_new)
    if codes_old.size and codes_new.size:
        pos = np.searchsorted(codes_old, codes_new)
        pos_c = np.minimum(pos, codes_old.size - 1)
        has = codes_old[pos_c] == codes_new
        sup_new[has] = sup0_old[pos_c[has]]
    if delta:
        keys = np.asarray(
            [u * gg_new.n_v + v for (u, v) in delta], dtype=np.int64)
        vals = np.asarray(list(delta.values()), dtype=np.int64)
        pos = np.searchsorted(codes_new, keys)
        pos_c = np.minimum(pos, max(codes_new.size - 1, 0))
        has = (codes_new.size > 0) & (codes_new[pos_c] == keys)
        np.add.at(sup_new, pos_c[has], vals[has])
    return sup_new


def common_entities(
    gg_old: BipartiteGraph, gg_new: BipartiteGraph, kind: str
) -> Tuple[np.ndarray, np.ndarray]:
    """Aligned index arrays ``(old_idx, new_idx)`` of the entities
    present in both graphs (edges matched by key for wing; U-side
    vertices are the identity for tip).  Both ascending, so the induced
    old→new id map is monotone."""
    if kind == "tip":
        ids = np.arange(gg_old.n_u, dtype=np.int64)
        return ids, ids.copy()
    codes_old = edge_codes(gg_old)
    codes_new = edge_codes(gg_new)
    _, old_idx, new_idx = np.intersect1d(
        codes_old, codes_new, assume_unique=True, return_indices=True)
    return old_idx.astype(np.int64), new_idx.astype(np.int64)


def dirty_partitions(
    part_old: np.ndarray,
    part_new: np.ndarray,
    old_common: np.ndarray,
    new_common: np.ndarray,
    sup_init_old: np.ndarray,
    sup_init_new: np.ndarray,
    touched_old: np.ndarray,
    touched_new: np.ndarray,
    p_eff_old: int,
    p_eff_new: int,
) -> np.ndarray:
    """Partition ids of the new CD run whose FD must re-run.

    An entity is *affected* when it exists on only one side (insert /
    delete), moved partitions, changed ⋈init, or is structurally
    touched.  Every partition up to the highest affected one is dirty
    (the prefix bound — see the module docstring); partitions the old
    run never produced are dirty unconditionally."""
    jmax = -1
    old_only = np.ones(part_old.size, dtype=bool)
    old_only[old_common] = False
    new_only = np.ones(part_new.size, dtype=bool)
    new_only[new_common] = False
    changed = (
        (part_old[old_common] != part_new[new_common])
        | (sup_init_old[old_common] != sup_init_new[new_common])
    )
    for arr in (
        part_old[old_only | touched_old],
        part_new[new_only | touched_new],
        part_old[old_common][changed],
        part_new[new_common][changed],
    ):
        if arr.size:
            jmax = max(jmax, int(arr.max()))
    dirty = np.arange(min(jmax + 1, p_eff_new), dtype=np.int64)
    if p_eff_new > p_eff_old:
        dirty = np.union1d(
            dirty, np.arange(p_eff_old, p_eff_new, dtype=np.int64))
    return dirty
