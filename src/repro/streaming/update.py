"""Incremental θ maintenance: the micro-epoch streaming updater.

One :class:`StreamState` owns the live decomposition of an evolving
bipartite graph.  Per micro-epoch:

1. **coalesce** the event batch to net inserts/deletes (`events.py`);
2. **delta**: exact wedge-local ⋈init update + touched set
   (`delta.py`) — no global butterfly recount;
3. **CD re-runs in full** (it is the cheap, host-driven phase and its
   partition ranges are what bound the repair's blast radius);
4. **dirty partitions** are detected by comparing the fresh Phase-1
   output against the previous epoch by entity key;
5. **localized FD**: only dirty partitions re-peel, dispatched through
   the existing ``core.peelspec.run_fd`` (``only=`` — the SAME jitted
   while_loop entries as a full run; no new call sites), clean
   partitions carry their θ and per-partition stats forward;
6. **hierarchy repair**: only dirty levels recompute their component
   labels; the forest re-assembles bit-identical to a from-scratch
   build (`hierarchy/repair.py`).

Every epoch's (θ, stats, forest) is **bit-identical** to peeling the
materialized graph from scratch — the differential harness in
``tests/test_streaming.py`` asserts it after every epoch, and the
invariant is exactly why serving can keep answering from the previous
forest during repair: the swap is atomic and the stale window is the
dirty subtrees (:func:`repro.hierarchy.repair.dirty_subtrees`).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.graph import BipartiteGraph
from repro.core.peel import PeelStats, build_peel_spec
from repro.core.peelspec import PeelResult, cd_loop, run_fd
from repro.hierarchy.build import Hierarchy
from repro.hierarchy import repair as hrepair
from . import delta as sdelta
from . import events as sevents

__all__ = ["StreamConfig", "StreamState", "EpochReport"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """How the stream peels.  Per-partition ``fd_driver`` values
    ("device" | "host") localize Phase 2 to dirty partitions;
    ``"vmapped"`` (csr engine, single device) instead re-dispatches the
    WHOLE Phase 2 as its one batched while_loop every epoch — a single
    launch, trading localization for dispatch count.  θ stays
    bit-identical either way (the differential harness covers all
    three)."""

    kind: str = "wing"          # "wing" | "tip"
    side: str = "u"             # tip only: which vertex set carries θ
    engine: str = "csr"         # "csr" | "dense"
    P: int = 16
    fd_driver: str = "device"   # "device" | "host" | "vmapped"
    batch_recount: object = "adaptive"  # dense tip only (the §5.1 knob)
    use_pallas: bool = False
    level_block: int = 32

    def __post_init__(self):
        if self.kind not in ("wing", "tip"):
            raise ValueError(self.kind)
        if self.engine not in ("csr", "dense"):
            raise ValueError(
                f"streaming supports engines 'csr' | 'dense', "
                f"got {self.engine!r}")
        if self.fd_driver not in ("device", "host", "vmapped"):
            raise ValueError(
                "streaming fd_driver must be 'device' | 'host' "
                "(per-partition, localized to dirty partitions) or "
                "'vmapped' (csr, single-device: one batched Phase-2 "
                "launch per epoch); the fused driver has no streaming "
                "entry")
        if self.fd_driver == "vmapped":
            if self.engine != "csr":
                raise ValueError(
                    "fd_driver='vmapped' is the csr single-dispatch "
                    "Phase 2; streaming supports it with engine='csr' "
                    "only")
            import jax
            if jax.device_count() > 1:
                raise ValueError(
                    "streaming fd_driver='vmapped' is single-device; "
                    "the distributed CD/FD path is not reachable from "
                    "StreamConfig — run the per-partition drivers or a "
                    "single device")
        if self.side not in ("u", "v"):
            raise ValueError(self.side)
        if self.kind == "wing" and self.side != "u":
            raise ValueError("wing has no side; use side='u'")


@dataclasses.dataclass
class EpochReport:
    """What one micro-epoch did (the CLI/benchmark row source)."""

    epoch: int
    n_events: int
    n_inserts: int           # net, after coalescing
    n_deletes: int
    noop: bool
    p_eff: int
    partitions_dirty: int
    levels_dirty: int
    levels_total: int
    stale_nodes: int         # old-forest nodes invalidated during repair
    stale_entities: int      # Σ dirty-subtree slice lengths (bound)
    repair_ms: float         # FD re-run + hierarchy repair
    epoch_ms: float          # whole epoch, coalesce → swap
    theta_max: int

    def as_dict(self) -> dict:
        """Flat JSON-ready view."""
        return dataclasses.asdict(self)


class StreamState:
    """The live decomposition of an evolving graph (one tenant)."""

    def __init__(self, g: BipartiteGraph, config: StreamConfig,
                 metrics: Optional["obs.MetricsRegistry"] = None):
        self.config = config
        self.metrics = metrics if metrics is not None \
            else obs.MetricsRegistry()
        self.epoch = 0
        self.g = g
        self.result: Optional[PeelResult] = None
        self.hierarchy: Optional[Hierarchy] = None
        self._sup0: Optional[np.ndarray] = None       # gg-space ⋈init
        self._pp: Dict[int, Tuple[int, int, int]] = {}  # j → (ρ, upd, rec)
        self._label_cache: Optional[hrepair.LabelCache] = None

    # ------------------------------------------------------------ internals
    def _gg(self, g: BipartiteGraph) -> BipartiteGraph:
        cfg = self.config
        return g if (cfg.kind == "wing" or cfg.side == "u") else g.transpose()

    def _fresh_stats(self) -> PeelStats:
        cfg = self.config
        return PeelStats(
            engine=cfg.engine,
            fd_driver=cfg.fd_driver if cfg.engine == "csr" else "host",
            side=cfg.side if cfg.kind == "tip" else "",
        )

    @staticmethod
    def initial(g: BipartiteGraph, config: StreamConfig,
                metrics=None) -> "StreamState":
        """Peel the starting graph through the SAME epoch machinery
        (everything dirty) so epoch 0 exercises the streaming path."""
        st = StreamState(g, config, metrics)
        st.apply_epoch([])
        return st

    # ---------------------------------------------------------------- epoch
    def apply_epoch(self, events: Sequence["sevents.EdgeEvent"]
                    ) -> EpochReport:
        """Ingest one micro-epoch; returns what changed.  The previous
        ``result``/``hierarchy`` stay readable (stale-but-bounded)
        until the final in-place swap."""
        with obs.span("stream.epoch", cat="stream", epoch=self.epoch,
                      events=len(events)) as sp:
            rep = self._apply(list(events), sp)
        self.metrics.inc("stream.epochs")
        self.metrics.observe("stream.epoch_ms", rep.epoch_ms)
        self.epoch += 1
        return rep

    def _apply(self, events: List["sevents.EdgeEvent"], sp) -> EpochReport:
        cfg = self.config
        t0 = time.perf_counter()
        ins, dels = sevents.coalesce(events, self.g)
        first = self.result is None
        if not first and ins.size == 0 and dels.size == 0:
            # structural no-op: same graph ⇒ a re-peel would reproduce
            # the current state bit-for-bit; serve it unchanged
            self.metrics.inc("stream.noop_epochs")
            rep = self._report(events, ins, dels, noop=True,
                               dirty=np.zeros(0, dtype=np.int64),
                               lv_dirty=0, repair_ms=0.0, t0=t0,
                               stale=(0, 0))
            if sp is not None:
                sp.update(noop=True)
            return rep

        gg_old = self._gg(self.g)
        g_new = sevents.apply_events(self.g, ins, dels)
        gg_new = self._gg(g_new)
        # internal orientation: tip side="v" peels the transpose's U side
        swap = cfg.kind == "tip" and cfg.side == "v"
        ins_i, dels_i = (ins[:, ::-1], dels[:, ::-1]) if swap else (ins, dels)

        # ---- wedge-local ⋈init delta + touched set (host, exact)
        if first:
            touched: set = set()
            sup0_new = None
        else:
            dlt, touched = sdelta.support_delta(
                gg_old, ins_i, dels_i, cfg.kind)
            if cfg.kind == "wing":
                sup0_new = sdelta.wing_sup0_new(
                    gg_old, self._sup0, gg_new, dlt)
            else:
                sup0_new = self._sup0.copy()
                for u, d in dlt.items():
                    sup0_new[u] += d

        # ---- Phase 1 re-runs in full (its ranges bound the blast radius)
        stats = self._fresh_stats()
        inject = sup0_new is not None and not (
            cfg.kind == "tip" and cfg.engine == "dense")
        spec = build_peel_spec(
            g_new, cfg.kind, stats, side=cfg.side, engine=cfg.engine,
            batch_recount=cfg.batch_recount, fd_driver=cfg.fd_driver,
            use_pallas=cfg.use_pallas,
            sup0=sup0_new if inject else None)
        with obs.span("stream.cd", cat="stream"):
            part, sup_init, ranges, p_eff = cd_loop(spec, cfg.P, stats)
        upd_cd, rec_cd = stats.updates, stats.recounts

        # ---- dirty partitions: fresh Phase-1 vs previous epoch, by key
        theta = np.zeros(spec.n, dtype=np.int64)
        if first:
            dirty = np.arange(p_eff, dtype=np.int64)
            oc = nc = np.zeros(0, dtype=np.int64)
        else:
            oc, nc = sdelta.common_entities(gg_old, gg_new, cfg.kind)
            t_old = self._touched_mask(gg_old, touched)
            t_new = self._touched_mask(gg_new, touched)
            dirty = sdelta.dirty_partitions(
                self.result.part, part, oc, nc,
                self.result.support_init, sup_init, t_old, t_new,
                int(self.result.stats.p_effective), p_eff)
            theta[nc] = self.result.theta[oc]

        # ---- localized FD + dirty-subtree forest repair
        t_rep = time.perf_counter()
        with obs.span("stream.repair", cat="stream",
                      partitions_dirty=int(dirty.size)) as rsp:
            pp_new: Dict[int, Tuple[int, int, int]] = {}
            if cfg.fd_driver == "vmapped":
                # the vmapped driver is ONE batched launch over every
                # partition — nothing to localize, so each epoch
                # re-dispatches the whole Phase 2 and the driver itself
                # writes the full-run stats row (rho totals set,
                # updates accumulated on top of the CD counts)
                with obs.span("stream.fd", cat="stream"):
                    run_fd(spec, part, sup_init, theta, p_eff, stats,
                           fd_driver="vmapped")
                pp_full = {}
            else:
                with obs.span("stream.fd", cat="stream"):
                    run_fd(spec, part, sup_init, theta, p_eff, stats,
                           fd_driver=cfg.fd_driver, only=dirty,
                           per_partition=pp_new)
                # reassemble the full-run stats row from carried
                # partitions
                pp_full = {
                    j: pp_new[j] if j in pp_new else self._pp[j]
                    for j in range(p_eff)
                }
                rows = list(pp_full.values())
                stats.rho_fd_total = sum(r for r, _, _ in rows)
                stats.rho_fd_max = max((r for r, _, _ in rows), default=0)
                stats.updates = upd_cd + sum(u for _, u, _ in rows)
                stats.recounts = rec_cd + sum(c for _, _, c in rows)
            result = PeelResult(
                theta=theta, part=part, ranges=ranges,
                support_init=sup_init, stats=stats)

            if first:
                h, cache, lv_dirty, lv_total = hrepair.repair_hierarchy(
                    g_new, result, cfg.kind, cfg.side, cache=None,
                    level_block=cfg.level_block)
                stale = (0, 0)
            else:
                stale = self._stale_bound(gg_old, oc, touched)
                h, cache, lv_dirty, lv_total = hrepair.repair_hierarchy(
                    g_new, result, cfg.kind, cfg.side,
                    cache=self._label_cache, old_common=oc, new_common=nc,
                    touched_old=self._touched_mask(gg_old, touched),
                    touched_new=self._touched_mask(gg_new, touched),
                    level_block=cfg.level_block)
            if rsp is not None:
                rsp.update(levels_dirty=lv_dirty)
        repair_ms = (time.perf_counter() - t_rep) * 1e3

        # ---- atomic swap: readers see the old state until here
        self.g = g_new
        self.result = result
        self.hierarchy = h
        self._label_cache = cache
        # next epoch's carried ⋈init: the injected incremental vector,
        # or the spec's own fresh count when the engine recounted anyway
        self._sup0 = sup0_new if inject \
            else np.asarray(spec.sup0, dtype=np.int64).copy()
        self._pp = pp_full

        self.metrics.observe("stream.repair_ms", repair_ms)
        self.metrics.inc("repair.partitions_dirty", int(dirty.size))
        obs.counter("repair.partitions_dirty",
                    dict(dirty=int(dirty.size), total=int(p_eff)))
        rep = self._report(events, ins, dels, noop=False, dirty=dirty,
                           lv_dirty=lv_dirty, repair_ms=repair_ms, t0=t0,
                           stale=stale)
        if sp is not None:
            sp.update(partitions_dirty=int(dirty.size),
                      repair_ms=repair_ms)
        return rep

    # --------------------------------------------------------------- helpers
    def _touched_mask(self, gg: BipartiteGraph, touched) -> np.ndarray:
        cfg = self.config
        if cfg.kind == "tip":
            mask = np.zeros(gg.n_u, dtype=bool)
            for u in touched:
                if 0 <= u < gg.n_u:
                    mask[u] = True
            return mask
        mask = np.zeros(gg.m, dtype=bool)
        if touched:
            codes = sdelta.edge_codes(gg)
            keys = np.asarray(
                [u * gg.n_v + v for (u, v) in touched], dtype=np.int64)
            pos = np.searchsorted(codes, keys)
            pos_c = np.minimum(pos, max(codes.size - 1, 0))
            has = (codes.size > 0) & (codes[pos_c] == keys)
            mask[pos_c[has]] = True
        return mask

    def _stale_bound(self, gg_old, oc, touched) -> Tuple[int, int]:
        """Old-forest blast radius: nodes + entity-slice bound of the
        region whose answers may go stale while this epoch repairs."""
        if self.hierarchy is None:
            return 0, 0
        t_old = self._touched_mask(gg_old, touched)
        affected = np.ones(t_old.size, dtype=bool)
        affected[oc] = False        # deleted entities
        affected |= t_old
        ids = np.where(affected)[0]
        nodes, slices = hrepair.dirty_subtrees(self.hierarchy, ids)
        return int(nodes.size), int(sum(hi - lo for lo, hi in slices))

    def _report(self, events, ins, dels, noop, dirty, lv_dirty,
                repair_ms, t0, stale) -> EpochReport:
        res = self.result
        lv_total = int(self.hierarchy.levels.size) if self.hierarchy \
            is not None else 0
        return EpochReport(
            epoch=self.epoch,
            n_events=len(events),
            n_inserts=int(ins.shape[0]),
            n_deletes=int(dels.shape[0]),
            noop=noop,
            p_eff=int(res.stats.p_effective) if res is not None else 0,
            partitions_dirty=int(dirty.size),
            levels_dirty=int(lv_dirty),
            levels_total=lv_total,
            stale_nodes=int(stale[0]),
            stale_entities=int(stale[1]),
            repair_ms=float(repair_ms),
            epoch_ms=(time.perf_counter() - t0) * 1e3,
            theta_max=int(res.theta.max()) if res is not None
            and res.theta.size else 0,
        )
