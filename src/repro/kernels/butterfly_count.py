"""Pallas TPU kernels for butterfly counting (DESIGN.md §2).

The paper's wedge traversal becomes MXU matmul tiles:

* ``vertex_count_kernel`` — fused: per (i, j) tile of W = A·Aᵀ compute
  C(W, 2), zero the diagonal, and row-reduce into a per-vertex
  accumulator.  W is never written to HBM (the fusion is the whole
  point: an n_u² intermediate would be memory-roofline death).
* ``matmul_kernel``       — generic tiled matmul used for the per-edge
  pass M = W·A (the −d_v correction happens in ops.py: (W−1)·A =
  W·A − Σ_k A[k, :]).

Block shapes are MXU-aligned (multiples of 128 on the matmul dims);
``ops.py`` pads inputs and picks blocks.  Validated against
``ref.py`` in interpret mode on CPU; compiled path targets TPU VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["vertex_count_pallas", "vertex_count_tile_pallas",
           "matmul_pallas"]


def _vertex_count_kernel(a_i_ref, a_j_ref, o_ref, acc_ref):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = jax.lax.dot_general(
        a_i_ref[...], a_j_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    bm, bn = w.shape
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    w = jnp.where(rows == cols, 0.0, w)
    acc_ref[...] += jnp.sum(w * (w - 1.0) * 0.5, axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def vertex_count_pallas(
    A: jax.Array, bm: int = 128, bn: int = 128, interpret: bool = False
) -> jax.Array:
    """Per-row-vertex butterfly counts of a padded adjacency.

    A must already be zero-padded to multiples of (bm, ...) rows; padded
    rows are all-zero so they contribute nothing.
    """
    n, k = A.shape
    assert n % bm == 0 and n % bn == 0, "pad rows before calling"
    grid = (n // bm, n // bn)
    return pl.pallas_call(
        _vertex_count_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm,), jnp.float32)],
        interpret=interpret,
    )(A, A)


def _vertex_count_tile_kernel(a_i_ref, a_j_ref, o_ref, acc_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = jax.lax.dot_general(
        a_i_ref[...], a_j_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += jnp.sum(w * (w - 1.0) * 0.5, axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def vertex_count_tile_pallas(
    A_rows: jax.Array,
    A: jax.Array,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Tile-accumulate mode: butterfly partials for ONE row tile.

    ``A_rows`` is a (rows, k) slice of the padded adjacency ``A``; the
    host loops row tiles (``ops.vertex_butterflies_tiled``), so peak
    device compute state is one (bm, k) × (bn, k) block pair no matter
    how many rows the graph has.  Unlike :func:`vertex_count_pallas`
    the diagonal is NOT masked in-kernel (the tile does not know its
    global row offset); the self-pair term is exactly C(d_r, 2) since
    W[r, r] = d_r, and the caller subtracts it on the host.
    """
    rows, k = A_rows.shape
    n = A.shape[0]
    assert rows % bm == 0 and n % bn == 0, "pad tiles before calling"
    grid = (rows // bm, n // bn)
    return pl.pallas_call(
        _vertex_count_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm,), jnp.float32)],
        interpret=interpret,
    )(A_rows, A)


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Tiled a @ b with VMEM accumulation (inputs pre-padded)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
