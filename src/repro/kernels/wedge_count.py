"""Blocked Pallas kernel for CSR wedge counting (the csr engine's hot loop).

The csr engine reduces every butterfly quantity to per-pair alive-wedge
counts W_p.  On the flat wedge list that is a segment_sum (scatter-add);
here the same reduction is expressed over the **pairs-major padded slot
matrix** (`core.csr.PaddedCSR`): row p holds pair p's wedge-alive flags,
zero padded to a lane multiple.

The kernel tiles that matrix (bp pairs × bk slots) through VMEM and
accumulates row sums across slot blocks in a VMEM scratch accumulator —
W never round-trips to HBM between slot blocks.  On the last block it
also emits a pair butterfly **estimate** C(W, 2) in f32: exact while
W(W−1) stays inside f32's integer range (W ≲ 5790), approximate beyond —
suitable for CD range *estimation*, never for final θ (the engine's
exact path derives counts from the int32 W instead and discards this
output).  Block shapes are TPU-tile aligned (sublane 8 × lane 128 for
f32); ``interpret=True`` runs the same kernel on CPU for CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wedge_count_pallas", "wedge_count_tile_pallas"]


def _wedge_count_kernel(slots_ref, w_ref, bf_ref, acc_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(slots_ref[...], axis=1)

    @pl.when(k == pl.num_programs(1) - 1)
    def _done():
        w = acc_ref[...]
        w_ref[...] = w
        bf_ref[...] = w * (w - 1.0) * 0.5


def _wedge_count_tile_kernel(slots_ref, w_ref, acc_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.sum(slots_ref[...], axis=1)

    @pl.when(k == pl.num_programs(1) - 1)
    def _done():
        w_ref[...] = acc_ref[...]


def wedge_count_tile_pallas(
    slots: jax.Array, bp: int = 8, bk: int = 128, interpret: bool = False
) -> jax.Array:
    """Tile-accumulate mode: exact int32 per-row partial counts.

    Used by the bounded-tile ⋈init path (``core.csr
    .tiled_butterfly_init``): each row holds a fixed-width segment of
    ONE pair's wedge flags, so a hub pair spans several rows whose
    int32 partials the host reduces in int64 — no f32 round-trip, no
    C(W, 2) emit, and therefore none of the 2²⁴ exactness ceiling of
    :func:`wedge_count_pallas`.  Per-launch device working set is one
    (bp, bk) block + the (bp,) accumulator regardless of tile size.

    slots: (n_rows_pad, width) int32 0/1 flags, pre-padded to (bp, bk)
    multiples.  Returns (n_rows_pad,) int32 row sums.
    """
    n, kdim = slots.shape
    assert n % bp == 0 and kdim % bk == 0, "pad slots before calling"
    grid = (n // bp, kdim // bk)
    return pl.pallas_call(
        _wedge_count_tile_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bk), lambda i, k: (i, k))],
        out_specs=pl.BlockSpec((bp,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bp,), jnp.int32)],
        interpret=interpret,
    )(slots)


def wedge_count_pallas(
    slots: jax.Array, bp: int = 128, bk: int = 128, interpret: bool = False
):
    """Per-pair wedge counts + butterflies from a padded slot matrix.

    slots: (n_pairs_pad, K) f32 alive flags, pre-padded to (bp, bk)
    multiples (padding rows/slots are zero and contribute nothing).
    Returns (W, bf), both (n_pairs_pad,) f32.
    """
    n, kdim = slots.shape
    assert n % bp == 0 and kdim % bk == 0, "pad slots before calling"
    grid = (n // bp, kdim // bk)
    return pl.pallas_call(
        _wedge_count_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bp, bk), lambda i, k: (i, k))],
        out_specs=[
            pl.BlockSpec((bp,), lambda i, k: (i,)),
            pl.BlockSpec((bp,), lambda i, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bp,), jnp.float32)],
        interpret=interpret,
    )(slots)
