"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "vertex_butterflies_ref",
    "edge_wedge_matrix_ref",
    "bloom_update_ref",
    "flash_attention_ref",
    "pair_wedge_counts_ref",
    "support_update_ref",
]


def pair_wedge_counts_ref(slots: jax.Array):
    """Row-sum oracle for the blocked wedge-count kernel: W = Σ slots,
    bf = C(W, 2)."""
    w = jnp.sum(slots.astype(jnp.float32), axis=1)
    return w, w * (w - 1.0) * 0.5


def support_update_ref(pe1, pe2, alive, W):
    """Oracle for the blocked support-update kernel, pairs-major layout.

    Inputs are (n_rows, K) f32 flags (pe1/pe2 = "slot's edge i peeled",
    alive = wedge alive) plus per-row alive wedge counts W; rows are
    graph pairs (CD path) or the flattened partition×pair stack (the
    in-loop FD path) — the algebra is row-local either way.  Returns
    (contrib1, contrib2, c): the per-slot butterfly losses charged to
    each slot's two edges and the dying-wedge count per row."""
    pe1 = pe1.astype(jnp.float32)
    pe2 = pe2.astype(jnp.float32)
    alive = alive.astype(jnp.float32)
    dies = alive * jnp.maximum(pe1, pe2)
    c = jnp.sum(dies, axis=1)
    surv_loss = (alive - dies) * c[:, None]
    widow = dies * (W.astype(jnp.float32) - 1.0)[:, None]
    return (
        (1.0 - pe1) * widow + surv_loss,
        (1.0 - pe2) * widow + surv_loss,
        c,
    )


def vertex_butterflies_ref(A: jax.Array) -> jax.Array:
    """⋈_u per row of A: Σ_{u'≠u} C(W[u,u'], 2) with W = A Aᵀ."""
    W = jnp.dot(A, A.T, preferred_element_type=jnp.float32)
    W = W * (1.0 - jnp.eye(W.shape[0], dtype=W.dtype))
    return jnp.sum(W * (W - 1.0) * 0.5, axis=1)


def edge_wedge_matrix_ref(A: jax.Array) -> jax.Array:
    """M = (W − 1) · A with W = A Aᵀ; per-edge counts are
    M[u,v] − (d_u − 1) gathered at the edge list."""
    W = jnp.dot(A, A.T, preferred_element_type=jnp.float32)
    return jnp.dot(W - 1.0, A, preferred_element_type=jnp.float32)


def bloom_update_ref(pe, pt, alive, canon, k_alive):
    """Per-bloom batch support update (alg.6 inner loop), dense layout.

    Inputs are [nb, K] bloom-major matrices (padded with alive=False) plus
    per-bloom pair counts k_alive [nb].  Returns (contrib [nb,K], c [nb]):
    c = dying pairs per bloom; contrib = per-link support loss to be
    scattered onto link_edge by the caller.
    """
    pair_dies = alive & (pe | pt)
    c = jnp.sum((pair_dies & canon).astype(jnp.float32), axis=1)
    widow = alive & ~pe & pt
    surv = alive & ~pair_dies
    contrib = (
        jnp.where(widow, k_alive[:, None] - 1.0, 0.0)
        + jnp.where(surv, c[:, None], 0.0)
    )
    return contrib, c


def flash_attention_ref(q, k, v, causal: bool = True, scale=None):
    """Plain softmax attention — oracle for the blockwise kernel.

    q: [B, H, Sq, D], k/v: [B, H, Sk, D] (kv heads already broadcast).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        # last query aligns with last key (supports sk >= sq prefill)
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
