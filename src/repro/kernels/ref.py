"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "vertex_butterflies_ref",
    "edge_wedge_matrix_ref",
    "bloom_update_ref",
    "flash_attention_ref",
    "pair_wedge_counts_ref",
    "support_update_ref",
    "fd_round_wing_ref",
    "fd_round_tip_ref",
]


def pair_wedge_counts_ref(slots: jax.Array):
    """Row-sum oracle for the blocked wedge-count kernel: W = Σ slots,
    bf = C(W, 2)."""
    w = jnp.sum(slots.astype(jnp.float32), axis=1)
    return w, w * (w - 1.0) * 0.5


def support_update_ref(pe1, pe2, alive, W):
    """Oracle for the blocked support-update kernel, pairs-major layout.

    Inputs are (n_rows, K) f32 flags (pe1/pe2 = "slot's edge i peeled",
    alive = wedge alive) plus per-row alive wedge counts W; rows are
    graph pairs (CD path) or the flattened partition×pair stack (the
    in-loop FD path) — the algebra is row-local either way.  Returns
    (contrib1, contrib2, c): the per-slot butterfly losses charged to
    each slot's two edges and the dying-wedge count per row."""
    pe1 = pe1.astype(jnp.float32)
    pe2 = pe2.astype(jnp.float32)
    alive = alive.astype(jnp.float32)
    dies = alive * jnp.maximum(pe1, pe2)
    c = jnp.sum(dies, axis=1)
    surv_loss = (alive - dies) * c[:, None]
    widow = dies * (W.astype(jnp.float32) - 1.0)[:, None]
    return (
        (1.0 - pe1) * widow + surv_loss,
        (1.0 - pe2) * widow + surv_loss,
        c,
    )


def _fd_advance_ref(sup, alive, theta, k):
    """Batched k-advance + frontier compaction shared by both fused-round
    oracles — the ``peelspec._fd_while_vmapped`` body prologue."""
    big = jnp.iinfo(jnp.int32).max
    live = jnp.any(alive, axis=1)
    k = jnp.maximum(k[:, 0], jnp.min(jnp.where(alive, sup, big), axis=1))
    S = alive & (sup <= k[:, None])
    theta = jnp.where(S, k[:, None], theta)
    return S, alive & ~S, theta, k[:, None], live


def fd_round_wing_ref(sup, alive, theta, k, rounds, nupd, aslot, W, e1, e2):
    """Oracle for the fused wing-FD round kernel, batched over the
    leading partition axis.

    Same state threading as ``fd_round_wing_pallas``: sup/alive/theta
    (B, E), k/rounds/nupd (B, 1), wedge slots (B, R, K) with sentinel
    edge id E, W (B, R).  Pure jnp — the k-advance/compaction prologue
    followed by ``support_update_ref``'s widow/survivor algebra and a
    segment-sum loss scatter."""
    alive = alive != 0
    aslot = aslot != 0
    S, alive, theta, k, live = _fd_advance_ref(sup, alive, theta, k)

    B, E = sup.shape
    S_pad = jnp.concatenate([S, jnp.zeros((B, 1), bool)], axis=1)
    pe1 = jnp.take_along_axis(S_pad, e1.reshape(B, -1), axis=1).reshape(
        e1.shape)
    pe2 = jnp.take_along_axis(S_pad, e2.reshape(B, -1), axis=1).reshape(
        e2.shape)
    # support_update_ref's widow/survivor algebra, batched over (B, R, K)
    dies = aslot & (pe1 | pe2)
    c_row = jnp.sum(dies.astype(jnp.float32), axis=2)
    surv = aslot & ~dies
    wm1 = (W.astype(jnp.float32) - 1.0)[:, :, None]
    surv_c = jnp.where(surv, c_row[:, :, None], 0.0)
    c1 = jnp.rint(
        jnp.where(dies & ~pe1, wm1, 0.0) + surv_c).astype(jnp.int32)
    c2 = jnp.rint(
        jnp.where(dies & ~pe2, wm1, 0.0) + surv_c).astype(jnp.int32)
    ci = jnp.rint(c_row).astype(jnp.int32)

    off = (jnp.arange(B, dtype=jnp.int32) * (E + 1))[:, None, None]
    loss = jax.ops.segment_sum(
        c1.reshape(-1), (e1 + off).reshape(-1), num_segments=B * (E + 1)
    ) + jax.ops.segment_sum(
        c2.reshape(-1), (e2 + off).reshape(-1), num_segments=B * (E + 1)
    )
    loss = loss.reshape(B, E + 1)[:, :E]
    nu = jnp.sum(
        (dies & (~pe1 | ~pe2)).astype(jnp.int32), axis=(1, 2)
    ) + jnp.sum((surv & (ci[:, :, None] > 0)).astype(jnp.int32), axis=(1, 2))
    return (sup - loss, alive.astype(jnp.int32), theta, k,
            rounds + live.astype(jnp.int32)[:, None],
            nupd + nu[:, None], surv.astype(jnp.int32),
            W.astype(jnp.float32) - c_row)


def fd_round_tip_ref(sup, alive, theta, k, rounds, pa, pb, bf):
    """Oracle for the fused tip-FD round kernel (batched): the k-advance
    prologue plus the static pair-butterfly delta of
    ``core.csr.tip_delta_csr`` over partition-local pair lists."""
    alive = alive != 0
    S, alive, theta, k, live = _fd_advance_ref(sup, alive, theta, k)
    B, E = sup.shape
    off = (jnp.arange(B, dtype=jnp.int32) * E)[:, None]
    Sf = S.reshape(-1)
    pag = (pa + off).reshape(-1)
    pbg = (pb + off).reshape(-1)
    loss = (
        jax.ops.segment_sum(
            jnp.where(Sf[pbg], bf.reshape(-1), 0), pag,
            num_segments=B * E)
        + jax.ops.segment_sum(
            jnp.where(Sf[pag], bf.reshape(-1), 0), pbg,
            num_segments=B * E)
    ).reshape(B, E)
    return (sup - loss, alive.astype(jnp.int32), theta, k,
            rounds + live.astype(jnp.int32)[:, None])


def vertex_butterflies_ref(A: jax.Array) -> jax.Array:
    """⋈_u per row of A: Σ_{u'≠u} C(W[u,u'], 2) with W = A Aᵀ."""
    W = jnp.dot(A, A.T, preferred_element_type=jnp.float32)
    W = W * (1.0 - jnp.eye(W.shape[0], dtype=W.dtype))
    return jnp.sum(W * (W - 1.0) * 0.5, axis=1)


def edge_wedge_matrix_ref(A: jax.Array) -> jax.Array:
    """M = (W − 1) · A with W = A Aᵀ; per-edge counts are
    M[u,v] − (d_u − 1) gathered at the edge list."""
    W = jnp.dot(A, A.T, preferred_element_type=jnp.float32)
    return jnp.dot(W - 1.0, A, preferred_element_type=jnp.float32)


def bloom_update_ref(pe, pt, alive, canon, k_alive):
    """Per-bloom batch support update (alg.6 inner loop), dense layout.

    Inputs are [nb, K] bloom-major matrices (padded with alive=False) plus
    per-bloom pair counts k_alive [nb].  Returns (contrib [nb,K], c [nb]):
    c = dying pairs per bloom; contrib = per-link support loss to be
    scattered onto link_edge by the caller.
    """
    pair_dies = alive & (pe | pt)
    c = jnp.sum((pair_dies & canon).astype(jnp.float32), axis=1)
    widow = alive & ~pe & pt
    surv = alive & ~pair_dies
    contrib = (
        jnp.where(widow, k_alive[:, None] - 1.0, 0.0)
        + jnp.where(surv, c[:, None], 0.0)
    )
    return contrib, c


def flash_attention_ref(q, k, v, causal: bool = True, scale=None):
    """Plain softmax attention — oracle for the blockwise kernel.

    q: [B, H, Sq, D], k/v: [B, H, Sk, D] (kv heads already broadcast).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        # last query aligns with last key (supports sk >= sq prefill)
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
