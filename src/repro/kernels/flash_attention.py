"""Blockwise (flash) attention Pallas kernel — the LM-side hot spot.

Grid (batch·heads, q_blocks, kv_blocks); online-softmax running max/sum
live in VMEM scratch; KV tiles stream through VMEM so the S×S score
matrix never exists.  Causal masking supports the decode/prefill case
where Sk ≥ Sq (queries align with the cache suffix).

This kernel is the TPU analogue of the memory-roofline fix the roofline
analysis demands for the 32k-prefill shapes; the pure-jnp blockwise
reference (models/attention.py) is what the CPU dry-run lowers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, offset: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    v = v_ref[0]  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    if causal:
        bq, bk = s.shape
        q_ids = (pl.program_id(1) * bq + offset
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
        k_ids = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_ids <= q_ids, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(2) - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BH, Sk, D]
    v: jax.Array,  # [BH, Sk, D]
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    offset: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    assert sq % bq == 0 and sk % bk == 0, "pad sequence dims before calling"
    if scale is None:
        scale = d ** -0.5
    if offset is None:
        offset = sk - sq
    grid = (bh, sq // bq, sk // bk)
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, offset=offset
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
