"""Pallas TPU kernels for PBNG's compute hot-spots + the LM attention.

Each kernel ships with an ``ops.py`` jit wrapper and a ``ref.py`` pure-jnp
oracle; tests sweep shapes/dtypes in interpret mode.
"""
from . import ops, ref
from .ops import (
    bloom_update,
    edge_wedge_matrix,
    flash_attention,
    pack_blooms,
    pair_wedge_counts,
    vertex_butterflies,
)

__all__ = [
    "ops",
    "ref",
    "bloom_update",
    "edge_wedge_matrix",
    "flash_attention",
    "pack_blooms",
    "pair_wedge_counts",
    "vertex_butterflies",
]
