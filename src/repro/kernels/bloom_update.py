"""Pallas kernel for the batched BE-Index support update (alg.6).

The peeling hot loop.  Host/XLA performs the (cheap, gather-friendly)
indexing — ``pe = peeled[link_edge]`` etc. — and packs links bloom-major
into dense [nb, K] matrices (K = padded pairs-per-bloom bucket).  The
kernel then does the bandwidth-bound part entirely in VMEM:

    pair_dies = alive & (pe | pt)
    c_B       = row-sum(pair_dies & canon)          (dying pairs)
    contrib   = widow ? (k_alive − 1) : surv ? c_B : 0

This is pure VPU work on 8×128 lanes — the TPU analogue of the paper's
per-bloom aggregation with atomics.  The scatter of ``contrib`` back to
edges stays in XLA (segment_sum).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bloom_update_pallas"]


def _bloom_update_kernel(pe_ref, pt_ref, alive_ref, canon_ref, k_ref,
                         contrib_ref, c_ref):
    pe = pe_ref[...]
    pt = pt_ref[...]
    alive = alive_ref[...]
    canon = canon_ref[...]
    k_alive = k_ref[...]

    pair_dies = alive & (pe | pt)
    c = jnp.sum(
        jnp.where(pair_dies & canon, 1.0, 0.0), axis=1, dtype=jnp.float32
    )
    widow = alive & jnp.logical_not(pe) & pt
    surv = alive & jnp.logical_not(pair_dies)
    contrib = jnp.where(widow, k_alive[:, None] - 1.0, 0.0) + jnp.where(
        surv, c[:, None], 0.0
    )
    contrib_ref[...] = contrib
    c_ref[...] = c


def bloom_update_pallas(
    pe: jax.Array,      # [nb, K] bool — peeled(link_edge)
    pt: jax.Array,      # [nb, K] bool — peeled(link_twin)
    alive: jax.Array,   # [nb, K] bool — pair alive
    canon: jax.Array,   # [nb, K] bool — canonical pair marker
    k_alive: jax.Array,  # [nb] f32    — alive pairs per bloom
    bb: int = 256,
    interpret: bool = False,
):
    """Returns (contrib [nb,K] f32, c [nb] f32).  nb must divide by bb."""
    nb, K = pe.shape
    assert nb % bb == 0, "pad bloom rows before calling"
    grid = (nb // bb,)
    row = pl.BlockSpec((bb, K), lambda i: (i, 0))
    return pl.pallas_call(
        _bloom_update_kernel,
        grid=grid,
        in_specs=[row, row, row, row, pl.BlockSpec((bb,), lambda i: (i,))],
        out_specs=(row, pl.BlockSpec((bb,), lambda i: (i,))),
        out_shape=(
            jax.ShapeDtypeStruct((nb, K), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ),
        interpret=interpret,
    )(pe, pt, alive, canon, k_alive)
