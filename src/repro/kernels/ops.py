"""Public jit'd wrappers around the Pallas kernels.

Handle padding to MXU-aligned blocks, interpret-mode selection (CPU
container → interpret=True; real TPU → compiled), and the bloom-major
dense packing used by ``bloom_update_pallas``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bloom_update import bloom_update_pallas
from .butterfly_count import (
    matmul_pallas,
    vertex_count_pallas,
    vertex_count_tile_pallas,
)
from .fd_round import fd_round_tip_pallas, fd_round_wing_pallas
from .flash_attention import flash_attention_pallas
from .support_update import support_update_pallas
from .wedge_count import wedge_count_pallas, wedge_count_tile_pallas

__all__ = [
    "vertex_butterflies",
    "vertex_butterflies_tiled",
    "edge_wedge_matrix",
    "bloom_update",
    "fd_round_tip",
    "fd_round_wing",
    "flash_attention",
    "pack_blooms",
    "pair_wedge_counts",
    "support_update",
    "tile_row_counts",
    "tip_slot_loss",
    "default_interpret",
]


def default_interpret() -> bool:
    """Pallas interpret mode unless running on real TPU."""
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def vertex_butterflies(
    A: jax.Array, bm: int = 128, bn: int = 128, interpret: bool = True
) -> jax.Array:
    """Per-row butterfly counts via the fused count kernel."""
    n = A.shape[0]
    Ap = _pad_to(_pad_to(A.astype(jnp.float32), bm, 0), 128, 1)
    # rows must also tile by bn for the column blocks of W
    Ap = _pad_to(Ap, bn, 0)
    out = vertex_count_pallas(Ap, bm=bm, bn=bn, interpret=interpret)
    return out[:n]


def _row_bucket(n: int, mult: int) -> int:
    """Round n up to a quarter-pow2 bucket (a multiple of ``mult``).

    Tile row counts vary per tile; jitting on the raw count would
    recompile the wrapper for every tile.  Bucketing to {1, 1.25, 1.5,
    1.75}·2^k caps the number of compiled shapes at O(log n) while
    wasting < 25 % rows of zero padding.
    """
    n = max(int(n), mult)
    p = 1 << (n - 1).bit_length()      # smallest pow2 >= n
    half = p // 2
    for q in (4, 5, 6, 7):
        cand = -(-(half * q // 4) // mult) * mult
        if cand >= n:
            return cand
    return -(-p // mult) * mult


@functools.partial(jax.jit, static_argnames=("bp", "bk", "interpret"))
def _tile_row_counts_inner(slots, bp, bk, interpret):
    s = _pad_to(_pad_to(slots, bp, 0), bk, 1)
    return wedge_count_tile_pallas(s, bp=bp, bk=bk, interpret=interpret)


def tile_row_counts(
    slots: np.ndarray,
    bp: int = 8,
    bk: int = 128,
    interpret: bool | None = None,
) -> np.ndarray:
    """Exact int32 row sums of an int32 0/1 slot matrix.

    The bounded-tile ⋈init path (``core.csr.tiled_butterfly_init``)
    calls this once per wedge tile; rows are fixed-width segments of a
    pair's flags, reduced to int64 totals on the host.  Row counts are
    bucketed (``_row_bucket``) so repeated tiles hit a handful of
    compiled shapes instead of one per tile.
    """
    if interpret is None:
        interpret = default_interpret()
    n = slots.shape[0]
    nb = _row_bucket(n, bp)
    if nb > n:
        slots = np.pad(slots, ((0, nb - n), (0, 0)))
    out = _tile_row_counts_inner(
        jnp.asarray(slots, jnp.int32), bp, bk, interpret
    )
    return np.asarray(out)[:n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def _vertex_tile_inner(A_rows, Ap, bm, bn, interpret):
    return vertex_count_tile_pallas(
        A_rows, Ap, bm=bm, bn=bn, interpret=interpret
    )


def vertex_butterflies_tiled(
    A,
    tile_rows: int = 1024,
    bm: int = 128,
    bn: int = 128,
    interpret: bool | None = None,
) -> np.ndarray:
    """Per-row butterfly counts with one row tile in flight at a time.

    Host loop over ``tile_rows``-row slices of the padded adjacency,
    each dispatched through the tile-accumulate kernel
    (``vertex_count_tile_pallas``); the kernel skips diagonal masking
    (a tile doesn't know its global row offset, and baking the offset
    in would recompile per tile), so the exact self-pair term C(d_r, 2)
    is subtracted here.  Every tile is padded to the same shape — one
    compiled program total.  Returns int64 counts.
    """
    if interpret is None:
        interpret = default_interpret()
    A = np.asarray(A)
    n = A.shape[0]
    deg = A.sum(axis=1).astype(np.int64)
    tile_rows = max(-(-tile_rows // bm) * bm, bm)
    Ap = np.asarray(
        _pad_to(_pad_to(jnp.asarray(A, jnp.float32), bn, 0), 128, 1)
    )
    Aj = jnp.asarray(Ap)
    out = np.zeros(n, dtype=np.float64)
    for r0 in range(0, n, tile_rows):
        r1 = min(r0 + tile_rows, n)
        tile = Ap[r0:r1]
        if tile.shape[0] < tile_rows:
            tile = np.pad(tile, ((0, tile_rows - tile.shape[0]), (0, 0)))
        part = _vertex_tile_inner(
            jnp.asarray(tile), Aj, bm, bn, interpret
        )
        out[r0:r1] = np.asarray(part, dtype=np.float64)[: r1 - r0]
    self_pair = deg * (deg - 1) // 2
    return np.rint(out).astype(np.int64) - self_pair


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def edge_wedge_matrix(
    A: jax.Array,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """M = (W − 1)·A with W = A·Aᵀ, both matmuls tiled in Pallas.

    Uses the identity (W − 1)·A = W·A − d_v so the −1 never materializes.
    Per-edge counts = M[u, v] − (d_u − 1), gathered by the caller.
    """
    n, nv = A.shape
    Af = A.astype(jnp.float32)
    Ap = _pad_to(_pad_to(Af, max(bm, bn, bk), 0), bk, 1)
    W = matmul_pallas(Ap, Ap.T, bm=bm, bn=bn, bk=bk, interpret=interpret)
    Ap2 = _pad_to(_pad_to(Af, bk, 0), bn, 1)
    W = W[: Ap2.shape[0], : Ap2.shape[0]]
    M = matmul_pallas(W, Ap2, bm=bm, bn=bn, bk=bk, interpret=interpret)
    dv = jnp.sum(Af, axis=0)
    return M[:n, :nv] - dv[None, :]


@functools.partial(jax.jit, static_argnames=("bp", "bk", "interpret"))
def pair_wedge_counts(
    slots: jax.Array, bp: int = 128, bk: int = 128, interpret: bool = True
) -> Tuple[jax.Array, jax.Array]:
    """Per-pair wedge counts W and the f32 butterfly estimate C(W, 2)
    via the blocked wedge-count kernel (estimate is exact only while
    W(W−1) fits f32 integers — see ``wedge_count.py``).  ``slots`` is
    the pairs-major alive matrix (``core.csr.pack_wedge_slots``);
    padding is handled here."""
    n = slots.shape[0]
    s = _pad_to(_pad_to(slots.astype(jnp.float32), bp, 0), bk, 1)
    W, bf = wedge_count_pallas(s, bp=bp, bk=bk, interpret=interpret)
    return W[:n], bf[:n]


@functools.partial(jax.jit, static_argnames=("bp", "bk", "interpret"))
def tip_slot_loss(
    vals: jax.Array, bp: int = 128, bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Per-row f32 sums of masked pair-butterfly values — the tip CD
    support delta through the blocked wedge-count kernel.

    ``vals`` is the vertex-major slot matrix (``core.csr.pack_tip_slots``)
    with each slot holding the pair's static butterfly count where the
    partner vertex was peeled this round, 0 otherwise; the kernel's
    row-sum phase IS the delta (its C(W, 2) output is ignored).  Rows
    are vertices, so the result needs no scatter.  Exact while per-row
    sums stay under 2²⁴ (guarded at pack time)."""
    n = vals.shape[0]
    v = _pad_to(_pad_to(vals.astype(jnp.float32), bp, 0), bk, 1)
    W, _ = wedge_count_pallas(v, bp=bp, bk=bk, interpret=interpret)
    return W[:n]


@functools.partial(jax.jit, static_argnames=("bp", "bk", "interpret"))
def support_update(
    pe1: jax.Array,
    pe2: jax.Array,
    alive: jax.Array,
    W: jax.Array,
    bp: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One csr support-update round through the blocked Pallas kernel.

    ``pe1``/``pe2``/``alive`` are (n_rows, K) pairs-major slot flags,
    ``W`` the per-row alive wedge counts.  Rows are pairs of ONE graph
    for the CD path (``core.csr.pack_update_slots``) or the flattened
    partition×pair stack for the in-loop FD path
    (``core.peel._fd_wing_vmapped_pallas`` — partitions ride the row
    grid).  Padding to (bp, bk) tiles is handled here.  Returns
    (contrib1, contrib2, c) trimmed back to the input shape — per-slot
    losses for each slot's two edges plus dying wedges per row."""
    n, kdim = pe1.shape

    def padf(x):
        return _pad_to(_pad_to(x.astype(jnp.float32), bp, 0), bk, 1)

    c1, c2, c = support_update_pallas(
        padf(pe1), padf(pe2), padf(alive),
        _pad_to(W.astype(jnp.float32), bp, 0),
        bp=bp, bk=bk, interpret=interpret,
    )
    return c1[:n, :kdim], c2[:n, :kdim], c[:n]


# The fd_round wrappers are deliberately NOT jitted: they only ever run
# inside an already-jitted while_loop body (``peelspec._fd_while_fused``
# consumers), where a nested pjit would wrap the pallas_call and obscure
# the round body's jaxpr — tests assert that body is exactly ONE
# pallas_call and nothing else (tests/test_fused_fd.py).
def fd_round_wing(sup, alive, theta, k, rounds, nupd, aslot, W, e1, e2,
                  interpret: bool | None = None):
    """One fused wing-FD round (k-advance + frontier compaction + widow/
    survivor support update) as a single Pallas launch.

    State in/out (same order): sup/alive/theta (B, E) i32, k/rounds/
    nupd (B, 1) i32, wedge-slot alive (B, R, K) i32, W (B, R) f32.
    ``e1``/``e2`` are the static (B, R, K) local edge ids with sentinel
    E (``distributed._pack_fd_slots_csr``)."""
    if interpret is None:
        interpret = default_interpret()
    return fd_round_wing_pallas(
        sup, alive, theta, k, rounds, nupd, aslot, W, e1, e2,
        interpret=interpret)


def fd_round_tip(sup, alive, theta, k, rounds, pa, pb, bf,
                 interpret: bool | None = None):
    """One fused tip-FD round as a single Pallas launch.

    State in/out (same order): sup/alive/theta (B, E) i32, k/rounds
    (B, 1) i32.  ``pa``/``pb``/``bf`` are the static (B, L) partition-
    local pair lists (``pack_fd_partitions_tip_csr(stacked=True)``;
    bf=0 padding is algebra-neutral)."""
    if interpret is None:
        interpret = default_interpret()
    return fd_round_tip_pallas(
        sup, alive, theta, k, rounds, pa, pb, bf, interpret=interpret)


def pack_blooms(
    link_edge: np.ndarray,
    link_twin: np.ndarray,
    link_bloom: np.ndarray,
    nb: int,
    bb: int = 256,
) -> dict:
    """Bloom-major dense packing: row b holds bloom b's links, padded to
    the max pairs-per-bloom (rounded to a lane multiple of 128)."""
    order = np.argsort(link_bloom, kind="stable")
    le, lt, lb = link_edge[order], link_twin[order], link_bloom[order]
    counts = np.bincount(lb, minlength=nb)
    K = max(int(counts.max() if counts.size else 1), 1)
    K = int(-(-K // 128) * 128)
    nb_pad = int(-(-max(nb, 1) // bb) * bb)
    col = np.zeros(le.size, dtype=np.int64)
    off = np.zeros(nb + 1, dtype=np.int64)
    np.cumsum(counts, out=off[1:])
    col = np.arange(le.size) - off[lb]
    dense = dict(
        le=np.full((nb_pad, K), -1, np.int32),
        lt=np.full((nb_pad, K), -1, np.int32),
        valid=np.zeros((nb_pad, K), bool),
        canon=np.zeros((nb_pad, K), bool),
    )
    dense["le"][lb, col] = le
    dense["lt"][lb, col] = lt
    dense["valid"][lb, col] = True
    dense["canon"][lb, col] = le < lt
    dense["nb"] = nb
    dense["nb_pad"] = nb_pad
    dense["K"] = K
    return dense


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def bloom_update(
    peeled: jax.Array,       # (m+1,) bool, sentinel last
    alive_pair: jax.Array,   # [nb_pad, K] bool
    k_alive: jax.Array,      # [nb_pad] f32
    le: jax.Array,           # [nb_pad, K] int32 (−1 → sentinel)
    lt: jax.Array,
    canon: jax.Array,        # [nb_pad, K] bool
    m: int = 0,
    bb: int = 256,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One batched support-update round through the Pallas kernel.

    Returns (loss per edge (m,), c per bloom, new alive_pair)."""
    sent = peeled.shape[0] - 1
    lei = jnp.where(le < 0, sent, le)
    lti = jnp.where(lt < 0, sent, lt)
    pe = peeled[lei]
    pt = peeled[lti]
    contrib, c = bloom_update_pallas(
        pe, pt, alive_pair, canon, k_alive, bb=bb, interpret=interpret
    )
    pair_dies = alive_pair & (pe | pt)
    loss = jax.ops.segment_sum(
        contrib.reshape(-1), lei.reshape(-1), num_segments=sent + 1
    )[:-1]
    return loss, c, alive_pair & ~pair_dies


@functools.partial(
    jax.jit, static_argnames=("causal", "bq", "bk", "interpret")
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, H, Sk, D]
    v: jax.Array,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    sk = k.shape[2]
    bq = min(bq, sq) if sq % min(bq, sq) == 0 else bq
    qr = _pad_to(q.reshape(b * h, sq, d), bq, 1)
    kr = _pad_to(k.reshape(b * h, sk, d), bk, 1)
    vr = _pad_to(v.reshape(b * h, sk, d), bk, 1)
    # padded keys must never win the softmax: mask via an explicit -inf
    # key would complicate the kernel; instead rely on causal masking for
    # the padded tail (padded queries are discarded, padded keys have
    # k_ids > every real q_id when causal).  For non-causal, require
    # exact multiples.
    if not causal:
        assert sq % bq == 0 and sk % bk == 0
    # the causal diagonal offset must come from the LOGICAL sq/sk, not the
    # padded shapes — padded key ids then sit above every real query id
    # and mask themselves out
    out = flash_attention_pallas(
        qr, kr, vr, causal=causal, bq=bq, bk=bk, offset=sk - sq,
        interpret=interpret
    )
    return out[:, :sq].reshape(b, h, sq, d)
