"""Blocked Pallas kernel for the csr engine's batched support update.

``core.csr.wing_loss_csr`` is a segment-subtract over the flat wedge
list: every peeled edge kills its wedges, and each death charges
butterfly losses to the surviving edges (widow / survivor algebra).
Here the same round runs over the **pairs-major padded slot matrix**
(`core.csr.PaddedCSR`): row p holds pair p's wedges, so the dying-wedge
count c_p is a row reduction and every per-slot contribution depends
only on its own flags plus (c_p, W_p).

The kernel tiles (bp pairs × bk slots) through VMEM with a two-phase
grid per row block:

  phase 0 — accumulate c_p (dying wedges per pair) across slot blocks in
            a VMEM scratch; nothing is written to HBM;
  phase 1 — re-stream the same slot blocks and emit the per-slot losses
            ``contrib1`` (to edge e1) and ``contrib2`` (to edge e2),
            plus c on the last block.

Per slot w of pair p (alive, flags pe1/pe2 = "edge i peeled"):

    dies          = alive ∧ (pe1 ∨ pe2)
    contrib1[w]   = dies ∧ ¬pe1 ? W_p − 1 : (alive ∧ ¬dies ? c_p : 0)
    contrib2[w]   = dies ∧ ¬pe2 ? W_p − 1 : (alive ∧ ¬dies ? c_p : 0)

The caller scatters contribs onto edges with one ``segment_sum`` per
side (``kernels.ops.support_update`` / ``core.csr.wing_update_slots``).
Counts travel as f32 through the MXU-aligned tiles — exact while W_p
fits f32 integers (< 2²⁴); the flat ``segment_sum`` path stays the
engine's exactness reference.  ``interpret=True`` runs the same kernel
on CPU for CI parity tests; compiled on TPU.

Two consumers drive the kernel:

  * **CD rounds** — ``core.csr.wing_update_slots`` over one graph-wide
    slot matrix (``wing_decomposition(use_pallas=True)``);
  * **the FD while_loop body** — ``core.peel._fd_wing_vmapped_pallas``
    flattens the stacked per-partition slot blocks along rows into one
    (B·R, K) matrix, so a single launch per peel round covers every
    partition of the single-dispatch Phase 2.  The row grid is
    oblivious to the partition structure: c_p stays a pure row
    reduction either way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["support_update_pallas"]


def _support_update_kernel(
    pe1_ref, pe2_ref, alive_ref, w_ref,
    c1_ref, c2_ref, c_ref, acc_ref,
):
    phase = pl.program_id(1)
    k = pl.program_id(2)

    alive = alive_ref[...]
    dies = alive * jnp.maximum(pe1_ref[...], pe2_ref[...])

    @pl.when((phase == 0) & (k == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(phase == 0)
    def _accumulate():
        acc_ref[...] += jnp.sum(dies, axis=1)

    @pl.when(phase == 1)
    def _emit():
        c = acc_ref[...]
        surv_loss = (alive - dies) * c[:, None]          # survivor rule
        widow = dies * (w_ref[...] - 1.0)[:, None]       # widow rule
        c1_ref[...] = (1.0 - pe1_ref[...]) * widow + surv_loss
        c2_ref[...] = (1.0 - pe2_ref[...]) * widow + surv_loss
        c_ref[...] = c


def support_update_pallas(
    pe1: jax.Array,
    pe2: jax.Array,
    alive: jax.Array,
    W: jax.Array,
    bp: int = 128,
    bk: int = 128,
    interpret: bool = False,
):
    """One support-update round over pairs-major slot matrices.

    pe1/pe2/alive: (n_pairs_pad, K) f32 flags, pre-padded to (bp, bk)
    multiples (padding slots have alive=0 and contribute nothing).
    W: (n_pairs_pad,) f32 current alive wedge count per pair.
    Returns (contrib1, contrib2, c): per-slot losses for each edge side
    and the dying-wedge count per pair.
    """
    n, kdim = pe1.shape
    assert n % bp == 0 and kdim % bk == 0, "pad slots before calling"
    grid = (n // bp, 2, kdim // bk)
    slot_spec = pl.BlockSpec((bp, bk), lambda i, ph, k: (i, k))
    return pl.pallas_call(
        _support_update_kernel,
        grid=grid,
        in_specs=[
            slot_spec,
            slot_spec,
            slot_spec,
            pl.BlockSpec((bp,), lambda i, ph, k: (i,)),
        ],
        out_specs=[
            slot_spec,
            slot_spec,
            pl.BlockSpec((bp,), lambda i, ph, k: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n, kdim), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bp,), jnp.float32)],
        interpret=interpret,
    )(pe1, pe2, alive, W)
