"""Fused FD round kernel — the whole peel round as ONE Pallas launch.

The FD cascade drivers (``core.peelspec._fd_while_vmapped`` /
``_fd_while_device``) used to run each round as a Pallas
``support_update`` launch plus a tail of XLA ops: the k-advance
(min-scan to the next peelable level), the frontier compaction
(θ write + alive mask update) and the loss scatter (two segment_sums).
That tail is pure dispatch overhead in the regime the vmapped driver
exists for — many small partitions, rounds bounded by latency, not
flops.  This kernel fuses the ENTIRE round body:

    live  = any(alive)                     # round accounting
    k     = max(k, min(alive ? sup : BIG)) # k-advance
    S     = alive & (sup <= k)             # peel frontier
    theta = S ? k : theta;  alive &= ~S    # frontier compaction
    ...widow/survivor support algebra...   # support update
    sup  -= scatter-add(c1, c2)            # loss applied in-kernel

so a round is one ``pallas_call`` and nothing else — the while_loop
body's jaxpr holds exactly one primitive doing real work (asserted by
``tests/test_fused_fd.py``).

Layout: grid = (B,), one program per stacked FD partition.  Each
program owns its partition's full state as VMEM-resident blocks —
``sup``/``alive``/``theta`` (1, E), the pairs-major wedge slots
(1, R, K) with sentinel edge id E (``distributed._pack_fd_slots_csr``),
per-pair alive wedge counts W (1, R) and the (1, 1) scalar carries
k/rounds/nupd.  ALL loop state flows through the kernel, so the caller
threads the outputs straight back in as the next round's inputs.

Exactness: the widow/survivor counts ride f32 lanes (same VPU shapes as
``support_update``) and are re-integerized with ``rint`` per slot, then
summed as int32 by the in-kernel scatter-add — exact while W_p < 2²⁴
(guarded at pack time; the per-edge loss itself is int32 and may exceed
2²⁴ safely).  Masks travel as int32 0/1 blocks.

The in-kernel gather (``S_pad[e1]``) and scatter-add are interpret-mode
legal everywhere; on a real TPU backend their Mosaic lowering is the
compatibility boundary — ``kernels/ops.py`` defaults to interpret mode
off-TPU like every other kernel here (see docs/KERNELS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fd_round_wing_pallas", "fd_round_tip_pallas"]

_BIG = jnp.iinfo(jnp.int32).max  # == peelspec._FD_BIG


def _advance(sup, alive, theta, k):
    """Shared k-advance + frontier compaction: returns the peel mask S
    and the updated (alive, theta, k, live) — bit-identical to the
    ``_fd_while_vmapped`` body's prologue for one partition row."""
    live = jnp.any(alive)
    k = jnp.maximum(k, jnp.min(jnp.where(alive, sup, _BIG)))
    S = alive & (sup <= k)
    theta = jnp.where(S, k, theta)
    alive = alive & ~S
    return S, alive, theta, k, live


def _fd_round_wing_kernel(sup_ref, alive_ref, theta_ref, k_ref, rounds_ref,
                          nupd_ref, aslot_ref, w_ref, e1_ref, e2_ref,
                          sup_o, alive_o, theta_o, k_o, rounds_o, nupd_o,
                          aslot_o, w_o):
    sup = sup_ref[0]                 # (E,) int32
    alive = alive_ref[0] != 0        # (E,)
    aslot = aslot_ref[0] != 0        # (R, K) wedge-slot alive
    W = w_ref[0]                     # (R,) f32 alive wedges per pair
    e1 = e1_ref[0]                   # (R, K) int32 local edge ids, sentinel E
    e2 = e2_ref[0]

    S, alive, theta, k, live = _advance(sup, alive, theta_ref[0], k_ref[0, 0])

    # widow/survivor support algebra (== kernels.ref.support_update_ref)
    S_pad = jnp.concatenate([S, jnp.zeros((1,), bool)])
    pe1 = S_pad[e1]
    pe2 = S_pad[e2]
    dies = aslot & (pe1 | pe2)
    c_row = jnp.sum(dies.astype(jnp.float32), axis=1)     # dying wedges/pair
    surv = aslot & ~dies
    wm1 = (W - 1.0)[:, None]
    surv_c = jnp.where(surv, c_row[:, None], 0.0)
    c1 = jnp.rint(jnp.where(dies & ~pe1, wm1, 0.0) + surv_c).astype(jnp.int32)
    c2 = jnp.rint(jnp.where(dies & ~pe2, wm1, 0.0) + surv_c).astype(jnp.int32)
    ci = jnp.rint(c_row).astype(jnp.int32)

    E = sup.shape[0]
    loss = (
        jnp.zeros((E + 1,), jnp.int32)   # +1: sentinel discard slot
        .at[e1.reshape(-1)].add(c1.reshape(-1))
        .at[e2.reshape(-1)].add(c2.reshape(-1))
    )[:E]
    nu = jnp.sum((dies & (~pe1 | ~pe2)).astype(jnp.int32)) + jnp.sum(
        (surv & (ci[:, None] > 0)).astype(jnp.int32)
    )

    sup_o[0] = sup - loss
    alive_o[0] = alive.astype(jnp.int32)
    theta_o[0] = theta
    k_o[0, 0] = k
    rounds_o[0, 0] = rounds_ref[0, 0] + live.astype(jnp.int32)
    nupd_o[0, 0] = nupd_ref[0, 0] + nu
    aslot_o[0] = surv.astype(jnp.int32)
    w_o[0] = W - c_row


def fd_round_wing_pallas(sup, alive, theta, k, rounds, nupd, aslot, W,
                         e1, e2, interpret: bool = True):
    """One fused wing-FD round over all B stacked partitions.

    State: sup/alive/theta (B, E) i32, k/rounds/nupd (B, 1) i32, wedge
    slots alive (B, R, K) i32, W (B, R) f32; statics e1/e2 (B, R, K)
    i32.  Returns the 8-tuple of updated state in the same order.
    """
    B, E = sup.shape
    _, R, K = e1.shape
    sE = pl.BlockSpec((1, E), lambda b: (b, 0))
    s1 = pl.BlockSpec((1, 1), lambda b: (b, 0))
    sRK = pl.BlockSpec((1, R, K), lambda b: (b, 0, 0))
    sR = pl.BlockSpec((1, R), lambda b: (b, 0))
    i32 = jnp.int32
    return pl.pallas_call(
        _fd_round_wing_kernel,
        grid=(B,),
        in_specs=[sE, sE, sE, s1, s1, s1, sRK, sR, sRK, sRK],
        out_specs=[sE, sE, sE, s1, s1, s1, sRK, sR],
        out_shape=[
            jax.ShapeDtypeStruct((B, E), i32),      # sup
            jax.ShapeDtypeStruct((B, E), i32),      # alive
            jax.ShapeDtypeStruct((B, E), i32),      # theta
            jax.ShapeDtypeStruct((B, 1), i32),      # k
            jax.ShapeDtypeStruct((B, 1), i32),      # rounds
            jax.ShapeDtypeStruct((B, 1), i32),      # nupd
            jax.ShapeDtypeStruct((B, R, K), i32),   # alive slots
            jax.ShapeDtypeStruct((B, R), jnp.float32),  # W
        ],
        interpret=interpret,
    )(sup, alive, theta, k, rounds, nupd, aslot, W, e1, e2)


def _fd_round_tip_kernel(sup_ref, alive_ref, theta_ref, k_ref, rounds_ref,
                         pa_ref, pb_ref, bf_ref,
                         sup_o, alive_o, theta_o, k_o, rounds_o):
    sup = sup_ref[0]                 # (E,) int32
    alive = alive_ref[0] != 0
    pa = pa_ref[0]                   # (L,) int32 partition-local vertex ids
    pb = pb_ref[0]
    bf = bf_ref[0]                   # (L,) int32 static pair ⋈ (0 on pad)

    S, alive, theta, k, live = _advance(sup, alive, theta_ref[0], k_ref[0, 0])

    # static pair-butterfly delta (== core.csr.tip_delta_csr): vertex u
    # loses bf(u, u') when partner u' peels; pad entries carry bf=0
    E = sup.shape[0]
    loss = (
        jnp.zeros((E,), jnp.int32)
        .at[pa].add(jnp.where(S[pb], bf, 0))
        .at[pb].add(jnp.where(S[pa], bf, 0))
    )

    sup_o[0] = sup - loss
    alive_o[0] = alive.astype(jnp.int32)
    theta_o[0] = theta
    k_o[0, 0] = k
    rounds_o[0, 0] = rounds_ref[0, 0] + live.astype(jnp.int32)


def fd_round_tip_pallas(sup, alive, theta, k, rounds, pa, pb, bf,
                        interpret: bool = True):
    """One fused tip-FD round over all B stacked partitions.

    State: sup/alive/theta (B, E) i32, k/rounds (B, 1) i32; statics
    pa/pb/bf (B, L) i32 (``pack_fd_partitions_tip_csr(stacked=True)``).
    Returns the 5-tuple of updated state in the same order.  Tip carries
    no per-wedge state (pair butterflies are static), hence no nupd.
    """
    B, E = sup.shape
    L = pa.shape[1]
    sE = pl.BlockSpec((1, E), lambda b: (b, 0))
    s1 = pl.BlockSpec((1, 1), lambda b: (b, 0))
    sL = pl.BlockSpec((1, L), lambda b: (b, 0))
    i32 = jnp.int32
    return pl.pallas_call(
        _fd_round_tip_kernel,
        grid=(B,),
        in_specs=[sE, sE, sE, s1, s1, sL, sL, sL],
        out_specs=[sE, sE, sE, s1, s1],
        out_shape=[
            jax.ShapeDtypeStruct((B, E), i32),      # sup
            jax.ShapeDtypeStruct((B, E), i32),      # alive
            jax.ShapeDtypeStruct((B, E), i32),      # theta
            jax.ShapeDtypeStruct((B, 1), i32),      # k
            jax.ShapeDtypeStruct((B, 1), i32),      # rounds
        ],
        interpret=interpret,
    )(sup, alive, theta, k, rounds, pa, pb, bf)
