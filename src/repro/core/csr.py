"""Sparse CSR peeling engine — wedge-list butterfly machinery (alg.1 on TPU).

The ``dense`` engine materializes the full n_u×n_v adjacency (and an
n_u×n_u wedge matrix) for every batch re-count, capping graph size at
O(n²) memory long before butterfly workload matters.  This module is the
O(Σ deg²) alternative used by ``engine="csr"``: ParButterfly/RECEIPT-style
wedge enumeration, expressed with static shapes so XLA can compile it.

Pipeline:
  1. Host side (numpy, vectorized): flatten the graph's V-side CSR into a
     **wedge list** — every pair of edges sharing a V center.  Wedges with
     the same U-endpoint pair {a, b} are grouped under one *pair id*; a
     butterfly is exactly two wedges of the same pair, so all counting
     reduces to per-pair wedge counts W_p:

         pair butterflies       = C(W_p, 2)
         ⋈_u (vertex support)   = Σ_{p ∋ u} C(W_p, 2)
         ⋈_e (edge support)     = Σ_{wedges w ∋ e} (W_{p(w)} − 1)

  2. Device side: all counts are ``jax.ops.segment_sum`` over the flat
     wedge list; peeling updates are *incremental* — only butterflies
     incident to peeled entities are recomputed (the BE-Index widow /
     survivor algebra with pairs playing the role of blooms).

  3. Optionally, the per-pair reduction runs through the blocked Pallas
     kernel in ``repro.kernels.wedge_count`` over a :class:`PaddedCSR`
     pairs-major slot matrix (MXU/VMEM tiling; interpret mode on CPU).

Everything is exact integer arithmetic (int32 on device) — no f32
rounding, so θ from the csr engine is bit-identical to the BUP oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graph import BipartiteGraph

__all__ = [
    "Wedges",
    "PaddedCSR",
    "TileStats",
    "build_wedges",
    "iter_wedge_tiles",
    "tiled_butterfly_init",
    "pad_segments",
    "pack_wedge_slots",
    "directed_pair_incidence",
    "pack_tip_slots",
    "pack_update_slots",
    "wedge_workload",
    "pair_wedge_counts",
    "vertex_butterflies_csr",
    "edge_butterflies_csr",
    "total_butterflies_csr",
    "tip_delta_csr",
    "tip_delta_slots",
    "wing_loss_csr",
    "wing_update_csr",
    "wing_update_slots",
]

_INT_LIMIT = 2 ** 31 - 1  # device counts are int32; guard exactness


# =====================================================================
# Host-side construction
# =====================================================================
@dataclasses.dataclass(frozen=True)
class Wedges:
    """Flattened wedge list of a bipartite graph (centers on the V side).

    A wedge is an ordered triple (u_a, v, u_b) with u_a < u_b; it is
    stored as its two edge ids plus the id of its U-endpoint *pair*.
    All arrays are host numpy; engines move them to device once.
    """

    n_u: int
    n_v: int
    m: int
    n_pairs: int
    pair_a: np.ndarray      # (n_pairs,) int32 — smaller U endpoint
    pair_b: np.ndarray      # (n_pairs,) int32 — larger U endpoint
    wedge_pair: np.ndarray  # (n_wedges,) int32 — pair id per wedge
    wedge_e1: np.ndarray    # (n_wedges,) int32 — edge (pair_a, center)
    wedge_e2: np.ndarray    # (n_wedges,) int32 — edge (pair_b, center)
    W0: np.ndarray          # (n_pairs,) int64 — static full-graph wedge count

    @property
    def n_wedges(self) -> int:
        """Number of enumerated wedges (= Σ_v C(d_v, 2))."""
        return int(self.wedge_pair.shape[0])

    def pair_butterflies0(self) -> np.ndarray:
        """Static C(W0, 2) per pair (V side never peeled ⇒ valid for tip)."""
        w = self.W0
        bf = w * (w - 1) // 2
        if bf.size and int(bf.max()) > _INT_LIMIT:
            raise OverflowError("pair butterfly counts exceed int32 range")
        return bf


def build_wedges(g: BipartiteGraph) -> Wedges:
    """Enumerate every wedge (V center, U endpoints) — vectorized numpy.

    Work and memory are O(Σ_v C(d_v, 2)); no n² anywhere.  Neighbor lists
    in ``csr_v`` are u-sorted, so pair endpoints come out ordered.
    """
    off, nbr, eid = g.csr_v()
    deg = np.diff(off)
    pos = np.arange(nbr.size, dtype=np.int64)
    center = np.repeat(np.arange(g.n_v, dtype=np.int64), deg)
    # position p pairs with every later position of the same center
    row_len = off[center + 1] - pos - 1 if nbr.size else np.zeros(0, np.int64)
    total = int(row_len.sum()) if nbr.size else 0
    if total == 0:
        empty32 = np.zeros(0, dtype=np.int32)
        return Wedges(
            n_u=g.n_u, n_v=g.n_v, m=g.m, n_pairs=0,
            pair_a=empty32, pair_b=empty32, wedge_pair=empty32,
            wedge_e1=empty32, wedge_e2=empty32,
            W0=np.zeros(0, dtype=np.int64),
        )
    e1_pos = np.repeat(pos, row_len)
    starts = np.cumsum(row_len) - row_len
    k = np.arange(total, dtype=np.int64) - np.repeat(starts, row_len)
    e2_pos = e1_pos + 1 + k
    a = nbr[e1_pos].astype(np.int64)
    b = nbr[e2_pos].astype(np.int64)
    key = a * g.n_u + b
    pair_key, wedge_pair = np.unique(key, return_inverse=True)
    if pair_key.size > _INT_LIMIT:
        raise OverflowError("pair count exceeds int32 range")
    return Wedges(
        n_u=g.n_u, n_v=g.n_v, m=g.m, n_pairs=int(pair_key.size),
        pair_a=(pair_key // g.n_u).astype(np.int32),
        pair_b=(pair_key % g.n_u).astype(np.int32),
        wedge_pair=wedge_pair.astype(np.int32),
        wedge_e1=eid[e1_pos].astype(np.int32),
        wedge_e2=eid[e2_pos].astype(np.int32),
        W0=np.bincount(wedge_pair, minlength=pair_key.size).astype(np.int64),
    )


# =====================================================================
# Bounded-tile wedge enumeration + ⋈init (the out-of-core counting path)
# =====================================================================
@dataclasses.dataclass
class TileStats:
    """What the tiled ⋈init actually did — feeds the obs counter and the
    peak-memory bench rows (``count.real.*``)."""

    n_tiles: int = 0
    n_wedges: int = 0          # Σ over tiles (== untiled wedge count)
    n_pairs: int = 0           # Σ distinct pairs (tiles don't split pairs)
    peak_tile_wedges: int = 0  # largest single tile
    peak_slot_bytes: int = 0   # largest Pallas slot matrix (0 = host path)


def iter_wedge_tiles(source, tile_wedges: int = 1 << 20):
    """Yield wedge batches ``(a, b, e1, e2)`` of ≈ ``tile_wedges`` each.

    The full wedge list is O(Σ_v C(d_v, 2)) — the memory blocker for
    real graphs.  This generator never materializes it: wedges are
    grouped by their **smaller U endpoint** ``a`` (neighbor lists in
    ``csr_v`` are u-sorted, so position p wedges with every later
    position of its center — all of them have ``a = nbr[p]``), and a
    tile covers a contiguous U range chosen greedily from the exact
    per-vertex wedge counts.  Because every wedge of pair {a, b} shares
    the same minimum endpoint, each pair's wedges land in exactly one
    tile — per-tile pair counts are globally complete, which is what
    makes :func:`tiled_butterfly_init` bit-identical to the untiled
    path.  A hub vertex whose own wedge count exceeds ``tile_wedges``
    becomes a tile by itself (peak = max(tile_wedges, max per-vertex
    count)); vertex-level splitting isn't needed below that.

    ``source`` is anything with ``n_u``/``n_v``/``m`` and ``csr_v()``
    (``BipartiteGraph`` or ``data.ingest.IngestedGraph`` — the latter
    memory-maps its CSR, so the graph itself stays on disk).
    """
    off, nbr, eid = source.csr_v()
    n_u = source.n_u
    if nbr.size == 0:
        return
    deg = np.diff(off)
    pos = np.arange(nbr.size, dtype=np.int64)
    center = np.repeat(np.arange(source.n_v, dtype=np.int64), deg)
    tail = (off[center + 1] - pos - 1).astype(np.int64)
    # exact wedge count per minimum endpoint, and V-CSR positions
    # grouped by that endpoint (stable sort keeps center order)
    w_u = np.bincount(nbr, weights=tail, minlength=n_u).astype(np.int64)
    by_u = np.argsort(nbr, kind="stable")
    eoff = np.zeros(n_u + 1, dtype=np.int64)
    np.cumsum(np.bincount(nbr, minlength=n_u), out=eoff[1:])
    cw = np.cumsum(w_u)
    u0 = 0
    base = 0
    while u0 < n_u:
        u1 = int(np.searchsorted(cw, base + tile_wedges, side="right"))
        u1 = min(max(u1, u0 + 1), n_u)
        base = int(cw[u1 - 1])
        P = by_u[eoff[u0]:eoff[u1]]
        u0 = u1
        t = tail[P]
        total = int(t.sum())
        if total == 0:
            continue
        e1_pos = np.repeat(P, t)
        starts = np.cumsum(t) - t
        k = np.arange(total, dtype=np.int64) - np.repeat(starts, t)
        e2_pos = e1_pos + 1 + k
        yield (
            nbr[e1_pos].astype(np.int64),
            nbr[e2_pos].astype(np.int64),
            eid[e1_pos].astype(np.int64),
            eid[e2_pos].astype(np.int64),
        )


def tiled_butterfly_init(
    source,
    tile_wedges: int = 1 << 20,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
    width: int = 512,
) -> Tuple[np.ndarray, np.ndarray, int, TileStats]:
    """⋈init under bounded memory: (sup_e, sup_u, total, stats).

    Streams :func:`iter_wedge_tiles` and reduces each tile to per-pair
    wedge counts — peak host memory is O(tile), peak device memory one
    Pallas block, never O(Σ deg²).  All accumulation is exact integer
    arithmetic: per-tile counts (int32 Pallas row partials of ≤ ``width``
    flags each, or a host ``diff``), reduced into int64 on the host — so
    there is **no** 2²⁴ ceiling here, and the outputs are bit-identical
    to :func:`edge_butterflies0` / :func:`vertex_butterflies_csr` /
    :func:`total_butterflies_csr` (integer addition commutes).

    With ``use_pallas`` each tile's count runs through the blocked
    tile-accumulate kernel (``kernels.wedge_count
    .wedge_count_tile_pallas``): pairs are laid out as fixed-``width``
    slot rows, hub pairs split across several rows whose int32 partials
    (each ≤ ``width``) are summed per pair in int64.
    """
    from repro import obs  # local import: keep core importable without obs

    n_u, m = source.n_u, source.m
    sup_e = np.zeros(m, dtype=np.int64)
    sup_u = np.zeros(n_u, dtype=np.int64)
    total = 0
    stats = TileStats()
    if use_pallas:
        from repro.kernels import ops as kops
        if interpret is None:
            interpret = kops.default_interpret()
    for a, b, e1, e2 in iter_wedge_tiles(source, tile_wedges):
        nk = a.size
        key = a * n_u + b
        order = np.argsort(key, kind="stable")
        ks = key[order]
        e1s = e1[order]
        e2s = e2[order]
        newp = np.empty(nk, dtype=bool)
        newp[0] = True
        np.not_equal(ks[1:], ks[:-1], out=newp[1:])
        starts_p = np.flatnonzero(newp)
        n_pairs_t = starts_p.size
        pid = np.cumsum(newp) - 1
        cnt = np.diff(np.append(starts_p, nk))
        if use_pallas:
            within = np.arange(nk, dtype=np.int64) - starts_p[pid]
            rows_per_pair = -(-cnt // width)
            row_base = np.cumsum(rows_per_pair) - rows_per_pair
            rowid = row_base[pid] + within // width
            col = within % width
            n_rows = int(rows_per_pair.sum())
            slots = np.zeros((n_rows, width), dtype=np.int32)
            slots[rowid, col] = 1
            stats.peak_slot_bytes = max(stats.peak_slot_bytes, slots.nbytes)
            row_sums = kops.tile_row_counts(slots, interpret=interpret)
            W = np.zeros(n_pairs_t, dtype=np.int64)
            row_to_pair = np.repeat(
                np.arange(n_pairs_t, dtype=np.int64), rows_per_pair
            )
            np.add.at(W, row_to_pair, row_sums.astype(np.int64))
        else:
            W = cnt.astype(np.int64)
        bf = W * (W - 1) // 2
        pa = ks[starts_p] // n_u
        pb = ks[starts_p] % n_u
        np.add.at(sup_u, pa, bf)
        np.add.at(sup_u, pb, bf)
        total += int(bf.sum())
        contrib = W[pid] - 1
        np.add.at(sup_e, e1s, contrib)
        np.add.at(sup_e, e2s, contrib)
        stats.n_tiles += 1
        stats.n_wedges += nk
        stats.n_pairs += n_pairs_t
        stats.peak_tile_wedges = max(stats.peak_tile_wedges, nk)
    obs.counter("counting.tiles", dict(
        tiles=stats.n_tiles, wedges=stats.n_wedges, pairs=stats.n_pairs,
        peak_tile_wedges=stats.peak_tile_wedges,
        peak_slot_bytes=stats.peak_slot_bytes,
    ))
    return sup_e, sup_u, total, stats


def wedge_workload(g: BipartiteGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Paper's range-selection workload proxy Σ_{v∈N_u} d_v, per side.

    Dense engine computes this as A @ d_v; here it is two bincounts."""
    du, dv = g.degrees()
    if g.m == 0:
        return np.zeros(g.n_u, np.int64), np.zeros(g.n_v, np.int64)
    wu = np.bincount(g.edges[:, 0], weights=dv[g.edges[:, 1]], minlength=g.n_u)
    wv = np.bincount(g.edges[:, 1], weights=du[g.edges[:, 0]], minlength=g.n_v)
    return wu.astype(np.int64), wv.astype(np.int64)


# =====================================================================
# Padded-CSR device representation (pairs-major slots for the kernel)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """Row-padded CSR block: row r holds segment r's items, −1 padded.

    The device-friendly face of a ragged grouping — rows padded to a
    sublane multiple, width to a lane multiple, so Pallas kernels can
    tile it straight into VMEM.
    """

    n_rows: int             # real segment count
    n_rows_pad: int         # rows after sublane padding
    width: int              # slots per row (lane multiple)
    idx: np.ndarray         # (n_rows_pad, width) int32, −1 = padding
    valid: np.ndarray       # (n_rows_pad, width) bool


def pad_segments(
    seg_ids: np.ndarray,
    n_rows: int,
    row_mult: int = 8,
    lane_mult: int = 128,
) -> PaddedCSR:
    """Pack item → segment assignments into a :class:`PaddedCSR`.

    ``idx[r, c]`` is the original item index of segment r's c-th member.
    """
    counts = np.bincount(seg_ids, minlength=max(n_rows, 1))
    width = max(int(counts.max()) if counts.size else 1, 1)
    width = -(-width // lane_mult) * lane_mult
    n_rows_pad = -(-max(n_rows, 1) // row_mult) * row_mult
    idx = np.full((n_rows_pad, width), -1, dtype=np.int32)
    valid = np.zeros((n_rows_pad, width), dtype=bool)
    if seg_ids.size:
        order = np.argsort(seg_ids, kind="stable")
        sorted_ids = seg_ids[order]
        off = np.zeros(n_rows + 1, dtype=np.int64)
        np.cumsum(counts[:n_rows], out=off[1:])
        col = np.arange(seg_ids.size, dtype=np.int64) - off[sorted_ids]
        idx[sorted_ids, col] = order.astype(np.int32)
        valid[sorted_ids, col] = True
    return PaddedCSR(
        n_rows=n_rows, n_rows_pad=n_rows_pad, width=width, idx=idx, valid=valid
    )


def pack_wedge_slots(w: Wedges) -> PaddedCSR:
    """Pairs-major wedge slots: row p lists pair p's wedge indices."""
    return pad_segments(w.wedge_pair, w.n_pairs)


def directed_pair_incidence(
    w: Wedges, pair_bf0: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Directed pair-incidence triple ``(dst, src, bf)`` — each pair
    {a, b} as two entries (dst=a, src=b) and (dst=b, src=a) carrying
    the static butterfly count.  THE tip-CD layout convention, shared
    by the vertex-major Pallas slots (:func:`pack_tip_slots`) and the
    distributed CD shards (``distributed.shard_tip_pairs``): vertex
    dst loses bf when src peels."""
    dst = np.concatenate([w.pair_a, w.pair_b]).astype(np.int64)
    src = np.concatenate([w.pair_b, w.pair_a]).astype(np.int64)
    val = np.concatenate([pair_bf0, pair_bf0]).astype(np.int32)
    return dst, src, val


def pack_tip_slots(
    w: Wedges, pair_bf0: np.ndarray, sup: Optional[np.ndarray] = None
) -> dict:
    """Vertex-major pair slots for the tip Pallas CD path.

    Row u lists vertex u's incident pairs as directed entries: each pair
    {a, b} appears twice — once in row a with partner b, once in row b
    with partner a — so a peel round's delta for u is the row sum of
    pair butterflies whose partner was peeled (``kernels.ops
    .tip_slot_loss``; rows ARE vertices, so no scatter back).  ``bf`` is
    0 on padding slots (algebra-neutral), ``partner`` the sentinel n.

    Per-row sums are bounded by the vertex's ⋈ support; past 2²⁴ those
    stop being exact f32 integers, so refuse up front like
    :func:`pack_update_slots` (supports only decrease — checking ⋈init
    once is sufficient).  Pass the caller's precomputed ⋈init as
    ``sup`` to skip recomputing it for the guard."""
    n = w.n_u
    if sup is None:
        sup = vertex_butterflies_csr(w)
    if sup.size and int(sup.max()) >= 2 ** 24:
        raise OverflowError(
            "tip supports exceed f32 integer range (2^24); "
            "use the segment_sum path (use_pallas=False)"
        )
    dst, src, val = directed_pair_incidence(w, pair_bf0)
    packed = pad_segments(dst, n)
    partner = np.full(packed.idx.shape, n, dtype=np.int32)
    bf = np.zeros(packed.idx.shape, dtype=np.int32)
    if dst.size:
        idx = np.maximum(packed.idx, 0)
        partner = np.where(packed.valid, src[idx], n).astype(np.int32)
        bf = np.where(packed.valid, val[idx], 0).astype(np.int32)
    return dict(partner=partner, bf=bf, n=n)


def tip_delta_slots(
    peeled_u: jax.Array,       # (n,) bool — U vertices peeled this round
    slot_partner: jax.Array,   # (n_rows_pad, K) int32, sentinel n
    slot_bf: jax.Array,        # (n_rows_pad, K) int32, 0 on padding
    n: int,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Pallas-kernel variant of :func:`tip_delta_csr` — same static
    pair-butterfly algebra, but the per-vertex reduction runs as blocked
    row sums over the vertex-major slot layout
    (:func:`pack_tip_slots`).  Exact while supports < 2²⁴ (guarded at
    pack time); parity-tested against the segment-sum path."""
    from repro.kernels import ops as kops  # local import: keep core light

    if interpret is None:
        interpret = kops.default_interpret()
    pe = jnp.concatenate([peeled_u, jnp.zeros((1,), bool)])
    vals = jnp.where(pe[slot_partner], slot_bf, 0)
    loss = kops.tip_slot_loss(vals, interpret=interpret)
    return jnp.rint(loss[:n]).astype(jnp.int32)


def pack_update_slots(w: Wedges) -> dict:
    """Slot-layout companion arrays for the Pallas support-update kernel.

    ``e1``/``e2`` map each slot to its wedge's two edge ids (sentinel m
    on padding slots, so peeled-flag gathers and loss scatters are safe
    without masking); ``valid`` marks real slots — the engine's initial
    alive matrix."""
    # the kernel carries W_p, W_p-1 and c_p as f32; past 2^24 those stop
    # being exact integers and rint() re-integerization silently corrupts
    # supports — refuse up front like every other exactness boundary
    # (W only decreases, so checking the static W0 once is sufficient)
    if w.W0.size and int(w.W0.max()) >= 2 ** 24:
        raise OverflowError(
            "pair wedge counts exceed f32 integer range (2^24); "
            "use the segment_sum path (use_pallas=False)"
        )
    packed = pack_wedge_slots(w)
    if w.n_wedges:
        idx = np.maximum(packed.idx, 0)
        e1 = np.where(packed.valid, w.wedge_e1[idx], w.m).astype(np.int32)
        e2 = np.where(packed.valid, w.wedge_e2[idx], w.m).astype(np.int32)
    else:
        e1 = np.full(packed.idx.shape, w.m, np.int32)
        e2 = e1.copy()
    return dict(
        e1=e1, e2=e2, valid=packed.valid,
        n_pairs=w.n_pairs, n_rows_pad=packed.n_rows_pad, m=w.m,
    )


# =====================================================================
# Device-side counting (segment_sum over the flat wedge list)
# =====================================================================
def _seg(x: jax.Array, ids: jax.Array, n: int) -> jax.Array:
    return jax.ops.segment_sum(x, ids, num_segments=max(n, 1))


@partial(jax.jit, static_argnames=("n_pairs",))
def _pair_counts_seg(wp: jax.Array, alive_w: jax.Array, n_pairs: int):
    return _seg(alive_w.astype(jnp.int32), wp, n_pairs)


def pair_wedge_counts(
    w: Wedges,
    alive_e: Optional[jax.Array] = None,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Alive wedge count W_p per pair.

    ``use_pallas`` routes the per-pair reduction through the blocked
    :mod:`repro.kernels.wedge_count` kernel (interpret mode on CPU).
    """
    wp = jnp.asarray(w.wedge_pair)
    if alive_e is None:
        alive_w = jnp.ones((w.n_wedges,), dtype=bool)
    else:
        alive_w = alive_e[jnp.asarray(w.wedge_e1)] & alive_e[jnp.asarray(w.wedge_e2)]
    if not use_pallas:
        return _pair_counts_seg(wp, alive_w, w.n_pairs)
    from repro.kernels import ops as kops  # local import: keep core light

    if interpret is None:
        interpret = kops.default_interpret()
    packed = pack_wedge_slots(w)
    idx = jnp.asarray(np.maximum(packed.idx, 0))
    valid = jnp.asarray(packed.valid)
    slots = jnp.where(valid, alive_w[idx], False)
    W, _ = kops.pair_wedge_counts(slots, interpret=interpret)
    return jnp.rint(W[: max(w.n_pairs, 1)]).astype(jnp.int32)


def vertex_butterflies_csr(w: Wedges, side: str = "u") -> np.ndarray:
    """⋈ per U vertex (tip support init) — exact int64, host output."""
    assert side == "u", "transpose the graph for the V side"
    bf = w.pair_butterflies0()
    out = np.zeros(w.n_u, dtype=np.int64)
    if w.n_pairs:
        np.add.at(out, w.pair_a, bf)
        np.add.at(out, w.pair_b, bf)
    return out


@partial(jax.jit, static_argnames=("n_pairs", "m"))
def _edge_butterflies_from_alive(
    alive_w: jax.Array, wp: jax.Array, we1: jax.Array, we2: jax.Array,
    n_pairs: int, m: int,
) -> jax.Array:
    W = _seg(alive_w.astype(jnp.int32), wp, n_pairs)
    contrib = jnp.where(alive_w, W[wp] - 1, 0)
    return _seg(contrib, we1, m) + _seg(contrib, we2, m)


def edge_butterflies_csr(
    w: Wedges,
    alive_e: Optional[jax.Array] = None,
    use_pallas: bool = False,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """⋈_e per edge over alive edges — the csr batch re-count.

    Each alive wedge w contributes (W_{p(w)} − 1) butterflies to both of
    its edges.  With ``use_pallas`` the W_p reduction runs in the blocked
    kernel; the scatter back to edges stays in ``segment_sum``.
    """
    if w.n_wedges == 0:
        return jnp.zeros((max(w.m, 1),), dtype=jnp.int32)[: w.m]
    we1 = jnp.asarray(w.wedge_e1)
    we2 = jnp.asarray(w.wedge_e2)
    wp = jnp.asarray(w.wedge_pair)
    if alive_e is None:
        alive_w = jnp.ones((w.n_wedges,), dtype=bool)
    else:
        alive_w = alive_e[we1] & alive_e[we2]
    if not use_pallas:
        return _edge_butterflies_from_alive(alive_w, wp, we1, we2, w.n_pairs, w.m)
    W = pair_wedge_counts(w, alive_e, use_pallas=True, interpret=interpret)
    contrib = jnp.where(alive_w, W[wp] - 1, 0)
    return _seg(contrib, we1, w.m) + _seg(contrib, we2, w.m)


def edge_butterflies0(w: Wedges) -> np.ndarray:
    """Full-graph ⋈_e — exact int64, host numpy (wing support init).

    Supports only ever decrease during peeling, so engines that verify
    this fits int32 once at init stay exact all the way down."""
    out = np.zeros(w.m, dtype=np.int64)
    if w.n_wedges:
        contrib = w.W0[w.wedge_pair] - 1
        np.add.at(out, w.wedge_e1, contrib)
        np.add.at(out, w.wedge_e2, contrib)
    return out


def total_butterflies_csr(w: Wedges) -> int:
    """⋈(G) = Σ_p C(W_p, 2) — exact int64 on host."""
    return int(w.pair_butterflies0().sum())


# =====================================================================
# Incremental peeling updates
# =====================================================================
@partial(jax.jit, static_argnames=("n",))
def tip_delta_csr(
    peeled_u: jax.Array,   # (n,) bool — U vertices peeled this round
    pair_a: jax.Array,
    pair_b: jax.Array,
    pair_bf: jax.Array,    # (n_pairs,) int32 — static C(W0, 2)
    n: int,
) -> jax.Array:
    """Δ⋈_u' = Σ_{u peeled} butterflies shared by pair (u', u).

    Pair butterfly counts are static because V is never peeled — the
    sparse analogue of the dense engine's ``pair_bf @ peel`` matvec,
    in O(n_pairs) instead of O(n²).
    """
    loss_a = jnp.where(peeled_u[pair_b], pair_bf, 0)
    loss_b = jnp.where(peeled_u[pair_a], pair_bf, 0)
    return _seg(loss_a, pair_a, n) + _seg(loss_b, pair_b, n)


def wing_loss_csr(
    peeled_e: jax.Array,   # (m,) bool — edges peeled this round
    alive_w: jax.Array,    # (n_wedges,) bool
    W: jax.Array,          # (n_pairs,) int32 — alive wedge count per pair
    we1: jax.Array,
    we2: jax.Array,
    wp: jax.Array,
    n_pairs: int,
    m: int,
):
    """Per-edge butterfly loss of one peel round (BE-Index algebra on
    pairs) — the traceable core shared by :func:`wing_update_csr` and the
    device-resident FD driver (``peel._fd_while_device``).

    A wedge dies when either of its edges is peeled.  For a surviving
    edge e:
      * e in a dying wedge w (its partner edge was peeled): e loses every
        butterfly through w — (W_old[p(w)] − 1) of them ("widow" rule);
      * e in a surviving wedge w: e loses one butterfly per dying wedge
        of the same pair — c[p(w)] of them ("survivor" rule).
    Both scatters are segment_sums; only butterflies incident to peeled
    edges are touched.

    Returns (alive_w', W', loss, n_updates).
    """
    pe1 = peeled_e[we1]
    pe2 = peeled_e[we2]
    w_dies = alive_w & (pe1 | pe2)
    c = _seg(w_dies.astype(jnp.int32), wp, n_pairs)
    surv = alive_w & ~w_dies
    surv_loss = jnp.where(surv, c[wp], 0)
    loss = (
        _seg(jnp.where(w_dies & ~pe1, W[wp] - 1, 0) + surv_loss, we1, m)
        + _seg(jnp.where(w_dies & ~pe2, W[wp] - 1, 0) + surv_loss, we2, m)
    )
    n_updates = jnp.sum((w_dies & (~pe1 | ~pe2)).astype(jnp.int32)) + jnp.sum(
        (surv & (c[wp] > 0)).astype(jnp.int32)
    )
    return alive_w & ~w_dies, W - c, loss, n_updates


def wing_update_slots(
    peeled_e: jax.Array,       # (m,) bool — edges peeled this round
    alive_slots: jax.Array,    # (n_rows_pad, K) bool — slot-layout alive
    W: jax.Array,              # (n_pairs,) int32 — alive wedges per pair
    support: jax.Array,        # (m,) int32
    slot_e1: jax.Array,        # (n_rows_pad, K) int32, sentinel m
    slot_e2: jax.Array,
    n_pairs: int,
    m: int,
    interpret: Optional[bool] = None,
):
    """Pallas-kernel variant of :func:`wing_update_csr` — same widow /
    survivor algebra, but the per-pair reduction and per-slot loss
    computation run in the blocked ``kernels.support_update`` kernel over
    the pairs-major slot layout; only the final scatter onto edges stays
    a ``segment_sum``.  Counts are re-integerized from f32 straight out
    of the kernel, so results are exact while W_p < 2²⁴ (parity-tested
    against the segment-sum path).

    Returns (alive_slots', W', support', n_updates).
    """
    from repro.kernels import ops as kops  # local import: keep core light

    if interpret is None:
        interpret = kops.default_interpret()
    rows = alive_slots.shape[0]
    W_rows = jnp.zeros((rows,), jnp.int32).at[:n_pairs].set(W)
    pe_pad = jnp.concatenate([peeled_e, jnp.zeros((1,), bool)])
    pe1 = pe_pad[slot_e1]
    pe2 = pe_pad[slot_e2]
    c1, c2, c_row = kops.support_update(
        pe1, pe2, alive_slots, W_rows, interpret=interpret
    )
    c1 = jnp.rint(c1).astype(jnp.int32)
    c2 = jnp.rint(c2).astype(jnp.int32)
    c_row = jnp.rint(c_row).astype(jnp.int32)
    loss = (
        _seg(c1.reshape(-1), slot_e1.reshape(-1), m + 1)[:m]
        + _seg(c2.reshape(-1), slot_e2.reshape(-1), m + 1)[:m]
    )
    dies = alive_slots & (pe1 | pe2)
    surv = alive_slots & ~dies
    n_updates = jnp.sum((dies & (~pe1 | ~pe2)).astype(jnp.int32)) + jnp.sum(
        (surv & (c_row[:, None] > 0)).astype(jnp.int32)
    )
    return alive_slots & ~dies, W - c_row[:n_pairs], support - loss, n_updates


@partial(jax.jit, static_argnames=("n_pairs", "m"))
def wing_update_csr(
    peeled_e: jax.Array,   # (m,) bool — edges peeled this round
    alive_w: jax.Array,    # (n_wedges,) bool
    W: jax.Array,          # (n_pairs,) int32 — alive wedge count per pair
    support: jax.Array,    # (m,) int32
    we1: jax.Array,
    we2: jax.Array,
    wp: jax.Array,
    n_pairs: int,
    m: int,
):
    """One batched incremental support update (see :func:`wing_loss_csr`)."""
    alive_w, W, loss, n_updates = wing_loss_csr(
        peeled_e, alive_w, W, we1, we2, wp, n_pairs, m
    )
    return alive_w, W, support - loss, n_updates
