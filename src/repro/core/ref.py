"""Pure-python / numpy oracles for every PBNG quantity.

These are the ground truth the JAX engines (dense + BE-Index) and the
Pallas kernels are validated against.  Written for clarity, not speed —
use on graphs up to a few thousand edges.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .graph import BipartiteGraph

__all__ = [
    "butterfly_count_total",
    "vertex_butterflies_ref",
    "edge_butterflies_ref",
    "bup_tip_ref",
    "bup_wing_ref",
    "wedge_count_ref",
]


def _neighbor_sets(g: BipartiteGraph) -> Tuple[List[set], List[set]]:
    nu: List[set] = [set() for _ in range(g.n_u)]
    nv: List[set] = [set() for _ in range(g.n_v)]
    for u, v in g.edges:
        nu[u].add(int(v))
        nv[v].add(int(u))
    return nu, nv


def _common_matrix(g: BipartiteGraph) -> np.ndarray:
    """W[u, u'] = |N_u ∩ N_u'| (wedge counts between U-pairs)."""
    A = g.adjacency(dtype=np.int64)
    return A @ A.T


def butterfly_count_total(g: BipartiteGraph) -> int:
    W = _common_matrix(g)
    np.fill_diagonal(W, 0)
    return int((W * (W - 1) // 2).sum() // 2)


def vertex_butterflies_ref(g: BipartiteGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Per-vertex butterfly counts (⋈_u for U, ⋈_v for V)."""
    W = _common_matrix(g)
    np.fill_diagonal(W, 0)
    bu = (W * (W - 1) // 2).sum(axis=1)
    Wt = _common_matrix(g.transpose())
    np.fill_diagonal(Wt, 0)
    bv = (Wt * (Wt - 1) // 2).sum(axis=1)
    return bu.astype(np.int64), bv.astype(np.int64)


def edge_butterflies_ref(g: BipartiteGraph) -> np.ndarray:
    """⋈_e for every edge: Σ_{u'∈N_v \\ u} (|N_u ∩ N_u'| − 1)."""
    nu, nv = _neighbor_sets(g)
    out = np.zeros(g.m, dtype=np.int64)
    for i, (u, v) in enumerate(g.edges):
        s = 0
        for up in nv[v]:
            if up == u:
                continue
            s += len(nu[u] & nu[up]) - 1
        out[i] = s
    return out


def wedge_count_ref(g: BipartiteGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Per-vertex wedge endpoints workload: Σ_{v∈N_u} d_v (paper's tip proxy)."""
    du, dv = g.degrees()
    wu = np.zeros(g.n_u, dtype=np.int64)
    wv = np.zeros(g.n_v, dtype=np.int64)
    for u, v in g.edges:
        wu[u] += dv[v]
        wv[v] += du[u]
    return wu, wv


# ------------------------------------------------------------------ peeling
def bup_tip_ref(g: BipartiteGraph, side: str = "u") -> np.ndarray:
    """Sequential bottom-up tip decomposition (alg.2 specialised to vertices).

    Returns tip numbers for the peeled side.  Exploits that V is never
    removed, so pairwise butterfly counts C(W[u,u'], 2) are static.
    """
    gg = g if side == "u" else g.transpose()
    n = gg.n_u
    W = _common_matrix(gg)
    np.fill_diagonal(W, 0)
    pair_bf = W * (W - 1) // 2  # butterflies shared by each U-pair
    support = pair_bf.sum(axis=1)
    alive = np.ones(n, dtype=bool)
    theta = np.zeros(n, dtype=np.int64)
    k = 0
    for _ in range(n):
        idx = np.where(alive)[0]
        if idx.size == 0:
            break
        u = idx[np.argmin(support[idx])]
        k = max(k, int(support[u]))
        theta[u] = k
        alive[u] = False
        support[alive] -= pair_bf[u, alive]
    return theta


def bup_wing_ref(g: BipartiteGraph) -> np.ndarray:
    """Sequential bottom-up wing (bitruss) decomposition — alg.2.

    Recomputes supports incrementally via explicit butterfly enumeration
    per peeled edge.  O(m · ⋈) — oracle-grade only.
    """
    m = g.m
    nu, nv = _neighbor_sets(g)
    eid: Dict[Tuple[int, int], int] = {
        (int(u), int(v)): i for i, (u, v) in enumerate(g.edges)
    }
    support = edge_butterflies_ref(g).copy()
    alive = np.ones(m, dtype=bool)
    theta = np.zeros(m, dtype=np.int64)
    k = 0
    for _ in range(m):
        idx = np.where(alive)[0]
        if idx.size == 0:
            break
        e = idx[np.argmin(support[idx])]
        k = max(k, int(support[e]))
        theta[e] = k
        alive[e] = False
        u, v = (int(x) for x in g.edges[e])
        nu[u].discard(v)
        nv[v].discard(u)
        # Every butterfly through e: pick v' ∈ N_u \ v, u' ∈ N_v ∩ N_v' \ u.
        for vp in list(nu[u]):
            e1 = eid[(u, vp)]
            for up in nv[v]:
                if up == u or vp not in nu[up]:
                    continue
                e2 = eid[(up, v)]
                e3 = eid[(up, vp)]
                for other in (e1, e2, e3):
                    if alive[other]:
                        support[other] = max(k, support[other] - 1)
    return theta
