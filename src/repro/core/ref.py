"""Pure-python / numpy oracles for every PBNG quantity.

These are the ground truth the JAX engines (dense + BE-Index) and the
Pallas kernels are validated against.  Written for clarity, not speed —
use on graphs up to a few thousand edges.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .graph import BipartiteGraph

__all__ = [
    "butterfly_count_total",
    "vertex_butterflies_ref",
    "edge_butterflies_ref",
    "bup_tip_ref",
    "bup_wing_ref",
    "wedge_count_ref",
    "wing_components_ref",
    "tip_components_ref",
    "wing_hierarchy_ref",
    "tip_hierarchy_ref",
]


def _neighbor_sets(g: BipartiteGraph) -> Tuple[List[set], List[set]]:
    nu: List[set] = [set() for _ in range(g.n_u)]
    nv: List[set] = [set() for _ in range(g.n_v)]
    for u, v in g.edges:
        nu[u].add(int(v))
        nv[v].add(int(u))
    return nu, nv


def _common_matrix(g: BipartiteGraph) -> np.ndarray:
    """W[u, u'] = |N_u ∩ N_u'| (wedge counts between U-pairs)."""
    A = g.adjacency(dtype=np.int64)
    return A @ A.T


def butterfly_count_total(g: BipartiteGraph) -> int:
    """⋈(G) ground truth: Σ over U pairs of C(#common neighbours, 2)."""
    W = _common_matrix(g)
    np.fill_diagonal(W, 0)
    return int((W * (W - 1) // 2).sum() // 2)


def vertex_butterflies_ref(g: BipartiteGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Per-vertex butterfly counts (⋈_u for U, ⋈_v for V)."""
    W = _common_matrix(g)
    np.fill_diagonal(W, 0)
    bu = (W * (W - 1) // 2).sum(axis=1)
    Wt = _common_matrix(g.transpose())
    np.fill_diagonal(Wt, 0)
    bv = (Wt * (Wt - 1) // 2).sum(axis=1)
    return bu.astype(np.int64), bv.astype(np.int64)


def edge_butterflies_ref(g: BipartiteGraph) -> np.ndarray:
    """⋈_e for every edge: Σ_{u'∈N_v \\ u} (|N_u ∩ N_u'| − 1)."""
    nu, nv = _neighbor_sets(g)
    out = np.zeros(g.m, dtype=np.int64)
    for i, (u, v) in enumerate(g.edges):
        s = 0
        for up in nv[v]:
            if up == u:
                continue
            s += len(nu[u] & nu[up]) - 1
        out[i] = s
    return out


def wedge_count_ref(g: BipartiteGraph) -> Tuple[np.ndarray, np.ndarray]:
    """Per-vertex wedge endpoints workload: Σ_{v∈N_u} d_v (paper's tip proxy)."""
    du, dv = g.degrees()
    wu = np.zeros(g.n_u, dtype=np.int64)
    wv = np.zeros(g.n_v, dtype=np.int64)
    for u, v in g.edges:
        wu[u] += dv[v]
        wv[v] += du[u]
    return wu, wv


# ------------------------------------------------------------------ peeling
def bup_tip_ref(g: BipartiteGraph, side: str = "u") -> np.ndarray:
    """Sequential bottom-up tip decomposition (alg.2 specialised to vertices).

    Returns tip numbers for the peeled side.  Exploits that V is never
    removed, so pairwise butterfly counts C(W[u,u'], 2) are static.
    """
    gg = g if side == "u" else g.transpose()
    n = gg.n_u
    W = _common_matrix(gg)
    np.fill_diagonal(W, 0)
    pair_bf = W * (W - 1) // 2  # butterflies shared by each U-pair
    support = pair_bf.sum(axis=1)
    alive = np.ones(n, dtype=bool)
    theta = np.zeros(n, dtype=np.int64)
    k = 0
    for _ in range(n):
        idx = np.where(alive)[0]
        if idx.size == 0:
            break
        u = idx[np.argmin(support[idx])]
        k = max(k, int(support[u]))
        theta[u] = k
        alive[u] = False
        support[alive] -= pair_bf[u, alive]
    return theta


def bup_wing_ref(g: BipartiteGraph) -> np.ndarray:
    """Sequential bottom-up wing (bitruss) decomposition — alg.2.

    Recomputes supports incrementally via explicit butterfly enumeration
    per peeled edge.  O(m · ⋈) — oracle-grade only.
    """
    m = g.m
    nu, nv = _neighbor_sets(g)
    eid: Dict[Tuple[int, int], int] = {
        (int(u), int(v)): i for i, (u, v) in enumerate(g.edges)
    }
    support = edge_butterflies_ref(g).copy()
    alive = np.ones(m, dtype=bool)
    theta = np.zeros(m, dtype=np.int64)
    k = 0
    for _ in range(m):
        idx = np.where(alive)[0]
        if idx.size == 0:
            break
        e = idx[np.argmin(support[idx])]
        k = max(k, int(support[e]))
        theta[e] = k
        alive[e] = False
        u, v = (int(x) for x in g.edges[e])
        nu[u].discard(v)
        nv[v].discard(u)
        # Every butterfly through e: pick v' ∈ N_u \ v, u' ∈ N_v ∩ N_v' \ u.
        for vp in list(nu[u]):
            e1 = eid[(u, vp)]
            for up in nv[v]:
                if up == u or vp not in nu[up]:
                    continue
                e2 = eid[(up, v)]
                e3 = eid[(up, vp)]
                for other in (e1, e2, e3):
                    if alive[other]:
                        support[other] = max(k, support[other] - 1)
    return theta


# ------------------------------------------------------ hierarchy oracle
class _UnionFind:
    def __init__(self, n: int):
        self.p = list(range(n))

    def find(self, x: int) -> int:
        """Root of x's set, with path halving."""
        while self.p[x] != x:
            self.p[x] = self.p[self.p[x]]
            x = self.p[x]
        return x

    def union(self, a: int, b: int) -> None:
        """Merge the sets of a and b (min root wins, for determinism)."""
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.p[max(ra, rb)] = min(ra, rb)


def wing_components_ref(g: BipartiteGraph, alive_e: np.ndarray) -> List[frozenset]:
    """Butterfly-connected components of an edge-induced subgraph.

    Brute force from neighbor sets: for every U pair (u1, u2), the
    common V neighbors reached through *alive* edges; any two of them
    form a butterfly on the pair, so all of the pair's alive edges merge
    into one group whenever ≥ 2 common neighbors exist.  Components are
    the transitive closure (union-find); edges in no butterfly stay out.
    """
    eid: Dict[Tuple[int, int], int] = {
        (int(u), int(v)): i for i, (u, v) in enumerate(g.edges)
    }
    adj: List[set] = [set() for _ in range(g.n_u)]
    for i, (u, v) in enumerate(g.edges):
        if alive_e[i]:
            adj[int(u)].add(int(v))
    uf = _UnionFind(g.m)
    in_bf = np.zeros(g.m, dtype=bool)
    for u1 in range(g.n_u):
        for u2 in range(u1 + 1, g.n_u):
            common = adj[u1] & adj[u2]
            if len(common) < 2:
                continue
            es = [eid[(u1, v)] for v in common] + [eid[(u2, v)] for v in common]
            in_bf[es] = True
            for e in es[1:]:
                uf.union(es[0], e)
    comps: Dict[int, set] = {}
    for e in range(g.m):
        if in_bf[e]:
            comps.setdefault(uf.find(e), set()).add(e)
    return [frozenset(c) for c in comps.values()]


def tip_components_ref(g: BipartiteGraph, alive_u: np.ndarray) -> List[frozenset]:
    """Butterfly-connected components of a vertex-induced subgraph
    (peeled side = U; transpose first for the V side).  Two U vertices
    join when they share ≥ 2 common neighbors — i.e. a butterfly."""
    adj: List[set] = [set() for _ in range(g.n_u)]
    for u, v in g.edges:
        if alive_u[int(u)]:
            adj[int(u)].add(int(v))
    uf = _UnionFind(g.n_u)
    in_bf = np.zeros(g.n_u, dtype=bool)
    for u1 in range(g.n_u):
        for u2 in range(u1 + 1, g.n_u):
            if len(adj[u1] & adj[u2]) >= 2:
                in_bf[u1] = in_bf[u2] = True
                uf.union(u1, u2)
    comps: Dict[int, set] = {}
    for u in range(g.n_u):
        if in_bf[u]:
            comps.setdefault(uf.find(u), set()).add(u)
    return [frozenset(c) for c in comps.values()]


def wing_hierarchy_ref(
    g: BipartiteGraph, theta: np.ndarray
) -> Dict[int, set]:
    """Ground-truth k-wing hierarchy: for every distinct level k ≥ 1,
    the butterfly-connected components of the θ ≥ k edge subgraph, as a
    set of frozensets of edge ids."""
    out: Dict[int, set] = {}
    for k in np.unique(theta[theta > 0]):
        out[int(k)] = set(wing_components_ref(g, theta >= k))
    return out


def tip_hierarchy_ref(
    g: BipartiteGraph, theta: np.ndarray, side: str = "u"
) -> Dict[int, set]:
    """Ground-truth k-tip hierarchy of the peeled side (vertex ids)."""
    gg = g if side == "u" else g.transpose()
    out: Dict[int, set] = {}
    for k in np.unique(theta[theta > 0]):
        out[int(k)] = set(tip_components_ref(gg, theta >= k))
    return out
