"""Entity-agnostic PBNG peeling core.

The paper (§4–§6) defines ONE two-phase peeling algorithm and
instantiates it for two entity universes: vertices (tip, §3.2) and
edges (wing, §3.3).  This module is that algorithm stated once:

* :class:`PeelSpec` — everything entity-specific, reduced to data and
  four callables: the entity universe size, the ⋈init supports, the
  range-selection workload proxy, the incremental CD support update,
  and the FD drivers.
* :func:`cd_loop` — the coarse-grained (Phase 1) driver: adaptive (or
  fixed) range selection + fully-parallel masked peel rounds.  Shared
  verbatim by tip/wing × dense/beindex/csr × single-device/mesh.
* :func:`run_fd` — the fine-grained (Phase 2) dispatcher: LPT partition
  order for the per-partition drivers, or the single-dispatch vmapped
  path.
* :func:`_fd_while_device` / :func:`_fd_while_vmapped` /
  :func:`_fd_cascade` — the THREE cascade driver bodies (one
  ``lax.while_loop`` per partition / one batched ``while_loop`` for the
  whole phase / host loop), each existing exactly once; engines supply
  only their ``update(S, aux)`` rule.

``core.peel`` builds the specs (tip and wing are thin wrappers),
``core.distributed`` reuses :func:`cd_loop` with sharded CD steps and
the same FD bodies under ``shard_map`` — so θ, round counts and update
counts are bit-identical across every instantiation (golden-tested
against the pre-refactor engines in ``tests/test_peelspec_goldens.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs

__all__ = [
    "PeelStats",
    "PeelResult",
    "PeelSpec",
    "AdaptiveTarget",
    "FixedTarget",
    "cd_loop",
    "run_fd",
    "decompose",
]


# =====================================================================
# Results / stats
# =====================================================================
@dataclasses.dataclass
class PeelStats:
    """Reproduces the paper's evaluation metrics (tables 3/4)."""

    rho_cd: int = 0          # CD global-sync rounds
    rho_fd_total: int = 0    # Σ sequential FD rounds  (≈ ParButterfly's ρ)
    rho_fd_max: int = 0      # FD critical path (what PBNG actually pays)
    updates: int = 0         # support updates applied (beindex engine)
    recounts: int = 0        # batch re-counts (dense engine)
    p_effective: int = 0     # partitions actually created
    engine: str = ""         # engine that produced THESE round counts
    fd_driver: str = ""      # "device" (one while_loop/partition) | "host"
    side: str = ""           # tip: peeled vertex set "u"|"v"; wing: ""

    @property
    def rho(self) -> int:
        """PBNG synchronization rounds = CD rounds only: FD partitions
        peel with NO global synchronization (the paper's ρ)."""
        return self.rho_cd

    @property
    def sync_reduction(self) -> float:
        """ρ(level-by-level parallel BUP) / ρ(PBNG) — the headline claim.

        ρ(ParB) ≈ total per-level rounds = rho_fd_total (footnote 6).
        Both counts come from *this* run — the ratio is only meaningful
        per engine (an engine's own FD cascade stands in for the
        level-synchronous baseline it would have been).  Benchmarks must
        therefore never mix one engine's rho_cd with another's
        rho_fd_total; :meth:`as_dict` gives them the honest per-engine
        row."""
        return self.rho_fd_total / max(self.rho_cd, 1)

    def as_dict(self) -> dict:
        """Flat JSON-ready view (per-engine rho + derived ratios)."""
        d = dataclasses.asdict(self)
        d["rho"] = self.rho
        d["sync_reduction"] = round(self.sync_reduction, 3)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PeelStats":
        """Inverse of :meth:`as_dict` — tolerates the derived keys
        (``rho``, ``sync_reduction``) that :meth:`as_dict` appends, so a
        stats row can round-trip through JSON / the hierarchy serializer
        without losing the engine / fd_driver / side provenance tags."""
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


@dataclasses.dataclass
class PeelResult:
    """Everything a decomposition produced.

    ``theta`` are the tip/wing numbers (the deliverable); ``part`` /
    ``ranges`` / ``support_init`` are the CD partition assignment, range
    boundaries θ(1..P+1), and the ⋈init support snapshot — together the
    provenance the hierarchy builder/serializer persists; ``stats`` is
    the engine-tagged :class:`PeelStats` row."""

    theta: np.ndarray        # entity numbers
    part: np.ndarray         # CD partition id per entity
    ranges: np.ndarray       # (P+1,) range boundaries θ(1..P+1)
    support_init: np.ndarray  # ⋈init vector
    stats: PeelStats
    # per-round work curves, present only when the obs layer was
    # enabled during the run (obs.enable(); see docs/OBSERVABILITY.md)
    timeline: Optional["obs.PeelTimeline"] = None

    def provenance(self) -> dict:
        """Everything besides θ a downstream consumer (the hierarchy
        builder/serializer) needs to reconstruct how this decomposition
        was produced: engine-tagged stats plus the CD partition
        assignment, range boundaries, and ⋈init — together they rebuild
        the peeling order (entities peel by partition, then by θ within
        the partition from the recorded support snapshot).  When a
        timeline was collected its compact digest rides along."""
        prov = dict(
            stats=self.stats.as_dict(),
            part=np.asarray(self.part),
            ranges=np.asarray(self.ranges),
            support_init=np.asarray(self.support_init),
        )
        if self.timeline is not None:
            prov["timeline"] = self.timeline.summary()
        return prov


# =====================================================================
# The spec — one entity universe + its peeling rules
# =====================================================================
@dataclasses.dataclass
class PeelSpec:
    """One PBNG peeling instance, entity-agnostically.

    The two-phase drivers below consume ONLY this interface; tip and
    wing (and every engine of each) differ solely in how they fill it:

    ========== ========================== ===========================
    field      tip instantiation          wing instantiation
    ========== ========================== ===========================
    n          \\|U\\| (or \\|V\\|)       \\|E\\|
    sup0       ⋈ per vertex               ⋈ per edge
    workload   Σ_{v∈N_u} d_v (static)     current support (dynamic)
    est        same wedge workload        ⋈init snapshot
    cd_step    pair-incidence deltas      widow/survivor wedge algebra
    ========== ========================== ===========================

    ``cd_step(active) -> sup_np`` applies one masked peel round to the
    engine's device state and returns the refreshed int64 support
    vector (charging ``stats.updates``/``stats.recounts`` itself).

    ``fd_partition(i, part, sup_init, theta, fd_driver) -> (rounds,
    n_updates, n_recounts)`` peels partition i bottom-up, writing θ in
    place.  ``fd_vmapped(part, sup_init, theta, n_parts) -> (rounds[B],
    n_updates)`` peels ALL partitions in one dispatch (csr engines).

    This is the extension point: a new entity universe (e.g. the
    (r,s)-nucleus generalization) plugs in by building a spec — the CD
    round loop, range selection, LPT scheduling, shape-bucketed packing
    and all three FD cascade drivers are inherited, not re-written.
    """

    kind: str                 # "tip" | "wing" — provenance tag
    n: int                    # entity universe size
    sup0: np.ndarray          # (n,) int64 — ⋈init supports
    workload: Callable        # sup_np -> (n,) range-selection weights
    est: Callable             # sup_np -> (n,) partition workload weights
    cd_step: Callable         # active mask -> refreshed int64 supports
    fd_partition: Optional[Callable] = None
    fd_vmapped: Optional[Callable] = None


# =====================================================================
# Range selection (§3.1.3) — host-side histogram + prefix scan
# =====================================================================
def _find_range(
    support: np.ndarray,
    workload: np.ndarray,
    alive: np.ndarray,
    tgt: float,
) -> int:
    """Smallest hi such that Σ workload[alive & support < hi] ≥ tgt."""
    s = support[alive]
    w = workload[alive]
    if s.size == 0:
        return 0
    order = np.argsort(s, kind="stable")
    s, w = s[order], w[order]
    cum = np.cumsum(w)
    pos = int(np.searchsorted(cum, max(tgt, 1e-9)))
    pos = min(pos, s.size - 1)
    return int(s[pos]) + 1


class AdaptiveTarget:
    """Two-way adaptive range targets (§3.1.3)."""

    def __init__(self, total_workload: float, P: int):
        self.P = P
        self.remaining = float(total_workload)
        self.scale = 1.0

    def target(self, i: int) -> float:
        """Workload target for partition i: remaining / remaining parts,
        damped by the last overshoot ratio."""
        rem_parts = max(self.P - i, 1)
        return self.scale * self.remaining / rem_parts

    def consumed(self, initial_estimate: float, final_estimate: float) -> None:
        """Record partition i's actual workload and update the damping."""
        self.remaining = max(self.remaining - final_estimate, 0.0)
        if final_estimate > 0 and initial_estimate > 0:
            # predictive local behaviour: next partition will overshoot
            # roughly like this one did
            self.scale = min(1.0, initial_estimate / final_estimate)


class FixedTarget:
    """Constant total/P range targets — the distributed CD policy
    (supports are already on device; re-estimating per partition buys
    nothing at mesh scale, and θ is partition-invariant anyway)."""

    def __init__(self, total_workload: float, P: int):
        self.tgt = float(total_workload) / max(P, 1)

    def target(self, i: int) -> float:
        """Constant workload target: total / P for every partition."""
        return self.tgt

    def consumed(self, initial_estimate: float, final_estimate: float) -> None:
        """No adaptation — the fixed policy ignores overshoot."""


def _lpt_order(work: np.ndarray) -> np.ndarray:
    """Longest-processing-time order of partitions (fig.4)."""
    return np.argsort(-work, kind="stable")


# =====================================================================
# Phase 1 — the CD round loop (exists once; every engine drives it)
# =====================================================================
def cd_loop(spec: PeelSpec, P: int, stats: PeelStats, target=None):
    """Coarse-grained decomposition: adaptive range selection + masked
    peel rounds until every entity is assigned a partition.

    Returns ``(part, sup_init, ranges, p_effective)``; each inner peel
    round charges ``stats.rho_cd`` (the paper's ρ — the only global
    synchronization points), and the engine's ``cd_step`` charges its
    own update/recount counters.

    When the obs layer is collecting (``obs.maybe_collect`` installed a
    collector), every inner round is additionally wrapped in a
    ``cd.round`` span and recorded into the run's timeline — span count
    == ``stats.rho_cd`` by construction.  CD is host-driven, so this is
    pure host bookkeeping: device programs are untouched either way."""
    col = obs.active_collector()
    sup_np = np.asarray(spec.sup0, dtype=np.int64).copy()
    n = sup_np.size
    if target is None:
        target = AdaptiveTarget(float(spec.est(sup_np).sum()), P)
    alive = np.ones(n, dtype=bool)
    part = np.full(n, -1, dtype=np.int32)
    sup_init = np.zeros(n, dtype=np.int64)
    ranges = [0]
    p_eff = 0
    for i in range(P):
        if not alive.any():
            break
        sup_init[alive] = sup_np[alive]
        if i == P - 1:
            hi = int(sup_np[alive].max()) + 1
        else:
            tgt = target.target(i)
            hi = _find_range(sup_np, spec.workload(sup_np), alive, tgt)
            hi = max(hi, int(sup_np[alive].min()) + 1)  # guarantee progress
        initial_est = float(spec.est(sup_np)[alive & (sup_np < hi)].sum())
        ranges.append(hi)

        # ---- inner peeling rounds for range [θ(i), hi)
        while True:
            active = alive & (sup_np < hi)
            if not active.any():
                break
            part[active] = i
            alive &= ~active
            if col is None:
                sup_np = spec.cd_step(active)
            else:
                died = int(active.sum())
                u0, r0 = stats.updates, stats.recounts
                with obs.span("cd.round", cat="cd.round",
                              part=int(i)) as sp:
                    sup_np = spec.cd_step(active)
                    frontier = int(alive.sum())
                    du = stats.updates - u0
                    dr = stats.recounts - r0
                    sp.update(died=died, frontier=frontier, hi=int(hi),
                              updates=du, recounts=dr)
                col.record_cd_round(i, died, frontier, int(hi), du, dr)
            stats.rho_cd += 1

        final_est = float(spec.est(sup_init)[part == i].sum())
        target.consumed(initial_est, final_est)
        p_eff = i + 1
    stats.p_effective = p_eff
    return part, sup_init, np.asarray(ranges, dtype=np.int64), p_eff


# =====================================================================
# Phase 2 — the FD dispatcher (LPT per-partition / single-dispatch)
# =====================================================================
def run_fd(
    spec: PeelSpec,
    part: np.ndarray,
    sup_init: np.ndarray,
    theta: np.ndarray,
    n_parts: int,
    stats: PeelStats,
    fd_driver: str = "device",
    only: Optional[np.ndarray] = None,
    per_partition: Optional[dict] = None,
) -> None:
    """Fine-grained decomposition over the CD partitions.

    ``fd_driver="vmapped"`` routes through ``spec.fd_vmapped`` (the
    whole phase in one batched while_loop); otherwise partitions run in
    LPT order through ``spec.fd_partition`` (which honours
    ``fd_driver`` = "device" | "host").  Writes θ in place and charges
    the FD round/update/recount counters.

    ``only`` restricts the per-partition path to a subset of partition
    ids (LPT-ordered among themselves) — the streaming repair driver
    (``repro.streaming``) uses it to re-peel just the dirty partitions;
    θ entries of skipped partitions are left untouched so carried-over
    values survive.  ``per_partition``, when given a dict, is filled
    with ``{i: (rounds, updates, recounts)}`` for every partition that
    ran — the cache that lets an incremental run reassemble PeelStats
    bit-identical to a from-scratch re-peel.  Neither knob changes any
    dispatched program: the jitted FD entries are shared verbatim."""
    if n_parts <= 0:
        return
    if fd_driver == "vmapped":
        if only is not None:
            raise ValueError(
                "only= requires a per-partition fd_driver "
                "('device' | 'host'); the vmapped driver dispatches "
                "every partition in one launch")
        with obs.span("fd.vmapped", cat="fd.launch",
                      n_parts=int(n_parts)) as sp:
            rounds_v, nupd = spec.fd_vmapped(part, sup_init, theta, n_parts)
            rounds_v = np.asarray(rounds_v)
            if sp is not None:
                sp.update(rounds=int(rounds_v.sum()), updates=int(nupd))
        stats.rho_fd_total = int(rounds_v.sum())
        stats.rho_fd_max = int(rounds_v.max()) if rounds_v.size else 0
        stats.updates += int(nupd)
        return
    if only is None:
        ids = np.arange(n_parts)
    else:
        ids = np.unique(np.asarray(only, dtype=np.int64))
        if ids.size and (ids[0] < 0 or ids[-1] >= n_parts):
            raise ValueError(
                f"only= ids outside [0, {n_parts}): {ids.tolist()}")
    est_w = spec.est(sup_init)
    part_work = np.array(
        [est_w[part == i].sum() for i in ids], dtype=np.float64
    )
    for j in _lpt_order(part_work):
        i = int(ids[j])
        with obs.span(f"fd.partition[{i}]", cat="fd.launch",
                      part=i) as sp:
            rounds, nupd, nrec = spec.fd_partition(
                i, part, sup_init, theta, fd_driver)
            if sp is not None:
                sp.update(rounds=int(rounds), updates=int(nupd),
                          recounts=int(nrec))
        if per_partition is not None:
            per_partition[i] = (int(rounds), int(nupd), int(nrec))
        stats.rho_fd_total += rounds
        stats.rho_fd_max = max(stats.rho_fd_max, rounds)
        stats.updates += nupd
        stats.recounts += nrec


def decompose(
    spec: PeelSpec,
    P: int,
    stats: PeelStats,
    fd_driver: str = "device",
    target=None,
) -> PeelResult:
    """Run both phases of one :class:`PeelSpec` and assemble the
    :class:`PeelResult` — THE driver behind ``tip_decomposition`` and
    ``wing_decomposition`` (every engine).

    When the obs layer is enabled this is also the telemetry root: it
    installs the timeline collector, wraps the run in a ``peel`` span
    with ``cd``/``fd`` phase spans, and attaches the built
    :class:`~repro.obs.PeelTimeline` to the result (synthesizing the
    per-round ``fd.round`` trace events from the drained rings)."""
    with obs.maybe_collect() as col:
        with obs.span("peel.decompose", cat="peel", kind=spec.kind,
                      engine=stats.engine, fd_driver=fd_driver, P=int(P)):
            with obs.span("cd", cat="cd"):
                part, sup_init, ranges, p_eff = cd_loop(
                    spec, P, stats, target=target)
            theta = np.zeros(spec.n, dtype=np.int64)
            with obs.span("fd", cat="fd", driver=fd_driver):
                run_fd(spec, part, sup_init, theta, p_eff, stats,
                       fd_driver=fd_driver)
    timeline = None
    if col is not None:
        timeline = col.build()
        tracer = obs.get_tracer()
        if tracer is not None:
            timeline.emit_trace_events(tracer)
    return PeelResult(
        theta=theta,
        part=part,
        ranges=ranges,
        support_init=sup_init,
        stats=stats,
        timeline=timeline,
    )


# =====================================================================
# FD cascade drivers — each body exists exactly once
# =====================================================================
def _fd_cascade(mine: np.ndarray, support0: np.ndarray, theta: np.ndarray,
                apply_peel, on_round=None) -> int:
    """Level-synchronous bottom-up cascade shared by the incremental FD
    engines: advance k to the minimum alive support, peel the ≤k set,
    apply the engine's update, repeat until the partition is empty.

    ``apply_peel(S, sup)`` consumes the peel mask and the current int64
    support vector and returns the refreshed one (updating any engine
    state it closes over).  Returns the number of peel rounds.
    ``on_round(k, died, frontier)``, when given, is called after every
    round — the obs layer's host-side stand-in for the device counter
    rings (None, the default, changes nothing).

    This is the *host-loop* driver (one device dispatch per peel round).
    The csr engine defaults to :func:`_fd_while_device`, which runs the
    identical cascade inside a single ``lax.while_loop``.
    """
    alive = mine.copy()
    sup = support0
    k = 0
    rounds = 0
    while alive.any():
        k = max(k, int(sup[alive].min()))
        while True:
            S = alive & (sup <= k)
            if not S.any():
                break
            theta[S] = k
            alive &= ~S
            sup = apply_peel(S, sup)
            rounds += 1
            if on_round is not None:
                on_round(k=k, died=int(S.sum()), frontier=int(alive.sum()))
    return rounds


# sentinel for masked-out supports in the k-advance; must be >= any real
# support (engines guard supports <= int32 max), else the while_loop can
# never peel the last entities and spins forever
_FD_BIG = jnp.iinfo(jnp.int32).max


def _bucket_pad(n: int, floor: int = 128) -> int:
    """Round n up to a quarter-power-of-two bucket (≥ floor) — pads
    per-partition pair / wedge arrays so the jitted FD drivers recompile
    per size *bucket* instead of per partition, with ≤25% padding waste
    (zero padding is algebra-neutral: a pair with 0 butterflies / a dead
    wedge contributes no loss)."""
    if n <= floor:
        return floor
    step = 1 << max(int(n - 1).bit_length() - 2, 0)
    return -(-n // step) * step


def _pad_zeros(x: np.ndarray, size: int) -> np.ndarray:
    if x.size >= size:
        return x
    return np.concatenate([x, np.zeros(size - x.size, dtype=x.dtype)])


def _fd_while_device(mine: jax.Array, sup0: jax.Array, update, aux):
    """The batched FD cascade as one ``lax.while_loop`` — shared by the
    csr tip and wing engines (and the sharded FD bodies in
    ``core.distributed``).

    Semantics are identical to :func:`_fd_cascade` — every iteration
    advances k to the minimum alive support and peels the ≤k set, so the
    round count matches the host driver exactly — but the whole cascade
    stays device-resident: zero host↔device transfers per partition,
    which is the paper's Phase-2 "no global synchronization" property
    stated structurally (one jit'd while_loop, no dispatch per round).

    ``update(S, aux) -> (loss, aux', n_upd)`` is the engine's incremental
    support update; ``aux`` is its loop-carried state (wedge/pair alive
    masks and counts).  Returns (theta, rounds, updates), all on device.
    """

    def cond(state):
        alive, *_ = state
        return jnp.any(alive)

    def body(state):
        alive, sup, aux, theta, k, rounds, nupd = state
        cur = jnp.where(alive, sup, _FD_BIG)
        k = jnp.maximum(k, jnp.min(cur))
        S = alive & (sup <= k)
        # S is non-empty whenever alive is (k ≥ min alive support), so
        # every iteration is one real peel round — same count as the
        # host cascade.
        theta = jnp.where(S, k, theta)
        alive = alive & ~S
        loss, aux, nu = update(S, aux)
        return (alive, sup - loss, aux, theta, k, rounds + 1, nupd + nu)

    # derive loop-constant inits from varying inputs so the carry's
    # manual-axes annotation is stable under shard_map (same trick as
    # distributed._fd_body_one_partition)
    zero_e = sup0 * 0
    zero_s = jnp.min(zero_e)
    init = (mine, sup0, aux, zero_e, zero_s, zero_s, zero_s)
    _, _, _, theta, _, rounds, nupd = jax.lax.while_loop(cond, body, init)
    return theta, rounds, nupd


def _fd_while_vmapped(mine: jax.Array, sup0: jax.Array, update, aux):
    """The FULL Phase 2 — every partition's cascade — as ONE batched
    ``lax.while_loop``: the single-dispatch companion of
    :func:`_fd_while_device`.

    ``mine``/``sup0`` carry a leading partition axis [B, E]; each
    iteration advances every still-alive partition by exactly one peel
    round (its own k-advance + ≤k peel), so per-partition round counts
    are bit-identical to the per-partition drivers and the loop's trip
    count is the FD *critical path* rho_fd_max.  Finished partitions
    idle (empty peel sets are algebra-neutral) until the last one
    drains — the whole Phase 2 is one dispatch, zero host round-trips,
    zero collectives: PBNG's "no global synchronization" claim stated
    structurally for the entire fine-grained phase, not per partition.

    ``update(S, aux) -> (loss, aux', n_upd)`` consumes the batched peel
    mask S [B, E] and returns batched losses plus the scalar update
    count of the round.  Returns (theta [B, E], rounds [B], updates).
    """

    def cond(state):
        alive, *_ = state
        return jnp.any(alive)

    def body(state):
        alive, sup, aux, theta, k, rounds, nupd = state
        live = jnp.any(alive, axis=1)
        cur = jnp.where(alive, sup, _FD_BIG)
        k = jnp.maximum(k, jnp.min(cur, axis=1))
        S = alive & (sup <= k[:, None])
        # per live partition S is non-empty (k ≥ its min alive support):
        # every iteration is one real peel round of every live partition
        theta = jnp.where(S, k[:, None], theta)
        alive = alive & ~S
        loss, aux, nu = update(S, aux)
        return (alive, sup - loss, aux, theta, k,
                rounds + live.astype(jnp.int32), nupd + nu)

    # derive loop-constant inits from varying inputs (cf. _fd_while_device)
    zero_e = sup0 * 0
    zero_p = jnp.min(zero_e, axis=1)
    init = (mine, sup0, aux, zero_e, zero_p, zero_p, jnp.int32(0))
    _, _, _, theta, _, rounds, nupd = jax.lax.while_loop(cond, body, init)
    return theta, rounds, nupd


def _fd_while_fused(state0, round_fn):
    """The zero-per-round-dispatch FD driver: the whole cascade is one
    ``lax.while_loop`` whose body is ONE fused Pallas round
    (``kernels.fd_round`` — k-advance, frontier compaction and support
    update all in-kernel), so a round's jaxpr is a single ``pallas_call``
    with no segment-sum / argmin / compaction tail.

    ``state0`` is the loop-carried tuple with the alive mask (any dtype,
    nonzero = alive) at index 1; ``round_fn(*state) -> state`` must be
    the fused round.  Loop-invariant operands (slot layouts, pair lists)
    stay closed over inside ``round_fn`` — they never enter the carry.
    Semantics (k-advance, per-partition round counts, θ) are
    bit-identical to :func:`_fd_while_vmapped` / :func:`_fd_while_device`
    (golden- and property-locked in ``tests/test_fused_fd.py``)."""

    def cond(state):
        return jnp.any(state[1] != 0)

    def body(state):
        return round_fn(*state)

    return jax.lax.while_loop(cond, body, state0)


# =====================================================================
# Telemetry-ON twins of the FD cascade drivers (obs counter rings)
# =====================================================================
# Each ``*_rings`` function repeats its twin's loop algebra VERBATIM and
# additionally threads preallocated per-round int32 counter rings
# through the carry — dying count, frontier size, k-advance, update
# count — written at slot ``min(round, cap-1)`` (first cap-1 rounds
# plus the final round survive an overflow; the drain flags it
# ``truncated``).  They are separate functions, not a branch inside the
# twins, so the telemetry-OFF path traces the byte-identical jaxpr — a
# guarantee locked by ``tests/goldens/obs_jaxprs.json``.  Entity
# wrappers in ``core.peel`` expose them behind a static ``ring_cap``
# argument and drain the rings into the run's timeline collector.

def _fd_while_device_rings(mine: jax.Array, sup0: jax.Array, update, aux,
                           ring_cap: int):
    """:func:`_fd_while_device` + counter rings; returns
    ``(theta, rounds, nupd, (died, frontier, k, upd))`` with each ring
    shaped ``(ring_cap,)``."""
    cap = int(ring_cap)

    def cond(state):
        alive, *_ = state
        return jnp.any(alive)

    def body(state):
        alive, sup, aux, theta, k, rounds, nupd, rings = state
        died_r, fr_r, k_r, nu_r = rings
        cur = jnp.where(alive, sup, _FD_BIG)
        k = jnp.maximum(k, jnp.min(cur))
        S = alive & (sup <= k)
        theta = jnp.where(S, k, theta)
        alive = alive & ~S
        loss, aux, nu = update(S, aux)
        slot = jnp.minimum(rounds, cap - 1)
        rings = (
            died_r.at[slot].set(jnp.sum(S.astype(jnp.int32))),
            fr_r.at[slot].set(jnp.sum(alive.astype(jnp.int32))),
            k_r.at[slot].set(k.astype(jnp.int32)),
            nu_r.at[slot].set(jnp.asarray(nu).astype(jnp.int32)),
        )
        return (alive, sup - loss, aux, theta, k, rounds + 1, nupd + nu,
                rings)

    zero_e = sup0 * 0
    zero_s = jnp.min(zero_e)
    zring = jnp.zeros((cap,), jnp.int32)
    init = (mine, sup0, aux, zero_e, zero_s, zero_s, zero_s,
            (zring, zring, zring, zring))
    out = jax.lax.while_loop(cond, body, init)
    return out[3], out[5], out[6], out[7]


def _fd_while_vmapped_rings(mine: jax.Array, sup0: jax.Array, update, aux,
                            ring_cap: int):
    """:func:`_fd_while_vmapped` + counter rings; returns
    ``(theta, rounds, nupd, (died, frontier, k, upd))`` where the first
    three rings are ``(ring_cap, B)`` and the update ring ``(ring_cap,)``
    (the engine's per-round update count is a phase-global scalar)."""
    cap = int(ring_cap)

    def cond(state):
        alive, *_ = state
        return jnp.any(alive)

    def body(state):
        alive, sup, aux, theta, k, rounds, nupd, it, rings = state
        died_r, fr_r, k_r, nu_r = rings
        live = jnp.any(alive, axis=1)
        cur = jnp.where(alive, sup, _FD_BIG)
        k = jnp.maximum(k, jnp.min(cur, axis=1))
        S = alive & (sup <= k[:, None])
        theta = jnp.where(S, k[:, None], theta)
        alive = alive & ~S
        loss, aux, nu = update(S, aux)
        slot = jnp.minimum(it, cap - 1)
        rings = (
            died_r.at[slot].set(jnp.sum(S.astype(jnp.int32), axis=1)),
            fr_r.at[slot].set(jnp.sum(alive.astype(jnp.int32), axis=1)),
            k_r.at[slot].set(k.astype(jnp.int32)),
            nu_r.at[slot].set(jnp.asarray(nu).astype(jnp.int32)),
        )
        return (alive, sup - loss, aux, theta, k,
                rounds + live.astype(jnp.int32), nupd + nu, it + 1, rings)

    zero_e = sup0 * 0
    zero_p = jnp.min(zero_e, axis=1)
    B = sup0.shape[0]
    zrow = jnp.zeros((cap, B), jnp.int32)
    init = (mine, sup0, aux, zero_e, zero_p, zero_p, jnp.int32(0),
            jnp.int32(0), (zrow, zrow, zrow, jnp.zeros((cap,), jnp.int32)))
    out = jax.lax.while_loop(cond, body, init)
    return out[3], out[5], out[6], out[8]


def _fd_while_fused_rings(state0, round_fn, ring_cap: int):
    """:func:`_fd_while_fused` + counter rings derived OUTSIDE the
    fused round (the Pallas kernel itself is untouched): died/frontier
    from the alive mask (state index 1, nonzero = alive) before/after
    the round, k from state index 3, and — when the state carries a
    per-partition update count at index 5 (the wing 8-tuple) — the ring
    stores its *cumulative* value per round (the drain converts to
    deltas via ``cumulative_updates=True``).  Returns
    ``(state, (died, frontier, k, upd_cum))``, rings ``(ring_cap, B)``.
    """
    cap = int(ring_cap)
    B = state0[1].shape[0]

    def cond(carry):
        state, _, _ = carry
        return jnp.any(state[1] != 0)

    def body(carry):
        state, it, rings = carry
        died_r, fr_r, k_r, nu_r = rings
        alive_before = jnp.sum((state[1] != 0).astype(jnp.int32), axis=1)
        new = round_fn(*state)
        alive_after = jnp.sum((new[1] != 0).astype(jnp.int32), axis=1)
        k_now = new[3][:, 0].astype(jnp.int32)
        nu_cum = (jnp.sum(new[5], axis=1).astype(jnp.int32)
                  if len(new) > 5 else jnp.zeros((B,), jnp.int32))
        slot = jnp.minimum(it, cap - 1)
        rings = (
            died_r.at[slot].set(alive_before - alive_after),
            fr_r.at[slot].set(alive_after),
            k_r.at[slot].set(k_now),
            nu_r.at[slot].set(nu_cum),
        )
        return (new, it + 1, rings)

    zrow = jnp.zeros((cap, B), jnp.int32)
    state, _, rings = jax.lax.while_loop(
        cond, body, (state0, jnp.int32(0), (zrow, zrow, zrow, zrow)))
    return state, rings
