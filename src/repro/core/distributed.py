"""Distributed PBNG — shard_map peeling for multi-device meshes.

Maps the paper's two phases onto an SPMD mesh:

* **CD** (coarse): the BE-Index *links* are sharded across devices; each
  round every device computes its partial bloom-death counts and per-edge
  losses with ``segment_sum`` and a single ``psum`` combines them.  One
  collective per peeling round — the JAX statement of "little
  synchronization".  Supports / frontier masks are replicated (O(m), tiny
  next to the index).

* **FD** (fine): partitions are padded to a common size, stacked on a
  leading axis and `shard_map`-ped over the ``peel`` mesh axis.  The
  per-partition while_loop contains **no collectives at all** — the HLO
  proves the paper's "no global synchronization" claim structurally.

Used by ``launch/peel.py`` for the production-mesh dry-run and by the
multi-device tests (spawned with forced host device counts).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding.compat import shard_map
from . import csr
from .beindex import BEIndex, build_beindex
from .graph import BipartiteGraph

__all__ = [
    "ShardedWingState",
    "ShardedCSRState",
    "shard_links",
    "shard_wedges",
    "shard_wedges_pair_aligned",
    "cd_round_sharded",
    "cd_round_sharded_csr",
    "make_cd_round_csr",
    "make_cd_round_csr_pair_aligned",
    "pack_fd_partitions",
    "pack_fd_partitions_csr",
    "pack_fd_partitions_tip_csr",
    "fd_peel_sharded",
    "fd_peel_sharded_csr",
    "distributed_wing_decomposition",
    "distributed_tip_decomposition",
]


# =====================================================================
# CD — link-sharded rounds, one psum per round
# =====================================================================
@dataclasses.dataclass
class ShardedWingState:
    """Link-sharded CD state: index arrays split over the mesh axis,
    supports / bloom numbers replicated (O(m) + O(nb), tiny next to the
    links)."""

    le: jax.Array          # (L_pad,) link -> edge, sharded
    lt: jax.Array          # (L_pad,) link -> twin
    lb: jax.Array          # (L_pad,) link -> bloom
    alive_link: jax.Array  # (L_pad,) sharded
    k_alive: jax.Array     # (nb,) replicated
    support: jax.Array     # (m,) replicated
    nb: int
    m: int


def shard_links(be: BEIndex, m: int, n_dev: int) -> ShardedWingState:
    """Pad link arrays to a multiple of n_dev.  Pad links point at a
    sentinel dead bloom/edge and start dead."""
    L = be.n_links
    pad = (-L) % max(n_dev, 1)
    def padded(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])
    le = padded(be.link_edge, m)        # sentinel edge m
    lt = padded(be.link_twin, m)
    lb = padded(be.link_bloom, be.nb)   # sentinel bloom nb
    alive = np.concatenate([np.ones(L, bool), np.zeros(pad, bool)])
    return ShardedWingState(
        le=jnp.asarray(le), lt=jnp.asarray(lt), lb=jnp.asarray(lb),
        alive_link=jnp.asarray(alive),
        k_alive=jnp.asarray(be.bloom_k.astype(np.int32)),
        support=jnp.asarray(be.edge_support(m).astype(np.int32)),
        nb=be.nb, m=m,
    )


def _cd_round_body(peeled_pad, alive_link, k_alive, support_pad,
                   le, lt, lb, *, nb: int, m: int, axis: str):
    """Runs per-shard under shard_map; one psum for c, one for loss."""
    pe = peeled_pad[le]
    pt = peeled_pad[lt]
    pair_dies = alive_link & (pe | pt)
    canon = le < lt
    c_local = jax.ops.segment_sum(
        (pair_dies & canon).astype(jnp.int32), lb, num_segments=nb + 1
    )
    c = jax.lax.psum(c_local, axis)
    widow = alive_link & ~pe & pt
    surv = alive_link & ~pair_dies
    contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(surv, c[lb], 0)
    loss_local = jax.ops.segment_sum(contrib, le, num_segments=m + 1)
    loss = jax.lax.psum(loss_local, axis)
    support_pad = support_pad - loss
    k_alive = k_alive - c[:nb]
    alive_link = alive_link & ~pair_dies
    return alive_link, k_alive, support_pad


def make_cd_round(mesh: Mesh, axis: str, nb: int, m: int):
    """Build the jitted, shard_map-ped CD round for a given mesh."""
    body = partial(_cd_round_body, nb=nb, m=m, axis=axis)
    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_r, spec_r),
    )
    return jax.jit(fn)


def cd_round_sharded(round_fn, st: ShardedWingState, peeled: jax.Array
                     ) -> ShardedWingState:
    """One CD peeling round. ``peeled`` is the (m,) frontier mask."""
    peeled_pad = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
    support_pad = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    alive_link, k_alive, support_pad = round_fn(
        peeled_pad, st.alive_link, st.k_alive, support_pad,
        st.le, st.lt, st.lb,
    )
    return dataclasses.replace(
        st, alive_link=alive_link, k_alive=k_alive, support=support_pad[:-1]
    )


# =====================================================================
# CD variant — bloom-aligned link sharding (§Perf optimization)
# =====================================================================
# Baseline CD needs TWO psums per round: dying-pair counts c_B (blooms
# straddle shards) then per-edge losses.  If every bloom's links live on
# ONE shard, c_B and k_alive become shard-local state and a round costs
# a single psum (the loss) — half the collectives, and bloom bookkeeping
# never crosses the interconnect.
def _greedy_balance(counts: np.ndarray, n_dev: int):
    """LPT-greedy segment→shard placement shared by the bloom- and
    pair-aligned one-psum CD layouts.

    Segments (blooms / U-pairs) are placed largest-first onto the
    least-loaded shard (heap, O(S log n_dev) — ties break to the lowest
    shard id like the original argmin).  Everything else is vectorized
    numpy: per shard, segments keep ascending-id order.  Returns
    ``(shard_of, local_id, seg_start, loads, n_local)`` — per segment
    its shard, shard-local id and first item column; per shard its item
    load and segment count."""
    import heapq

    S = int(counts.size)
    if S == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, np.zeros(n_dev, np.int64), np.zeros(n_dev, np.int64)
    shard_of = np.zeros(S, dtype=np.int64)
    heap = [(0, s) for s in range(max(n_dev, 1))]
    heapq.heapify(heap)
    for sid in np.argsort(-counts, kind="stable"):
        load, s = heapq.heappop(heap)
        shard_of[sid] = s
        heapq.heappush(heap, (load + int(counts[sid]), s))
    order = np.argsort(shard_of, kind="stable")   # group by shard, id-sorted
    grouped = shard_of[order]
    starts = np.flatnonzero(np.r_[True, np.diff(grouped) > 0])
    sizes = np.diff(np.r_[starts, S])
    rank = np.arange(S, dtype=np.int64) - np.repeat(starts, sizes)
    local_id = np.empty(S, dtype=np.int64)
    local_id[order] = rank
    cs = np.cumsum(counts[order]) - counts[order]  # items before, global
    seg_start = np.empty(S, dtype=np.int64)
    seg_start[order] = cs - np.repeat(cs[starts], sizes)
    loads = np.bincount(
        shard_of, weights=counts.astype(np.float64), minlength=n_dev
    ).astype(np.int64)
    n_local = np.bincount(shard_of, minlength=n_dev)
    return shard_of, local_id, seg_start, loads, n_local


def shard_links_bloom_aligned(be: BEIndex, m: int, n_dev: int) -> dict:
    """Greedy-balance blooms over shards by link count so every bloom's
    links land on ONE device; returns [n_dev, ...] blocks with
    shard-local bloom ids (see the one-psum rationale above)."""
    order = np.argsort(be.link_bloom, kind="stable")
    le, lt, lb = (be.link_edge[order], be.link_twin[order],
                  be.link_bloom[order])
    counts = np.bincount(lb, minlength=be.nb)
    shard_of, loc_bloom, seg_start, loads, nb_local = _greedy_balance(
        counts, n_dev)
    Lmax = max(int(loads.max()) if n_dev else 1, 1)
    Bmax = max(int(nb_local.max()) if nb_local.size else 1, 1)

    le_s = np.full((n_dev, Lmax), m, np.int32)
    lt_s = np.full((n_dev, Lmax), m, np.int32)
    lb_s = np.full((n_dev, Lmax), Bmax, np.int32)
    alive = np.zeros((n_dev, Lmax), bool)
    k0 = np.zeros((n_dev, Bmax), np.int32)
    if lb.size:
        off = np.zeros(be.nb + 1, dtype=np.int64)
        np.cumsum(counts, out=off[1:])
        sh = shard_of[lb]
        pos = np.arange(lb.size, dtype=np.int64) - off[lb] + seg_start[lb]
        le_s[sh, pos] = le
        lt_s[sh, pos] = lt
        lb_s[sh, pos] = loc_bloom[lb]
        alive[sh, pos] = True
    if be.nb:
        k0[shard_of, loc_bloom] = be.bloom_k
    return dict(le=le_s, lt=lt_s, lb=lb_s, alive=alive, k0=k0,
                Bmax=Bmax, m=m)


def make_cd_round_bloom(mesh: Mesh, axis: str, Bmax: int, m: int):
    """One-psum CD round over bloom-aligned shards."""

    def body(peeled_pad, alive_link, k_alive, support_pad, le, lt, lb):
        # all per-shard [1, ...] blocks (leading shard axis split)
        pe = peeled_pad[le]
        pt = peeled_pad[lt]
        pair_dies = alive_link & (pe | pt)
        canon = le < lt
        c = jax.ops.segment_sum(
            (pair_dies & canon).astype(jnp.int32).reshape(-1),
            lb.reshape(-1), num_segments=Bmax + 1)  # LOCAL — no psum
        widow = alive_link & ~pe & pt
        surv = alive_link & ~pair_dies
        contrib = jnp.where(widow, k_alive.reshape(-1)[lb] - 1, 0) \
            + jnp.where(surv, c[lb], 0)
        loss = jax.ops.segment_sum(
            contrib.reshape(-1), le.reshape(-1), num_segments=m + 1)
        loss = jax.lax.psum(loss, axis)          # the ONLY collective
        support_pad = support_pad - loss
        k_alive = k_alive - c[:Bmax].reshape(k_alive.shape)
        alive_link = alive_link & ~pair_dies
        return alive_link, k_alive, support_pad

    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_l, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_l, spec_r),
    )
    return jax.jit(fn)


# =====================================================================
# CD — wedge-sharded rounds for the csr engine (no BE-Index anywhere)
# =====================================================================
# Same two-psums-per-round structure as the link-sharded beindex CD, but
# the sharded unit is the flat wedge list (``core.csr.Wedges``): pairs
# play the role of blooms, per-pair alive wedge counts W_p the role of
# bloom numbers.  This is the only CD that scales with O(Σ deg²) memory
# — the engine that survives past the dense wall also shards.
@dataclasses.dataclass
class ShardedCSRState:
    """Wedge-sharded CD state: the flat wedge list split over the mesh
    axis, per-pair counts W and supports replicated."""

    we1: jax.Array         # (L_pad,) wedge -> edge 1, sharded (sentinel m)
    we2: jax.Array         # (L_pad,) wedge -> edge 2
    wp: jax.Array          # (L_pad,) wedge -> pair (sentinel n_pairs)
    alive_w: jax.Array     # (L_pad,) sharded
    W_pad: jax.Array       # (n_pairs+1,) replicated — alive wedges/pair
    support: jax.Array     # (m,) replicated
    n_pairs: int
    m: int


def shard_wedges(wed: csr.Wedges, n_dev: int) -> ShardedCSRState:
    """Pad the wedge list to a multiple of n_dev.  Pad wedges point at
    the sentinel edge m / pair n_pairs and start dead."""
    L = wed.n_wedges
    m = wed.m
    n_pairs = wed.n_pairs
    pad = (-L) % max(n_dev, 1)
    if L + pad == 0:
        pad = max(n_dev, 1)

    def padded(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    sup0 = csr.edge_butterflies0(wed)
    if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
        raise OverflowError("wing supports exceed int32; shard the graph")
    W_pad = np.zeros(n_pairs + 1, dtype=np.int32)
    W_pad[:n_pairs] = wed.W0.astype(np.int32)
    return ShardedCSRState(
        we1=jnp.asarray(padded(wed.wedge_e1, m)),
        we2=jnp.asarray(padded(wed.wedge_e2, m)),
        wp=jnp.asarray(padded(wed.wedge_pair, n_pairs)),
        alive_w=jnp.asarray(
            np.concatenate([np.ones(L, bool), np.zeros(pad, bool)])),
        W_pad=jnp.asarray(W_pad),
        support=jnp.asarray(sup0.astype(np.int32)),
        n_pairs=n_pairs, m=m,
    )


def _cd_round_body_csr(peeled_pad, alive_w, W_pad, support_pad,
                       we1, we2, wp, *, n_pairs: int, m: int, axis: str):
    """Per-shard csr CD round (wing_loss_csr algebra + two psums)."""
    pe1 = peeled_pad[we1]
    pe2 = peeled_pad[we2]
    w_dies = alive_w & (pe1 | pe2)
    c_local = jax.ops.segment_sum(
        w_dies.astype(jnp.int32), wp, num_segments=n_pairs + 1
    )
    c = jax.lax.psum(c_local, axis)
    surv = alive_w & ~w_dies
    surv_loss = jnp.where(surv, c[wp], 0)
    loss_local = (
        jax.ops.segment_sum(
            jnp.where(w_dies & ~pe1, W_pad[wp] - 1, 0) + surv_loss,
            we1, num_segments=m + 1)
        + jax.ops.segment_sum(
            jnp.where(w_dies & ~pe2, W_pad[wp] - 1, 0) + surv_loss,
            we2, num_segments=m + 1)
    )
    loss = jax.lax.psum(loss_local, axis)
    return alive_w & ~w_dies, W_pad - c, support_pad - loss


def make_cd_round_csr(mesh: Mesh, axis: str, n_pairs: int, m: int):
    """Build the jitted, shard_map-ped csr CD round for a given mesh."""
    body = partial(_cd_round_body_csr, n_pairs=n_pairs, m=m, axis=axis)
    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_r, spec_r),
    )
    return jax.jit(fn)


# =====================================================================
# CD variant — pair-aligned ("bloom-aligned") wedge sharding, one psum
# =====================================================================
# Baseline csr CD needs TWO psums per round: dying-wedge counts c_p
# (pairs straddle shards) then per-edge losses.  If every pair's wedges
# live on ONE shard — pairs play the role of blooms — c_p and W_p become
# shard-local state and a round costs a single psum (the loss): half the
# collectives, mirroring ``shard_links_bloom_aligned`` for the engine
# that scales past the BE-Index.
def shard_wedges_pair_aligned(wed: csr.Wedges, n_dev: int) -> dict:
    """Greedy-balance pairs over shards by wedge count (LPT-flavoured),
    keeping all of a pair's wedges on one shard with shard-local pair
    ids.  Returns [n_dev, ...] blocks: ``we1``/``we2`` (sentinel edge
    m), ``wp`` (local pair ids, sentinel Pmax), ``alive``, ``W0`` (local
    alive wedge counts, [n_dev, Pmax]), plus ``Pmax`` and ``m``."""
    m = wed.m
    n_pairs = wed.n_pairs
    order = np.argsort(wed.wedge_pair, kind="stable")
    we1, we2, wp = (wed.wedge_e1[order], wed.wedge_e2[order],
                    wed.wedge_pair[order])
    counts = np.bincount(wp, minlength=n_pairs)
    shard_of, loc_pair, seg_start, loads, np_local = _greedy_balance(
        counts, n_dev)
    Lmax = max(int(loads.max()) if n_dev else 1, 1)
    Pmax = max(int(np_local.max()) if np_local.size else 1, 1)

    we1_s = np.full((n_dev, Lmax), m, np.int32)
    we2_s = np.full((n_dev, Lmax), m, np.int32)
    wp_s = np.full((n_dev, Lmax), Pmax, np.int32)
    alive = np.zeros((n_dev, Lmax), bool)
    W0 = np.zeros((n_dev, Pmax), np.int32)
    if wp.size:
        off = np.zeros(n_pairs + 1, dtype=np.int64)
        np.cumsum(counts, out=off[1:])
        sh = shard_of[wp]
        pos = np.arange(wp.size, dtype=np.int64) - off[wp] + seg_start[wp]
        we1_s[sh, pos] = we1
        we2_s[sh, pos] = we2
        wp_s[sh, pos] = loc_pair[wp]
        alive[sh, pos] = True
    if n_pairs:
        W0[shard_of, loc_pair] = counts
    return dict(we1=we1_s, we2=we2_s, wp=wp_s, alive=alive, W0=W0,
                Pmax=Pmax, m=m)


def make_cd_round_csr_pair_aligned(mesh: Mesh, axis: str, Pmax: int, m: int):
    """One-psum csr CD round over pair-aligned wedge shards.

    Same widow/survivor algebra as :func:`_cd_round_body_csr`, but c_p
    and W_p are shard-local (a pair's wedges never straddle shards), so
    the per-edge loss reduction is the ONLY collective per round."""

    def body(peeled_pad, alive_w, W_loc, support_pad, we1, we2, wp):
        # all sharded inputs are per-shard [1, ...] blocks
        pe1 = peeled_pad[we1]
        pe2 = peeled_pad[we2]
        w_dies = alive_w & (pe1 | pe2)
        c = jax.ops.segment_sum(
            w_dies.astype(jnp.int32).reshape(-1),
            wp.reshape(-1), num_segments=Pmax + 1)   # LOCAL — no psum
        surv = alive_w & ~w_dies
        surv_loss = jnp.where(surv.reshape(-1), c[wp.reshape(-1)], 0)
        W_flat = W_loc.reshape(-1)
        Wm1 = jnp.concatenate([W_flat - 1, jnp.zeros((1,), jnp.int32)])
        loss_local = (
            jax.ops.segment_sum(
                jnp.where((w_dies & ~pe1).reshape(-1),
                          Wm1[wp.reshape(-1)], 0) + surv_loss,
                we1.reshape(-1), num_segments=m + 1)
            + jax.ops.segment_sum(
                jnp.where((w_dies & ~pe2).reshape(-1),
                          Wm1[wp.reshape(-1)], 0) + surv_loss,
                we2.reshape(-1), num_segments=m + 1)
        )
        loss = jax.lax.psum(loss_local, axis)        # the ONLY collective
        support_pad = support_pad - loss
        W_loc = W_loc - c[:Pmax].reshape(W_loc.shape)
        alive_w = alive_w & ~w_dies
        return alive_w, W_loc, support_pad

    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_l, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_l, spec_r),
    )
    return jax.jit(fn)


def cd_round_sharded_csr(round_fn, st: ShardedCSRState, peeled: jax.Array
                         ) -> ShardedCSRState:
    """One csr CD peeling round. ``peeled`` is the (m,) frontier mask."""
    peeled_pad = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
    support_pad = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    alive_w, W_pad, support_pad = round_fn(
        peeled_pad, st.alive_w, st.W_pad, support_pad,
        st.we1, st.we2, st.wp,
    )
    return dataclasses.replace(
        st, alive_w=alive_w, W_pad=W_pad, support=support_pad[:-1]
    )


# =====================================================================
# FD — partition-stacked, communication-free shard_map
# =====================================================================
def pack_fd_partitions(
    g: BipartiteGraph, be: BEIndex, part: np.ndarray, sup_init: np.ndarray,
    n_parts: int, pad_to: Optional[int] = None,
) -> dict:
    """Build [n_parts_padded, ...] stacked local sub-indices (alg.5).

    Local ids per partition; twins outside the partition map to a
    sentinel never-peeled slot.  Everything padded so partitions stack.
    """
    ple = part[be.link_edge]
    plt_ = part[be.link_twin]
    canon_full = be.link_edge < be.link_twin
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(g.m, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        pair_ge = (ple >= i) & (plt_ >= i)
        # only links anchored at a local (peelable) edge; cross-partition
        # pairs therefore appear exactly once
        keep = pair_ge & (ple == i)
        k_init = np.zeros(be.nb, dtype=np.int64)
        np.add.at(k_init, be.link_bloom[pair_ge & canon_full], 1)
        kl_e, kl_t, kl_b = (be.link_edge[keep], be.link_twin[keep],
                            be.link_bloom[keep])
        twin_local = part[kl_t] == i
        # count each dying pair once: both-local pairs via id order,
        # cross pairs via their single link
        canon = np.where(twin_local, kl_e < kl_t, True)
        blooms = np.unique(kl_b)
        bloc = np.full(be.nb + 1, 0, dtype=np.int64)
        if blooms.size:
            bloc[blooms] = np.arange(blooms.size)
        per.append(dict(
            edges=mine_idx,
            le=loc[kl_e], lt=np.where(twin_local, loc[kl_t], -1),
            lb=bloc[kl_b], canon=canon,
            k0=k_init[blooms],
            sup0=sup_init[mine_idx],
        ))
    Lmax = max((p["le"].size for p in per), default=1) or 1
    Emax = max((p["edges"].size for p in per), default=1) or 1
    Bmax = max((p["k0"].size for p in per), default=1) or 1
    if pad_to:
        Lmax, Emax, Bmax = (max(Lmax, pad_to), max(Emax, pad_to),
                            max(Bmax, pad_to))

    def pk(key, size, fill, dtype=np.int32):
        out = np.full((n_parts, size), fill, dtype=dtype)
        for i, p in enumerate(per):
            x = p[key]
            out[i, : x.size] = x
        return out

    # sentinel local edge id = Emax (extra never-peeled slot)
    le = pk("le", Lmax, Emax)
    lt = np.where(pk("lt", Lmax, -1) < 0, Emax,
                  pk("lt", Lmax, -1)).astype(np.int32)
    canon = pk("canon", Lmax, 0, dtype=bool)
    alive0 = np.zeros((n_parts, Lmax), dtype=bool)
    for i, p in enumerate(per):
        alive0[i, : p["le"].size] = True
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    for i, p in enumerate(per):
        mine[i, : p["edges"].size] = True
        sup0[i, : p["edges"].size] = p["sup0"]
        gids[i, : p["edges"].size] = p["edges"]
    k0 = pk("k0", Bmax, 0)
    return dict(
        le=le, lt=lt, lb=pk("lb", Lmax, Bmax - 1), alive0=alive0,
        canon=canon, k0=k0, sup0=sup0, mine=mine, gids=gids,
        sizes=(Lmax, Emax, Bmax),
    )


def _fd_body_one_partition(le, lt, lb, alive0, canon, k0, sup0, mine):
    """Peel one partition bottom-up — pure lax.while_loop, NO collectives."""
    Emax = mine.shape[0]
    Bmax = k0.shape[0]
    BIG = jnp.iinfo(jnp.int32).max  # >= any guarded support

    def update(peeled, alive_link, k_alive, support):
        pe = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
        p_e = pe[le]
        p_t = pe[lt]
        pair_dies = alive_link & (p_e | p_t)
        c = jax.ops.segment_sum(
            (pair_dies & canon).astype(jnp.int32), lb, num_segments=Bmax)
        widow = alive_link & ~p_e & p_t
        surv = alive_link & ~pair_dies
        contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(
            surv, c[lb], 0)
        loss = jax.ops.segment_sum(contrib, le, num_segments=Emax + 1)[:-1]
        return (alive_link & ~pair_dies, k_alive - c, support - loss)

    def cond(state):
        alive_e, *_ = state
        return jnp.any(alive_e)

    def body(state):
        alive_e, alive_link, k_alive, support, theta, k, rounds = state
        cur = jnp.where(alive_e, support, BIG)
        k = jnp.maximum(k, jnp.min(cur))
        S = alive_e & (support <= k)
        # S is non-empty whenever alive_e is (k >= min alive support)
        theta = jnp.where(S, k, theta)
        alive_e = alive_e & ~S
        alive_link, k_alive, support = update(S, alive_link, k_alive, support)
        return (alive_e, alive_link, k_alive, support, theta, k, rounds + 1)

    # derive loop-constant inits from varying inputs so the carry's
    # manual-axes annotation is stable under shard_map
    zero_e = mine.astype(jnp.int32) * 0
    zero_s = jnp.min(zero_e)
    init = (
        mine, alive0, k0.astype(jnp.int32), sup0.astype(jnp.int32),
        zero_e, zero_s, zero_s,
    )
    alive_e, _, _, _, theta, _, rounds = jax.lax.while_loop(cond, body, init)
    return theta, rounds


def _fd_run_sharded(body, packed: dict, keys: Tuple[str, ...],
                    mesh: Mesh, axis: str) -> Tuple[np.ndarray, np.ndarray]:
    """Shared FD launcher: pad the partition axis to the device count,
    shard_map the vmapped per-partition body, trim the results."""
    n_parts = packed[keys[0]].shape[0]
    n_dev = mesh.devices.size
    pad = (-n_parts) % n_dev

    def padp(x):
        if pad == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], axis=0))

    args = tuple(padp(packed[k]) for k in keys)
    fn = shard_map(
        jax.vmap(body), mesh=mesh,
        in_specs=tuple(P(axis) for _ in args),
        out_specs=(P(axis), P(axis)),
    )
    theta, rounds = jax.jit(fn)(*args)
    return np.asarray(theta)[:n_parts], np.asarray(rounds)[:n_parts]


def fd_peel_sharded(packed: dict, mesh: Mesh, axis: str
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Peel all partitions concurrently: shard_map over the partition axis
    (device-parallel), vmap within a shard.  Returns (theta[m'], rounds[P])
    in packed local layout."""
    return _fd_run_sharded(
        _fd_body_one_partition, packed,
        ("le", "lt", "lb", "alive0", "canon", "k0", "sup0", "mine"),
        mesh, axis,
    )


# =====================================================================
# FD — csr variant: partition-stacked wedge lists, zero collectives
# =====================================================================
def pack_fd_partitions_csr(
    wed: csr.Wedges, part: np.ndarray, sup_init: np.ndarray,
    n_parts: int, pad_to: Optional[int] = None,
    bucket: bool = False, slots: bool = False, flat: bool = False,
) -> dict:
    """Stack per-partition wedge sub-lists into [n_parts, ...] arrays.

    Partition i's sub-structure = wedges with both edges in partitions
    ≥ i (the same induced subgraph the single-device csr FD uses); edge
    ids are partition-local with a sentinel slot Emax for never-peeled
    later-partition edges, pair ids are relabeled per partition.  Same
    sentinel/pad machinery as :func:`pack_fd_partitions`.

    ``bucket=True`` rounds the stacked dims (Lmax, Emax, Pmax) up to
    quarter-power-of-two buckets (``peel._bucket_pad``) so the jitted
    single-dispatch FD driver (``peel._fd_while_vmapped`` consumers)
    recompiles once per shape *bucket* instead of once per partition
    layout — the same trick the per-partition launcher used, applied to
    the whole stack.  Partitions whose individual sizes straddle
    different buckets still land in ONE stacked layout (and therefore
    one while_loop); the bucket only bounds recompiles across graphs.

    ``flat=True`` additionally emits the ragged-concatenated arrays the
    single-device single-dispatch driver consumes (see
    :func:`_pack_fd_flat_csr` — the touching-wedge lists are disjoint,
    so concatenation carries zero padding waste).

    ``slots=True`` additionally packs each partition's wedge list into
    the pairs-major slot layout the blocked Pallas ``support_update``
    kernel consumes (`core.csr.PaddedCSR` per partition, stacked):
    ``slot_e1``/``slot_e2`` are [n_parts, R, K] partition-local edge ids
    (sentinel Emax on padding slots), ``slot_valid`` the initial alive
    matrix.  Rows of all partitions share one (R, K) shape so the FD
    while_loop body can flatten the partition axis into the kernel's row
    grid — one kernel launch per round covering every partition."""
    m = part.size
    pe1 = part[wed.wedge_e1] if wed.n_wedges else np.zeros(0, np.int32)
    pe2 = part[wed.wedge_e2] if wed.n_wedges else np.zeros(0, np.int32)
    pmin = np.minimum(pe1, pe2)
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(m, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        keep_ge = (pe1 >= i) & (pe2 >= i)
        # only wedges TOUCHING partition i can die during FD_i (edges of
        # later partitions never peel here), and survivor charges from
        # untouched ≥i wedges land only on discarded later-partition
        # edges — so the wedge list holds the touching wedges while the
        # untouched ones fold into the static W0 count (they stay alive
        # the whole phase).  Exact, and it makes the stacked lists
        # disjoint across partitions: each wedge appears exactly once,
        # in partition min(part[e1], part[e2]).
        keep = keep_ge & (pmin == i)
        kwe1 = wed.wedge_e1[keep]
        kwe2 = wed.wedge_e2[keep]
        pair_ids, wp_loc = np.unique(wed.wedge_pair[keep],
                                     return_inverse=True)
        cnt_ge = np.bincount(wed.wedge_pair[keep_ge],
                             minlength=max(wed.n_pairs, 1))
        per.append(dict(
            edges=mine_idx,
            we1=np.where(part[kwe1] == i, loc[kwe1], -1),
            we2=np.where(part[kwe2] == i, loc[kwe2], -1),
            wp=wp_loc,
            W0=(cnt_ge[pair_ids] if pair_ids.size
                else np.zeros(1, np.int64)),
            sup0=sup_init[mine_idx],
        ))
    Lmax = max((p["we1"].size for p in per), default=1) or 1
    Emax = max((p["edges"].size for p in per), default=1) or 1
    Pmax = max((p["W0"].size for p in per), default=1) or 1
    if bucket:
        from .peel import _bucket_pad

        Lmax = _bucket_pad(Lmax)
        Emax = _bucket_pad(Emax, floor=8)
        Pmax = _bucket_pad(Pmax, floor=8)
    if pad_to:
        Lmax, Emax, Pmax = (max(Lmax, pad_to), max(Emax, pad_to),
                            max(Pmax, pad_to))

    def pk(key, size, fill, dtype=np.int32):
        out = np.full((n_parts, size), fill, dtype=dtype)
        for i, p in enumerate(per):
            x = p[key]
            out[i, : x.size] = x
        return out

    # sentinel local edge id = Emax (extra never-peeled slot); pad wedges
    # carry pair 0 but start dead, so they contribute nothing
    w1 = pk("we1", Lmax, -1)
    w2 = pk("we2", Lmax, -1)
    we1 = np.where(w1 < 0, Emax, w1).astype(np.int32)
    we2 = np.where(w2 < 0, Emax, w2).astype(np.int32)
    alive0 = np.zeros((n_parts, Lmax), dtype=bool)
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    for i, p in enumerate(per):
        alive0[i, : p["we1"].size] = True
        mine[i, : p["edges"].size] = True
        sup0[i, : p["edges"].size] = p["sup0"]
        gids[i, : p["edges"].size] = p["edges"]
    packed = dict(
        we1=we1, we2=we2, wp=pk("wp", Lmax, 0), alive0=alive0,
        W0=pk("W0", Pmax, 0), sup0=sup0, mine=mine, gids=gids,
        sizes=(Lmax, Emax, Pmax),
    )
    if flat:
        packed.update(_pack_fd_flat_csr(per, n_parts, Emax, bucket=bucket))
    if slots:
        packed.update(_pack_fd_slots_csr(per, n_parts, Emax, bucket=bucket))
    return packed


def _pack_fd_flat_csr(per: list, n_parts: int, Emax: int,
                      bucket: bool = False) -> dict:
    """Ragged-concatenated wedge arrays for the single-dispatch FD.

    The touching-wedge lists are disjoint across partitions, so instead
    of stacking them [n_parts, Lmax] (up to Lmax/mean padding waste) the
    single-device vmapped driver concatenates them into ONE flat list
    with pre-globalized segment ids: partition b's local edge e becomes
    segment b·(Emax+1)+e, its local pair p becomes base_b+p.  Per-round
    work is then O(Σ|list_i|) regardless of partition imbalance.  Pad
    wedges (bucketed tail) point at partition 0's sentinel edge and a
    dedicated dead pair and start dead."""
    sizes = [p["wp"].size for p in per]
    npairs = [int(p["W0"].size) for p in per]
    pair_base = np.zeros(n_parts + 1, dtype=np.int64)
    np.cumsum(npairs, out=pair_base[1:])
    Ptot = int(pair_base[-1])
    Wtot = int(sum(sizes))
    Wpad = Wtot
    Ppad = Ptot + 1
    if bucket:
        from .peel import _bucket_pad

        Wpad = _bucket_pad(max(Wtot, 1))
        Ppad = _bucket_pad(Ptot + 1, floor=8)
    fe1 = np.full(Wpad, Emax, dtype=np.int32)   # partition-0 sentinel
    fe2 = np.full(Wpad, Emax, dtype=np.int32)
    fwp = np.full(Wpad, Ptot, dtype=np.int32)   # dedicated dead pair
    falive = np.zeros(Wpad, dtype=bool)
    fW0 = np.zeros(Ppad, dtype=np.int32)
    pos = 0
    for i, p in enumerate(per):
        k = p["wp"].size
        off = i * (Emax + 1)
        e1 = np.where(p["we1"] < 0, Emax, p["we1"]) + off
        e2 = np.where(p["we2"] < 0, Emax, p["we2"]) + off
        fe1[pos: pos + k] = e1
        fe2[pos: pos + k] = e2
        fwp[pos: pos + k] = p["wp"] + pair_base[i]
        falive[pos: pos + k] = True
        fW0[pair_base[i]: pair_base[i + 1]] = p["W0"]
        pos += k
    return dict(flat_we1=fe1, flat_we2=fe2, flat_wp=fwp,
                flat_alive0=falive, flat_W0=fW0,
                flat_sizes=(Wpad, Ppad))


def _pack_fd_slots_csr(per: list, n_parts: int, Emax: int,
                       bucket: bool = False) -> dict:
    """Stacked pairs-major slot layout for the Pallas in-loop FD update.

    Row r of partition i's block holds the wedges of local pair r
    (``core.csr.pad_segments`` per partition), all blocks padded to one
    (R, K) shape.  Slot edge ids are partition-local with sentinel Emax
    (the extra never-peeled edge slot), so the FD body's peeled-flag
    gathers and loss scatters need no masking."""
    # the kernel carries counts as f32 — same exactness boundary as
    # core.csr.pack_update_slots (W only decreases; checking W0 suffices)
    wmax = max((int(p["W0"].max()) if p["W0"].size else 0 for p in per),
               default=0)
    if wmax >= 2 ** 24:
        raise OverflowError(
            "pair wedge counts exceed f32 integer range (2^24); "
            "use the segment_sum FD body (use_pallas=False)")
    packs = [csr.pad_segments(p["wp"].astype(np.int64),
                              max(p["W0"].size, 1)) for p in per]
    R = max((pk.n_rows_pad for pk in packs), default=1) or 1
    K = max((pk.width for pk in packs), default=1) or 1
    if bucket:
        from .peel import _bucket_pad

        R = _bucket_pad(R, floor=8)
        K = _bucket_pad(K, floor=128)
    slot_e1 = np.full((n_parts, R, K), Emax, dtype=np.int32)
    slot_e2 = np.full((n_parts, R, K), Emax, dtype=np.int32)
    slot_valid = np.zeros((n_parts, R, K), dtype=bool)
    for i, (p, pk) in enumerate(zip(per, packs)):
        if p["wp"].size == 0:
            continue
        idx = np.maximum(pk.idx, 0)
        # local edge ids; -1 (edge of a later partition) → sentinel Emax
        e1 = np.where(p["we1"] < 0, Emax, p["we1"]).astype(np.int32)
        e2 = np.where(p["we2"] < 0, Emax, p["we2"]).astype(np.int32)
        r, c = pk.idx.shape
        slot_e1[i, :r, :c] = np.where(pk.valid, e1[idx], Emax)
        slot_e2[i, :r, :c] = np.where(pk.valid, e2[idx], Emax)
        slot_valid[i, :r, :c] = pk.valid
    return dict(slot_e1=slot_e1, slot_e2=slot_e2, slot_valid=slot_valid,
                slot_sizes=(R, K))


def pack_fd_partitions_tip_csr(
    wed: csr.Wedges, pair_bf0: np.ndarray, part: np.ndarray,
    sup_init: np.ndarray, n_parts: int, bucket: bool = False,
) -> dict:
    """Tip counterpart of :func:`pack_fd_partitions_csr`.

    Tip FD needs only the pairs with BOTH endpoints inside the partition
    (vertices of later partitions never peel during FD_i and deltas onto
    them are discarded), so the stacked pair lists are disjoint across
    partitions — no duplication.  Pair butterfly counts are static (the
    V side is never peeled), so there is no per-partition wedge state:
    pad pairs carry bf=0 and are algebra-neutral.

    The kept pair lists are disjoint across partitions (each pair lives
    where both endpoints do), so they concatenate ragged with
    pre-globalized vertex ids — zero stacking padding.  Returns
    ``pa``/``pb`` (W,) globalized segment ids b·Emax+u, ``bf`` (W,)
    static pair butterflies (0 on the bucketed pad tail — algebra
    neutral), plus [n_parts, Emax] ``mine``/``sup0``/``gids``."""
    n = part.size
    pa_p = part[wed.pair_a] if wed.n_pairs else np.zeros(0, np.int32)
    pb_p = part[wed.pair_b] if wed.n_pairs else np.zeros(0, np.int32)
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(n, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        keep = (pa_p == i) & (pb_p == i)
        per.append(dict(
            nodes=mine_idx,
            pa=loc[wed.pair_a[keep]], pb=loc[wed.pair_b[keep]],
            bf=pair_bf0[keep].astype(np.int32),
            sup0=sup_init[mine_idx],
        ))
    Emax = max((p["nodes"].size for p in per), default=1) or 1
    Wtot = int(sum(p["pa"].size for p in per))
    Wpad = max(Wtot, 1)
    if bucket:
        from .peel import _bucket_pad

        Emax = _bucket_pad(Emax, floor=8)
        Wpad = _bucket_pad(Wpad)
    pa = np.zeros(Wpad, dtype=np.int32)
    pb = np.zeros(Wpad, dtype=np.int32)
    bf = np.zeros(Wpad, dtype=np.int32)
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    pos = 0
    for i, p in enumerate(per):
        k = p["pa"].size
        pa[pos: pos + k] = p["pa"] + i * Emax
        pb[pos: pos + k] = p["pb"] + i * Emax
        bf[pos: pos + k] = p["bf"]
        pos += k
        mine[i, : p["nodes"].size] = True
        sup0[i, : p["nodes"].size] = p["sup0"]
        gids[i, : p["nodes"].size] = p["nodes"]
    return dict(pa=pa, pb=pb, bf=bf, mine=mine, sup0=sup0, gids=gids,
                sizes=(Wpad, Emax))


def _fd_body_one_partition_csr(we1, we2, wp, alive0, W0, sup0, mine):
    """Peel one csr partition bottom-up — the shared device FD driver
    (``peel._fd_while_device``): one while_loop, NO collectives."""
    from .peel import _fd_while_device

    Emax = mine.shape[0]
    Pmax = W0.shape[0]

    def update(S, aux):
        alive_w, W = aux
        S_pad = jnp.concatenate([S, jnp.zeros((1,), bool)])
        alive_w, W, loss, _ = csr.wing_loss_csr(
            S_pad, alive_w, W, we1, we2, wp, Pmax, Emax + 1
        )
        return loss[:Emax], (alive_w, W), jnp.int32(0)

    theta, rounds, _ = _fd_while_device(
        mine, sup0.astype(jnp.int32), update,
        (alive0, W0.astype(jnp.int32)),
    )
    return theta, rounds


def fd_peel_sharded_csr(packed: dict, mesh: Mesh, axis: str
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """csr counterpart of :func:`fd_peel_sharded` — shard_map over the
    padded wedge-slot stacks, zero collectives inside partitions."""
    return _fd_run_sharded(
        _fd_body_one_partition_csr, packed,
        ("we1", "we2", "wp", "alive0", "W0", "sup0", "mine"),
        mesh, axis,
    )


# =====================================================================
# End-to-end distributed wing decomposition
# =====================================================================
def _cd_partition_loop(sup_np: np.ndarray, P_parts: int, step):
    """Shared CD driver: range selection + inner peel rounds, engine
    supplied as ``step(active) -> refreshed int64 support``.

    Returns (part, sup_init, rho_cd)."""
    m = sup_np.size
    alive = np.ones(m, dtype=bool)
    part = np.full(m, -1, dtype=np.int32)
    sup_init = np.zeros(m, dtype=np.int64)
    total_work = float(sup_np.sum())
    rho_cd = 0
    for i in range(P_parts):
        if not alive.any():
            break
        sup_init[alive] = sup_np[alive]
        if i == P_parts - 1:
            hi = int(sup_np[alive].max()) + 1
        else:
            tgt = total_work / P_parts
            s = np.sort(sup_np[alive])
            w = np.maximum(s, 1).astype(np.float64)
            cum = np.cumsum(w)
            pos = min(int(np.searchsorted(cum, tgt)), s.size - 1)
            hi = int(s[pos]) + 1
            hi = max(hi, int(sup_np[alive].min()) + 1)
        while True:
            active = alive & (sup_np < hi)
            if not active.any():
                break
            part[active] = i
            alive &= ~active
            sup_np = step(active)
            rho_cd += 1
    return part, sup_init, rho_cd


def distributed_wing_decomposition(
    g: BipartiteGraph,
    mesh: Mesh,
    axis: str = "peel",
    P_parts: int = 8,
    be: Optional[BEIndex] = None,
    bloom_aligned: bool = False,
    engine: str = "beindex",
    pair_aligned: bool = False,
) -> Tuple[np.ndarray, dict]:
    """Full PBNG wing decomposition on a device mesh.

    ``engine="beindex"``: link-sharded CD rounds (two psums;
    ``bloom_aligned=True`` uses the one-psum §Perf variant) + link-packed
    FD.  ``engine="csr"``: wedge-sharded CD rounds + wedge-packed FD —
    O(Σ deg²) memory end to end, no BE-Index built;
    ``pair_aligned=True`` shards wedges pair-aligned (all of a pair's
    wedges on one device) so the dying-count reduction c_p is
    shard-local and CD pays ONE psum per round instead of two.  FD is
    communication-free either way.  Returns (theta, stats).

    Example (8 forced host devices)::

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        theta, stats = distributed_wing_decomposition(
            g, mesh, engine="csr", pair_aligned=True)
    """
    if engine not in ("beindex", "csr"):
        raise ValueError(engine)
    if pair_aligned and engine != "csr":
        raise ValueError(
            "pair_aligned shards the wedge list: csr engine only "
            "(the beindex analogue is bloom_aligned)"
        )
    if engine == "csr":
        if bloom_aligned or be is not None:
            raise ValueError(
                "engine='csr' builds no BE-Index: bloom_aligned/be "
                "only apply to engine='beindex'"
            )
        return _distributed_wing_csr(g, mesh, axis, P_parts,
                                     pair_aligned=pair_aligned)
    if be is None:
        be = build_beindex(g)
    m = g.m
    n_dev = mesh.devices.size
    if bloom_aligned:
        packed = shard_links_bloom_aligned(be, m, n_dev)
        round_fn = make_cd_round_bloom(mesh, axis, packed["Bmax"], m)
        bl_alive = jnp.asarray(packed["alive"])
        bl_k = jnp.asarray(packed["k0"])
        bl_le = jnp.asarray(packed["le"])
        bl_lt = jnp.asarray(packed["lt"])
        bl_lb = jnp.asarray(packed["lb"])
        support = jnp.asarray(be.edge_support(m).astype(np.int32))
        st = None
    else:
        st = shard_links(be, m, n_dev)
        round_fn = make_cd_round(mesh, axis, st.nb, m)
        support = st.support

    def step(active: np.ndarray) -> np.ndarray:
        nonlocal st, support, bl_alive, bl_k
        if bloom_aligned:
            peeled_pad = jnp.concatenate(
                [jnp.asarray(active), jnp.zeros((1,), bool)])
            support_pad = jnp.concatenate(
                [support, jnp.zeros((1,), jnp.int32)])
            bl_alive, bl_k, support_pad = round_fn(
                peeled_pad, bl_alive, bl_k, support_pad,
                bl_le, bl_lt, bl_lb)
            support = support_pad[:-1]
            return np.asarray(support).astype(np.int64)
        st = cd_round_sharded(round_fn, st, jnp.asarray(active))
        return np.asarray(st.support).astype(np.int64)

    part, sup_init, rho_cd = _cd_partition_loop(
        np.asarray(support).astype(np.int64), P_parts, step)
    n_parts = int(part.max()) + 1

    packed = pack_fd_partitions(g, be, part, sup_init, n_parts)
    theta_loc, rounds = fd_peel_sharded(packed, mesh, axis)
    theta = np.zeros(m, dtype=np.int64)
    for i in range(n_parts):
        mine = packed["mine"][i]
        theta[packed["gids"][i][mine]] = theta_loc[i][mine]
    stats = dict(
        engine="beindex",
        rho_cd=rho_cd,
        rho_fd_total=int(rounds.sum()),
        rho_fd_max=int(rounds.max()) if rounds.size else 0,
        n_parts=n_parts,
        n_links=be.n_links,
        n_dev=n_dev,
    )
    return theta, stats


def _distributed_wing_csr(
    g: BipartiteGraph, mesh: Mesh, axis: str, P_parts: int,
    pair_aligned: bool = False,
) -> Tuple[np.ndarray, dict]:
    """csr engine on a mesh: wedge-sharded CD + wedge-packed FD.

    ``pair_aligned`` swaps the round-robin wedge padding for the
    pair-aligned layout (one psum per CD round instead of two)."""
    wed = csr.build_wedges(g)
    m = g.m
    n_dev = int(mesh.devices.size)
    if pair_aligned:
        packed = shard_wedges_pair_aligned(wed, n_dev)
        round_fn = make_cd_round_csr_pair_aligned(
            mesh, axis, packed["Pmax"], m)
        pa_alive = jnp.asarray(packed["alive"])
        pa_W = jnp.asarray(packed["W0"])
        pa_we1 = jnp.asarray(packed["we1"])
        pa_we2 = jnp.asarray(packed["we2"])
        pa_wp = jnp.asarray(packed["wp"])
        sup0 = csr.edge_butterflies0(wed)
        if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
            raise OverflowError(
                "wing supports exceed int32; shard the graph")
        support = jnp.asarray(sup0.astype(np.int32))
        st = None
    else:
        st = shard_wedges(wed, n_dev)
        round_fn = make_cd_round_csr(mesh, axis, st.n_pairs, m)
        support = st.support

    def step(active: np.ndarray) -> np.ndarray:
        nonlocal st, support, pa_alive, pa_W
        if pair_aligned:
            peeled_pad = jnp.concatenate(
                [jnp.asarray(active), jnp.zeros((1,), bool)])
            support_pad = jnp.concatenate(
                [support, jnp.zeros((1,), jnp.int32)])
            pa_alive, pa_W, support_pad = round_fn(
                peeled_pad, pa_alive, pa_W, support_pad,
                pa_we1, pa_we2, pa_wp)
            support = support_pad[:-1]
            return np.asarray(support).astype(np.int64)
        st = cd_round_sharded_csr(round_fn, st, jnp.asarray(active))
        return np.asarray(st.support).astype(np.int64)

    part, sup_init, rho_cd = _cd_partition_loop(
        np.asarray(support).astype(np.int64), P_parts, step)
    n_parts = int(part.max()) + 1

    packed = pack_fd_partitions_csr(wed, part, sup_init, n_parts)
    theta_loc, rounds = fd_peel_sharded_csr(packed, mesh, axis)
    theta = np.zeros(m, dtype=np.int64)
    for i in range(n_parts):
        mine = packed["mine"][i]
        theta[packed["gids"][i][mine]] = theta_loc[i][mine]
    stats = dict(
        engine="csr",
        cd_sharding="pair_aligned" if pair_aligned else "wedge",
        rho_cd=rho_cd,
        rho_fd_total=int(rounds.sum()),
        rho_fd_max=int(rounds.max()) if rounds.size else 0,
        n_parts=n_parts,
        n_wedges=wed.n_wedges,
        n_pairs=wed.n_pairs,
        n_dev=n_dev,
    )
    return theta, stats


# =====================================================================
# Distributed TIP decomposition (vertex peeling, §3.2)
# =====================================================================
# CD: batch re-counting is a masked matmul — shard the *row blocks* of W
# across devices; each device re-counts butterflies for its vertex shard
# with zero collectives (A is replicated at container scale; row-sharded
# A + one all-gather per round at cluster scale).
# FD: partitions stack on a leading axis and peel under shard_map with
# no communication, pairwise butterfly counts computed once per
# partition inside the kernel (static because V is never peeled).
def _tip_cd_recount_body(A_blk, alive_blk, A_full, alive_full, row0):
    Am = A_full * alive_full[:, None]
    W = jax.lax.dot(A_blk * alive_blk[:, None], Am.T,
                    precision=jax.lax.Precision.HIGHEST)
    rows = row0 + jnp.arange(A_blk.shape[0])
    cols = jnp.arange(A_full.shape[0])
    W = jnp.where(rows[:, None] == cols[None, :], 0.0, W)
    return jnp.sum(W * (W - 1.0) * 0.5, axis=1)


def make_tip_cd_recount(mesh: Mesh, axis: str, n: int, n_dev: int):
    """Jitted row-sharded tip batch re-count; returns (fn, rows/shard)."""
    blk = -(-n // n_dev)

    def body(A_pad, alive_pad, shard_idx):
        # per-shard: A_pad [blk, nv], alive [blk], idx [1]
        row0 = shard_idx[0] * blk
        return _tip_cd_recount_body(
            A_pad, alive_pad,
            jax.lax.all_gather(A_pad, axis, axis=0, tiled=True),
            jax.lax.all_gather(alive_pad, axis, axis=0, tiled=True),
            row0)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    return jax.jit(fn), blk


def _tip_fd_kernel(A_i, mine, sup0):
    """Peel one tip partition bottom-up — no collectives.

    A_i: [Umax, nv] rows of this partition (zero-padded), mine [Umax],
    sup0 [Umax].  Pairwise butterflies are static (V never peeled)."""
    W = jax.lax.dot(A_i, A_i.T, precision=jax.lax.Precision.HIGHEST)
    Umax = W.shape[0]
    W = W * (1.0 - jnp.eye(Umax, dtype=W.dtype))
    pair_bf = W * (W - 1.0) * 0.5
    BIG = jnp.float32(2 ** 30)

    def cond(state):
        alive, *_ = state
        return jnp.any(alive)

    def body(state):
        alive, support, theta, k, rounds = state
        cur = jnp.where(alive, support, BIG)
        k = jnp.maximum(k, jnp.min(cur))
        S = alive & (support <= k)
        theta = jnp.where(S, k, theta)
        alive = alive & ~S
        support = support - pair_bf @ S.astype(jnp.float32)
        return (alive, support, theta, k, rounds + 1)

    zero = jnp.sum(mine.astype(jnp.float32)) * 0.0
    init = (mine, sup0.astype(jnp.float32),
            jnp.zeros((Umax,), jnp.float32) + zero, zero,
            jnp.int32(0) + zero.astype(jnp.int32))
    _, _, theta, _, rounds = jax.lax.while_loop(cond, body, init)
    return theta, rounds


def distributed_tip_decomposition(
    g: BipartiteGraph,
    mesh: Mesh,
    axis: str = "peel",
    side: str = "u",
    P_parts: int = 8,
) -> Tuple[np.ndarray, dict]:
    """Full PBNG tip decomposition on a device mesh.

    CD re-counts supports with row-sharded masked matmuls (zero
    collectives per round at container scale — A is replicated); FD
    stacks padded partitions and peels them under ``shard_map`` with no
    communication, pairwise butterfly counts computed once per partition
    inside the kernel (static: V is never peeled).  Returns
    (theta, stats) with θ bit-identical to the single-device engines.

    Example (8 forced host devices)::

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        theta, stats = distributed_tip_decomposition(g, mesh, side="u")
    """
    from . import counting

    gg = g if side == "u" else g.transpose()
    n, nv = gg.n_u, gg.n_v
    n_dev = int(mesh.devices.size)
    A_np = gg.adjacency()
    recount_fn, blk = make_tip_cd_recount(mesh, axis, n, n_dev)
    n_pad = blk * n_dev
    A = jnp.asarray(np.pad(A_np, ((0, n_pad - n), (0, 0))))
    shard_idx = jnp.arange(n_dev, dtype=jnp.int32)

    alive = np.ones(n_pad, bool)
    alive[n:] = False
    support = np.asarray(recount_fn(A, jnp.asarray(alive), shard_idx))
    support = np.rint(support).astype(np.int64)
    wedge_w = np.rint(np.asarray(
        counting.vertex_wedge_workload(jnp.asarray(A_np)))).astype(np.int64)

    part = np.full(n, -1, np.int32)
    sup_init = np.zeros(n, np.int64)
    total_w = float(wedge_w.sum())
    rho_cd = 0
    for i in range(P_parts):
        av = alive[:n]
        if not av.any():
            break
        sup_init[av] = support[:n][av]
        if i == P_parts - 1:
            hi = int(support[:n][av].max()) + 1
        else:
            s = np.sort(support[:n][av])
            w = wedge_w[av][np.argsort(support[:n][av], kind="stable")]
            cum = np.cumsum(np.maximum(w, 1))
            pos = min(int(np.searchsorted(cum, total_w / P_parts)),
                      s.size - 1)
            hi = max(int(s[pos]) + 1, int(s[0]) + 1)
        while True:
            active = alive[:n] & (support[:n] < hi)
            if not active.any():
                break
            part[active] = i
            alive[:n] &= ~active
            support = np.rint(np.asarray(recount_fn(
                A, jnp.asarray(alive), shard_idx))).astype(np.int64)
            rho_cd += 1
    n_parts = int(part.max()) + 1

    # ---- FD: stack padded partitions, shard over devices
    rows_per = [np.where(part == i)[0] for i in range(n_parts)]
    Umax = max(max((r.size for r in rows_per), default=1), 1)
    pad_parts = -(-n_parts // n_dev) * n_dev
    A_st = np.zeros((pad_parts, Umax, nv), np.float32)
    mine = np.zeros((pad_parts, Umax), bool)
    sup0 = np.zeros((pad_parts, Umax), np.float32)
    gids = np.zeros((pad_parts, Umax), np.int64)
    for i, r in enumerate(rows_per):
        A_st[i, : r.size] = A_np[r]
        mine[i, : r.size] = True
        sup0[i, : r.size] = sup_init[r]
        gids[i, : r.size] = r
    vk = jax.vmap(_tip_fd_kernel)
    fd = shard_map(
        vk, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    theta_st, rounds = jax.jit(fd)(
        jnp.asarray(A_st), jnp.asarray(mine), jnp.asarray(sup0))
    theta_st = np.rint(np.asarray(theta_st)).astype(np.int64)
    theta = np.zeros(n, np.int64)
    for i in range(n_parts):
        theta[gids[i][mine[i]]] = theta_st[i][mine[i]]
    stats = dict(
        rho_cd=rho_cd,
        rho_fd_total=int(np.asarray(rounds).sum()),
        rho_fd_max=int(np.asarray(rounds).max()) if n_parts else 0,
        n_parts=n_parts, n_dev=n_dev,
    )
    return theta, stats
