"""Distributed PBNG — shard_map peeling for multi-device meshes.

Maps the paper's two phases onto an SPMD mesh:

* **CD** (coarse): the peeling structure (BE-Index *links* for the
  beindex engine, the flat *wedge list* / *pair list* for the csr tip
  and wing engines) is sharded across devices; each round every device
  computes its partial dying counts and per-entity losses with
  ``segment_sum`` and ``psum`` combines them.  One or two collectives
  per peeling round — the JAX statement of "little synchronization".
  Supports / frontier masks are replicated (O(n), tiny next to the
  index).  The round loop itself is ``core.peelspec.cd_loop`` — the
  same entity-agnostic driver the single-device engines run, with a
  :class:`~repro.core.peelspec.FixedTarget` range policy.

* **FD** (fine): partitions are padded to a common size, stacked on a
  leading axis and `shard_map`-ped over the ``peel`` mesh axis.  The
  per-partition cascade is ``core.peelspec._fd_while_device`` — **no
  collectives at all** — so the HLO proves the paper's "no global
  synchronization" claim structurally.

Used by ``launch/peel.py`` for the production-mesh dry-run and by the
multi-device tests (spawned with forced host device counts).
"""
from __future__ import annotations

import dataclasses
from functools import partial, wraps
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..sharding.compat import shard_map
from . import csr
from .beindex import BEIndex, build_beindex
from .graph import BipartiteGraph
from .. import obs
from .peelspec import (
    FixedTarget,
    PeelResult,
    PeelSpec,
    PeelStats,
    _fd_while_device,
    cd_loop,
)

__all__ = [
    "ShardedWingState",
    "ShardedCSRState",
    "shard_links",
    "shard_wedges",
    "shard_wedges_pair_aligned",
    "shard_tip_pairs",
    "cd_round_sharded",
    "cd_round_sharded_csr",
    "make_cd_round_csr",
    "make_cd_round_csr_pair_aligned",
    "make_cd_round_tip_csr",
    "pack_fd_partitions",
    "pack_fd_partitions_csr",
    "pack_fd_partitions_tip_csr",
    "fd_peel_sharded",
    "fd_peel_sharded_csr",
    "fd_peel_sharded_tip_csr",
    "distributed_wing_decomposition",
    "distributed_tip_decomposition",
]


def _psum_staged(x, axis):
    """One logical psum, optionally staged over a hierarchical mesh.

    ``axis`` is a mesh-axis name (flat all-reduce, the default) or a
    tuple of names — e.g. ``("grp", "loc")`` on a 2-D mesh
    (:func:`repro.launch.mesh.make_peel_mesh_2d`).  A tuple lowers to
    staged all-reduces, innermost axis first: reduce WITHIN each group
    of co-located devices, then ACROSS groups — two small collectives
    with nested replica groups instead of one flat n-device ring, the
    classic hierarchical-reduction layout for rack-scale meshes.  All
    CD psums here ride int32, so every grouping is exact and the staged
    result is bit-identical to the flat one."""
    if isinstance(axis, str):
        return jax.lax.psum(x, axis)
    for a in reversed(axis):
        x = jax.lax.psum(x, a)
    return x


# =====================================================================
# CD — link-sharded rounds, one psum per round
# =====================================================================
@dataclasses.dataclass
class ShardedWingState:
    """Link-sharded CD state: index arrays split over the mesh axis,
    supports / bloom numbers replicated (O(m) + O(nb), tiny next to the
    links)."""

    le: jax.Array          # (L_pad,) link -> edge, sharded
    lt: jax.Array          # (L_pad,) link -> twin
    lb: jax.Array          # (L_pad,) link -> bloom
    alive_link: jax.Array  # (L_pad,) sharded
    k_alive: jax.Array     # (nb,) replicated
    support: jax.Array     # (m,) replicated
    nb: int
    m: int


def shard_links(be: BEIndex, m: int, n_dev: int) -> ShardedWingState:
    """Pad link arrays to a multiple of n_dev.  Pad links point at a
    sentinel dead bloom/edge and start dead."""
    L = be.n_links
    pad = (-L) % max(n_dev, 1)
    def padded(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])
    le = padded(be.link_edge, m)        # sentinel edge m
    lt = padded(be.link_twin, m)
    lb = padded(be.link_bloom, be.nb)   # sentinel bloom nb
    alive = np.concatenate([np.ones(L, bool), np.zeros(pad, bool)])
    return ShardedWingState(
        le=jnp.asarray(le), lt=jnp.asarray(lt), lb=jnp.asarray(lb),
        alive_link=jnp.asarray(alive),
        k_alive=jnp.asarray(be.bloom_k.astype(np.int32)),
        support=jnp.asarray(be.edge_support(m).astype(np.int32)),
        nb=be.nb, m=m,
    )


def _cd_round_body(peeled_pad, alive_link, k_alive, support_pad,
                   le, lt, lb, *, nb: int, m: int, axis: str | Tuple[str, ...]):
    """Runs per-shard under shard_map; one psum for c, one for loss."""
    pe = peeled_pad[le]
    pt = peeled_pad[lt]
    pair_dies = alive_link & (pe | pt)
    canon = le < lt
    c_local = jax.ops.segment_sum(
        (pair_dies & canon).astype(jnp.int32), lb, num_segments=nb + 1
    )
    c = _psum_staged(c_local, axis)
    widow = alive_link & ~pe & pt
    surv = alive_link & ~pair_dies
    contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(surv, c[lb], 0)
    loss_local = jax.ops.segment_sum(contrib, le, num_segments=m + 1)
    loss = _psum_staged(loss_local, axis)
    support_pad = support_pad - loss
    k_alive = k_alive - c[:nb]
    alive_link = alive_link & ~pair_dies
    return alive_link, k_alive, support_pad


def make_cd_round(mesh: Mesh, axis: str | Tuple[str, ...], nb: int, m: int):
    """Build the jitted, shard_map-ped CD round for a given mesh."""
    body = partial(_cd_round_body, nb=nb, m=m, axis=axis)
    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_r, spec_r),
    )
    return jax.jit(fn)


def cd_round_sharded(round_fn, st: ShardedWingState, peeled: jax.Array
                     ) -> ShardedWingState:
    """One CD peeling round. ``peeled`` is the (m,) frontier mask."""
    peeled_pad = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
    support_pad = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    alive_link, k_alive, support_pad = round_fn(
        peeled_pad, st.alive_link, st.k_alive, support_pad,
        st.le, st.lt, st.lb,
    )
    return dataclasses.replace(
        st, alive_link=alive_link, k_alive=k_alive, support=support_pad[:-1]
    )


# =====================================================================
# Aligned ("segment-on-one-shard") layouts — shared scaffolding
# =====================================================================
# Baseline CD pays TWO psums per round when its grouping segments
# (blooms for beindex, U-pairs for csr wing) straddle shards: one for
# the dying counts, one for the losses.  If every segment's items live
# on ONE shard the count state is shard-local and a round costs a
# single psum.  The greedy-balance placement and the scatter into
# [n_dev, Lmax] blocks are identical for every such layout (bloom-,
# pair- and vertex-aligned); only the per-item arrays differ.
def _greedy_balance(counts: np.ndarray, n_dev: int):
    """LPT-greedy segment→shard placement shared by the aligned one-psum
    CD layouts.

    Segments (blooms / U-pairs / vertices) are placed largest-first onto
    the least-loaded shard (heap, O(S log n_dev) — ties break to the
    lowest shard id like the original argmin).  Everything else is
    vectorized numpy: per shard, segments keep ascending-id order.
    Returns ``(shard_of, local_id, seg_start, loads, n_local)`` — per
    segment its shard, shard-local id and first item column; per shard
    its item load and segment count."""
    import heapq

    S = int(counts.size)
    if S == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z, np.zeros(n_dev, np.int64), np.zeros(n_dev, np.int64)
    shard_of = np.zeros(S, dtype=np.int64)
    heap = [(0, s) for s in range(max(n_dev, 1))]
    heapq.heapify(heap)
    for sid in np.argsort(-counts, kind="stable"):
        load, s = heapq.heappop(heap)
        shard_of[sid] = s
        heapq.heappush(heap, (load + int(counts[sid]), s))
    order = np.argsort(shard_of, kind="stable")   # group by shard, id-sorted
    grouped = shard_of[order]
    starts = np.flatnonzero(np.r_[True, np.diff(grouped) > 0])
    sizes = np.diff(np.r_[starts, S])
    rank = np.arange(S, dtype=np.int64) - np.repeat(starts, sizes)
    local_id = np.empty(S, dtype=np.int64)
    local_id[order] = rank
    cs = np.cumsum(counts[order]) - counts[order]  # items before, global
    seg_start = np.empty(S, dtype=np.int64)
    seg_start[order] = cs - np.repeat(cs[starts], sizes)
    loads = np.bincount(
        shard_of, weights=counts.astype(np.float64), minlength=n_dev
    ).astype(np.int64)
    n_local = np.bincount(shard_of, minlength=n_dev)
    return shard_of, local_id, seg_start, loads, n_local


def _aligned_layout(seg_ids: np.ndarray, n_seg: int, n_dev: int):
    """Entity-agnostic core of every aligned layout: greedy-balance
    segments over shards by item count, keeping ALL of a segment's items
    on one shard, and compute the block scatter.

    Returns ``(order, sh, pos, shard_of, loc_seg, Lmax, Smax,
    counts)``: sort the item arrays by ``order``, then
    ``arr_s[sh, pos] = arr[order]`` fills the [n_dev, Lmax] blocks;
    ``shard_of``/``loc_seg`` give each segment's shard and shard-local
    id (Smax = max local segments); ``counts`` the per-segment item
    counts (already computed for the balance — callers that need them
    must not re-derive)."""
    order = np.argsort(seg_ids, kind="stable")
    sorted_seg = seg_ids[order]
    counts = np.bincount(seg_ids, minlength=n_seg)
    shard_of, loc_seg, seg_start, loads, n_local = _greedy_balance(
        counts, n_dev)
    Lmax = max(int(loads.max()) if n_dev else 1, 1)
    Smax = max(int(n_local.max()) if n_local.size else 1, 1)
    if sorted_seg.size:
        off = np.zeros(n_seg + 1, dtype=np.int64)
        np.cumsum(counts, out=off[1:])
        sh = shard_of[sorted_seg]
        pos = (np.arange(sorted_seg.size, dtype=np.int64)
               - off[sorted_seg] + seg_start[sorted_seg])
    else:
        sh = pos = np.zeros(0, dtype=np.int64)
    return order, sh, pos, shard_of, loc_seg, Lmax, Smax, counts


def shard_links_bloom_aligned(be: BEIndex, m: int, n_dev: int) -> dict:
    """Greedy-balance blooms over shards by link count so every bloom's
    links land on ONE device; returns [n_dev, ...] blocks with
    shard-local bloom ids (see the one-psum rationale above)."""
    order, sh, pos, shard_of, loc_bloom, Lmax, Bmax, _ = _aligned_layout(
        be.link_bloom, be.nb, n_dev)
    le, lt, lb = (be.link_edge[order], be.link_twin[order],
                  be.link_bloom[order])

    le_s = np.full((n_dev, Lmax), m, np.int32)
    lt_s = np.full((n_dev, Lmax), m, np.int32)
    lb_s = np.full((n_dev, Lmax), Bmax, np.int32)
    alive = np.zeros((n_dev, Lmax), bool)
    k0 = np.zeros((n_dev, Bmax), np.int32)
    if lb.size:
        le_s[sh, pos] = le
        lt_s[sh, pos] = lt
        lb_s[sh, pos] = loc_bloom[lb]
        alive[sh, pos] = True
    if be.nb:
        k0[shard_of, loc_bloom] = be.bloom_k
    return dict(le=le_s, lt=lt_s, lb=lb_s, alive=alive, k0=k0,
                Bmax=Bmax, m=m)


def make_cd_round_bloom(mesh: Mesh, axis: str | Tuple[str, ...], Bmax: int, m: int):
    """One-psum CD round over bloom-aligned shards."""

    def body(peeled_pad, alive_link, k_alive, support_pad, le, lt, lb):
        # all per-shard [1, ...] blocks (leading shard axis split)
        pe = peeled_pad[le]
        pt = peeled_pad[lt]
        pair_dies = alive_link & (pe | pt)
        canon = le < lt
        c = jax.ops.segment_sum(
            (pair_dies & canon).astype(jnp.int32).reshape(-1),
            lb.reshape(-1), num_segments=Bmax + 1)  # LOCAL — no psum
        widow = alive_link & ~pe & pt
        surv = alive_link & ~pair_dies
        contrib = jnp.where(widow, k_alive.reshape(-1)[lb] - 1, 0) \
            + jnp.where(surv, c[lb], 0)
        loss = jax.ops.segment_sum(
            contrib.reshape(-1), le.reshape(-1), num_segments=m + 1)
        loss = _psum_staged(loss, axis)          # the ONLY collective
        support_pad = support_pad - loss
        k_alive = k_alive - c[:Bmax].reshape(k_alive.shape)
        alive_link = alive_link & ~pair_dies
        return alive_link, k_alive, support_pad

    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_l, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_l, spec_r),
    )
    return jax.jit(fn)


# =====================================================================
# CD — wedge-sharded rounds for the csr engine (no BE-Index anywhere)
# =====================================================================
# Same two-psums-per-round structure as the link-sharded beindex CD, but
# the sharded unit is the flat wedge list (``core.csr.Wedges``): pairs
# play the role of blooms, per-pair alive wedge counts W_p the role of
# bloom numbers.  This is the only CD that scales with O(Σ deg²) memory
# — the engine that survives past the dense wall also shards.
@dataclasses.dataclass
class ShardedCSRState:
    """Wedge-sharded CD state: the flat wedge list split over the mesh
    axis, per-pair counts W and supports replicated."""

    we1: jax.Array         # (L_pad,) wedge -> edge 1, sharded (sentinel m)
    we2: jax.Array         # (L_pad,) wedge -> edge 2
    wp: jax.Array          # (L_pad,) wedge -> pair (sentinel n_pairs)
    alive_w: jax.Array     # (L_pad,) sharded
    W_pad: jax.Array       # (n_pairs+1,) replicated — alive wedges/pair
    support: jax.Array     # (m,) replicated
    n_pairs: int
    m: int


def shard_wedges(wed: csr.Wedges, n_dev: int) -> ShardedCSRState:
    """Pad the wedge list to a multiple of n_dev.  Pad wedges point at
    the sentinel edge m / pair n_pairs and start dead."""
    L = wed.n_wedges
    m = wed.m
    n_pairs = wed.n_pairs
    pad = (-L) % max(n_dev, 1)
    if L + pad == 0:
        pad = max(n_dev, 1)

    def padded(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    sup0 = csr.edge_butterflies0(wed)
    if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
        raise OverflowError("wing supports exceed int32; shard the graph")
    W_pad = np.zeros(n_pairs + 1, dtype=np.int32)
    W_pad[:n_pairs] = wed.W0.astype(np.int32)
    return ShardedCSRState(
        we1=jnp.asarray(padded(wed.wedge_e1, m)),
        we2=jnp.asarray(padded(wed.wedge_e2, m)),
        wp=jnp.asarray(padded(wed.wedge_pair, n_pairs)),
        alive_w=jnp.asarray(
            np.concatenate([np.ones(L, bool), np.zeros(pad, bool)])),
        W_pad=jnp.asarray(W_pad),
        support=jnp.asarray(sup0.astype(np.int32)),
        n_pairs=n_pairs, m=m,
    )


def _cd_round_body_csr(peeled_pad, alive_w, W_pad, support_pad,
                       we1, we2, wp, *, n_pairs: int, m: int, axis: str | Tuple[str, ...]):
    """Per-shard csr CD round (wing_loss_csr algebra + two psums)."""
    pe1 = peeled_pad[we1]
    pe2 = peeled_pad[we2]
    w_dies = alive_w & (pe1 | pe2)
    c_local = jax.ops.segment_sum(
        w_dies.astype(jnp.int32), wp, num_segments=n_pairs + 1
    )
    c = _psum_staged(c_local, axis)
    surv = alive_w & ~w_dies
    surv_loss = jnp.where(surv, c[wp], 0)
    loss_local = (
        jax.ops.segment_sum(
            jnp.where(w_dies & ~pe1, W_pad[wp] - 1, 0) + surv_loss,
            we1, num_segments=m + 1)
        + jax.ops.segment_sum(
            jnp.where(w_dies & ~pe2, W_pad[wp] - 1, 0) + surv_loss,
            we2, num_segments=m + 1)
    )
    loss = _psum_staged(loss_local, axis)
    return alive_w & ~w_dies, W_pad - c, support_pad - loss


def make_cd_round_csr(mesh: Mesh, axis: str | Tuple[str, ...], n_pairs: int, m: int):
    """Build the jitted, shard_map-ped csr CD round for a given mesh."""
    body = partial(_cd_round_body_csr, n_pairs=n_pairs, m=m, axis=axis)
    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_r, spec_r),
    )
    return jax.jit(fn)


# =====================================================================
# CD variant — pair-aligned ("bloom-aligned") wedge sharding, one psum
# =====================================================================
# Baseline csr CD needs TWO psums per round: dying-wedge counts c_p
# (pairs straddle shards) then per-edge losses.  If every pair's wedges
# live on ONE shard — pairs play the role of blooms — c_p and W_p become
# shard-local state and a round costs a single psum (the loss): half the
# collectives, mirroring ``shard_links_bloom_aligned`` for the engine
# that scales past the BE-Index.
def shard_wedges_pair_aligned(wed: csr.Wedges, n_dev: int) -> dict:
    """Greedy-balance pairs over shards by wedge count (LPT-flavoured),
    keeping all of a pair's wedges on one shard with shard-local pair
    ids.  Returns [n_dev, ...] blocks: ``we1``/``we2`` (sentinel edge
    m), ``wp`` (local pair ids, sentinel Pmax), ``alive``, ``W0`` (local
    alive wedge counts, [n_dev, Pmax]), plus ``Pmax`` and ``m``."""
    m = wed.m
    n_pairs = wed.n_pairs
    order, sh, pos, shard_of, loc_pair, Lmax, Pmax, counts = (
        _aligned_layout(wed.wedge_pair, n_pairs, n_dev))
    we1, we2, wp = (wed.wedge_e1[order], wed.wedge_e2[order],
                    wed.wedge_pair[order])

    we1_s = np.full((n_dev, Lmax), m, np.int32)
    we2_s = np.full((n_dev, Lmax), m, np.int32)
    wp_s = np.full((n_dev, Lmax), Pmax, np.int32)
    alive = np.zeros((n_dev, Lmax), bool)
    W0 = np.zeros((n_dev, Pmax), np.int32)
    if wp.size:
        we1_s[sh, pos] = we1
        we2_s[sh, pos] = we2
        wp_s[sh, pos] = loc_pair[wp]
        alive[sh, pos] = True
    if n_pairs:
        W0[shard_of, loc_pair] = counts
    return dict(we1=we1_s, we2=we2_s, wp=wp_s, alive=alive, W0=W0,
                Pmax=Pmax, m=m)


def make_cd_round_csr_pair_aligned(mesh: Mesh, axis: str | Tuple[str, ...], Pmax: int, m: int):
    """One-psum csr CD round over pair-aligned wedge shards.

    Same widow/survivor algebra as :func:`_cd_round_body_csr`, but c_p
    and W_p are shard-local (a pair's wedges never straddle shards), so
    the per-edge loss reduction is the ONLY collective per round."""

    def body(peeled_pad, alive_w, W_loc, support_pad, we1, we2, wp):
        # all sharded inputs are per-shard [1, ...] blocks
        pe1 = peeled_pad[we1]
        pe2 = peeled_pad[we2]
        w_dies = alive_w & (pe1 | pe2)
        c = jax.ops.segment_sum(
            w_dies.astype(jnp.int32).reshape(-1),
            wp.reshape(-1), num_segments=Pmax + 1)   # LOCAL — no psum
        surv = alive_w & ~w_dies
        surv_loss = jnp.where(surv.reshape(-1), c[wp.reshape(-1)], 0)
        W_flat = W_loc.reshape(-1)
        Wm1 = jnp.concatenate([W_flat - 1, jnp.zeros((1,), jnp.int32)])
        loss_local = (
            jax.ops.segment_sum(
                jnp.where((w_dies & ~pe1).reshape(-1),
                          Wm1[wp.reshape(-1)], 0) + surv_loss,
                we1.reshape(-1), num_segments=m + 1)
            + jax.ops.segment_sum(
                jnp.where((w_dies & ~pe2).reshape(-1),
                          Wm1[wp.reshape(-1)], 0) + surv_loss,
                we2.reshape(-1), num_segments=m + 1)
        )
        loss = _psum_staged(loss_local, axis)        # the ONLY collective
        support_pad = support_pad - loss
        W_loc = W_loc - c[:Pmax].reshape(W_loc.shape)
        alive_w = alive_w & ~w_dies
        return alive_w, W_loc, support_pad

    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_l, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_l, spec_r),
    )
    return jax.jit(fn)


def cd_round_sharded_csr(round_fn, st: ShardedCSRState, peeled: jax.Array
                         ) -> ShardedCSRState:
    """One csr CD peeling round. ``peeled`` is the (m,) frontier mask."""
    peeled_pad = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
    support_pad = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    alive_w, W_pad, support_pad = round_fn(
        peeled_pad, st.alive_w, st.W_pad, support_pad,
        st.we1, st.we2, st.wp,
    )
    return dataclasses.replace(
        st, alive_w=alive_w, W_pad=W_pad, support=support_pad[:-1]
    )


# =====================================================================
# CD — tip csr: sharded pair incidence, ONE psum per round always
# =====================================================================
# Tip's CD update has NO cross-round sharded state: pair butterfly
# counts are static (V is never peeled), so a round is a single
# gather + segment_sum over directed pair entries (vertex u loses
# bf(u, u') when partner u' peels) and the per-vertex loss reduction is
# the ONLY collective regardless of layout.  ``aligned=True`` applies
# the generalized greedy balance so ALL of a vertex's entries land on
# one device — each vertex's loss is computed wholly locally (pure
# disjoint-support merge through the psum) and shards are balanced by
# incident-pair count instead of round-robin entry count.
def shard_tip_pairs(
    wed: csr.Wedges, pair_bf0: np.ndarray, n_dev: int,
    aligned: bool = False,
) -> dict:
    """Shard the directed pair-incidence list for the tip csr CD.

    Each pair {a, b} becomes two directed entries (dst=a, src=b) and
    (dst=b, src=a) carrying the static butterfly count, so a round's
    loss for dst is Σ bf over entries whose src peeled.  Returns
    [n_dev, Lmax] blocks ``dst``/``src`` (global vertex ids, sentinel
    n) and ``bf`` (0 on padding — algebra-neutral): round-robin split
    by default, vertex-aligned greedy balance with ``aligned=True``."""
    n = wed.n_u
    dst, src, val = csr.directed_pair_incidence(wed, pair_bf0)
    n_dev = max(n_dev, 1)
    if aligned:
        order, sh, pos, _, _, Lmax, _, _ = _aligned_layout(dst, n, n_dev)
        dst_s = np.full((n_dev, Lmax), n, np.int32)
        src_s = np.full((n_dev, Lmax), n, np.int32)
        bf_s = np.zeros((n_dev, Lmax), np.int32)
        if dst.size:
            dst_s[sh, pos] = dst[order]
            src_s[sh, pos] = src[order]
            bf_s[sh, pos] = val[order]
    else:
        L = dst.size
        Lmax = max(-(-L // n_dev), 1)
        pad = n_dev * Lmax - L
        dst_s = np.concatenate(
            [dst, np.full(pad, n, np.int64)]).astype(np.int32)
        src_s = np.concatenate(
            [src, np.full(pad, n, np.int64)]).astype(np.int32)
        bf_s = np.concatenate([val, np.zeros(pad, np.int32)])
        dst_s = dst_s.reshape(n_dev, Lmax)
        src_s = src_s.reshape(n_dev, Lmax)
        bf_s = bf_s.reshape(n_dev, Lmax)
    return dict(dst=dst_s, src=src_s, bf=bf_s, n=n)


def make_cd_round_tip_csr(mesh: Mesh, axis: str | Tuple[str, ...], n: int):
    """One-psum tip csr CD round over sharded pair-incidence blocks.

    The same jitted round serves both layouts of :func:`shard_tip_pairs`
    (round-robin and vertex-aligned): pair butterflies are static, so
    the per-vertex loss reduction is the single collective either way.
    """

    def body(peeled_pad, support_pad, dst, src, bf):
        contrib = jnp.where(peeled_pad[src.reshape(-1)], bf.reshape(-1), 0)
        loss = jax.ops.segment_sum(
            contrib, dst.reshape(-1), num_segments=n + 1)
        loss = _psum_staged(loss, axis)          # the ONLY collective
        return support_pad - loss

    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=spec_r,
    )
    return jax.jit(fn)


# =====================================================================
# FD — partition-stacked, communication-free shard_map
# =====================================================================
def pack_fd_partitions(
    g: BipartiteGraph, be: BEIndex, part: np.ndarray, sup_init: np.ndarray,
    n_parts: int, pad_to: Optional[int] = None,
) -> dict:
    """Build [n_parts_padded, ...] stacked local sub-indices (alg.5).

    Local ids per partition; twins outside the partition map to a
    sentinel never-peeled slot.  Everything padded so partitions stack.
    """
    ple = part[be.link_edge]
    plt_ = part[be.link_twin]
    canon_full = be.link_edge < be.link_twin
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(g.m, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        pair_ge = (ple >= i) & (plt_ >= i)
        # only links anchored at a local (peelable) edge; cross-partition
        # pairs therefore appear exactly once
        keep = pair_ge & (ple == i)
        k_init = np.zeros(be.nb, dtype=np.int64)
        np.add.at(k_init, be.link_bloom[pair_ge & canon_full], 1)
        kl_e, kl_t, kl_b = (be.link_edge[keep], be.link_twin[keep],
                            be.link_bloom[keep])
        twin_local = part[kl_t] == i
        # count each dying pair once: both-local pairs via id order,
        # cross pairs via their single link
        canon = np.where(twin_local, kl_e < kl_t, True)
        blooms = np.unique(kl_b)
        bloc = np.full(be.nb + 1, 0, dtype=np.int64)
        if blooms.size:
            bloc[blooms] = np.arange(blooms.size)
        per.append(dict(
            edges=mine_idx,
            le=loc[kl_e], lt=np.where(twin_local, loc[kl_t], -1),
            lb=bloc[kl_b], canon=canon,
            k0=k_init[blooms],
            sup0=sup_init[mine_idx],
        ))
    Lmax = max((p["le"].size for p in per), default=1) or 1
    Emax = max((p["edges"].size for p in per), default=1) or 1
    Bmax = max((p["k0"].size for p in per), default=1) or 1
    if pad_to:
        Lmax, Emax, Bmax = (max(Lmax, pad_to), max(Emax, pad_to),
                            max(Bmax, pad_to))

    def pk(key, size, fill, dtype=np.int32):
        out = np.full((n_parts, size), fill, dtype=dtype)
        for i, p in enumerate(per):
            x = p[key]
            out[i, : x.size] = x
        return out

    # sentinel local edge id = Emax (extra never-peeled slot)
    le = pk("le", Lmax, Emax)
    lt = np.where(pk("lt", Lmax, -1) < 0, Emax,
                  pk("lt", Lmax, -1)).astype(np.int32)
    canon = pk("canon", Lmax, 0, dtype=bool)
    alive0 = np.zeros((n_parts, Lmax), dtype=bool)
    for i, p in enumerate(per):
        alive0[i, : p["le"].size] = True
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    for i, p in enumerate(per):
        mine[i, : p["edges"].size] = True
        sup0[i, : p["edges"].size] = p["sup0"]
        gids[i, : p["edges"].size] = p["edges"]
    k0 = pk("k0", Bmax, 0)
    return dict(
        le=le, lt=lt, lb=pk("lb", Lmax, Bmax - 1), alive0=alive0,
        canon=canon, k0=k0, sup0=sup0, mine=mine, gids=gids,
        sizes=(Lmax, Emax, Bmax),
    )


def _fd_body_one_partition(le, lt, lb, alive0, canon, k0, sup0, mine):
    """Peel one beindex partition bottom-up — the shared device FD
    driver (``peelspec._fd_while_device``) with the alg.6 widow/survivor
    update: one while_loop, NO collectives."""
    Emax = mine.shape[0]
    Bmax = k0.shape[0]

    def update(S, aux):
        alive_link, k_alive = aux
        pe = jnp.concatenate([S, jnp.zeros((1,), bool)])
        p_e = pe[le]
        p_t = pe[lt]
        pair_dies = alive_link & (p_e | p_t)
        c = jax.ops.segment_sum(
            (pair_dies & canon).astype(jnp.int32), lb, num_segments=Bmax)
        widow = alive_link & ~p_e & p_t
        surv = alive_link & ~pair_dies
        contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(
            surv, c[lb], 0)
        loss = jax.ops.segment_sum(contrib, le, num_segments=Emax + 1)[:-1]
        return loss, (alive_link & ~pair_dies, k_alive - c), jnp.int32(0)

    theta, rounds, _ = _fd_while_device(
        mine, sup0.astype(jnp.int32), update,
        (alive0, k0.astype(jnp.int32)),
    )
    return theta, rounds


def _fd_run_sharded(body, packed: dict, keys: Tuple[str, ...],
                    mesh: Mesh, axis: str | Tuple[str, ...]) -> Tuple[np.ndarray, np.ndarray]:
    """Shared FD launcher: pad the partition axis to the device count,
    shard_map the vmapped per-partition body, trim the results."""
    n_parts = packed[keys[0]].shape[0]
    n_dev = mesh.devices.size
    pad = (-n_parts) % n_dev

    def padp(x):
        if pad == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], axis=0))

    args = tuple(padp(packed[k]) for k in keys)
    fn = shard_map(
        jax.vmap(body), mesh=mesh,
        in_specs=tuple(P(axis) for _ in args),
        out_specs=(P(axis), P(axis)),
    )
    theta, rounds = jax.jit(fn)(*args)
    return np.asarray(theta)[:n_parts], np.asarray(rounds)[:n_parts]


def fd_peel_sharded(packed: dict, mesh: Mesh, axis: str | Tuple[str, ...]
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Peel all partitions concurrently: shard_map over the partition axis
    (device-parallel), vmap within a shard.  Returns (theta[m'], rounds[P])
    in packed local layout."""
    return _fd_run_sharded(
        _fd_body_one_partition, packed,
        ("le", "lt", "lb", "alive0", "canon", "k0", "sup0", "mine"),
        mesh, axis,
    )


# =====================================================================
# FD — csr variant: partition-stacked wedge lists, zero collectives
# =====================================================================
def pack_fd_partitions_csr(
    wed: csr.Wedges, part: np.ndarray, sup_init: np.ndarray,
    n_parts: int, pad_to: Optional[int] = None,
    bucket: bool = False, slots: bool = False, flat: bool = False,
) -> dict:
    """Stack per-partition wedge sub-lists into [n_parts, ...] arrays.

    Partition i's sub-structure = wedges with both edges in partitions
    ≥ i (the same induced subgraph the single-device csr FD uses); edge
    ids are partition-local with a sentinel slot Emax for never-peeled
    later-partition edges, pair ids are relabeled per partition.  Same
    sentinel/pad machinery as :func:`pack_fd_partitions`.

    ``bucket=True`` rounds the stacked dims (Lmax, Emax, Pmax) up to
    quarter-power-of-two buckets (``peelspec._bucket_pad``) so the
    jitted single-dispatch FD driver (``peelspec._fd_while_vmapped``
    consumers) recompiles once per shape *bucket* instead of once per
    partition layout — the same trick the per-partition launcher used,
    applied to the whole stack.  Partitions whose individual sizes
    straddle different buckets still land in ONE stacked layout (and
    therefore one while_loop); the bucket only bounds recompiles across
    graphs.

    ``flat=True`` additionally emits the ragged-concatenated arrays the
    single-device single-dispatch driver consumes (see
    :func:`_pack_fd_flat_csr` — the touching-wedge lists are disjoint,
    so concatenation carries zero padding waste).

    ``slots=True`` additionally packs each partition's wedge list into
    the pairs-major slot layout the blocked Pallas ``support_update``
    kernel consumes (`core.csr.PaddedCSR` per partition, stacked):
    ``slot_e1``/``slot_e2`` are [n_parts, R, K] partition-local edge ids
    (sentinel Emax on padding slots), ``slot_valid`` the initial alive
    matrix.  Rows of all partitions share one (R, K) shape so the FD
    while_loop body can flatten the partition axis into the kernel's row
    grid — one kernel launch per round covering every partition."""
    m = part.size
    pe1 = part[wed.wedge_e1] if wed.n_wedges else np.zeros(0, np.int32)
    pe2 = part[wed.wedge_e2] if wed.n_wedges else np.zeros(0, np.int32)
    pmin = np.minimum(pe1, pe2)
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(m, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        keep_ge = (pe1 >= i) & (pe2 >= i)
        # only wedges TOUCHING partition i can die during FD_i (edges of
        # later partitions never peel here), and survivor charges from
        # untouched ≥i wedges land only on discarded later-partition
        # edges — so the wedge list holds the touching wedges while the
        # untouched ones fold into the static W0 count (they stay alive
        # the whole phase).  Exact, and it makes the stacked lists
        # disjoint across partitions: each wedge appears exactly once,
        # in partition min(part[e1], part[e2]).
        keep = keep_ge & (pmin == i)
        kwe1 = wed.wedge_e1[keep]
        kwe2 = wed.wedge_e2[keep]
        pair_ids, wp_loc = np.unique(wed.wedge_pair[keep],
                                     return_inverse=True)
        cnt_ge = np.bincount(wed.wedge_pair[keep_ge],
                             minlength=max(wed.n_pairs, 1))
        per.append(dict(
            edges=mine_idx,
            we1=np.where(part[kwe1] == i, loc[kwe1], -1),
            we2=np.where(part[kwe2] == i, loc[kwe2], -1),
            wp=wp_loc,
            W0=(cnt_ge[pair_ids] if pair_ids.size
                else np.zeros(1, np.int64)),
            sup0=sup_init[mine_idx],
        ))
    Lmax = max((p["we1"].size for p in per), default=1) or 1
    Emax = max((p["edges"].size for p in per), default=1) or 1
    Pmax = max((p["W0"].size for p in per), default=1) or 1
    if bucket:
        from .peelspec import _bucket_pad

        Lmax = _bucket_pad(Lmax)
        Emax = _bucket_pad(Emax, floor=8)
        Pmax = _bucket_pad(Pmax, floor=8)
    if pad_to:
        Lmax, Emax, Pmax = (max(Lmax, pad_to), max(Emax, pad_to),
                            max(Pmax, pad_to))

    def pk(key, size, fill, dtype=np.int32):
        out = np.full((n_parts, size), fill, dtype=dtype)
        for i, p in enumerate(per):
            x = p[key]
            out[i, : x.size] = x
        return out

    # sentinel local edge id = Emax (extra never-peeled slot); pad wedges
    # carry pair 0 but start dead, so they contribute nothing
    w1 = pk("we1", Lmax, -1)
    w2 = pk("we2", Lmax, -1)
    we1 = np.where(w1 < 0, Emax, w1).astype(np.int32)
    we2 = np.where(w2 < 0, Emax, w2).astype(np.int32)
    alive0 = np.zeros((n_parts, Lmax), dtype=bool)
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    for i, p in enumerate(per):
        alive0[i, : p["we1"].size] = True
        mine[i, : p["edges"].size] = True
        sup0[i, : p["edges"].size] = p["sup0"]
        gids[i, : p["edges"].size] = p["edges"]
    packed = dict(
        we1=we1, we2=we2, wp=pk("wp", Lmax, 0), alive0=alive0,
        W0=pk("W0", Pmax, 0), sup0=sup0, mine=mine, gids=gids,
        sizes=(Lmax, Emax, Pmax),
    )
    if flat:
        packed.update(_pack_fd_flat_csr(per, n_parts, Emax, bucket=bucket))
    if slots:
        packed.update(_pack_fd_slots_csr(per, n_parts, Emax, bucket=bucket))
    return packed


def _pack_fd_flat_csr(per: list, n_parts: int, Emax: int,
                      bucket: bool = False) -> dict:
    """Ragged-concatenated wedge arrays for the single-dispatch FD.

    The touching-wedge lists are disjoint across partitions, so instead
    of stacking them [n_parts, Lmax] (up to Lmax/mean padding waste) the
    single-device vmapped driver concatenates them into ONE flat list
    with pre-globalized segment ids: partition b's local edge e becomes
    segment b·(Emax+1)+e, its local pair p becomes base_b+p.  Per-round
    work is then O(Σ|list_i|) regardless of partition imbalance.  Pad
    wedges (bucketed tail) point at partition 0's sentinel edge and a
    dedicated dead pair and start dead."""
    sizes = [p["wp"].size for p in per]
    npairs = [int(p["W0"].size) for p in per]
    pair_base = np.zeros(n_parts + 1, dtype=np.int64)
    np.cumsum(npairs, out=pair_base[1:])
    Ptot = int(pair_base[-1])
    Wtot = int(sum(sizes))
    Wpad = Wtot
    Ppad = Ptot + 1
    if bucket:
        from .peelspec import _bucket_pad

        Wpad = _bucket_pad(max(Wtot, 1))
        Ppad = _bucket_pad(Ptot + 1, floor=8)
    fe1 = np.full(Wpad, Emax, dtype=np.int32)   # partition-0 sentinel
    fe2 = np.full(Wpad, Emax, dtype=np.int32)
    fwp = np.full(Wpad, Ptot, dtype=np.int32)   # dedicated dead pair
    falive = np.zeros(Wpad, dtype=bool)
    fW0 = np.zeros(Ppad, dtype=np.int32)
    pos = 0
    for i, p in enumerate(per):
        k = p["wp"].size
        off = i * (Emax + 1)
        e1 = np.where(p["we1"] < 0, Emax, p["we1"]) + off
        e2 = np.where(p["we2"] < 0, Emax, p["we2"]) + off
        fe1[pos: pos + k] = e1
        fe2[pos: pos + k] = e2
        fwp[pos: pos + k] = p["wp"] + pair_base[i]
        falive[pos: pos + k] = True
        fW0[pair_base[i]: pair_base[i + 1]] = p["W0"]
        pos += k
    return dict(flat_we1=fe1, flat_we2=fe2, flat_wp=fwp,
                flat_alive0=falive, flat_W0=fW0,
                flat_sizes=(Wpad, Ppad))


def _pack_fd_slots_csr(per: list, n_parts: int, Emax: int,
                       bucket: bool = False) -> dict:
    """Stacked pairs-major slot layout for the Pallas in-loop FD update.

    Row r of partition i's block holds the wedges of local pair r
    (``core.csr.pad_segments`` per partition), all blocks padded to one
    (R, K) shape.  Slot edge ids are partition-local with sentinel Emax
    (the extra never-peeled edge slot), so the FD body's peeled-flag
    gathers and loss scatters need no masking."""
    # the kernel carries counts as f32 — same exactness boundary as
    # core.csr.pack_update_slots (W only decreases; checking W0 suffices)
    wmax = max((int(p["W0"].max()) if p["W0"].size else 0 for p in per),
               default=0)
    if wmax >= 2 ** 24:
        raise OverflowError(
            "pair wedge counts exceed f32 integer range (2^24); "
            "use the segment_sum FD body (use_pallas=False)")
    packs = [csr.pad_segments(p["wp"].astype(np.int64),
                              max(p["W0"].size, 1)) for p in per]
    R = max((pk.n_rows_pad for pk in packs), default=1) or 1
    K = max((pk.width for pk in packs), default=1) or 1
    if bucket:
        from .peelspec import _bucket_pad

        R = _bucket_pad(R, floor=8)
        K = _bucket_pad(K, floor=128)
    slot_e1 = np.full((n_parts, R, K), Emax, dtype=np.int32)
    slot_e2 = np.full((n_parts, R, K), Emax, dtype=np.int32)
    slot_valid = np.zeros((n_parts, R, K), dtype=bool)
    for i, (p, pk) in enumerate(zip(per, packs)):
        if p["wp"].size == 0:
            continue
        idx = np.maximum(pk.idx, 0)
        # local edge ids; -1 (edge of a later partition) → sentinel Emax
        e1 = np.where(p["we1"] < 0, Emax, p["we1"]).astype(np.int32)
        e2 = np.where(p["we2"] < 0, Emax, p["we2"]).astype(np.int32)
        r, c = pk.idx.shape
        slot_e1[i, :r, :c] = np.where(pk.valid, e1[idx], Emax)
        slot_e2[i, :r, :c] = np.where(pk.valid, e2[idx], Emax)
        slot_valid[i, :r, :c] = pk.valid
    return dict(slot_e1=slot_e1, slot_e2=slot_e2, slot_valid=slot_valid,
                slot_sizes=(R, K))


def pack_fd_partitions_tip_csr(
    wed: csr.Wedges, pair_bf0: np.ndarray, part: np.ndarray,
    sup_init: np.ndarray, n_parts: int, bucket: bool = False,
    stacked: bool = False,
) -> dict:
    """Tip counterpart of :func:`pack_fd_partitions_csr`.

    Tip FD needs only the pairs with BOTH endpoints inside the partition
    (vertices of later partitions never peel during FD_i and deltas onto
    them are discarded), so the stacked pair lists are disjoint across
    partitions — no duplication.  Pair butterfly counts are static (the
    V side is never peeled), so there is no per-partition wedge state:
    pad pairs carry bf=0 and are algebra-neutral.

    The kept pair lists are disjoint across partitions (each pair lives
    where both endpoints do), so they concatenate ragged with
    pre-globalized vertex ids — zero stacking padding.  Returns
    ``pa``/``pb`` (W,) globalized segment ids b·Emax+u, ``bf`` (W,)
    static pair butterflies (0 on the bucketed pad tail — algebra
    neutral), plus [n_parts, Emax] ``mine``/``sup0``/``gids``.

    ``stacked=True`` additionally emits the [n_parts, Lmax] blocks
    ``st_pa``/``st_pb``/``st_bf`` (partition-LOCAL vertex ids, bf=0 on
    padding) the per-partition shard_map FD
    (:func:`fd_peel_sharded_tip_csr`) consumes."""
    n = part.size
    pa_p = part[wed.pair_a] if wed.n_pairs else np.zeros(0, np.int32)
    pb_p = part[wed.pair_b] if wed.n_pairs else np.zeros(0, np.int32)
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(n, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        keep = (pa_p == i) & (pb_p == i)
        per.append(dict(
            nodes=mine_idx,
            pa=loc[wed.pair_a[keep]], pb=loc[wed.pair_b[keep]],
            bf=pair_bf0[keep].astype(np.int32),
            sup0=sup_init[mine_idx],
        ))
    Emax = max((p["nodes"].size for p in per), default=1) or 1
    Wtot = int(sum(p["pa"].size for p in per))
    Wpad = max(Wtot, 1)
    if bucket:
        from .peelspec import _bucket_pad

        Emax = _bucket_pad(Emax, floor=8)
        Wpad = _bucket_pad(Wpad)
    pa = np.zeros(Wpad, dtype=np.int32)
    pb = np.zeros(Wpad, dtype=np.int32)
    bf = np.zeros(Wpad, dtype=np.int32)
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    pos = 0
    for i, p in enumerate(per):
        k = p["pa"].size
        pa[pos: pos + k] = p["pa"] + i * Emax
        pb[pos: pos + k] = p["pb"] + i * Emax
        bf[pos: pos + k] = p["bf"]
        pos += k
        mine[i, : p["nodes"].size] = True
        sup0[i, : p["nodes"].size] = p["sup0"]
        gids[i, : p["nodes"].size] = p["nodes"]
    packed = dict(pa=pa, pb=pb, bf=bf, mine=mine, sup0=sup0, gids=gids,
                  sizes=(Wpad, Emax))
    if stacked:
        Lmax = max((p["pa"].size for p in per), default=1) or 1
        if bucket:
            from .peelspec import _bucket_pad

            Lmax = _bucket_pad(Lmax, floor=8)
        st_pa = np.zeros((n_parts, Lmax), dtype=np.int32)
        st_pb = np.zeros((n_parts, Lmax), dtype=np.int32)
        st_bf = np.zeros((n_parts, Lmax), dtype=np.int32)
        for i, p in enumerate(per):
            k = p["pa"].size
            st_pa[i, :k] = p["pa"]
            st_pb[i, :k] = p["pb"]
            st_bf[i, :k] = p["bf"]
        packed.update(st_pa=st_pa, st_pb=st_pb, st_bf=st_bf)
    return packed


def _fd_body_one_partition_csr(we1, we2, wp, alive0, W0, sup0, mine):
    """Peel one csr wing partition bottom-up — the shared device FD
    driver (``peelspec._fd_while_device``): one while_loop, NO
    collectives."""
    Emax = mine.shape[0]
    Pmax = W0.shape[0]

    def update(S, aux):
        alive_w, W = aux
        S_pad = jnp.concatenate([S, jnp.zeros((1,), bool)])
        alive_w, W, loss, _ = csr.wing_loss_csr(
            S_pad, alive_w, W, we1, we2, wp, Pmax, Emax + 1
        )
        return loss[:Emax], (alive_w, W), jnp.int32(0)

    theta, rounds, _ = _fd_while_device(
        mine, sup0.astype(jnp.int32), update,
        (alive0, W0.astype(jnp.int32)),
    )
    return theta, rounds


def _fd_body_one_partition_tip_csr(pa, pb, bf, mine, sup0):
    """Peel one csr tip partition bottom-up — the shared device FD
    driver with the static pair-butterfly update: one while_loop, NO
    collectives."""
    Emax = mine.shape[0]

    def update(S, aux):
        loss = csr.tip_delta_csr(S, pa, pb, bf, Emax)
        return loss, aux, jnp.int32(0)

    theta, rounds, _ = _fd_while_device(
        mine, sup0.astype(jnp.int32), update, jnp.int32(0))
    return theta, rounds


def fd_peel_sharded_csr(packed: dict, mesh: Mesh, axis: str | Tuple[str, ...]
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """csr wing counterpart of :func:`fd_peel_sharded` — shard_map over
    the padded wedge-slot stacks, zero collectives inside partitions."""
    return _fd_run_sharded(
        _fd_body_one_partition_csr, packed,
        ("we1", "we2", "wp", "alive0", "W0", "sup0", "mine"),
        mesh, axis,
    )


def fd_peel_sharded_tip_csr(packed: dict, mesh: Mesh, axis: str | Tuple[str, ...]
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """csr tip counterpart of :func:`fd_peel_sharded` — shard_map over
    the stacked local pair lists (``pack_fd_partitions_tip_csr`` with
    ``stacked=True``), zero collectives inside partitions."""
    return _fd_run_sharded(
        _fd_body_one_partition_tip_csr, packed,
        ("st_pa", "st_pb", "st_bf", "mine", "sup0"),
        mesh, axis,
    )


# =====================================================================
# End-to-end distributed wing decomposition
# =====================================================================
def _scatter_theta(theta, packed, theta_loc, n_parts):
    """Map packed-local θ back to global entity ids."""
    for i in range(n_parts):
        mine = packed["mine"][i]
        theta[packed["gids"][i][mine]] = theta_loc[i][mine]


def _finish(theta, part, ranges, sup_init, stats, extras, return_result):
    """Assemble the (theta, stats[, PeelResult]) return of the
    distributed decompositions: JSON-able stats dict with the mesh
    extras, full provenance only when asked for."""
    stats_out = stats.as_dict()
    stats_out.update(extras)
    if not return_result:
        return theta, stats_out
    result = PeelResult(
        theta=theta, part=part, ranges=ranges,
        support_init=sup_init, stats=stats,
    )
    return theta, stats_out, result


def _record_fd_sharded(n_parts: int, rounds) -> None:
    """Record a sharded FD launch's per-partition round counts into the
    active timeline collector (per-round rings don't cross the
    ``shard_map`` boundary; totals stay exact)."""
    col = obs.active_collector()
    if col is not None and n_parts:
        r = np.asarray(rounds).reshape(-1)[:n_parts]
        col.record_fd_counts(
            "sharded", list(range(n_parts)),
            r.astype(np.int64).tolist())


def _with_obs(kind: str):
    """Wrap a distributed decomposition entry with the observability
    collector: a ``peel``-cat span around the run, a timeline built from
    the collector (CD rounds recorded live by ``cd_loop``; FD round
    counts recorded by the sharded/vmapped FD sections), its trace
    events, and attachment to the returned stats dict / PeelResult.
    With the obs layer off this adds one ``is None`` check."""
    def deco(fn):
        @wraps(fn)
        def wrapper(*args, **kwargs):
            with obs.maybe_collect() as col:
                with obs.span(f"peel.{fn.__name__}", cat="peel",
                              kind=kind):
                    out = fn(*args, **kwargs)
            if col is not None:
                tl = col.build()
                tracer = obs.get_tracer()
                if tracer is not None:
                    tl.emit_trace_events(tracer)
                out[1]["timeline"] = tl.summary()
                if len(out) == 3:
                    out[2].timeline = tl
            return out
        return wrapper
    return deco


@_with_obs("wing")
def distributed_wing_decomposition(
    g: BipartiteGraph,
    mesh: Mesh,
    axis: str | Tuple[str, ...] = "peel",
    P_parts: int = 8,
    be: Optional[BEIndex] = None,
    bloom_aligned: bool = False,
    engine: str = "beindex",
    pair_aligned: bool = False,
    aligned: Optional[bool] = None,
    return_result: bool = False,
):
    """Full PBNG wing decomposition on a device mesh.

    ``engine="beindex"``: link-sharded CD rounds (two psums;
    ``bloom_aligned=True`` uses the one-psum §Perf variant) + link-packed
    FD.  ``engine="csr"``: wedge-sharded CD rounds + wedge-packed FD —
    O(Σ deg²) memory end to end, no BE-Index built;
    ``pair_aligned=True`` shards wedges pair-aligned (all of a pair's
    wedges on one device) so the dying-count reduction c_p is
    shard-local and CD pays ONE psum per round instead of two.  FD is
    communication-free either way.

    ``aligned`` is the entity-agnostic spelling of the one-psum layout
    (the flag ``launch/peel.py`` passes for both tip and wing): it maps
    to ``pair_aligned`` for csr and ``bloom_aligned`` for beindex.

    Returns ``(theta, stats)`` — ``return_result=True`` appends the full
    :class:`~repro.core.peelspec.PeelResult` (partition provenance for
    the hierarchy serializer).

    Example (8 forced host devices)::

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        theta, stats = distributed_wing_decomposition(
            g, mesh, engine="csr", pair_aligned=True)
    """
    if engine not in ("beindex", "csr"):
        raise ValueError(engine)
    if aligned is not None:
        if engine == "csr":
            pair_aligned = aligned
        else:
            bloom_aligned = aligned
    if pair_aligned and engine != "csr":
        raise ValueError(
            "pair_aligned shards the wedge list: csr engine only "
            "(the beindex analogue is bloom_aligned)"
        )
    if engine == "csr":
        if bloom_aligned or be is not None:
            raise ValueError(
                "engine='csr' builds no BE-Index: bloom_aligned/be "
                "only apply to engine='beindex'"
            )
        return _distributed_wing_csr(
            g, mesh, axis, P_parts, pair_aligned=pair_aligned,
            return_result=return_result)
    if be is None:
        be = build_beindex(g)
    m = g.m
    n_dev = mesh.devices.size
    if bloom_aligned:
        packed = shard_links_bloom_aligned(be, m, n_dev)
        round_fn = make_cd_round_bloom(mesh, axis, packed["Bmax"], m)
        bl_alive = jnp.asarray(packed["alive"])
        bl_k = jnp.asarray(packed["k0"])
        bl_le = jnp.asarray(packed["le"])
        bl_lt = jnp.asarray(packed["lt"])
        bl_lb = jnp.asarray(packed["lb"])
        support = jnp.asarray(be.edge_support(m).astype(np.int32))
        st = None
    else:
        st = shard_links(be, m, n_dev)
        round_fn = make_cd_round(mesh, axis, st.nb, m)
        support = st.support

    def step(active: np.ndarray) -> np.ndarray:
        nonlocal st, support, bl_alive, bl_k
        if bloom_aligned:
            peeled_pad = jnp.concatenate(
                [jnp.asarray(active), jnp.zeros((1,), bool)])
            support_pad = jnp.concatenate(
                [support, jnp.zeros((1,), jnp.int32)])
            bl_alive, bl_k, support_pad = round_fn(
                peeled_pad, bl_alive, bl_k, support_pad,
                bl_le, bl_lt, bl_lb)
            support = support_pad[:-1]
            return np.asarray(support).astype(np.int64)
        st = cd_round_sharded(round_fn, st, jnp.asarray(active))
        return np.asarray(st.support).astype(np.int64)

    stats = PeelStats(engine="beindex", fd_driver="device")
    sup0 = np.asarray(support).astype(np.int64)
    spec = PeelSpec(
        kind="wing", n=m, sup0=sup0,
        workload=lambda s: np.maximum(s, 1), est=lambda s: s,
        cd_step=step,
    )
    with obs.span("cd", cat="cd"):
        part, sup_init, ranges, n_parts = cd_loop(
            spec, P_parts, stats,
            target=FixedTarget(float(sup0.sum()), P_parts))

    with obs.span("fd", cat="fd", driver="sharded") as sp:
        packed = pack_fd_partitions(g, be, part, sup_init, n_parts)
        theta_loc, rounds = fd_peel_sharded(packed, mesh, axis)
        if sp is not None:
            sp.update(rounds=int(rounds.sum()))
    theta = np.zeros(m, dtype=np.int64)
    _scatter_theta(theta, packed, theta_loc, n_parts)
    stats.rho_fd_total = int(rounds.sum())
    stats.rho_fd_max = int(rounds.max()) if rounds.size else 0
    _record_fd_sharded(n_parts, rounds)
    return _finish(
        theta, part, ranges, sup_init, stats,
        dict(n_parts=n_parts, n_links=be.n_links, n_dev=int(n_dev)),
        return_result)


def _distributed_wing_csr(
    g: BipartiteGraph, mesh: Mesh, axis: str | Tuple[str, ...], P_parts: int,
    pair_aligned: bool = False, return_result: bool = False,
):
    """csr engine on a mesh: wedge-sharded CD + wedge-packed FD.

    ``pair_aligned`` swaps the round-robin wedge padding for the
    pair-aligned layout (one psum per CD round instead of two)."""
    wed = csr.build_wedges(g)
    m = g.m
    n_dev = int(mesh.devices.size)
    if pair_aligned:
        packed = shard_wedges_pair_aligned(wed, n_dev)
        round_fn = make_cd_round_csr_pair_aligned(
            mesh, axis, packed["Pmax"], m)
        pa_alive = jnp.asarray(packed["alive"])
        pa_W = jnp.asarray(packed["W0"])
        pa_we1 = jnp.asarray(packed["we1"])
        pa_we2 = jnp.asarray(packed["we2"])
        pa_wp = jnp.asarray(packed["wp"])
        sup0 = csr.edge_butterflies0(wed)
        if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
            raise OverflowError(
                "wing supports exceed int32; shard the graph")
        support = jnp.asarray(sup0.astype(np.int32))
        st = None
    else:
        st = shard_wedges(wed, n_dev)
        round_fn = make_cd_round_csr(mesh, axis, st.n_pairs, m)
        support = st.support

    def step(active: np.ndarray) -> np.ndarray:
        nonlocal st, support, pa_alive, pa_W
        if pair_aligned:
            peeled_pad = jnp.concatenate(
                [jnp.asarray(active), jnp.zeros((1,), bool)])
            support_pad = jnp.concatenate(
                [support, jnp.zeros((1,), jnp.int32)])
            pa_alive, pa_W, support_pad = round_fn(
                peeled_pad, pa_alive, pa_W, support_pad,
                pa_we1, pa_we2, pa_wp)
            support = support_pad[:-1]
            return np.asarray(support).astype(np.int64)
        st = cd_round_sharded_csr(round_fn, st, jnp.asarray(active))
        return np.asarray(st.support).astype(np.int64)

    stats = PeelStats(engine="csr", fd_driver="device")
    sup0_np = np.asarray(support).astype(np.int64)
    spec = PeelSpec(
        kind="wing", n=m, sup0=sup0_np,
        workload=lambda s: np.maximum(s, 1), est=lambda s: s,
        cd_step=step,
    )
    with obs.span("cd", cat="cd"):
        part, sup_init, ranges, n_parts = cd_loop(
            spec, P_parts, stats,
            target=FixedTarget(float(sup0_np.sum()), P_parts))

    with obs.span("fd", cat="fd", driver="sharded") as sp:
        packed = pack_fd_partitions_csr(wed, part, sup_init, n_parts)
        theta_loc, rounds = fd_peel_sharded_csr(packed, mesh, axis)
        if sp is not None:
            sp.update(rounds=int(rounds.sum()))
    theta = np.zeros(m, dtype=np.int64)
    _scatter_theta(theta, packed, theta_loc, n_parts)
    stats.rho_fd_total = int(rounds.sum())
    stats.rho_fd_max = int(rounds.max()) if rounds.size else 0
    _record_fd_sharded(n_parts, rounds)
    return _finish(
        theta, part, ranges, sup_init, stats,
        dict(cd_sharding="pair_aligned" if pair_aligned else "wedge",
             n_parts=n_parts, n_wedges=wed.n_wedges,
             n_pairs=wed.n_pairs, n_dev=n_dev),
        return_result)


# =====================================================================
# Distributed TIP decomposition (vertex peeling, §3.2)
# =====================================================================
def make_tip_cd_recount(mesh: Mesh, axis: str | Tuple[str, ...], n: int, n_dev: int):
    """Jitted row-sharded tip batch re-count; returns (fn, rows/shard).

    The dense-engine fallback: shard the *row blocks* of the wedge
    matrix across devices; each device re-counts butterflies for its
    vertex shard (A gathered per round — O(n²) work and memory, which
    is exactly why ``engine="csr"`` is the default)."""
    blk = -(-n // n_dev)

    def body(A_pad, alive_pad, shard_idx):
        # per-shard: A_pad [blk, nv], alive [blk], idx [1]
        row0 = shard_idx[0] * blk
        A_full = jax.lax.all_gather(A_pad, axis, axis=0, tiled=True)
        alive_full = jax.lax.all_gather(alive_pad, axis, axis=0, tiled=True)
        Am = A_full * alive_full[:, None]
        W = jax.lax.dot(A_pad * alive_pad[:, None], Am.T,
                        precision=jax.lax.Precision.HIGHEST)
        rows = row0 + jnp.arange(A_pad.shape[0])
        cols = jnp.arange(A_full.shape[0])
        W = jnp.where(rows[:, None] == cols[None, :], 0.0, W)
        return jnp.sum(W * (W - 1.0) * 0.5, axis=1)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    return jax.jit(fn), blk


def _tip_fd_kernel(A_i, mine, sup0):
    """Peel one dense tip partition bottom-up — the shared device FD
    driver with the static pairwise-butterfly matvec update: one
    while_loop, no collectives.

    A_i: [Umax, nv] rows of this partition (zero-padded), mine [Umax],
    sup0 [Umax].  Pairwise butterflies are static (V never peeled)."""
    W = jax.lax.dot(A_i, A_i.T, precision=jax.lax.Precision.HIGHEST)
    Umax = W.shape[0]
    W = W * (1.0 - jnp.eye(Umax, dtype=W.dtype))
    pair_bf = W * (W - 1.0) * 0.5

    def update(S, aux):
        loss = jnp.rint(pair_bf @ S.astype(jnp.float32)).astype(jnp.int32)
        return loss, aux, jnp.int32(0)

    theta, rounds, _ = _fd_while_device(
        mine, jnp.rint(sup0).astype(jnp.int32), update, jnp.int32(0))
    return theta, rounds


@_with_obs("tip")
def distributed_tip_decomposition(
    g: BipartiteGraph,
    mesh: Mesh,
    axis: str | Tuple[str, ...] = "peel",
    side: str = "u",
    P_parts: int = 8,
    engine: str = "csr",
    aligned: bool = False,
    fd_driver: str = "device",
    return_result: bool = False,
):
    """Full PBNG tip decomposition on a device mesh.

    ``engine="csr"`` (default): wedge-list CD — the directed
    pair-incidence list is sharded (``aligned=True`` keeps ALL of a
    vertex's entries on one device via the generalized greedy balance)
    and every round pays exactly ONE psum (pair butterflies are static,
    so there is no dying-count collective at all); FD stacks the
    disjoint per-partition pair lists and peels under ``shard_map`` with
    zero collectives (``fd_driver="device"``), or in ONE batched
    single-dispatch while_loop (``fd_driver="vmapped"``).  O(Σ deg²)
    memory end to end — the path that opens the largest-graph tip
    workloads.

    ``engine="dense"``: the explicit O(n²) fallback — row-sharded
    masked-matmul re-counts for CD, stacked matmul-cascade partitions
    for FD.  Kept for machines where the wedge list is the bigger
    allocation (near-complete bipartite cores); refuses nothing but
    memory.

    θ is bit-identical across both engines and to the single-device
    oracle.  Returns ``(theta, stats)``; ``return_result=True`` appends
    the full :class:`~repro.core.peelspec.PeelResult` (partition
    provenance for the hierarchy serializer).

    Example (8 forced host devices)::

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        theta, stats = distributed_tip_decomposition(
            g, mesh, side="u", engine="csr", aligned=True)
    """
    if engine not in ("csr", "dense"):
        raise ValueError(engine)
    if fd_driver not in ("device", "vmapped"):
        raise ValueError(fd_driver)
    if engine == "dense" and (aligned or fd_driver != "device"):
        raise ValueError(
            "aligned / fd_driver='vmapped' need the wedge list: "
            "engine='csr' only")
    gg = g if side == "u" else g.transpose()
    if engine == "csr":
        return _distributed_tip_csr(
            gg, mesh, axis, side, P_parts, aligned=aligned,
            fd_driver=fd_driver, return_result=return_result)
    return _distributed_tip_dense(
        gg, mesh, axis, side, P_parts, return_result=return_result)


def _distributed_tip_csr(
    gg: BipartiteGraph, mesh: Mesh, axis: str | Tuple[str, ...], side: str, P_parts: int,
    aligned: bool = False, fd_driver: str = "device",
    return_result: bool = False,
):
    """csr tip on a mesh: one-psum pair-incidence CD + stacked pair FD."""
    wed = csr.build_wedges(gg)
    n = gg.n_u
    n_dev = int(mesh.devices.size)
    pair_bf0 = wed.pair_butterflies0()
    sup0 = csr.vertex_butterflies_csr(wed)
    if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
        raise OverflowError("tip supports exceed int32; shard the graph")
    wu, _ = csr.wedge_workload(gg)
    wedge_w = wu.astype(np.float64)

    blocks = shard_tip_pairs(wed, pair_bf0, n_dev, aligned=aligned)
    round_fn = make_cd_round_tip_csr(mesh, axis, n)
    dst = jnp.asarray(blocks["dst"])
    src = jnp.asarray(blocks["src"])
    bf = jnp.asarray(blocks["bf"])
    state = dict(support=jnp.asarray(sup0.astype(np.int32)))

    def step(active: np.ndarray) -> np.ndarray:
        peeled_pad = jnp.concatenate(
            [jnp.asarray(active), jnp.zeros((1,), bool)])
        support_pad = jnp.concatenate(
            [state["support"], jnp.zeros((1,), jnp.int32)])
        support_pad = round_fn(peeled_pad, support_pad, dst, src, bf)
        state["support"] = support_pad[:-1]
        return np.asarray(state["support"]).astype(np.int64)

    stats = PeelStats(engine="csr", fd_driver=fd_driver, side=side)
    # same ≥1 workload clamp as the dense distributed path so the two
    # engines pick identical range boundaries (stats comparability)
    spec = PeelSpec(
        kind="tip", n=n, sup0=sup0,
        workload=lambda s: np.maximum(wedge_w, 1),
        est=lambda s: wedge_w,
        cd_step=step,
    )
    with obs.span("cd", cat="cd"):
        part, sup_init, ranges, n_parts = cd_loop(
            spec, P_parts, stats,
            target=FixedTarget(float(wedge_w.sum()), P_parts))

    theta = np.zeros(n, dtype=np.int64)
    if n_parts:
        with obs.span("fd", cat="fd", driver=fd_driver) as sp:
            if fd_driver == "vmapped":
                from .peel import _tip_fd_vmapped_csr

                # the vmapped wrapper drains its own counter rings
                rounds = _tip_fd_vmapped_csr(
                    wed, pair_bf0, part, sup_init, theta, n_parts)
            else:
                packed = pack_fd_partitions_tip_csr(
                    wed, pair_bf0, part, sup_init, n_parts, stacked=True)
                theta_loc, rounds = fd_peel_sharded_tip_csr(
                    packed, mesh, axis)
                _scatter_theta(theta, packed, theta_loc, n_parts)
                _record_fd_sharded(n_parts, rounds)
            if sp is not None:
                sp.update(rounds=int(np.asarray(rounds).sum()))
        stats.rho_fd_total = int(np.asarray(rounds).sum())
        stats.rho_fd_max = int(np.asarray(rounds).max())
    return _finish(
        theta, part, ranges, sup_init, stats,
        dict(cd_sharding="vertex_aligned" if aligned else "pair",
             n_parts=n_parts, n_wedges=wed.n_wedges,
             n_pairs=wed.n_pairs, n_dev=n_dev),
        return_result)


def _distributed_tip_dense(
    gg: BipartiteGraph, mesh: Mesh, axis: str | Tuple[str, ...], side: str, P_parts: int,
    return_result: bool = False,
):
    """Dense tip on a mesh: row-sharded masked-matmul re-counts for CD,
    stacked matmul-cascade partitions for FD — the explicit O(n²)
    fallback behind ``engine="dense"``."""
    from . import counting

    n, nv = gg.n_u, gg.n_v
    n_dev = int(mesh.devices.size)
    A_np = gg.adjacency()
    recount_fn, blk = make_tip_cd_recount(mesh, axis, n, n_dev)
    n_pad = blk * n_dev
    A = jnp.asarray(np.pad(A_np, ((0, n_pad - n), (0, 0))))
    shard_idx = jnp.arange(n_dev, dtype=jnp.int32)

    alive_pad = np.ones(n_pad, bool)
    alive_pad[n:] = False
    sup0 = np.rint(np.asarray(
        recount_fn(A, jnp.asarray(alive_pad), shard_idx))).astype(
            np.int64)[:n]
    wedge_w = np.rint(np.asarray(
        counting.vertex_wedge_workload(jnp.asarray(A_np)))).astype(np.int64)

    def step(active: np.ndarray) -> np.ndarray:
        alive_pad[:n] &= ~active
        sup = np.rint(np.asarray(recount_fn(
            A, jnp.asarray(alive_pad), shard_idx))).astype(np.int64)
        return sup[:n]

    stats = PeelStats(engine="dense", fd_driver="device", side=side)
    # range-selection weights clamp to ≥1 (as pre-refactor) so
    # zero-wedge vertices still advance the cumulative-workload scan
    spec = PeelSpec(
        kind="tip", n=n, sup0=sup0,
        workload=lambda s: np.maximum(wedge_w, 1),
        est=lambda s: wedge_w,
        cd_step=step,
    )
    with obs.span("cd", cat="cd"):
        part, sup_init, ranges, n_parts = cd_loop(
            spec, P_parts, stats,
            target=FixedTarget(float(wedge_w.sum()), P_parts))

    # ---- FD: stack padded partitions, shard over devices
    rows_per = [np.where(part == i)[0] for i in range(n_parts)]
    Umax = max(max((r.size for r in rows_per), default=1), 1)
    pad_parts = -(-max(n_parts, 1) // n_dev) * n_dev
    A_st = np.zeros((pad_parts, Umax, nv), np.float32)
    mine = np.zeros((pad_parts, Umax), bool)
    sup_st = np.zeros((pad_parts, Umax), np.float32)
    gids = np.zeros((pad_parts, Umax), np.int64)
    for i, r in enumerate(rows_per):
        A_st[i, : r.size] = A_np[r]
        mine[i, : r.size] = True
        sup_st[i, : r.size] = sup_init[r]
        gids[i, : r.size] = r
    vk = jax.vmap(_tip_fd_kernel)
    fd = shard_map(
        vk, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    with obs.span("fd", cat="fd", driver="sharded") as sp:
        theta_st, rounds = jax.jit(fd)(
            jnp.asarray(A_st), jnp.asarray(mine), jnp.asarray(sup_st))
        if sp is not None:
            sp.update(rounds=int(np.asarray(rounds)[:n_parts].sum()))
    theta_st = np.asarray(theta_st).astype(np.int64)
    theta = np.zeros(n, np.int64)
    _scatter_theta(theta, dict(mine=mine, gids=gids), theta_st, n_parts)
    rounds = np.asarray(rounds)[:n_parts]
    stats.rho_fd_total = int(rounds.sum())
    stats.rho_fd_max = int(rounds.max()) if n_parts else 0
    _record_fd_sharded(n_parts, rounds)
    return _finish(
        theta, part, ranges, sup_init, stats,
        dict(n_parts=n_parts, n_dev=n_dev),
        return_result)
