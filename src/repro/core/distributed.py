"""Distributed PBNG — shard_map peeling for multi-device meshes.

Maps the paper's two phases onto an SPMD mesh:

* **CD** (coarse): the BE-Index *links* are sharded across devices; each
  round every device computes its partial bloom-death counts and per-edge
  losses with ``segment_sum`` and a single ``psum`` combines them.  One
  collective per peeling round — the JAX statement of "little
  synchronization".  Supports / frontier masks are replicated (O(m), tiny
  next to the index).

* **FD** (fine): partitions are padded to a common size, stacked on a
  leading axis and `shard_map`-ped over the ``peel`` mesh axis.  The
  per-partition while_loop contains **no collectives at all** — the HLO
  proves the paper's "no global synchronization" claim structurally.

Used by ``launch/peel.py`` for the production-mesh dry-run and by the
multi-device tests (spawned with forced host device counts).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..sharding.compat import shard_map
from . import csr
from .beindex import BEIndex, build_beindex
from .graph import BipartiteGraph

__all__ = [
    "ShardedWingState",
    "ShardedCSRState",
    "shard_links",
    "shard_wedges",
    "cd_round_sharded",
    "cd_round_sharded_csr",
    "make_cd_round_csr",
    "pack_fd_partitions",
    "pack_fd_partitions_csr",
    "fd_peel_sharded",
    "fd_peel_sharded_csr",
    "distributed_wing_decomposition",
    "distributed_tip_decomposition",
]


# =====================================================================
# CD — link-sharded rounds, one psum per round
# =====================================================================
@dataclasses.dataclass
class ShardedWingState:
    le: jax.Array          # (L_pad,) link -> edge, sharded
    lt: jax.Array          # (L_pad,) link -> twin
    lb: jax.Array          # (L_pad,) link -> bloom
    alive_link: jax.Array  # (L_pad,) sharded
    k_alive: jax.Array     # (nb,) replicated
    support: jax.Array     # (m,) replicated
    nb: int
    m: int


def shard_links(be: BEIndex, m: int, n_dev: int) -> ShardedWingState:
    """Pad link arrays to a multiple of n_dev.  Pad links point at a
    sentinel dead bloom/edge and start dead."""
    L = be.n_links
    pad = (-L) % max(n_dev, 1)
    def padded(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])
    le = padded(be.link_edge, m)        # sentinel edge m
    lt = padded(be.link_twin, m)
    lb = padded(be.link_bloom, be.nb)   # sentinel bloom nb
    alive = np.concatenate([np.ones(L, bool), np.zeros(pad, bool)])
    return ShardedWingState(
        le=jnp.asarray(le), lt=jnp.asarray(lt), lb=jnp.asarray(lb),
        alive_link=jnp.asarray(alive),
        k_alive=jnp.asarray(be.bloom_k.astype(np.int32)),
        support=jnp.asarray(be.edge_support(m).astype(np.int32)),
        nb=be.nb, m=m,
    )


def _cd_round_body(peeled_pad, alive_link, k_alive, support_pad,
                   le, lt, lb, *, nb: int, m: int, axis: str):
    """Runs per-shard under shard_map; one psum for c, one for loss."""
    pe = peeled_pad[le]
    pt = peeled_pad[lt]
    pair_dies = alive_link & (pe | pt)
    canon = le < lt
    c_local = jax.ops.segment_sum(
        (pair_dies & canon).astype(jnp.int32), lb, num_segments=nb + 1
    )
    c = jax.lax.psum(c_local, axis)
    widow = alive_link & ~pe & pt
    surv = alive_link & ~pair_dies
    contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(surv, c[lb], 0)
    loss_local = jax.ops.segment_sum(contrib, le, num_segments=m + 1)
    loss = jax.lax.psum(loss_local, axis)
    support_pad = support_pad - loss
    k_alive = k_alive - c[:nb]
    alive_link = alive_link & ~pair_dies
    return alive_link, k_alive, support_pad


def make_cd_round(mesh: Mesh, axis: str, nb: int, m: int):
    """Build the jitted, shard_map-ped CD round for a given mesh."""
    body = partial(_cd_round_body, nb=nb, m=m, axis=axis)
    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_r, spec_r),
    )
    return jax.jit(fn)


def cd_round_sharded(round_fn, st: ShardedWingState, peeled: jax.Array
                     ) -> ShardedWingState:
    """One CD peeling round. ``peeled`` is the (m,) frontier mask."""
    peeled_pad = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
    support_pad = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    alive_link, k_alive, support_pad = round_fn(
        peeled_pad, st.alive_link, st.k_alive, support_pad,
        st.le, st.lt, st.lb,
    )
    return dataclasses.replace(
        st, alive_link=alive_link, k_alive=k_alive, support=support_pad[:-1]
    )


# =====================================================================
# CD variant — bloom-aligned link sharding (§Perf optimization)
# =====================================================================
# Baseline CD needs TWO psums per round: dying-pair counts c_B (blooms
# straddle shards) then per-edge losses.  If every bloom's links live on
# ONE shard, c_B and k_alive become shard-local state and a round costs
# a single psum (the loss) — half the collectives, and bloom bookkeeping
# never crosses the interconnect.
def shard_links_bloom_aligned(be: BEIndex, m: int, n_dev: int) -> dict:
    order = np.argsort(be.link_bloom, kind="stable")
    le, lt, lb = (be.link_edge[order], be.link_twin[order],
                  be.link_bloom[order])
    counts = np.bincount(lb, minlength=be.nb)
    # greedy balance blooms over shards by link count (LPT-flavoured)
    shard_of = np.zeros(be.nb, dtype=np.int64)
    load = np.zeros(n_dev, dtype=np.int64)
    for bid in np.argsort(-counts, kind="stable"):
        s = int(np.argmin(load))
        shard_of[bid] = s
        load[s] += counts[bid]
    Lmax = int(load.max()) if n_dev else 1
    Lmax = max(Lmax, 1)
    # local bloom ids per shard
    nb_local = np.zeros(n_dev, dtype=np.int64)
    loc_bloom = np.zeros(be.nb, dtype=np.int64)
    for bid in range(be.nb):
        s = shard_of[bid]
        loc_bloom[bid] = nb_local[s]
        nb_local[s] += 1
    Bmax = max(int(nb_local.max()), 1)

    le_s = np.full((n_dev, Lmax), m, np.int32)
    lt_s = np.full((n_dev, Lmax), m, np.int32)
    lb_s = np.full((n_dev, Lmax), Bmax, np.int32)
    alive = np.zeros((n_dev, Lmax), bool)
    k0 = np.zeros((n_dev, Bmax), np.int32)
    fill = np.zeros(n_dev, dtype=np.int64)
    off = np.zeros(be.nb + 1, dtype=np.int64)
    np.cumsum(counts, out=off[1:])
    for bid in range(be.nb):
        s = shard_of[bid]
        n = counts[bid]
        a, b = off[bid], off[bid + 1]
        f = fill[s]
        le_s[s, f: f + n] = le[a:b]
        lt_s[s, f: f + n] = lt[a:b]
        lb_s[s, f: f + n] = loc_bloom[bid]
        alive[s, f: f + n] = True
        k0[s, loc_bloom[bid]] = be.bloom_k[bid]
        fill[s] += n
    return dict(le=le_s, lt=lt_s, lb=lb_s, alive=alive, k0=k0,
                Bmax=Bmax, m=m)


def make_cd_round_bloom(mesh: Mesh, axis: str, Bmax: int, m: int):
    """One-psum CD round over bloom-aligned shards."""

    def body(peeled_pad, alive_link, k_alive, support_pad, le, lt, lb):
        # all per-shard [1, ...] blocks (leading shard axis split)
        pe = peeled_pad[le]
        pt = peeled_pad[lt]
        pair_dies = alive_link & (pe | pt)
        canon = le < lt
        c = jax.ops.segment_sum(
            (pair_dies & canon).astype(jnp.int32).reshape(-1),
            lb.reshape(-1), num_segments=Bmax + 1)  # LOCAL — no psum
        widow = alive_link & ~pe & pt
        surv = alive_link & ~pair_dies
        contrib = jnp.where(widow, k_alive.reshape(-1)[lb] - 1, 0) \
            + jnp.where(surv, c[lb], 0)
        loss = jax.ops.segment_sum(
            contrib.reshape(-1), le.reshape(-1), num_segments=m + 1)
        loss = jax.lax.psum(loss, axis)          # the ONLY collective
        support_pad = support_pad - loss
        k_alive = k_alive - c[:Bmax].reshape(k_alive.shape)
        alive_link = alive_link & ~pair_dies
        return alive_link, k_alive, support_pad

    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_l, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_l, spec_r),
    )
    return jax.jit(fn)


# =====================================================================
# CD — wedge-sharded rounds for the csr engine (no BE-Index anywhere)
# =====================================================================
# Same two-psums-per-round structure as the link-sharded beindex CD, but
# the sharded unit is the flat wedge list (``core.csr.Wedges``): pairs
# play the role of blooms, per-pair alive wedge counts W_p the role of
# bloom numbers.  This is the only CD that scales with O(Σ deg²) memory
# — the engine that survives past the dense wall also shards.
@dataclasses.dataclass
class ShardedCSRState:
    we1: jax.Array         # (L_pad,) wedge -> edge 1, sharded (sentinel m)
    we2: jax.Array         # (L_pad,) wedge -> edge 2
    wp: jax.Array          # (L_pad,) wedge -> pair (sentinel n_pairs)
    alive_w: jax.Array     # (L_pad,) sharded
    W_pad: jax.Array       # (n_pairs+1,) replicated — alive wedges/pair
    support: jax.Array     # (m,) replicated
    n_pairs: int
    m: int


def shard_wedges(wed: csr.Wedges, n_dev: int) -> ShardedCSRState:
    """Pad the wedge list to a multiple of n_dev.  Pad wedges point at
    the sentinel edge m / pair n_pairs and start dead."""
    L = wed.n_wedges
    m = wed.m
    n_pairs = wed.n_pairs
    pad = (-L) % max(n_dev, 1)
    if L + pad == 0:
        pad = max(n_dev, 1)

    def padded(x, fill):
        return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])

    sup0 = csr.edge_butterflies0(wed)
    if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
        raise OverflowError("wing supports exceed int32; shard the graph")
    W_pad = np.zeros(n_pairs + 1, dtype=np.int32)
    W_pad[:n_pairs] = wed.W0.astype(np.int32)
    return ShardedCSRState(
        we1=jnp.asarray(padded(wed.wedge_e1, m)),
        we2=jnp.asarray(padded(wed.wedge_e2, m)),
        wp=jnp.asarray(padded(wed.wedge_pair, n_pairs)),
        alive_w=jnp.asarray(
            np.concatenate([np.ones(L, bool), np.zeros(pad, bool)])),
        W_pad=jnp.asarray(W_pad),
        support=jnp.asarray(sup0.astype(np.int32)),
        n_pairs=n_pairs, m=m,
    )


def _cd_round_body_csr(peeled_pad, alive_w, W_pad, support_pad,
                       we1, we2, wp, *, n_pairs: int, m: int, axis: str):
    """Per-shard csr CD round (wing_loss_csr algebra + two psums)."""
    pe1 = peeled_pad[we1]
    pe2 = peeled_pad[we2]
    w_dies = alive_w & (pe1 | pe2)
    c_local = jax.ops.segment_sum(
        w_dies.astype(jnp.int32), wp, num_segments=n_pairs + 1
    )
    c = jax.lax.psum(c_local, axis)
    surv = alive_w & ~w_dies
    surv_loss = jnp.where(surv, c[wp], 0)
    loss_local = (
        jax.ops.segment_sum(
            jnp.where(w_dies & ~pe1, W_pad[wp] - 1, 0) + surv_loss,
            we1, num_segments=m + 1)
        + jax.ops.segment_sum(
            jnp.where(w_dies & ~pe2, W_pad[wp] - 1, 0) + surv_loss,
            we2, num_segments=m + 1)
    )
    loss = jax.lax.psum(loss_local, axis)
    return alive_w & ~w_dies, W_pad - c, support_pad - loss


def make_cd_round_csr(mesh: Mesh, axis: str, n_pairs: int, m: int):
    """Build the jitted, shard_map-ped csr CD round for a given mesh."""
    body = partial(_cd_round_body_csr, n_pairs=n_pairs, m=m, axis=axis)
    spec_l = P(axis)
    spec_r = P()
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_r, spec_l, spec_r, spec_r, spec_l, spec_l, spec_l),
        out_specs=(spec_l, spec_r, spec_r),
    )
    return jax.jit(fn)


def cd_round_sharded_csr(round_fn, st: ShardedCSRState, peeled: jax.Array
                         ) -> ShardedCSRState:
    """One csr CD peeling round. ``peeled`` is the (m,) frontier mask."""
    peeled_pad = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
    support_pad = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    alive_w, W_pad, support_pad = round_fn(
        peeled_pad, st.alive_w, st.W_pad, support_pad,
        st.we1, st.we2, st.wp,
    )
    return dataclasses.replace(
        st, alive_w=alive_w, W_pad=W_pad, support=support_pad[:-1]
    )


# =====================================================================
# FD — partition-stacked, communication-free shard_map
# =====================================================================
def pack_fd_partitions(
    g: BipartiteGraph, be: BEIndex, part: np.ndarray, sup_init: np.ndarray,
    n_parts: int, pad_to: Optional[int] = None,
) -> dict:
    """Build [n_parts_padded, ...] stacked local sub-indices (alg.5).

    Local ids per partition; twins outside the partition map to a
    sentinel never-peeled slot.  Everything padded so partitions stack.
    """
    ple = part[be.link_edge]
    plt_ = part[be.link_twin]
    canon_full = be.link_edge < be.link_twin
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(g.m, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        pair_ge = (ple >= i) & (plt_ >= i)
        # only links anchored at a local (peelable) edge; cross-partition
        # pairs therefore appear exactly once
        keep = pair_ge & (ple == i)
        k_init = np.zeros(be.nb, dtype=np.int64)
        np.add.at(k_init, be.link_bloom[pair_ge & canon_full], 1)
        kl_e, kl_t, kl_b = (be.link_edge[keep], be.link_twin[keep],
                            be.link_bloom[keep])
        twin_local = part[kl_t] == i
        # count each dying pair once: both-local pairs via id order,
        # cross pairs via their single link
        canon = np.where(twin_local, kl_e < kl_t, True)
        blooms = np.unique(kl_b)
        bloc = np.full(be.nb + 1, 0, dtype=np.int64)
        if blooms.size:
            bloc[blooms] = np.arange(blooms.size)
        per.append(dict(
            edges=mine_idx,
            le=loc[kl_e], lt=np.where(twin_local, loc[kl_t], -1),
            lb=bloc[kl_b], canon=canon,
            k0=k_init[blooms],
            sup0=sup_init[mine_idx],
        ))
    Lmax = max((p["le"].size for p in per), default=1) or 1
    Emax = max((p["edges"].size for p in per), default=1) or 1
    Bmax = max((p["k0"].size for p in per), default=1) or 1
    if pad_to:
        Lmax, Emax, Bmax = (max(Lmax, pad_to), max(Emax, pad_to),
                            max(Bmax, pad_to))

    def pk(key, size, fill, dtype=np.int32):
        out = np.full((n_parts, size), fill, dtype=dtype)
        for i, p in enumerate(per):
            x = p[key]
            out[i, : x.size] = x
        return out

    # sentinel local edge id = Emax (extra never-peeled slot)
    le = pk("le", Lmax, Emax)
    lt = np.where(pk("lt", Lmax, -1) < 0, Emax,
                  pk("lt", Lmax, -1)).astype(np.int32)
    canon = pk("canon", Lmax, 0, dtype=bool)
    alive0 = np.zeros((n_parts, Lmax), dtype=bool)
    for i, p in enumerate(per):
        alive0[i, : p["le"].size] = True
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    for i, p in enumerate(per):
        mine[i, : p["edges"].size] = True
        sup0[i, : p["edges"].size] = p["sup0"]
        gids[i, : p["edges"].size] = p["edges"]
    k0 = pk("k0", Bmax, 0)
    return dict(
        le=le, lt=lt, lb=pk("lb", Lmax, Bmax - 1), alive0=alive0,
        canon=canon, k0=k0, sup0=sup0, mine=mine, gids=gids,
        sizes=(Lmax, Emax, Bmax),
    )


def _fd_body_one_partition(le, lt, lb, alive0, canon, k0, sup0, mine):
    """Peel one partition bottom-up — pure lax.while_loop, NO collectives."""
    Emax = mine.shape[0]
    Bmax = k0.shape[0]
    BIG = jnp.iinfo(jnp.int32).max  # >= any guarded support

    def update(peeled, alive_link, k_alive, support):
        pe = jnp.concatenate([peeled, jnp.zeros((1,), bool)])
        p_e = pe[le]
        p_t = pe[lt]
        pair_dies = alive_link & (p_e | p_t)
        c = jax.ops.segment_sum(
            (pair_dies & canon).astype(jnp.int32), lb, num_segments=Bmax)
        widow = alive_link & ~p_e & p_t
        surv = alive_link & ~pair_dies
        contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(
            surv, c[lb], 0)
        loss = jax.ops.segment_sum(contrib, le, num_segments=Emax + 1)[:-1]
        return (alive_link & ~pair_dies, k_alive - c, support - loss)

    def cond(state):
        alive_e, *_ = state
        return jnp.any(alive_e)

    def body(state):
        alive_e, alive_link, k_alive, support, theta, k, rounds = state
        cur = jnp.where(alive_e, support, BIG)
        k = jnp.maximum(k, jnp.min(cur))
        S = alive_e & (support <= k)
        # S is non-empty whenever alive_e is (k >= min alive support)
        theta = jnp.where(S, k, theta)
        alive_e = alive_e & ~S
        alive_link, k_alive, support = update(S, alive_link, k_alive, support)
        return (alive_e, alive_link, k_alive, support, theta, k, rounds + 1)

    # derive loop-constant inits from varying inputs so the carry's
    # manual-axes annotation is stable under shard_map
    zero_e = mine.astype(jnp.int32) * 0
    zero_s = jnp.min(zero_e)
    init = (
        mine, alive0, k0.astype(jnp.int32), sup0.astype(jnp.int32),
        zero_e, zero_s, zero_s,
    )
    alive_e, _, _, _, theta, _, rounds = jax.lax.while_loop(cond, body, init)
    return theta, rounds


def _fd_run_sharded(body, packed: dict, keys: Tuple[str, ...],
                    mesh: Mesh, axis: str) -> Tuple[np.ndarray, np.ndarray]:
    """Shared FD launcher: pad the partition axis to the device count,
    shard_map the vmapped per-partition body, trim the results."""
    n_parts = packed[keys[0]].shape[0]
    n_dev = mesh.devices.size
    pad = (-n_parts) % n_dev

    def padp(x):
        if pad == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], axis=0))

    args = tuple(padp(packed[k]) for k in keys)
    fn = shard_map(
        jax.vmap(body), mesh=mesh,
        in_specs=tuple(P(axis) for _ in args),
        out_specs=(P(axis), P(axis)),
    )
    theta, rounds = jax.jit(fn)(*args)
    return np.asarray(theta)[:n_parts], np.asarray(rounds)[:n_parts]


def fd_peel_sharded(packed: dict, mesh: Mesh, axis: str
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Peel all partitions concurrently: shard_map over the partition axis
    (device-parallel), vmap within a shard.  Returns (theta[m'], rounds[P])
    in packed local layout."""
    return _fd_run_sharded(
        _fd_body_one_partition, packed,
        ("le", "lt", "lb", "alive0", "canon", "k0", "sup0", "mine"),
        mesh, axis,
    )


# =====================================================================
# FD — csr variant: partition-stacked wedge lists, zero collectives
# =====================================================================
def pack_fd_partitions_csr(
    wed: csr.Wedges, part: np.ndarray, sup_init: np.ndarray,
    n_parts: int, pad_to: Optional[int] = None,
) -> dict:
    """Stack per-partition wedge sub-lists into [n_parts, ...] arrays.

    Partition i's sub-structure = wedges with both edges in partitions
    ≥ i (the same induced subgraph the single-device csr FD uses); edge
    ids are partition-local with a sentinel slot Emax for never-peeled
    later-partition edges, pair ids are relabeled per partition.  Same
    sentinel/pad machinery as :func:`pack_fd_partitions`."""
    m = part.size
    pe1 = part[wed.wedge_e1] if wed.n_wedges else np.zeros(0, np.int32)
    pe2 = part[wed.wedge_e2] if wed.n_wedges else np.zeros(0, np.int32)
    per = []
    for i in range(n_parts):
        mine_idx = np.where(part == i)[0]
        loc = np.full(m, -1, dtype=np.int64)
        loc[mine_idx] = np.arange(mine_idx.size)
        keep = (pe1 >= i) & (pe2 >= i)
        kwe1 = wed.wedge_e1[keep]
        kwe2 = wed.wedge_e2[keep]
        pair_ids, wp_loc = np.unique(wed.wedge_pair[keep],
                                     return_inverse=True)
        per.append(dict(
            edges=mine_idx,
            we1=np.where(part[kwe1] == i, loc[kwe1], -1),
            we2=np.where(part[kwe2] == i, loc[kwe2], -1),
            wp=wp_loc,
            W0=np.bincount(wp_loc, minlength=max(pair_ids.size, 1)),
            sup0=sup_init[mine_idx],
        ))
    Lmax = max((p["we1"].size for p in per), default=1) or 1
    Emax = max((p["edges"].size for p in per), default=1) or 1
    Pmax = max((p["W0"].size for p in per), default=1) or 1
    if pad_to:
        Lmax, Emax, Pmax = (max(Lmax, pad_to), max(Emax, pad_to),
                            max(Pmax, pad_to))

    def pk(key, size, fill, dtype=np.int32):
        out = np.full((n_parts, size), fill, dtype=dtype)
        for i, p in enumerate(per):
            x = p[key]
            out[i, : x.size] = x
        return out

    # sentinel local edge id = Emax (extra never-peeled slot); pad wedges
    # carry pair 0 but start dead, so they contribute nothing
    w1 = pk("we1", Lmax, -1)
    w2 = pk("we2", Lmax, -1)
    we1 = np.where(w1 < 0, Emax, w1).astype(np.int32)
    we2 = np.where(w2 < 0, Emax, w2).astype(np.int32)
    alive0 = np.zeros((n_parts, Lmax), dtype=bool)
    mine = np.zeros((n_parts, Emax), dtype=bool)
    sup0 = np.zeros((n_parts, Emax), dtype=np.int32)
    gids = np.zeros((n_parts, Emax), dtype=np.int32)
    for i, p in enumerate(per):
        alive0[i, : p["we1"].size] = True
        mine[i, : p["edges"].size] = True
        sup0[i, : p["edges"].size] = p["sup0"]
        gids[i, : p["edges"].size] = p["edges"]
    return dict(
        we1=we1, we2=we2, wp=pk("wp", Lmax, 0), alive0=alive0,
        W0=pk("W0", Pmax, 0), sup0=sup0, mine=mine, gids=gids,
        sizes=(Lmax, Emax, Pmax),
    )


def _fd_body_one_partition_csr(we1, we2, wp, alive0, W0, sup0, mine):
    """Peel one csr partition bottom-up — the shared device FD driver
    (``peel._fd_while_device``): one while_loop, NO collectives."""
    from .peel import _fd_while_device

    Emax = mine.shape[0]
    Pmax = W0.shape[0]

    def update(S, aux):
        alive_w, W = aux
        S_pad = jnp.concatenate([S, jnp.zeros((1,), bool)])
        alive_w, W, loss, _ = csr.wing_loss_csr(
            S_pad, alive_w, W, we1, we2, wp, Pmax, Emax + 1
        )
        return loss[:Emax], (alive_w, W), jnp.int32(0)

    theta, rounds, _ = _fd_while_device(
        mine, sup0.astype(jnp.int32), update,
        (alive0, W0.astype(jnp.int32)),
    )
    return theta, rounds


def fd_peel_sharded_csr(packed: dict, mesh: Mesh, axis: str
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """csr counterpart of :func:`fd_peel_sharded` — shard_map over the
    padded wedge-slot stacks, zero collectives inside partitions."""
    return _fd_run_sharded(
        _fd_body_one_partition_csr, packed,
        ("we1", "we2", "wp", "alive0", "W0", "sup0", "mine"),
        mesh, axis,
    )


# =====================================================================
# End-to-end distributed wing decomposition
# =====================================================================
def _cd_partition_loop(sup_np: np.ndarray, P_parts: int, step):
    """Shared CD driver: range selection + inner peel rounds, engine
    supplied as ``step(active) -> refreshed int64 support``.

    Returns (part, sup_init, rho_cd)."""
    m = sup_np.size
    alive = np.ones(m, dtype=bool)
    part = np.full(m, -1, dtype=np.int32)
    sup_init = np.zeros(m, dtype=np.int64)
    total_work = float(sup_np.sum())
    rho_cd = 0
    for i in range(P_parts):
        if not alive.any():
            break
        sup_init[alive] = sup_np[alive]
        if i == P_parts - 1:
            hi = int(sup_np[alive].max()) + 1
        else:
            tgt = total_work / P_parts
            s = np.sort(sup_np[alive])
            w = np.maximum(s, 1).astype(np.float64)
            cum = np.cumsum(w)
            pos = min(int(np.searchsorted(cum, tgt)), s.size - 1)
            hi = int(s[pos]) + 1
            hi = max(hi, int(sup_np[alive].min()) + 1)
        while True:
            active = alive & (sup_np < hi)
            if not active.any():
                break
            part[active] = i
            alive &= ~active
            sup_np = step(active)
            rho_cd += 1
    return part, sup_init, rho_cd


def distributed_wing_decomposition(
    g: BipartiteGraph,
    mesh: Mesh,
    axis: str = "peel",
    P_parts: int = 8,
    be: Optional[BEIndex] = None,
    bloom_aligned: bool = False,
    engine: str = "beindex",
) -> Tuple[np.ndarray, dict]:
    """Full PBNG wing decomposition on a device mesh.

    ``engine="beindex"``: link-sharded CD rounds (two psums;
    ``bloom_aligned=True`` uses the one-psum §Perf variant) + link-packed
    FD.  ``engine="csr"``: wedge-sharded CD rounds + wedge-packed FD —
    O(Σ deg²) memory end to end, no BE-Index built.  FD is
    communication-free either way.  Returns (theta, stats).
    """
    if engine not in ("beindex", "csr"):
        raise ValueError(engine)
    if engine == "csr":
        if bloom_aligned or be is not None:
            raise ValueError(
                "engine='csr' builds no BE-Index: bloom_aligned/be "
                "only apply to engine='beindex'"
            )
        return _distributed_wing_csr(g, mesh, axis, P_parts)
    if be is None:
        be = build_beindex(g)
    m = g.m
    n_dev = mesh.devices.size
    if bloom_aligned:
        packed = shard_links_bloom_aligned(be, m, n_dev)
        round_fn = make_cd_round_bloom(mesh, axis, packed["Bmax"], m)
        bl_alive = jnp.asarray(packed["alive"])
        bl_k = jnp.asarray(packed["k0"])
        bl_le = jnp.asarray(packed["le"])
        bl_lt = jnp.asarray(packed["lt"])
        bl_lb = jnp.asarray(packed["lb"])
        support = jnp.asarray(be.edge_support(m).astype(np.int32))
        st = None
    else:
        st = shard_links(be, m, n_dev)
        round_fn = make_cd_round(mesh, axis, st.nb, m)
        support = st.support

    def step(active: np.ndarray) -> np.ndarray:
        nonlocal st, support, bl_alive, bl_k
        if bloom_aligned:
            peeled_pad = jnp.concatenate(
                [jnp.asarray(active), jnp.zeros((1,), bool)])
            support_pad = jnp.concatenate(
                [support, jnp.zeros((1,), jnp.int32)])
            bl_alive, bl_k, support_pad = round_fn(
                peeled_pad, bl_alive, bl_k, support_pad,
                bl_le, bl_lt, bl_lb)
            support = support_pad[:-1]
            return np.asarray(support).astype(np.int64)
        st = cd_round_sharded(round_fn, st, jnp.asarray(active))
        return np.asarray(st.support).astype(np.int64)

    part, sup_init, rho_cd = _cd_partition_loop(
        np.asarray(support).astype(np.int64), P_parts, step)
    n_parts = int(part.max()) + 1

    packed = pack_fd_partitions(g, be, part, sup_init, n_parts)
    theta_loc, rounds = fd_peel_sharded(packed, mesh, axis)
    theta = np.zeros(m, dtype=np.int64)
    for i in range(n_parts):
        mine = packed["mine"][i]
        theta[packed["gids"][i][mine]] = theta_loc[i][mine]
    stats = dict(
        engine="beindex",
        rho_cd=rho_cd,
        rho_fd_total=int(rounds.sum()),
        rho_fd_max=int(rounds.max()) if rounds.size else 0,
        n_parts=n_parts,
        n_links=be.n_links,
        n_dev=n_dev,
    )
    return theta, stats


def _distributed_wing_csr(
    g: BipartiteGraph, mesh: Mesh, axis: str, P_parts: int
) -> Tuple[np.ndarray, dict]:
    """csr engine on a mesh: wedge-sharded CD + wedge-packed FD."""
    wed = csr.build_wedges(g)
    m = g.m
    n_dev = int(mesh.devices.size)
    st = shard_wedges(wed, n_dev)
    round_fn = make_cd_round_csr(mesh, axis, st.n_pairs, m)

    def step(active: np.ndarray) -> np.ndarray:
        nonlocal st
        st = cd_round_sharded_csr(round_fn, st, jnp.asarray(active))
        return np.asarray(st.support).astype(np.int64)

    part, sup_init, rho_cd = _cd_partition_loop(
        np.asarray(st.support).astype(np.int64), P_parts, step)
    n_parts = int(part.max()) + 1

    packed = pack_fd_partitions_csr(wed, part, sup_init, n_parts)
    theta_loc, rounds = fd_peel_sharded_csr(packed, mesh, axis)
    theta = np.zeros(m, dtype=np.int64)
    for i in range(n_parts):
        mine = packed["mine"][i]
        theta[packed["gids"][i][mine]] = theta_loc[i][mine]
    stats = dict(
        engine="csr",
        rho_cd=rho_cd,
        rho_fd_total=int(rounds.sum()),
        rho_fd_max=int(rounds.max()) if rounds.size else 0,
        n_parts=n_parts,
        n_wedges=wed.n_wedges,
        n_pairs=wed.n_pairs,
        n_dev=n_dev,
    )
    return theta, stats


# =====================================================================
# Distributed TIP decomposition (vertex peeling, §3.2)
# =====================================================================
# CD: batch re-counting is a masked matmul — shard the *row blocks* of W
# across devices; each device re-counts butterflies for its vertex shard
# with zero collectives (A is replicated at container scale; row-sharded
# A + one all-gather per round at cluster scale).
# FD: partitions stack on a leading axis and peel under shard_map with
# no communication, pairwise butterfly counts computed once per
# partition inside the kernel (static because V is never peeled).
def _tip_cd_recount_body(A_blk, alive_blk, A_full, alive_full, row0):
    Am = A_full * alive_full[:, None]
    W = jax.lax.dot(A_blk * alive_blk[:, None], Am.T,
                    precision=jax.lax.Precision.HIGHEST)
    rows = row0 + jnp.arange(A_blk.shape[0])
    cols = jnp.arange(A_full.shape[0])
    W = jnp.where(rows[:, None] == cols[None, :], 0.0, W)
    return jnp.sum(W * (W - 1.0) * 0.5, axis=1)


def make_tip_cd_recount(mesh: Mesh, axis: str, n: int, n_dev: int):
    blk = -(-n // n_dev)

    def body(A_pad, alive_pad, shard_idx):
        # per-shard: A_pad [blk, nv], alive [blk], idx [1]
        row0 = shard_idx[0] * blk
        return _tip_cd_recount_body(
            A_pad, alive_pad,
            jax.lax.all_gather(A_pad, axis, axis=0, tiled=True),
            jax.lax.all_gather(alive_pad, axis, axis=0, tiled=True),
            row0)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=P(axis),
    )
    return jax.jit(fn), blk


def _tip_fd_kernel(A_i, mine, sup0):
    """Peel one tip partition bottom-up — no collectives.

    A_i: [Umax, nv] rows of this partition (zero-padded), mine [Umax],
    sup0 [Umax].  Pairwise butterflies are static (V never peeled)."""
    W = jax.lax.dot(A_i, A_i.T, precision=jax.lax.Precision.HIGHEST)
    Umax = W.shape[0]
    W = W * (1.0 - jnp.eye(Umax, dtype=W.dtype))
    pair_bf = W * (W - 1.0) * 0.5
    BIG = jnp.float32(2 ** 30)

    def cond(state):
        alive, *_ = state
        return jnp.any(alive)

    def body(state):
        alive, support, theta, k, rounds = state
        cur = jnp.where(alive, support, BIG)
        k = jnp.maximum(k, jnp.min(cur))
        S = alive & (support <= k)
        theta = jnp.where(S, k, theta)
        alive = alive & ~S
        support = support - pair_bf @ S.astype(jnp.float32)
        return (alive, support, theta, k, rounds + 1)

    zero = jnp.sum(mine.astype(jnp.float32)) * 0.0
    init = (mine, sup0.astype(jnp.float32),
            jnp.zeros((Umax,), jnp.float32) + zero, zero,
            jnp.int32(0) + zero.astype(jnp.int32))
    _, _, theta, _, rounds = jax.lax.while_loop(cond, body, init)
    return theta, rounds


def distributed_tip_decomposition(
    g: BipartiteGraph,
    mesh: Mesh,
    axis: str = "peel",
    side: str = "u",
    P_parts: int = 8,
) -> Tuple[np.ndarray, dict]:
    from . import counting

    gg = g if side == "u" else g.transpose()
    n, nv = gg.n_u, gg.n_v
    n_dev = int(mesh.devices.size)
    A_np = gg.adjacency()
    recount_fn, blk = make_tip_cd_recount(mesh, axis, n, n_dev)
    n_pad = blk * n_dev
    A = jnp.asarray(np.pad(A_np, ((0, n_pad - n), (0, 0))))
    shard_idx = jnp.arange(n_dev, dtype=jnp.int32)

    alive = np.ones(n_pad, bool)
    alive[n:] = False
    support = np.asarray(recount_fn(A, jnp.asarray(alive), shard_idx))
    support = np.rint(support).astype(np.int64)
    wedge_w = np.rint(np.asarray(
        counting.vertex_wedge_workload(jnp.asarray(A_np)))).astype(np.int64)

    part = np.full(n, -1, np.int32)
    sup_init = np.zeros(n, np.int64)
    total_w = float(wedge_w.sum())
    rho_cd = 0
    for i in range(P_parts):
        av = alive[:n]
        if not av.any():
            break
        sup_init[av] = support[:n][av]
        if i == P_parts - 1:
            hi = int(support[:n][av].max()) + 1
        else:
            s = np.sort(support[:n][av])
            w = wedge_w[av][np.argsort(support[:n][av], kind="stable")]
            cum = np.cumsum(np.maximum(w, 1))
            pos = min(int(np.searchsorted(cum, total_w / P_parts)),
                      s.size - 1)
            hi = max(int(s[pos]) + 1, int(s[0]) + 1)
        while True:
            active = alive[:n] & (support[:n] < hi)
            if not active.any():
                break
            part[active] = i
            alive[:n] &= ~active
            support = np.rint(np.asarray(recount_fn(
                A, jnp.asarray(alive), shard_idx))).astype(np.int64)
            rho_cd += 1
    n_parts = int(part.max()) + 1

    # ---- FD: stack padded partitions, shard over devices
    rows_per = [np.where(part == i)[0] for i in range(n_parts)]
    Umax = max(max((r.size for r in rows_per), default=1), 1)
    pad_parts = -(-n_parts // n_dev) * n_dev
    A_st = np.zeros((pad_parts, Umax, nv), np.float32)
    mine = np.zeros((pad_parts, Umax), bool)
    sup0 = np.zeros((pad_parts, Umax), np.float32)
    gids = np.zeros((pad_parts, Umax), np.int64)
    for i, r in enumerate(rows_per):
        A_st[i, : r.size] = A_np[r]
        mine[i, : r.size] = True
        sup0[i, : r.size] = sup_init[r]
        gids[i, : r.size] = r
    vk = jax.vmap(_tip_fd_kernel)
    fd = shard_map(
        vk, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
    )
    theta_st, rounds = jax.jit(fd)(
        jnp.asarray(A_st), jnp.asarray(mine), jnp.asarray(sup0))
    theta_st = np.rint(np.asarray(theta_st)).astype(np.int64)
    theta = np.zeros(n, np.int64)
    for i in range(n_parts):
        theta[gids[i][mine[i]]] = theta_st[i][mine[i]]
    stats = dict(
        rho_cd=rho_cd,
        rho_fd_total=int(np.asarray(rounds).sum()),
        rho_fd_max=int(np.asarray(rounds).max()) if n_parts else 0,
        n_parts=n_parts, n_dev=n_dev,
    )
    return theta, stats
