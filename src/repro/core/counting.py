"""Butterfly counting in JAX — the TPU-native reformulation.

The paper counts butterflies by traversing wedges with per-thread hashmaps
(alg.1).  On TPU we replace pointer-chasing with dense linear algebra on
the MXU:

    W = A · Aᵀ                      (wedge counts between same-side pairs)
    ⋈_u = Σ_{u'≠u} C(W[u,u'], 2)    (per-vertex butterflies)
    ⋈_e = ((W−1)·A)[u,v] − (d_u−1)  (per-edge butterflies)

All functions take an ``alive``-masked adjacency so the same code performs
the paper's §5.1 batch *re-counting* optimization during peeling.

Counts are exact in float32 for values < 2^24, which covers the
container-scale graphs; ``assert_exact`` guards it.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

__all__ = [
    "wedge_counts",
    "vertex_butterflies",
    "edge_butterflies",
    "total_butterflies",
    "vertex_wedge_workload",
    "masked_adjacency",
    "vertex_butterflies_blocked",
]


def masked_adjacency(shape, edges: jax.Array, alive_e: jax.Array) -> jax.Array:
    """Adjacency with only alive edges set (for wing peeling)."""
    A = jnp.zeros(shape, dtype=jnp.float32)
    return A.at[edges[:, 0], edges[:, 1]].add(alive_e.astype(jnp.float32))


def wedge_counts(A: jax.Array) -> jax.Array:
    """W[i, j] = number of common neighbours of rows i and j."""
    return jax.lax.dot(A, A.T, precision=jax.lax.Precision.HIGHEST)


def _choose2(x: jax.Array) -> jax.Array:
    return x * (x - 1.0) * 0.5


def _dense_limit() -> int:
    """Element budget for materializing the full n×n wedge matrix W
    (shared knob with the dense peel engine's guard)."""
    return int(os.environ.get("REPRO_DENSE_MAX_ELEMS", str(2 ** 28)))


def vertex_butterflies(A: jax.Array, block: int = 512) -> jax.Array:
    """⋈ for every row vertex of A (mask rows for tip peeling).

    When the full wedge matrix W = A·Aᵀ would exceed
    ``REPRO_DENSE_MAX_ELEMS`` elements, the reduction routes itself
    through the row-blocked path (:func:`vertex_butterflies_blocked`,
    O(block·n) peak) instead of failing — W is only ever consumed as
    row sums here, so the tiling is exact and invisible to callers.
    The routing decision is a static-shape check, so under jit it costs
    nothing at run time; an obs ``counting.tiles`` counter records when
    it fires."""
    n = A.shape[0]
    if n * n > _dense_limit():
        from repro import obs  # local import: keep core light
        obs.counter("counting.tiles", dict(
            tiles=-(-n // block), block=block, rows=n))
        return vertex_butterflies_blocked(A, block=block)
    W = wedge_counts(A)
    W = W * (1.0 - jnp.eye(W.shape[0], dtype=W.dtype))
    return jnp.sum(_choose2(W), axis=1)


def vertex_butterflies_blocked(A: jax.Array, block: int = 512) -> jax.Array:
    """Row-blocked variant — O(block·n) peak memory instead of O(n²).

    Mirrors the Pallas kernel tiling; used for graphs whose full W would
    not fit (and as the jnp oracle for the kernel).
    """
    n = A.shape[0]
    pad = (-n) % block
    Ap = jnp.pad(A, ((0, pad), (0, 0)))
    nb = Ap.shape[0] // block
    rows = Ap.reshape(nb, block, A.shape[1])

    def body(carry, blk_idx):
        blk = rows[blk_idx]
        W = jax.lax.dot(blk, A.T, precision=jax.lax.Precision.HIGHEST)
        row_ids = blk_idx * block + jnp.arange(block)
        cols = jnp.arange(n)
        W = jnp.where(row_ids[:, None] == cols[None, :], 0.0, W)
        return carry, jnp.sum(_choose2(W), axis=1)

    _, out = jax.lax.scan(body, None, jnp.arange(nb))
    return out.reshape(-1)[:n]


def edge_butterflies(A: jax.Array, edges: jax.Array) -> jax.Array:
    """⋈_e for the edge list (entries for dead edges are garbage — mask
    downstream).  A must already be alive-masked."""
    W = wedge_counts(A)
    du = jnp.sum(A, axis=1)
    M = jax.lax.dot(W - 1.0, A, precision=jax.lax.Precision.HIGHEST)
    u, v = edges[:, 0], edges[:, 1]
    return M[u, v] - (du[u] - 1.0)


def total_butterflies(A: jax.Array) -> jax.Array:
    """⋈(G): each butterfly counts once per U endpoint, so halve."""
    return jnp.sum(vertex_butterflies(A)) / 2.0


def vertex_wedge_workload(A: jax.Array) -> jax.Array:
    """Σ_{v∈N_u} d_v — the paper's workload proxy for tip range selection."""
    dv = jnp.sum(A, axis=0)
    return A @ dv


@functools.partial(jax.jit, static_argnames=("shape",))
def recount_vertex(shape, A: jax.Array, alive_u: jax.Array) -> jax.Array:
    """Batch re-count for tip CD: butterflies among alive row vertices."""
    Am = A * alive_u[:, None].astype(A.dtype)
    return vertex_butterflies(Am)


def assert_exact(x: jax.Array) -> None:
    """Counts must stay below f32's exact-integer range."""
    if bool(jnp.any(jnp.abs(x) >= 2 ** 24)):
        raise OverflowError(
            "butterfly counts exceed f32 exact range; use the blocked/"
            "int path or smaller graphs on this container"
        )


def approx_vertex_butterflies(
    A: jax.Array, n_cols: int, key: jax.Array, n_rounds: int = 4
) -> jax.Array:
    """Column-sampled butterfly estimate (FLEET-style [49] sampling).

    Each round samples ``n_cols`` V-columns without replacement; with
    X ~ Hypergeometric(n_v, W, n_cols) common-neighbour survivors,
    E[X(X−1)] = W(W−1)·n(n−1)/(N(N−1)), giving the unbiased estimator
    C2 ≈ X(X−1)/2 · N(N−1)/(n(n−1)).  Variance is butterfly-skew heavy,
    so estimates average over ``n_rounds`` draws.  Used only for CD
    *range estimation* on huge graphs, never for final θ.
    """
    n_u, n_v = A.shape
    n_cols = min(n_cols, n_v)
    scale = (n_v * (n_v - 1)) / (n_cols * (n_cols - 1))

    def one(k):
        cols = jax.random.choice(k, n_v, (n_cols,), replace=False)
        X = wedge_counts(A[:, cols])
        X = X * (1.0 - jnp.eye(n_u, dtype=X.dtype))
        return jnp.sum(X * (X - 1.0), axis=1) * 0.5 * scale

    keys = jax.random.split(key, n_rounds)
    return jnp.mean(jnp.stack([one(k) for k in keys]), axis=0)
