"""PBNG two-phased peeling (§3) — tip and wing decomposition in JAX.

Phase 1 — **coarse-grained decomposition (CD)**: iteratively peel every
entity whose support lies in the current range [θ(i), θ(i+1)).  Each round
is one fully-parallel masked update (the only global synchronization
point), a dramatic reduction versus level-by-level peeling.

Phase 2 — **fine-grained decomposition (FD)**: partitions are mutually
independent given the support-initialization vector ⋈init, so each is
peeled to exact entity numbers with *zero* communication.  Partitions are
processed in LPT (longest-processing-time) order.

Both phases are driven by the entity-agnostic core in ``core.peelspec``
— :func:`tip_decomposition` and :func:`wing_decomposition` only build
the :class:`~repro.core.peelspec.PeelSpec` (supports, workload proxy,
incremental update rule, FD packers) for their entity universe and hand
it to ``peelspec.decompose``.  The CD round loop, range selection and
all three FD cascade drivers exist exactly once, shared by every engine
below and by ``core.distributed``.

Three engines:
  * ``engine="dense"``   — TPU-native: supports re-counted per round with
    masked MXU matmuls (the paper's §5.1 batch re-count optimization taken
    to its logical extreme on TPU).  O(n²) memory — guarded by
    ``REPRO_DENSE_MAX_ELEMS``.
  * ``engine="beindex"`` — paper-faithful: BE-Index twin/bloom bookkeeping
    with ``segment_sum`` replacing atomics (alg.4/alg.6 semantics).
  * ``engine="csr"``     — sparse: ParButterfly-style wedge-list counting
    with incremental ``segment_sum`` updates (``core.csr``).  O(Σ deg²)
    memory — the only engine that scales past dense adjacency.

All return identical θ (validated against the pure-python BUP oracle).
"""
from __future__ import annotations

import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import counting, csr
from .. import obs
from .beindex import BEIndex, build_beindex
from .graph import BipartiteGraph
from .peelspec import (  # noqa: F401 — canonical home is peelspec; kept
    PeelResult,           # importable from here for compatibility
    PeelSpec,
    PeelStats,
    AdaptiveTarget as _AdaptiveTarget,
    _FD_BIG,
    _bucket_pad,
    _fd_cascade,
    _fd_while_device,
    _fd_while_fused,
    _fd_while_vmapped,
    _find_range,
    _lpt_order,
    _pad_zeros,
)
from . import peelspec

__all__ = [
    "PeelStats",
    "PeelResult",
    "PeelSpec",
    "build_peel_spec",
    "tip_decomposition",
    "wing_decomposition",
    "wing_decomposition_bepc",
    "bup_levels",
]


def build_peel_spec(
    g: BipartiteGraph,
    kind: str,
    stats: PeelStats,
    side: str = "u",
    engine: str = "csr",
    batch_recount="adaptive",
    be: Optional[BEIndex] = None,
    fd_driver: str = "device",
    use_pallas: bool = False,
    fused: bool = False,
    sup0: Optional[np.ndarray] = None,
    wed: Optional["csr.Wedges"] = None,
) -> PeelSpec:
    """Build the :class:`PeelSpec` for a ``(kind, engine)`` universe.

    The shared front door for :func:`tip_decomposition`,
    :func:`wing_decomposition` and the streaming updater
    (``repro.streaming``): one place validates the engine/driver matrix
    and hands back the spec without running the decomposition, so a
    caller that already knows the support vector can drive
    ``peelspec.cd_loop`` / ``peelspec.run_fd`` directly.

    ``sup0`` injects a precomputed ⋈init vector (int64, one entry per
    entity of ``kind``) — honored by both csr specs and the wing dense
    spec, where it skips the from-scratch butterfly count (the streaming
    path maintains it incrementally via wedge-local deltas).  The tip
    dense spec recounts regardless: its device CD state needs the
    counting pass anyway.  ``wed`` likewise injects prebuilt wedge
    structures for the csr specs.  Injection never changes results —
    only who pays for the count."""
    if kind not in ("tip", "wing"):
        raise ValueError(kind)
    if kind == "tip":
        if engine not in ("dense", "csr"):
            raise ValueError(engine)
    else:
        if engine not in ("beindex", "dense", "csr"):
            raise ValueError(engine)
    if fd_driver not in ("device", "host", "vmapped"):
        raise ValueError(fd_driver)
    if kind == "tip" and use_pallas and engine != "csr":
        raise ValueError("use_pallas applies to engine='csr' only")
    if fused and engine != "csr":
        raise ValueError("fused applies to engine='csr' only")
    if fused and fd_driver == "host":
        raise ValueError("fused requires fd_driver='device' or 'vmapped'")
    if kind == "tip":
        gg = g if side == "u" else g.transpose()
        if engine == "csr":
            return _tip_spec_csr(gg, stats, use_pallas=use_pallas,
                                 fused=fused, sup0=sup0, wed=wed)
        return _tip_spec_dense(gg, batch_recount, stats)
    if engine == "beindex":
        return _wing_spec_beindex(g, be, stats)
    if engine == "csr":
        return _wing_spec_csr(g, stats, use_pallas=use_pallas, fused=fused,
                              sup0=sup0, wed=wed)
    return _wing_spec_dense(g, stats, sup0=sup0)


# =====================================================================
# Entity-specific single-dispatch (vmapped) FD bodies
# =====================================================================
# Each body's ``update`` rule lives in a ``*_update`` builder shared by
# the default entry and its ``*_rings`` telemetry twin (a separate jit
# entry with a static ``ring_cap``), so the peeling algebra exists once
# while the default entry's jaxpr stays byte-identical to the
# pre-instrumentation tree (tests/goldens/obs_jaxprs.json).

def _tip_vmapped_update(pag, pbg, bff, B, Emax):
    def update(S, aux):
        Sf = S.reshape(-1)
        loss = (
            jax.ops.segment_sum(
                jnp.where(Sf[pbg], bff, 0), pag, num_segments=B * Emax)
            + jax.ops.segment_sum(
                jnp.where(Sf[pag], bff, 0), pbg, num_segments=B * Emax)
        ).reshape(B, Emax)
        return loss, aux, jnp.int32(0)

    return update


@jax.jit
def _fd_tip_vmapped(
    pag: jax.Array,      # (W,) int32 — globalized pair endpoints b·Emax+u
    pbg: jax.Array,
    bff: jax.Array,      # (W,) int32 — static pair butterflies (0 on pad)
    mine: jax.Array,     # (B, E) bool — partition members
    sup0: jax.Array,     # (B, E) int32 — ⋈init (zero outside mine)
):
    """All tip-FD partitions in a single while_loop (one dispatch).

    :func:`csr.tip_delta_csr` over the ragged-concatenated pair lists
    with the partition axis folded into pre-globalized segment ids
    (partition b's vertex u → segment b·Emax+u): one flat
    ``segment_sum`` pass per round covers every partition.  Padding
    pairs carry bf=0 and are algebra-neutral."""
    B, Emax = mine.shape
    update = _tip_vmapped_update(pag, pbg, bff, B, Emax)
    return _fd_while_vmapped(mine, sup0, update, jnp.int32(0))


@partial(jax.jit, static_argnames=("ring_cap",))
def _fd_tip_vmapped_rings(pag, pbg, bff, mine, sup0, ring_cap: int):
    """:func:`_fd_tip_vmapped` + per-round counter rings (obs)."""
    B, Emax = mine.shape
    update = _tip_vmapped_update(pag, pbg, bff, B, Emax)
    return peelspec._fd_while_vmapped_rings(
        mine, sup0, update, jnp.int32(0), ring_cap)


def _wing_vmapped_update(e1g, e2g, wpg, B, Emax, n_pairs):
    def update(S, aux):
        alive_w, W = aux                      # (W,), (n_pairs,)
        S_pad = jnp.concatenate(
            [S, jnp.zeros((B, 1), bool)], axis=1).reshape(-1)
        pe1 = S_pad[e1g]
        pe2 = S_pad[e2g]
        w_dies = alive_w & (pe1 | pe2)
        c = jax.ops.segment_sum(
            w_dies.astype(jnp.int32), wpg, num_segments=n_pairs)
        surv = alive_w & ~w_dies
        surv_loss = jnp.where(surv, c[wpg], 0)
        nseg = B * (Emax + 1)
        loss = (
            jax.ops.segment_sum(
                jnp.where(w_dies & ~pe1, W[wpg] - 1, 0) + surv_loss,
                e1g, num_segments=nseg)
            + jax.ops.segment_sum(
                jnp.where(w_dies & ~pe2, W[wpg] - 1, 0) + surv_loss,
                e2g, num_segments=nseg)
        ).reshape(B, Emax + 1)[:, :Emax]
        nu = jnp.sum((w_dies & (~pe1 | ~pe2)).astype(jnp.int32)) + jnp.sum(
            (surv & (c[wpg] > 0)).astype(jnp.int32)
        )
        return loss, (alive_w & ~w_dies, W - c), nu

    return update


@partial(jax.jit, static_argnames=("n_pairs",))
def _fd_wing_vmapped(
    e1g: jax.Array,      # (W,) int32 — globalized edge ids b·(Emax+1)+e
    e2g: jax.Array,
    wpg: jax.Array,      # (W,) int32 — globalized pair ids (dead pad → n_pairs-ish slot)
    alive0: jax.Array,   # (W,) bool — wedges touching their partition
    W0: jax.Array,       # (n_pairs,) int32 — alive ≥i wedges per pair
    mine: jax.Array,     # (B, E) bool
    sup0: jax.Array,     # (B, E) int32
    n_pairs: int,
):
    """All wing-FD partitions in a single while_loop (one dispatch).

    The per-round update is :func:`csr.wing_loss_csr`'s widow/survivor
    algebra over the ragged-CONCATENATED wedge lists: the partition axis
    is folded into pre-globalized segment ids (partition b's edge e →
    segment b·(Emax+1)+e), so every round is ONE flat ``segment_sum``
    pass whose work is Σ|touching wedges| with zero stacking padding —
    and one scatter-add instead of a batched one.  No collectives
    anywhere."""
    B, Emax = mine.shape
    update = _wing_vmapped_update(e1g, e2g, wpg, B, Emax, n_pairs)
    return _fd_while_vmapped(mine, sup0, update, (alive0, W0))


@partial(jax.jit, static_argnames=("n_pairs", "ring_cap"))
def _fd_wing_vmapped_rings(e1g, e2g, wpg, alive0, W0, mine, sup0,
                           n_pairs: int, ring_cap: int):
    """:func:`_fd_wing_vmapped` + per-round counter rings (obs)."""
    B, Emax = mine.shape
    update = _wing_vmapped_update(e1g, e2g, wpg, B, Emax, n_pairs)
    return peelspec._fd_while_vmapped_rings(
        mine, sup0, update, (alive0, W0), ring_cap)


def _wing_pallas_update(slot_e1, slot_e2, B, Emax, interpret):
    from repro.kernels import ops as kops  # local import: keep core light

    _, R, K = slot_e1.shape
    # globalize slot edge ids: partition b's edge e → b·(Emax+1) + e
    # (sentinel Emax lands in b's own discard slot)
    off = (jnp.arange(B, dtype=jnp.int32) * (Emax + 1))[:, None, None]
    e1g = (slot_e1 + off).reshape(B * R, K)
    e2g = (slot_e2 + off).reshape(B * R, K)

    def update(S, aux):
        alive_slots, W = aux                       # (B·R, K), (B·R)
        S_pad = jnp.concatenate(
            [S, jnp.zeros((B, 1), bool)], axis=1).reshape(-1)
        pe1 = S_pad[e1g]
        pe2 = S_pad[e2g]
        c1, c2, c_row = kops.support_update(
            pe1, pe2, alive_slots, W, interpret=interpret
        )
        c1 = jnp.rint(c1).astype(jnp.int32)
        c2 = jnp.rint(c2).astype(jnp.int32)
        c_row = jnp.rint(c_row).astype(jnp.int32)
        nseg = B * (Emax + 1)
        loss = (
            jax.ops.segment_sum(c1.reshape(-1), e1g.reshape(-1),
                                num_segments=nseg)
            + jax.ops.segment_sum(c2.reshape(-1), e2g.reshape(-1),
                                  num_segments=nseg)
        ).reshape(B, Emax + 1)[:, :Emax]
        dies = alive_slots & (pe1 | pe2)
        surv = alive_slots & ~dies
        nu = jnp.sum((dies & (~pe1 | ~pe2)).astype(jnp.int32)) + jnp.sum(
            (surv & (c_row[:, None] > 0)).astype(jnp.int32)
        )
        return loss, (alive_slots & ~dies, W - c_row), nu

    return update


@partial(jax.jit, static_argnames=("interpret",))
def _fd_wing_vmapped_pallas(
    slot_e1: jax.Array,     # (B, R, K) int32 — local edge ids, sentinel E
    slot_e2: jax.Array,
    valid0: jax.Array,      # (B, R, K) bool — initial alive slots
    W0: jax.Array,          # (B, R) int32 — alive wedges per slot row
    mine: jax.Array,        # (B, E) bool
    sup0: jax.Array,        # (B, E) int32
    interpret: bool = True,
):
    """Single-dispatch wing FD with the blocked Pallas ``support_update``
    kernel INSIDE the while_loop body.

    The stacked pairs-major slot blocks flatten along rows into one
    (B·R, K) matrix, so each round is ONE kernel launch covering every
    partition (the partition axis rides the kernel's row grid — no vmap
    over ``pallas_call`` needed); only the loss scatter back onto the
    per-partition edge slots stays a ``segment_sum``.  Counts are
    re-integerized from f32 straight out of the kernel — exact while
    W_p < 2²⁴ (guarded at pack time), parity-tested against the
    segment-sum body.
    """
    B, Emax = mine.shape
    _, R, K = slot_e1.shape
    update = _wing_pallas_update(slot_e1, slot_e2, B, Emax, interpret)
    return _fd_while_vmapped(
        mine, sup0, update, (valid0.reshape(B * R, K), W0.reshape(B * R))
    )


@partial(jax.jit, static_argnames=("interpret", "ring_cap"))
def _fd_wing_vmapped_pallas_rings(slot_e1, slot_e2, valid0, W0, mine, sup0,
                                  interpret: bool, ring_cap: int):
    """:func:`_fd_wing_vmapped_pallas` + per-round counter rings (obs)."""
    B, Emax = mine.shape
    _, R, K = slot_e1.shape
    update = _wing_pallas_update(slot_e1, slot_e2, B, Emax, interpret)
    return peelspec._fd_while_vmapped_rings(
        mine, sup0, update,
        (valid0.reshape(B * R, K), W0.reshape(B * R)), ring_cap)


# =====================================================================
# Fused FD bodies — the whole round is ONE Pallas launch
# =====================================================================
def _wing_fused_setup(slot_e1, slot_e2, valid0, W0, mine, sup0, interpret):
    from repro.kernels import ops as kops  # local import: keep core light

    # loop-constant inits derived from inputs (cf. _fd_while_vmapped)
    z = sup0 * 0
    z1 = z[:, :1]
    state0 = (
        sup0.astype(jnp.int32), mine.astype(jnp.int32), z, z1, z1, z1,
        valid0.astype(jnp.int32), W0.astype(jnp.float32),
    )

    def round_fn(sup, alive, theta, k, rounds, nupd, aslot, W):
        return kops.fd_round_wing(
            sup, alive, theta, k, rounds, nupd, aslot, W,
            slot_e1, slot_e2, interpret=interpret)

    return state0, round_fn


def _fd_wing_fused_impl(
    slot_e1: jax.Array,     # (B, R, K) int32 — local edge ids, sentinel E
    slot_e2: jax.Array,
    valid0: jax.Array,      # (B, R, K) bool — initial alive slots
    W0: jax.Array,          # (B, R) int32 — alive wedges per slot row
    mine: jax.Array,        # (B, E) bool
    sup0: jax.Array,        # (B, E) int32
    interpret: bool = True,
):
    """Zero-per-round-dispatch wing FD: the while_loop body is ONE fused
    ``kernels.fd_round`` launch — k-advance, frontier compaction AND the
    widow/survivor support update all in-kernel, no segment-sum/argmin
    tail (cf. :func:`_fd_wing_vmapped_pallas`, which still scatters the
    losses outside the kernel).  Returns (theta (B, E), rounds (B),
    update count) bit-identical to the unfused drivers."""
    state0, round_fn = _wing_fused_setup(
        slot_e1, slot_e2, valid0, W0, mine, sup0, interpret)
    out = peelspec._fd_while_fused(state0, round_fn)
    return out[2], out[4][:, 0], jnp.sum(out[5])


_fd_wing_fused = partial(
    jax.jit, static_argnames=("interpret",))(_fd_wing_fused_impl)


def _fd_wing_fused_rings_impl(slot_e1, slot_e2, valid0, W0, mine, sup0,
                              interpret: bool, ring_cap: int):
    """:func:`_fd_wing_fused_impl` + per-round counter rings derived
    around the fused round (the kernel itself is untouched); the update
    ring carries the state's *cumulative* per-partition counts — drain
    with ``cumulative_updates=True``."""
    state0, round_fn = _wing_fused_setup(
        slot_e1, slot_e2, valid0, W0, mine, sup0, interpret)
    out, rings = peelspec._fd_while_fused_rings(state0, round_fn, ring_cap)
    return out[2], out[4][:, 0], jnp.sum(out[5]), rings


_fd_wing_fused_rings = partial(
    jax.jit,
    static_argnames=("interpret", "ring_cap"))(_fd_wing_fused_rings_impl)


def _tip_fused_setup(st_pa, st_pb, st_bf, mine, sup0, interpret):
    from repro.kernels import ops as kops

    z = sup0 * 0
    z1 = z[:, :1]
    state0 = (sup0.astype(jnp.int32), mine.astype(jnp.int32), z, z1, z1)

    def round_fn(sup, alive, theta, k, rounds):
        return kops.fd_round_tip(
            sup, alive, theta, k, rounds, st_pa, st_pb, st_bf,
            interpret=interpret)

    return state0, round_fn


def _fd_tip_fused_impl(
    st_pa: jax.Array,       # (B, L) int32 — partition-local pair lists
    st_pb: jax.Array,
    st_bf: jax.Array,       # (B, L) int32 — static pair ⋈ (0 on pad)
    mine: jax.Array,        # (B, E) bool
    sup0: jax.Array,        # (B, E) int32
    interpret: bool = True,
):
    """Tip counterpart of :func:`_fd_wing_fused_impl`: one fused Pallas
    launch per round over the stacked partition-local pair lists.
    Returns (theta (B, E), rounds (B))."""
    state0, round_fn = _tip_fused_setup(
        st_pa, st_pb, st_bf, mine, sup0, interpret)
    out = peelspec._fd_while_fused(state0, round_fn)
    return out[2], out[4][:, 0]


_fd_tip_fused = partial(
    jax.jit, static_argnames=("interpret",))(_fd_tip_fused_impl)


def _fd_tip_fused_rings_impl(st_pa, st_pb, st_bf, mine, sup0,
                             interpret: bool, ring_cap: int):
    """:func:`_fd_tip_fused_impl` + per-round counter rings (obs)."""
    state0, round_fn = _tip_fused_setup(
        st_pa, st_pb, st_bf, mine, sup0, interpret)
    out, rings = peelspec._fd_while_fused_rings(state0, round_fn, ring_cap)
    return out[2], out[4][:, 0], rings


_fd_tip_fused_rings = partial(
    jax.jit,
    static_argnames=("interpret", "ring_cap"))(_fd_tip_fused_rings_impl)


# =====================================================================
# Entity-specific per-partition (device) FD bodies
# =====================================================================
def _tip_device_update(pa, pb, pbf, n):
    def update(S, aux):
        loss = csr.tip_delta_csr(S, pa, pb, pbf, n)
        return loss, aux, jnp.int32(0)

    return update


@partial(jax.jit, static_argnames=("n",))
def _fd_tip_device(
    mine: jax.Array,      # (n,) bool — partition members
    sup0: jax.Array,      # (n,) int32 — ⋈init (zero outside mine)
    pa: jax.Array,        # partition-local pair endpoints (global ids)
    pb: jax.Array,
    pbf: jax.Array,       # (n_pairs_i,) int32 static pair butterflies
    n: int,
):
    """Whole tip-FD cascade of one partition in a single while_loop."""
    update = _tip_device_update(pa, pb, pbf, n)
    return _fd_while_device(mine, sup0, update, jnp.int32(0))


@partial(jax.jit, static_argnames=("n", "ring_cap"))
def _fd_tip_device_rings(mine, sup0, pa, pb, pbf, n: int, ring_cap: int):
    """:func:`_fd_tip_device` + per-round counter rings (obs)."""
    update = _tip_device_update(pa, pb, pbf, n)
    return peelspec._fd_while_device_rings(
        mine, sup0, update, jnp.int32(0), ring_cap)


def _wing_device_update(we1, we2, wp, n_pairs, m):
    def update(S, aux):
        alive_w, W = aux
        alive_w, W, loss, nu = csr.wing_loss_csr(
            S, alive_w, W, we1, we2, wp, n_pairs, m
        )
        return loss, (alive_w, W), nu

    return update


@partial(jax.jit, static_argnames=("n_pairs", "m"))
def _fd_wing_device(
    mine: jax.Array,      # (m,) bool — partition members
    sup0: jax.Array,      # (m,) int32 — ⋈init (zero outside mine)
    alive_w0: jax.Array,  # (n_kept,) bool — wedges of the ≥i subgraph
    W0: jax.Array,        # (n_pairs,) int32 — alive wedge count per pair
    we1: jax.Array,
    we2: jax.Array,
    wp: jax.Array,
    n_pairs: int,
    m: int,
):
    """Whole wing-FD cascade of one partition in a single while_loop."""
    update = _wing_device_update(we1, we2, wp, n_pairs, m)
    return _fd_while_device(mine, sup0, update, (alive_w0, W0))


@partial(jax.jit, static_argnames=("n_pairs", "m", "ring_cap"))
def _fd_wing_device_rings(mine, sup0, alive_w0, W0, we1, we2, wp,
                          n_pairs: int, m: int, ring_cap: int):
    """:func:`_fd_wing_device` + per-round counter rings (obs)."""
    update = _wing_device_update(we1, we2, wp, n_pairs, m)
    return peelspec._fd_while_device_rings(
        mine, sup0, update, (alive_w0, W0), ring_cap)


def _drain_rings(mode, parts, rounds, rings, cap, cumulative=False):
    """Hand one FD launch's counter rings to the active timeline
    collector (no-op when the obs layer is off)."""
    col = obs.active_collector()
    if col is not None:
        col.record_fd_rings(mode, parts, rounds,
                            [np.asarray(r) for r in rings], cap,
                            cumulative_updates=cumulative)


def _dense_guard(n_u: int, n_v: int) -> None:
    """Refuse dense-engine allocations that cannot fit.

    The dense engine materializes an n_u×n_v adjacency and an n_u×n_u
    wedge matrix; past ``REPRO_DENSE_MAX_ELEMS`` elements (default 2²⁸ ≈
    1 GiB of f32) that is memory-roofline death, so fail fast with a
    pointer at the csr engine instead of letting XLA OOM.
    """
    limit = int(os.environ.get("REPRO_DENSE_MAX_ELEMS", str(2 ** 28)))
    need = max(n_u * n_v, n_u * n_u)
    if need > limit:
        raise MemoryError(
            f"dense engine needs a {n_u}x{max(n_v, n_u)} matrix "
            f"({need} > REPRO_DENSE_MAX_ELEMS={limit}); "
            "use engine='csr' for graphs this large"
        )


# =====================================================================
# Tip decomposition (vertex peeling)
# =====================================================================
@partial(jax.jit, static_argnames=())
def _tip_recount(A: jax.Array, alive: jax.Array) -> jax.Array:
    return counting.vertex_butterflies(A * alive[:, None].astype(A.dtype))


@jax.jit
def _tip_fd_delta(pair_bf: jax.Array, peel: jax.Array) -> jax.Array:
    """Δ⋈_u' = Σ_{u peeled} (butterflies shared by pair (u', u))."""
    return pair_bf @ peel.astype(pair_bf.dtype)


def tip_decomposition(
    g: BipartiteGraph,
    side: str = "u",
    P: int = 16,
    batch_recount="adaptive",
    engine: str = "dense",
    fd_driver: str = "device",
    use_pallas: bool = False,
    fused: bool = False,
    sup0: Optional[np.ndarray] = None,
) -> PeelResult:
    """PBNG tip decomposition (§3.2) — θ per U (or V) vertex.

    ``engine``/``fd_driver`` matrix (all combinations θ-bit-identical):

    ========  =====================================  ====================
    engine    support counting / update              fd_driver
    ========  =====================================  ====================
    dense     masked MXU matmul re-counts, O(n²)     (host cascade)
    csr       incremental pair updates, O(Σ deg²)    device │ vmapped │ host
    ========  =====================================  ====================

    Example::

        from repro.core import random_bipartite, tip_decomposition
        g = random_bipartite(1000, 800, 8000, seed=0)
        res = tip_decomposition(g, side="u", engine="csr", P=8)
        print(res.theta.max(), res.stats.rho_cd)

    ``engine="dense"`` (default) re-counts with masked MXU matmuls;
    ``engine="csr"`` peels on the sparse wedge list (``core.csr``) with
    purely incremental pair updates — O(Σ deg²) memory, the only option
    once the n×n wedge matrix stops fitting.

    ``fd_driver`` (csr engine only): ``"device"`` (default) peels each FD
    partition in a single ``lax.while_loop`` dispatch — zero host↔device
    transfers inside a partition; ``"vmapped"`` stacks ALL partitions
    into one shape-bucketed layout and runs the whole Phase 2 as ONE
    batched while_loop (a single dispatch total); ``"host"`` drives
    rounds from a python loop (the PR-1 baseline kept for A/B
    benchmarks).

    ``use_pallas`` (csr engine only): run CD support updates through the
    blocked ``kernels.wedge_count`` row-sum kernel on the vertex-major
    pair-slot layout (``csr.tip_delta_slots``; interpret mode off-TPU)
    instead of flat segment_sums — θ and round/update counts
    parity-locked either way.

    ``fused`` (csr engine, device/vmapped drivers): run every FD round
    as ONE fused Pallas launch (``kernels.fd_round``) — k-advance,
    frontier compaction and the support delta all in-kernel, zero
    per-round dispatch tail.  θ and round counts bit-identical to the
    unfused drivers.

    ``batch_recount`` (dense engine only): the §5.1 batch optimization
    knob —
      * ``"adaptive"`` (default, paper-faithful): per round, re-count all
        survivors iff the frontier's wedge workload exceeds the counting
        bound ∧cnt = Σ_e min(d_u, d_v); otherwise apply incremental
        pairwise updates.
      * ``True`` — always re-count; ``False`` — always incremental
        (the PBNG-- ablation).
    """
    stats = PeelStats(
        engine=engine,
        fd_driver=fd_driver if engine == "csr" else "host",
        side=side,
    )
    spec = build_peel_spec(
        g, "tip", stats, side=side, engine=engine,
        batch_recount=batch_recount, fd_driver=fd_driver,
        use_pallas=use_pallas, fused=fused, sup0=sup0)
    return peelspec.decompose(spec, P, stats, fd_driver=fd_driver)


def _tip_spec_dense(
    gg: BipartiteGraph, batch_recount, stats: PeelStats
) -> PeelSpec:
    """Dense-engine tip spec: masked-MXU batch re-counts (or §5.1
    adaptive incremental pairwise updates) as the CD step, static
    pairwise-butterfly cascade as the FD rule."""
    n = gg.n_u
    _dense_guard(gg.n_u, gg.n_v)
    A = jnp.asarray(gg.adjacency())
    wedge_w = np.asarray(counting.vertex_wedge_workload(A))  # paper's proxy

    support = counting.vertex_butterflies(A)
    counting.assert_exact(support)
    sup0 = np.rint(np.asarray(support)).astype(np.int64)

    # counting-work bound ∧cnt (alg.1 complexity) for the adaptive rule
    du, dv = gg.degrees()
    cnt_bound = float(
        np.minimum(du[gg.edges[:, 0]], dv[gg.edges[:, 1]]).sum())

    # Static pairwise butterfly matrix for the incremental path.
    pair_bf_full = None
    if batch_recount is not True:
        W = np.array(counting.wedge_counts(A))
        np.fill_diagonal(W, 0)
        pair_bf_full = jnp.asarray(W * (W - 1) / 2)

    state = dict(alive=jnp.ones((n,), dtype=bool), support=support)

    def cd_step(active: np.ndarray) -> np.ndarray:
        state["alive"] = state["alive"] & jnp.asarray(~active)
        if batch_recount is True:
            use_recount = True
        elif batch_recount is False:
            use_recount = False
        else:  # adaptive §5.1: peel-work vs recount-work
            use_recount = float(wedge_w[active].sum()) > cnt_bound
        if use_recount:
            state["support"] = _tip_recount(A, state["alive"])
            stats.recounts += 1
        else:
            state["support"] = state["support"] - _tip_fd_delta(
                pair_bf_full, jnp.asarray(active)
            )
            stats.updates += int(active.sum()) * int(
                np.asarray(state["alive"]).sum())
        return np.rint(np.asarray(state["support"])).astype(np.int64)

    A_np = np.asarray(A)

    def fd_partition(i, part, sup_init, theta, fd_driver):
        rows = np.where(part == i)[0]
        if rows.size == 0:
            return 0, 0, 0
        rounds = _tip_fd_peel(A_np, rows, sup_init[rows], theta, int(i))
        return rounds, 0, 0

    return PeelSpec(
        kind="tip", n=n, sup0=sup0,
        workload=lambda s: wedge_w,
        est=lambda s: wedge_w,
        cd_step=cd_step,
        fd_partition=fd_partition,
    )


def _tip_fd_peel(
    A_np: np.ndarray, rows: np.ndarray, sup0: np.ndarray,
    theta: np.ndarray, part_i: int = 0,
) -> int:
    """Sequential (level-synchronous) bottom-up peel of one partition.

    Exact because a butterfly has exactly two U-endpoints and V is never
    peeled: pairwise counts within the partition are static.
    """
    Ai = jnp.asarray(A_np[rows])
    W = np.array(counting.wedge_counts(Ai))
    np.fill_diagonal(W, 0)
    pair_bf = jnp.asarray(W * (W - 1) / 2)

    s = rows.size
    alive = np.ones(s, dtype=bool)
    support = sup0.astype(np.float64).copy()
    col = obs.active_collector()
    trows: list = []
    k = 0
    rounds = 0
    while alive.any():
        k = max(k, int(support[alive].min()))
        while True:
            S = alive & (support <= k)
            if not S.any():
                break
            theta[rows[S]] = k
            alive &= ~S
            delta = np.asarray(_tip_fd_delta(pair_bf, jnp.asarray(S)))
            support -= delta
            rounds += 1
            if col is not None:
                trows.append(dict(k=k, died=int(S.sum()),
                                  frontier=int(alive.sum())))
    if col is not None:
        col.record_fd_host(part_i, trows)
    return rounds


# =====================================================================
# Tip decomposition, csr engine (sparse wedge list, core/csr.py)
# =====================================================================
def _tip_spec_csr(
    gg: BipartiteGraph, stats: PeelStats, use_pallas: bool = False,
    fused: bool = False, sup0: Optional[np.ndarray] = None,
    wed: Optional[csr.Wedges] = None,
) -> PeelSpec:
    """csr-engine tip spec: CD + FD on the flat wedge list — no dense
    matrices anywhere.

    Support init and every update are exact int32 ``segment_sum``s over
    U-endpoint pairs; pair butterfly counts are static because the V side
    is never peeled, so the engine is purely incremental (zero
    re-counts).  ``use_pallas`` routes the CD delta through the blocked
    row-sum kernel over the vertex-major slot layout
    (:func:`csr.tip_delta_slots`).  ``fused`` runs the FD phase through
    the fused ``kernels.fd_round`` launch (device driver: pack once,
    slice each partition from the shared stack; vmapped: the whole
    stack at once)."""
    n = gg.n_u
    if wed is None:
        wed = csr.build_wedges(gg)
    pa = jnp.asarray(wed.pair_a)
    pb = jnp.asarray(wed.pair_b)
    pair_bf0 = wed.pair_butterflies0()
    pbf = jnp.asarray(pair_bf0.astype(np.int32))
    wu, _ = csr.wedge_workload(gg)
    wedge_w = wu.astype(np.float64)

    sup_np = (csr.vertex_butterflies_csr(wed) if sup0 is None
              else np.asarray(sup0, dtype=np.int64))
    if sup_np.size and int(sup_np.max()) > 2 ** 31 - 1:
        raise OverflowError("tip supports exceed int32; shard the graph")
    state = dict(support=jnp.asarray(sup_np.astype(np.int32)))

    if use_pallas:
        slots = csr.pack_tip_slots(wed, pair_bf0, sup=sup_np)
        slot_partner = jnp.asarray(slots["partner"])
        slot_bf = jnp.asarray(slots["bf"])

    def cd_step(active: np.ndarray) -> np.ndarray:
        if use_pallas:
            delta = csr.tip_delta_slots(
                jnp.asarray(active), slot_partner, slot_bf, n)
        else:
            delta = csr.tip_delta_csr(jnp.asarray(active), pa, pb, pbf, n)
        state["support"] = state["support"] - delta
        if wed.n_pairs:
            stats.updates += int(
                np.count_nonzero(active[wed.pair_a] | active[wed.pair_b])
            )
        return np.asarray(state["support"]).astype(np.int64)

    # fused device driver: pack the partition stack ONCE (lazily, on the
    # first fd_partition call — part/sup_init are fixed for the whole FD
    # phase), then slice each partition as a B=1 batch into the same
    # jitted fused entry.  One compile for every partition (shared
    # Emax/Lmax buckets), bit-identical to the unfused cascade.
    fused_pack: dict = {}

    def fd_partition(i, part, sup_init, theta, fd_driver):
        if fused and fd_driver == "device":
            from repro.kernels import ops as kops

            if "p" not in fused_pack:
                from .distributed import pack_fd_partitions_tip_csr

                fused_pack["p"] = pack_fd_partitions_tip_csr(
                    wed, pair_bf0, part, sup_init,
                    int(part.max()) + 1 if part.size else 0,
                    bucket=True, stacked=True,
                )
            p = fused_pack["p"]
            f_args = (
                jnp.asarray(p["st_pa"][i:i + 1]),
                jnp.asarray(p["st_pb"][i:i + 1]),
                jnp.asarray(p["st_bf"][i:i + 1]),
                jnp.asarray(p["mine"][i:i + 1]),
                jnp.asarray(p["sup0"][i:i + 1]),
            )
            cap = obs.fd_ring_cap()
            if cap:
                theta_st, rounds, rings = _fd_tip_fused_rings(
                    *f_args, interpret=kops.default_interpret(),
                    ring_cap=cap)
                _drain_rings("fused", [i], [int(rounds[0])], rings, cap,
                             cumulative=True)
            else:
                theta_st, rounds = _fd_tip_fused(
                    *f_args, interpret=kops.default_interpret())
            mm = p["mine"][i]
            theta[p["gids"][i][mm]] = (
                np.asarray(theta_st[0]).astype(np.int64)[mm])
            return int(rounds[0]), 0, 0
        rounds = _tip_fd_csr(
            wed, pair_bf0, part, i, sup_init, theta, fd_driver=fd_driver)
        return rounds, 0, 0

    def fd_vmapped(part, sup_init, theta, n_parts):
        rounds = _tip_fd_vmapped_csr(
            wed, pair_bf0, part, sup_init, theta, n_parts, fused=fused)
        return rounds, 0

    return PeelSpec(
        kind="tip", n=n, sup0=sup_np,
        workload=lambda s: wedge_w,
        est=lambda s: wedge_w,
        cd_step=cd_step,
        fd_partition=fd_partition,
        fd_vmapped=fd_vmapped,
    )


def _tip_fd_csr(
    wed: csr.Wedges,
    pair_bf0: np.ndarray,
    part: np.ndarray,
    i: int,
    sup_init: np.ndarray,
    theta: np.ndarray,
    fd_driver: str = "device",
) -> int:
    """Bottom-up peel of partition i on the pair list.

    Only pairs with both endpoints inside the partition matter: vertices
    of later partitions are never peeled during FD_i, and deltas to them
    are discarded anyway.

    ``fd_driver="device"`` (default) runs the whole cascade in one
    ``lax.while_loop`` (:func:`_fd_tip_device`) — a single dispatch per
    partition, zero host round-trips.  ``"host"`` keeps the per-round
    dispatch loop (the PR-1 baseline, benchmarked against).
    """
    mine = part == i
    if not mine.any():
        return 0
    n = part.size
    mask = mine[wed.pair_a] & mine[wed.pair_b] if wed.n_pairs else np.zeros(0, bool)

    support0 = np.zeros(n, dtype=np.int64)
    support0[mine] = sup_init[mine]

    cap = obs.fd_ring_cap()
    if fd_driver == "device":
        # bucket-pad the pair arrays so the while_loop compiles once per
        # size bucket, not once per partition
        size = _bucket_pad(int(mask.sum()))
        args = (
            jnp.asarray(mine), jnp.asarray(support0.astype(np.int32)),
            jnp.asarray(_pad_zeros(wed.pair_a[mask], size)),
            jnp.asarray(_pad_zeros(wed.pair_b[mask], size)),
            jnp.asarray(_pad_zeros(pair_bf0[mask].astype(np.int32), size)),
            n,
        )
        if cap:
            theta_d, rounds, _, rings = _fd_tip_device_rings(
                *args, ring_cap=cap)
            _drain_rings("device", [i], [int(rounds)], rings, cap)
        else:
            theta_d, rounds, _ = _fd_tip_device(*args)
        theta_np = np.asarray(theta_d).astype(np.int64)
        theta[mine] = theta_np[mine]
        return int(rounds)

    pa = jnp.asarray(wed.pair_a[mask])
    pb = jnp.asarray(wed.pair_b[mask])
    pbf = jnp.asarray(pair_bf0[mask].astype(np.int32))

    def peel(S, sup):
        delta = np.asarray(
            csr.tip_delta_csr(jnp.asarray(S), pa, pb, pbf, n)
        ).astype(np.int64)
        return sup - delta

    col = obs.active_collector()
    if col is None:
        return _fd_cascade(mine, support0, theta, peel)
    rows: list = []
    rounds = _fd_cascade(
        mine, support0, theta, peel,
        on_round=lambda k, died, frontier: rows.append(
            dict(k=k, died=died, frontier=frontier)))
    col.record_fd_host(i, rows)
    return rounds


def _tip_fd_vmapped_csr(
    wed: csr.Wedges,
    pair_bf0: np.ndarray,
    part: np.ndarray,
    sup_init: np.ndarray,
    theta: np.ndarray,
    n_parts: int,
    fused: bool = False,
) -> np.ndarray:
    """Single-dispatch tip Phase 2: pack all partitions into one stacked
    shape-bucketed layout and peel them in ONE batched while_loop
    (:func:`_fd_tip_vmapped`).  Writes θ in place; returns the (B,)
    per-partition round counts (bit-identical to the per-partition
    drivers — same cascade, one dispatch).

    ``fused=True`` swaps the segment-sum round body for the fused
    ``kernels.fd_round`` launch over the stacked partition-local pair
    lists (:func:`_fd_tip_fused_impl`) — one Pallas call per round and
    nothing else."""
    if n_parts == 0:
        return np.zeros(0, dtype=np.int64)
    from .distributed import pack_fd_partitions_tip_csr

    packed = pack_fd_partitions_tip_csr(
        wed, pair_bf0, part, sup_init, n_parts, bucket=True, stacked=fused
    )
    cap = obs.fd_ring_cap()
    if fused:
        from repro.kernels import ops as kops

        if cap:
            theta_st, rounds, rings = _fd_tip_fused_rings(
                jnp.asarray(packed["st_pa"]), jnp.asarray(packed["st_pb"]),
                jnp.asarray(packed["st_bf"]), jnp.asarray(packed["mine"]),
                jnp.asarray(packed["sup0"]),
                interpret=kops.default_interpret(), ring_cap=cap,
            )
        else:
            theta_st, rounds = _fd_tip_fused(
                jnp.asarray(packed["st_pa"]), jnp.asarray(packed["st_pb"]),
                jnp.asarray(packed["st_bf"]), jnp.asarray(packed["mine"]),
                jnp.asarray(packed["sup0"]),
                interpret=kops.default_interpret(),
            )
    else:
        if cap:
            theta_st, rounds, _, rings = _fd_tip_vmapped_rings(
                jnp.asarray(packed["pa"]), jnp.asarray(packed["pb"]),
                jnp.asarray(packed["bf"]), jnp.asarray(packed["mine"]),
                jnp.asarray(packed["sup0"]), ring_cap=cap,
            )
        else:
            theta_st, rounds, _ = _fd_tip_vmapped(
                jnp.asarray(packed["pa"]), jnp.asarray(packed["pb"]),
                jnp.asarray(packed["bf"]), jnp.asarray(packed["mine"]),
                jnp.asarray(packed["sup0"]),
            )
    mm = packed["mine"]
    theta[packed["gids"][mm]] = np.asarray(theta_st).astype(np.int64)[mm]
    rounds_np = np.asarray(rounds).astype(np.int64)
    if cap:
        _drain_rings("fused" if fused else "vmapped",
                     list(range(rounds_np.size)), rounds_np.tolist(),
                     rings, cap, cumulative=fused)
    return rounds_np


def _wing_fd_vmapped_csr(
    wed: csr.Wedges,
    part: np.ndarray,
    sup_init: np.ndarray,
    theta: np.ndarray,
    n_parts: int,
    use_pallas: bool = False,
    fused: bool = False,
) -> Tuple[np.ndarray, int]:
    """Single-dispatch wing Phase 2 (see :func:`_tip_fd_vmapped_csr`).

    ``use_pallas`` swaps the vmapped segment-sum body for the blocked
    Pallas ``support_update`` kernel over the stacked slot layout
    (:func:`_fd_wing_vmapped_pallas`) — interpret mode off-TPU, θ and
    round/update counts parity-locked either way.  ``fused`` goes one
    further: the ENTIRE round body (k-advance + compaction + support
    update + loss scatter) is one ``kernels.fd_round`` launch
    (:func:`_fd_wing_fused_impl`).  Returns (rounds (B,), update
    count)."""
    if n_parts == 0:
        return np.zeros(0, dtype=np.int64), 0
    from .distributed import pack_fd_partitions_csr

    slotted = use_pallas or fused
    packed = pack_fd_partitions_csr(
        wed, part, sup_init, n_parts, bucket=True,
        flat=not slotted, slots=slotted,
    )
    cap = obs.fd_ring_cap()
    rings = None
    if slotted:
        from repro.kernels import ops as kops  # local: keep core light

        R, _ = packed["slot_sizes"]
        W0 = packed["W0"]
        W_rows = np.zeros((n_parts, R), dtype=np.int32)
        w = min(R, W0.shape[1])
        W_rows[:, :w] = W0[:, :w]
        if cap:
            body = (_fd_wing_fused_rings if fused
                    else _fd_wing_vmapped_pallas_rings)
            theta_st, rounds, nupd, rings = body(
                jnp.asarray(packed["slot_e1"]),
                jnp.asarray(packed["slot_e2"]),
                jnp.asarray(packed["slot_valid"]), jnp.asarray(W_rows),
                jnp.asarray(packed["mine"]), jnp.asarray(packed["sup0"]),
                interpret=kops.default_interpret(), ring_cap=cap,
            )
        else:
            body = _fd_wing_fused if fused else _fd_wing_vmapped_pallas
            theta_st, rounds, nupd = body(
                jnp.asarray(packed["slot_e1"]),
                jnp.asarray(packed["slot_e2"]),
                jnp.asarray(packed["slot_valid"]), jnp.asarray(W_rows),
                jnp.asarray(packed["mine"]), jnp.asarray(packed["sup0"]),
                interpret=kops.default_interpret(),
            )
    else:
        if cap:
            theta_st, rounds, nupd, rings = _fd_wing_vmapped_rings(
                jnp.asarray(packed["flat_we1"]),
                jnp.asarray(packed["flat_we2"]),
                jnp.asarray(packed["flat_wp"]),
                jnp.asarray(packed["flat_alive0"]),
                jnp.asarray(packed["flat_W0"]), jnp.asarray(packed["mine"]),
                jnp.asarray(packed["sup0"]),
                n_pairs=int(packed["flat_W0"].shape[0]), ring_cap=cap,
            )
        else:
            theta_st, rounds, nupd = _fd_wing_vmapped(
                jnp.asarray(packed["flat_we1"]),
                jnp.asarray(packed["flat_we2"]),
                jnp.asarray(packed["flat_wp"]),
                jnp.asarray(packed["flat_alive0"]),
                jnp.asarray(packed["flat_W0"]), jnp.asarray(packed["mine"]),
                jnp.asarray(packed["sup0"]),
                n_pairs=int(packed["flat_W0"].shape[0]),
            )
    mm = packed["mine"]
    theta[packed["gids"][mm]] = np.asarray(theta_st).astype(np.int64)[mm]
    rounds_np = np.asarray(rounds).astype(np.int64)
    if rings is not None:
        _drain_rings("fused" if fused else "vmapped",
                     list(range(rounds_np.size)), rounds_np.tolist(),
                     rings, cap, cumulative=fused)
    return rounds_np, int(nupd)


# =====================================================================
# Wing decomposition (edge peeling)
# =====================================================================
@partial(jax.jit, static_argnames=("shape",))
def _wing_recount(shape, edges: jax.Array, alive_e: jax.Array) -> jax.Array:
    A = counting.masked_adjacency(shape, edges, alive_e)
    return counting.edge_butterflies(A, edges)


def _wing_links(be: BEIndex):
    return (
        jnp.asarray(be.link_edge),
        jnp.asarray(be.link_twin),
        jnp.asarray(be.link_bloom),
    )


@partial(jax.jit, static_argnames=("nb", "m"))
def _wing_update(
    peeled_e: jax.Array,
    alive_link: jax.Array,
    k_alive: jax.Array,
    support: jax.Array,
    le: jax.Array,
    lt: jax.Array,
    lb: jax.Array,
    nb: int,
    m: int,
):
    """Batched BE-Index support update (alg.6 exact semantics).

    Bloom bookkeeping: a twin *pair* dies when either member is peeled.
    Dying-pair survivors (widows) lose every butterfly they had in the
    bloom (k_alive − 1); edges of surviving pairs lose one butterfly per
    dying pair (c_B).  ``segment_sum`` replaces the paper's atomics.
    """
    pe = peeled_e[le]
    pt = peeled_e[lt]
    pair_dies = alive_link & (pe | pt)
    canon = le < lt
    c = jax.ops.segment_sum(
        (pair_dies & canon).astype(jnp.int32), lb, num_segments=nb
    )
    widow = alive_link & ~pe & pt
    surv = alive_link & ~pair_dies
    contrib = jnp.where(widow, k_alive[lb] - 1, 0) + jnp.where(
        surv, c[lb], 0
    )
    loss = jax.ops.segment_sum(contrib, le, num_segments=m)
    support = support - loss
    k_alive = k_alive - c
    alive_link = alive_link & ~pair_dies
    n_updates = jnp.sum(widow.astype(jnp.int32)) + jnp.sum(
        (surv & (c[lb] > 0)).astype(jnp.int32)
    )
    return alive_link, k_alive, support, n_updates


def wing_decomposition(
    g: BipartiteGraph,
    P: int = 16,
    engine: str = "beindex",
    be: Optional[BEIndex] = None,
    fd_driver: str = "device",
    use_pallas: bool = False,
    fused: bool = False,
    sup0: Optional[np.ndarray] = None,
) -> PeelResult:
    """PBNG wing decomposition (§3.3) — θ per edge.

    ``engine``/``fd_driver`` matrix (all combinations θ-bit-identical):

    ========  =====================================  ====================
    engine    support counting / update              fd_driver
    ========  =====================================  ====================
    beindex   BE-Index widow/survivor (alg. 4/6)     (host cascade)
    dense     masked MXU matmul re-counts, O(n²)     (host cascade)
    csr       incremental wedge-list updates         device │ vmapped │ host
    ========  =====================================  ====================

    Example::

        from repro.core import random_bipartite, wing_decomposition
        g = random_bipartite(1000, 800, 8000, seed=0)
        res = wing_decomposition(g, engine="csr", fd_driver="vmapped")
        print(res.theta.max(), res.stats.sync_reduction)

    ``engine`` ∈ {"beindex", "dense", "csr"}: BE-Index incremental
    updates, masked-matmul re-counts, or sparse wedge-list incremental
    updates (``core.csr`` — the scalable path).

    ``fd_driver`` (csr engine only): ``"device"`` (default) peels each FD
    partition in one ``lax.while_loop`` dispatch; ``"vmapped"`` stacks
    ALL partitions into one shape-bucketed layout and runs the whole
    Phase 2 as ONE batched while_loop — a single dispatch total, the
    paper's "no global synchronization" stated structurally for the
    entire fine-grained phase; ``"host"`` keeps the per-round python
    loop as an A/B baseline.  All drivers produce bit-identical θ and
    identical per-partition round/update counts.

    ``use_pallas`` (csr engine only): run CD support updates through the
    blocked ``kernels.support_update`` Pallas kernel on the pairs-major
    slot layout (interpret mode off-TPU) instead of flat segment_sums.
    With ``fd_driver="vmapped"`` the same kernel also runs INSIDE the FD
    while_loop body over the stacked partition slot layout (one kernel
    launch per round covering every partition).

    ``fused`` (csr engine, device/vmapped drivers): fuse the ENTIRE FD
    round body — k-advance, frontier compaction, widow/survivor support
    update and loss scatter — into one ``kernels.fd_round`` Pallas
    launch, so a round is a single kernel dispatch and nothing else.  θ
    and round/update counts bit-identical to the unfused drivers."""
    stats = PeelStats(
        engine=engine,
        fd_driver=fd_driver if engine == "csr" else "host",
    )
    spec = build_peel_spec(
        g, "wing", stats, engine=engine, be=be, fd_driver=fd_driver,
        use_pallas=use_pallas, fused=fused, sup0=sup0)
    return peelspec.decompose(spec, P, stats, fd_driver=fd_driver)


def _wing_workload_est():
    """Wing's range/estimate weights: workload proxy for edges = current
    support (§3.3.2); partition estimates read the same supports."""
    return (lambda s: np.maximum(s, 1), lambda s: s)


def _wing_spec_beindex(
    g: BipartiteGraph, be: Optional[BEIndex], stats: PeelStats
) -> PeelSpec:
    """BE-Index wing spec: alg.4/6 widow/survivor updates as the CD
    step, link-packed sub-indices (alg.5) as the FD rule."""
    m = g.m
    if be is None:
        be = build_beindex(g)
    le, lt, lb = _wing_links(be)
    nb = max(be.nb, 1)
    state = dict(
        alive_link=jnp.ones((be.n_links,), dtype=bool),
        k_alive=jnp.asarray(be.bloom_k.astype(np.int32)),
        support=jnp.asarray(be.edge_support(m).astype(np.int32)),
    )
    sup0 = np.rint(np.asarray(state["support"])).astype(np.int64)

    def cd_step(active: np.ndarray) -> np.ndarray:
        state["alive_link"], state["k_alive"], state["support"], nupd = (
            _wing_update(
                jnp.asarray(active), state["alive_link"], state["k_alive"],
                state["support"], le, lt, lb, nb, m,
            )
        )
        stats.updates += int(nupd)
        return np.rint(np.asarray(state["support"])).astype(np.int64)

    def fd_partition(i, part, sup_init, theta, fd_driver):
        rounds, nupd = _wing_fd_beindex(g, be, part, i, sup_init, theta)
        return rounds, nupd, 0

    workload, est = _wing_workload_est()
    return PeelSpec(
        kind="wing", n=m, sup0=sup0, workload=workload, est=est,
        cd_step=cd_step, fd_partition=fd_partition,
    )


def _wing_spec_dense(
    g: BipartiteGraph, stats: PeelStats,
    sup0: Optional[np.ndarray] = None,
) -> PeelSpec:
    """Dense wing spec: masked-MXU batch re-counts for both phases."""
    m = g.m
    _dense_guard(g.n_u, g.n_v)
    edges = jnp.asarray(g.edges.astype(np.int32))
    shape = (g.n_u, g.n_v)
    if sup0 is None:
        support = _wing_recount(shape, edges, jnp.ones((m,), dtype=bool))
        counting.assert_exact(support)
        sup0 = np.rint(np.asarray(support)).astype(np.int64)
    else:
        sup0 = np.asarray(sup0, dtype=np.int64)
    state = dict(alive=np.ones(m, dtype=bool))

    def cd_step(active: np.ndarray) -> np.ndarray:
        state["alive"] &= ~active
        sup = _wing_recount(shape, edges, jnp.asarray(state["alive"]))
        stats.recounts += 1
        return np.rint(np.asarray(sup)).astype(np.int64)

    def fd_partition(i, part, sup_init, theta, fd_driver):
        rounds, nrec = _wing_fd_dense(g, part, i, sup_init, theta)
        return rounds, 0, nrec

    workload, est = _wing_workload_est()
    return PeelSpec(
        kind="wing", n=m, sup0=sup0, workload=workload, est=est,
        cd_step=cd_step, fd_partition=fd_partition,
    )


def _wing_spec_csr(
    g: BipartiteGraph, stats: PeelStats, use_pallas: bool = False,
    fused: bool = False, sup0: Optional[np.ndarray] = None,
    wed: Optional[csr.Wedges] = None,
) -> PeelSpec:
    """csr wing spec: incremental wedge-list widow/survivor updates as
    the CD step (optionally through the blocked Pallas kernel on the
    pairs-major slot layout), touching-wedge packed lists as the FD
    rule.  ``fused`` routes the FD phase through the fused
    ``kernels.fd_round`` launch (see :func:`_fd_wing_fused_impl`)."""
    m = g.m
    if wed is None:
        wed = csr.build_wedges(g)
    we1 = jnp.asarray(wed.wedge_e1)
    we2 = jnp.asarray(wed.wedge_e2)
    wpj = jnp.asarray(wed.wedge_pair)
    n_pairs = wed.n_pairs
    sup0 = (csr.edge_butterflies0(wed) if sup0 is None
            else np.asarray(sup0, dtype=np.int64))
    if sup0.size and int(sup0.max()) > 2 ** 31 - 1:
        raise OverflowError("wing supports exceed int32; shard the graph")
    state = dict(
        alive_w=jnp.ones((wed.n_wedges,), dtype=bool),
        Wp=csr.pair_wedge_counts(wed),
        support=jnp.asarray(sup0.astype(np.int32)),
    )
    if use_pallas:
        slots = csr.pack_update_slots(wed)
        state["alive_slots"] = jnp.asarray(slots["valid"])
        slot_e1 = jnp.asarray(slots["e1"])
        slot_e2 = jnp.asarray(slots["e2"])

    def cd_step(active: np.ndarray) -> np.ndarray:
        if use_pallas:
            state["alive_slots"], state["Wp"], state["support"], nupd = (
                csr.wing_update_slots(
                    jnp.asarray(active), state["alive_slots"], state["Wp"],
                    state["support"], slot_e1, slot_e2, n_pairs, m,
                )
            )
        else:
            state["alive_w"], state["Wp"], state["support"], nupd = (
                csr.wing_update_csr(
                    jnp.asarray(active), state["alive_w"], state["Wp"],
                    state["support"], we1, we2, wpj, n_pairs, m,
                )
            )
        stats.updates += int(nupd)
        return np.rint(np.asarray(state["support"])).astype(np.int64)

    # fused device driver: one lazy pack of the full partition stack,
    # each partition sliced as a B=1 batch into the shared jitted fused
    # entry (same bucketed shapes → one compile for all partitions)
    fused_pack: dict = {}

    def fd_partition(i, part, sup_init, theta, fd_driver):
        if fused and fd_driver == "device":
            from repro.kernels import ops as kops

            if "p" not in fused_pack:
                from .distributed import pack_fd_partitions_csr

                n_parts = int(part.max()) + 1 if part.size else 0
                p = pack_fd_partitions_csr(
                    wed, part, sup_init, n_parts, bucket=True, slots=True)
                R, _ = p["slot_sizes"]
                W_rows = np.zeros((n_parts, R), dtype=np.int32)
                w = min(R, p["W0"].shape[1])
                W_rows[:, :w] = p["W0"][:, :w]
                p["W_rows"] = W_rows
                fused_pack["p"] = p
            p = fused_pack["p"]
            f_args = (
                jnp.asarray(p["slot_e1"][i:i + 1]),
                jnp.asarray(p["slot_e2"][i:i + 1]),
                jnp.asarray(p["slot_valid"][i:i + 1]),
                jnp.asarray(p["W_rows"][i:i + 1]),
                jnp.asarray(p["mine"][i:i + 1]),
                jnp.asarray(p["sup0"][i:i + 1]),
            )
            cap = obs.fd_ring_cap()
            if cap:
                theta_st, rounds, nupd, rings = _fd_wing_fused_rings(
                    *f_args, interpret=kops.default_interpret(),
                    ring_cap=cap)
                _drain_rings("fused", [i], [int(rounds[0])], rings, cap,
                             cumulative=True)
            else:
                theta_st, rounds, nupd = _fd_wing_fused(
                    *f_args, interpret=kops.default_interpret())
            mm = p["mine"][i]
            theta[p["gids"][i][mm]] = (
                np.asarray(theta_st[0]).astype(np.int64)[mm])
            return int(rounds[0]), int(nupd), 0
        rounds, nupd = _wing_fd_csr(
            wed, part, i, sup_init, theta, fd_driver=fd_driver)
        return rounds, nupd, 0

    def fd_vmapped(part, sup_init, theta, n_parts):
        return _wing_fd_vmapped_csr(
            wed, part, sup_init, theta, n_parts, use_pallas=use_pallas,
            fused=fused)

    workload, est = _wing_workload_est()
    return PeelSpec(
        kind="wing", n=m, sup0=sup0, workload=workload, est=est,
        cd_step=cd_step, fd_partition=fd_partition, fd_vmapped=fd_vmapped,
    )


def _wing_fd_dense(
    g: BipartiteGraph,
    part: np.ndarray,
    i: int,
    sup_init: np.ndarray,
    theta: np.ndarray,
) -> Tuple[int, int]:
    """FD for partition i, dense engine: peel E_i inside the ≥i subgraph,
    re-counting supports on the masked adjacency each round."""
    sel = np.where(part >= i)[0]
    mine = part[sel] == i
    if not mine.any():
        return 0, 0
    sub_edges = jnp.asarray(g.edges[sel].astype(np.int32))
    shape = (g.n_u, g.n_v)

    alive = np.ones(sel.size, dtype=bool)
    support = sup_init[sel].astype(np.int64).copy()
    col = obs.active_collector()
    trows: list = []
    k = 0
    rounds = 0
    recounts = 0
    while (alive & mine).any():
        k = max(k, int(support[alive & mine].min()))
        while True:
            S = alive & mine & (support <= k)
            if not S.any():
                break
            theta[sel[S]] = k
            alive &= ~S
            sup = _wing_recount(shape, sub_edges, jnp.asarray(alive))
            recounts += 1
            support = np.rint(np.asarray(sup)).astype(np.int64)
            rounds += 1
            if col is not None:
                trows.append(dict(k=k, died=int(S.sum()),
                                  frontier=int((alive & mine).sum())))
    if col is not None:
        col.record_fd_host(int(i), trows)
    return rounds, recounts


def _wing_fd_csr(
    wed: csr.Wedges,
    part: np.ndarray,
    i: int,
    sup_init: np.ndarray,
    theta: np.ndarray,
    fd_driver: str = "device",
) -> Tuple[int, int]:
    """FD for partition i, csr engine.

    Sub-structure = the ≥i induced subgraph (the same one the dense FD
    re-counts on): per-pair alive counts W_p are re-derived over ALL ≥i
    wedges, but the wedge *list* carries only the wedges touching
    partition i — later-partition-only wedges never die during FD_i and
    their survivor charges land on edges whose deltas are discarded
    anyway (their FD runs from its own ⋈init snapshot).

    ``fd_driver="device"`` (default) runs the whole cascade in one
    ``lax.while_loop`` (:func:`_fd_wing_device`); ``"host"`` keeps the
    per-round dispatch loop (the PR-1 baseline, benchmarked against).
    """
    mine = part == i
    if not mine.any():
        return 0, 0
    m = part.size
    n_pairs = wed.n_pairs
    if wed.n_wedges:
        p1 = part[wed.wedge_e1]
        p2 = part[wed.wedge_e2]
        keep_ge = (p1 >= i) & (p2 >= i)
        # only wedges TOUCHING partition i can die during FD_i; the
        # untouched ≥i wedges stay alive all phase and their survivor
        # charges land on discarded later-partition edges — fold them
        # into the static W_p init instead of carrying them (exact; see
        # distributed.pack_fd_partitions_csr)
        keep = keep_ge & (np.minimum(p1, p2) == i)
    else:
        keep_ge = keep = np.zeros(0, bool)
    Wp = jnp.asarray(
        np.bincount(
            wed.wedge_pair[keep_ge], minlength=max(n_pairs, 1)
        ).astype(np.int32)
    )

    support_full = np.zeros(m, dtype=np.int64)
    support_full[mine] = sup_init[mine]

    cap = obs.fd_ring_cap()
    if fd_driver == "device":
        # bucket-pad the wedge arrays (dead zero wedges are inert) so
        # the while_loop compiles once per size bucket
        n_kept = int(keep.sum())
        size = _bucket_pad(n_kept)
        alive_w = np.zeros(size, dtype=bool)
        alive_w[:n_kept] = True
        args = (
            jnp.asarray(mine), jnp.asarray(support_full.astype(np.int32)),
            jnp.asarray(alive_w), Wp,
            jnp.asarray(_pad_zeros(wed.wedge_e1[keep], size)),
            jnp.asarray(_pad_zeros(wed.wedge_e2[keep], size)),
            jnp.asarray(_pad_zeros(wed.wedge_pair[keep], size)),
            n_pairs, m,
        )
        if cap:
            theta_d, rounds, nupd, rings = _fd_wing_device_rings(
                *args, ring_cap=cap)
            _drain_rings("device", [i], [int(rounds)], rings, cap)
        else:
            theta_d, rounds, nupd = _fd_wing_device(*args)
        theta_np = np.asarray(theta_d).astype(np.int64)
        theta[mine] = theta_np[mine]
        return int(rounds), int(nupd)

    kwe1 = jnp.asarray(wed.wedge_e1[keep])
    kwe2 = jnp.asarray(wed.wedge_e2[keep])
    kwp = jnp.asarray(wed.wedge_pair[keep])
    alive_w = jnp.ones((int(keep.sum()),), dtype=bool)

    support = jnp.asarray(support_full.astype(np.int32))
    nupd = 0

    def peel(S, sup):
        nonlocal alive_w, Wp, support, nupd
        alive_w, Wp, support, nu = csr.wing_update_csr(
            jnp.asarray(S), alive_w, Wp, support,
            kwe1, kwe2, kwp, n_pairs, m,
        )
        nupd += int(nu)
        return np.asarray(support).astype(np.int64)

    col = obs.active_collector()
    if col is None:
        rounds = _fd_cascade(mine, support_full, theta, peel)
        return rounds, nupd
    rows: list = []
    upds: list = []
    last = dict(n=0)

    def on_round(k, died, frontier):
        rows.append(dict(k=k, died=died, frontier=frontier))
        upds.append(nupd - last["n"])
        last["n"] = nupd

    rounds = _fd_cascade(mine, support_full, theta, peel,
                         on_round=on_round)
    col.record_fd_host(i, rows, updates=upds)
    return rounds, nupd


def _wing_fd_beindex(
    g: BipartiteGraph,
    be: BEIndex,
    part: np.ndarray,
    i: int,
    sup_init: np.ndarray,
    theta: np.ndarray,
) -> Tuple[int, int]:
    """FD for partition i, BE-Index engine (alg.5 semantics).

    Sub-index = links whose pair touches partition i with both members in
    partitions ≥ i; bloom numbers initialised to the count of pairs with
    both members ≥ i (alg.5 lines 21-24).
    """
    ple = part[be.link_edge]
    plt_ = part[be.link_twin]
    pair_min = np.minimum(ple, plt_)
    pair_ge = (ple >= i) & (plt_ >= i)
    keep = pair_ge & (pair_min == i)          # pairs that can die in FD_i
    if not keep.any():
        return 0, 0

    canon_full = be.link_edge < be.link_twin
    # bloom number in I_i: pairs with both members ≥ i
    k_init = np.zeros(be.nb, dtype=np.int64)
    np.add.at(k_init, be.link_bloom[pair_ge & canon_full], 1)

    le = jnp.asarray(be.link_edge[keep])
    lt = jnp.asarray(be.link_twin[keep])
    lb = jnp.asarray(be.link_bloom[keep])
    nb = max(be.nb, 1)
    m = g.m

    alive_link = jnp.ones((int(keep.sum()),), dtype=bool)
    k_alive = jnp.asarray(k_init.astype(np.int32))
    support_full = np.zeros(m, dtype=np.int64)
    mine_idx = np.where(part == i)[0]
    support_full[mine_idx] = sup_init[mine_idx]
    support = jnp.asarray(support_full.astype(np.int32))

    mine = part == i
    nupd = 0

    def peel(S, sup):
        nonlocal alive_link, k_alive, support, nupd
        alive_link, k_alive, support, nu = _wing_update(
            jnp.asarray(S), alive_link, k_alive, support,
            le, lt, lb, nb, m,
        )
        nupd += int(nu)
        return np.asarray(support).astype(np.int64)

    col = obs.active_collector()
    if col is None:
        rounds = _fd_cascade(mine, support_full.copy(), theta, peel)
        return rounds, nupd
    rows: list = []
    upds: list = []
    last = dict(n=0)

    def on_round(k, died, frontier):
        rows.append(dict(k=k, died=died, frontier=frontier))
        upds.append(nupd - last["n"])
        last["n"] = nupd

    rounds = _fd_cascade(mine, support_full.copy(), theta, peel,
                         on_round=on_round)
    col.record_fd_host(i, rows, updates=upds)
    return rounds, nupd


# =====================================================================
# Baseline: level-synchronous bottom-up peeling round count
# =====================================================================
def bup_levels(theta: np.ndarray) -> int:
    """Number of peeling iterations a level-by-level parallel BUP
    (ParButterfly) needs — its synchronization count ρ (paper footnote 6
    approximates this by FD round counts; exact value = Σ over levels of
    cascade rounds, lower-bounded by #distinct levels)."""
    return int(np.unique(theta).size)


# =====================================================================
# Baseline: BE_PC — progressive-compression peeling (Wang et al. [67])
# =====================================================================
def wing_decomposition_bepc(
    g: BipartiteGraph, tau: float = 0.25
) -> Tuple[np.ndarray, PeelStats]:
    """Top-down progressive compression (the paper's strongest baseline,
    table 3's BE_PC row).

    Descending support thresholds t: extract the maximal subgraph whose
    edges keep ≥ t butterflies (a t-wing superset — everything with
    θ ≥ t), resolve it by bottom-up peeling *within the subgraph*, then
    move down.  High-θ edges never receive updates from low-θ peels —
    the mechanism that made BE_PC state-of-the-art pre-PBNG.

    Dense-recount formulation; exact vs the oracle (tests).
    """
    m = g.m
    edges = jnp.asarray(g.edges.astype(np.int32))
    shape = (g.n_u, g.n_v)
    stats = PeelStats()

    def recount(mask: np.ndarray) -> np.ndarray:
        stats.recounts += 1
        sup = _wing_recount(shape, edges, jnp.asarray(mask))
        return np.rint(np.asarray(sup)).astype(np.int64)

    theta = np.zeros(m, dtype=np.int64)
    resolved = np.zeros(m, dtype=bool)
    sup0 = recount(np.ones(m, bool))
    t = max(int(sup0.max()), 1)
    thresholds = []
    while t > 1:
        thresholds.append(t)
        t = max(1, int(t * tau))
    thresholds.append(1)

    for t in thresholds:
        # ---- candidate core: unresolved edges keeping >= t butterflies
        core = ~resolved
        while True:
            sup = recount(core | resolved)
            bad = core & (sup < t)
            if not bad.any():
                break
            core &= ~bad
        if not core.any():
            continue
        # ---- resolve θ for the core by bottom-up peeling inside
        #      (core ∪ resolved); resolved edges are never peeled
        alive = core | resolved
        peelable = core.copy()
        sup = recount(alive)
        k = t
        while peelable.any():
            k = max(k, int(sup[peelable].min()))
            while True:
                S = peelable & (sup <= k)
                if not S.any():
                    break
                theta[S] = k
                alive &= ~S
                peelable &= ~S
                sup = recount(alive)
                stats.rho_fd_total += 1
        resolved |= core

    theta[~resolved] = 0  # butterfly-free edges
    return theta, stats
