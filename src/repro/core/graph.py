"""Bipartite graph container used by every PBNG engine.

The paper's graphs are CSR adjacency lists mutated in place; XLA needs
static shapes, so we carry immutable edge lists + CSR offsets built host
side (numpy) and express deletion with boolean ``alive`` masks on device.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "BipartiteGraph",
    "random_bipartite",
    "powerlaw_bipartite",
    "paper_proxy_dataset",
    "PAPER_PROXIES",
]


@dataclasses.dataclass(frozen=True)
class BipartiteGraph:
    """Static bipartite graph ``G(U, V, E)``.

    Attributes
    ----------
    n_u, n_v : sizes of the two vertex sets.
    edges    : (m, 2) int32 array of (u, v) pairs, deduplicated,
               sorted lexicographically.  ``edges[:, 0] in [0, n_u)``,
               ``edges[:, 1] in [0, n_v)``.
    """

    n_u: int
    n_v: int
    edges: np.ndarray  # (m, 2) int32

    # ---------------------------------------------------------------- basic
    @property
    def m(self) -> int:
        """Edge count |E|."""
        return int(self.edges.shape[0])

    @property
    def n(self) -> int:
        """Combined vertex count |U| + |V|."""
        return self.n_u + self.n_v

    def degrees(self) -> Tuple[np.ndarray, np.ndarray]:
        """(d_u, d_v) int64 degree vectors."""
        du = np.bincount(self.edges[:, 0], minlength=self.n_u)
        dv = np.bincount(self.edges[:, 1], minlength=self.n_v)
        return du.astype(np.int64), dv.astype(np.int64)

    # ----------------------------------------------------------------- CSR
    def csr_u(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-U CSR: (offsets[n_u+1], neighbor v ids, edge ids)."""
        order = np.lexsort((self.edges[:, 1], self.edges[:, 0]))
        e = self.edges[order]
        du, _ = self.degrees()
        off = np.zeros(self.n_u + 1, dtype=np.int64)
        np.cumsum(du, out=off[1:])
        return off, e[:, 1].astype(np.int32), order.astype(np.int32)

    def csr_v(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-V CSR: (offsets[n_v+1], neighbor u ids, edge ids)."""
        order = np.lexsort((self.edges[:, 0], self.edges[:, 1]))
        e = self.edges[order]
        _, dv = self.degrees()
        off = np.zeros(self.n_v + 1, dtype=np.int64)
        np.cumsum(dv, out=off[1:])
        return off, e[:, 0].astype(np.int32), order.astype(np.int32)

    # --------------------------------------------------------------- dense
    def adjacency(self, dtype=np.float32) -> np.ndarray:
        """Dense (n_u, n_v) adjacency — the MXU-friendly representation."""
        A = np.zeros((self.n_u, self.n_v), dtype=dtype)
        A[self.edges[:, 0], self.edges[:, 1]] = 1
        return A

    def transpose(self) -> "BipartiteGraph":
        """Swap U and V (tip decomposition of the V side peels the
        transpose's U side)."""
        e = self.edges[:, ::-1].copy()
        order = np.lexsort((e[:, 1], e[:, 0]))
        return BipartiteGraph(self.n_v, self.n_u, e[order])

    # --------------------------------------------------------------- build
    @staticmethod
    def from_edges(n_u: int, n_v: int, edges) -> "BipartiteGraph":
        """Canonical constructor: dedup + lexsort + bounds-check edges."""
        e = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
        if e.size:
            e = np.unique(e, axis=0)
            assert e[:, 0].min() >= 0 and e[:, 0].max() < n_u, "u id out of range"
            assert e[:, 1].min() >= 0 and e[:, 1].max() < n_v, "v id out of range"
        return BipartiteGraph(int(n_u), int(n_v), e)


# -------------------------------------------------------------- generators
def random_bipartite(
    n_u: int, n_v: int, m: int, seed: int = 0
) -> BipartiteGraph:
    """Erdos–Renyi-style bipartite graph with ~m distinct edges."""
    rng = np.random.default_rng(seed)
    m = min(m, n_u * n_v)
    u = rng.integers(0, n_u, size=2 * m + 8)
    v = rng.integers(0, n_v, size=2 * m + 8)
    e = np.unique(np.stack([u, v], axis=1), axis=0)
    if e.shape[0] > m:
        sel = rng.choice(e.shape[0], size=m, replace=False)
        e = e[np.sort(sel)]
    return BipartiteGraph.from_edges(n_u, n_v, e)


def powerlaw_bipartite(
    n_u: int, n_v: int, m: int, alpha: float = 1.3, seed: int = 0
) -> BipartiteGraph:
    """Skewed-degree bipartite graph (preferential attachment flavour).

    Real datasets in the paper (trackers, orkut, wikipedia) are heavily
    skewed; butterfly counts explode super-linearly with skew, which is
    the regime PBNG targets.
    """
    rng = np.random.default_rng(seed)
    pu = (np.arange(1, n_u + 1, dtype=np.float64)) ** (-alpha)
    pv = (np.arange(1, n_v + 1, dtype=np.float64)) ** (-alpha)
    pu /= pu.sum()
    pv /= pv.sum()
    u = rng.choice(n_u, size=3 * m, p=pu)
    v = rng.choice(n_v, size=3 * m, p=pv)
    e = np.unique(np.stack([u, v], axis=1), axis=0)
    if e.shape[0] > m:
        sel = rng.choice(e.shape[0], size=m, replace=False)
        e = e[np.sort(sel)]
    return BipartiteGraph.from_edges(n_u, n_v, e)


# Laptop-scale stand-ins for the paper's table-2 datasets.  Name -> kwargs.
PAPER_PROXIES = {
    # name          n_u    n_v     m      alpha  seed
    "di_af":   dict(n_u=700, n_v=120, m=2200, alpha=1.10, seed=1),
    "de_ti":   dict(n_u=900, n_v=160, m=3200, alpha=1.20, seed=2),
    "fr":      dict(n_u=260, n_v=380, m=2600, alpha=1.25, seed=3),
    "di_st":   dict(n_u=800, n_v=48,  m=2800, alpha=1.05, seed=4),
    "it":      dict(n_u=900, n_v=220, m=3600, alpha=1.30, seed=5),
    "digg":    dict(n_u=600, n_v=64,  m=4200, alpha=1.15, seed=6),
    "en":      dict(n_u=1400, n_v=420, m=5200, alpha=1.30, seed=7),
    "lj":      dict(n_u=1100, n_v=900, m=5600, alpha=1.35, seed=8),
    "gtr":     dict(n_u=520, n_v=760, m=6400, alpha=1.20, seed=9),
    "tr":      dict(n_u=1600, n_v=900, m=7000, alpha=1.45, seed=10),
    "or_":     dict(n_u=900, n_v=1600, m=8000, alpha=1.30, seed=11),
    "de_ut":   dict(n_u=1000, n_v=420, m=6000, alpha=1.25, seed=12),
}


def paper_proxy_dataset(name: str) -> BipartiteGraph:
    """Scaled-down synthetic proxy for a paper dataset (same skew regime)."""
    kw = PAPER_PROXIES[name]
    return powerlaw_bipartite(**kw)


def from_tsv(path: str, comment: str = "%") -> BipartiteGraph:
    """Load a KONECT-style bipartite edge list (u<TAB>v per line, 1-based
    or 0-based ids; comment lines start with '%').  Ids are compacted."""
    us, vs = [], []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(comment):
                continue
            parts = line.split()
            us.append(int(parts[0]))
            vs.append(int(parts[1]))
    u = np.asarray(us, dtype=np.int64)
    v = np.asarray(vs, dtype=np.int64)
    _, u = np.unique(u, return_inverse=True)
    _, v = np.unique(v, return_inverse=True)
    return BipartiteGraph.from_edges(
        int(u.max()) + 1 if u.size else 0,
        int(v.max()) + 1 if v.size else 0,
        np.stack([u, v], axis=1),
    )
