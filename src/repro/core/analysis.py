"""Applications of PBNG inside an LM system (DESIGN.md §4).

* ``moe_affinity``  — tip-decompose the token×expert routing graph of a
  mixture-of-experts layer: experts with high tip numbers form densely
  co-activated groups (candidates for co-location on a device).
* ``interaction_curriculum`` — wing-decompose a user×item graph and bucket
  edges by wing-number level: a dense-subgraph curriculum for
  link-prediction training data (the paper's e-commerce use case).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .graph import BipartiteGraph
from .peel import tip_decomposition, wing_decomposition

__all__ = ["moe_affinity", "interaction_curriculum", "routing_graph"]


def routing_graph(assignments: np.ndarray, n_experts: int) -> BipartiteGraph:
    """Token×expert bipartite graph from a router's top-k assignments.

    assignments: (tokens, k) int expert ids.
    """
    t = np.repeat(np.arange(assignments.shape[0]), assignments.shape[1])
    e = assignments.reshape(-1)
    return BipartiteGraph.from_edges(
        int(assignments.shape[0]), int(n_experts), np.stack([t, e], axis=1)
    )


def moe_affinity(
    assignments: np.ndarray, n_experts: int, P: int = 8
) -> np.ndarray:
    """Per-expert tip numbers of the routing graph.

    High tip number ⇔ the expert participates in many butterflies ⇔ it is
    frequently co-activated with other experts on shared tokens.  Experts
    in the same high-k tip are good candidates for the same EP shard.
    """
    g = routing_graph(assignments, n_experts)
    return tip_decomposition(g, side="v", P=P).theta


def interaction_curriculum(
    g: BipartiteGraph, n_levels: int = 4, P: int = 8
) -> Tuple[np.ndarray, np.ndarray]:
    """Bucket edges into ``n_levels`` density levels by wing number.

    Returns (level per edge, level boundaries).  Level n_levels−1 is the
    densest community core — the curriculum feeds dense levels first for
    link-prediction pretraining (paper §1 applications).
    """
    theta = wing_decomposition(g, P=P, engine="beindex").theta
    qs = np.quantile(theta, np.linspace(0, 1, n_levels + 1)[1:-1])
    bounds = np.unique(np.concatenate([[0], qs, [theta.max() + 1]]))
    level = np.clip(np.searchsorted(bounds, theta, side="right") - 1, 0,
                    n_levels - 1)
    return level.astype(np.int32), bounds.astype(np.int64)
