"""PBNG core — parallel peeling of bipartite networks (the paper's contribution).

Public API:
    BipartiteGraph, random_bipartite, powerlaw_bipartite, paper_proxy_dataset
    build_beindex, BEIndex
    tip_decomposition, wing_decomposition      (two-phased PBNG)
    PeelSpec, decompose (core.peelspec)        (entity-agnostic core)
    distributed_tip_decomposition,
    distributed_wing_decomposition             (shard_map, multi-device)
    ref                                        (pure-python oracles)
"""
from .graph import (
    BipartiteGraph,
    from_tsv,
    random_bipartite,
    powerlaw_bipartite,
    paper_proxy_dataset,
    PAPER_PROXIES,
)
from .beindex import BEIndex, build_beindex
from .peel import (
    PeelResult,
    PeelStats,
    tip_decomposition,
    wing_decomposition,
    wing_decomposition_bepc,
    bup_levels,
)
from .peelspec import PeelSpec
from . import peelspec
from .distributed import (
    distributed_tip_decomposition,
    distributed_wing_decomposition,
)
from . import counting, csr, ref

__all__ = [
    "csr",
    "BipartiteGraph",
    "random_bipartite",
    "powerlaw_bipartite",
    "paper_proxy_dataset",
    "PAPER_PROXIES",
    "BEIndex",
    "build_beindex",
    "PeelResult",
    "PeelStats",
    "PeelSpec",
    "peelspec",
    "tip_decomposition",
    "wing_decomposition",
    "bup_levels",
    "wing_decomposition_bepc",
    "from_tsv",
    "distributed_tip_decomposition",
    "distributed_wing_decomposition",
    "counting",
    "ref",
]
