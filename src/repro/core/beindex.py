"""Bloom-Edge-Index (BE-Index, §2.3) — paper-faithful butterfly index.

A *maximal priority bloom* is a (2,k)-biclique whose dominant 2-vertex set
contains the bloom's highest-priority vertex (priority = decreasing degree
over the combined vertex set, ties by id).  Every butterfly lives in
exactly one bloom (property 2); an edge shares k−1 butterflies with its
twin and 1 with every other bloom edge (property 1).

Construction happens host-side in numpy (it is a data-pipeline step, like
tokenization); peeling consumes the flat arrays on device via
``jax.ops.segment_sum`` — the TPU replacement for the paper's atomics.

Flat layout (all int32):
    bloom_k[nb]       initial bloom number (alive twin pairs)
    link_edge[L]      link -> edge id          (CSR grouped by bloom)
    link_twin[L]      link -> twin edge id
    link_bloom[L]     link -> bloom id
Each twin *pair* contributes two links (e, t) and (t, e).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Tuple

import numpy as np

from .graph import BipartiteGraph

__all__ = ["BEIndex", "build_beindex"]


@dataclasses.dataclass(frozen=True)
class BEIndex:
    """Flat Bloom-Edge-Index (§2.3): every (edge, twin) pair of every
    maximal priority bloom as parallel link arrays — the paper's
    pointer-based index rebuilt as segment-sum-able flat storage."""

    nb: int
    bloom_k: np.ndarray    # (nb,) int32 — #twin pairs per bloom
    link_edge: np.ndarray  # (L,) int32
    link_twin: np.ndarray  # (L,) int32
    link_bloom: np.ndarray  # (L,) int32

    @property
    def n_links(self) -> int:
        """Number of (edge, twin, bloom) links in the index."""
        return int(self.link_edge.shape[0])

    def total_butterflies(self) -> int:
        """⋈(G) = Σ_B C(k_B, 2) — every butterfly sits in one bloom."""
        k = self.bloom_k.astype(np.int64)
        return int((k * (k - 1) // 2).sum())

    def edge_support(self, m: int) -> np.ndarray:
        """⋈_e = Σ_{B∋e} (k_B − 1) — support init straight from the index."""
        out = np.zeros(m, dtype=np.int64)
        np.add.at(out, self.link_edge, self.bloom_k[self.link_bloom].astype(np.int64) - 1)
        return out


def _priority_labels(g: BipartiteGraph) -> np.ndarray:
    """Combined-vertex labels: 0 = highest degree (highest priority)."""
    du, dv = g.degrees()
    deg = np.concatenate([du, dv])
    order = np.lexsort((np.arange(deg.size), -deg))
    labels = np.empty(deg.size, dtype=np.int64)
    labels[order] = np.arange(deg.size)
    return labels


def build_beindex(g: BipartiteGraph) -> BEIndex:
    """Enumerate maximal priority blooms from both vertex sides.

    For a same-side pair {a, b} with higher-priority member h, the bloom's
    non-dominant set is every common neighbour ``mid`` with
    label(mid) > label(h).  Blooms with k < 2 hold no butterflies and are
    dropped.  Cost: Σ_mid d_mid² wedge enumerations (host numpy).
    """
    labels = _priority_labels(g)
    eid: Dict[Tuple[int, int], int] = {
        (int(u), int(v)): i for i, (u, v) in enumerate(g.edges)
    }
    # Adjacency lists over combined ids.  U vertex u -> u ; V vertex v -> n_u+v.
    nbrs = [[] for _ in range(g.n + 1)]
    for u, v in g.edges:
        nbrs[int(u)].append(g.n_u + int(v))
        nbrs[g.n_u + int(v)].append(int(u))

    # blooms[(a, b)] = list of mids (a < b combined ids, same side).
    blooms: Dict[Tuple[int, int], list] = defaultdict(list)
    for mid in range(g.n):
        ns = nbrs[mid]
        lm = labels[mid]
        for i in range(len(ns)):
            for j in range(i + 1, len(ns)):
                a, b = ns[i], ns[j]
                if a > b:
                    a, b = b, a
                # higher-priority endpoint = smaller label
                lh = min(labels[a], labels[b])
                if lm > lh:
                    blooms[(a, b)].append(mid)

    bloom_k, link_edge, link_twin, link_bloom = [], [], [], []
    nb = 0

    def edge_of(x: int, y: int) -> int:
        # one of x, y is a U id, the other a combined V id
        if x < g.n_u:
            return eid[(x, y - g.n_u)]
        return eid[(y, x - g.n_u)]

    for (a, b), mids in blooms.items():
        k = len(mids)
        if k < 2:
            continue
        bid = nb
        nb += 1
        bloom_k.append(k)
        for mid in mids:
            e1 = edge_of(a, mid)
            e2 = edge_of(b, mid)
            link_edge.extend((e1, e2))
            link_twin.extend((e2, e1))
            link_bloom.extend((bid, bid))

    return BEIndex(
        nb=nb,
        bloom_k=np.asarray(bloom_k, dtype=np.int32).reshape(-1),
        link_edge=np.asarray(link_edge, dtype=np.int32).reshape(-1),
        link_twin=np.asarray(link_twin, dtype=np.int32).reshape(-1),
        link_bloom=np.asarray(link_bloom, dtype=np.int32).reshape(-1),
    )
