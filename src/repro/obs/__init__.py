"""Structured observability for the peel-to-serve stack.

Three parts (see docs/OBSERVABILITY.md):

* ``obs.trace``  — host-side span recorder with Chrome-trace export
  (Perfetto-loadable) and ``jax.profiler.TraceAnnotation`` bridging;
* ``obs.timeline`` — per-round peel timelines: CD rounds recorded live,
  FD rounds drained from device counter rings threaded through the FD
  ``while_loop`` carries;
* ``obs.metrics`` — counters / gauges / fixed-bucket latency histograms
  (p50/p99) for the serving layer, with a JSON snapshot exporter.

The whole layer is gated by :func:`enable` / :func:`disable`.  **Off
(the default) is zero-overhead**: no ring code is traced, so every
structural jaxpr invariant (single-``while`` FD, one-``pallas_call``
fused body, one-psum CD, loop-free dispatch) sees the byte-identical
program — asserted against ``tests/goldens/obs_jaxprs.json``.

Set ``REPRO_OBS=1`` to enable at import time (CI trace jobs), and
``REPRO_OBS_RING_CAP`` to size the per-round FD rings (default 1024).
"""
from __future__ import annotations

import os as _os

from .metrics import (Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry, percentiles)
from .timeline import (PeelTimeline, TimelineCollector,  # noqa: F401
                       RING_CAP_DEFAULT, fd_ring_cap, maybe_collect)
from .timeline import active as active_collector  # noqa: F401
from .trace import (Tracer, counter, disable, enable,  # noqa: F401
                    enabled, get_tracer, instant, span)

__all__ = [
    "Tracer", "enable", "disable", "enabled", "get_tracer",
    "span", "instant", "counter",
    "PeelTimeline", "TimelineCollector", "RING_CAP_DEFAULT",
    "fd_ring_cap", "maybe_collect", "active_collector",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "percentiles",
]

if _os.environ.get("REPRO_OBS", "") in ("1", "true", "yes"):
    enable()
