"""Per-round peel timelines: the paper's "work per round" curves as a
first-class output.

Two sources feed a :class:`PeelTimeline`:

* **CD rounds** are host-driven (``peelspec.cd_loop``), so each round is
  recorded directly: partition id, dying-entity count, frontier size,
  the level's upper bound ``hi`` and the update/recount deltas charged
  by ``cd_step``.

* **FD rounds** run inside a single device-resident ``while_loop`` (one
  per partition, or ONE for the whole vmapped/fused Phase 2), invisible
  to the host.  The telemetry-on twins of the FD drivers
  (``peelspec._fd_while_*_rings``) thread preallocated int32 **counter
  rings** through the loop carry — per-round dying count, frontier
  size, k-advance and update count, written at ``min(round, cap-1)`` —
  and the entity wrappers drain them here post-run.  Ring capacity
  comes from ``fd_ring_cap()``: 0 whenever the obs layer is off (the
  default path traces no ring code at all), else ``REPRO_OBS_RING_CAP``
  (default 1024).  Cascades longer than the cap keep their first
  ``cap-1`` rounds plus the final round and are flagged ``truncated``.

The collector is installed by ``peelspec.decompose`` (and the
distributed decompositions) via ``maybe_collect()``; the resulting
timeline is attached to ``PeelResult.timeline`` and summarized into
artifact provenance.
"""
from __future__ import annotations

import os
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence

import numpy as np

from . import trace

__all__ = [
    "PeelTimeline", "TimelineCollector", "maybe_collect", "active",
    "fd_ring_cap", "RING_CAP_DEFAULT",
]

RING_CAP_DEFAULT = 1024

_CD_KEYS = ("part", "died", "frontier", "hi", "updates", "recounts")


@dataclass
class PeelTimeline:
    """Per-round curves for one decomposition run.

    ``cd``: dict of equal-length int64 arrays (one entry per CD round):
    ``part, died, frontier, hi, updates, recounts``.

    ``fd``: one dict per FD launch::

        {"mode": "device"|"vmapped"|"fused"|"host",
         "parts": [int, ...],          # partitions covered (len B)
         "rounds": [int, ...],         # per-partition round count (len B)
         "died": (T, B) int array,     # per recorded iteration
         "frontier": (T, B) int array,
         "k": (T, B) int array,
         "updates": (T,) int array | None,  # per-iteration totals
         "truncated": bool}

    ``T = min(max(rounds), ring capacity)`` — iterations actually
    captured in the rings.
    """
    cd: Dict[str, np.ndarray]
    fd: List[Dict[str, Any]] = field(default_factory=list)

    # -- totals (the exact-match oracle against PeelStats) -----------
    @property
    def cd_rounds(self) -> int:
        """Number of CD rounds (== ``PeelStats.rho_cd``)."""
        return int(self.cd["part"].shape[0])

    def fd_rounds_total(self) -> int:
        """Summed per-partition FD rounds (== ``rho_fd_total``)."""
        return int(sum(sum(L["rounds"]) for L in self.fd))

    def fd_rounds_max(self) -> int:
        """Longest single-partition cascade (the FD critical path)."""
        return int(max((max(L["rounds"], default=0) for L in self.fd),
                       default=0))

    def updates_total(self) -> int:
        """CD + FD support updates, where launches recorded them."""
        tot = int(self.cd["updates"].sum())
        for L in self.fd:
            if L.get("updates") is not None:
                tot += int(np.sum(L["updates"]))
        return tot

    def truncated(self) -> bool:
        """Whether any launch's cascade overflowed its ring."""
        return any(L.get("truncated") for L in self.fd)

    # -- (de)serialization -------------------------------------------
    def as_dict(self) -> Dict[str, Any]:
        """Pure-JSON form (inverse of :meth:`from_dict`)."""
        return {
            "cd": {k: np.asarray(v).tolist() for k, v in self.cd.items()},
            "fd": [{**L,
                    "died": np.asarray(L["died"]).tolist(),
                    "frontier": np.asarray(L["frontier"]).tolist(),
                    "k": np.asarray(L["k"]).tolist(),
                    "updates": (None if L.get("updates") is None
                                else np.asarray(L["updates"]).tolist())}
                   for L in self.fd],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PeelTimeline":
        """Rebuild from :meth:`as_dict` output."""
        cd = {k: np.asarray(d["cd"][k], np.int64) for k in _CD_KEYS}
        fd = []
        for L in d.get("fd", []):
            fd.append({**L,
                       "died": np.asarray(L["died"], np.int64),
                       "frontier": np.asarray(L["frontier"], np.int64),
                       "k": np.asarray(L["k"], np.int64),
                       "updates": (None if L.get("updates") is None else
                                   np.asarray(L["updates"], np.int64))})
        return cls(cd=cd, fd=fd)

    def summary(self) -> Dict[str, Any]:
        """Compact JSON-able digest for artifact provenance."""
        return {
            "cd_rounds": self.cd_rounds,
            "fd_launches": len(self.fd),
            "fd_rounds_total": self.fd_rounds_total(),
            "fd_rounds_max": self.fd_rounds_max(),
            "cd_died_max": int(self.cd["died"].max(initial=0)),
            "truncated": self.truncated(),
        }

    # -- trace integration -------------------------------------------
    def emit_trace_events(self, tracer: "trace.Tracer") -> None:
        """Synthesize per-round trace events from the drained rings.

        CD rounds were recorded as live ``cd.round`` spans already; this
        adds (a) a ``peel.cd`` counter track sampled at each of those
        spans' end timestamps and (b) one ``fd.round`` instant per
        partition-round (count == ``PeelStats.rho_fd_total``) carrying
        died/frontier/k args where the ring captured that iteration.
        """
        cd_spans = sorted(tracer.spans("cd.round"), key=lambda e: e["ts"])
        for j in range(self.cd_rounds):
            ts = (cd_spans[j]["ts"] + cd_spans[j]["dur"]
                  if j < len(cd_spans) else tracer.now())
            tracer.counter("peel.cd", {
                "died": int(self.cd["died"][j]),
                "frontier": int(self.cd["frontier"][j])}, ts=ts)
        for L in self.fd:
            base = tracer.now()
            T = int(np.asarray(L["died"]).shape[0])
            for b, (p, r) in enumerate(zip(L["parts"], L["rounds"])):
                for t in range(int(r)):
                    args: Dict[str, Any] = {"part": int(p), "round": t}
                    if t < T:
                        args.update(
                            died=int(L["died"][t][b]),
                            frontier=int(L["frontier"][t][b]),
                            k=int(L["k"][t][b]))
                    tracer.instant("fd.round", cat="fd.round",
                                   ts=base + t, **args)


class TimelineCollector:
    """Accumulates CD rows and drained FD rings during one run."""

    def __init__(self) -> None:
        self.cd_rows: List[Dict[str, int]] = []
        self.fd_launches: List[Dict[str, Any]] = []

    # -- CD (host-driven, recorded live) -----------------------------
    def record_cd_round(self, part: int, died: int, frontier: int,
                        hi: int, updates: int, recounts: int) -> None:
        """Record one masked CD peel round (called from ``cd_loop``)."""
        self.cd_rows.append(dict(part=int(part), died=int(died),
                                 frontier=int(frontier), hi=int(hi),
                                 updates=int(updates),
                                 recounts=int(recounts)))

    # -- FD ring drains ----------------------------------------------
    def record_fd_rings(self, mode: str, parts: Sequence[int],
                        rounds: Sequence[int], rings: Any, cap: int,
                        cumulative_updates: bool = False) -> None:
        """Drain one launch's counter rings.

        ``rings`` is the carry tail returned by a ``*_rings`` FD driver:
        ``(died, frontier, k, updates)`` device arrays shaped ``(cap,)``
        (device driver) or ``(cap, B)`` / ``(cap,)`` for the update ring
        (vmapped / fused).  ``cumulative_updates=True`` marks rings that
        store the running per-partition update total (the fused wing
        kernel's state carries cumulative ``nupd``); the drain converts
        them to per-iteration deltas.
        """
        died, frontier, k, upd = (np.asarray(r) for r in rings[:4])
        if died.ndim == 1:                       # device driver: B == 1
            died, frontier, k = (a[:, None] for a in (died, frontier, k))
            if upd.ndim == 1 and cumulative_updates:
                upd = upd[:, None]
        rounds = [int(r) for r in rounds]
        n = min(max(rounds, default=0), int(cap))
        died, frontier, k = died[:n], frontier[:n], k[:n]
        updates: Optional[np.ndarray]
        if cumulative_updates:
            per_part = np.diff(upd[:n], axis=0, prepend=0)
            updates = per_part.sum(axis=1).astype(np.int64)
        else:
            updates = upd[:n].astype(np.int64)
        self.fd_launches.append(dict(
            mode=mode, parts=[int(p) for p in parts], rounds=rounds,
            died=died.astype(np.int64), frontier=frontier.astype(np.int64),
            k=k.astype(np.int64), updates=updates,
            truncated=max(rounds, default=0) > int(cap)))

    def record_fd_counts(self, mode: str, parts: Sequence[int],
                         rounds: Sequence[int]) -> None:
        """A launch where only per-partition round counts are visible
        (sharded FD under ``shard_map`` — rings don't cross the
        collective boundary).  Round totals stay exact; per-round
        died/frontier/k detail is absent (``T == 0``)."""
        rounds = [int(r) for r in rounds]
        z = np.zeros((0, len(list(parts))), np.int64)
        self.fd_launches.append(dict(
            mode=mode, parts=[int(p) for p in parts], rounds=rounds,
            died=z, frontier=z.copy(), k=z.copy(), updates=None,
            truncated=False))

    def record_fd_host(self, part: int, rows: List[Dict[str, int]],
                       updates: Optional[Sequence[int]] = None) -> None:
        """One host-driven cascade (``_fd_cascade`` / dense FD loops);
        ``rows`` carry died/frontier/k per round."""
        n = len(rows)
        self.fd_launches.append(dict(
            mode="host", parts=[int(part)], rounds=[n],
            died=np.array([[r["died"]] for r in rows], np.int64),
            frontier=np.array([[r["frontier"]] for r in rows], np.int64),
            k=np.array([[r["k"]] for r in rows], np.int64),
            updates=(None if updates is None
                     else np.asarray(updates, np.int64)),
            truncated=False))

    def build(self) -> PeelTimeline:
        """Assemble the collected rows into a :class:`PeelTimeline`."""
        cd = {k: np.array([r[k] for r in self.cd_rows], np.int64)
              for k in _CD_KEYS}
        return PeelTimeline(cd=cd, fd=list(self.fd_launches))


# ----------------------------------------------------------------------
# Active-collector plumbing.  ``decompose`` installs a collector for the
# duration of one run; the spec fd/cd functions look it up here instead
# of growing new callback parameters.
# ----------------------------------------------------------------------
_collector: Optional[TimelineCollector] = None


def active() -> Optional[TimelineCollector]:
    """The collector of the in-flight decomposition, or None when the
    obs layer is off / no run is collecting."""
    return _collector


@contextmanager
def maybe_collect() -> Iterator[Optional[TimelineCollector]]:
    """Install a fresh collector iff the obs layer is enabled; yields
    None (and changes nothing) otherwise."""
    global _collector
    if not trace.enabled():
        yield None
        return
    prev = _collector
    _collector = c = TimelineCollector()
    try:
        yield c
    finally:
        _collector = prev


def fd_ring_cap() -> int:
    """Ring capacity the FD entity wrappers should trace with: 0 unless
    a collector is live (so the default path never sees ring code)."""
    if _collector is None or not trace.enabled():
        return 0
    try:
        return max(int(os.environ.get("REPRO_OBS_RING_CAP",
                                      RING_CAP_DEFAULT)), 1)
    except ValueError:
        return RING_CAP_DEFAULT
