"""Serving metrics: counters, gauges and fixed-bucket latency
histograms with a JSON snapshot exporter.

Registries are **per-instance** (a ``ForestPool`` owns one and shares
it with its ``MultiTenantService``) so tests never fight over global
state; ``launch/hserve.py --metrics PATH`` snapshots the pool's
registry at exit.

Histograms use fixed geometric buckets (default 1 µs … ~67 s in ×2
steps, values in milliseconds) — constant memory per metric, p50/p99
read back by linear interpolation inside the owning bucket.  For exact
percentiles over raw samples (the bench harness keeps its samples),
use :func:`percentiles`.
"""
from __future__ import annotations

import json
import threading
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "percentiles",
]

# bucket upper bounds in ms: 0.001, 0.002, ... ~67_000 (2**26 µs)
DEFAULT_BUCKETS: Tuple[float, ...] = tuple(
    0.001 * (2.0 ** i) for i in range(27))


class Counter:
    """Monotonic event count."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        """Add ``n`` events."""
        self.value += int(n)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able state dict."""
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        """Overwrite the current value."""
        self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able state dict."""
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket latency histogram (record values in ms)."""

    def __init__(self, buckets: Optional[Sequence[float]] = None) -> None:
        self.bounds = np.asarray(buckets if buckets is not None
                                 else DEFAULT_BUCKETS, np.float64)
        self.counts = np.zeros(self.bounds.shape[0] + 1, np.int64)
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def record(self, v: float) -> None:
        """Add one observation (milliseconds)."""
        v = float(v)
        self.counts[int(np.searchsorted(self.bounds, v, side="left"))] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def percentile(self, p: float) -> float:
        """Approximate percentile: linear interpolation inside the
        bucket holding rank ``p``; clamped to the observed min/max."""
        if self.count == 0:
            return 0.0
        rank = (p / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = (self.bounds[i] if i < self.bounds.shape[0]
                      else self.max)
                frac = (rank - cum) / c
                v = lo + (hi - lo) * frac
                return float(min(max(v, self.min), self.max))
            cum += c
        return self.max

    def snapshot(self) -> Dict[str, Any]:
        """JSON-able summary: count/sum/mean/min/max + p50/p99 (ms)."""
        if self.count == 0:
            return {"type": "histogram", "count": 0}
        return {"type": "histogram", "count": self.count,
                "sum_ms": self.sum, "mean_ms": self.sum / self.count,
                "min_ms": self.min, "max_ms": self.max,
                "p50_ms": self.percentile(50.0),
                "p99_ms": self.percentile(99.0)}


class MetricsRegistry:
    """Named counters/gauges/histograms with get-or-create semantics."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(*args)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} is {type(m).__name__}, "
                    f"not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        """Get-or-create the named counter."""
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        """Get-or-create the named gauge."""
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        """Get-or-create the named histogram."""
        return self._get(name, Histogram, buckets)

    # convenience one-liners for instrumentation sites
    def inc(self, name: str, n: int = 1) -> None:
        """Increment the named counter by ``n``."""
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        """Set the named gauge to ``v``."""
        self.gauge(name).set(v)

    def observe(self, name: str, ms: float) -> None:
        """Record ``ms`` into the named histogram."""
        self.histogram(name).record(ms)

    def get(self, name: str):
        """The named metric object, or ``None``."""
        return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Any]:
        """Name-sorted dict of every metric's snapshot."""
        with self._lock:
            return {name: m.snapshot()
                    for name, m in sorted(self._metrics.items())}

    def save(self, path: str) -> None:
        """Write :meth:`snapshot` as indented JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1)


def percentiles(samples: Iterable[float],
                ps: Sequence[float] = (50.0, 99.0)) -> Dict[str, float]:
    """Exact percentiles over raw samples: ``{"p50": ..., "p99": ...}``.
    Shared by the bench harness (serve p50/p99 rows) and tests."""
    arr = np.asarray(list(samples), np.float64)
    if arr.size == 0:
        return {f"p{g:g}": 0.0 for g in ps}
    return {f"p{g:g}": float(np.percentile(arr, g)) for g in ps}
