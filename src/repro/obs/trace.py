"""Host-side span tracing with Chrome-trace export.

One module-level switch (``enable()`` / ``disable()``) gates the whole
observability layer: with it off (the default) every ``span()`` /
``instant()`` call returns a shared null object and the peel core picks
a zero ring capacity, so the traced jaxprs are byte-identical to the
uninstrumented tree (``tests/goldens/obs_jaxprs.json``).

With it on, a :class:`Tracer` records nested spans (Chrome-trace
"complete" events, ``ph="X"``), instants (``ph="i"``) and counter
samples (``ph="C"``) with categories and JSON-able args.  ``save()``
writes the standard ``{"traceEvents": [...]}`` envelope, loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Host spans
also enter ``jax.profiler.TraceAnnotation`` so device work lines up
under them when a jax profile is being captured concurrently.

Span taxonomy (see docs/OBSERVABILITY.md):

====================  ==========  ===========================================
cat                   ph          meaning
====================  ==========  ===========================================
``peel``              X           one ``decompose()`` / distributed run
``cd``                X           Phase 1 (cover decomposition) total
``cd.round``          X           one masked peel round; count == ``rho_cd``
``fd``                X           Phase 2 (fine decomposition) total
``fd.launch``         X           one FD dispatch (a partition, or the one
                                  vmapped/fused launch covering all of them)
``fd.round``          i           one partition-round; count == rho_fd_total
``hierarchy``         X           hierarchy build / save steps
``serve``             X           pool admission + batched dispatch chunks
====================  ==========  ===========================================
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional

try:  # pragma: no cover - import guard only
    from jax.profiler import TraceAnnotation as _TraceAnnotation
except Exception:  # pragma: no cover
    _TraceAnnotation = None

__all__ = [
    "Tracer", "enable", "disable", "enabled", "get_tracer",
    "span", "instant", "counter",
]


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars / arrays into plain JSON values."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if hasattr(v, "tolist"):          # numpy scalar or array
        return v.tolist()
    if isinstance(v, (int, float)):
        return v
    return str(v)


class _NullSpan:
    """Context manager returned when tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Tracer:
    """Records Chrome-trace events; timestamps are microseconds since
    the tracer was created (Chrome-trace native unit)."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []

    # -- recording ---------------------------------------------------
    def now(self) -> float:
        """Microseconds since tracer start."""
        return (time.perf_counter() - self._t0) * 1e6

    @contextmanager
    def span(self, name: str, cat: str = "",
             **args: Any) -> Iterator[Dict[str, Any]]:
        """Record a complete event around the block.  Yields a dict the
        block may fill with late args (values only known mid-span, e.g.
        a round's update delta) — merged into the event at exit."""
        t0 = self.now()
        late: Dict[str, Any] = {}
        ann = _TraceAnnotation(name) if _TraceAnnotation is not None else None
        if ann is not None:
            ann.__enter__()
        try:
            yield late
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            args.update(late)
            ev: Dict[str, Any] = dict(
                name=name, cat=cat or name, ph="X", ts=t0,
                dur=self.now() - t0, pid=0, tid=0)
            if args:
                ev["args"] = {k: _jsonable(v) for k, v in args.items()}
            with self._lock:
                self.events.append(ev)

    def instant(self, name: str, cat: str = "",
                ts: Optional[float] = None, **args: Any) -> None:
        """Record a zero-duration event (Chrome-trace ``ph="i"``)."""
        ev: Dict[str, Any] = dict(
            name=name, cat=cat or name, ph="i", s="t",
            ts=self.now() if ts is None else ts, pid=0, tid=0)
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        with self._lock:
            self.events.append(ev)

    def counter(self, name: str, values: Dict[str, Any],
                ts: Optional[float] = None) -> None:
        """A counter-track sample (renders as a curve in Perfetto)."""
        ev = dict(name=name, cat=name, ph="C",
                  ts=self.now() if ts is None else ts, pid=0, tid=0,
                  args={k: _jsonable(v) for k, v in values.items()})
        with self._lock:
            self.events.append(ev)

    # -- queries (used by the trace/stats exact-match tests) ---------
    def spans(self, cat: Optional[str] = None,
              ph: Optional[str] = None) -> List[Dict[str, Any]]:
        """Events filtered by category and/or phase."""
        return [e for e in self.events
                if (cat is None or e.get("cat") == cat)
                and (ph is None or e.get("ph") == ph)]

    def count(self, cat: Optional[str] = None,
              ph: Optional[str] = None) -> int:
        """Number of events matching the category/phase filter."""
        return len(self.spans(cat, ph))

    def sum_arg(self, key: str, cat: Optional[str] = None) -> int:
        """Sum an integer arg over every matching event."""
        return sum(int(e.get("args", {}).get(key, 0))
                   for e in self.spans(cat))

    # -- export ------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """The standard Chrome-trace envelope (Perfetto-loadable)."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        """Write :meth:`to_chrome` as JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


# ----------------------------------------------------------------------
# Module-level gate.  ALL instrumentation in the peel core / hierarchy /
# serving layer routes through these helpers so the off path costs one
# ``is None`` check and changes no traced program.
# ----------------------------------------------------------------------
_tracer: Optional[Tracer] = None


def enable() -> Tracer:
    """Turn the observability layer on; returns the active tracer
    (fresh on the first call, reused afterwards)."""
    global _tracer
    if _tracer is None:
        _tracer = Tracer()
    return _tracer


def disable() -> None:
    """Turn the observability layer off and drop the tracer."""
    global _tracer
    _tracer = None


def enabled() -> bool:
    """Whether the observability layer is on."""
    return _tracer is not None


def get_tracer() -> Optional[Tracer]:
    """The active tracer, or ``None`` when the layer is off."""
    return _tracer


def span(name: str, cat: str = "", **args: Any):
    """Module-level :meth:`Tracer.span`; inert null span when off."""
    t = _tracer
    return t.span(name, cat, **args) if t is not None else _NULL_SPAN


def instant(name: str, cat: str = "", **args: Any) -> None:
    """Module-level :meth:`Tracer.instant`; no-op when off."""
    t = _tracer
    if t is not None:
        t.instant(name, cat, **args)


def counter(name: str, values: Dict[str, Any]) -> None:
    """Module-level :meth:`Tracer.counter`; no-op when off."""
    t = _tracer
    if t is not None:
        t.counter(name, values)
