"""Continuous-batching serving engine.

Production decode loop: a fixed pool of batch *slots* shares one KV
cache; requests join free slots as they arrive (prefill via teacher
forcing on the decode path), finished sequences retire immediately and
free their slot — no head-of-line blocking on long generations.

The decode step is the same jitted ``serve_step`` the dry-run compiles:
slot occupancy is data (masks), not shape, so one XLA program serves any
request mix.  Per-slot lengths ride in a [slots] int32 vector; attention
masks each slot to its own length.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.models.config import ModelConfig

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int = 16
    eos: Optional[int] = None  # stop at the FIRST generated eos, inclusive
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Slot-based continuous batching over ``serve_step``.

    Limitation (documented): ``serve_step`` advances all slots with one
    shared position scalar, so a slot joining mid-flight restarts the
    engine's step clock for itself via per-slot masking — we implement
    this by tracking per-slot lengths and passing the *maximum* as the
    cache write position while masking reads per slot.  Cache slots are
    therefore recycled only at quiescent points (all-done or step 0) in
    this reference implementation; a production port would thread a
    per-slot position vector through ``dynamic_update_slice`` per slot.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_seq: int = 128, greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_len = np.zeros(n_slots, np.int64)
        self.slot_todo: List[List[int]] = [[] for _ in range(n_slots)]
        self._cache = jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype),
            M.cache_specs(cfg, n_slots, max_seq, dtype=jnp.float32))
        self._step = jax.jit(
            lambda p, c, t, l: M.serve_step(p, c, t, l, cfg))
        self.position = 0
        self.steps = 0

    # ------------------------------------------------------------ admin
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self.slot_todo[i] = list(req.prompt)
                self.slot_len[i] = 0

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def pending(self) -> int:
        return len(self.queue)

    # ------------------------------------------------------------- step
    def step(self) -> None:
        """One engine iteration: admit, decode one token per slot."""
        if self.position == 0 or self.active == 0:
            self._admit()
        tok = np.zeros(self.n_slots, np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.slot_todo[i]:
                tok[i] = self.slot_todo[i].pop(0)   # prefill (teacher)
            elif req.output:
                tok[i] = req.output[-1]
        logits, self._cache = self._step(
            self.params, self._cache, jnp.asarray(tok),
            jnp.int32(self.position))
        nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        self.position += 1
        self.steps += 1
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_len[i] += 1
            if self.slot_todo[i]:
                continue  # still prefilling
            req.output.append(int(nxt[i]))
            # eos contract: stop at the first GENERATED eos, which is
            # included in the output; prefill (teacher-forced) tokens
            # never trigger this (the `continue` above skips them)
            hit_eos = req.eos is not None and int(nxt[i]) == req.eos
            if len(req.output) >= req.max_new or hit_eos \
                    or self.position >= self.max_seq - 1:
                req.done = True
                self.slots[i] = None
        if self.active == 0:
            # quiescent point: reset clock, recycle the cache wholesale
            self.position = 0
            self._cache = jax.tree.map(jnp.zeros_like, self._cache)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drain the queue; returns all completed requests."""
        done: List[Request] = []
        seen: Dict[int, Request] = {}
        while (self.queue or self.active) and self.steps < max_steps:
            for s in self.slots:
                if s is not None:
                    seen[s.uid] = s
            self.step()
        for r in seen.values():
            if r.done:
                done.append(r)
        return sorted(done, key=lambda r: r.uid)
