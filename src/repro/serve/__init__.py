from .engine import ContinuousBatcher, Request

__all__ = ["ContinuousBatcher", "Request"]
