"""repro — PBNG (parallel bipartite network peeling) as a production
JAX framework, plus the assigned-architecture training/serving stack.

Subpackages: core (the paper), kernels (Pallas), models, configs,
sharding, train, data, serve, launch.
"""
__version__ = "0.1.0"
