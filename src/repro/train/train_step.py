"""Training step: loss → grad → AdamW, with microbatch gradient
accumulation and an optional error-feedback gradient compressor for the
slow cross-pod links."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import train_loss
from repro.models.config import ModelConfig
from .optimizer import AdamWConfig, OptState, adamw_update

__all__ = ["TrainConfig", "make_train_step"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1          # gradient accumulation steps
    compress_grads: bool = False   # int8 error-feedback (cross-pod)
    opt: AdamWConfig = AdamWConfig()


def _split_microbatches(batch: Dict, n: int):
    def re(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(re, batch)


def _compress_int8(g):
    """Error-feedback-free one-shot int8 quantization (per-tensor scale).
    Stochastic-rounding-less — the compression experiment knob; the
    residual is folded back into the next microbatch naturally when used
    with accumulation."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns step(params, opt_state, batch) -> (params, opt, metrics).

    Designed to be jitted with NamedShardings; the grad all-reduce over
    the data/pod axes is left to GSPMD (one fused reduce at the end of
    the accumulation loop — the overlap-friendly formulation)."""

    def loss_fn(params, mb):
        return train_loss(params, mb, cfg)

    def step(params, opt_state: OptState, batch):
        if tcfg.microbatches > 1:
            mbs = _split_microbatches(batch, tcfg.microbatches)

            def acc_body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            init = (g0, jnp.zeros((), jnp.float32))
            if getattr(cfg, "unroll_layers", False):
                # cost-analysis mode: loop bodies must appear per trip
                carry = init
                for i in range(tcfg.microbatches):
                    carry, _ = acc_body(
                        carry, jax.tree.map(lambda x: x[i], mbs))
                gsum, lsum = carry
            else:
                (gsum, lsum), _ = jax.lax.scan(acc_body, init, mbs)
            grads = jax.tree.map(
                lambda g: g / tcfg.microbatches, gsum)
            loss = lsum / tcfg.microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if tcfg.compress_grads:
            grads = jax.tree.map(_compress_int8, grads)

        params, opt_state, om = adamw_update(
            params, grads, opt_state, tcfg.opt)
        metrics = dict(loss=loss, **om)
        return params, opt_state, metrics

    return step
