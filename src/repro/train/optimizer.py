"""AdamW in pure JAX — optimizer state shards exactly like its parameter
(ZeRO-3: moments inherit the param's NamedSharding via tree_map)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: Any = jnp.float32


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jax.Array


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def abstract_opt_state(params_abstract, cfg: AdamWConfig = AdamWConfig()):
    z = lambda p: jax.ShapeDtypeStruct(p.shape, cfg.moment_dtype)
    return OptState(
        mu=jax.tree.map(z, params_abstract),
        nu=jax.tree.map(z, params_abstract),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: OptState,
                 cfg: AdamWConfig = AdamWConfig()):
    step = state.step + 1
    lr = _schedule(cfg, step.astype(jnp.float32))
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m.astype(cfg.moment_dtype), v.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(mu=new_m, nu=new_v, step=step), dict(
        grad_norm=gn, lr=lr)
