from .optimizer import AdamWConfig, OptState, adamw_init, adamw_update
from .train_step import TrainConfig, make_train_step
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .elastic import StragglerDetector, remesh

__all__ = [
    "AdamWConfig",
    "OptState",
    "adamw_init",
    "adamw_update",
    "TrainConfig",
    "make_train_step",
    "latest_step",
    "restore_checkpoint",
    "save_checkpoint",
    "StragglerDetector",
    "remesh",
]
