"""Fault-tolerant checkpointing.

Design (scales to multi-host):
  * params/opt state saved as one npz per host process (this container:
    one), keyed by flattened tree paths;
  * a JSON manifest (step, config name, mesh axes, tree structure hash)
    written LAST with an atomic rename — a checkpoint without a manifest
    is incomplete and ignored on restore;
  * ``latest_step`` scans manifests, so a crash mid-save can never be
    resumed from;
  * checkpoints store *logical* metadata only (no device layout), so a
    restore may target a different mesh — elastic re-sharding is just
    ``device_put`` with the new NamedShardings (see elastic.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]


def _flat(tree) -> Dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _treedef_hash(tree) -> str:
    s = str(jax.tree_util.tree_structure(tree))
    return hashlib.sha256(s.encode()).hexdigest()[:16]


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    proc = jax.process_index()
    tag = f"step_{step:08d}"
    path = os.path.join(ckpt_dir, tag)
    os.makedirs(path, exist_ok=True)

    np.savez(os.path.join(path, f"params_{proc}.npz"), **_flat(params))
    np.savez(os.path.join(path, f"opt_{proc}.npz"), **_flat(opt_state))

    manifest = dict(
        step=step,
        n_processes=jax.process_count(),
        params_hash=_treedef_hash(params),
        opt_hash=_treedef_hash(opt_state),
        extra=extra or {},
    )
    # manifest last + atomic: incomplete checkpoints are invisible
    fd, tmp = tempfile.mkstemp(dir=path)
    with os.fdopen(fd, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, os.path.join(path, "MANIFEST.json"))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "MANIFEST.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def _unflat(template, flat: Dict[str, np.ndarray], shardings=None):
    leaves_p = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves_p[0]))
    for (path, leaf), sh in zip(leaves_p[0], sh_leaves):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = flat[key].astype(leaf.dtype)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        vals.append(arr)
    return jax.tree_util.tree_unflatten(leaves_p[1], vals)


def restore_checkpoint(
    ckpt_dir: str, step: int, params_template, opt_template,
    param_shardings=None, opt_shardings=None,
) -> Tuple[Any, Any, Dict]:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    if manifest["params_hash"] != _treedef_hash(params_template):
        raise ValueError(
            "checkpoint tree structure differs from model config — "
            "refusing to restore")
    proc = jax.process_index()
    pz = np.load(os.path.join(path, f"params_{proc}.npz"))
    oz = np.load(os.path.join(path, f"opt_{proc}.npz"))
    params = _unflat(params_template, pz, param_shardings)
    opt = _unflat(opt_template, oz, opt_shardings)
    return params, opt, manifest
