"""Elastic scaling + straggler detection.

* ``remesh``: after losing (or gaining) a pod, rebuild NamedShardings for
  the surviving mesh from the *logical* axis rules and re-place the
  state.  Checkpoints are layout-free (checkpoint.py), so pod-count
  changes never invalidate them.
* ``StragglerDetector``: per-step wall-time EWMA + z-score; on real
  clusters this feeds the scheduler (here it logs and can trigger an
  early checkpoint).
"""
from __future__ import annotations

import time
from typing import Optional

import jax

from repro.sharding import param_shardings

__all__ = ["remesh", "StragglerDetector"]


def remesh(params, opt_state, axes_tree, new_mesh):
    """Re-place a (params, opt) pytree onto a new mesh.

    Works across device-count changes as long as every array fits the
    new mesh's divisibility rules (the resolver falls back to
    replication otherwise).
    """
    p_sh = param_shardings(axes_tree, params, new_mesh)
    params = jax.tree.map(jax.device_put, params, p_sh)

    def opt_put(x, sh):
        return jax.device_put(x, sh)

    # moments share the param layout; step is replicated
    new_mu = jax.tree.map(opt_put, opt_state.mu, p_sh)
    new_nu = jax.tree.map(opt_put, opt_state.nu, p_sh)
    step = jax.device_put(opt_state.step)
    return params, type(opt_state)(mu=new_mu, nu=new_nu, step=step)


class StragglerDetector:
    """EWMA step-time monitor; flags steps > mean + k·std (paper §3.1.4's
    workload-aware scheduling is the peeling analogue)."""

    def __init__(self, alpha: float = 0.1, threshold_sigma: float = 3.0):
        self.alpha = alpha
        self.k = threshold_sigma
        self.mean: Optional[float] = None
        self.var = 0.0
        self._t0: Optional[float] = None
        self.flagged = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        dt = time.perf_counter() - self._t0
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = dt > self.mean + self.k * (self.var ** 0.5 + 1e-9)
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.flagged += int(is_straggler)
        return is_straggler
