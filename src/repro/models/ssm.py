"""Recurrent / state-space blocks: Mamba2 (SSD), mLSTM, sLSTM.

All sequence mixing goes through one generic *chunked linear recurrence*

    S_t = d_t · S_{t-1} + g_t · k_t v_tᵀ ,   y_t = q_tᵀ S_t

computed chunk-parallel (intra-chunk: L×L decay-masked attention on the
MXU; inter-chunk: a short ``lax.scan`` over chunk summaries).  Decode is
the O(1)-state single-step recurrence — this is what makes the ssm/hybrid
architectures eligible for the 500k-context shape.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "chunked_recurrence",
    "recurrence_step",
    "mamba2_mix",
    "mamba2_step",
    "mlstm_mix",
    "mlstm_step",
    "slstm_mix",
    "slstm_step",
]


# ============================================================ core scan
def chunked_recurrence(
    q: jax.Array,      # [b, h, s, dk]
    k: jax.Array,      # [b, h, s, dk]
    v: jax.Array,      # [b, h, s, dv]
    decay: jax.Array,  # [b, h, s]   in (0, 1]
    gain: jax.Array,   # [b, h, s]
    chunk: int = 64,
    unroll: bool = False,
) -> jax.Array:
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, (s, L)
    nc = s // L

    def cs(x, extra=()):
        return x.reshape(b, h, nc, L, *extra)

    qc = cs(q, (dk,)).astype(jnp.float32)
    kc = cs(k, (dk,)).astype(jnp.float32)
    vc = cs(v, (dv,)).astype(jnp.float32)
    logd = jnp.log(jnp.clip(decay, 1e-12, 1.0)).reshape(b, h, nc, L)
    gc = gain.reshape(b, h, nc, L).astype(jnp.float32)

    cum = jnp.cumsum(logd, axis=-1)                       # log Π_{i<=t}
    # intra-chunk: y[t] += Σ_{s<=t} exp(cum[t]-cum[s]) g[s] (q_t·k_s) v_s
    diff = cum[..., :, None] - cum[..., None, :]          # [.., t, s]
    tri = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(tri, jnp.exp(diff), 0.0) * gc[..., None, :]
    scores = jnp.einsum("bhctd,bhcsd->bhcts", qc, kc) * D
    y_intra = jnp.einsum("bhcts,bhcse->bhcte", scores, vc)

    # chunk summaries: S_c = Σ_s exp(cum[L-1]-cum[s]) g[s] k_s v_sᵀ
    wl = jnp.exp(cum[..., -1:] - cum) * gc                # [b,h,nc,L]
    S_c = jnp.einsum("bhcs,bhcsd,bhcse->bhcde", wl, kc, vc)
    chunk_decay = jnp.exp(cum[..., -1])                   # [b,h,nc]

    # inter-chunk scan
    def step(S, inp):
        S_chunk, cd, q_chunk, cum_chunk = inp
        # y_inter[t] = exp(cum[t]) q_t · S_in
        y = jnp.einsum("bhtd,bhde->bhte", q_chunk, S) * jnp.exp(
            cum_chunk
        )[..., None]
        S_new = cd[..., None, None] * S + S_chunk
        return S_new, y

    S0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    xs = (
        jnp.moveaxis(S_c, 2, 0),
        jnp.moveaxis(chunk_decay, 2, 0),
        jnp.moveaxis(qc, 2, 0),
        jnp.moveaxis(cum, 2, 0),
    )
    if unroll:  # cost-analysis mode
        Scur, ys = S0, []
        for i in range(nc):
            Scur, y = step(Scur, jax.tree.map(lambda a: a[i], xs))
            ys.append(y)
        y_inter = jnp.stack(ys, axis=0)
    else:
        _, y_inter = jax.lax.scan(step, S0, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 2)
    return y.reshape(b, h, s, dv)


def recurrence_step(
    S: jax.Array,      # [b, h, dk, dv]
    q: jax.Array,      # [b, h, dk]
    k: jax.Array,
    v: jax.Array,      # [b, h, dv]
    decay: jax.Array,  # [b, h]
    gain: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """One decode step; returns (new state, y [b,h,dv])."""
    S = decay[..., None, None] * S + gain[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    y = jnp.einsum("bhd,bhde->bhe", q, S)
    return S, y


# ============================================================== Mamba2
def _mamba_parts(x, p, cfg):
    """Shared projections for train/decode.  Returns per-token q(C), k(B),
    v(dt·x), decay, gain, z."""
    d_in = p["in_proj"].shape[1]
    zxbcdt = jnp.einsum("...d,de->...e", x, p["in_proj"])
    nh = p["A_log"].shape[0]
    dh = (d_in - 2 * cfg.ssm_state - nh) // (2 * nh)
    z, xin, B, C, dt = jnp.split(
        zxbcdt,
        [dh * nh, 2 * dh * nh, 2 * dh * nh + cfg.ssm_state,
         2 * dh * nh + 2 * cfg.ssm_state],
        axis=-1,
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    decay = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt)
    return z, xin, B, C, dt, decay, nh, dh


def mamba2_mix(x: jax.Array, p: dict, cfg) -> jax.Array:
    """Mamba2 (SSD) sequence mixing, chunk-parallel.  x: [b, s, d]."""
    b, s, _ = x.shape
    z, xin, B, C, dt, decay, nh, dh = _mamba_parts(x, p, cfg)
    # causal depthwise conv on the x-branch (width ssm_conv)
    xin = _causal_conv(xin, p["conv_w"])
    xh = xin.reshape(b, s, nh, dh)
    v = (dt[..., None] * xh.astype(jnp.float32)).transpose(0, 2, 1, 3)
    k = jnp.broadcast_to(
        B[:, None].astype(jnp.float32), (b, nh, s, cfg.ssm_state)
    )
    q = jnp.broadcast_to(
        C[:, None].astype(jnp.float32), (b, nh, s, cfg.ssm_state)
    )
    y = chunked_recurrence(
        q, k, v, decay.transpose(0, 2, 1),
        jnp.ones_like(decay).transpose(0, 2, 1), chunk=cfg.ssm_chunk,
        unroll=cfg.unroll_layers,
    )                                                    # [b,nh,s,dh]
    y = y + p["D"][None, :, None, None] * xh.transpose(0, 2, 1, 3)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, nh * dh)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("...e,ed->...d", y.astype(x.dtype), p["out_proj"])


def mamba2_step(x, state, p, cfg):
    """One decode token.  x: [b, d]; state: (conv_buf, S)."""
    conv_buf, S = state
    b = x.shape[0]
    z, xin, B, C, dt, decay, nh, dh = _mamba_parts(x[:, None], p, cfg)
    z, xin, B, C = z[:, 0], xin[:, 0], B[:, 0], C[:, 0]
    dt, decay = dt[:, 0], decay[:, 0]
    # rolling conv buffer [b, w, d_conv]
    conv_buf = jnp.concatenate([conv_buf[:, 1:], xin[:, None]], axis=1)
    xin = jnp.einsum("bwc,wc->bc", conv_buf, p["conv_w"])
    xin = jax.nn.silu(xin)
    xh = xin.reshape(b, nh, dh)
    v = dt[..., None] * xh.astype(jnp.float32)
    k = jnp.broadcast_to(B[:, None].astype(jnp.float32),
                         (b, nh, cfg.ssm_state))
    q = jnp.broadcast_to(C[:, None].astype(jnp.float32),
                         (b, nh, cfg.ssm_state))
    S, y = recurrence_step(S, q, k, v, decay, jnp.ones_like(decay))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(b, nh * dh) * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("be,ed->bd", y.astype(x.dtype), p["out_proj"])
    return out, (conv_buf, S)


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, width w.shape[0]; x: [b, s, c]."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i: i + x.shape[1]] * w[i][None, None, :]
        for i in range(width)
    )
    return jax.nn.silu(out)


# =============================================================== mLSTM
def mlstm_mix(x: jax.Array, p: dict, cfg) -> jax.Array:
    """xLSTM mLSTM block: matrix memory + sigmoid forget / input gates
    (bounded-gate simplification of exponential gating, see DESIGN.md)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    dh = cfg.lstm_proj_factor * cfg.d_model // nh
    up = jnp.einsum("...d,de->...e", x, p["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("...d,de->...e", xi, p["wq"]).reshape(b, s, nh, dh)
    k = jnp.einsum("...d,de->...e", xi, p["wk"]).reshape(b, s, nh, dh)
    v = jnp.einsum("...d,de->...e", xi, p["wv"]).reshape(b, s, nh, dh)
    gates = jnp.einsum("...d,de->...e", xi, p["wg"])      # [b,s,2*nh]
    f, i = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    decay = jax.nn.sigmoid(f).transpose(0, 2, 1)          # [b,nh,s]
    gain = jax.nn.sigmoid(i).transpose(0, 2, 1)
    y = chunked_recurrence(
        q.transpose(0, 2, 1, 3).astype(jnp.float32) * dh ** -0.5,
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        decay, gain, chunk=cfg.ssm_chunk, unroll=cfg.unroll_layers,
    )
    # normalizer: same recurrence with v ≡ 1
    n = chunked_recurrence(
        q.transpose(0, 2, 1, 3).astype(jnp.float32) * dh ** -0.5,
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        jnp.ones((b, nh, s, 1), jnp.float32),
        decay, gain, chunk=cfg.ssm_chunk, unroll=cfg.unroll_layers,
    )
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, nh * dh)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("...e,ed->...d", y, p["down_proj"])


def mlstm_step(x, state, p, cfg):
    """Decode step; state = (S [b,nh,dh,dh], n [b,nh,dh])."""
    S, nstate = state
    b, d = x.shape
    nh = cfg.n_heads
    dh = cfg.lstm_proj_factor * cfg.d_model // nh
    up = jnp.einsum("bd,de->be", x, p["up_proj"])
    xi, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bd,de->be", xi, p["wq"]).reshape(b, nh, dh)
    k = jnp.einsum("bd,de->be", xi, p["wk"]).reshape(b, nh, dh)
    v = jnp.einsum("bd,de->be", xi, p["wv"]).reshape(b, nh, dh)
    gates = jnp.einsum("bd,de->be", xi, p["wg"]).astype(jnp.float32)
    f, i = jnp.split(gates, 2, axis=-1)
    decay = jax.nn.sigmoid(f)
    gain = jax.nn.sigmoid(i)
    qf = q.astype(jnp.float32) * dh ** -0.5
    S, y = recurrence_step(S, qf, k.astype(jnp.float32),
                           v.astype(jnp.float32), decay, gain)
    nstate = decay[..., None] * nstate + gain[..., None] * k.astype(
        jnp.float32)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qf, nstate))[..., None], 1.0
    )
    y = y / denom
    y = y.reshape(b, nh * dh).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y, p["down_proj"])
    return out, (S, nstate)


# =============================================================== sLSTM
def slstm_mix(x: jax.Array, p: dict, cfg) -> jax.Array:
    """sLSTM: scalar-memory LSTM with per-head recurrence (lax.scan over
    time — inherently sequential, as in the paper)."""
    b, s, d = x.shape
    nh, dh, _ = p["R"].shape
    gx = jnp.einsum("bsd,de->bse", x, p["W"])             # [b,s,4*nh*dh]

    def step(carry, g_t):
        h, c, n = carry                                    # [b,nh,dh]
        rec = jnp.einsum("bhd,hde->bhe", h, p["R"])        # [b,nh,4*dh]
        g = g_t.reshape(b, nh, 4 * dh) + rec
        i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        i = jnp.exp(jnp.minimum(i, 8.0))                   # capped exp gate
        f = jax.nn.sigmoid(f)
        c = f * c + i * jnp.tanh(z)
        n = f * n + i
        h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
        return (h, c, n), h

    h0 = jnp.zeros((b, nh, dh), jnp.float32)
    (_, _, _), hs = jax.lax.scan(
        step, (h0, h0, h0), jnp.moveaxis(gx, 1, 0)
    )
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, nh * dh).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out"])


def slstm_step(x, state, p, cfg):
    h, c, n = state
    b, d = x.shape
    nh, dh, _ = p["R"].shape
    g = jnp.einsum("bd,de->be", x, p["W"]).reshape(b, nh, 4 * dh)
    g = g + jnp.einsum("bhd,hde->bhe", h, p["R"])
    i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    i = jnp.exp(jnp.minimum(i, 8.0))
    f = jax.nn.sigmoid(f)
    c = f * c + i * jnp.tanh(z)
    n = f * n + i
    h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
    y = h.reshape(b, nh * dh).astype(x.dtype)
    return jnp.einsum("be,ed->bd", y, p["out"]), (h, c, n)
