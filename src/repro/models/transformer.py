"""Transformer building blocks: attention block (GQA/MQA/MLA), dense/MoE
FFN, and the per-family layer bodies used under ``lax.scan``.

Everything is functional: ``block(params, x, ...) -> x``.  Decode variants
thread an explicit cache.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (
    DP_AXES,
    apply_rope,
    blockwise_attention,
    constrain,
    decode_attention,
    mlp,
    rms_norm,
)
from .moe import moe_layer

__all__ = [
    "attention",
    "attention_decode",
    "mla_attention",
    "mla_attention_decode",
    "ffn",
    "decoder_block",
    "decoder_block_decode",
]


# ------------------------------------------------------------- attention
def _qkv(x, p, cfg: ModelConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(
        b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(
        b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(
        b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return q, k, v


def attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    positions: jax.Array,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence (training / prefill) GQA attention."""
    b, s, _ = x.shape
    q, k, v = _qkv(x, p, cfg)
    q = apply_rope(q, positions, cfg.rope_type, cfg.rope_theta,
                   cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_type, cfg.rope_theta,
                   cfg.mrope_sections)
    o = blockwise_attention(
        q, k, v, causal=causal,
        block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        unroll=cfg.unroll_layers, causal_skip=cfg.attn_causal_skip,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])


def attention_prefill_cache(x, p, cfg, positions):
    """Prefill: returns (output, (k_cache, v_cache))."""
    b, s, _ = x.shape
    q, k, v = _qkv(x, p, cfg)
    q = apply_rope(q, positions, cfg.rope_type, cfg.rope_theta,
                   cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_type, cfg.rope_theta,
                   cfg.mrope_sections)
    o = blockwise_attention(q, k, v, causal=True,
                            block_q=cfg.attn_block_q,
                            block_k=cfg.attn_block_k,
                            unroll=cfg.unroll_layers)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"]), (k, v)


def attention_decode(
    x: jax.Array,            # [b, d] single token
    p: dict,
    cfg: ModelConfig,
    cache: Tuple[jax.Array, jax.Array],   # k/v [b, kv, S, hd]
    length: jax.Array,       # current cache fill (scalar int32)
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    b, _ = x.shape
    hd = cfg.resolved_head_dim
    k_cache, v_cache = cache
    pos = jnp.full((b, 1), length, dtype=jnp.int32)
    xq = x[:, None]
    q = jnp.einsum("bsd,de->bse", xq, p["wq"]).reshape(
        b, 1, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,de->bse", xq, p["wk"]).reshape(
        b, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,de->bse", xq, p["wv"]).reshape(
        b, 1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    rope_pos = pos if cfg.rope_type != "mrope" else jnp.broadcast_to(
        pos[:, None, :], (b, 3, 1))
    q = apply_rope(q, rope_pos, cfg.rope_type, cfg.rope_theta,
                   cfg.mrope_sections)
    k = apply_rope(k, rope_pos, cfg.rope_type, cfg.rope_theta,
                   cfg.mrope_sections)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.transpose(0, 1, 2, 3), (0, 0, length, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v, (0, 0, length, 0))
    o = decode_attention(q, k_cache, v_cache, length + 1)
    o = o.reshape(b, -1)
    return jnp.einsum("be,ed->bd", o, p["wo"]), (k_cache, v_cache)


# ----------------------------------------------------------------- MLA
def _mla_qkv(x, p, cfg: ModelConfig, positions):
    """DeepSeek-V2 multi-head latent attention: KV compressed to kv_lora
    dims + a decoupled shared RoPE key."""
    b, s, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(
        b, s, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, "full", cfg.rope_theta)

    ckv = jnp.einsum("bsd,de->bse", x, p["kv_down"])   # [b,s,lora+dr]
    c, k_rope = ckv[..., : cfg.kv_lora], ckv[..., cfg.kv_lora:]
    k_rope = apply_rope(
        k_rope[:, None], positions, "full", cfg.rope_theta)  # [b,1,s,dr]
    k_nope = jnp.einsum("bsc,ce->bse", c, p["k_up"]).reshape(
        b, s, H, dn).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsc,ce->bse", c, p["v_up"]).reshape(
        b, s, H, dv).transpose(0, 2, 1, 3)
    k_rope_b = jnp.broadcast_to(k_rope, (b, H, s, dr))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return q_full, k_full, v, ckv


def mla_attention(x, p, cfg: ModelConfig, positions) -> jax.Array:
    b, s, _ = x.shape
    q, k, v, _ = _mla_qkv(x, p, cfg, positions)
    o = blockwise_attention(q, k, v, causal=True,
                            block_q=cfg.attn_block_q,
                            block_k=cfg.attn_block_k,
                            unroll=cfg.unroll_layers,
                            causal_skip=cfg.attn_causal_skip)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])


def mla_attention_decode(x, p, cfg: ModelConfig, cache, length):
    """Cache holds the *compressed* ckv [b, S, lora+dr] — the MLA memory
    win (this is why deepseek's 32k cache fits where GQA's would not)."""
    b, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos = jnp.full((b, 1), length, dtype=jnp.int32)
    xq = x[:, None]
    q = jnp.einsum("bsd,de->bse", xq, p["wq"]).reshape(
        b, 1, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, "full", cfg.rope_theta)

    ckv_new = jnp.einsum("bsd,de->bse", xq, p["kv_down"])[:, 0]
    # rope the decoupled key before caching (cache stores roped keys)
    c_new, kr_new = ckv_new[..., : cfg.kv_lora], ckv_new[..., cfg.kv_lora:]
    kr_new = apply_rope(kr_new[:, None, None], pos, "full",
                        cfg.rope_theta)[:, 0, 0]
    ckv_new = jnp.concatenate([c_new, kr_new], axis=-1)
    cache = jax.lax.dynamic_update_slice(
        cache, ckv_new[:, None], (0, length, 0))

    c = cache[..., : cfg.kv_lora]                       # [b,S,lora]
    k_rope = cache[..., cfg.kv_lora:]                   # [b,S,dr]
    k_nope = jnp.einsum("bsc,ce->bse", c, p["k_up"]).reshape(
        b, -1, H, dn).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsc,ce->bse", c, p["v_up"]).reshape(
        b, -1, H, dv).transpose(0, 2, 1, 3)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None],
                                  (b, H, cache.shape[1], dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = decode_attention(q_full, k_full, v, length + 1)
    o = o.reshape(b, -1)
    return jnp.einsum("be,ed->bd", o, p["wo"]), cache


def mla_attention_decode_absorbed(x, p, cfg: ModelConfig, cache, length):
    """MLA decode with up-projection absorption (§Perf lever).

    Never materializes k_nope/v [b,S,H,·]: scores act directly on the
    compressed cache via q_abs = q_nopeᵀW_uk and out = (p·c)ᵀW_uv.
    Per-token FLOPs drop from O(S·lora·H·(dn+dv)) to O(S·H·(2·lora+dr))
    — ~100× on deepseek-v2 dims."""
    b, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.kv_lora
    pos = jnp.full((b, 1), length, dtype=jnp.int32)
    xq = x[:, None]
    q = jnp.einsum("bsd,de->bse", xq, p["wq"]).reshape(
        b, 1, H, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, "full", cfg.rope_theta)[:, :, 0]

    ckv_new = jnp.einsum("bsd,de->bse", xq, p["kv_down"])[:, 0]
    c_new, kr_new = ckv_new[..., :lora], ckv_new[..., lora:]
    kr_new = apply_rope(kr_new[:, None, None], pos, "full",
                        cfg.rope_theta)[:, 0, 0]
    ckv_new = jnp.concatenate([c_new, kr_new], axis=-1)
    cache = jax.lax.dynamic_update_slice(
        cache, ckv_new[:, None], (0, length, 0))

    c = cache[..., :lora].astype(jnp.float32)          # [b,S,lora]
    k_rope = cache[..., lora:].astype(jnp.float32)     # [b,S,dr]
    k_up3 = p["k_up"].reshape(lora, H, dn).astype(jnp.float32)
    v_up3 = p["v_up"].reshape(lora, H, dv).astype(jnp.float32)

    q_abs = jnp.einsum("bhsd,lhd->bhl",
                       q_nope.astype(jnp.float32), k_up3)
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bhl,bsl->bhs", q_abs, c)
         + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                      k_rope)) * scale
    S_len = cache.shape[1]
    valid = jnp.arange(S_len)[None, :] < (length + 1)
    s = jnp.where(valid[:, None, :], s, -1e30)
    pv = jax.nn.softmax(s, axis=-1)
    out_c = jnp.einsum("bhs,bsl->bhl", pv, c)
    o = jnp.einsum("bhl,lhd->bhd", out_c, v_up3)
    o = o.reshape(b, H * dv).astype(x.dtype)
    return jnp.einsum("be,ed->bd", o, p["wo"]), cache


# ------------------------------------------------------------------ FFN
def ffn(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.is_moe:
        return moe_layer(x, p, cfg)
    return mlp(x, p, cfg.mlp_type)


# -------------------------------------------------------- decoder block
def decoder_block(x, p, cfg: ModelConfig, positions, causal=True):
    """Pre-norm transformer block (the scanned layer body)."""
    act_spec = (DP_AXES, "model" if cfg.seq_shard else None, None)
    x = constrain(x, act_spec)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if cfg.is_mla:
        h = mla_attention(h, p["attn"], cfg, positions)
    else:
        h = attention(h, p["attn"], cfg, positions, causal=causal)
    x = x + h
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + ffn(h, p["ffn"], cfg)
    return constrain(x, act_spec)


def decoder_block_decode(x, p, cfg: ModelConfig, cache, length):
    x = constrain(x, (DP_AXES, None))
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if cfg.is_mla and cfg.mla_absorb:
        h, cache = mla_attention_decode_absorbed(
            h, p["attn"], cfg, cache, length)
    elif cfg.is_mla:
        h, cache = mla_attention_decode(h, p["attn"], cfg, cache, length)
    else:
        h, cache = attention_decode(h, p["attn"], cfg, cache, length)
    x = x + h
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + ffn(h[:, None], p["ffn"], cfg)[:, 0]
    return x, cache
