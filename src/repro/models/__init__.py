"""Model zoo: the ten assigned architectures as one functional family."""
from .config import ModelConfig, reduced
from .model import (
    SHAPE_SETS,
    abstract_params,
    cache_specs,
    forward,
    init_params,
    input_specs,
    logical_axes,
    prefill,
    serve_step,
    shape_applicable,
    train_loss,
)

__all__ = [
    "ModelConfig",
    "reduced",
    "SHAPE_SETS",
    "abstract_params",
    "cache_specs",
    "forward",
    "init_params",
    "input_specs",
    "logical_axes",
    "prefill",
    "serve_step",
    "shape_applicable",
    "train_loss",
]
