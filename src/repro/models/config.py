"""Model configuration schema covering all ten assigned architectures.

One frozen dataclass; every family (dense / moe / ssm / hybrid / audio /
vlm) is a point in this space.  ``src/repro/configs/<arch>.py`` holds the
exact published values.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # --- MLP / norm flavour
    mlp_type: str = "swiglu"         # swiglu | geglu
    norm_eps: float = 1e-5
    scale_embedding: bool = False    # gemma-style sqrt(d) scaling
    tie_embeddings: bool = True

    # --- RoPE flavour
    rope_type: str = "full"          # full | half | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()

    # --- MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v2)
    kv_lora: int = 0                 # compressed kv dim (0 = standard GQA)
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM / hybrid
    ssm_state: int = 0               # mamba2 state size
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 64
    slstm_every: int = 0             # xlstm: one sLSTM per this many layers
    attn_every: int = 0              # zamba2: shared attn block period
    lstm_proj_factor: int = 2

    # --- encoder-decoder (whisper)
    is_encdec: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # precomputed frame embeddings (stub)

    # --- modality frontend stub
    frontend: str = "none"           # none | audio_stub | patch_stub

    # --- attention impl
    attn_block_q: int = 512
    attn_block_k: int = 512
    # §Perf knobs (hillclimb levers — defaults = paper-faithful baseline)
    attn_causal_skip: bool = False   # skip upper-triangular kv blocks
    remat_policy: str = "full"       # full | dots | none
    loss_chunk: int = 0              # chunked CE loss (0 = monolithic)
    mla_absorb: bool = False         # absorb k_up/v_up into q/out (decode)
    shard_state_dim: bool = False    # recurrent state: shard feature dim
    #                                  over 'model' (nh often < mesh axis)
    seq_shard: bool = False          # sequence-parallel activations
    #                                  (shard seq over 'model' at layer
    #                                  boundaries; attention re-gathers)

    # --- training
    max_seq: int = 4096
    remat: bool = True

    # --- cost-analysis mode: XLA's HloCostAnalysis counts while/scan
    # bodies ONCE, so the roofline harness compiles unrolled shallow
    # variants (L=1, L=2) and extrapolates the per-layer slope.
    unroll_layers: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_mla(self) -> bool:
        return self.kv_lora > 0

    @property
    def is_recurrent(self) -> bool:
        """O(1)-state decode (eligible for long_500k)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Total parameter count (exact for the families implemented)."""
        from . import model as _m  # lazy, avoids cycle
        import jax
        shapes = _m.abstract_params(self)
        return sum(
            int(x.size) for x in jax.tree_util.tree_leaves(shapes)
        )

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        import jax
        from . import model as _m
        shapes = _m.abstract_params(self)
        expert = sum(
            int(x.size)
            for k, x in _m.flat_items(shapes)
            if k.endswith((".we1", ".we2", ".we3"))
        )
        per_expert = expert // max(self.n_experts, 1)
        return total - expert + per_expert * self.top_k


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized variant of an architecture: same family/topology,
    tiny dims.  Keeps structural ratios (GQA grouping, MoE top-k, block
    patterns) intact."""
    small = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4),
        head_dim=32,
        d_ff=256,
        vocab=512,
        max_seq=128,
        attn_block_q=64,
        attn_block_k=64,
        ssm_chunk=16,
    )
    if cfg.n_kv_heads == cfg.n_heads:
        small["n_kv_heads"] = 4
    elif cfg.n_kv_heads == 1:
        small["n_kv_heads"] = 1
    else:
        small["n_kv_heads"] = 2
    if cfg.is_moe:
        small.update(
            n_experts=min(cfg.n_experts, 8),
            top_k=min(cfg.top_k, 2),
            d_ff_expert=128,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            # no capacity drops at toy scale: keeps decode ≡ forward exact
            capacity_factor=8.0,
        )
    if cfg.is_mla:
        small.update(kv_lora=64, qk_nope_dim=32, qk_rope_dim=16,
                     v_head_dim=32)
    if cfg.ssm_state:
        small.update(ssm_state=16)
    if cfg.slstm_every:
        small.update(n_layers=cfg.slstm_every, slstm_every=cfg.slstm_every)
    if cfg.attn_every:
        small.update(n_layers=2 * cfg.attn_every, attn_every=cfg.attn_every)
    if cfg.is_encdec:
        small.update(encoder_layers=2, encoder_seq=64)
    if cfg.mrope_sections:
        # rescale sections to the reduced head_dim (roughly 1:1.5:1.5)
        hd2 = small.get("head_dim", cfg.resolved_head_dim) // 2
        a = hd2 // 4
        b_ = (hd2 - a) // 2
        small.update(mrope_sections=(a, b_, hd2 - a - b_))
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
