"""Mixture-of-experts layer — group-wise sort-based dispatch.

Tokens are routed *within their sequence group* (leading batch axis),
so every dispatch op — top-k, per-group sort, rank, scatter — is batched
over a dimension that stays sharded over ``data``; expert buffers shard
experts over ``model`` (EP).  No global sort, no replicated buffers
(a global-sort first cut replicated dispatch buffers: 200 GB/device
temps on dbrx train_4k — see EXPERIMENTS §Perf iteration 0b).

FLOPs scale with top-k·capacity_factor, not n_experts, so the
roofline's 6·N_active·D accounting holds.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import DP_AXES, constrain, mlp

__all__ = ["moe_layer", "capacity"]


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    c = math.ceil(
        group_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_layer(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    """x: [b, s, d] -> [b, s, d].  p: router, we1/we2/we3, shared."""
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, s)
    sk = s * k

    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32),
        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                  # [b, s, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # ---- group-local dispatch (everything batched over b)
    e_flat = idx.reshape(b, sk)
    order = jnp.argsort(e_flat, axis=1)                   # [b, sk]
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    t_sorted = order // k                                 # token within group
    # bucket starts via searchsorted on the sorted expert ids — O(sk·logE)
    # (a [b, sk, E] one-hot here cost hundreds of GB of temps at scale)
    start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E, dtype=row.dtype))
    )(e_sorted).astype(jnp.int32)                         # [b, E]
    rank = jnp.arange(sk)[None, :] - jnp.take_along_axis(
        start, e_sorted, axis=1)
    keep = rank < C
    slot = jnp.where(keep, e_sorted * C + rank, E * C)    # overflow bin

    rows = jnp.arange(b)[:, None]
    x_sorted = jnp.take_along_axis(
        x, t_sorted[..., None], axis=1)                   # [b, sk, d]
    buf = jnp.zeros((b, E * C + 1, d), x.dtype).at[rows, slot].add(x_sorted)
    eb = buf[:, :-1].reshape(b, E, C, d)
    # EP: groups shard over data, experts over model
    eb = constrain(eb, (DP_AXES, "model", None, None))

    # ---- expert FFN
    act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
    g = act(jnp.einsum("becd,edf->becf", eb, p["we1"]))
    u = jnp.einsum("becd,edf->becf", eb, p["we3"])
    out_e = jnp.einsum("becf,efd->becd", g * u, p["we2"])
    out_e = constrain(out_e, (DP_AXES, "model", None, None))

    # ---- combine (undo sort, weight by gates)
    flat = jnp.concatenate(
        [out_e.reshape(b, E * C, d),
         jnp.zeros((b, 1, d), x.dtype)], axis=1)
    picked = jnp.take_along_axis(flat, slot[..., None], axis=1)
    picked = picked * keep[..., None].astype(x.dtype)     # [b, sk, d]
    inv = jnp.zeros_like(order).at[rows, order].set(
        jnp.broadcast_to(jnp.arange(sk)[None], (b, sk)))
    per_tk = jnp.take_along_axis(picked, inv[..., None], axis=1)
    per_tk = per_tk.reshape(b, s, k, d)
    out = jnp.einsum("bskd,bsk->bsd", per_tk, gates.astype(x.dtype))

    if cfg.n_shared_experts:
        out = out + mlp(x, p["shared"], cfg.mlp_type)
    return out
