"""Model assembly: parameter specs, init, train/prefill/decode forwards,
and input specs for every assigned architecture family.

Layers are stacked on a leading axis and driven by ``lax.scan`` so the
HLO stays layer-count-independent (mandatory for compiling 80-layer
models at 512 devices on this container).

Logical sharding axes used in specs (resolved by sharding/partition.py):
    "embed"   — d_model-like dims            -> fsdp ("data")
    "heads"   — attention head / q dims      -> tensor ("model")
    "kv"      — kv head dims                 -> tensor if divisible
    "mlp"     — ffn hidden                   -> tensor
    "expert"  — MoE expert axis              -> tensor (EP)
    "vocab"   — vocabulary                   -> tensor
    "layers", None — never sharded
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (
    DP_AXES,
    apply_rope,
    blockwise_attention,
    constrain,
    mlp,
    mrope_positions,
    rms_norm,
)
from . import ssm as S
from .transformer import (
    attention,
    decoder_block,
    decoder_block_decode,
)

__all__ = [
    "PSpec",
    "param_specs",
    "abstract_params",
    "init_params",
    "logical_axes",
    "flat_items",
    "train_loss",
    "prefill",
    "serve_step",
    "cache_specs",
    "input_specs",
    "SHAPE_SETS",
    "shape_applicable",
]


class PSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | ones | zeros | a_log


# =====================================================================
# Parameter specs per family
# =====================================================================
def _attn_specs(cfg: ModelConfig, L: int, cross: bool = False) -> Dict:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    if cfg.is_mla and not cross:
        dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
        return dict(
            wq=PSpec((L, d, cfg.n_heads * (dn + dr)),
                     ("layers", "embed", "heads")),
            kv_down=PSpec((L, d, cfg.kv_lora + dr),
                          ("layers", "embed", None)),
            k_up=PSpec((L, cfg.kv_lora, cfg.n_heads * dn),
                       ("layers", None, "heads")),
            v_up=PSpec((L, cfg.kv_lora, cfg.n_heads * dv),
                       ("layers", None, "heads")),
            wo=PSpec((L, cfg.n_heads * dv, d), ("layers", "heads", "embed")),
        )
    return dict(
        wq=PSpec((L, d, cfg.n_heads * hd), ("layers", "embed", "heads")),
        wk=PSpec((L, d, cfg.n_kv_heads * hd), ("layers", "embed", "kv")),
        wv=PSpec((L, d, cfg.n_kv_heads * hd), ("layers", "embed", "kv")),
        wo=PSpec((L, cfg.n_heads * hd, d), ("layers", "heads", "embed")),
    )


def _ffn_specs(cfg: ModelConfig, L: int) -> Dict:
    d = cfg.d_model
    if cfg.is_moe:
        fe = cfg.d_ff_expert
        out = dict(
            router=PSpec((L, d, cfg.n_experts), ("layers", "embed", None)),
            we1=PSpec((L, cfg.n_experts, d, fe),
                      ("layers", "expert", "embed", None)),
            we3=PSpec((L, cfg.n_experts, d, fe),
                      ("layers", "expert", "embed", None)),
            we2=PSpec((L, cfg.n_experts, fe, d),
                      ("layers", "expert", None, "embed")),
        )
        if cfg.n_shared_experts:
            fs = fe * cfg.n_shared_experts
            out["shared"] = dict(
                w1=PSpec((L, d, fs), ("layers", "embed", "mlp")),
                w3=PSpec((L, d, fs), ("layers", "embed", "mlp")),
                w2=PSpec((L, fs, d), ("layers", "mlp", "embed")),
            )
        return out
    ff = cfg.d_ff
    out = dict(
        w1=PSpec((L, d, ff), ("layers", "embed", "mlp")),
        w2=PSpec((L, ff, d), ("layers", "mlp", "embed")),
    )
    if cfg.mlp_type in ("swiglu", "geglu"):
        out["w3"] = PSpec((L, d, ff), ("layers", "embed", "mlp"))
    return out


def _decoder_block_specs(cfg: ModelConfig, L: int) -> Dict:
    d = cfg.d_model
    return dict(
        norm1=PSpec((L, d), ("layers", None), "ones"),
        attn=_attn_specs(cfg, L),
        norm2=PSpec((L, d), ("layers", None), "ones"),
        ffn=_ffn_specs(cfg, L),
    )


def _mamba_specs(cfg: ModelConfig, L: int) -> Dict:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    nh = d_inner // 64                      # mamba2 head dim 64
    d_in = 2 * d_inner + 2 * cfg.ssm_state + nh
    return dict(
        norm=PSpec((L, d), ("layers", None), "ones"),
        in_proj=PSpec((L, d, d_in), ("layers", "embed", "heads")),
        conv_w=PSpec((L, cfg.ssm_conv, d_inner), ("layers", None, "heads")),
        A_log=PSpec((L, nh), ("layers", None), "a_log"),
        dt_bias=PSpec((L, nh), ("layers", None), "zeros"),
        D=PSpec((L, nh), ("layers", None), "ones"),
        out_proj=PSpec((L, d_inner, d), ("layers", "heads", "embed")),
    )


def _xlstm_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    G = cfg.n_layers // cfg.slstm_every
    M = cfg.slstm_every - 1
    pf = cfg.lstm_proj_factor
    di = pf * d
    nh = cfg.n_heads
    dh2 = d // nh
    return dict(
        mlstm=dict(
            norm=PSpec((G, M, d), ("layers", "layers", None), "ones"),
            up_proj=PSpec((G, M, d, 2 * di),
                          ("layers", "layers", "embed", "heads")),
            wq=PSpec((G, M, di, di), ("layers", "layers", None, "heads")),
            wk=PSpec((G, M, di, di), ("layers", "layers", None, "heads")),
            wv=PSpec((G, M, di, di), ("layers", "layers", None, "heads")),
            wg=PSpec((G, M, di, 2 * nh), ("layers", "layers", "heads", None)),
            down_proj=PSpec((G, M, di, d),
                            ("layers", "layers", "heads", "embed")),
        ),
        slstm=dict(
            norm=PSpec((G, d), ("layers", None), "ones"),
            W=PSpec((G, d, 4 * nh * dh2), ("layers", "embed", "heads")),
            R=PSpec((G, nh, dh2, 4 * dh2), ("layers", "kv", None, None)),
            out=PSpec((G, nh * dh2, d), ("layers", "heads", "embed")),
        ),
    )


def param_specs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    specs: Dict[str, Any] = dict(
        embed=PSpec((cfg.vocab, d), ("vocab", "embed")),
        final_norm=PSpec((d,), (None,), "ones"),
    )
    if not cfg.tie_embeddings:
        specs["lm_head"] = PSpec((d, cfg.vocab), ("embed", "vocab"))

    if cfg.family in ("dense", "moe", "vlm"):
        specs["blocks"] = _decoder_block_specs(cfg, cfg.n_layers)
    elif cfg.family == "ssm":
        specs.update(_xlstm_specs(cfg))
    elif cfg.family == "hybrid":
        specs["blocks"] = _mamba_specs(cfg, cfg.n_layers)
        shared = ModelConfig(**{
            **dataclasses.asdict(cfg), "kv_lora": 0, "n_experts": 0,
        })
        specs["shared_attn"] = dict(
            norm1=PSpec((d,), (None,), "ones"),
            attn={k: PSpec(v.shape[1:], v.axes[1:], v.init)
                  for k, v in _attn_specs(shared, 1).items()},
            norm2=PSpec((d,), (None,), "ones"),
            ffn={k: PSpec(v.shape[1:], v.axes[1:], v.init)
                 for k, v in _ffn_specs(shared, 1).items()},
        )
    elif cfg.family == "audio":  # whisper enc-dec
        specs["enc_blocks"] = _decoder_block_specs(cfg, cfg.encoder_layers)
        dec = _decoder_block_specs(cfg, cfg.n_layers)
        dec["norm_x"] = PSpec((cfg.n_layers, d), ("layers", None), "ones")
        dec["cross"] = _attn_specs(cfg, cfg.n_layers)
        specs["dec_blocks"] = dec
        specs["enc_norm"] = PSpec((d,), (None,), "ones")
    else:
        raise ValueError(cfg.family)
    return specs


# ------------------------------------------------------------- realize
def flat_items(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from flat_items(v, f"{prefix}.{k}" if prefix else k)
    else:
        yield prefix, tree


def _map_specs(fn, tree):
    if isinstance(tree, dict):
        return {k: _map_specs(fn, v) for k, v in tree.items()}
    return fn(tree)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return _map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), param_specs(cfg)
    )


def logical_axes(cfg: ModelConfig):
    return _map_specs(lambda s: s.axes, param_specs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    specs = list(flat_items(param_specs(cfg)))
    keys = jax.random.split(key, len(specs))
    out: Dict[str, Any] = {}

    def put(path, val):
        node = out
        parts = path.split(".")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    for (path, spec), k in zip(specs, keys):
        if spec.init == "ones":
            v = jnp.ones(spec.shape, dtype)
        elif spec.init == "zeros":
            v = jnp.zeros(spec.shape, dtype)
        elif spec.init == "a_log":
            v = jnp.zeros(spec.shape, dtype)  # A = -1
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            v = (jax.random.normal(k, spec.shape, jnp.float32)
                 * (fan_in ** -0.5)).astype(dtype)
        put(path, v)
    return out


# =====================================================================
# Forward passes
# =====================================================================
def _embed(params, tokens, cfg: ModelConfig):
    x = params["embed"][tokens]
    if cfg.scale_embedding:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return constrain(x, (DP_AXES,) + (None,) * (x.ndim - 1))


def _unembed(params, x, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    out = jnp.einsum("...d,dv->...v", x, w)
    return constrain(out, (DP_AXES,) + (None,) * (out.ndim - 2) + ("model",))


def _sinusoid(s: int, d: int, dtype) -> jax.Array:
    pos = np.arange(s)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10_000 ** (2 * i / d))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)


def _sinusoid_at(pos: jax.Array, d: int, dtype) -> jax.Array:
    """Sinusoidal embedding for one (traced) position scalar."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10_000 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat or cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def layer_scan(body, carry, xs, cfg: ModelConfig):
    """lax.scan over stacked layers, or an unrolled python loop when
    cfg.unroll_layers (cost-analysis mode — see config.py)."""
    if not cfg.unroll_layers:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def _positions(cfg, b, s, given=None):
    if given is not None:
        return given
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.rope_type == "mrope":
        return mrope_positions(pos)
    return pos


# ----------------------------------------------------- decoder backbone
def _decoder_backbone(params, x, cfg: ModelConfig, positions):
    body = _maybe_remat(
        lambda h, p: (decoder_block(h, p, cfg, positions), None), cfg
    )
    x, _ = layer_scan(body, x, params["blocks"], cfg)
    return x


def _xlstm_backbone(params, x, cfg: ModelConfig):
    def mlstm_layer(h, p):
        h = h + S.mlstm_mix(rms_norm(h, p["norm"], cfg.norm_eps), p, cfg)
        return constrain(h, (DP_AXES, None, None)), None

    def group(h, gp):
        h, _ = layer_scan(_maybe_remat(mlstm_layer, cfg), h, gp["mlstm"], cfg)
        sp = gp["slstm"]
        h = h + S.slstm_mix(rms_norm(h, sp["norm"], cfg.norm_eps), sp, cfg)
        return constrain(h, (DP_AXES, None, None)), None

    x, _ = layer_scan(
        group, x, {"mlstm": params["mlstm"], "slstm": params["slstm"]}, cfg
    )
    return x


def _zamba_backbone(params, x, cfg: ModelConfig, positions):
    shared = params["shared_attn"]
    L = cfg.n_layers
    use_attn = jnp.asarray(
        [(i + 1) % cfg.attn_every == 0 for i in range(L)])

    def layer(h, inp):
        p, flag = inp
        h = h + S.mamba2_mix(rms_norm(h, p["norm"], cfg.norm_eps), p, cfg)

        def with_attn(h):
            a = rms_norm(h, shared["norm1"], cfg.norm_eps)
            a = attention(a, shared["attn"], cfg, positions, causal=True)
            h = h + a
            f = rms_norm(h, shared["norm2"], cfg.norm_eps)
            return h + mlp(f, shared["ffn"], cfg.mlp_type)

        h = jax.lax.cond(flag, with_attn, lambda v: v, h)
        return constrain(h, (DP_AXES, None, None)), None

    x, _ = layer_scan(
        _maybe_remat(layer, cfg), x, (params["blocks"], use_attn), cfg
    )
    return x


def _whisper_encode(params, frames, cfg: ModelConfig):
    b, se, d = frames.shape
    x = frames + _sinusoid(se, d, frames.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None], (b, se))
    body = _maybe_remat(
        lambda h, p: (decoder_block(h, p, cfg, pos, causal=False), None),
        cfg,
    )
    x, _ = layer_scan(body, x, params["enc_blocks"], cfg)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attention(x, p, cfg: ModelConfig, memory):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(
        b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = jnp.einsum("bsd,de->bse", memory, p["wk"]).reshape(
        b, -1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = jnp.einsum("bsd,de->bse", memory, p["wv"]).reshape(
        b, -1, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    o = blockwise_attention(q, k, v, causal=False,
                            block_q=cfg.attn_block_q,
                            block_k=min(cfg.attn_block_k, k.shape[2]),
                            unroll=cfg.unroll_layers)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"])


def _whisper_decode_train(params, x, cfg: ModelConfig, positions, enc_out):
    def body(h, p):
        a = rms_norm(h, p["norm1"], cfg.norm_eps)
        h = h + attention(a, p["attn"], cfg, positions, causal=True)
        cx = rms_norm(h, p["norm_x"], cfg.norm_eps)
        h = h + _cross_attention(cx, p["cross"], cfg, enc_out)
        f = rms_norm(h, p["norm2"], cfg.norm_eps)
        h = h + mlp(f, p["ffn"], cfg.mlp_type)
        return constrain(h, (DP_AXES, None, None)), None

    x, _ = layer_scan(_maybe_remat(body, cfg), x, params["dec_blocks"], cfg)
    return x


def forward(params, tokens, cfg: ModelConfig, positions=None,
            frames=None, return_hidden: bool = False) -> jax.Array:
    """Full-sequence forward -> logits [b, s, vocab] (or hidden)."""
    b, s = tokens.shape
    pos = _positions(cfg, b, s, positions)
    x = _embed(params, tokens, cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        x = _decoder_backbone(params, x, cfg, pos)
    elif cfg.family == "ssm":
        x = _xlstm_backbone(params, x, cfg)
    elif cfg.family == "hybrid":
        x = _zamba_backbone(params, x, cfg, pos)
    elif cfg.family == "audio":
        enc_out = _whisper_encode(params, frames, cfg)
        x = x + _sinusoid(s, cfg.d_model, x.dtype)[None]
        x = _whisper_decode_train(params, x, cfg, pos, enc_out)
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return _unembed(params, x, cfg)


def _nll(params, x, labels, cfg) -> jax.Array:
    logits = _unembed(params, x, cfg).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def train_loss(params, batch, cfg: ModelConfig) -> jax.Array:
    labels = batch["labels"]
    if cfg.loss_chunk:
        # §Perf lever: never materialize the full [b, s, vocab] logits —
        # unembed + CE one sequence chunk at a time
        x = forward(params, batch["tokens"], cfg,
                    positions=batch.get("positions"),
                    frames=batch.get("frames"), return_hidden=True)
        b, s, d = x.shape
        c = min(cfg.loss_chunk, s)
        assert s % c == 0, (s, c)
        xc = x.reshape(b, s // c, c, d).swapaxes(0, 1)
        lc = labels.reshape(b, s // c, c).swapaxes(0, 1)

        def body(tot, inp):
            xi, li = inp
            return tot + jnp.sum(_nll(params, xi, li, cfg)), None

        if cfg.unroll_layers:
            tot = jnp.float32(0)
            for i in range(s // c):
                tot, _ = body(tot, (xc[i], lc[i]))
        else:
            tot, _ = jax.lax.scan(body, jnp.float32(0), (xc, lc))
        return tot / (b * s)
    x = forward(params, batch["tokens"], cfg,
                positions=batch.get("positions"),
                frames=batch.get("frames"), return_hidden=True)
    return jnp.mean(_nll(params, x, labels, cfg))


def prefill(params, tokens, cfg: ModelConfig, positions=None, frames=None):
    """Prefill = full forward; returns last-position logits.

    (The KV cache produced during a production prefill is the same k/v
    tensors the forward computes; for the dry-run we account its cost via
    the forward itself.)"""
    logits = forward(params, tokens, cfg, positions, frames)
    return logits[:, -1]


# =====================================================================
# Decode (serve_step)
# =====================================================================
def cache_specs(cfg: ModelConfig, batch: int, seq: int,
                dtype=jnp.bfloat16) -> Dict:
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.is_mla:
            return dict(ckv=jax.ShapeDtypeStruct(
                (L, batch, seq, cfg.kv_lora + cfg.qk_rope_dim), dtype))
        return dict(
            k=jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, seq, hd), dtype),
            v=jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, seq, hd), dtype),
        )
    if cfg.family == "ssm":
        G = cfg.n_layers // cfg.slstm_every
        M = cfg.slstm_every - 1
        nh = cfg.n_heads
        dh = cfg.lstm_proj_factor * cfg.d_model // nh
        dh2 = cfg.d_model // nh
        f32 = jnp.float32
        return dict(
            mlstm_S=jax.ShapeDtypeStruct((G, M, batch, nh, dh, dh), f32),
            mlstm_n=jax.ShapeDtypeStruct((G, M, batch, nh, dh), f32),
            slstm_h=jax.ShapeDtypeStruct((G, batch, nh, dh2), f32),
            slstm_c=jax.ShapeDtypeStruct((G, batch, nh, dh2), f32),
            slstm_n=jax.ShapeDtypeStruct((G, batch, nh, dh2), f32),
        )
    if cfg.family == "hybrid":
        d_inner = cfg.ssm_expand * cfg.d_model
        nh = d_inner // 64
        n_att = cfg.n_layers // cfg.attn_every
        f32 = jnp.float32
        return dict(
            conv=jax.ShapeDtypeStruct(
                (L, batch, cfg.ssm_conv, d_inner), dtype),
            S=jax.ShapeDtypeStruct((L, batch, nh, cfg.ssm_state, 64), f32),
            attn_k=jax.ShapeDtypeStruct(
                (n_att, batch, cfg.n_kv_heads, seq, hd), dtype),
            attn_v=jax.ShapeDtypeStruct(
                (n_att, batch, cfg.n_kv_heads, seq, hd), dtype),
        )
    if cfg.family == "audio":
        return dict(
            k=jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, seq, hd), dtype),
            v=jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, seq, hd), dtype),
            enc_out=jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq, cfg.d_model), dtype),
        )
    raise ValueError(cfg.family)


def serve_step(params, cache: Dict, token: jax.Array, length: jax.Array,
               cfg: ModelConfig):
    """One decode step: token [b] int32 -> (logits [b, vocab], new cache)."""
    x = _embed(params, token[:, None], cfg)[:, 0]

    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.is_mla:
            def body(h, inp):
                p, ckv = inp
                h, ckv = decoder_block_decode(h, p, cfg, ckv, length)
                return h, ckv
            x, ckv = layer_scan(body, x, (params["blocks"], cache["ckv"]), cfg)
            new_cache = dict(ckv=ckv)
        else:
            def body(h, inp):
                p, k, v = inp
                h, (k, v) = decoder_block_decode(h, p, cfg, (k, v), length)
                return h, (k, v)
            x, (k, v) = layer_scan(
                body, x, (params["blocks"], cache["k"], cache["v"]), cfg)
            new_cache = dict(k=k, v=v)

    elif cfg.family == "ssm":
        def mlayer(h, inp):
            p, Sm, nm = inp
            y, (Sm, nm) = S.mlstm_step(
                rms_norm(h, p["norm"], cfg.norm_eps), (Sm, nm), p, cfg)
            return h + y, (Sm, nm)

        def group(h, inp):
            gp, Sm, nm, hh, cc, nn = inp
            h, (Sm, nm) = layer_scan(mlayer, h, (gp["mlstm"], Sm, nm), cfg)
            sp = gp["slstm"]
            y, (hh, cc, nn) = S.slstm_step(
                rms_norm(h, sp["norm"], cfg.norm_eps), (hh, cc, nn), sp, cfg)
            return h + y, (Sm, nm, hh, cc, nn)

        x, st = layer_scan(
            group, x,
            ({"mlstm": params["mlstm"], "slstm": params["slstm"]},
             cache["mlstm_S"], cache["mlstm_n"],
             cache["slstm_h"], cache["slstm_c"], cache["slstm_n"]), cfg)
        new_cache = dict(
            mlstm_S=st[0], mlstm_n=st[1], slstm_h=st[2], slstm_c=st[3],
            slstm_n=st[4])

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        L = cfg.n_layers
        n_att = L // cfg.attn_every
        use_attn = jnp.asarray(
            [(i + 1) % cfg.attn_every == 0 for i in range(L)])
        att_idx = jnp.asarray(
            [((i + 1) // cfg.attn_every - 1) if (i + 1) % cfg.attn_every == 0
             else 0 for i in range(L)], jnp.int32)

        def layer(carry, inp):
            h, ak, av = carry
            p, flag, ai, conv, Sst = inp
            y, (conv, Sst) = S.mamba2_step(
                rms_norm(h, p["norm"], cfg.norm_eps), (conv, Sst), p, cfg)
            h = h + y

            def with_attn(op):
                h, ak, av = op
                from .transformer import attention_decode
                a = rms_norm(h, shared["norm1"], cfg.norm_eps)
                kc = jax.lax.dynamic_index_in_dim(ak, ai, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(av, ai, 0, keepdims=False)
                a, (kc, vc) = attention_decode(
                    a, shared["attn"], cfg, (kc, vc), length)
                ak = jax.lax.dynamic_update_index_in_dim(ak, kc, ai, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, vc, ai, 0)
                h = h + a
                f = rms_norm(h, shared["norm2"], cfg.norm_eps)
                h = h + mlp(f[:, None], shared["ffn"], cfg.mlp_type)[:, 0]
                return h, ak, av

            h, ak, av = jax.lax.cond(
                flag, with_attn, lambda op: op, (h, ak, av))
            return (h, ak, av), (conv, Sst)

        (x, ak, av), (conv, Sst) = layer_scan(
            layer, (x, cache["attn_k"], cache["attn_v"]),
            (params["blocks"], use_attn, att_idx, cache["conv"],
             cache["S"]), cfg)
        new_cache = dict(conv=conv, S=Sst, attn_k=ak, attn_v=av)

    elif cfg.family == "audio":
        enc_out = cache["enc_out"]
        # whisper uses absolute (sinusoidal-stub) positions, not RoPE
        x = x + _sinusoid_at(length, cfg.d_model, x.dtype)[None]

        def body(h, inp):
            from .transformer import attention_decode
            p, k, v = inp
            a = rms_norm(h, p["norm1"], cfg.norm_eps)
            a, (k, v) = attention_decode(a, p["attn"], cfg, (k, v), length)
            h = h + a
            cx = rms_norm(h, p["norm_x"], cfg.norm_eps)
            h = h + _cross_attention(
                cx[:, None], p["cross"], cfg, enc_out)[:, 0]
            f = rms_norm(h, p["norm2"], cfg.norm_eps)
            h = h + mlp(f[:, None], p["ffn"], cfg.mlp_type)[:, 0]
            return h, (k, v)

        x, (k, v) = layer_scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"]), cfg)
        new_cache = dict(k=k, v=v, enc_out=enc_out)
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, x, cfg)
    return logits, new_cache


# =====================================================================
# Input specs per assigned shape
# =====================================================================
SHAPE_SETS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not cfg.is_recurrent:
        return False, (
            "pure full-attention arch: 524k dense-KV decode is "
            "architecturally quadratic — skipped per DESIGN.md §4"
        )
    return True, ""


def input_specs(cfg: ModelConfig, shape: str,
                batch: Optional[int] = None,
                seq: Optional[int] = None) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    info = SHAPE_SETS[shape]
    b = batch or info["batch"]
    s = seq or info["seq"]
    i32 = jnp.int32
    if info["kind"] in ("train", "prefill"):
        # whisper trains/serves on (audio frames -> text): text length s
        out = dict(tokens=jax.ShapeDtypeStruct((b, s), i32))
        if info["kind"] == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.family == "audio":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.rope_type == "mrope":
            out["positions"] = jax.ShapeDtypeStruct((b, 3, s), i32)
        return out
    # decode
    return dict(
        token=jax.ShapeDtypeStruct((b,), i32),
        length=jax.ShapeDtypeStruct((), i32),
        cache=cache_specs(cfg, b, s),
    )
