"""Shared neural net layers — functional JAX, no framework.

Parameters are nested dicts of arrays.  Every parameter has a *logical
sharding axis* tuple declared in the spec tree (see ``model.py``); the
mesh rules in ``sharding/partition.py`` map logical axes to mesh axes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "apply_rope",
    "mrope_positions",
    "mlp",
    "blockwise_attention",
    "decode_attention",
    "constrain",
    "DP_AXES",
]

DP_AXES = ("pod", "data")  # batch shards over these when present


def constrain(x: jax.Array, spec_axes) -> jax.Array:
    """with_sharding_constraint against the *current* mesh, filtering
    axis names that don't exist (so the same model code runs on 1-device
    tests, the 16×16 pod, and the 2×16×16 multi-pod mesh).

    Activation sharding is load-bearing: without it GSPMD propagates the
    FSDP (embed→data) parameter axis into activations and replicates the
    batch — a 16× compute blow-up we caught in the roofline dry-run.
    """
    from jax.sharding import PartitionSpec as _P
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = set(mesh.axis_names) if mesh is not None else set()
    except Exception:
        names = set()
    if not names:
        return x
    out = []
    for s in spec_axes:
        if s is None:
            out.append(None)
        elif isinstance(s, str):
            out.append(s if s in names else None)
        else:
            f = tuple(a for a in s if a in names)
            out.append(f if f else None)
    return jax.lax.with_sharding_constraint(x, _P(*out))


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------ RoPE
def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions [..., s] -> angles [..., s, dim//2]."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    )
    return positions[..., None].astype(jnp.float32) * freqs


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x [..., s, d] with angles [..., s, d//2] (broadcast over heads)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c, s = jnp.cos(angles), jnp.sin(angles)
    c = c.astype(x.dtype)
    s = s.astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def apply_rope(
    x: jax.Array,              # [b, h, s, d]
    positions: jax.Array,      # [b, s]  (or [b, 3, s] for mrope)
    rope_type: str = "full",
    theta: float = 10_000.0,
    sections: Tuple[int, ...] = (),
) -> jax.Array:
    d = x.shape[-1]
    if rope_type == "none":
        return x
    if rope_type == "full":
        ang = _rope_angles(positions, d, theta)[:, None]      # [b,1,s,d/2]
        return _rotate(x, ang)
    if rope_type == "half":
        # chatglm-style 2d rope: rotary on the first half of head dims
        dr = d // 2
        ang = _rope_angles(positions, dr, theta)[:, None]
        return jnp.concatenate(
            [_rotate(x[..., :dr], ang), x[..., dr:]], axis=-1
        )
    if rope_type == "mrope":
        # qwen2-vl: frequency bands split into (t, h, w) sections, each
        # driven by its own position stream.  positions: [b, 3, s].
        assert sections and sum(sections) == d // 2, (sections, d)
        full = _rope_angles(positions, d, theta)   # [b, 3, s, d/2]
        parts = []
        start = 0
        for sec_i, sec in enumerate(sections):
            parts.append(full[:, sec_i, :, start: start + sec])
            start += sec
        ang = jnp.concatenate(parts, axis=-1)[:, None]         # [b,1,s,d/2]
        return _rotate(x, ang)
    raise ValueError(rope_type)


def mrope_positions(positions: jax.Array) -> jax.Array:
    """Text-only default: all three M-RoPE streams share positions."""
    return jnp.broadcast_to(
        positions[:, None, :], (positions.shape[0], 3, positions.shape[1])
    )


# ------------------------------------------------------------------- MLP
def mlp(x: jax.Array, p: dict, kind: str = "swiglu") -> jax.Array:
    act = jax.nn.silu if kind == "swiglu" else (
        lambda y: jax.nn.gelu(y, approximate=True)
    )
    g = act(jnp.einsum("...d,df->...f", x, p["w1"]))
    if kind == "gelu":  # plain (whisper-style), no gate
        return jnp.einsum("...f,fd->...d", g, p["w2"])
    u = jnp.einsum("...d,df->...f", x, p["w3"])
    return jnp.einsum("...f,fd->...d", g * u, p["w2"])


# -------------------------------------------------------------- attention
_NEG = -1e30


def blockwise_attention(
    q: jax.Array,     # [b, n_heads, sq, d]
    k: jax.Array,     # [b, n_kv, sk, d]
    v: jax.Array,     # [b, n_kv, sk, d]
    causal: bool = True,
    block_q: int = 512,
    block_k: int = 512,
    q_offset: int = 0,
    unroll: bool = False,
    causal_skip: bool = False,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp.

    Memory-bounded: the S×S score matrix never materializes (peak
    intermediate is [b, heads, sq, block_k]).  This is what the dry-run
    lowers; on real TPU the Pallas kernel (kernels/flash_attention.py)
    replaces it 1:1.
    """
    b, h, sq, d = q.shape
    n_kv, sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // n_kv
    scale = d ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq0, sk0 = sq, sk
    if sq % bq or sk % bk:  # pad ragged sequences (whisper's 1500 frames)
        pq, pk = (-sq) % bq, (-sk) % bk
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
        sq, sk = sq + pq, sk + pk
    nq, nk = sq // bq, sk // bk

    qb = q.reshape(b, n_kv, g, nq, bq, d).astype(jnp.float32) * scale
    kb = k.reshape(b, n_kv, nk, bk, d)
    vb = v.reshape(b, n_kv, nk, bk, dv)

    q_ids = q_offset + jnp.arange(sq).reshape(nq, bq)
    k_ids = jnp.arange(sk).reshape(nk, bk)

    if causal_skip and causal and sq == sk and bq == bk:
        return _blockwise_causal_skip(
            qb, kb, vb, q_ids, k_ids, dv, unroll
        ).reshape(b, h, sq, dv)[:, :, :sq0].astype(q.dtype)

    def kv_step(carry, inp):
        acc, m, l = carry                       # [b,kv,g,nq,bq,d], [...,bq]
        kblk, vblk, kid = inp                   # [b,kv,bk,d], [nk-slice...]
        s = jnp.einsum(
            "bKgqBd,bKcd->bKgqBc", qb, kblk.astype(jnp.float32)
        )                                        # [b,kv,g,nq,bq,bk]
        if causal:
            mask = kid[None, :] <= q_ids[..., None]   # [nq,bq,bk]
            s = jnp.where(mask[None, None, None], s, _NEG)
        elif sk != sk0:  # mask key padding (non-causal ragged case)
            s = jnp.where((kid < sk0)[None, None, None, None, None],
                          s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bKgqBc,bKcd->bKgqBd", p, vblk.astype(jnp.float32)
        )
        return (acc, m_new, l), None

    acc0 = jnp.zeros((b, n_kv, g, nq, bq, dv), jnp.float32)
    m0 = jnp.full((b, n_kv, g, nq, bq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, nq, bq), jnp.float32)
    xs = (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), k_ids)
    if unroll:  # cost-analysis mode: loop bodies must appear per-trip
        carry = (acc0, m0, l0)
        for i in range(nk):
            carry, _ = kv_step(
                carry, jax.tree.map(lambda a: a[i], xs))
        acc, m, l = carry
    else:
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), xs)
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return out.reshape(b, h, sq, dv)[:, :, :sq0].astype(q.dtype)


def _blockwise_causal_skip(qb, kb, vb, q_ids, k_ids, dv, unroll):
    """Causal attention over the lower-triangular block set only —
    halves attention FLOPs vs the dense-block baseline (§Perf lever).

    Scans the static (i, j ≤ i) pair list; per-q-block online-softmax
    state lives in full-width carries updated with dynamic slices.
    """
    b, n_kv, g, nq, bq, d = qb.shape
    pairs = [(i, j) for i in range(nq) for j in range(i + 1)]

    def step(carry, ij):
        acc, m, l = carry
        i, j = ij
        q_i = jax.lax.dynamic_index_in_dim(qb, i, 3, keepdims=False)
        k_j = jax.lax.dynamic_index_in_dim(kb, j, 2, keepdims=False)
        v_j = jax.lax.dynamic_index_in_dim(vb, j, 2, keepdims=False)
        qid = jax.lax.dynamic_index_in_dim(q_ids, i, 0, keepdims=False)
        kid = jax.lax.dynamic_index_in_dim(k_ids, j, 0, keepdims=False)
        s = jnp.einsum("bKgBd,bKcd->bKgBc", q_i,
                       k_j.astype(jnp.float32))
        s = jnp.where((kid[None, :] <= qid[:, None])[None, None, None],
                      s, _NEG)
        m_i = jax.lax.dynamic_index_in_dim(m, i, 3, keepdims=False)
        l_i = jax.lax.dynamic_index_in_dim(l, i, 3, keepdims=False)
        a_i = jax.lax.dynamic_index_in_dim(acc, i, 3, keepdims=False)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_i * alpha + jnp.sum(p, axis=-1)
        a_new = a_i * alpha[..., None] + jnp.einsum(
            "bKgBc,bKce->bKgBe", p, v_j.astype(jnp.float32))
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 3)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 3)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 3)
        return (acc, m, l), None

    acc0 = jnp.zeros((b, n_kv, g, nq, bq, dv), jnp.float32)
    m0 = jnp.full((b, n_kv, g, nq, bq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, nq, bq), jnp.float32)
    if unroll:
        carry = (acc0, m0, l0)
        for i, j in pairs:  # static python ints (cost-analysis mode)
            carry, _ = step(carry, (i, j))
        acc, m, l = carry
    else:
        pairs_i = jnp.asarray([p[0] for p in pairs], jnp.int32)
        pairs_j = jnp.asarray([p[1] for p in pairs], jnp.int32)
        (acc, m, l), _ = jax.lax.scan(
            step, (acc0, m0, l0), (pairs_i, pairs_j))
    return acc / jnp.maximum(l[..., None], 1e-20)


def decode_attention(
    q: jax.Array,        # [b, n_heads, 1, d]
    k_cache: jax.Array,  # [b, n_kv, S, d]
    v_cache: jax.Array,  # [b, n_kv, S, d]
    length: jax.Array,   # scalar or [b] — number of valid cache slots
) -> jax.Array:
    """Single-token decode against a (possibly sequence-sharded) cache."""
    b, h, _, d = q.shape
    n_kv, S = k_cache.shape[1], k_cache.shape[2]
    dv = v_cache.shape[-1]
    g = h // n_kv
    scale = d ** -0.5
    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bKgd,bKsd->bKgs", qg, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < jnp.reshape(length, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKgs,bKse->bKge", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, 1, dv).astype(q.dtype)
