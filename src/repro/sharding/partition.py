"""Logical-axis → mesh-axis resolution (GSPMD partitioning rules).

Models annotate every parameter dimension with a logical axis name
(models/model.py docstring); here those names meet a concrete mesh:

    vocab / heads / kv / mlp / expert  -> "model"   (TP / EP)
    embed                              -> "data"    (FSDP / ZeRO-3)
    layers / None                      -> replicated

A dimension that does not divide its mesh axis falls back to replication
(e.g. gemma's single KV head on a 16-way model axis).  Activation
shardings are provided per shape kind (train / prefill / decode / long).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES",
    "resolve_spec",
    "param_shardings",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
]

LOGICAL_RULES: Dict[str, str] = {
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "expert": "model",
    "embed": "data",
    "layers": None,  # scanned — never sharded
}


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes carrying the batch: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name]


def resolve_spec(
    axes: Tuple[Optional[str], ...],
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Optional[Dict[str, str]] = None,
) -> P:
    """PartitionSpec for one parameter, with divisibility fallback."""
    rules = rules or LOGICAL_RULES
    out = []
    used = set()
    for dim, ax in zip(shape, axes):
        mesh_ax = rules.get(ax) if ax else None
        if (
            mesh_ax
            and mesh_ax in mesh.axis_names
            and mesh_ax not in used
            and dim % _axis_size(mesh, mesh_ax) == 0
        ):
            out.append(mesh_ax)
            used.add(mesh_ax)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(axes_tree, shapes_tree, mesh: Mesh,
                    rules: Optional[Dict[str, str]] = None):
    """NamedSharding tree for a parameter pytree."""
    def one(axes, shp):
        return NamedSharding(
            mesh, resolve_spec(tuple(axes), tuple(shp.shape), mesh, rules)
        )
    return jax.tree.map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def batch_shardings(batch_tree, mesh: Mesh):
    """Shard batch dims over ('pod','data'); sequence stays unsharded for
    training (activations shard over model inside the computation)."""
    dp = data_axes(mesh)

    def one(x):
        nd = len(x.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec = [dp if x.shape[0] % int(np.prod([mesh.shape[a] for a in dp])) == 0
                else None] + [None] * (nd - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_tree)


def cache_shardings(cache_tree, mesh: Mesh, cfg, seq_axis_shard: bool = True):
    """Decode caches: batch over ('pod','data'), cache sequence dim over
    'model' (SP).  Batch-1 long-context: state heads over 'model',
    replicate elsewhere.  Layout conventions per models.cache_specs."""
    dp = data_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    mdl = "model" if "model" in mesh.axis_names else None

    def one_named(path, x):
        name = path[-1] if path else ""
        shp = x.shape
        spec = [None] * len(shp)
        # leading dim is the stacked-layer axis for most entries
        if name in ("k", "v", "attn_k", "attn_v"):
            # [L, B, KV, S, hd]
            if shp[1] % max(dp_size, 1) == 0 and dp:
                spec[1] = dp
            if mdl and seq_axis_shard and shp[3] % mesh.shape[mdl] == 0:
                spec[3] = mdl
        elif name == "ckv":
            # [L, B, S, lora]
            if shp[1] % max(dp_size, 1) == 0 and dp:
                spec[1] = dp
            if mdl and seq_axis_shard and shp[2] % mesh.shape[mdl] == 0:
                spec[2] = mdl
        elif name == "enc_out":
            if shp[0] % max(dp_size, 1) == 0 and dp:
                spec[0] = dp
        elif name in ("mlstm_S", "mlstm_n"):
            # [G, M, B, nh, ...] — batch over data; heads over model, OR
            # (shard_state_dim) the last feature dim: nh is usually tiny
            # (xlstm: 4) and falls back to full replication + per-step
            # all-reduces of the matrix memory
            if shp[2] % max(dp_size, 1) == 0 and dp:
                spec[2] = dp
            if getattr(cfg, "shard_state_dim", False):
                if mdl and shp[-1] % mesh.shape[mdl] == 0:
                    spec[-1] = mdl
            elif mdl and shp[3] % mesh.shape[mdl] == 0:
                spec[3] = mdl
        elif name in ("slstm_h", "slstm_c", "slstm_n"):
            if shp[1] % max(dp_size, 1) == 0 and dp:
                spec[1] = dp
            if getattr(cfg, "shard_state_dim", False):
                if mdl and shp[-1] % mesh.shape[mdl] == 0:
                    spec[-1] = mdl
            elif mdl and shp[2] % mesh.shape[mdl] == 0:
                spec[2] = mdl
        elif name in ("conv", "S"):
            # [L, B, ...] mamba states: batch over data, channel/head dim
            # over model
            if shp[1] % max(dp_size, 1) == 0 and dp:
                spec[1] = dp
            if mdl and shp[2] % mesh.shape[mdl] == 0:
                spec[2] = mdl
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(
        lambda kp, x: one_named([getattr(k, "key", str(k)) for k in kp], x),
        cache_tree,
    )
