from .compat import shard_map
from .partition import (
    LOGICAL_RULES,
    batch_shardings,
    cache_shardings,
    data_axes,
    param_shardings,
    resolve_spec,
)

__all__ = [
    "shard_map",
    "LOGICAL_RULES",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "param_shardings",
    "resolve_spec",
]
