from .partition import (
    LOGICAL_RULES,
    batch_shardings,
    cache_shardings,
    data_axes,
    param_shardings,
    resolve_spec,
)

__all__ = [
    "LOGICAL_RULES",
    "batch_shardings",
    "cache_shardings",
    "data_axes",
    "param_shardings",
    "resolve_spec",
]
