"""jax version compatibility for SPMD primitives.

The repo pins a jax whose ``shard_map`` still lives under
``jax.experimental.shard_map``; newer releases promote it to
``jax.shard_map``.  Every SPMD call site imports :func:`shard_map` from
here so the peeling engines run on both.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6
    from functools import wraps

    from jax.experimental.shard_map import shard_map as _shard_map

    @wraps(_shard_map)
    def shard_map(f, **kw):
        # the old replication checker has no rule for while_loop (our FD
        # bodies are one big while_loop), so it must be off here; newer
        # jax dropped the argument entirely
        kw.setdefault("check_rep", False)
        return _shard_map(f, **kw)
