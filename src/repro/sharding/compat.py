"""jax version compatibility for SPMD primitives.

The repo pins a jax whose ``shard_map`` still lives under
``jax.experimental.shard_map``; newer releases promote it to
``jax.shard_map``.  Every SPMD call site imports :func:`shard_map` from
here so the peeling engines run on both.

Same story for two mesh-context APIs the launch drivers use:

* :func:`set_mesh` — ``jax.set_mesh`` is jax ≥ 0.6; on the pinned
  toolchain entering the ``Mesh`` context manager is the equivalent
  (named axes become visible to ``with_sharding_constraint`` and
  friends), and ``Mesh`` has been a context manager since long before
  the pin.
* :data:`AxisType` — ``jax.sharding.AxisType`` is jax ≥ 0.5.  Older
  jax only has GSPMD auto-propagation semantics, so the shim is a
  sentinel enum whose ``Auto`` member callers may pass around; mesh
  constructors must simply omit ``axis_types`` when
  ``HAS_AXIS_TYPE`` is False (there is nothing to configure).
"""
from __future__ import annotations

import enum

import jax

__all__ = ["shard_map", "set_mesh", "AxisType", "HAS_AXIS_TYPE"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax < 0.6
    from functools import wraps

    from jax.experimental.shard_map import shard_map as _shard_map

    @wraps(_shard_map)
    def shard_map(f, **kw):
        # the old replication checker has no rule for while_loop (our FD
        # bodies are one big while_loop), so it must be off here; newer
        # jax dropped the argument entirely
        kw.setdefault("check_rep", False)
        return _shard_map(f, **kw)


if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:  # jax < 0.6: the Mesh object itself is the context manager

    def set_mesh(mesh):
        """Enter ``mesh`` as the ambient mesh; returns a context
        manager exactly like ``jax.set_mesh`` (use as
        ``ctx = set_mesh(m); ctx.__enter__()`` or ``with set_mesh(m)``).
        """
        return mesh


try:  # jax >= 0.5
    from jax.sharding import AxisType

    HAS_AXIS_TYPE = True
except ImportError:  # older jax: GSPMD auto semantics are the only mode

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    HAS_AXIS_TYPE = False
