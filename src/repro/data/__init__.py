from .pipeline import DataConfig, memmap_batches, synthetic_batches
from .graph_data import curriculum_sequences, sequence_batches
from .ingest import IngestedGraph, ingest_edges, load_ingested

__all__ = [
    "DataConfig",
    "IngestedGraph",
    "ingest_edges",
    "load_ingested",
    "memmap_batches",
    "synthetic_batches",
    "curriculum_sequences",
    "sequence_batches",
]
