from .pipeline import DataConfig, memmap_batches, synthetic_batches
from .graph_data import curriculum_sequences, sequence_batches

__all__ = [
    "DataConfig",
    "memmap_batches",
    "synthetic_batches",
    "curriculum_sequences",
    "sequence_batches",
]
