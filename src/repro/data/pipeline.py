"""Token data pipeline.

Deterministic, restart-safe synthetic stream (seeded per step — resuming
at step k reproduces the exact batch k would have seen, which makes
checkpoint/restart bit-reproducible), plus a memmap-backed file source
for real corpora.  Each host materializes only its data shard.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "synthetic_batches", "memmap_batches"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq: int
    vocab: int
    seed: int = 0


def _make_batch(cfg: DataConfig, step: int,
                extra: Optional[Dict] = None) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((cfg.seed, step))
    # zipfian tokens — realistic softmax skew
    z = rng.zipf(1.3, size=(cfg.batch, cfg.seq + 1))
    toks = (z % cfg.vocab).astype(np.int32)
    out = dict(tokens=toks[:, :-1], labels=toks[:, 1:])
    if extra:
        out.update({k: f(rng) for k, f in extra.items()})
    return out


def synthetic_batches(cfg: DataConfig, start_step: int = 0,
                      extra: Optional[Dict] = None
                      ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield _make_batch(cfg, step, extra)
        step += 1


def memmap_batches(path: str, cfg: DataConfig, start_step: int = 0
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Flat int32 token file; sequential non-overlapping windows."""
    data = np.memmap(path, dtype=np.int32, mode="r")
    per_batch = cfg.batch * (cfg.seq + 1)
    n_batches = data.size // per_batch
    step = start_step
    while True:
        i = step % n_batches
        window = np.asarray(
            data[i * per_batch:(i + 1) * per_batch]
        ).reshape(cfg.batch, cfg.seq + 1) % cfg.vocab
        yield dict(tokens=window[:, :-1], labels=window[:, 1:])
        step += 1
