"""Out-of-core bipartite edge-list ingestion (the real-dataset front door).

The paper's headline graphs (trackers, bi-twitter) do not fit the
"parse the whole file into RAM" loader (`core.graph.from_tsv`): the raw
text alone is tens of GB and the edge array follows it.  This module
builds a **degree-ordered, memory-mapped host CSR** from a KONECT/SNAP
style edge list while holding only O(chunk + vertices) in RAM:

1. **vocab pass** — stream the file in bounded chunks, collecting the
   sorted raw-id vocabulary per side (vertices ≪ edges, so the id maps
   stay resident) and the source sha256 (the ingest-cache key).
2. **dedup pass** — re-stream, compact raw ids via ``searchsorted``,
   encode each edge as one int64 key, and spill *sorted runs* of
   ``(key, net)`` pairs to the workdir.  ``net`` is the signed line
   count: a KONECT weight < 0 is a deletion event, so duplicates
   accumulate and self-cancelling lines erase each other.  The merge is
   a k-way streamed reduce — an edge survives iff its net insert count
   is positive — so the result is **invariant to chunk size and input
   order** (property-tested in ``tests/test_ingest.py``).
3. **degree relabel** — vertices are renumbered by decreasing surviving
   degree (ties broken by compact raw-id order, keeping the relabel
   deterministic and order-invariant); vertices whose edges all
   cancelled vanish from the id space.  Degree order is what keeps the
   downstream wedge **tiles** balanced (`core.csr.iter_wedge_tiles`):
   hub vertices land in the low ranks where the adaptive tile
   boundaries isolate them — ParButterfly / RECEIPT's degree-ordering
   trick applied at ingest time.
4. **CSR passes** — two more external sorts write the U-side edge list
   (lex (u, v) — edge id = row, matching ``BipartiteGraph`` exactly)
   and the V-side CSR (neighbors + edge ids per center) as raw memmaps,
   so the graph never needs to exist in RAM at once.

Everything lands in an ingest directory (``<edges>.ingest`` by
default): ``edges.bin`` / ``off_u.bin`` / ``off_v.bin`` / ``nbr_v.bin``
/ ``eid_v.bin`` + ``meta.json``.  Re-ingesting the same file is a
cache hit keyed on the source sha256.

Run merging streams ``heapq.merge`` over block-buffered readers —
I/O-shaped by construction; the point is the *memory* bound, and the
bench tier (`benchmarks/real_graphs.py`) records the wall cost next to
the counting rows it unlocks.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import itertools
import json
import os
from typing import Iterator, Optional, Sequence, Tuple

import numpy as np

__all__ = ["IngestedGraph", "ingest_edges", "load_ingested"]

_VERSION = 1
_RUN_BLOCK = 1 << 16      # elements per buffered read while merging runs
_ID_LIMIT = 2 ** 31 - 1   # compact ids / edge ids are int32 downstream


# =====================================================================
# Streaming parse
# =====================================================================
def _parse_chunks(
    path: str, chunk_edges: int, comment: Sequence[str]
) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Yield (u_raw, v_raw, sign) int64 chunks from an edge-list file.

    Lines are ``u v [w [t]]``; a weight < 0 is a deletion event (the
    KONECT temporal convention), anything else an insertion.  Blank
    lines and comment-prefixed lines are skipped.
    """
    us, vs, sg = [], [], []
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s[0] in comment:
                continue
            parts = s.split()
            us.append(int(parts[0]))
            vs.append(int(parts[1]))
            sg.append(-1 if len(parts) > 2 and float(parts[2]) < 0 else 1)
            if len(us) >= chunk_edges:
                yield (np.asarray(us, np.int64), np.asarray(vs, np.int64),
                       np.asarray(sg, np.int64))
                us, vs, sg = [], [], []
    if us:
        yield (np.asarray(us, np.int64), np.asarray(vs, np.int64),
               np.asarray(sg, np.int64))


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for blk in iter(lambda: f.read(1 << 20), b""):
            h.update(blk)
    return h.hexdigest()


# =====================================================================
# External sorted runs (key int64 [+ payload int64]) + k-way merge
# =====================================================================
class _RunWriter:
    """Spill sorted (key[, payload]) chunks as numbered .npy run files."""

    def __init__(self, workdir: str, tag: str):
        self.workdir = workdir
        self.tag = tag
        self.paths: list = []

    def write(self, keys: np.ndarray, payload: Optional[np.ndarray] = None):
        if keys.size == 0:
            return
        base = os.path.join(self.workdir, f"{self.tag}.{len(self.paths)}")
        np.save(base + ".k.npy", keys)
        if payload is not None:
            np.save(base + ".p.npy", payload)
        self.paths.append(base)

    def cleanup(self):
        for base in self.paths:
            for suf in (".k.npy", ".p.npy"):
                if os.path.exists(base + suf):
                    os.remove(base + suf)
        self.paths = []


def _run_stream(base: str, with_payload: bool):
    """Yield (key, payload) tuples from one run, reading bounded blocks."""
    keys = np.load(base + ".k.npy", mmap_mode="r")
    pay = np.load(base + ".p.npy", mmap_mode="r") if with_payload else None
    n = keys.shape[0]
    for lo in range(0, n, _RUN_BLOCK):
        kb = np.asarray(keys[lo:lo + _RUN_BLOCK])
        pb = np.asarray(pay[lo:lo + _RUN_BLOCK]) if with_payload else kb
        for i in range(kb.shape[0]):
            yield int(kb[i]), int(pb[i])


def _merge_runs(writer: _RunWriter, with_payload: bool):
    """K-way merge of a writer's runs into a sorted (key, payload) stream."""
    streams = [_run_stream(b, with_payload) for b in writer.paths]
    return heapq.merge(*streams, key=lambda kv: kv[0])


def _batched(stream, size: int):
    """Chunk a (key, payload) stream into int64 array pairs."""
    while True:
        block = list(itertools.islice(stream, size))
        if not block:
            return
        yield (np.asarray([k for k, _ in block], np.int64),
               np.asarray([p for _, p in block], np.int64))


# =====================================================================
# Result container
# =====================================================================
@dataclasses.dataclass(frozen=True)
class IngestedGraph:
    """Memory-mapped degree-ordered CSR of an ingested edge list.

    Quacks like :class:`repro.core.graph.BipartiteGraph` where the
    counting layer needs it (``n_u``/``n_v``/``m``/``csr_u``/``csr_v``/
    ``degrees``) but every O(m) array is a read-only memmap.  The edge
    list is lex-sorted (u, v) with edge id = row — the exact
    ``BipartiteGraph`` contract, so ⋈init vectors computed here index
    straight into the peeling engines.
    """

    n_u: int
    n_v: int
    m: int
    edges: np.ndarray      # (m, 2) int32 memmap, lex (u, v)
    off_u: np.ndarray      # (n_u+1,) int64
    off_v: np.ndarray      # (n_v+1,) int64
    nbr_v: np.ndarray      # (m,) int32 memmap — u ids per center, ascending
    eid_v: np.ndarray      # (m,) int32 memmap — edge ids per center
    meta: dict

    def degrees(self) -> Tuple[np.ndarray, np.ndarray]:
        return np.diff(self.off_u), np.diff(self.off_v)

    def csr_u(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(offsets, neighbor v ids, edge ids) — edges are u-major, so
        edge ids are just the row range."""
        return (self.off_u, self.edges[:, 1],
                np.arange(self.m, dtype=np.int32))

    def csr_v(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self.off_v, self.nbr_v, self.eid_v

    def as_graph(self):
        """A :class:`BipartiteGraph` view over the edge memmap (no copy;
        engines that need host scratch will allocate their own)."""
        from repro.core.graph import BipartiteGraph

        return BipartiteGraph(self.n_u, self.n_v, self.edges)


# =====================================================================
# The pipeline
# =====================================================================
def _vocab_pass(path, chunk_edges, comment):
    vu = np.zeros(0, np.int64)
    vv = np.zeros(0, np.int64)
    n_lines = 0
    for u, v, _ in _parse_chunks(path, chunk_edges, comment):
        n_lines += u.size
        if u.size and (u.min() < 0 or v.min() < 0):
            raise ValueError("negative vertex ids in edge list")
        vu = np.union1d(vu, u)
        vv = np.union1d(vv, v)
    return vu, vv, n_lines


def _dedup_pass(path, chunk_edges, comment, vu, vv, workdir):
    """Spill sorted (key, net) runs; key = compact_u * n_v0 + compact_v."""
    n_v0 = max(vv.size, 1)
    if vu.size * n_v0 > 2 ** 62:
        raise OverflowError("vertex-id product exceeds int64 edge keys")
    w = _RunWriter(workdir, "dedup")
    for u_raw, v_raw, sg in _parse_chunks(path, chunk_edges, comment):
        key = np.searchsorted(vu, u_raw) * n_v0 + np.searchsorted(vv, v_raw)
        order = np.argsort(key, kind="stable")
        ks = key[order]
        uniq, starts = np.unique(ks, return_index=True)
        net = np.add.reduceat(sg[order], starts) if ks.size else sg
        keep = net != 0
        w.write(uniq[keep], net[keep])
    return w


def _reduce_dedup(writer, n_u0, n_v0, workdir):
    """Merge dedup runs, keep keys with positive net; return the
    surviving key memmap + per-side degree counts (compact-raw space)."""
    bound = sum(np.load(b + ".k.npy", mmap_mode="r").shape[0]
                for b in writer.paths)
    path0 = os.path.join(workdir, "keys0.bin")
    keys0 = np.memmap(path0, dtype=np.int64, mode="w+",
                      shape=(max(bound, 1),))
    deg_u = np.zeros(max(n_u0, 1), np.int64)
    deg_v = np.zeros(max(n_v0, 1), np.int64)
    m = 0
    stream = _merge_runs(writer, with_payload=True)
    grouped = itertools.groupby(stream, key=lambda kv: kv[0])
    surviving = (k for k, grp in grouped if sum(p for _, p in grp) > 0)
    for block in _batched(((k, 0) for k in surviving), _RUN_BLOCK):
        kb = block[0]
        keys0[m:m + kb.size] = kb
        deg_u += np.bincount(kb // max(n_v0, 1), minlength=deg_u.size)
        deg_v += np.bincount(kb % max(n_v0, 1), minlength=deg_v.size)
        m += kb.size
    keys0.flush()
    writer.cleanup()
    if m > _ID_LIMIT:
        raise OverflowError("edge count exceeds int32 edge ids")
    return path0, m, deg_u, deg_v


def _degree_rank(deg: np.ndarray) -> Tuple[np.ndarray, int]:
    """rank[i] = decreasing-degree rank of compact-raw id i; isolated
    (degree-0) ids get -1 and vanish.  Stable on compact-raw order, so
    the relabel is deterministic and input-order invariant."""
    order = np.lexsort((np.arange(deg.size), -deg))
    n_kept = int((deg > 0).sum())
    rank = np.full(deg.size, -1, np.int64)
    rank[order[:n_kept]] = np.arange(n_kept)
    return rank, n_kept


def _relabel_sort(path0, m, n_v0, rank_u, rank_v, n_v, workdir, chunk):
    """Rewrite surviving keys into degree-rank space and re-sort."""
    keys0 = np.memmap(path0, dtype=np.int64, mode="r")[:max(m, 1)]
    w = _RunWriter(workdir, "relabel")
    for lo in range(0, m, chunk):
        kb = np.asarray(keys0[lo:lo + chunk])
        nk = rank_u[kb // max(n_v0, 1)] * max(n_v, 1) + rank_v[kb % max(n_v0, 1)]
        w.write(np.sort(nk))
    return w


def _emit_u_side(writer, m, n_u, n_v, workdir):
    edges = np.memmap(os.path.join(workdir, "edges.bin"), dtype=np.int32,
                      mode="w+", shape=(max(m, 1), 2))
    deg_u = np.zeros(max(n_u, 1), np.int64)
    pos = 0
    stream = _merge_runs(writer, with_payload=False)
    for kb, _ in _batched(stream, _RUN_BLOCK):
        u = kb // max(n_v, 1)
        edges[pos:pos + kb.size, 0] = u
        edges[pos:pos + kb.size, 1] = kb % max(n_v, 1)
        deg_u += np.bincount(u, minlength=deg_u.size)
        pos += kb.size
    edges.flush()
    writer.cleanup()
    off_u = np.zeros(n_u + 1, np.int64)
    np.cumsum(deg_u[:n_u], out=off_u[1:])
    off_u.tofile(os.path.join(workdir, "off_u.bin"))
    return edges


def _emit_v_side(edges, m, n_u, n_v, workdir, chunk):
    """External sort by (v, u) carrying edge ids → V-side CSR memmaps."""
    w = _RunWriter(workdir, "vside")
    for lo in range(0, m, chunk):
        eb = np.asarray(edges[lo:lo + chunk])
        key = eb[:, 1].astype(np.int64) * max(n_u, 1) + eb[:, 0]
        order = np.argsort(key, kind="stable")
        w.write(key[order], (lo + order).astype(np.int64))
    nbr = np.memmap(os.path.join(workdir, "nbr_v.bin"), dtype=np.int32,
                    mode="w+", shape=(max(m, 1),))
    eid = np.memmap(os.path.join(workdir, "eid_v.bin"), dtype=np.int32,
                    mode="w+", shape=(max(m, 1),))
    deg_v = np.zeros(max(n_v, 1), np.int64)
    pos = 0
    for kb, pb in _batched(_merge_runs(w, with_payload=True), _RUN_BLOCK):
        nbr[pos:pos + kb.size] = kb % max(n_u, 1)
        eid[pos:pos + kb.size] = pb
        deg_v += np.bincount(kb // max(n_u, 1), minlength=deg_v.size)
        pos += kb.size
    nbr.flush()
    eid.flush()
    w.cleanup()
    off_v = np.zeros(n_v + 1, np.int64)
    np.cumsum(deg_v[:n_v], out=off_v[1:])
    off_v.tofile(os.path.join(workdir, "off_v.bin"))


def load_ingested(out_dir: str) -> IngestedGraph:
    """Reopen an ingest directory written by :func:`ingest_edges`."""
    with open(os.path.join(out_dir, "meta.json")) as f:
        meta = json.load(f)
    n_u, n_v, m = meta["n_u"], meta["n_v"], meta["m"]

    def mm(name, dtype, shape):
        return np.memmap(os.path.join(out_dir, name), dtype=dtype,
                         mode="r", shape=shape)

    return IngestedGraph(
        n_u=n_u, n_v=n_v, m=m,
        edges=mm("edges.bin", np.int32, (max(m, 1), 2))[:m],
        off_u=np.fromfile(os.path.join(out_dir, "off_u.bin"), np.int64),
        off_v=np.fromfile(os.path.join(out_dir, "off_v.bin"), np.int64),
        nbr_v=mm("nbr_v.bin", np.int32, (max(m, 1),))[:m],
        eid_v=mm("eid_v.bin", np.int32, (max(m, 1),))[:m],
        meta=meta,
    )


def ingest_edges(
    path: str,
    out_dir: Optional[str] = None,
    chunk_edges: int = 1 << 20,
    comment: Sequence[str] = ("%", "#"),
    refresh: bool = False,
) -> IngestedGraph:
    """Ingest a KONECT/SNAP edge list out of core (see module docstring).

    ``out_dir`` defaults to ``<path>.ingest``; an existing directory
    whose recorded source sha256 matches is reused (``refresh=True``
    forces a rebuild).  ``chunk_edges`` bounds resident edge memory —
    results are bit-identical for ANY chunk size (property-tested).
    """
    if out_dir is None:
        out_dir = path + ".ingest"
    os.makedirs(out_dir, exist_ok=True)
    sha = _sha256(path)
    meta_path = os.path.join(out_dir, "meta.json")
    if not refresh and os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("source_sha256") == sha \
                and meta.get("version") == _VERSION \
                and meta.get("chunk_edges") == chunk_edges:
            return load_ingested(out_dir)

    chunk_edges = max(int(chunk_edges), 1)
    vu, vv, n_lines = _vocab_pass(path, chunk_edges, comment)
    n_u0, n_v0 = vu.size, vv.size
    dedup = _dedup_pass(path, chunk_edges, comment, vu, vv, out_dir)
    keys0_path, m, deg_u0, deg_v0 = _reduce_dedup(dedup, n_u0, n_v0, out_dir)
    rank_u, n_u = _degree_rank(deg_u0[:max(n_u0, 1)])
    rank_v, n_v = _degree_rank(deg_v0[:max(n_v0, 1)])
    relab = _relabel_sort(keys0_path, m, n_v0, rank_u, rank_v, n_v,
                          out_dir, chunk_edges)
    edges = _emit_u_side(relab, m, n_u, n_v, out_dir)
    _emit_v_side(edges, m, n_u, n_v, out_dir, chunk_edges)
    os.remove(keys0_path)

    meta = dict(
        version=_VERSION, source=os.path.abspath(path), source_sha256=sha,
        chunk_edges=chunk_edges, n_lines=n_lines,
        n_u=n_u, n_v=n_v, m=m,
        n_u_raw=int(n_u0), n_v_raw=int(n_v0),
        n_dropped_u=int(n_u0 - n_u), n_dropped_v=int(n_v0 - n_v),
    )
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    return load_ingested(out_dir)
