"""PBNG → LM data bridge: dense-subgraph curriculum for link prediction.

The paper's applications (recommendation, spam detection, co-clustering)
consume the decomposition hierarchy.  Here we turn a user×item bipartite
graph into token sequences for the training examples:

    [USER u] [ITEM v1] [ITEM v2] ... per wing-number level,

feeding densest levels first (curriculum).  Used by
examples/graph_curriculum.py.
"""
from __future__ import annotations

from typing import Dict, Iterator, List

import numpy as np

from repro.core.analysis import interaction_curriculum
from repro.core.graph import BipartiteGraph

__all__ = ["curriculum_sequences", "sequence_batches"]


def curriculum_sequences(
    g: BipartiteGraph, n_levels: int = 4, P: int = 8, max_len: int = 64
) -> List[np.ndarray]:
    """Token sequences grouped by descending density level.

    Vocabulary: [0, n_u) users, [n_u, n_u+n_v) items.
    """
    level, _ = interaction_curriculum(g, n_levels=n_levels, P=P)
    out = []
    for lv in range(n_levels - 1, -1, -1):
        edges = g.edges[level == lv]
        by_user: Dict[int, List[int]] = {}
        for u, v in edges:
            by_user.setdefault(int(u), []).append(g.n_u + int(v))
        seqs = []
        for u, items in sorted(by_user.items()):
            # chunk long histories — every interaction lands in a sequence
            for i in range(0, len(items), max_len - 1):
                seq = [u] + items[i: i + max_len - 1]
                seqs.append(np.asarray(seq, dtype=np.int32))
        out.append(seqs)
    return [s for lvl in out for s in lvl]


def sequence_batches(
    seqs: List[np.ndarray], batch: int, seq_len: int, pad: int = 0
) -> Iterator[Dict[str, np.ndarray]]:
    """Pack curriculum sequences into fixed (batch, seq_len) batches."""
    buf = []
    for s in seqs:
        s = s[: seq_len + 1]
        if s.size < seq_len + 1:
            s = np.concatenate(
                [s, np.full(seq_len + 1 - s.size, pad, np.int32)])
        buf.append(s)
        if len(buf) == batch:
            arr = np.stack(buf)
            yield dict(tokens=arr[:, :-1], labels=arr[:, 1:])
            buf = []
    if buf:
        while len(buf) < batch:
            buf.append(np.full(seq_len + 1, pad, np.int32))
        arr = np.stack(buf)
        yield dict(tokens=arr[:, :-1], labels=arr[:, 1:])
