"""Roofline analysis (EXPERIMENTS.md §Roofline).

XLA's HloCostAnalysis counts while/scan bodies ONCE, so the scanned
production programs under-report FLOPs/bytes by the trip counts.  We
therefore compile two *unrolled* shallow variants (L1 and L2 layers,
inner loops unrolled too) on the SAME mesh and extrapolate linearly:

    metric(L) = a + b·L  ->  total = m(L1) + b · (L_full − L1)

This keeps every number HLO-derived (no hand FLOP formulas) while being
exact in the layer count.  Two documented approximations:
  * unrolled variants use larger attention/ssm blocks (2048 / 512) to
    bound HLO size — block-size changes masking waste only;
  * the sLSTM time-step scan (inherently sequential, 4096 trips) cannot
    be unrolled; its recurrent-matmul FLOPs are added analytically.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link
ICI.  cost_analysis numbers are per-device (SPMD program), so terms are
computed per chip directly.
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # must precede first jax backend init
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
from typing import Dict, Optional

from repro.configs import ARCHS, get_config
import repro.models as M
from repro.models.model import SHAPE_SETS

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "roofline")


def _variant_layers(cfg) -> tuple:
    """(L1, L2, L_full) in the unit the family scans over."""
    if cfg.family == "ssm":
        return cfg.slstm_every, 2 * cfg.slstm_every, cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every, cfg.n_layers
    return 1, 2, cfg.n_layers


def _overrides(cfg, L: int, shape: str) -> Dict:
    ov = dict(n_layers=L, unroll_layers=True,
              attn_block_q=2048, attn_block_k=2048, ssm_chunk=512)
    if cfg.family == "audio":
        ov["encoder_layers"] = L
    info = SHAPE_SETS[shape]
    seq = info["seq"]
    ov["attn_block_q"] = min(2048, seq)
    ov["attn_block_k"] = min(2048, seq)
    if cfg.family in ("ssm", "hybrid"):
        ov["ssm_chunk"] = min(512, seq)
    return ov


def _slstm_correction_flops(cfg, shape: str) -> float:
    """Analytic FLOPs of the sLSTM recurrent matmul (per device), which
    hides inside an un-unrollable time scan.  fwd 2·b·s·nh·dh·4dh,
    train ≈ 3× fwd (bwd ≈ 2×); divided across data-parallel shards."""
    if cfg.family != "ssm":
        return 0.0
    info = SHAPE_SETS[shape]
    if info["kind"] != "train":
        return 0.0
    b, s = info["batch"], info["seq"]
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    G = cfg.n_layers // cfg.slstm_every
    total = 3 * 2 * b * s * nh * dh * (4 * dh) * G
    return total / 256.0  # per chip on the 16x16 mesh (data shards)


def roofline_cell(arch: str, shape: str, multi_pod: bool = False,
                  use_cache: Optional[dict] = None,
                  mb: int = 1,
                  extra_overrides: Optional[Dict] = None,
                  tag: str = "") -> Dict:
    from repro.launch.dryrun import dryrun_cell
    cfg = get_config(arch)
    ok, why = M.shape_applicable(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape, status="skipped", reason=why)

    L1, L2, Lf = _variant_layers(cfg)
    recs = {}
    for L in (L1, L2):
        key = f"{arch}/{shape}/{multi_pod}/L{L}/mb{mb}/{tag}"
        if use_cache and key in use_cache:
            recs[L] = use_cache[key]
            continue
        ov = dict(_overrides(cfg, L, shape))
        if extra_overrides:
            ov.update(extra_overrides)
        r = dryrun_cell(arch, shape, multi_pod=multi_pod,
                        microbatches=mb,
                        cfg_overrides=ov,
                        verbose=False)
        if r["status"] != "ok":
            return dict(arch=arch, shape=shape, status="error",
                        at=f"L{L}", detail=r)
        recs[L] = r
        if use_cache is not None:
            use_cache[key] = r

    def total(field, sub=None):
        def g(r):
            v = r[field]
            if sub is not None:
                v = v.get(sub, 0)
            return float(v)
        m1, m2 = g(recs[L1]), g(recs[L2])
        b = (m2 - m1) / (L2 - L1)
        return max(m1 + b * (Lf - L1), 0.0)

    flops = total("flops") + _slstm_correction_flops(cfg, shape)
    bytes_acc = total("bytes_accessed")
    coll = {}
    for kind in set(
        list(recs[L1]["collective_bytes"]) + list(recs[L2]["collective_bytes"])
    ):
        m1 = recs[L1]["collective_bytes"].get(kind, 0)
        m2 = recs[L2]["collective_bytes"].get(kind, 0)
        coll[kind] = max(
            m1 + (m2 - m1) / (L2 - L1) * (Lf - L1), 0.0)
    coll_total = sum(coll.values())

    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_total / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    bottleneck = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N·D train / 2·N·D inference, N = active non-embedding
    info = SHAPE_SETS[shape]
    n_active = cfg.active_param_count()
    embed = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n_eff = max(n_active - embed, 1)
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    mult = 6 if info["kind"] == "train" else 2
    n_dev = recs[L1]["n_devices"]
    model_flops = mult * n_eff * tokens / n_dev  # per chip
    useful = model_flops / max(flops, 1.0)

    return dict(
        arch=arch, shape=shape, multi_pod=multi_pod, status="ok",
        tag=tag,
        kind=info["kind"], n_devices=n_dev, mb=mb,
        flops_per_chip=flops, bytes_per_chip=bytes_acc,
        collective_bytes_per_chip=coll, collective_total=coll_total,
        t_compute_s=t_compute, t_memory_s=t_memory, t_collective_s=t_coll,
        bottleneck=bottleneck,
        model_flops_per_chip=model_flops,
        useful_flop_ratio=useful,
        roofline_fraction=t_compute / max(
            t_compute, t_memory, t_coll),
        mem=recs[L2].get("mem"),
        compile_s=(recs[L1]["time_compile_s"], recs[L2]["time_compile_s"]),
    )


def run_all(out_path: str, archs=None, shapes=None, multi_pod=False,
            resume=True):
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = []
    done = set()
    if resume and os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r.get("multi_pod", False))
                for r in results}
    cache_path = out_path + ".cache.json"
    cache = {}
    if os.path.exists(cache_path):
        with open(cache_path) as f:
            cache = json.load(f)
    for arch in (archs or ARCHS):
        for shape in (shapes or list(SHAPE_SETS)):
            if (arch, shape, multi_pod) in done:
                continue
            try:
                rec = roofline_cell(arch, shape, multi_pod=multi_pod,
                                    use_cache=cache)
            except Exception as e:  # noqa: BLE001
                import traceback
                traceback.print_exc()
                rec = dict(arch=arch, shape=shape, multi_pod=multi_pod,
                           status="error", error=str(e)[-2000:])
            results.append(rec)
            with open(out_path, "w") as f:
                json.dump(results, f, indent=1)
            with open(cache_path, "w") as f:
                json.dump(cache, f)
            if rec["status"] == "ok":
                print(f"[roofline] {arch:18s} {shape:12s} "
                      f"bottleneck={rec['bottleneck']:10s} "
                      f"comp={rec['t_compute_s']:.2e}s "
                      f"mem={rec['t_memory_s']:.2e}s "
                      f"coll={rec['t_collective_s']:.2e}s "
                      f"useful={rec['useful_flop_ratio']:.2f}", flush=True)
            else:
                print(f"[roofline] {arch} {shape} {rec['status']}",
                      flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out = args.out or os.path.abspath(
        os.path.join(RESULTS_DIR, "results.json"))
    run_all(out,
            archs=[args.arch] if args.arch else None,
            shapes=[args.shape] if args.shape else None,
            multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()
