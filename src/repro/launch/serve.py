"""Batched serving driver: prefill a batch of prompts, then decode with
a KV cache (greedy).  Structural twin of the decode dry-run cells.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.sharding.compat import set_mesh
import repro.models as M
from repro.models.config import reduced


def run(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_local_mesh()
    ctx = set_mesh(mesh)
    ctx.__enter__()

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed),
                           dtype=jnp.float32)
    rng = np.random.default_rng(args.seed)
    b = args.batch
    total = args.prompt_len + args.gen
    prompts = rng.integers(0, cfg.vocab, (b, args.prompt_len)).astype(
        np.int32)

    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, b, total, dtype=jnp.float32))
    if cfg.family == "audio":
        from repro.models.model import _whisper_encode
        frames = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.float32)
        cache["enc_out"] = _whisper_encode(params, frames, cfg)

    step = jax.jit(
        lambda p, c, t, l: M.serve_step(p, c, t, l, cfg))

    # prefill via the decode path (teacher-forced) then greedy generate
    tok = jnp.asarray(prompts[:, 0])
    t0 = time.time()
    out_tokens = [np.asarray(tok)]
    for i in range(total - 1):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        if i + 1 < args.prompt_len:
            tok = jnp.asarray(prompts[:, i + 1])
        else:
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    dt = time.time() - t0
    seqs = np.stack(out_tokens, axis=1)
    print(f"[serve] {b} seqs × {total} steps in {dt:.2f}s "
          f"({b * (total - 1) / dt:.1f} tok/s)")
    print("[serve] sample:", seqs[0, args.prompt_len:][:16].tolist())
    ctx.__exit__(None, None, None)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    sys.exit(run(ap.parse_args()))


if __name__ == "__main__":
    main()
