"""Multi-tenant hierarchy serving driver.

Serves a directory of hierarchy artifacts (``<tenant>.npz``, written by
``launch/peel.py --emit-hierarchy`` / ``repro.hierarchy.save_hierarchy``)
behind one endpoint: tenants load through the pool's LRU artifact cache
into shape-bucketed slots, and mixed-tenant mixed-op query batches are
answered with ONE jitted dispatch per shape bucket
(``repro.hierarchy.multiserve``).

``--dryrun`` needs no artifacts: it synthesizes tenants in two shape
buckets, serves a mixed workload, and asserts the serving-layer
structural claims — exactly one compiled dispatch per bucket, a cold
same-bucket load triggering zero retraces, and a dispatch jaxpr that is
pure gathers/selects (no ``while``, no collectives).

The serve loop shuts down gracefully: SIGINT/SIGTERM stop it between
dispatch chunks, queued slots are drained, the final metrics snapshot
(``--metrics``) and trace (``--trace``) are flushed, and the process
exits 0.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time


class GracefulShutdown:
    """Flip ``stop`` on SIGINT/SIGTERM instead of dying mid-dispatch;
    previous handlers are restored on exit (nested use is safe)."""

    def __init__(self):
        self.stop = False
        self._prev = {}

    def _handler(self, signum, frame):
        self.stop = True

    def __enter__(self):
        for s in (signal.SIGINT, signal.SIGTERM):
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:      # not the main thread
                pass
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False


def _mixed_workload(pool, tenants, n, seed=0):
    """Random mixed-op parallel arrays over ``tenants`` (round-robin),
    each slot's ids drawn inside its tenant's true dims."""
    import numpy as np

    from repro.hierarchy.serve import OPS

    rng = np.random.default_rng(seed)
    t_col = [tenants[i % len(tenants)] for i in range(n)]
    ops = rng.integers(0, 5, n).astype(np.int32)
    a = np.zeros(n, np.int32)
    b = np.zeros(n, np.int32)
    for i, t in enumerate(t_col):
        m = pool.meta[t]
        lim = m.n_nodes if ops[i] == OPS["subtree_size"] else m.n_entities
        a[i] = rng.integers(0, max(lim, 1))
        b[i] = rng.integers(0, max(m.n_entities, 1))
    return t_col, ops, a, b


def _dryrun() -> int:
    import os as _os
    _os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + _os.environ.get("XLA_FLAGS", ""))
    import tempfile

    import jax
    import jax.numpy as jnp

    from repro.core.graph import powerlaw_bipartite
    from repro.core.peel import wing_decomposition
    from repro.hierarchy import (ForestPool, MultiTenantService,
                                 build_hierarchy, multiserve, save_hierarchy)

    d = tempfile.mkdtemp(prefix="hserve_dryrun_")
    shapes = [(120, 80, 420), (120, 80, 420), (120, 80, 420), (24, 16, 64)]
    for i, (nu, nv, m) in enumerate(shapes):
        g = powerlaw_bipartite(nu, nv, m, seed=i)
        h = build_hierarchy(g, wing_decomposition(g, P=4, engine="csr"))
        save_hierarchy(os.path.join(d, f"tenant{i}.npz"), h)

    pool = ForestPool(slots=8, artifact_dir=d)
    svc = MultiTenantService(pool, batch=256)
    warm = ["tenant0", "tenant1", "tenant3"]   # two shape buckets
    for t in warm:
        pool.ensure(t)
    tenants, ops, a, b = _mixed_workload(pool, warm, 1024)
    svc.query_batch(tenants, ops, a, b)
    n_buckets = len(pool.buckets)
    n_compiles = multiserve.compiled_dispatch_count()
    assert n_compiles == n_buckets, (n_compiles, n_buckets)
    print(f"[hserve-dryrun] {len(warm)} tenants over {n_buckets} shape "
          f"buckets: exactly ONE compiled dispatch per bucket ✓")

    # cold load into the big bucket: values change, shapes don't —
    # the dispatch cache must not grow
    pool.ensure("tenant2")
    tenants, ops, a, b = _mixed_workload(pool, warm + ["tenant2"], 1024)
    svc.query_batch(tenants, ops, a, b)
    assert multiserve.compiled_dispatch_count() == n_compiles, \
        "cold same-bucket load must not retrace"
    print("[hserve-dryrun] cold same-bucket tenant load: ZERO retraces ✓")

    # the dispatch program is pure gathers + selects: no while, no
    # collectives (it must stay latency-shaped at any device count —
    # lowered here on the 512-device host platform)
    key = pool.meta["tenant0"].bucket
    arrs = pool.bucket_arrays(key)
    z = jnp.zeros(256, jnp.int32)
    jaxpr = str(jax.make_jaxpr(
        lambda *x: multiserve._answer_batch_multi(
            *x, J=svc.buckets_J(key)))(
        arrs["theta"], arrs["entity_node"], arrs["node_level"],
        arrs["depth"], arrs["node_size"], arrs["up"], z, z, z, z))
    assert "while[" not in jaxpr, "dispatch must be loop-free"
    assert not any(c in jaxpr for c in ("psum", "all_gather", "ppermute")), \
        "dispatch must be collective-free"
    print(f"[hserve-dryrun] dispatch jaxpr is loop- and collective-free "
          f"({len(jax.devices())} host devices) ✓")

    # eviction safety: pin one tenant, flood the pool, assert survival
    pool.pin("tenant3")
    for i in range(4):
        g = powerlaw_bipartite(24, 16, 64, seed=100 + i)
        h = build_hierarchy(g, wing_decomposition(g, P=2, engine="csr"))
        save_hierarchy(os.path.join(d, f"flood{i}.npz"), h)
    small_pool = ForestPool(slots=2, artifact_dir=d)
    small_pool.pin("tenant3")
    for i in range(4):
        small_pool.ensure(f"flood{i}")
    assert small_pool.resident("tenant3"), "pinned tenant must survive"
    print("[hserve-dryrun] pinned tenant survives a pool flood ✓")
    return 0


def _run(args) -> int:
    import numpy as np

    from repro import obs
    from repro.hierarchy import ForestPool, MultiTenantService, multiserve

    tenants = sorted(
        f[:-4] for f in os.listdir(args.artifact_dir) if f.endswith(".npz"))
    if not tenants:
        print(f"[hserve] no *.npz artifacts in {args.artifact_dir}")
        return 1
    pool = ForestPool(slots=args.pool_slots, artifact_dir=args.artifact_dir)
    svc = MultiTenantService(pool, batch=args.batch)
    warm = tenants[:args.pool_slots]
    t0 = time.perf_counter()
    with obs.span("serve.warm", cat="serve", n=len(warm)):
        for t in warm:
            pool.ensure(t)
    t_load = time.perf_counter() - t0
    print(f"[hserve] {len(tenants)} tenants found; warmed {len(warm)} "
          f"into {len(pool.buckets)} shape buckets in {t_load * 1e3:.1f} ms")

    served = 0
    checksum = np.int64(0)
    interrupted = False
    # the shutdown handler covers workload generation too: a SIGINT any
    # time after the warm print takes the graceful path
    with GracefulShutdown() as gs:
        t_col, ops, a, b = _mixed_workload(pool, warm, args.queries,
                                           seed=args.seed)
        t0 = time.perf_counter()
        try:
            # one dispatch-sized chunk per iteration so a shutdown
            # signal is honored between dispatches, never inside one
            for lo in range(0, args.queries, args.batch):
                if gs.stop:
                    interrupted = True
                    break
                hi = min(lo + args.batch, args.queries)
                out = svc.query_batch(
                    t_col[lo:hi], ops[lo:hi], a[lo:hi], b[lo:hi])
                checksum += np.int64(out.sum())
                served += hi - lo
        finally:
            # drain queued slots so no tenant retires with in-flight
            # queries (run() is a no-op on an empty queue)
            svc.run()
        dt = time.perf_counter() - t0
        interrupted = interrupted or gs.stop
    qps = served / max(dt, 1e-9)
    print(f"[hserve] {served} mixed-tenant queries in "
          f"{dt * 1e3:.1f} ms -> {qps:,.0f} q/s "
          f"({svc.dispatches} dispatches, "
          f"{multiserve.compiled_dispatch_count()} compiled programs)")
    print(f"[hserve] cache: {pool.stats()}")
    if interrupted:
        print("[hserve] shutdown signal: queue drained, telemetry "
              "flushed, exiting 0")
    svc.metrics.set_gauge("serve.qps", qps)
    if args.metrics:
        svc.metrics.save(args.metrics)
        print(f"[hserve] metrics snapshot -> {args.metrics}")
    if args.out:
        import json
        with open(args.out, "w") as f:
            json.dump(dict(qps=qps, n_tenants=len(warm),
                           served=served,
                           answers_checksum=int(checksum),
                           **pool.stats()), f)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifact-dir", default=None, metavar="DIR",
                    help="directory of <tenant>.npz hierarchy artifacts "
                         "(write them with launch/peel.py "
                         "--emit-hierarchy)")
    ap.add_argument("--pool-slots", type=int, default=64,
                    help="resident-tenant budget of the forest pool "
                         "(LRU eviction past it)")
    ap.add_argument("--batch", type=int, default=1024,
                    help="slots per compiled dispatch")
    ap.add_argument("--queries", type=int, default=50_000,
                    help="size of the mixed-op probe workload")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="dump qps + cache stats JSON")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the final serving-metrics snapshot "
                         "(pool.* cache counters, serve.* dispatch "
                         "latency histograms with p50/p99) as JSON")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the observability layer and write a "
                         "Chrome-trace JSON of the serve run (warm / "
                         "cold-load / dispatch spans; open in Perfetto)")
    ap.add_argument("--dryrun", action="store_true",
                    help="no artifacts needed: synthesize two shape "
                         "buckets and assert the serving invariants "
                         "(one compile per bucket, zero-retrace cold "
                         "load, loop/collective-free dispatch)")
    args = ap.parse_args()
    if args.trace:
        from repro import obs
        obs.enable()
    if args.dryrun:
        rc = _dryrun()
    else:
        if not args.artifact_dir:
            ap.error("--artifact-dir is required (or pass --dryrun)")
        rc = _run(args)
    if args.trace:
        from repro import obs
        tracer = obs.get_tracer()
        tracer.save(args.trace)
        print(f"[hserve] trace: {len(tracer.events)} events -> "
              f"{args.trace}")
    sys.exit(rc)


if __name__ == "__main__":
    main()
