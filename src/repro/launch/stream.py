"""Streaming peel service driver: replay an edge-event trace through
the incremental updater.

The job loads/generates a bipartite graph, stands up a
:class:`repro.streaming.StreamState`, then feeds it micro-epochs of
edge inserts/deletes — either replayed from a JSONL trace
(``--events``, see ``repro.streaming.events.load_trace``) or
synthesized against the live edge set (``--epochs``/``--batch``/
``--p-delete``).  Per epoch it prints what the updater actually did:
net events after coalescing, dirty partitions / dirty hierarchy
levels vs totals, the stale-serving bound (how many old-forest nodes
and packed-forest entities an in-flight reader could see stale
answers from — everything else is untouched by the repair), and the
repair/epoch wall time.

Serving never blocks: the previous epoch's forest stays readable
until the atomic swap, which the driver demonstrates by answering a
densest-leaves query from the pre-epoch snapshot while the repair for
that epoch is already committed.  ``--dryrun`` is the nightly
self-check: stream a few epochs on a small graph and assert θ, the
stats row, and every packed-forest array are bit-identical to a
from-scratch re-peel of the materialized graph (the same invariant
``tests/test_streaming.py`` checks exhaustively).
"""
from __future__ import annotations

import argparse
import json
import sys


class LaunchError(SystemExit):
    """Unsupported flag combination — raised instead of silently
    falling back to a different engine/driver."""

    def __init__(self, msg: str):
        super().__init__(f"[stream] error: {msg}")


def _validate(args) -> None:
    if args.engine is None:
        args.engine = "csr"
    if args.engine not in ("csr", "dense"):
        raise LaunchError(
            "streaming localizes FD re-runs per partition; that needs "
            "the csr or dense engine (beindex has no partition-local "
            "FD entry) — pass --engine csr|dense")
    if args.fd_driver not in ("device", "host", "vmapped"):
        raise LaunchError(
            "streaming supports the per-partition fd_drivers (device/"
            "host — dirty partitions re-run alone) and vmapped (the "
            "whole Phase 2 redispatches as its one batched loop); "
            "fused is not wired — pass --fd-driver device|host|vmapped")
    if args.fd_driver == "vmapped" and args.engine != "csr":
        raise LaunchError(
            "fd_driver='vmapped' is the csr single-dispatch Phase 2 — "
            "pass --engine csr")
    if args.kind == "wing" and args.side != "u":
        raise LaunchError("wing peels edges; there is no --side (use u)")
    if args.batch <= 0:
        raise LaunchError("--batch must be positive")


def _epoch_batches(args, st):
    """Yield one event list per micro-epoch."""
    from repro.streaming import load_trace, make_random_events

    if args.events:
        trace = load_trace(args.events)
        print(f"[stream] trace: {len(trace)} events from {args.events} "
              f"in batches of {args.batch}")
        for i in range(0, len(trace), args.batch):
            yield trace[i:i + args.batch]
    else:
        for e in range(args.epochs):
            # synthesized against the LIVE edge set so deletes stay
            # meaningful as the graph drifts
            yield make_random_events(
                st.g, args.batch, seed=args.seed + 1 + e,
                p_delete=args.p_delete)


def _densest(h):
    """Tiny serving query used to demonstrate the stale snapshot."""
    from repro.hierarchy import top_densest_leaves

    top = top_densest_leaves(h, 1)
    if len(top["density"]) == 0:
        return "-"
    return f"{float(top['density'][0]):.3f}@k={int(top['level'][0])}"


def _run(args) -> int:
    from repro.core.graph import paper_proxy_dataset, powerlaw_bipartite
    from repro.streaming import StreamConfig, StreamState

    _validate(args)
    if args.dataset:
        g = paper_proxy_dataset(args.dataset)
    else:
        g = powerlaw_bipartite(args.n_u, args.n_v, args.m, seed=args.seed)
    print(f"[stream] graph |U|={g.n_u} |V|={g.n_v} |E|={g.m}")

    cfg = StreamConfig(kind=args.kind, side=args.side, engine=args.engine,
                       P=args.parts, fd_driver=args.fd_driver)
    st = StreamState.initial(g, cfg)
    h0 = st.hierarchy
    print(f"[stream] init: kind={cfg.kind} engine={cfg.engine} "
          f"fd_driver={cfg.fd_driver} p_eff={st.result.stats.p_effective} "
          f"theta_max={int(st.result.theta.max()) if st.result.theta.size else 0} "
          f"forest={h0.n_nodes} nodes / {int(h0.levels.size)} levels")

    reports = []
    for events in _epoch_batches(args, st):
        # the pre-epoch snapshot a reader would be holding mid-repair
        snap = st.hierarchy
        rep = st.apply_epoch(events)
        reports.append(rep.as_dict())
        # stale-but-bounded serving: the snapshot stays fully queryable
        # after the swap; at most `stale_nodes` of its subtrees
        # (`stale_entities` packed entities) were invalidated by this
        # epoch's repair
        q_old, q_new = _densest(snap), _densest(st.hierarchy)
        tag = "noop " if rep.noop else ""
        print(f"[stream] epoch {rep.epoch}: {tag}"
              f"events={rep.n_events} net=+{rep.n_inserts}/-{rep.n_deletes} "
              f"dirty={rep.partitions_dirty}/{rep.p_eff} parts, "
              f"{rep.levels_dirty}/{rep.levels_total} levels; "
              f"stale<=({rep.stale_nodes} nodes, {rep.stale_entities} ents); "
              f"repair={rep.repair_ms:.1f}ms epoch={rep.epoch_ms:.1f}ms; "
              f"densest {q_old} -> {q_new}")

    ne = len(reports)
    if ne:
        avg = sum(r["epoch_ms"] for r in reports) / ne
        davg = sum(r["partitions_dirty"] for r in reports) / ne
        print(f"[stream] {ne} epochs: avg epoch {avg:.1f}ms, "
              f"avg dirty partitions {davg:.1f}, final |E|={st.g.m} "
              f"theta_max={int(st.result.theta.max()) if st.result.theta.size else 0}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(dict(
                config=dict(kind=cfg.kind, side=cfg.side, engine=cfg.engine,
                            parts=cfg.P, fd_driver=cfg.fd_driver),
                epochs=reports,
                theta=st.result.theta.tolist(),
                metrics=st.metrics.snapshot(),
            ), f)
        print(f"[stream] wrote {ne} epoch reports -> {args.out}")
    return 0


def _dryrun() -> int:
    """Nightly self-check: per-epoch bit-identity against from-scratch
    re-peels, for both entity kinds, on a small graph."""
    import numpy as np

    from repro.core.graph import powerlaw_bipartite
    from repro.core.peel import tip_decomposition, wing_decomposition
    from repro.hierarchy import build_hierarchy
    from repro.streaming import (StreamConfig, StreamState,
                                 make_random_events)

    g0 = powerlaw_bipartite(60, 40, 260, seed=3)
    for kind in ("wing", "tip"):
        cfg = StreamConfig(kind=kind, engine="csr", P=8, fd_driver="device")
        st = StreamState.initial(g0, cfg)
        dirt = []
        for e in range(3):
            events = make_random_events(st.g, 14, seed=100 + e)
            rep = st.apply_epoch(events)
            dirt.append(f"{rep.partitions_dirty}/{rep.p_eff}")
            if kind == "wing":
                ref = wing_decomposition(st.g, P=8, engine="csr")
            else:
                ref = tip_decomposition(st.g, side="u", P=8, engine="csr")
            assert np.array_equal(st.result.theta, ref.theta), \
                f"{kind} epoch {e}: incremental theta diverged"
            sa, sb = st.result.stats.as_dict(), ref.stats.as_dict()
            assert sa == sb, f"{kind} epoch {e}: stats diverged {sa} {sb}"
            h_ref = build_hierarchy(st.g, ref, kind=kind)
            h = st.hierarchy
            for f_ in ("node_level", "parent", "entity_node", "member_off",
                       "member_ids", "child_off", "child_ids", "tin",
                       "tout", "ent_order", "estart", "eend", "node_m",
                       "node_nu", "node_nv"):
                assert np.array_equal(getattr(h, f_), getattr(h_ref, f_)), \
                    f"{kind} epoch {e}: forest field {f_} diverged"
            assert np.allclose(h.density, h_ref.density), \
                f"{kind} epoch {e}: forest density diverged"
        print(f"[stream-dryrun] {kind}: 3 epochs bit-identical to "
              f"from-scratch re-peel (theta, stats, packed forest) ✓ "
              f"dirty={dirt}")
    print("[stream-dryrun] incremental maintenance = from-scratch "
          "semantics on both entity kinds ✓")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", choices=["wing", "tip"], default="wing",
                    help="entity universe to maintain incrementally: "
                         "edges (wing) or vertices (tip)")
    ap.add_argument("--side", default="u",
                    help="tip only: which vertex set carries theta")
    ap.add_argument("--engine", default=None, choices=["csr", "dense"],
                    help="peel engine; streaming needs a partition-"
                         "local FD entry, so csr (default) or dense")
    ap.add_argument("--fd-driver", default="device",
                    choices=["device", "host", "vmapped"],
                    help="FD driver for the per-epoch re-runs: device/"
                         "host re-peel only the dirty partitions; "
                         "vmapped (csr only) redispatches the whole "
                         "Phase 2 as its one batched while_loop")
    ap.add_argument("--parts", type=int, default=16)
    ap.add_argument("--dataset", default=None)
    ap.add_argument("--n-u", type=int, default=400)
    ap.add_argument("--n-v", type=int, default=200)
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--events", default=None, metavar="PATH",
                    help="JSONL edge-event trace to replay (one "
                         '{"op": "+"|"-", "u": int, "v": int} per '
                         "line), consumed in --batch sized "
                         "micro-epochs; default: synthesize --epochs "
                         "epochs of --batch random events")
    ap.add_argument("--epochs", type=int, default=4,
                    help="synthesized micro-epochs when no --events "
                         "trace is given")
    ap.add_argument("--batch", type=int, default=32,
                    help="events per micro-epoch")
    ap.add_argument("--p-delete", type=float, default=0.3,
                    help="synthesized traffic: probability an event "
                         "deletes an existing edge")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write per-epoch reports + final theta + "
                         "metrics snapshot as JSON")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the observability layer and write a "
                         "Chrome-trace JSON of the run (stream.epoch/"
                         "stream.cd/stream.fd/stream.repair spans, "
                         "hierarchy.repair levels).  Off by default — "
                         "the dispatched programs are byte-identical "
                         "without it")
    ap.add_argument("--dryrun", action="store_true",
                    help="small-graph self-check: per-epoch bit-"
                         "identity vs from-scratch re-peel, both kinds")
    args = ap.parse_args()
    if args.trace:
        from repro import obs
        obs.enable()
    rc = _dryrun() if args.dryrun else _run(args)
    if args.trace:
        from repro import obs
        tracer = obs.get_tracer()
        tracer.save(args.trace)
        print(f"[stream] trace: {len(tracer.events)} events -> "
              f"{args.trace}")
    sys.exit(rc)


if __name__ == "__main__":
    main()
