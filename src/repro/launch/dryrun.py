"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on placeholder devices and extract roofline inputs.

MUST be the very first lines — jax locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.sharding.compat import set_mesh  # noqa: E402
import repro.models as M  # noqa: E402
from repro.models.model import SHAPE_SETS  # noqa: E402
from repro.sharding import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.train.optimizer import AdamWConfig, OptState, abstract_opt_state  # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def dryrun_cell(arch: str, shape: str, multi_pod: bool = False,
                microbatches: int = 1, verbose: bool = True,
                extra_tags: str = "",
                cfg_overrides: Optional[Dict] = None) -> Dict:
    """Lower + compile one cell; returns the roofline record."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    ok, why = M.shape_applicable(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape, multi_pod=multi_pod,
                    status="skipped", reason=why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    info = SHAPE_SETS[shape]
    axes = M.logical_axes(cfg)
    pabs = M.abstract_params(cfg, jnp.bfloat16)
    p_sh = param_shardings(axes, pabs, mesh)
    t0 = time.time()
    ctx = set_mesh(mesh)  # so constrain() sees axis names
    ctx.__enter__()

    if info["kind"] == "train":
        oabs = abstract_opt_state(pabs)
        o_sh = OptState(mu=p_sh, nu=p_sh,
                        step=NamedSharding(mesh, P()))
        batch_abs = M.input_specs(cfg, shape)
        b_sh = batch_shardings(batch_abs, mesh)
        step = make_train_step(
            cfg, TrainConfig(microbatches=microbatches,
                             opt=AdamWConfig()))
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        )
        lowered = jitted.lower(pabs, oabs, batch_abs)
    elif info["kind"] == "prefill":
        batch_abs = M.input_specs(cfg, shape)
        b_sh = batch_shardings(batch_abs, mesh)

        def pf(params, batch):
            return M.prefill(params, batch["tokens"], cfg,
                             positions=batch.get("positions"),
                             frames=batch.get("frames"))

        jitted = jax.jit(pf, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(pabs, batch_abs)
    else:  # decode
        spec = M.input_specs(cfg, shape)
        cache_abs = spec["cache"]
        c_sh = cache_shardings(cache_abs, mesh, cfg)
        tok_sh = batch_shardings(
            dict(token=spec["token"]), mesh)["token"]

        def dec(params, cache, token, length):
            return M.serve_step(params, cache, token, length, cfg)

        jitted = jax.jit(
            dec,
            in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, c_sh),
        )
        lowered = jitted.lower(pabs, cache_abs, spec["token"],
                               spec["length"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ctx.__exit__(None, None, None)

    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec = dict(
        arch=arch, shape=shape, multi_pod=multi_pod, status="ok",
        kind=info["kind"],
        n_devices=int(mesh.devices.size),
        flops=float(cost.get("flops", -1)),
        bytes_accessed=float(cost.get("bytes accessed", -1)),
        collective_bytes=coll,
        time_lower_s=round(t_lower, 1),
        time_compile_s=round(t_compile, 1),
        tags=extra_tags,
    )
    for k in ("bytes accessed0{}", "bytes accessed1{}",
              "bytes accessedout{}"):
        if k in cost:
            rec[k.replace(" ", "_").replace("{}", "")] = float(cost[k])
    if mem is not None:
        rec["mem"] = dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", -1)),
            output_bytes=int(getattr(mem, "output_size_in_bytes", -1)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", -1)),
            code_bytes=int(
                getattr(mem, "generated_code_size_in_bytes", -1)),
        )
    if verbose:
        tb = rec.get("mem", {}).get("temp_bytes", -1)
        print(f"[dryrun] {arch:18s} {shape:12s} "
              f"{'2pod' if multi_pod else '1pod'} OK "
              f"flops={rec['flops']:.3e} bytes={rec['bytes_accessed']:.3e} "
              f"coll={sum(coll.values()):.3e}B temp={tb:.3e}B "
              f"compile={t_compile:.0f}s", flush=True)
    return rec


def run_all(out_path: str, multi_pod_values=(False, True),
            archs=None, shapes=None, resume=True,
            microbatches: int = 1):
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = []
    done = set()
    if resume and os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
        done = {(r["arch"], r["shape"], r["multi_pod"],
                 r.get("tags", "")) for r in results}
    tags = f"mb{microbatches}" if microbatches > 1 else ""
    for arch in (archs or ARCHS):
        for shape in (shapes or list(SHAPE_SETS)):
            for mp in multi_pod_values:
                key = (arch, shape, mp, tags)
                if key in done:
                    continue
                try:
                    rec = dryrun_cell(arch, shape, multi_pod=mp,
                                      microbatches=microbatches,
                                      extra_tags=tags)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    rec = dict(arch=arch, shape=shape, multi_pod=mp,
                               status="error", error=str(e)[-2000:],
                               tags=tags)
                    print(f"[dryrun] {arch} {shape} mp={mp} FAILED: "
                          f"{type(e).__name__}", flush=True)
                results.append(rec)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out = args.out or os.path.abspath(
        os.path.join(RESULTS_DIR, "results.json"))
    if args.arch and args.shape:
        rec = dryrun_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                          microbatches=args.microbatches)
        print(json.dumps(rec, indent=2))
        return
    mp_vals = (False, True)
    if args.single_pod_only:
        mp_vals = (False,)
    if args.multi_pod_only:
        mp_vals = (True,)
    archs = [args.arch] if args.arch else None
    shapes = [args.shape] if args.shape else None
    run_all(out, mp_vals, archs, shapes,
            microbatches=args.microbatches)


if __name__ == "__main__":
    main()
