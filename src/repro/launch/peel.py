"""Graph-peeling service driver + production-mesh dry-run for PBNG.

This is the paper's analytic as a deployable job: load/generate a
bipartite graph, run distributed two-phase peeling over a device mesh,
emit wing/tip numbers + stats.  Flags are uniform across
``--kind wing`` and ``--kind tip`` (``--engine csr``, ``--aligned``,
``--fd-driver vmapped``, ``--use-pallas``); unsupported combinations are
rejected with an explicit error — never a silent fallback to another
engine.  ``--dryrun`` lowers the CD rounds and the FD partition-peels of
BOTH entity kinds on the 512-device production mesh and verifies the
structural claims (one-psum aligned CD, collective-free FD,
single-``while`` vmapped Phase 2) at scale.
"""
from __future__ import annotations

import argparse
import json
import sys


class LaunchError(SystemExit):
    """Unsupported flag combination — raised instead of silently
    falling back to a different engine/driver."""

    def __init__(self, msg: str):
        super().__init__(f"[peel] error: {msg}")


def _validate(args, n_dev: int) -> None:
    """Resolve the per-kind engine default, then reject unsupported
    flag combinations with explicit errors."""
    if args.engine is None:
        # per-kind default: the user never chose an engine, so resolve
        # to each kind's canonical one instead of erroring on a default
        # (real graphs default to csr — the only engine whose memory is
        # wedge-bounded, matching the tiled ⋈init they arrive through)
        if args.edges:
            args.engine = "csr"
        else:
            args.engine = "beindex" if args.kind == "wing" else "csr"
    if args.edges and args.dataset:
        raise LaunchError(
            "--edges and --dataset are exclusive graph sources")
    if args.edges and n_dev > 1:
        raise LaunchError(
            "--edges feeds the tiled ⋈init into the single-device "
            "engines; the distributed CD/FD paths take proxy graphs "
            "(run single-device, or --dryrun for mesh checks)")
    if args.kind == "tip" and args.engine == "beindex":
        raise LaunchError(
            "tip peels vertices — there is no BE-Index tip engine; "
            "pass --engine csr (scalable) or --engine dense")
    if args.use_pallas and args.engine != "csr":
        raise LaunchError(
            "--use-pallas routes csr slot layouts through the blocked "
            "kernels; pass --engine csr")
    if args.fd_driver == "vmapped" and args.engine != "csr":
        raise LaunchError(
            "--fd-driver vmapped is the csr single-dispatch Phase 2; "
            "pass --engine csr")
    if args.aligned and args.engine not in ("csr", "beindex"):
        raise LaunchError(
            "--aligned is the one-psum CD sharding (csr: pair/vertex "
            "aligned; beindex: bloom aligned); --engine dense has no "
            "sharded index to align")
    if args.fused_fd and args.engine != "csr":
        raise LaunchError(
            "--fused-fd is the fused csr FD round kernel; pass "
            "--engine csr")
    if args.fused_fd and args.fd_driver == "host":
        raise LaunchError(
            "--fused-fd fuses the device-side FD round; the host driver "
            "has no device round body (pass --fd-driver device|vmapped)")
    if n_dev > 1:
        if args.fused_fd:
            raise LaunchError(
                "--fused-fd is wired for the single-device csr FD "
                "drivers; distributed FD runs per-partition while_loops "
                "under shard_map")
        if args.kind == "wing" and args.engine == "dense":
            raise LaunchError(
                "no distributed dense wing path; pass --engine "
                "beindex|csr (or run single-device)")
        if args.kind == "wing" and args.fd_driver == "vmapped":
            raise LaunchError(
                "distributed wing FD runs one while_loop per partition "
                "under shard_map (driver 'device'); the single-dispatch "
                "vmapped Phase 2 is single-device wing or distributed "
                "tip only")
        if args.fd_driver == "host":
            raise LaunchError(
                "--fd-driver host is the single-device A/B baseline; "
                "the distributed FD drivers are device|vmapped")
        if args.use_pallas:
            raise LaunchError(
                "--use-pallas is wired for the single-device csr "
                "engines; the distributed CD rounds use segment_sum "
                "shards")
    else:
        if args.aligned:
            raise LaunchError(
                "--aligned shards the CD index across devices; it needs "
                "a multi-device mesh (or use --dryrun)")
    if args.fused_fd is None:
        # default ON where supported: single device, csr engine, a
        # device-side FD driver — the zero-per-round-dispatch round is
        # θ-bit-identical to the unfused path, so there is no reason
        # not to take it (pass --no-fused-fd for the A/B baseline)
        args.fused_fd = (
            n_dev == 1 and args.engine == "csr"
            and args.fd_driver in ("device", "vmapped"))


def _dryrun() -> int:
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import distributed as D
    from repro.core.beindex import build_beindex
    from repro.core.graph import powerlaw_bipartite
    from repro.core.peel import wing_decomposition
    from repro.launch.mesh import make_peel_mesh
    from repro.sharding.compat import shard_map

    mesh = make_peel_mesh(512)
    g = powerlaw_bipartite(400, 200, 2000, seed=1)
    be = build_beindex(g)

    # --- CD round at 512 devices
    st = D.shard_links(be, g.m, 512)
    fn = D.make_cd_round(mesh, "peel", st.nb, g.m)
    peeled = jnp.zeros((g.m + 1,), bool)
    sup = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    lowered = fn.lower(peeled, st.alive_link, st.k_alive, sup,
                       st.le, st.lt, st.lb)
    comp = lowered.compile()
    txt = comp.as_text()
    n_ar = txt.count("all-reduce")
    print(f"[peel-dryrun] CD round compiled at 512 devices; "
          f"all-reduce sites={n_ar}")

    # --- FD partition peel at 512 devices
    res = wing_decomposition(g, P=64, engine="beindex", be=be)
    packed = D.pack_fd_partitions(
        g, be, res.part, res.support_init, res.stats.p_effective,
    )
    n_parts = packed["le"].shape[0]
    pad = (-n_parts) % 512

    def padp(x):
        if pad == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], 0))

    args_ = tuple(padp(packed[k]) for k in
                  ("le", "lt", "lb", "alive0", "canon", "k0", "sup0",
                   "mine"))
    vb = jax.vmap(D._fd_body_one_partition)
    fd = shard_map(vb, mesh=mesh,
                   in_specs=tuple(P("peel") for _ in args_),
                   out_specs=(P("peel"), P("peel")))
    fd_comp = jax.jit(fd).lower(*args_).compile()
    fd_txt = fd_comp.as_text()
    bad = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")
           if w in fd_txt]
    assert not bad, f"FD must be collective-free, found {bad}"
    print("[peel-dryrun] FD peel compiled at 512 devices; "
          "NO collectives in HLO ✓")
    ca = fd_comp.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax returns [dict]
        ca = ca[0] if ca else {}
    print(f"[peel-dryrun] FD flops/device={ca.get('flops', -1):.3e} "
          f"bytes={ca.get('bytes accessed', -1):.3e}")

    # --- csr engine at 512 devices: wedge-sharded CD + wedge-packed FD
    from repro.core import csr

    wed = csr.build_wedges(g)
    st = D.shard_wedges(wed, 512)
    cfn = D.make_cd_round_csr(mesh, "peel", st.n_pairs, g.m)
    sup = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    ctxt = cfn.lower(peeled, st.alive_w, st.W_pad, sup,
                     st.we1, st.we2, st.wp).compile().as_text()
    print(f"[peel-dryrun] csr CD round compiled at 512 devices; "
          f"all-reduce sites={ctxt.count('all-reduce')}")

    # --- pair-aligned csr CD at 512 devices: ONE psum per round
    pal = D.shard_wedges_pair_aligned(wed, 512)
    pfn = D.make_cd_round_csr_pair_aligned(mesh, "peel", pal["Pmax"], g.m)
    ptxt = pfn.lower(peeled, jnp.asarray(pal["alive"]),
                     jnp.asarray(pal["W0"]), sup,
                     jnp.asarray(pal["we1"]), jnp.asarray(pal["we2"]),
                     jnp.asarray(pal["wp"])).compile().as_text()
    n_pal = ptxt.count("all-reduce(") + ptxt.count("all-reduce-start(")
    assert n_pal == 1, f"pair-aligned CD must pay ONE psum, found {n_pal}"
    print("[peel-dryrun] pair-aligned csr CD compiled at 512 devices; "
          "exactly ONE all-reduce per round ✓")

    res_c = wing_decomposition(g, P=64, engine="csr")
    packed_c = D.pack_fd_partitions_csr(
        wed, res_c.part, res_c.support_init, res_c.stats.p_effective)
    n_parts_c = packed_c["we1"].shape[0]
    pad_c = (-n_parts_c) % 512

    def padc(x):
        if pad_c == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad_c,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], 0))

    args_c = tuple(padc(packed_c[k]) for k in
                   ("we1", "we2", "wp", "alive0", "W0", "sup0", "mine"))
    fd_c = shard_map(jax.vmap(D._fd_body_one_partition_csr), mesh=mesh,
                     in_specs=tuple(P("peel") for _ in args_c),
                     out_specs=(P("peel"), P("peel")))
    fd_c_txt = jax.jit(fd_c).lower(*args_c).compile().as_text()
    bad_c = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute")
             if w in fd_c_txt]
    assert not bad_c, f"csr FD must be collective-free, found {bad_c}"
    print("[peel-dryrun] csr FD peel compiled at 512 devices; "
          "NO collectives in HLO ✓")

    # --- single-dispatch vmapped FD (single device): the whole Phase 2
    # must lower to exactly ONE while_loop with zero collectives
    from repro.core.peel import _fd_tip_vmapped, _fd_wing_vmapped

    packed_v = D.pack_fd_partitions_csr(
        wed, res_c.part, res_c.support_init, res_c.stats.p_effective,
        bucket=True, flat=True)
    args_v = tuple(jnp.asarray(packed_v[k]) for k in
                   ("flat_we1", "flat_we2", "flat_wp", "flat_alive0",
                    "flat_W0", "mine", "sup0"))
    n_pairs_v = int(packed_v["flat_W0"].shape[0])
    jaxpr = str(jax.make_jaxpr(
        lambda *a: _fd_wing_vmapped(*a, n_pairs=n_pairs_v))(*args_v))
    n_while = jaxpr.count("while[")
    assert n_while == 1, f"vmapped FD must be ONE while_loop, got {n_while}"
    assert not any(c in jaxpr for c in ("psum", "all_gather", "ppermute")), \
        "vmapped FD must be collective-free"
    print("[peel-dryrun] vmapped csr FD: whole Phase 2 is ONE while_loop, "
          "zero collectives ✓")

    # --- TIP csr at 512 devices: the entity-agnostic core's second
    # instantiation gets the same structural guarantees as wing
    from repro.core.peel import tip_decomposition

    bf0 = wed.pair_butterflies0()
    n = g.n_u
    tal = D.shard_tip_pairs(wed, bf0, 512, aligned=True)
    tfn = D.make_cd_round_tip_csr(mesh, "peel", n)
    tpe = jnp.zeros((n + 1,), bool)
    tsup = jnp.zeros((n + 1,), jnp.int32)
    ttxt = tfn.lower(tpe, tsup, jnp.asarray(tal["dst"]),
                     jnp.asarray(tal["src"]),
                     jnp.asarray(tal["bf"])).compile().as_text()
    n_tip = ttxt.count("all-reduce(") + ttxt.count("all-reduce-start(")
    assert n_tip == 1, f"aligned tip CD must pay ONE psum, found {n_tip}"
    print("[peel-dryrun] vertex-aligned tip csr CD compiled at 512 "
          "devices; exactly ONE all-reduce per round ✓")

    res_t = tip_decomposition(g, side="u", P=64, engine="csr")
    packed_t = D.pack_fd_partitions_tip_csr(
        wed, bf0, res_t.part, res_t.support_init,
        res_t.stats.p_effective, stacked=True)
    n_parts_t = packed_t["st_pa"].shape[0]
    pad_t = (-n_parts_t) % 512

    def padt(x):
        if pad_t == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad_t,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], 0))

    args_t = tuple(padt(packed_t[k]) for k in
                   ("st_pa", "st_pb", "st_bf", "mine", "sup0"))
    fd_t = shard_map(jax.vmap(D._fd_body_one_partition_tip_csr), mesh=mesh,
                     in_specs=tuple(P("peel") for _ in args_t),
                     out_specs=(P("peel"), P("peel")))
    fd_t_txt = jax.jit(fd_t).lower(*args_t).compile().as_text()
    bad_t = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute")
             if w in fd_t_txt]
    assert not bad_t, f"tip csr FD must be collective-free, found {bad_t}"
    print("[peel-dryrun] tip csr FD peel compiled at 512 devices; "
          "NO collectives in HLO ✓")

    packed_tv = D.pack_fd_partitions_tip_csr(
        wed, bf0, res_t.part, res_t.support_init,
        res_t.stats.p_effective, bucket=True)
    tjaxpr = str(jax.make_jaxpr(_fd_tip_vmapped)(
        jnp.asarray(packed_tv["pa"]), jnp.asarray(packed_tv["pb"]),
        jnp.asarray(packed_tv["bf"]), jnp.asarray(packed_tv["mine"]),
        jnp.asarray(packed_tv["sup0"])))
    n_tw = tjaxpr.count("while[")
    assert n_tw == 1, f"vmapped tip FD must be ONE while_loop, got {n_tw}"
    assert not any(c in tjaxpr for c in ("psum", "all_gather", "ppermute")), \
        "vmapped tip FD must be collective-free"
    print("[peel-dryrun] vmapped tip FD: whole Phase 2 is ONE while_loop, "
          "zero collectives ✓")

    # --- fused FD (single device): the while_loop ROUND BODY must be
    # exactly ONE pallas_call — no segment-sum/argmin/compaction tail
    from repro.core.peel import _fd_wing_fused_impl

    packed_f = D.pack_fd_partitions_csr(
        wed, res_c.part, res_c.support_init, res_c.stats.p_effective,
        bucket=True, slots=True)
    R_f, _ = packed_f["slot_sizes"]
    B_f = packed_f["sup0"].shape[0]
    W_rows = np.zeros((B_f, R_f), np.int32)
    w_f = min(R_f, packed_f["W0"].shape[1])
    W_rows[:, :w_f] = packed_f["W0"][:, :w_f]
    fj = jax.make_jaxpr(lambda *a: _fd_wing_fused_impl(*a, interpret=True))(
        jnp.asarray(packed_f["slot_e1"]), jnp.asarray(packed_f["slot_e2"]),
        jnp.asarray(packed_f["slot_valid"]), jnp.asarray(W_rows),
        jnp.asarray(packed_f["mine"]), jnp.asarray(packed_f["sup0"]))
    whiles = [e for e in fj.jaxpr.eqns if e.primitive.name == "while"]
    assert len(whiles) == 1, f"fused FD must be ONE while_loop, {len(whiles)}"
    body_prims = [e.primitive.name
                  for e in whiles[0].params["body_jaxpr"].jaxpr.eqns]
    assert body_prims.count("pallas_call") == 1, body_prims
    banned_f = {"scatter", "scatter-add", "scatter_add", "gather",
                "argmin", "reduce_min", "cumsum", "sort", "segment_sum"}
    assert not banned_f & set(body_prims), body_prims
    print("[peel-dryrun] fused FD round body is ONE pallas_call "
          f"(body prims: {body_prims}) ✓")

    # --- hierarchical CD at 512 devices: the ONE logical psum staged
    # over a (16, 32) 2-D mesh — exactly two all-reduces with nested
    # replica groups, bit-identical int32 reduction
    from repro.launch.mesh import make_peel_mesh_2d

    mesh2 = make_peel_mesh_2d(512)
    hfn = D.make_cd_round_csr_pair_aligned(
        mesh2, ("grp", "loc"), pal["Pmax"], g.m)
    htxt = hfn.lower(peeled, jnp.asarray(pal["alive"]),
                     jnp.asarray(pal["W0"]), sup,
                     jnp.asarray(pal["we1"]), jnp.asarray(pal["we2"]),
                     jnp.asarray(pal["wp"])).compile().as_text()
    n_h = htxt.count("all-reduce(") + htxt.count("all-reduce-start(")
    assert n_h == 2, f"staged CD psum must be TWO all-reduces, found {n_h}"
    hflat = htxt.replace(" ", "")
    assert "{0,1,2,3" in hflat and "{0,32,64," in hflat, \
        "staged CD psum must carry nested replica groups"
    print("[peel-dryrun] hierarchical pair-aligned CD compiled at 512 "
          "devices (16 groups x 32); one logical psum = two staged "
          "all-reduces with nested replica groups ✓")
    return 0


def _emit_hierarchy(args, g, result, kind: str, stats=None) -> None:
    """Build the dense-subgraph hierarchy from peel output and write the
    versioned artifact (see ``repro.hierarchy``): decompose once, serve
    forever.  ``result`` is a PeelResult whenever one exists — the
    single-device engines AND the distributed paths
    (``return_result=True``) — so the artifact always carries the
    PeelStats + CD partition provenance; ``stats`` is only the fallback
    row for raw-θ input."""
    import time

    import numpy as np

    from repro.core.peel import PeelResult
    from repro.hierarchy import (build_hierarchy, density_profile,
                                 save_hierarchy, top_densest_leaves)

    meta = None
    if not isinstance(result, PeelResult) and stats:
        meta = dict(stats=stats)
    t0 = time.perf_counter()
    h = build_hierarchy(g, result, kind=kind, side=args.side, meta=meta)
    dt = time.perf_counter() - t0
    save_hierarchy(args.emit_hierarchy, h)
    lv = h.levels
    print(f"[peel] hierarchy: {h.n_nodes} nodes over {lv.size} levels "
          f"built in {dt * 1e3:.1f} ms -> {args.emit_hierarchy}")
    if lv.size:
        prof = density_profile(h, int(lv[0]))
        top = top_densest_leaves(h, 3)
        print(f"[peel] k={int(lv[0])}: {prof['n_components']} components; "
              f"densest leaves: "
              f"{np.round(top['density'], 3).tolist()} "
              f"at k={top['level'].tolist()}")


def _run(args) -> int:
    import jax
    import numpy as np

    from repro.core import distributed as D
    from repro.core.graph import paper_proxy_dataset, powerlaw_bipartite
    from repro.core.peel import tip_decomposition, wing_decomposition
    from repro.launch.mesh import make_peel_mesh

    n_dev = len(jax.devices())
    _validate(args, n_dev)

    sup0 = None
    if args.edges:
        # real-data path: out-of-core ingest → bounded-tile ⋈init →
        # the same CD/FD engines, fed through sup0 injection (the
        # engines never see the O(Σ deg²) wedge list at once)
        from types import SimpleNamespace

        from repro.core import csr as csrmod
        from repro.data import ingest_edges

        ig = ingest_edges(args.edges, out_dir=args.ingest_dir)
        g = ig.as_graph()
        print(f"[peel] ingested {args.edges}: |U|={ig.n_u} "
              f"|V|={ig.n_v} |E|={ig.m}")
        if args.kind == "tip" and args.side == "v":
            # wedge centers must sit on the peeled side's opposite
            # partition: transpose the CSR view, not the data
            src = SimpleNamespace(n_u=ig.n_v, n_v=ig.n_u, m=ig.m,
                                  csr_v=ig.csr_u)
        else:
            src = ig
        sup_e, sup_u, total_bf, tstats = csrmod.tiled_butterfly_init(
            src, tile_wedges=args.tile_wedges,
            use_pallas=args.use_pallas)
        sup0 = sup_e if args.kind == "wing" else sup_u
        print(f"[peel] tiled init: butterflies={total_bf} "
              f"tiles={tstats.n_tiles} wedges={tstats.n_wedges} "
              f"peak_tile_wedges={tstats.peak_tile_wedges}")
    elif args.dataset:
        g = paper_proxy_dataset(args.dataset)
    else:
        g = powerlaw_bipartite(args.n_u, args.n_v, args.m, seed=args.seed)
    print(f"[peel] graph |U|={g.n_u} |V|={g.n_v} |E|={g.m}")

    stats_out = {}
    result = None  # PeelResult when available (single-device OR dist.)
    if args.kind == "wing":
        if n_dev > 1:
            mesh = make_peel_mesh()
            theta, stats_out, result = D.distributed_wing_decomposition(
                g, mesh, P_parts=args.parts, engine=args.engine,
                aligned=args.aligned, return_result=True)
            print(f"[peel] distributed over {stats_out['n_dev']} devices: "
                  f"{stats_out}")
        else:
            res = wing_decomposition(
                g, P=args.parts, engine=args.engine,
                fd_driver=args.fd_driver, use_pallas=args.use_pallas,
                fused=args.fused_fd, sup0=sup0)
            result = res
            theta = res.theta
            s = res.stats
            stats_out = s.as_dict()
            print(f"[peel] engine={s.engine} rho_cd={s.rho_cd} "
                  f"rho_fd_max={s.rho_fd_max} updates={s.updates} "
                  f"sync_reduction={s.sync_reduction:.1f}x")
    else:
        if n_dev > 1:
            mesh = make_peel_mesh()
            theta, stats_out, result = D.distributed_tip_decomposition(
                g, mesh, side=args.side, P_parts=args.parts,
                engine=args.engine, aligned=args.aligned,
                fd_driver=args.fd_driver, return_result=True)
            print(f"[peel] distributed over {stats_out['n_dev']} devices: "
                  f"{stats_out}")
        else:
            res = tip_decomposition(
                g, side=args.side, P=args.parts, engine=args.engine,
                fd_driver=args.fd_driver, use_pallas=args.use_pallas,
                fused=args.fused_fd, sup0=sup0)
            result = res
            theta = res.theta
            s = res.stats
            stats_out = s.as_dict()
            print(f"[peel] engine={s.engine} side={s.side} "
                  f"rho_cd={s.rho_cd} rho_fd_max={s.rho_fd_max} "
                  f"recounts={s.recounts}")

    if (result is not None
            and getattr(result, "timeline", None) is not None):
        stats_out["timeline"] = result.timeline.summary()
        print(f"[peel] timeline: {stats_out['timeline']}")
    import hashlib
    theta_sha = hashlib.sha256(
        np.asarray(theta, dtype=np.int64).tobytes()).hexdigest()
    stats_out["theta_sha256"] = theta_sha
    print(f"[peel] theta: max={int(theta.max()) if theta.size else 0} "
          f"levels={len(set(theta.tolist()))} sha256={theta_sha}")
    if args.emit_hierarchy:
        _emit_hierarchy(args, g, result if result is not None else theta,
                        kind=args.kind, stats=stats_out)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(dict(theta=theta.tolist(), stats=stats_out), f)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", "--mode", dest="kind",
                    choices=["wing", "tip"], default="wing",
                    help="entity universe to peel: edges (wing) or "
                         "vertices (tip); flags below apply uniformly")
    ap.add_argument("--dataset", default=None)
    ap.add_argument("--edges", default=None, metavar="PATH",
                    help="peel a real graph: KONECT/SNAP-style edge "
                         "list (TSV/space separated, %% or # comments, "
                         "1- or 0-based ids, negative third column = "
                         "deletion).  Ingested out of core (chunked "
                         "dedup + degree-ordered relabel to a "
                         "memory-mapped CSR), then counted in bounded "
                         "wedge tiles (--tile-wedges) before the "
                         "engines run.  Exclusive with --dataset")
    ap.add_argument("--tile-wedges", type=int, default=1 << 20,
                    help="wedge-tile budget for the --edges counting "
                         "pass: peak host memory is O(tile) and peak "
                         "device memory one kernel block, never the "
                         "full O(Σ deg²) wedge list (default 2^20)")
    ap.add_argument("--ingest-dir", default=None, metavar="DIR",
                    help="cache directory for the --edges ingestion "
                         "artifacts (default: <edges>.ingest next to "
                         "the input; re-runs hit the cache)")
    ap.add_argument("--n-u", type=int, default=400)
    ap.add_argument("--n-v", type=int, default=200)
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--parts", type=int, default=16)
    ap.add_argument("--engine", default=None,
                    choices=["beindex", "dense", "csr"],
                    help="beindex (wing only), dense, or csr (the "
                         "scalable path for both kinds); default: "
                         "beindex for wing, csr for tip")
    ap.add_argument("--fd-driver", default="device",
                    choices=["device", "vmapped", "host"],
                    help="csr FD cascade driver: one while_loop per "
                         "partition (device), ONE while_loop for the "
                         "whole Phase 2 (vmapped — single dispatch), or "
                         "per-round dispatch (host; single-device A/B "
                         "baseline only)")
    ap.add_argument("--aligned", "--pair-aligned", dest="aligned",
                    action="store_true",
                    help="distributed one-psum CD sharding: keep every "
                         "segment's items on one device (wing csr: "
                         "pair-aligned wedges; tip csr: vertex-aligned "
                         "pair entries; wing beindex: bloom-aligned "
                         "links)")
    ap.add_argument("--fused-fd", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="csr engines, single device: run every FD round "
                         "as ONE fused Pallas launch (kernels.fd_round) "
                         "— k-advance + compaction + support update "
                         "in-kernel, zero per-round dispatch tail.  "
                         "Default: on where supported; --no-fused-fd "
                         "forces the unfused A/B baseline")
    ap.add_argument("--use-pallas", action="store_true",
                    help="csr engines only: run CD support updates "
                         "through the blocked Pallas kernels (and, for "
                         "wing --fd-driver vmapped, inside the FD "
                         "while_loop)")
    ap.add_argument("--side", default="u")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--emit-hierarchy", default=None, metavar="PATH",
                    help="build the dense-subgraph hierarchy from the "
                         "decomposition and save it as a versioned npz "
                         "artifact (load with "
                         "repro.hierarchy.load_hierarchy)")
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the observability layer and write a "
                         "Chrome-trace JSON of the run (open in "
                         "Perfetto / chrome://tracing): peel/cd/fd "
                         "spans, per-round cd.round/fd.round events, "
                         "hierarchy build spans.  Off by default — the "
                         "traced programs are byte-identical without it")
    args = ap.parse_args()
    if args.trace:
        from repro import obs
        obs.enable()
    rc = _dryrun() if args.dryrun else _run(args)
    if args.trace:
        tracer = obs.get_tracer()
        tracer.save(args.trace)
        print(f"[peel] trace: {len(tracer.events)} events -> "
              f"{args.trace}")
    sys.exit(rc)


if __name__ == "__main__":
    main()
