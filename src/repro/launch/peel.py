"""Graph-peeling service driver + production-mesh dry-run for PBNG.

This is the paper's analytic as a deployable job: load/generate a
bipartite graph, run distributed two-phase peeling over a device mesh,
emit wing/tip numbers + stats.  ``--dryrun`` lowers the CD round and the
FD partition-peel on the 512-device production mesh and verifies the FD
HLO is collective-free (the paper's "no global synchronization", checked
structurally at scale).
"""
from __future__ import annotations

import argparse
import json
import sys


def _dryrun() -> int:
    import os
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core import distributed as D
    from repro.core.beindex import build_beindex
    from repro.core.graph import powerlaw_bipartite
    from repro.core.peel import wing_decomposition
    from repro.launch.mesh import make_peel_mesh

    mesh = make_peel_mesh(512)
    g = powerlaw_bipartite(400, 200, 2000, seed=1)
    be = build_beindex(g)

    # --- CD round at 512 devices
    st = D.shard_links(be, g.m, 512)
    fn = D.make_cd_round(mesh, "peel", st.nb, g.m)
    peeled = jnp.zeros((g.m + 1,), bool)
    sup = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
    lowered = fn.lower(peeled, st.alive_link, st.k_alive, sup,
                       st.le, st.lt, st.lb)
    comp = lowered.compile()
    txt = comp.as_text()
    n_ar = txt.count("all-reduce")
    print(f"[peel-dryrun] CD round compiled at 512 devices; "
          f"all-reduce sites={n_ar}")

    # --- FD partition peel at 512 devices
    res = wing_decomposition(g, P=64, engine="beindex", be=be)
    packed = D.pack_fd_partitions(
        g, be, res.part, res.support_init, res.stats.p_effective,
    )
    n_parts = packed["le"].shape[0]
    pad = (-n_parts) % 512

    def padp(x):
        if pad == 0:
            return jnp.asarray(x)
        fill = np.zeros((pad,) + x.shape[1:], dtype=x.dtype)
        return jnp.asarray(np.concatenate([x, fill], 0))

    args_ = tuple(padp(packed[k]) for k in
                  ("le", "lt", "lb", "alive0", "canon", "k0", "sup0",
                   "mine"))
    vb = jax.vmap(D._fd_body_one_partition)
    fd = jax.shard_map(vb, mesh=mesh,
                       in_specs=tuple(P("peel") for _ in args_),
                       out_specs=(P("peel"), P("peel")))
    fd_comp = jax.jit(fd).lower(*args_).compile()
    fd_txt = fd_comp.as_text()
    bad = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute")
           if w in fd_txt]
    assert not bad, f"FD must be collective-free, found {bad}"
    print("[peel-dryrun] FD peel compiled at 512 devices; "
          "NO collectives in HLO ✓")
    ca = fd_comp.cost_analysis() or {}
    print(f"[peel-dryrun] FD flops/device={ca.get('flops', -1):.3e} "
          f"bytes={ca.get('bytes accessed', -1):.3e}")
    return 0


def _run(args) -> int:
    import jax
    import numpy as np

    from repro.core import distributed as D
    from repro.core.graph import paper_proxy_dataset, powerlaw_bipartite
    from repro.core.peel import tip_decomposition, wing_decomposition
    from repro.launch.mesh import make_peel_mesh

    if args.dataset:
        g = paper_proxy_dataset(args.dataset)
    else:
        g = powerlaw_bipartite(args.n_u, args.n_v, args.m, seed=args.seed)
    print(f"[peel] graph |U|={g.n_u} |V|={g.n_v} |E|={g.m}")

    if args.mode == "wing":
        if len(jax.devices()) > 1:
            mesh = make_peel_mesh()
            theta, stats = D.distributed_wing_decomposition(
                g, mesh, P_parts=args.parts)
            print(f"[peel] distributed over {stats['n_dev']} devices: "
                  f"{stats}")
        else:
            res = wing_decomposition(g, P=args.parts, engine=args.engine)
            theta = res.theta
            s = res.stats
            print(f"[peel] rho_cd={s.rho_cd} rho_fd_max={s.rho_fd_max} "
                  f"updates={s.updates} sync_reduction="
                  f"{s.sync_reduction:.1f}x")
    else:
        if args.engine in ("dense", "csr"):
            tip_engine = args.engine
        else:
            tip_engine = "dense"
            print(f"[peel] tip has no '{args.engine}' engine; using dense "
                  "(pass --engine dense|csr to silence)")
        res = tip_decomposition(
            g, side=args.side, P=args.parts, engine=tip_engine)
        theta = res.theta
        s = res.stats
        print(f"[peel] rho_cd={s.rho_cd} rho_fd_max={s.rho_fd_max} "
              f"recounts={s.recounts}")

    print(f"[peel] theta: max={int(theta.max()) if theta.size else 0} "
          f"levels={len(set(theta.tolist()))}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(dict(theta=theta.tolist()), f)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["wing", "tip"], default="wing")
    ap.add_argument("--dataset", default=None)
    ap.add_argument("--n-u", type=int, default=400)
    ap.add_argument("--n-v", type=int, default=200)
    ap.add_argument("--m", type=int, default=2000)
    ap.add_argument("--parts", type=int, default=16)
    ap.add_argument("--engine", default="beindex",
                    choices=["beindex", "dense", "csr"])
    ap.add_argument("--side", default="u")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--dryrun", action="store_true")
    args = ap.parse_args()
    if args.dryrun:
        sys.exit(_dryrun())
    sys.exit(_run(args))


if __name__ == "__main__":
    main()
