"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt \
        --resume auto

Fault tolerance: checkpoints every ``--ckpt-every`` steps (atomic
manifests), auto-resume from the latest complete checkpoint, straggler
detection via step-time z-score, optional crash injection (--crash-at)
used by the restart test.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data import DataConfig, synthetic_batches
from repro.launch.mesh import make_local_mesh
from repro.sharding.compat import set_mesh
import repro.models as M
from repro.models.config import reduced
from repro.sharding import batch_shardings, param_shardings
from repro.train import (
    AdamWConfig,
    StragglerDetector,
    TrainConfig,
    latest_step,
    make_train_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.train.optimizer import adamw_init


def run(args) -> int:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.seq:
        cfg = dataclasses.replace(cfg, max_seq=args.seq)

    mesh = make_local_mesh()
    ctx = set_mesh(mesh)
    ctx.__enter__()

    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key, dtype=jnp.float32)
    axes = M.logical_axes(cfg)
    p_sh = param_shardings(axes, params, mesh)
    params = jax.tree.map(jax.device_put, params, p_sh)
    opt = adamw_init(params)

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        opt=AdamWConfig(lr=args.lr, total_steps=args.steps),
    )
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    start = 0
    if args.resume == "auto" and args.ckpt_dir:
        s = latest_step(args.ckpt_dir)
        if s is not None:
            params, opt, _ = restore_checkpoint(
                args.ckpt_dir, s, params, opt)
            start = s
            print(f"[train] resumed from step {s}", flush=True)

    dcfg = DataConfig(batch=args.batch, seq=args.seq or cfg.max_seq,
                      vocab=cfg.vocab, seed=args.seed)
    extra = None
    if cfg.family == "audio":
        extra = {"frames": lambda rng: rng.normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32) * 0.02}
    if cfg.rope_type == "mrope":
        s_len = args.seq or cfg.max_seq
        extra = {"positions": lambda rng: np.broadcast_to(
            np.arange(s_len, dtype=np.int32)[None, None],
            (args.batch, 3, s_len)).copy()}
    data = synthetic_batches(dcfg, start_step=start, extra=extra)

    det = StragglerDetector()
    losses = []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        det.start()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        if det.stop():
            print(f"[train] straggler step {step} detected", flush=True)
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params, opt,
                            extra=dict(arch=cfg.name))
        if args.crash_at is not None and step + 1 == args.crash_at:
            print("[train] injected crash", flush=True)
            os._exit(42)

    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params, opt,
                        extra=dict(arch=cfg.name))
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] done: loss {first:.4f} -> {last:.4f} "
          f"({len(losses)} steps, stragglers={det.flagged})", flush=True)
    ctx.__exit__(None, None, None)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto")
    ap.add_argument("--crash-at", type=int, default=None)
    sys.exit(run(ap.parse_args()))


if __name__ == "__main__":
    main()
