"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run forces a 512-device host platform before first init;
tests and benches must keep seeing a single device).
"""
from __future__ import annotations

import jax

from repro.sharding.compat import HAS_AXIS_TYPE, AxisType

__all__ = [
    "make_production_mesh",
    "make_peel_mesh",
    "make_peel_mesh_2d",
    "make_local_mesh",
]


def _mesh(shape, axes):
    # GSPMD auto-propagation semantics (explicit-mode is jax>=0.9 default)
    if not HAS_AXIS_TYPE:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_peel_mesh(n_devices: int | None = None):
    """1-D mesh for distributed graph peeling (CD link shards / FD
    partitions)."""
    n = n_devices or len(jax.devices())
    return _mesh((n,), ("peel",))


def make_peel_mesh_2d(n_devices: int | None = None,
                      groups: int | None = None):
    """2-D ("grp", "loc") mesh for hierarchical CD collectives.

    The CD round's single logical psum runs staged over this mesh
    (``core.distributed._psum_staged`` with ``axis=("grp", "loc")``):
    reduce within each group of ``loc`` co-located devices, then across
    the ``groups`` groups — nested replica groups instead of one flat
    n-device ring.  ``groups`` defaults to the largest power of two with
    groups² ≤ n that divides n (8 → 2×4, 512 → 16×32); for n = 1 the
    mesh degenerates to (1, 1) and the staged psum is a no-op pair.
    """
    n = n_devices or len(jax.devices())
    if groups is None:
        groups = 1
        while groups * 2 * groups * 2 <= n and n % (groups * 2) == 0:
            groups *= 2
    if n % groups:
        raise ValueError(f"groups={groups} does not divide n={n}")
    return _mesh((groups, n // groups), ("grp", "loc"))


def make_local_mesh():
    """Whatever this host has — used by tests and the quickstart."""
    n = len(jax.devices())
    if n == 1:
        return _mesh((1, 1), ("data", "model"))
    m = 2 if n % 2 == 0 else 1
    return _mesh((n // m, m), ("data", "model"))
