"""HLO text analysis: collective-op byte accounting for the roofline.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but no collective
traffic; we parse the (SPMD-partitioned) HLO and sum the result-shape
bytes of every collective op, bucketed by kind.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

__all__ = ["collective_bytes", "count_ops", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Bytes moved per collective kind (result-shape accounting, per
    device).  Start/done pairs are counted once (on the -start)."""
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        lhs, rhs = ls.split(" = ", 1)
        for kind in COLLECTIVES:
            # match the opcode at the start of the RHS expression only
            m = re.match(
                r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+(%?)("
                + kind + r")(-start)?\(", rhs)
            if m is None:
                continue
            if f"{kind}-done" in rhs:
                break
            for dt, dims in _SHAPE_RE.findall(m.group(1)):
                out[kind] += _shape_bytes(dt, dims)
            break
    return dict(out)


def count_ops(hlo_text: str, opcodes=("fusion", "dot", "convolution")
              ) -> Dict[str, int]:
    out = {k: 0 for k in opcodes}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if " = " not in ls:
            continue
        rhs = ls.split(" = ", 1)[1]
        for k in opcodes:
            if re.search(r"\b" + k + r"\(", rhs):
                out[k] += 1
    return out
