"""Architecture registry — one module per assigned architecture."""
from importlib import import_module

from repro.models.config import ModelConfig, reduced

ARCHS = [
    "tinyllama_1_1b",
    "codeqwen1_5_7b",
    "gemma_2b",
    "chatglm3_6b",
    "deepseek_v2_236b",
    "dbrx_132b",
    "xlstm_1_3b",
    "zamba2_7b",
    "whisper_large_v3",
    "qwen2_vl_72b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    mod = name.replace("-", "_").replace(".", "_")
    mod = _ALIAS.get(name, mod)
    return import_module(f"repro.configs.{mod}").CONFIG


def list_archs():
    return list(ARCHS)


__all__ = ["ARCHS", "get_config", "list_archs", "ModelConfig", "reduced"]
