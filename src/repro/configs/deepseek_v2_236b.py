"""DeepSeek-V2 236B — MLA (kv_lora 512) + 2 shared / 160 routed experts
top-6 [arXiv:2405.04434]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288, vocab=102400,
    n_experts=160, n_shared_experts=2, top_k=6, d_ff_expert=1536,
    kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    head_dim=192,  # qk_nope + qk_rope
    mlp_type="swiglu", rope_type="full", rope_theta=10_000.0,
    tie_embeddings=False,
)
