"""TinyLlama-1.1B — llama2-architecture small model [arXiv:2401.02385]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632, vocab=32000,
    mlp_type="swiglu", rope_type="full", rope_theta=10_000.0,
    tie_embeddings=False,
)
