"""ChatGLM3-6B — 2d-RoPE (half-dim rotary), GQA kv=2 [arXiv:2406.12793]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab=65024,
    mlp_type="swiglu", rope_type="half", rope_theta=10_000.0,
    tie_embeddings=False,
)
