"""xLSTM-1.3B — mLSTM + sLSTM blocks (7:1) [arXiv:2405.04517]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_every=8, lstm_proj_factor=2, ssm_chunk=64,
    rope_type="none", tie_embeddings=False,
)
