"""DBRX-132B — 16 experts top-4, fine-grained MoE [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352,
    n_experts=16, n_shared_experts=0, top_k=4, d_ff_expert=10752,
    mlp_type="swiglu", rope_type="full", rope_theta=500_000.0,
    tie_embeddings=False,
)
