"""Whisper-large-v3 — enc-dec; conv frontend is a stub (precomputed frame
embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab=51866,
    is_encdec=True, encoder_layers=32, encoder_seq=1500,
    frontend="audio_stub",
    mlp_type="gelu", rope_type="none", tie_embeddings=True,
)
