"""Qwen2-VL-72B backbone — M-RoPE, patch frontend stubbed
[arXiv:2409.12191]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064,
    mlp_type="swiglu", rope_type="mrope", rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="patch_stub", tie_embeddings=False,
)
