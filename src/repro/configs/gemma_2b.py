"""Gemma-2B — GeGLU, head_dim 256, MQA [arXiv:2403.08295]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000,
    mlp_type="geglu", rope_type="full", rope_theta=10_000.0,
    scale_embedding=True, tie_embeddings=True,
)
