"""CodeQwen1.5-7B — qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=13440, vocab=92416,
    mlp_type="swiglu", rope_type="full", rope_theta=1_000_000.0,
    tie_embeddings=False,
)
