"""Fig. 8/11 analogue: multi-device scaling of distributed PBNG.

One physical core backs all host devices here, so wall-clock speedup is
not observable; we report the *structural* scaling quantities instead:
per-device work (link-shard size, FD partitions per device) and the
synchronization count, which is device-count-invariant — exactly the
property that gave the paper its 19.7× on real cores.  Wall time is
reported for completeness.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import emit

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = """
import json, time
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.graph import powerlaw_bipartite
from repro.core.beindex import build_beindex
from repro.core.distributed import distributed_wing_decomposition
n = {n_dev}
mesh = Mesh(np.array(jax.devices()).reshape(n), ("peel",))
g = powerlaw_bipartite(300, 150, 1400, seed=4)
be = build_beindex(g)
t0 = time.time()
theta, stats = distributed_wing_decomposition(g, mesh, P_parts=32, be=be)
dt = time.time() - t0
stats.update(wall_s=dt, links_per_dev=-(-be.n_links // n),
             theta_sum=int(theta.sum()))
print(json.dumps(stats))
"""


_SCRIPT_CSR = """
import json, time
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.graph import powerlaw_bipartite
from repro.core.distributed import distributed_wing_decomposition
n = {n_dev}
mesh = Mesh(np.array(jax.devices()).reshape(n), ("peel",))
g = powerlaw_bipartite(300, 150, 1400, seed=4)
out = {{}}
for pal in (False, True):
    t0 = time.time()
    theta, stats = distributed_wing_decomposition(
        g, mesh, P_parts=32, engine="csr", pair_aligned=pal)
    stats.update(wall_s=time.time() - t0, theta_sum=int(theta.sum()))
    out["pal" if pal else "wedge"] = stats
assert out["pal"]["theta_sum"] == out["wedge"]["theta_sum"]
print(json.dumps(out))
"""


_SCRIPT_TIP = """
import json, time
import numpy as np, jax
from jax.sharding import Mesh
from repro.core.graph import powerlaw_bipartite
from repro.core.distributed import distributed_tip_decomposition
n = {n_dev}
mesh = Mesh(np.array(jax.devices()).reshape(n), ("peel",))
g = powerlaw_bipartite(300, 150, 1400, seed=4)
out = {{}}
for aligned in (False, True):
    t0 = time.time()
    theta, stats = distributed_tip_decomposition(
        g, mesh, side="u", P_parts=32, engine="csr", aligned=aligned)
    stats.update(wall_s=time.time() - t0, theta_sum=int(theta.sum()))
    out["aligned" if aligned else "rr"] = stats
assert out["aligned"]["theta_sum"] == out["rr"]["theta_sum"]
print(json.dumps(out))
"""


_SCRIPT_HIER = """
import json, time
import numpy as np, jax
from repro.core.graph import powerlaw_bipartite
from repro.core.distributed import distributed_wing_decomposition
from repro.launch.mesh import make_peel_mesh_2d
n = {n_dev}
mesh2 = make_peel_mesh_2d(n)
g = powerlaw_bipartite(300, 150, 1400, seed=4)
t0 = time.time()
theta, stats = distributed_wing_decomposition(
    g, mesh2, axis=("grp", "loc"), P_parts=32, engine="csr",
    pair_aligned=True)
stats.update(wall_s=time.time() - t0, theta_sum=int(theta.sum()),
             groups=int(mesh2.devices.shape[0]),
             loc=int(mesh2.devices.shape[1]))
print(json.dumps(stats))
"""


def run(small: bool = True):
    devs = (1, 4) if small else (1, 2, 4, 8, 16)
    base = None
    tip_base = None
    for n in devs:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_SCRIPT.format(n_dev=n))],
            env=env, capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        stats = json.loads(out.stdout.strip().splitlines()[-1])
        if base is None:
            base = stats["theta_sum"]
        assert stats["theta_sum"] == base, "device count changed results!"
        emit(f"scaling.wing.dev{n}", stats["wall_s"],
             rho_cd=stats["rho_cd"], links_per_dev=stats["links_per_dev"],
             parts_per_dev=-(-stats["n_parts"] // n))
        # csr CD sharding A/B: round-robin wedge shards (two psums per
        # round) vs pair-aligned shards (ONE psum) — report.py renders
        # the cd.pair_aligned/wedge ratio row from these
        out = subprocess.run(
            [sys.executable, "-c",
             textwrap.dedent(_SCRIPT_CSR.format(n_dev=n))],
            env=env, capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        both = json.loads(out.stdout.strip().splitlines()[-1])
        emit(f"scaling.wing.dev{n}.csr", both["wedge"]["wall_s"],
             rho_cd=both["wedge"]["rho_cd"], psums_per_round=2,
             cd_sharding="wedge")
        emit(f"scaling.wing.dev{n}.csr_pal", both["pal"]["wall_s"],
             rho_cd=both["pal"]["rho_cd"], psums_per_round=1,
             cd_sharding="pair_aligned")
        # hierarchical-collective A/B: the SAME one logical psum staged
        # over a 2-D ("grp", "loc") mesh — two all-reduces with nested
        # replica groups vs the flat ring (groups degenerate to 1 below
        # 4 devices).  On forced host devices the staging is pure
        # overhead; the row certifies theta-invariance and tracks the
        # structural cost.  report.py renders cd.hier/flat from these.
        out = subprocess.run(
            [sys.executable, "-c",
             textwrap.dedent(_SCRIPT_HIER.format(n_dev=n))],
            env=env, capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        hier = json.loads(out.stdout.strip().splitlines()[-1])
        assert hier["theta_sum"] == both["pal"]["theta_sum"], \
            "hierarchical mesh changed results!"
        emit(f"scaling.wing.dev{n}.csr_pal_hier", hier["wall_s"],
             rho_cd=hier["rho_cd"], psums_per_round=1,
             staged_allreduces=2, cd_sharding="pair_aligned",
             mesh=f"{hier['groups']}x{hier['loc']}")
        # tip csr CD sharding A/B: round-robin vs vertex-aligned pair
        # entries — both pay ONE psum per round (pair butterflies are
        # static), so the A/B isolates the greedy balance; report.py
        # renders the cd.aligned/roundrobin ratio row from these
        out = subprocess.run(
            [sys.executable, "-c",
             textwrap.dedent(_SCRIPT_TIP.format(n_dev=n))],
            env=env, capture_output=True, text=True, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        tips = json.loads(out.stdout.strip().splitlines()[-1])
        if tip_base is None:
            tip_base = tips["rr"]["theta_sum"]
        assert tips["rr"]["theta_sum"] == tip_base, \
            "device count changed tip results!"
        emit(f"scaling.tip.dev{n}.tip_csr", tips["rr"]["wall_s"],
             rho_cd=tips["rr"]["rho_cd"], psums_per_round=1,
             cd_sharding="pair", side="u")
        emit(f"scaling.tip.dev{n}.tip_aligned", tips["aligned"]["wall_s"],
             rho_cd=tips["aligned"]["rho_cd"], psums_per_round=1,
             cd_sharding="vertex_aligned", side="u")


if __name__ == "__main__":
    run(small=False)
