"""Table 3 reproduction: wing decomposition — execution time, support
updates, and synchronization rounds (ρ) for PBNG vs the baselines.

Baselines at container scale:
  * BUP          — sequential bottom-up peeling (pure-python oracle)
  * LevelSync    — level-synchronous parallel peeling with BE-Index
                   updates = ParButterfly's structure (ρ = #levels
                   cascaded, one sync per round)
  * PBNG         — two-phased (beindex engine, the faithful repro)
  * PBNG-dense   — beyond-paper TPU formulation (masked MXU recounts)
  * PBNG-csr     — sparse wedge-list engine (segment_sum incremental
                   updates; the only engine that scales past O(n²))
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import ref
from repro.core.beindex import build_beindex
from repro.core.graph import paper_proxy_dataset
from repro.core.peel import (_wing_update, wing_decomposition,
                             wing_decomposition_bepc)

from .common import emit, timed


def levelsync_wing(g, be):
    """ParButterfly-equivalent: peel min-support level each round."""
    m = g.m
    le, lt, lb = (jnp.asarray(be.link_edge), jnp.asarray(be.link_twin),
                  jnp.asarray(be.link_bloom))
    nb = max(be.nb, 1)
    alive_link = jnp.ones((be.n_links,), bool)
    k_alive = jnp.asarray(be.bloom_k.astype(np.int32))
    support = jnp.asarray(be.edge_support(m).astype(np.int32))
    sup = np.asarray(support).astype(np.int64)
    alive = np.ones(m, bool)
    theta = np.zeros(m, np.int64)
    k = 0
    rho = 0
    updates = 0
    while alive.any():
        k = max(k, int(sup[alive].min()))
        while True:
            S = alive & (sup <= k)
            if not S.any():
                break
            theta[S] = k
            alive &= ~S
            alive_link, k_alive, support, nu = _wing_update(
                jnp.asarray(S), alive_link, k_alive, support,
                le, lt, lb, nb, m)
            updates += int(nu)
            sup = np.asarray(support).astype(np.int64)
            rho += 1
    return theta, rho, updates


def run(small: bool = True):
    names = ["di_af", "fr", "di_st"] if small else [
        "di_af", "de_ti", "fr", "di_st", "it", "digg"]
    for name in names:
        g = paper_proxy_dataset(name)
        be = build_beindex(g)

        res, t_pbng = timed(
            wing_decomposition, g, P=16, engine="beindex", be=be)
        s = res.stats

        (theta_ls, rho_ls, upd_ls), t_ls = timed(levelsync_wing, g, be)
        assert np.array_equal(theta_ls, res.theta), name

        _, t_dense = timed(wing_decomposition, g, P=16, engine="dense")

        # csr engine, device-resident FD (one while_loop per partition)
        # vs the host-loop FD baseline — the two-phase speedup row.
        # repeat=2 so best-of excludes one-time while_loop compilation:
        # the A/B isolates steady-state dispatch/transfer overhead.
        res_csr, t_csr = timed(
            wing_decomposition, g, P=16, engine="csr", repeat=2)
        assert np.array_equal(res_csr.theta, res.theta), name
        res_csr_h, t_csr_h = timed(
            wing_decomposition, g, P=16, engine="csr", fd_driver="host",
            repeat=2)
        assert np.array_equal(res_csr_h.theta, res.theta), name

        # single-dispatch Phase 2: ALL partitions in ONE while_loop.
        # The honest three-way FD A/B (report.py renders the ratio rows
        # fd.device/host and fd.vmapped/device from these).
        res_csr_v, t_csr_v = timed(
            wing_decomposition, g, P=16, engine="csr",
            fd_driver="vmapped", repeat=2)
        assert np.array_equal(res_csr_v.theta, res.theta), name
        assert res_csr_v.stats.rho_fd_total == res_csr.stats.rho_fd_total

        (theta_pc, st_pc), t_pc = timed(wing_decomposition_bepc, g)
        assert np.array_equal(theta_pc, res.theta), name

        emit(f"wing.{name}.pbng", t_pbng,
             updates=s.updates, rho_sync=s.rho_cd,
             fd_critical=s.rho_fd_max, parts=s.p_effective,
             sync_reduction=round(s.sync_reduction, 1))
        emit(f"wing.{name}.levelsync(ParB)", t_ls,
             updates=upd_ls, rho=rho_ls,
             sync_reduction=round(rho_ls / max(s.rho_cd, 1), 1))
        emit(f"wing.{name}.pbng_dense", t_dense, engine="dense")
        sc = res_csr.stats
        emit(f"wing.{name}.pbng_csr", t_csr, engine="csr",
             updates=sc.updates, rho_sync=sc.rho_cd,
             sync_reduction=round(sc.sync_reduction, 1),
             fd_driver="device",
             speedup_vs_hostfd=round(t_csr_h / max(t_csr, 1e-9), 2))
        emit(f"wing.{name}.pbng_csr_hostfd", t_csr_h, engine="csr",
             rho_sync=res_csr_h.stats.rho_cd,
             sync_reduction=round(res_csr_h.stats.sync_reduction, 1),
             fd_driver="host")
        emit(f"wing.{name}.pbng_csr_vmapped", t_csr_v, engine="csr",
             fd_driver="vmapped",
             rho_fd_max=res_csr_v.stats.rho_fd_max,
             vs_device=round(t_csr_v / max(t_csr, 1e-9), 2))
        emit(f"wing.{name}.be_pc", t_pc, recounts=st_pc.recounts,
             kind="top-down-baseline")
        if g.m <= 3000:
            _, t_bup = timed(ref.bup_wing_ref, g)
            emit(f"wing.{name}.bup", t_bup, kind="sequential-oracle")

    # ---- in-loop Pallas support_update A/B (one synthetic graph: the
    # kernel runs in interpret mode on CPU, so the paper proxies would
    # dominate the smoke budget; parity is what the row certifies, the
    # compiled-kernel speed story lives on TPU)
    from repro.core.graph import powerlaw_bipartite

    gp = powerlaw_bipartite(60, 40, 260, seed=7)
    res_v, t_v = timed(
        wing_decomposition, gp, P=6, engine="csr", fd_driver="vmapped",
        repeat=2)
    res_vp, t_vp = timed(
        wing_decomposition, gp, P=6, engine="csr", fd_driver="vmapped",
        use_pallas=True, repeat=2)
    assert np.array_equal(res_vp.theta, res_v.theta)
    assert res_vp.stats.updates == res_v.stats.updates
    emit("wing.pl60.pbng_csr_vmapped", t_v, engine="csr",
         fd_driver="vmapped")
    emit("wing.pl60.pbng_csr_vmapped_pallas", t_vp, engine="csr",
         fd_driver="vmapped", fd_update="pallas",
         note="interpret-mode;compiled-on-TPU-target")

    # fused-round A/B: the whole FD round body as ONE pallas_call
    # (kernels/fd_round.py) vs the unfused driver.  Same caveat as the
    # in-loop kernel row: on CPU the kernel interprets (slower), the
    # row certifies bit-parity; the dispatch-latency story is the
    # accelerator target.  report.py renders fd.fused/unfused.
    res_f, t_f = timed(
        wing_decomposition, gp, P=6, engine="csr", fd_driver="vmapped",
        fused=True, repeat=2)
    assert np.array_equal(res_f.theta, res_v.theta)
    assert res_f.stats.updates == res_v.stats.updates
    assert res_f.stats.rho_fd_max == res_v.stats.rho_fd_max
    emit("wing.pl60.pbng_csr_vmapped_fused", t_f, engine="csr",
         fd_driver="vmapped", fd_round="fused",
         vs_unfused=round(t_f / max(t_v, 1e-9), 2),
         note="interpret-mode;compiled-on-TPU-target")


if __name__ == "__main__":
    run(small=False)
