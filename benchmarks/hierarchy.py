"""Hierarchy subsystem benchmark: forest build time + batched query
throughput (the serving-path numbers the ROADMAP north star asks for).

Rows:
  * ``hier.<ds>.build``    — θ → packed forest (batched label-propagation
    components + host assembly), best-of-2 so one-time jit compilation
    of the while_loop kernel is excluded.
  * ``hier.<ds>.query50k`` — 50k mixed queries (max_k / node_of / LCA /
    LCA-level / subtree-size) answered by :class:`HierarchyService` in
    4096-slot batches; ``qps`` is the headline (target ≥ 10k/s on the
    smoke graph, trivially exceeded on real hardware).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import paper_proxy_dataset
from repro.core.peel import wing_decomposition
from repro.hierarchy import HierarchyService, build_hierarchy

from .common import emit, timed

N_QUERIES = 50_000
BATCH = 4096


def run(small: bool = True):
    names = ["fr"] if small else ["fr", "di_af", "digg"]
    for name in names:
        g = paper_proxy_dataset(name)
        res, _ = timed(wing_decomposition, g, P=16, engine="csr")

        h, t_build = timed(build_hierarchy, g, res, repeat=2)
        emit(f"hier.{name}.build", t_build,
             nodes=h.n_nodes, levels=int(h.levels.size), m=g.m)

        svc = HierarchyService(h, batch=BATCH)
        rng = np.random.default_rng(0)
        ops = rng.integers(0, 5, N_QUERIES).astype(np.int32)
        a = rng.integers(0, g.m, N_QUERIES).astype(np.int32)
        b = rng.integers(0, g.m, N_QUERIES).astype(np.int32)
        a = np.where(ops == 4, a % h.n_nodes, a)  # subtree_size takes a node

        def serve_all():
            for i in range(0, N_QUERIES, BATCH):
                svc.query_batch(ops[i:i + BATCH], a[i:i + BATCH],
                                b[i:i + BATCH])

        _, t_q = timed(serve_all, repeat=2)  # best-of-2 excludes compile
        qps = N_QUERIES / max(t_q, 1e-9)
        emit(f"hier.{name}.query50k", t_q,
             qps=int(qps), batch=BATCH, n_queries=N_QUERIES)
        if qps < 10_000:
            print(f"[bench] WARNING: hierarchy qps {qps:.0f} below the "
                  "10k/s smoke target", flush=True)


if __name__ == "__main__":
    run(small=False)
