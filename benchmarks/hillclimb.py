"""§Perf hillclimb driver.

Each iteration = (cell, hypothesis, cfg overrides).  Re-derives the
roofline terms with the override applied and appends a structured record
(hypothesis → change → before → after → verdict) to
experiments/perf/hillclimb.json.

Run AFTER the baseline roofline sweep:
    PYTHONPATH=src python -m benchmarks.hillclimb
"""
from __future__ import annotations

import os

if __name__ == "__main__":  # before first jax init
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))

import json  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PERF = os.path.join(ROOT, "experiments", "perf")
BASE = os.path.join(ROOT, "experiments", "roofline", "results.json")

# (cell, tag, hypothesis, overrides, expected-effect-field)
ITERATIONS = [
    # ---- cell A: attention-dominated causal prefill -----------------
    ("codeqwen1_5_7b", "prefill_32k", "causal_skip",
     "causal attention computes the full S² block grid; skipping the "
     "upper-triangular kv blocks should cut attention FLOPs ~2x -> "
     "compute term down ~30-45% on this attention-heavy 32k prefill",
     dict(attn_causal_skip=True)),
    ("codeqwen1_5_7b", "prefill_32k", "causal_skip+bq1024",
     "smaller q/kv blocks tighten the diagonal waste of block-causal "
     "skipping (finer triangle) at slightly worse MXU utilization; "
     "expect a further few % off the compute term",
     dict(attn_causal_skip=True, attn_block_q=1024, attn_block_k=1024)),
    # ---- cell B: memory-bound train cell ----------------------------
    ("tinyllama_1_1b", "train_4k", "dots_remat",
     "full remat recomputes every matmul in the bwd pass (8ND vs 6ND); "
     "saving dot outputs should cut the compute term ~25% and bytes "
     "~15-20% at higher live memory",
     dict(remat_policy="dots")),
    ("tinyllama_1_1b", "train_4k", "dots+chunked_loss",
     "the [b,s,32k-vocab] logits+softmax dominates temp bytes; chunked "
     "CE (512-token chunks) should cut bytes_accessed and temp memory "
     "with no FLOP change",
     dict(remat_policy="dots", loss_chunk=512)),
    ("tinyllama_1_1b", "train_4k", "dots+chunk+causal_skip",
     "stack all three exact levers; expect compounded compute+memory "
     "drop",
     dict(remat_policy="dots", loss_chunk=512, attn_causal_skip=True)),
    # ---- cell C: MLA decode (representative of deepseek's mechanism) --
    ("deepseek_v2_236b", "decode_32k", "mla_absorb",
     "naive MLA decode re-expands the compressed cache to k_nope/v "
     "[b,S,H,128] every step (O(S·lora·H·(dn+dv)) flops + bytes); "
     "absorbing W_uk into q and W_uv into the output acts on the "
     "compressed cache directly -> expect ~100x fewer attention flops "
     "and an order of magnitude off the memory term",
     dict(mla_absorb=True)),
    # ---- cell D: most collective-bound — xlstm decode ----------------
    ("xlstm_1_3b", "decode_32k", "shard_state_dim",
     "xlstm has only 4 heads, so the [G,M,B,4,1024,1024] matrix memory "
     "cannot shard over model=16 and is replicated -> every step "
     "all-reduces the full state.  Sharding the 1024-wide feature dim "
     "over model instead should collapse the collective term by ~16x",
     dict(shard_state_dim=True)),
    # ---- cell E: worst roofline fraction — whisper train -------------
    ("whisper_large_v3", "train_4k", "chunk+dots",
     "whisper train is the worst-fraction cell (useful 0.35, memory "
     "bound): the 51866-vocab logits over 4096 tokens dominate bytes "
     "and full remat doubles matmul work; chunked CE + dots policy "
     "should cut memory and compute terms together",
     dict(remat_policy="dots", loss_chunk=512)),
    # ---- round 2 on whisper: decoder self-attn is causal -------------
    ("whisper_large_v3", "train_4k", "chunk+dots+causal_skip",
     "whisper's decoder self-attention is causal (encoder/cross are "
     "not): block-skipping there should shave the remaining compute "
     "term a further ~10-15% on top of chunk+dots",
     dict(remat_policy="dots", loss_chunk=512, attn_causal_skip=True)),
    # ---- round 3: sequence parallelism on the prefill cell -----------
    ("codeqwen1_5_7b", "prefill_32k", "causal_skip+seq_shard",
     "with 32k-token activations, sharding the sequence dim over "
     "'model' at layer boundaries (SP) splits norm/residual bytes 16x; "
     "attention must re-gather seq, so collectives should rise — net "
     "memory win if bytes drop > collective growth",
     dict(attn_causal_skip=True, seq_shard=True)),
]


def main():
    from repro.launch.roofline import roofline_cell

    os.makedirs(PERF, exist_ok=True)
    out_path = os.path.join(PERF, "hillclimb.json")
    results = []
    if os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["arch"], r["shape"], r["tag"]) for r in results}

    base = {}
    if os.path.exists(BASE):
        for r in json.load(open(BASE)):
            if r["status"] == "ok":
                base[(r["arch"], r["shape"])] = r

    cache = {}
    for arch, shape, tag, hypothesis, ov in ITERATIONS:
        if (arch, shape, tag) in done:
            continue
        rec = roofline_cell(arch, shape, use_cache=cache,
                            extra_overrides=ov, tag=tag)
        rec["hypothesis"] = hypothesis
        rec["overrides"] = {k: str(v) for k, v in ov.items()}
        b = base.get((arch, shape))
        if b and rec["status"] == "ok":
            rec["delta"] = {
                k: round(rec[k] / max(b[k], 1e-30) - 1, 4)
                for k in ("t_compute_s", "t_memory_s", "t_collective_s")
            }
            print(f"[hillclimb] {arch} {shape} {tag}: "
                  f"compute {b['t_compute_s']:.2e}->"
                  f"{rec['t_compute_s']:.2e} "
                  f"mem {b['t_memory_s']:.2e}->{rec['t_memory_s']:.2e} "
                  f"coll {b['t_collective_s']:.2e}->"
                  f"{rec['t_collective_s']:.2e}", flush=True)
        results.append(rec)
        with open(out_path, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
