"""Benchmark-regression comparator — CI's ``bench-compare`` gate.

Compares a fresh ``BENCH_*.json`` against the committed baseline
(``benchmarks/baselines/BENCH_csr.json``) and hard-fails when any *hot*
row slowed down by more than ``--threshold`` (default 1.3×).

Hot rows are the ones big enough to measure reliably on a shared CI
runner: ``us_per_call`` of the baseline must exceed ``--min-us``
(default 10 ms).  Single processes can vary >1.5x from scheduler /
allocator noise, so both sides of the gate are **best-of-N across
processes**: pass several fresh JSONs (CI runs the smoke bench three
times) and the per-row minimum is compared; the committed baseline is
itself a min-merge.  New rows are reported but never fail the gate;
missing hot rows do.  Baseline rows flagged ``gate: true`` (latency
percentiles from ``common.emit_latency``, e.g. ``serve.p99.t8``) are
gated even below the hot floor — a tail SLO stated over many samples
is stable where a single sub-floor timing is noise.

A hot baseline row missing from the fresh output also fails the gate —
renaming or dropping a benchmark must go through a baseline refresh, or
the gate silently stops watching that row.

Refreshing the baseline: the committed file should come from the same
machine class the gate runs on.  Download CI's ``bench-json-<sha>``
artifact from a green bench-smoke run and commit it (a laptop-timed
baseline skews every ratio by the machine-speed difference); CI skips
the gate when the commit message contains ``[bench-reset]``.

``--normalize NAME`` divides every row by row NAME of its own run
before comparing — a machine-independent mode (at the cost of the
normalizer row's noise, and blind to regressions in the normalizer row
itself) for baselines that cannot come from CI.  Such baselines must be
written with ``--write-merged ... --normalize NAME`` so both sides of
the gate are min-of-per-run-ratios; min-merging raw microseconds and
normalizing afterwards mixes minima from different runs and biases
every ratio low.
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINE = "benchmarks/baselines/BENCH_csr.json"


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["us_per_call"]) for r in payload["rows"]}


def load_gates(path: str) -> set:
    """Names of baseline rows carrying ``gate: true`` — latency-SLO rows
    (``common.emit_latency``) that must stay gated even below the
    ``--min-us`` hot floor: a p99 over many samples is stable where a
    single sub-floor timing is noise."""
    with open(path) as f:
        payload = json.load(f)
    return {r["name"] for r in payload["rows"] if r.get("gate")}


def check_provenance(path: str) -> None:
    """Warn (never fail) when a baseline lacks the ``source_sha``
    header ``common.write_bench`` stamps — an untraceable baseline
    can't be re-derived when its rows come under dispute."""
    with open(path) as f:
        payload = json.load(f)
    sha = payload.get("source_sha")
    if not sha or sha == "unknown":
        print(f"WARNING: baseline {path} has no source_sha header — "
              "refresh it from a BENCH_*.json produced by the current "
              "benchmarks/common.py to record which commit it measured")


def min_merge(paths, normalize: str = "", with_src: bool = False):
    """Per-row minimum across several runs of the same bench — best-of-N
    across *processes*, the only statistic stable enough to gate on when
    single runs can vary >1.5x from scheduler/allocator noise.

    With ``normalize``, every run's rows are first divided by that
    run's OWN normalizer row (each process is its own clock), and the
    minimum is taken over the *ratios*.  Normalizing the min-merge
    instead would let one fast outlier sample of the normalizer row
    inflate every other row's ratio and fail the gate spuriously.

    ``with_src=True`` additionally returns ``{name: path}`` of the run
    that achieved each row's minimum (the argmin run's full row dict is
    what ``--write-merged`` archives, so derived stats stay consistent
    with the timing they rode in with)."""
    merged: dict = {}
    src: dict = {}
    for path in paths:
        rows = load_rows(path)
        if normalize:
            if normalize not in rows:
                raise SystemExit(
                    f"normalizer row '{normalize}' missing from {path}")
            scale = 1.0 / max(rows[normalize], 1e-9)
            rows = {n: us * scale for n, us in rows.items()}
        for name, us in rows.items():
            if us < merged.get(name, float("inf")):
                merged[name] = us
                src[name] = path
    if with_src:
        return merged, src
    return merged


def compare(
    baseline: dict, new: dict, threshold: float, min_us: float,
    normalize: str = "", gated: set = frozenset(),
) -> int:
    """``new`` rows must already be in normalizer units when
    ``normalize`` is set (see :func:`min_merge`); the baseline converts
    here with its OWN normalizer row.  Hotness (``min_us``) always
    checks the baseline's raw microseconds; rows in ``gated`` (baseline
    rows flagged ``gate: true``) are hot regardless of the floor."""
    base_norm = 1.0
    if normalize:
        if normalize not in baseline:
            print(f"normalizer row '{normalize}' missing from baseline")
            return 1
        base_norm = max(baseline[normalize], 1e-9)
        print(f"normalizing by {normalize}: per-run ratios, displayed in "
              "baseline-equivalent us")
    regressions = []
    width = max((len(n) for n in baseline), default=4)
    print(f"{'name':<{width}}  {'base_us':>12}  {'new_us':>12}  {'ratio':>6}")
    for name in sorted(baseline):
        base = baseline[name]
        if name not in new:
            hot = base >= min_us or name in gated
            flag = "  << MISSING HOT ROW" if hot else ""
            print(f"{name:<{width}}  {base:>12.1f}  {'MISSING':>12}  "
                  f"{'—':>6}{flag}")
            if hot:
                regressions.append((name, base, float("nan"), float("nan")))
            continue
        cur = new[name] * base_norm if normalize else new[name]
        ratio = cur / max(base, 1e-9)
        hot = base >= min_us or name in gated
        flag = ""
        if hot and ratio > threshold:
            flag = "  << REGRESSION"
            regressions.append((name, base, cur, ratio))
        elif not hot:
            flag = "  (cold: skipped)"
        elif base < min_us:
            flag = "  (gated: latency SLO)"
        print(f"{name:<{width}}  {base:>12.1f}  {cur:>12.1f}  "
              f"{ratio:>6.2f}{flag}")
    for name in sorted(set(new) - set(baseline)):
        cur = new[name] * base_norm if normalize else new[name]
        print(f"{name:<{width}}  {'NEW':>12}  {cur:>12.1f}  {'—':>6}")
    if regressions:
        print(f"\n{len(regressions)} hot row(s) slower than "
              f"{threshold}x baseline (or missing):")
        for name, base, cur, ratio in regressions:
            print(f"  {name}: {base:.0f}us -> {cur:.0f}us ({ratio:.2f}x)")
        print("If intentional, refresh the baseline and include "
              "[bench-reset] in the commit message.")
        return 1
    print("\nbench-compare: no hot-row regressions ✓")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", nargs="+",
                    help="freshly produced BENCH_*.json file(s); several "
                         "runs are min-merged per row before comparing")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--threshold", type=float, default=1.3,
                    help="fail when new/base exceeds this on a hot row")
    ap.add_argument("--min-us", type=float, default=10_000.0,
                    help="rows faster than this in the baseline are too "
                         "noisy to gate on")
    ap.add_argument("--normalize", default="",
                    help="divide all rows by this row of the same run "
                         "before comparing (machine-independent mode)")
    ap.add_argument("--write-merged", default="", metavar="PATH",
                    help="write the min-merge of the fresh runs to PATH "
                         "in baseline schema (baseline refresh) and exit")
    args = ap.parse_args()
    if args.write_merged:
        # With --normalize the stored us values are min-of-per-run-RATIOS
        # rescaled by the min-merged normalizer, so compare's
        # base/base_norm reproduces exactly the per-run-ratio minimum —
        # a raw min-merge would mix minima from different runs and bias
        # every normalized ratio below 1 (silently loosening the gate).
        merged, src = min_merge(args.new, args.normalize, with_src=True)
        if args.normalize:
            norm_min = min_merge(args.new)[args.normalize]
            merged = {n: r * norm_min for n, r in merged.items()}
        with open(args.new[0]) as f:
            payload = json.load(f)
        # archive each row's derived stats from the run that PRODUCED
        # its minimum — mixing run 1's metadata with run 3's timing
        # would commit internally inconsistent baseline rows
        rows_by_run = {
            p: {r["name"]: r for r in json.load(open(p))["rows"]}
            for p in args.new
        }
        rows = []
        for n in sorted(merged):
            row = dict(rows_by_run[src[n]][n])
            row["us_per_call"] = merged[n]
            rows.append(row)
        payload["rows"] = rows
        payload["note"] = (
            f"min-merge of {len(args.new)} smoke runs"
            + (f", per-run normalized by {args.normalize}"
               if args.normalize else "")
            + " (see benchmarks/compare.py)")
        with open(args.write_merged, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[bench] wrote min-merged baseline -> {args.write_merged}")
        return 0
    check_provenance(args.baseline)
    return compare(
        load_rows(args.baseline), min_merge(args.new, args.normalize),
        args.threshold, args.min_us, args.normalize,
        gated=load_gates(args.baseline),
    )


if __name__ == "__main__":
    sys.exit(main())
