"""Streaming-update benchmark: incremental repair vs full re-peel.

Per dataset and micro-epoch batch size B, the same synthesized event
sequence is consumed twice from the same initial decomposition:

  * ``streaming.repair.b<B>.<ds>`` — :class:`repro.streaming.StreamState`
    epochs (wedge-local ⋈init delta, full CD, FD re-run on the dirty
    partitions only, dirty-level hierarchy repair);
  * ``streaming.full.b<B>.<ds>``   — from-scratch re-peel of the same
    materialized graph each epoch (global butterfly recount +
    ``wing_decomposition`` + ``build_hierarchy``).

Both rows are the **mean epoch time over E epochs** after a full
warmup pass over the identical per-epoch graph shapes, so jit
compilation (which both paths pay equally and only once per shape) is
excluded and the steady-state compute is what's compared.  The repair
row carries ``speedup`` (full/repair) and the mean dirty fractions as
derived fields; the win condition is small batches — at B=1 most
partitions and hierarchy levels are clean and repair skips their FD
launches and label recomputes entirely, while the full re-peel pays
everything every epoch.  Rows are ``gate: true``: epoch times are
means over E epochs, stable enough to gate below the hot floor.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.graph import paper_proxy_dataset
from repro.core.peel import wing_decomposition
from repro.hierarchy import build_hierarchy
from repro.streaming import StreamConfig, StreamState, make_random_events

from .common import emit, note_telemetry

P_PARTS = 16
# per-batch (epochs, event seed): B=1 runs a longer fixed window whose
# deterministic event sequence exercises BOTH regimes — epochs whose
# blast radius stays in low partitions (levels_dirty=0, FD re-runs one
# partition) and epochs that dirty everything; dirty_frac /
# levels_dirty_frac on the row show the split
PROFILES = {1: (6, 7), 8: (3, 8000), 64: (3, 64000)}


def _sequences(g0, cfg, epochs: int, batch: int, seed: int):
    """Synthesize the epoch event lists + materialized graphs once (the
    warmup pass for the repair path), so both timed variants replay
    byte-identical inputs."""
    st = StreamState.initial(g0, cfg)
    events, graphs = [], []
    for e in range(epochs):
        ev = make_random_events(st.g, batch, seed=seed + e)
        st.apply_epoch(ev)
        events.append(ev)
        graphs.append(st.g)
    return events, graphs


def _bench_one(ds: str, g0, batch: int):
    epochs, seed = PROFILES[batch]
    cfg = StreamConfig(kind="wing", engine="csr", P=P_PARTS,
                       fd_driver="device")
    events, graphs = _sequences(g0, cfg, epochs, batch, seed)

    # full-repeel warmup: same shapes as the timed pass below
    for g in graphs:
        res = wing_decomposition(g, P=P_PARTS, engine="csr")
        build_hierarchy(g, res)

    # ---- timed: incremental repair (fresh state, warm jit caches)
    st = StreamState.initial(g0, cfg)
    reps = []
    t_rep = 0.0
    for ev in events:
        t0 = time.perf_counter()
        rep = st.apply_epoch(ev)
        t_rep += time.perf_counter() - t0
        reps.append(rep)
    t_rep /= epochs

    # ---- timed: from-scratch re-peel of the same materialized graphs
    t_full = 0.0
    for g in graphs:
        t0 = time.perf_counter()
        res = wing_decomposition(g, P=P_PARTS, engine="csr")
        build_hierarchy(g, res)
        t_full += time.perf_counter() - t0
    t_full /= epochs

    dirty = float(np.mean([r.partitions_dirty / max(r.p_eff, 1)
                           for r in reps]))
    lv_dirty = float(np.mean([r.levels_dirty / max(r.levels_total, 1)
                              for r in reps]))
    emit(f"streaming.repair.b{batch}.{ds}", t_rep, gate=True,
         speedup=round(t_full / max(t_rep, 1e-9), 2),
         dirty_frac=round(dirty, 3), levels_dirty_frac=round(lv_dirty, 3),
         epochs=epochs, m=g0.m)
    emit(f"streaming.full.b{batch}.{ds}", t_full, gate=True,
         epochs=epochs, m=g0.m)
    note_telemetry(f"streaming.repair.b{batch}.{ds}", dict(
        metrics=st.metrics.snapshot(),
        epochs=[r.as_dict() for r in reps]))
    return t_rep, t_full


def run(small: bool = True):
    names = ["fr"] if small else ["fr", "di_af"]
    batches = (1, 8, 64)
    for ds in names:
        g0 = paper_proxy_dataset(ds)
        for b in batches:
            t_rep, t_full = _bench_one(ds, g0, b)
            if b == batches[0] and t_rep >= t_full:
                print(f"[bench] WARNING: streaming repair at B={b} "
                      f"({t_rep * 1e3:.0f}ms) did not beat full re-peel "
                      f"({t_full * 1e3:.0f}ms) on {ds}", flush=True)


if __name__ == "__main__":
    run(small=True)
