"""Butterfly counting throughput (alg.1 analogue): numpy oracle vs jnp
dense matmul vs the Pallas kernel (interpret mode on this container)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import counting, ref
from repro.core.graph import powerlaw_bipartite
from repro.kernels import ops

from .common import emit, timed


def run(small: bool = True):
    sizes = [(200, 100, 1000)] if small else [
        (200, 100, 1000), (600, 300, 4000), (1200, 600, 9000)]
    for n_u, n_v, m in sizes:
        g = powerlaw_bipartite(n_u, n_v, m, seed=7)
        A = jnp.asarray(g.adjacency())

        (bu, _), t_ref = timed(ref.vertex_butterflies_ref, g)
        out, t_jnp = timed(
            lambda: np.asarray(counting.vertex_butterflies(A)), repeat=3)
        out_k, t_kern = timed(
            lambda: np.asarray(ops.vertex_butterflies(A, interpret=True)),
            repeat=1)
        assert np.array_equal(np.rint(out).astype(np.int64), bu)
        assert np.array_equal(np.rint(out_k).astype(np.int64), bu)
        emit(f"count.{n_u}x{n_v}.oracle", t_ref)
        emit(f"count.{n_u}x{n_v}.jnp_mxu", t_jnp,
             speedup=round(t_ref / max(t_jnp, 1e-9), 1))
        emit(f"count.{n_u}x{n_v}.pallas_interp", t_kern,
             note="interpret-mode;compiled-on-TPU-target")


if __name__ == "__main__":
    run(small=False)
