"""Butterfly counting throughput (alg.1 analogue) across density regimes.

Engines compared per graph:
  * oracle        — pure-python/numpy reference
  * dense (jnp)   — MXU matmul formulation (O(n²) memory)
  * dense (pallas)— fused vertex-count kernel (interpret mode here)
  * csr (segsum)  — flat wedge list + ``segment_sum`` (O(Σ deg²) memory)
  * csr (pallas)  — per-pair reduction in the blocked wedge-count kernel

Sparse/medium/dense rows make the crossover visible: dense matmuls win on
small dense graphs, the wedge list wins as soon as n² outruns Σ deg².
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import counting, csr, ref
from repro.core.graph import powerlaw_bipartite
from repro.kernels import ops

from .common import emit, timed


def run(small: bool = True):
    # (n_u, n_v, avg_deg) — sparse / medium / dense per size
    regimes = [(200, 100, 5), (200, 100, 20)] if small else [
        (200, 100, 5), (200, 100, 20), (200, 100, 60),
        (600, 300, 7), (600, 300, 25),
        (1200, 600, 8), (1200, 600, 30),
    ]
    for n_u, n_v, avg in regimes:
        m = min(n_u * avg, n_u * n_v)
        g = powerlaw_bipartite(n_u, n_v, m, seed=7)
        tag = f"count.{n_u}x{n_v}.d{avg}"
        A = jnp.asarray(g.adjacency())

        # repeat=3: the oracle row doubles as bench-compare's --normalize
        # reference, so its noise multiplies into every gated ratio
        (bu, _), t_ref = timed(ref.vertex_butterflies_ref, g, repeat=3)
        out, t_jnp = timed(
            lambda: np.asarray(counting.vertex_butterflies(A)), repeat=3)
        out_k, t_kern = timed(
            lambda: np.asarray(ops.vertex_butterflies(A, interpret=True)),
            repeat=2)
        assert np.array_equal(np.rint(out).astype(np.int64), bu)
        assert np.array_equal(np.rint(out_k).astype(np.int64), bu)

        wed, t_build = timed(csr.build_wedges, g, repeat=3)
        out_c, t_csr = timed(lambda: csr.vertex_butterflies_csr(wed), repeat=3)
        assert np.array_equal(out_c, bu)

        be_ref = ref.edge_butterflies_ref(g)
        out_e, t_ecsr = timed(
            lambda: np.asarray(csr.edge_butterflies_csr(wed)), repeat=3)
        assert np.array_equal(out_e.astype(np.int64), be_ref)
        out_ep, t_epal = timed(
            lambda: np.asarray(
                csr.edge_butterflies_csr(wed, use_pallas=True, interpret=True)
            ),
            repeat=2)
        assert np.array_equal(out_ep.astype(np.int64), be_ref)

        emit(f"{tag}.oracle", t_ref, wedges=wed.n_wedges, pairs=wed.n_pairs)
        emit(f"{tag}.dense_mxu", t_jnp,
             speedup=round(t_ref / max(t_jnp, 1e-9), 1))
        emit(f"{tag}.dense_pallas", t_kern,
             note="interpret-mode;compiled-on-TPU-target")
        emit(f"{tag}.csr_build", t_build)
        emit(f"{tag}.csr_vertex", t_csr,
             speedup=round(t_ref / max(t_csr, 1e-9), 1))
        emit(f"{tag}.csr_edge_segsum", t_ecsr)
        emit(f"{tag}.csr_edge_pallas", t_epal,
             note="interpret-mode;compiled-on-TPU-target")


if __name__ == "__main__":
    from .common import write_bench

    run(small=False)
    write_bench("BENCH_csr.json")
