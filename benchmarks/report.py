"""Render EXPERIMENTS.md §Dry-run / §Roofline / §Benchmark tables from
the JSON results written by repro.launch.dryrun, repro.launch.roofline
and benchmarks.run (``BENCH_*.json``).

    PYTHONPATH=src python -m benchmarks.report [BENCH_csr.json ...]

The §Benchmarks section renders EVERY row of the given bench files —
including the FD/CD A/B ratio rows whose names contain ``/`` (e.g.
``wing.fr.fd.device/host``): a ``/`` in a row name is a ratio label,
not a path separator, and must never be filtered or split.  When no
bench file is passed, the committed baseline
(``benchmarks/baselines/BENCH_csr.json``) is rendered.
"""
from __future__ import annotations

import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun", "results.json")
ROOF = os.path.join(ROOT, "experiments", "roofline", "results.json")
BASELINE = os.path.join(ROOT, "benchmarks", "baselines", "BENCH_csr.json")

# A/B pairs synthesized from sibling time rows: (suffix_a, suffix_b,
# ratio label, name-prefix families the pair is benchmarked in).  The
# label becomes "<common prefix>.<label>" — names that deliberately
# contain '/' so a < 1.0 ratio reads "a is faster".  The families scope
# the half-missing-sibling 'n/a' marker to rows where the sibling is
# SUPPOSED to exist: a variant a family never benchmarks by design
# (e.g. tip has no hostfd row) must not drown real dropped-sibling gaps
# in structural noise.
AB_PAIRS = [
    ("pbng_csr", "pbng_csr_hostfd", "fd.device/host", ("wing.",)),
    ("pbng_csr_vmapped", "pbng_csr", "fd.vmapped/device",
     ("wing.", "tip.")),
    ("pbng_csr_vmapped_pallas", "pbng_csr_vmapped", "fd.pallas/segsum",
     ("wing.pl",)),
    ("csr", "csr_hostfd", "fd.device/host", ("psweep.",)),
    ("csr_vmapped", "csr", "fd.vmapped/device", ("psweep.",)),
    ("csr_pal", "csr", "cd.pair_aligned/wedge", ("scaling.",)),
    ("csr_pal_hier", "csr_pal", "cd.hier/flat", ("scaling.",)),
    ("tip_aligned", "tip_csr", "cd.aligned/roundrobin", ("scaling.",)),
    ("pbng_csr_vmapped_fused", "pbng_csr_vmapped", "fd.fused/unfused",
     ("wing.pl", "tip.pl")),
    ("csr_vmapped_fused", "csr_vmapped", "fd.fused/unfused",
     ("psweep.",)),
]


def _fmt(x, unit=""):
    if x is None:
        return "-"
    if abs(x) >= 1e12:
        return f"{x/1e12:.2f}T{unit}"
    if abs(x) >= 1e9:
        return f"{x/1e9:.2f}G{unit}"
    if abs(x) >= 1e6:
        return f"{x/1e6:.2f}M{unit}"
    return f"{x:.3g}{unit}"


def dryrun_table() -> str:
    if not os.path.exists(DRY):
        return "_dry-run results not yet generated_\n"
    rs = json.load(open(DRY))
    lines = [
        "| arch | shape | mesh | status | HLO flops/dev (scan-once) | "
        "bytes/dev | collective B/dev | temp B/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rs, key=lambda r: (r["arch"], r["shape"],
                                       r.get("multi_pod", False))):
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r["status"] == "ok":
            coll = sum(r["collective_bytes"].values())
            tmp = r.get("mem", {}).get("temp_bytes")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
                f"{_fmt(r['flops'])} | {_fmt(r['bytes_accessed'])} | "
                f"{_fmt(coll)} | {_fmt(tmp)} | "
                f"{r['time_compile_s']} |")
        elif r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | "
                f"SKIP ({r['reason'][:60]}…) | - | - | - | - | - |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ERROR | - | - "
                f"| - | - | - |")
    ok = sum(r["status"] == "ok" for r in rs)
    sk = sum(r["status"] == "skipped" for r in rs)
    er = len(rs) - ok - sk
    lines.append("")
    lines.append(f"**{ok} compiled, {sk} documented skips, {er} errors** "
                 f"(skips = long_500k on pure full-attention archs, "
                 f"per DESIGN.md §4).")
    return "\n".join(lines) + "\n"


def roofline_table() -> str:
    if not os.path.exists(ROOF):
        return "_roofline results not yet generated_\n"
    rs = json.load(open(ROOF))
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | "
        "bottleneck | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{r['t_compute_s']:.2e}s | {r['t_memory_s']:.2e}s | "
                f"{r['t_collective_s']:.2e}s | **{r['bottleneck']}** | "
                f"{r['useful_flop_ratio']:.2f} | "
                f"{r['roofline_fraction']:.2f} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"skip | - | - |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"ERROR | - | - |")
    return "\n".join(lines) + "\n"


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.1f}ms"
    return f"{us:.0f}us"


def _escape(name: str) -> str:
    """Markdown-table safety: only '|' breaks a cell.  '/' is a legal
    row-name character (A/B ratio rows) and renders verbatim."""
    return name.replace("|", "\\|")


def ab_rows(rows: dict) -> list:
    """Synthesize the A/B ratio rows from sibling time rows.

    For every configured (a, b) suffix pair present with a common
    prefix — e.g. ``wing.fr.pbng_csr`` / ``wing.fr.pbng_csr_hostfd`` —
    emit ``(prefix.label, ratio)`` where ratio = t_a / t_b (< 1.0 means
    the numerator variant is faster).  A prefix where only ONE side of
    the pair exists still emits its row, with ratio ``None`` — the
    renderer marks it ``n/a`` so a dropped/renamed sibling is a visible
    gap in the report instead of a silently missing ratio."""
    out = []
    seen = set()
    for name, us in sorted(rows.items()):
        for suf_a, suf_b, label, families in AB_PAIRS:
            if not name.startswith(families):
                continue
            if name.endswith("." + suf_a):
                prefix = name[: -len(suf_a) - 1]
            elif name.endswith("." + suf_b):
                prefix = name[: -len(suf_b) - 1]
            else:
                continue
            key = f"{prefix}.{label}"
            if key in seen:
                continue
            seen.add(key)
            t_a = rows.get(f"{prefix}.{suf_a}")
            t_b = rows.get(f"{prefix}.{suf_b}")
            if t_a is not None and t_b is not None and t_b > 0:
                out.append((key, t_a / t_b))
            else:
                out.append((key, None))
    return out


def bench_table(paths: list) -> str:
    """§Benchmarks: every row of the bench JSONs (min-merged across
    files), then the synthesized A/B ratio rows.  No row is skipped —
    names containing '/' are ratio labels and render verbatim."""
    rows: dict = {}
    derived: dict = {}
    for path in paths:
        if not os.path.exists(path):
            return f"_bench results not found: {path}_\n"
        payload = json.load(open(path))
        for r in payload["rows"]:
            us = float(r["us_per_call"])
            if us < rows.get(r["name"], float("inf")):
                rows[r["name"]] = us
                derived[r["name"]] = {
                    k: v for k, v in r.items()
                    if k not in ("name", "us_per_call")
                }
    lines = ["| row | best-of time | derived |", "|---|---|---|"]
    for name in sorted(rows):
        extra = " ".join(f"{k}={v}" for k, v in derived[name].items())
        lines.append(
            f"| {_escape(name)} | {_fmt_us(rows[name])} | {extra} |")
    ab = ab_rows(rows)
    if ab:
        lines.append("")
        lines.append("### A/B ratios (t_a / t_b — < 1.0 ⇒ a faster)")
        lines.append("")
        lines.append("| a/b | ratio |")
        lines.append("|---|---|")
        for name, ratio in ab:
            cell = "n/a (pair side missing)" if ratio is None \
                else f"{ratio:.2f}"
            lines.append(f"| {_escape(name)} | {cell} |")
    return "\n".join(lines) + "\n"


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline\n")
    print(roofline_table())
    print("\n## §Benchmarks\n")
    print(bench_table(argv if argv else [BASELINE]))


if __name__ == "__main__":
    main()
