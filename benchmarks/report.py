"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the JSON
results written by repro.launch.dryrun / repro.launch.roofline.

    PYTHONPATH=src python -m benchmarks.report
"""
from __future__ import annotations

import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun", "results.json")
ROOF = os.path.join(ROOT, "experiments", "roofline", "results.json")


def _fmt(x, unit=""):
    if x is None:
        return "-"
    if abs(x) >= 1e12:
        return f"{x/1e12:.2f}T{unit}"
    if abs(x) >= 1e9:
        return f"{x/1e9:.2f}G{unit}"
    if abs(x) >= 1e6:
        return f"{x/1e6:.2f}M{unit}"
    return f"{x:.3g}{unit}"


def dryrun_table() -> str:
    if not os.path.exists(DRY):
        return "_dry-run results not yet generated_\n"
    rs = json.load(open(DRY))
    lines = [
        "| arch | shape | mesh | status | HLO flops/dev (scan-once) | "
        "bytes/dev | collective B/dev | temp B/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rs, key=lambda r: (r["arch"], r["shape"],
                                       r.get("multi_pod", False))):
        mesh = "2×16×16" if r.get("multi_pod") else "16×16"
        if r["status"] == "ok":
            coll = sum(r["collective_bytes"].values())
            tmp = r.get("mem", {}).get("temp_bytes")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
                f"{_fmt(r['flops'])} | {_fmt(r['bytes_accessed'])} | "
                f"{_fmt(coll)} | {_fmt(tmp)} | "
                f"{r['time_compile_s']} |")
        elif r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | "
                f"SKIP ({r['reason'][:60]}…) | - | - | - | - | - |")
        else:
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ERROR | - | - "
                f"| - | - | - |")
    ok = sum(r["status"] == "ok" for r in rs)
    sk = sum(r["status"] == "skipped" for r in rs)
    er = len(rs) - ok - sk
    lines.append("")
    lines.append(f"**{ok} compiled, {sk} documented skips, {er} errors** "
                 f"(skips = long_500k on pure full-attention archs, "
                 f"per DESIGN.md §4).")
    return "\n".join(lines) + "\n"


def roofline_table() -> str:
    if not os.path.exists(ROOF):
        return "_roofline results not yet generated_\n"
    rs = json.load(open(ROOF))
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | "
        "bottleneck | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | "
                f"{r['t_compute_s']:.2e}s | {r['t_memory_s']:.2e}s | "
                f"{r['t_collective_s']:.2e}s | **{r['bottleneck']}** | "
                f"{r['useful_flop_ratio']:.2f} | "
                f"{r['roofline_fraction']:.2f} |")
        elif r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"skip | - | - |")
        else:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"ERROR | - | - |")
    return "\n".join(lines) + "\n"


def main():
    print("## §Dry-run\n")
    print(dryrun_table())
    print("\n## §Roofline\n")
    print(roofline_table())


if __name__ == "__main__":
    main()
