"""Shared benchmark utilities.

Timing rows ride in ``ROWS`` (``emit`` / ``write_bench``); latency
*distributions* go through :func:`emit_latency`, which records exact
p50/p99 over the raw samples (``repro.obs.percentiles``) and emits the
p99 as the row's gated value — tail latency is what a serving SLO is
stated on, so ``compare.py`` gates it like any other hot row (rows
carry ``gate: true`` to stay gated below the ``--min-us`` floor).

Per-row *telemetry* (cache counters, dispatch histograms, peel
timelines) rides in a separate ``TELEMETRY`` channel —
``note_telemetry`` + ``write_telemetry`` — so the BENCH_*.json schema
the regression gate parses stays pure timings.  The observability
layer itself stays OFF during timed sections: telemetry here is
read from metric registries after the clock stops.
"""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Callable, Dict, Iterable, List

ROWS: List[Dict] = []
TELEMETRY: Dict[str, Dict] = {}


def source_sha() -> str:
    """Best-effort git HEAD of the tree that produced the rows — rides
    in the BENCH_*.json header so a committed baseline is traceable to
    the code it measured (compare.py warns when it is absent)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, seconds: float, **derived):
    ROWS.append(dict(name=name, us_per_call=seconds * 1e6, **derived))
    extra = " ".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{seconds * 1e6:.1f},{extra}", flush=True)


def emit_latency(name: str, samples: Iterable[float], gate: bool = True,
                 **derived):
    """Emit a latency-distribution row from raw per-call seconds.

    ``us_per_call`` is the exact p99 (the SLO number — gate the tail,
    not the mean); p50/p99/count ride as derived fields.  ``gate=True``
    marks the row for ``compare.py`` to gate even below its
    ``--min-us`` hot floor (percentiles over many samples are stable
    where single sub-floor timings are noise)."""
    from repro.obs import percentiles

    arr = [float(s) for s in samples]
    ps = percentiles(arr, ps=(50.0, 99.0))
    emit(name, ps["p99"], gate=bool(gate),
         p50_us=ps["p50"] * 1e6, p99_us=ps["p99"] * 1e6,
         n_samples=len(arr), **derived)


def note_telemetry(name: str, payload: Dict) -> None:
    """Attach a JSON-able telemetry blob (metrics snapshot, timeline
    summary) to bench row ``name``; written by :func:`write_telemetry`,
    never parsed by the regression gate."""
    TELEMETRY[name] = payload


def write_telemetry(path: str) -> None:
    """Dump the per-row telemetry channel next to the BENCH json (CI
    uploads both under the same artifact)."""
    with open(path, "w") as f:
        json.dump(dict(schema=1, source_sha=source_sha(),
                       telemetry=TELEMETRY), f, indent=1)
    print(f"[bench] wrote telemetry for {len(TELEMETRY)} rows -> {path}",
          flush=True)


def write_bench(path: str) -> None:
    """Dump every row emitted so far as a BENCH_*.json artifact.

    CI's benchmark-smoke job uploads these so the perf trajectory
    accumulates across commits."""
    import jax

    payload = dict(
        schema=1,
        backend=jax.default_backend(),
        python=platform.python_version(),
        jax=jax.__version__,
        source_sha=source_sha(),
        rows=ROWS,
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench] wrote {len(ROWS)} rows -> {path}", flush=True)


def datasets(small_only: bool = False):
    names = ["di_af", "fr", "di_st"] if small_only else [
        "di_af", "de_ti", "fr", "di_st", "it", "digg"]
    return names
