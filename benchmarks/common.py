"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time
from typing import Callable, Dict, List

ROWS: List[Dict] = []


def source_sha() -> str:
    """Best-effort git HEAD of the tree that produced the rows — rides
    in the BENCH_*.json header so a committed baseline is traceable to
    the code it measured (compare.py warns when it is absent)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=root,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, seconds: float, **derived):
    ROWS.append(dict(name=name, us_per_call=seconds * 1e6, **derived))
    extra = " ".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{seconds * 1e6:.1f},{extra}", flush=True)


def write_bench(path: str) -> None:
    """Dump every row emitted so far as a BENCH_*.json artifact.

    CI's benchmark-smoke job uploads these so the perf trajectory
    accumulates across commits."""
    import jax

    payload = dict(
        schema=1,
        backend=jax.default_backend(),
        python=platform.python_version(),
        jax=jax.__version__,
        source_sha=source_sha(),
        rows=ROWS,
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench] wrote {len(ROWS)} rows -> {path}", flush=True)


def datasets(small_only: bool = False):
    names = ["di_af", "fr", "di_st"] if small_only else [
        "di_af", "de_ti", "fr", "di_st", "it", "digg"]
    return names
