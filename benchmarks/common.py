"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

ROWS: List[Dict] = []


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, seconds: float, **derived):
    ROWS.append(dict(name=name, us_per_call=seconds * 1e6, **derived))
    extra = " ".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{seconds * 1e6:.1f},{extra}", flush=True)


def datasets(small_only: bool = False):
    names = ["di_af", "fr", "di_st"] if small_only else [
        "di_af", "de_ti", "fr", "di_st", "it", "digg"]
    return names
