"""Shared benchmark utilities."""
from __future__ import annotations

import json
import platform
import time
from typing import Callable, Dict, List

ROWS: List[Dict] = []


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def emit(name: str, seconds: float, **derived):
    ROWS.append(dict(name=name, us_per_call=seconds * 1e6, **derived))
    extra = " ".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{seconds * 1e6:.1f},{extra}", flush=True)


def write_bench(path: str) -> None:
    """Dump every row emitted so far as a BENCH_*.json artifact.

    CI's benchmark-smoke job uploads these so the perf trajectory
    accumulates across commits."""
    import jax

    payload = dict(
        schema=1,
        backend=jax.default_backend(),
        python=platform.python_version(),
        jax=jax.__version__,
        rows=ROWS,
    )
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench] wrote {len(ROWS)} rows -> {path}", flush=True)


def datasets(small_only: bool = False):
    names = ["di_af", "fr", "di_st"] if small_only else [
        "di_af", "de_ti", "fr", "di_st", "it", "digg"]
    return names
