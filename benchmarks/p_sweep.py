"""Fig. 5 reproduction: execution time vs number of partitions P.

The paper's claim: runtime is robust (within ~2× of optimal) across a
wide range of P; small P starves FD parallelism, large P adds CD rounds.
"""
from __future__ import annotations

from repro.core.graph import paper_proxy_dataset
from repro.core.peel import wing_decomposition

from .common import emit, timed


def run(small: bool = True):
    name = "fr"
    g = paper_proxy_dataset(name)
    ps = (2, 8, 32) if small else (1, 2, 4, 8, 16, 32, 64, 128)
    for P in ps:
        res, t = timed(wing_decomposition, g, P=P, engine="beindex")
        s = res.stats
        emit(f"psweep.{name}.P{P}", t, rho_cd=s.rho_cd,
             rho_fd_max=s.rho_fd_max, parts=s.p_effective,
             updates=s.updates)


if __name__ == "__main__":
    run(small=False)
