"""Fig. 5 reproduction: execution time vs number of partitions P.

The paper's claim: runtime is robust (within ~2× of optimal) across a
wide range of P; small P starves FD parallelism, large P adds CD rounds.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import paper_proxy_dataset, powerlaw_bipartite
from repro.core.peel import wing_decomposition

from .common import emit, timed


def run(small: bool = True):
    name = "fr"
    g = paper_proxy_dataset(name)
    ps = (2, 8, 32) if small else (1, 2, 4, 8, 16, 32, 64, 128)
    for P in ps:
        res, t = timed(wing_decomposition, g, P=P, engine="beindex")
        s = res.stats
        emit(f"psweep.{name}.P{P}", t, rho_cd=s.rho_cd,
             rho_fd_max=s.rho_fd_max, parts=s.p_effective,
             updates=s.updates)
        # csr engine at the same P: device-resident FD vs host-loop FD —
        # the sync-reduction claim with the engine's OWN rho (not the
        # beindex run's), plus the wall-clock win of the while_loop FD
        res_d, t_d = timed(wing_decomposition, g, P=P, engine="csr",
                           repeat=2)
        res_h, t_h = timed(
            wing_decomposition, g, P=P, engine="csr", fd_driver="host",
            repeat=2)
        res_v, t_v = timed(
            wing_decomposition, g, P=P, engine="csr",
            fd_driver="vmapped", repeat=2)
        sd = res_d.stats
        emit(f"psweep.{name}.P{P}.csr", t_d, rho_cd=sd.rho_cd,
             rho_fd_max=sd.rho_fd_max,
             sync_reduction=round(sd.sync_reduction, 1),
             fd_driver="device",
             speedup_vs_hostfd=round(t_h / max(t_d, 1e-9), 2))
        emit(f"psweep.{name}.P{P}.csr_hostfd", t_h,
             rho_cd=res_h.stats.rho_cd, fd_driver="host")
        # the P-sensitivity of the single-dispatch FD: lock-step cost
        # grows with partition-drain imbalance, dispatch savings with P
        emit(f"psweep.{name}.P{P}.csr_vmapped", t_v,
             rho_fd_max=res_v.stats.rho_fd_max, fd_driver="vmapped",
             vs_device=round(t_v / max(t_d, 1e-9), 2))
    # fused round P-sensitivity: per-round dispatch tail goes to zero,
    # so the sweep isolates pure lock-step padding cost.  Measured on
    # the pl60 proxy, NOT fr — the kernel interprets on CPU (orders
    # slower; fr-scale wedge lists blow the smoke-time budget) and the
    # dispatch story is the accelerator target.  Parity asserted per P;
    # report.py renders fd.fused/unfused.
    gp = powerlaw_bipartite(60, 40, 260, seed=7)
    for P in ps:
        res_v, t_v = timed(wing_decomposition, gp, P=P, engine="csr",
                           fd_driver="vmapped", repeat=2)
        res_f, t_f = timed(
            wing_decomposition, gp, P=P, engine="csr",
            fd_driver="vmapped", fused=True, repeat=2)
        assert np.array_equal(res_f.theta, res_v.theta)
        assert res_f.stats.rho_fd_max == res_v.stats.rho_fd_max
        emit(f"psweep.pl60.P{P}.csr_vmapped", t_v,
             rho_fd_max=res_v.stats.rho_fd_max, fd_driver="vmapped",
             parts=res_v.stats.p_effective)
        emit(f"psweep.pl60.P{P}.csr_vmapped_fused", t_f,
             fd_driver="vmapped", fd_round="fused",
             vs_unfused=round(t_f / max(t_v, 1e-9), 2),
             note="interpret-mode;compiled-on-TPU-target")


if __name__ == "__main__":
    run(small=False)
