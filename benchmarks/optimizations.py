"""Fig. 6/9 reproduction: effect of the §5 workload optimizations.

Tip: batch re-counting ON vs OFF (PBNG vs PBNG-- analogue) — the paper's
biggest lever.  Wing: BE-Index batched updates (faithful engine) vs
dense re-count per round, measuring support updates applied.
"""
from __future__ import annotations

from repro.core.graph import paper_proxy_dataset
from repro.core.peel import tip_decomposition, wing_decomposition

from .common import emit, timed


def run(small: bool = True):
    names = ["di_af"] if small else ["di_af", "fr", "di_st", "digg"]
    for name in names:
        g = paper_proxy_dataset(name)
        res_a, t_a = timed(tip_decomposition, g, side="u", P=8,
                           batch_recount="adaptive")
        res, t_on = timed(tip_decomposition, g, side="u", P=8,
                          batch_recount=True)
        res_off, t_off = timed(tip_decomposition, g, side="u", P=8,
                               batch_recount=False)
        assert (res.theta == res_off.theta).all()
        assert (res.theta == res_a.theta).all()
        emit(f"opt.tip.{name}.adaptive(PBNG)", t_a,
             recounts=res_a.stats.recounts, updates=res_a.stats.updates)
        emit(f"opt.tip.{name}.always_recount", t_on,
             recounts=res.stats.recounts)
        emit(f"opt.tip.{name}.no_batch(PBNG--)", t_off,
             updates=res_off.stats.updates,
             speedup=round(t_off / max(t_on, 1e-9), 2))

        rw, t_be = timed(wing_decomposition, g, P=8, engine="beindex")
        rd, t_de = timed(wing_decomposition, g, P=8, engine="dense")
        emit(f"opt.wing.{name}.beindex", t_be, updates=rw.stats.updates)
        emit(f"opt.wing.{name}.dense_recount", t_de,
             recounts=rd.stats.recounts)


if __name__ == "__main__":
    run(small=False)
