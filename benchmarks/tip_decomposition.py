"""Table 4 reproduction: tip decomposition — time, traversal work and ρ
for both vertex sets of each proxy dataset, across the dense and csr
engines (the csr rows are the entity-agnostic-core instantiation that
scales past the dense wall; distributed tip csr scaling lives in
``benchmarks.scaling`` as the ``dev{n}.tip_csr``/``.tip_aligned``
A/B)."""
from __future__ import annotations

import numpy as np

from repro.core import ref
from repro.core.graph import paper_proxy_dataset
from repro.core.peel import tip_decomposition

from .common import emit, timed


def run(small: bool = True):
    names = ["di_af", "fr"] if small else [
        "di_af", "fr", "di_st", "it", "digg", "lj"]
    for name in names:
        g = paper_proxy_dataset(name)
        for side in ("u", "v"):
            res, t = timed(tip_decomposition, g, side=side, P=12)
            s = res.stats
            emit(f"tip.{name}{side.upper()}.pbng", t,
                 rho=s.rho_cd + s.rho_fd_max, rho_cd=s.rho_cd,
                 rho_parb=s.rho_fd_total, recounts=s.recounts,
                 side=s.side,
                 sync_reduction=round(s.sync_reduction, 1))

            # csr engine: device-resident FD (one while_loop per
            # partition) vs the single-dispatch vmapped Phase 2 — the
            # same A/B the wing rows carry, now for the tip side of the
            # unified core.  repeat=2 so best-of excludes one-time
            # while_loop compilation.
            res_c, t_c = timed(
                tip_decomposition, g, side=side, P=12, engine="csr",
                repeat=2)
            assert np.array_equal(res_c.theta, res.theta), (name, side)
            res_v, t_v = timed(
                tip_decomposition, g, side=side, P=12, engine="csr",
                fd_driver="vmapped", repeat=2)
            assert np.array_equal(res_v.theta, res.theta), (name, side)
            assert res_v.stats.rho_fd_total == res_c.stats.rho_fd_total
            sc = res_c.stats
            emit(f"tip.{name}{side.upper()}.pbng_csr", t_c,
                 engine="csr", fd_driver="device", side=sc.side,
                 updates=sc.updates, rho_cd=sc.rho_cd,
                 sync_reduction=round(sc.sync_reduction, 1))
            emit(f"tip.{name}{side.upper()}.pbng_csr_vmapped", t_v,
                 engine="csr", fd_driver="vmapped", side=side,
                 rho_fd_max=res_v.stats.rho_fd_max,
                 vs_device=round(t_v / max(t_c, 1e-9), 2))
            if g.m <= 3000:
                _, t_bup = timed(ref.bup_tip_ref, g, side)
                emit(f"tip.{name}{side.upper()}.bup", t_bup,
                     kind="sequential-oracle")

    # fused-round A/B on one synthetic graph (same rationale as the
    # wing pl60 rows: the kernel interprets on CPU, so the row
    # certifies bit-parity; the zero-dispatch win is the accelerator
    # story).
    from repro.core.graph import powerlaw_bipartite

    gp = powerlaw_bipartite(60, 40, 260, seed=7)
    res_v, t_v = timed(
        tip_decomposition, gp, side="u", P=6, engine="csr",
        fd_driver="vmapped", repeat=2)
    res_f, t_f = timed(
        tip_decomposition, gp, side="u", P=6, engine="csr",
        fd_driver="vmapped", fused=True, repeat=2)
    assert np.array_equal(res_f.theta, res_v.theta)
    assert res_f.stats.rho_fd_max == res_v.stats.rho_fd_max
    emit("tip.pl60U.pbng_csr_vmapped", t_v, engine="csr",
         fd_driver="vmapped", side="u")
    emit("tip.pl60U.pbng_csr_vmapped_fused", t_f, engine="csr",
         fd_driver="vmapped", side="u", fd_round="fused",
         vs_unfused=round(t_f / max(t_v, 1e-9), 2),
         note="interpret-mode;compiled-on-TPU-target")


if __name__ == "__main__":
    run(small=False)
