"""Table 4 reproduction: tip decomposition — time, traversal work and ρ
for both vertex sets of each proxy dataset."""
from __future__ import annotations

import numpy as np

from repro.core import ref
from repro.core.graph import paper_proxy_dataset
from repro.core.peel import tip_decomposition

from .common import emit, timed


def run(small: bool = True):
    names = ["di_af", "fr"] if small else [
        "di_af", "fr", "di_st", "it", "digg", "lj"]
    for name in names:
        g = paper_proxy_dataset(name)
        for side in ("u", "v"):
            res, t = timed(tip_decomposition, g, side=side, P=12)
            s = res.stats
            emit(f"tip.{name}{side.upper()}.pbng", t,
                 rho=s.rho_cd + s.rho_fd_max, rho_cd=s.rho_cd,
                 rho_parb=s.rho_fd_total, recounts=s.recounts,
                 sync_reduction=round(s.sync_reduction, 1))
            if g.m <= 3000:
                _, t_bup = timed(ref.bup_tip_ref, g, side)
                emit(f"tip.{name}{side.upper()}.bup", t_bup,
                     kind="sequential-oracle")


if __name__ == "__main__":
    run(small=False)
