"""Real-graph benchmark: out-of-core ingest + bounded-tile counting.

The rows pin the real-data path end to end on the committed KONECT
graph (``datasets/southern_women.tsv``) and quantify the bounded-memory
claim on a synthetic graph big enough for tiling to matter:

  * ``count.real.sw.ingest``  — chunked ingest (cache bypassed with
    ``refresh=True`` so the parse/dedup/relabel is what's timed);
  * ``count.real.sw.tiled``   — ``csr.tiled_butterfly_init`` at a small
    wedge budget (many tiles on purpose);
  * ``count.real.sw.untiled`` — the flat wedge-list counts
    (``build_wedges`` + edge/vertex butterflies), the exactness
    reference the tiled counts are asserted equal to;
  * ``peel.real.sw.wing``     — the sup0-injected wing peel, with the
    θ sha256 asserted against ``tests/goldens/real_graphs.json`` — a
    bench run that drifts from the golden FAILS, it does not emit;
  * ``count.tiled.pl.b<B>``   — synthetic powerlaw sweep: derived
    fields carry ``peak_tile_wedges`` (asserted ≤ budget + one
    vertex's own wedges), ``peak_slot_bytes`` vs ``full_wedge_bytes``
    (the memory the untiled path would need), and the tile count.

``main()`` adds the nightly mode: ``--download southern_women``
fetches the KONECT original into ``~/.cache/repro-datasets`` (one
network hit, then cached), ingests it and asserts the SAME committed
checksums — proving the committed copy and the upstream dataset reduce
to the bit-identical decomposition.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile

import numpy as np

from repro.core import csr
from repro.core.graph import powerlaw_bipartite
from repro.core.peel import wing_decomposition
from repro.data import ingest_edges

from .common import emit, note_telemetry, timed

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
DATASET = os.path.join(ROOT, "datasets", "southern_women.tsv")
GOLDENS = os.path.join(ROOT, "tests", "goldens", "real_graphs.json")

# name -> (KONECT tarball URL, member file inside it)
KONECT = {
    "southern_women": (
        "http://konect.cc/files/download.tsv.brunson_southern-women.tar.bz2",
        "brunson_southern-women/out.brunson_southern-women",
    ),
}


def _sha(theta) -> str:
    return hashlib.sha256(
        np.asarray(theta, dtype=np.int64).tobytes()).hexdigest()


def _golden(name: str) -> dict:
    with open(GOLDENS) as f:
        return json.load(f)[name]


def _assert_golden(name: str, path: str, tile_wedges: int = 64) -> None:
    """Ingest + tile-count + peel ``path`` and fail loudly unless every
    committed invariant for ``name`` holds."""
    want = _golden(name)
    with tempfile.TemporaryDirectory() as td:
        ig = ingest_edges(path, out_dir=os.path.join(td, "ing"))
        got = (ig.n_u, ig.n_v, ig.m)
        expect = (want["n_u"], want["n_v"], want["m"])
        assert got == expect, f"{name}: dims {got} != golden {expect}"
        sup_e, _, total, _ = csr.tiled_butterfly_init(
            ig, tile_wedges=tile_wedges)
        assert total == want["total_butterflies"], (
            f"{name}: total {total} != golden {want['total_butterflies']}")
        res = wing_decomposition(ig.as_graph(), engine="csr", sup0=sup_e)
        got_sha = _sha(res.theta)
        assert got_sha == want["theta_wing_sha256"], (
            f"{name}: theta sha {got_sha} != golden")


def _bench_real(small: bool) -> None:
    name = "sw"
    want = _golden("southern_women")
    with tempfile.TemporaryDirectory() as td:
        ing_dir = os.path.join(td, "ing")
        # warm once (also the correctness pass), then time the real work
        ig = ingest_edges(DATASET, out_dir=ing_dir)
        ig, t_ing = timed(ingest_edges, DATASET, out_dir=ing_dir,
                          refresh=True, repeat=3 if small else 5)
        emit(f"count.real.{name}.ingest", t_ing,
             n_u=ig.n_u, n_v=ig.n_v, m=ig.m)

        (sup_e, sup_u, total, stats), t_tiled = timed(
            csr.tiled_butterfly_init, ig, tile_wedges=64,
            repeat=3 if small else 5)
        emit(f"count.real.{name}.tiled", t_tiled, tiles=stats.n_tiles,
             wedges=stats.n_wedges, peak_tile_wedges=stats.peak_tile_wedges)

        def _untiled():
            w = csr.build_wedges(ig.as_graph())
            return w, csr.edge_butterflies0(w), csr.vertex_butterflies_csr(w)

        (w, sup_e0, sup_u0), t_flat = timed(_untiled,
                                            repeat=3 if small else 5)
        emit(f"count.real.{name}.untiled", t_flat, wedges=w.n_wedges)
        assert np.array_equal(sup_e, sup_e0), "tiled != untiled (edges)"
        assert np.array_equal(sup_u, sup_u0), "tiled != untiled (vertices)"
        assert total == want["total_butterflies"], "total drifted"

        g = ig.as_graph()
        res = wing_decomposition(g, engine="csr", sup0=sup_e)  # warm jit
        res, t_peel = timed(wing_decomposition, g, engine="csr",
                            sup0=sup_e, repeat=3 if small else 5)
        theta_sha = _sha(res.theta)
        assert theta_sha == want["theta_wing_sha256"], (
            "peel.real.sw.wing drifted from tests/goldens/real_graphs.json")
        emit(f"peel.real.{name}.wing", t_peel, gate=True,
             rho_cd=res.stats.rho_cd, theta_ok=1)
        note_telemetry(f"peel.real.{name}.wing", dict(
            theta_sha256=theta_sha, total_butterflies=int(total),
            tiles=stats.n_tiles))


def _bench_bounded(small: bool) -> None:
    """The bounded-memory row: tiled counting on a graph whose flat
    wedge list dwarfs any single tile."""
    n_u, n_v, m = (600, 400, 6000) if small else (3000, 2000, 40000)
    g = powerlaw_bipartite(n_u, n_v, m, seed=11)
    w = csr.build_wedges(g)
    # one vertex's own wedges bound how far a singleton hub tile can
    # exceed the budget
    per_u = np.zeros(g.n_u, dtype=np.int64)
    np.add.at(per_u, np.minimum(w.pair_a, w.pair_b)[w.wedge_pair], 1)
    sup_e0 = csr.edge_butterflies0(w)
    full_bytes = int(w.n_wedges) * 8  # int64 wedge keys, the O(Σ deg²) term

    for budget in ((1 << 10,) if small else (1 << 10, 1 << 14)):
        (sup_e, _, _, stats), t = timed(
            csr.tiled_butterfly_init, g, tile_wedges=budget, repeat=3)
        assert np.array_equal(sup_e, sup_e0), "tiled != untiled on powerlaw"
        assert stats.peak_tile_wedges <= budget + int(per_u.max()), (
            f"peak tile {stats.peak_tile_wedges} exceeds budget {budget} "
            f"+ hub max {int(per_u.max())}")
        emit(f"count.tiled.pl.b{budget}", t, gate=True,
             tiles=stats.n_tiles,
             peak_tile_wedges=stats.peak_tile_wedges,
             full_wedge_bytes=full_bytes,
             mem_ratio=round(full_bytes / max(stats.peak_tile_wedges * 8, 1),
                             1))

    # the device-memory claim: the Pallas tile path materializes one
    # padded slot matrix per tile (peak_slot_bytes) and dispatches it
    # one fixed (bp, bk) block at a time — vs the O(Σ deg²) flat list
    budget = 1 << 10
    _ = csr.tiled_butterfly_init(g, tile_wedges=budget, use_pallas=True,
                                 width=128)  # warm jit
    (sup_e, _, _, stats), t = timed(
        csr.tiled_butterfly_init, g, tile_wedges=budget, use_pallas=True,
        width=128, repeat=2)
    assert np.array_equal(sup_e, sup_e0), "pallas tiled != untiled"
    assert stats.peak_slot_bytes < full_bytes, (
        "tiling stopped bounding memory: one tile's slot matrix "
        "outgrew the whole wedge list")
    emit(f"count.tiled.pl.pallas.b{budget}", t, gate=True,
         tiles=stats.n_tiles, peak_slot_bytes=stats.peak_slot_bytes,
         full_wedge_bytes=full_bytes,
         mem_ratio=round(full_bytes / max(stats.peak_slot_bytes, 1), 1))


def run(small: bool = True):
    _bench_real(small)
    _bench_bounded(small)


def _fetch(name: str) -> str:
    """Download + extract the KONECT original into the local cache,
    returning the edge-list path (a no-op when already cached)."""
    import tarfile
    import urllib.request

    url, member = KONECT[name]
    cache = os.path.join(os.path.expanduser("~"), ".cache",
                         "repro-datasets")
    os.makedirs(cache, exist_ok=True)
    dest = os.path.join(cache, os.path.basename(member))
    if os.path.exists(dest):
        print(f"[real] cached: {dest}", flush=True)
        return dest
    tar_path = os.path.join(cache, os.path.basename(url))
    if not os.path.exists(tar_path):
        print(f"[real] downloading {url}", flush=True)
        urllib.request.urlretrieve(url, tar_path)
    with tarfile.open(tar_path, "r:bz2") as tf:
        with tf.extractfile(member) as src, open(dest, "wb") as out:
            out.write(src.read())
    print(f"[real] extracted -> {dest}", flush=True)
    return dest


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--download", default=None, metavar="NAME",
                    choices=sorted(KONECT),
                    help="nightly mode: fetch the KONECT original into "
                         "~/.cache/repro-datasets and assert the "
                         "committed θ checksums on it (no bench rows)")
    args = ap.parse_args()
    if args.download:
        path = _fetch(args.download)
        _assert_golden(args.download, path)
        print(f"[real] {args.download}: downloaded original matches the "
              f"committed goldens", flush=True)
        return 0
    print("name,us_per_call,derived")
    run(small=not args.full)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
