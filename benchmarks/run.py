"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived``
CSV rows.  --full runs the larger dataset sweeps used for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump rows as a BENCH_*.json artifact")
    args = ap.parse_args()
    small = not args.full

    from . import (counting, hierarchy, optimizations, p_sweep, scaling,
                   tip_decomposition, wing_decomposition)
    mods = dict(
        counting=counting,
        wing=wing_decomposition,
        tip=tip_decomposition,
        hierarchy=hierarchy,
        p_sweep=p_sweep,
        optimizations=optimizations,
        scaling=scaling,
    )
    picks = args.only.split(",") if args.only else list(mods)
    print("name,us_per_call,derived")
    for name in picks:
        mods[name].run(small=small)
    if args.json:
        from .common import write_bench

        write_bench(args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
