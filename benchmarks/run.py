"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived``
CSV rows.  --full runs the larger dataset sweeps used for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys

# registry: declared up front (no heavy imports) so --only can be
# validated before any module is loaded
MODULES = ("counting", "wing", "tip", "hierarchy", "serve",
           "p_sweep", "optimizations", "scaling")

_IMPORTS = dict(
    counting="counting",
    wing="wing_decomposition",
    tip="tip_decomposition",
    hierarchy="hierarchy",
    serve="serve",
    p_sweep="p_sweep",
    optimizations="optimizations",
    scaling="scaling",
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names "
                         f"(choose from: {', '.join(MODULES)})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump rows as a BENCH_*.json artifact")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="dump the per-row telemetry channel (metrics "
                         "snapshots noted by bench modules) as JSON — "
                         "separate from --json so the regression gate's "
                         "schema stays pure timings")
    args = ap.parse_args()
    small = not args.full

    picks = args.only.split(",") if args.only else list(MODULES)
    unknown = [p for p in picks if p not in MODULES]
    if unknown:
        # argparse-style exit 2 with the full menu, instead of a raw
        # KeyError from deep inside the loop after minutes of work
        ap.error(f"unknown --only module(s) {', '.join(sorted(unknown))}; "
                 f"valid names: {', '.join(MODULES)}")

    import importlib

    print("name,us_per_call,derived")
    for name in picks:
        mod = importlib.import_module(f".{_IMPORTS[name]}", __package__)
        mod.run(small=small)
    if args.json:
        from .common import write_bench

        write_bench(args.json)
    if args.telemetry:
        from .common import write_telemetry

        write_telemetry(args.telemetry)
    return 0


if __name__ == "__main__":
    sys.exit(main())
