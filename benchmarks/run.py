"""Benchmark harness — one module per paper table/figure.

``python -m benchmarks.run [--full]`` prints ``name,us_per_call,derived``
CSV rows.  --full runs the larger dataset sweeps used for EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import os
import sys

# registry: declared up front (no heavy imports) so --only can be
# validated before any module is loaded
MODULES = ("counting", "wing", "tip", "hierarchy", "serve", "streaming",
           "real", "p_sweep", "optimizations", "scaling")

_IMPORTS = dict(
    counting="counting",
    real="real_graphs",
    wing="wing_decomposition",
    tip="tip_decomposition",
    hierarchy="hierarchy",
    serve="serve",
    streaming="streaming",
    p_sweep="p_sweep",
    optimizations="optimizations",
    scaling="scaling",
)

_PKG_DIR = os.path.dirname(os.path.abspath(__file__))


def _purge_stale_bytecode() -> None:
    """Drop compiled leftovers whose source module is gone.

    A renamed/deleted bench module leaves artifacts behind: a
    sourceless ``.pyc`` next to the package shadows the import outright
    (the old code silently runs under the new name), and ``__pycache__``
    leftovers make the module *look* present to naive discovery.
    Hygiene runs before any import so ``--only`` always exercises the
    code that is actually in the tree."""
    for d in (_PKG_DIR, os.path.join(_PKG_DIR, "__pycache__")):
        if not os.path.isdir(d):
            continue
        for fn in os.listdir(d):
            if not fn.endswith((".pyc", ".pyo")):
                continue
            src = os.path.join(_PKG_DIR, fn.split(".")[0] + ".py")
            if not os.path.exists(src):
                path = os.path.join(d, fn)
                os.remove(path)
                print(f"[bench] purged stale bytecode {path} "
                      f"(no matching source)", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names "
                         f"(choose from: {', '.join(MODULES)})")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump rows as a BENCH_*.json artifact")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="dump the per-row telemetry channel (metrics "
                         "snapshots noted by bench modules) as JSON — "
                         "separate from --json so the regression gate's "
                         "schema stays pure timings")
    args = ap.parse_args()
    small = not args.full

    picks = args.only.split(",") if args.only else list(MODULES)
    unknown = [p for p in picks if p not in MODULES]
    if unknown:
        # argparse-style exit 2 with the full menu, instead of a raw
        # KeyError from deep inside the loop after minutes of work
        ap.error(f"unknown --only module(s) {', '.join(sorted(unknown))}; "
                 f"valid names: {', '.join(MODULES)}")

    _purge_stale_bytecode()
    # discovery must see the SOURCE, not a compiled leftover: a stale
    # sourceless .pyc imports fine but runs the pre-rename code
    gone = [p for p in picks if not os.path.exists(
        os.path.join(_PKG_DIR, _IMPORTS[p] + ".py"))]
    if gone:
        ap.error(f"module(s) {', '.join(sorted(gone))} have no source "
                 f"file under benchmarks/ (stale bytecode is ignored)")

    import importlib

    print("name,us_per_call,derived")
    for name in picks:
        mod = importlib.import_module(f".{_IMPORTS[name]}", __package__)
        mod.run(small=small)
    if args.json:
        from .common import write_bench

        write_bench(args.json)
    if args.telemetry:
        from .common import write_telemetry

        write_telemetry(args.telemetry)
    return 0


if __name__ == "__main__":
    sys.exit(main())
