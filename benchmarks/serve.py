"""Multi-tenant hierarchy serving benchmark (the traffic-scale story).

Rows:
  * ``serve.mt.t{1,8,64}.q50k`` — 50k mixed-op queries round-robined
    across 1 / 8 / 64 tenants through :class:`MultiTenantService`,
    4096-slot dispatches, best-of-2 (first pass pays the one compile
    per shape bucket; steady-state is what a server sees).  ``qps`` is
    the headline: cross-tenant slot batching should hold throughput
    near the single-tenant ``hier.*.query50k`` line instead of
    dividing it by tenant count.
  * ``serve.p99.t8`` — tail latency: per-dispatch-chunk wall time over
    the 8-tenant workload, emitted as exact p99 (p50 rides as a derived
    field) with ``gate: true`` so ``compare.py`` gates the tail even
    below its hot-row floor.  A serving SLO is a percentile, not a
    mean — the qps rows above can hold steady while p99 regresses.
  * ``serve.load.miss`` — cold tenant admission: versioned npz off
    disk into a free pool slot (v2 artifacts carry the pack cache, so
    this is pure array reads — no O(n) host walk, no retrace).
  * ``serve.load.hit``  — resident-tenant ``ensure``: the LRU-touch
    fast path.
  * ``serve.admit.slot`` / ``serve.admit.bucket`` — admission A/B:
    per-slot ``dynamic_update_slice`` upload into a device-resident
    bucket vs dirtying the bucket and re-uploading the whole stack on
    the next dispatch.  Timed as admit + device-visible; the pool's
    ``pool.admission_upload_ms`` / ``pool.bucket_upload_ms`` metrics
    ride in the telemetry channel as the proof.

Tenants are small powerlaw graphs spread over two shape buckets (the
mixed-bucket case is the expensive one: one dispatch per bucket per
chunk).  64 tenant artifacts cycle 8 distinct decompositions — build
cost is not what this module measures.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.graph import powerlaw_bipartite
from repro.core.peel import wing_decomposition
from repro.hierarchy import (ForestPool, MultiTenantService, build_hierarchy,
                             save_hierarchy)
from repro.hierarchy.serve import OPS

from .common import emit, emit_latency, note_telemetry, timed

N_QUERIES = 50_000
BATCH = 4096
N_TENANTS = 64
DISTINCT = 8


def _artifacts(d):
    hs = []
    for i in range(DISTINCT):
        nu, nv, m = (60, 40, 200) if i % 4 == 3 else (120, 80, 420)
        g = powerlaw_bipartite(nu, nv, m, seed=i)
        hs.append(build_hierarchy(g, wing_decomposition(g, P=4,
                                                        engine="csr")))
    names = [f"t{t:02d}" for t in range(N_TENANTS)]
    for t, name in enumerate(names):
        save_hierarchy(os.path.join(d, f"{name}.npz"), hs[t % DISTINCT])
    return names


def _workload(pool, tenants, n, seed=0):
    rng = np.random.default_rng(seed)
    t_col = [tenants[i % len(tenants)] for i in range(n)]
    ops = rng.integers(0, 5, n).astype(np.int32)
    a = np.zeros(n, np.int32)
    b = np.zeros(n, np.int32)
    sub = OPS["subtree_size"]
    for i, t in enumerate(t_col):
        m = pool.meta[t]
        a[i] = rng.integers(0, m.n_nodes if ops[i] == sub else m.n_entities)
        b[i] = rng.integers(0, m.n_entities)
    return t_col, ops, a, b


def run(small: bool = True):
    with tempfile.TemporaryDirectory(prefix="bench_serve_") as d:
        names = _artifacts(d)

        for n_t in (1, 8, 64):
            pool = ForestPool(slots=N_TENANTS, artifact_dir=d)
            svc = MultiTenantService(pool, batch=BATCH)
            active = names[:n_t]
            for t in active:
                pool.ensure(t)
            tenants, ops, a, b = _workload(pool, active, N_QUERIES)
            _, t_q = timed(svc.query_batch, tenants, ops, a, b,
                           repeat=2)  # best-of-2 excludes per-bucket compile
            qps = N_QUERIES / max(t_q, 1e-9)
            row = f"serve.mt.t{n_t}.q50k"
            emit(row, t_q,
                 qps=int(qps), batch=BATCH, n_queries=N_QUERIES,
                 buckets=len(pool.buckets), dispatches=svc.dispatches // 2)
            note_telemetry(row, svc.metrics.snapshot())

        # tail latency: per-dispatch-chunk samples over the 8-tenant mix
        # (compiles already paid above would pollute the distribution, so
        # a fresh pool warms once before sampling)
        pool = ForestPool(slots=N_TENANTS, artifact_dir=d)
        svc = MultiTenantService(pool, batch=BATCH)
        active = names[:8]
        for t in active:
            pool.ensure(t)
        tenants, ops, a, b = _workload(pool, active, N_QUERIES, seed=1)
        svc.query_batch(tenants[:BATCH], ops[:BATCH], a[:BATCH], b[:BATCH])
        samples = []
        for lo in range(0, N_QUERIES, BATCH):
            hi = min(lo + BATCH, N_QUERIES)
            t0 = time.perf_counter()
            svc.query_batch(tenants[lo:hi], ops[lo:hi], a[lo:hi], b[lo:hi])
            samples.append(time.perf_counter() - t0)
        emit_latency("serve.p99.t8", samples, gate=True,
                     batch=BATCH, n_tenants=8)
        note_telemetry("serve.p99.t8", svc.metrics.snapshot())

        # load latency: admission path (cold, off disk) vs LRU-touch (hot)
        pool = ForestPool(slots=N_TENANTS, artifact_dir=d)
        probe = names[:16]
        t0 = time.perf_counter()
        for t in probe:
            pool.ensure(t)
        t_miss = (time.perf_counter() - t0) / len(probe)
        emit("serve.load.miss", t_miss,
             n_loads=len(probe), format_version=2, pack_cache="v2")
        _, t_hit = timed(pool.ensure, probe[0], repeat=3)
        emit("serve.load.hit", t_hit, **pool.stats())

        # admission A/B: per-slot dynamic_update_slice vs whole-bucket
        # re-upload.  Both sides time admit + device-visible (the bucket
        # must be device-resident before admission for the slot path to
        # exercise the in-place update; `evict` then frees the slot for
        # the next admission without touching device arrays)
        from repro.hierarchy.serialize import load_hierarchy

        probe_h = load_hierarchy(os.path.join(d, f"{names[16]}.npz"))
        for mode, slot_upload in (("slot", True), ("bucket", False)):
            pool = ForestPool(slots=N_TENANTS, artifact_dir=d,
                              slot_upload=slot_upload)
            for t in names[:8]:
                pool.ensure(t)
            for key in list(pool.buckets):
                pool.bucket_arrays(key)       # device-resident baseline

            def _admit_cycle():
                pool.add("probe", probe_h)
                for key in list(pool.buckets):
                    pool.bucket_arrays(key)   # pay any dirty re-upload
                pool.evict("probe")

            _admit_cycle()                    # claim/grow once, off-clock
            _, t_admit = timed(_admit_cycle, repeat=5)
            emit(f"serve.admit.{mode}", t_admit,
                 slot_upload=slot_upload, warm_tenants=8)
            note_telemetry(f"serve.admit.{mode}", pool.metrics.snapshot())


if __name__ == "__main__":
    run(small=False)
