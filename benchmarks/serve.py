"""Multi-tenant hierarchy serving benchmark (the traffic-scale story).

Rows:
  * ``serve.mt.t{1,8,64}.q50k`` — 50k mixed-op queries round-robined
    across 1 / 8 / 64 tenants through :class:`MultiTenantService`,
    4096-slot dispatches, best-of-2 (first pass pays the one compile
    per shape bucket; steady-state is what a server sees).  ``qps`` is
    the headline: cross-tenant slot batching should hold throughput
    near the single-tenant ``hier.*.query50k`` line instead of
    dividing it by tenant count.
  * ``serve.load.miss`` — cold tenant admission: versioned npz off
    disk into a free pool slot (v2 artifacts carry the pack cache, so
    this is pure array reads — no O(n) host walk, no retrace).
  * ``serve.load.hit``  — resident-tenant ``ensure``: the LRU-touch
    fast path.

Tenants are small powerlaw graphs spread over two shape buckets (the
mixed-bucket case is the expensive one: one dispatch per bucket per
chunk).  64 tenant artifacts cycle 8 distinct decompositions — build
cost is not what this module measures.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.graph import powerlaw_bipartite
from repro.core.peel import wing_decomposition
from repro.hierarchy import (ForestPool, MultiTenantService, build_hierarchy,
                             save_hierarchy)
from repro.hierarchy.serve import OPS

from .common import emit, timed

N_QUERIES = 50_000
BATCH = 4096
N_TENANTS = 64
DISTINCT = 8


def _artifacts(d):
    hs = []
    for i in range(DISTINCT):
        nu, nv, m = (60, 40, 200) if i % 4 == 3 else (120, 80, 420)
        g = powerlaw_bipartite(nu, nv, m, seed=i)
        hs.append(build_hierarchy(g, wing_decomposition(g, P=4,
                                                        engine="csr")))
    names = [f"t{t:02d}" for t in range(N_TENANTS)]
    for t, name in enumerate(names):
        save_hierarchy(os.path.join(d, f"{name}.npz"), hs[t % DISTINCT])
    return names


def _workload(pool, tenants, n, seed=0):
    rng = np.random.default_rng(seed)
    t_col = [tenants[i % len(tenants)] for i in range(n)]
    ops = rng.integers(0, 5, n).astype(np.int32)
    a = np.zeros(n, np.int32)
    b = np.zeros(n, np.int32)
    sub = OPS["subtree_size"]
    for i, t in enumerate(t_col):
        m = pool.meta[t]
        a[i] = rng.integers(0, m.n_nodes if ops[i] == sub else m.n_entities)
        b[i] = rng.integers(0, m.n_entities)
    return t_col, ops, a, b


def run(small: bool = True):
    with tempfile.TemporaryDirectory(prefix="bench_serve_") as d:
        names = _artifacts(d)

        for n_t in (1, 8, 64):
            pool = ForestPool(slots=N_TENANTS, artifact_dir=d)
            svc = MultiTenantService(pool, batch=BATCH)
            active = names[:n_t]
            for t in active:
                pool.ensure(t)
            tenants, ops, a, b = _workload(pool, active, N_QUERIES)
            _, t_q = timed(svc.query_batch, tenants, ops, a, b,
                           repeat=2)  # best-of-2 excludes per-bucket compile
            qps = N_QUERIES / max(t_q, 1e-9)
            emit(f"serve.mt.t{n_t}.q50k", t_q,
                 qps=int(qps), batch=BATCH, n_queries=N_QUERIES,
                 buckets=len(pool.buckets), dispatches=svc.dispatches // 2)

        # load latency: admission path (cold, off disk) vs LRU-touch (hot)
        pool = ForestPool(slots=N_TENANTS, artifact_dir=d)
        probe = names[:16]
        t0 = time.perf_counter()
        for t in probe:
            pool.ensure(t)
        t_miss = (time.perf_counter() - t0) / len(probe)
        emit("serve.load.miss", t_miss,
             n_loads=len(probe), format_version=2, pack_cache="v2")
        _, t_hit = timed(pool.ensure, probe[0], repeat=3)
        emit("serve.load.hit", t_hit, **pool.stats())


if __name__ == "__main__":
    run(small=False)
