"""Per-architecture smoke tests (reduced configs, single CPU device)
plus train↔decode consistency properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import ARCHS, get_config
from repro.models.config import reduced
from repro.models import ssm as S


def _batch(cfg, b=2, s=64):
    out = dict(
        tokens=jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (b, s)),
            jnp.int32),
        labels=jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (b, s)),
            jnp.int32),
    )
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            np.random.default_rng(2).normal(size=(b, cfg.encoder_seq,
                                                  cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.rope_type == "mrope":
        out["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None], (b, 3, s))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_decode(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = M.forward(params, batch["tokens"], cfg,
                       positions=batch.get("positions"),
                       frames=batch.get("frames"))
    assert logits.shape == (2, 64, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/Inf in forward"
    loss = M.train_loss(params, batch, cfg)
    assert bool(jnp.isfinite(loss))

    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, 2, 32, dtype=jnp.float32))
    lg, new_cache = M.serve_step(
        params, cache, batch["tokens"][:, 0], jnp.int32(0), cfg)
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))
    # cache structure is preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "xlstm_1_3b"])
def test_arch_grad_step_reduces_loss(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, s=32)
    loss_fn = lambda p: M.train_loss(p, batch, cfg)
    l0, g = jax.value_and_grad(loss_fn)(params)
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), (float(l0), float(l1))


@pytest.mark.parametrize(
    "arch", ["tinyllama_1_1b", "gemma_2b", "deepseek_v2_236b"])
def test_decode_matches_forward(arch):
    """Incremental decode with cache must reproduce the full forward."""
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    s = 8
    toks = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (2, s)), jnp.int32)
    full = M.forward(params, toks, cfg)

    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, 2, s, dtype=jnp.float32))
    for t in range(s):
        lg, cache = M.serve_step(params, cache, toks[:, t],
                                 jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), atol=2e-2, rtol=1e-2)


@pytest.mark.parametrize("arch", ["xlstm_1_3b", "zamba2_7b"])
def test_recurrent_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    s = max(cfg.ssm_chunk, 16)
    toks = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (2, s)), jnp.int32)
    full = M.forward(params, toks, cfg)
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, 2, s, dtype=jnp.float32))
    for t in range(s):
        lg, cache = M.serve_step(params, cache, toks[:, t],
                                 jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), atol=5e-2, rtol=2e-2)


def test_whisper_decode_matches_forward():
    cfg = reduced(get_config("whisper_large_v3"))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    s = 8
    b = 2
    toks = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (b, s)), jnp.int32)
    frames = jnp.asarray(
        np.random.default_rng(6).normal(size=(b, cfg.encoder_seq,
                                              cfg.d_model)) * 0.02,
        jnp.float32)
    full = M.forward(params, toks, cfg, frames=frames)
    from repro.models.model import _whisper_encode
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, b, s, dtype=jnp.float32))
    cache["enc_out"] = _whisper_encode(params, frames, cfg)
    for t in range(s):
        lg, cache = M.serve_step(params, cache, toks[:, t],
                                 jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(full[:, t]), atol=2e-2, rtol=1e-2)


# ------------------------------------------------ recurrence primitives
def test_chunked_recurrence_matches_sequential():
    """chunk-parallel scan == naive step recurrence (the SSD identity)."""
    rng = np.random.default_rng(0)
    b, h, s, dk, dv = 2, 3, 64, 8, 5
    q = jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, s, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, s, dv)), jnp.float32)
    decay = jnp.asarray(rng.uniform(0.5, 1.0, size=(b, h, s)), jnp.float32)
    gain = jnp.asarray(rng.uniform(0.1, 1.0, size=(b, h, s)), jnp.float32)

    for chunk in (8, 16, 64):
        y = S.chunked_recurrence(q, k, v, decay, gain, chunk=chunk)
        St = jnp.zeros((b, h, dk, dv))
        ys = []
        for t in range(s):
            St, yt = S.recurrence_step(
                St, q[:, :, t], k[:, :, t], v[:, :, t],
                decay[:, :, t], gain[:, :, t])
            ys.append(yt)
        want = jnp.stack(ys, axis=2)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(want), atol=1e-3, rtol=1e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor 1.25 and random routing, most tokens route."""
    import repro.models.moe as moe
    cfg = reduced(get_config("dbrx_132b"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    layer0 = jax.tree.map(lambda p: p[0], params["blocks"])  # first layer
    out = moe.moe_layer(x, layer0["ffn"], cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_shape_applicability_rules():
    assert M.shape_applicable(get_config("xlstm_1_3b"), "long_500k")[0]
    assert M.shape_applicable(get_config("zamba2_7b"), "long_500k")[0]
    ok, why = M.shape_applicable(get_config("tinyllama_1_1b"), "long_500k")
    assert not ok and "quadratic" in why
    assert M.shape_applicable(get_config("whisper_large_v3"), "decode_32k")[0]
