"""benchmarks.report must render EVERY bench row — including names
containing '/' (A/B ratio labels, not path separators) — and synthesize
the FD/CD A/B ratio rows from sibling time rows."""
import json
import sys

import pytest


@pytest.fixture()
def bench_file(tmp_path):
    payload = dict(
        schema=1, backend="cpu", python="3.10", jax="0.4.37",
        rows=[
            dict(name="wing.fr.pbng_csr", us_per_call=2_000_000.0,
                 fd_driver="device"),
            dict(name="wing.fr.pbng_csr_hostfd", us_per_call=2_500_000.0),
            dict(name="wing.fr.pbng_csr_vmapped", us_per_call=3_000_000.0),
            dict(name="wing.pl120.pbng_csr_vmapped", us_per_call=100_000.0),
            dict(name="wing.pl120.pbng_csr_vmapped_pallas",
                 us_per_call=400_000.0),
            dict(name="scaling.wing.dev4.csr", us_per_call=500_000.0,
                 psums_per_round=2),
            dict(name="scaling.wing.dev4.csr_pal", us_per_call=450_000.0,
                 psums_per_round=1),
            # a raw row whose NAME already contains '/': must render
            # verbatim, never be skipped or split
            dict(name="wing.fr.fd.device/host", us_per_call=800_000.0),
        ],
    )
    path = tmp_path / "BENCH_test.json"
    path.write_text(json.dumps(payload))
    return str(path)


def _load_report():
    sys.path.insert(0, ".")
    from benchmarks import report

    return report


def test_bench_table_renders_slash_rows(bench_file):
    report = _load_report()
    out = report.bench_table([bench_file])
    # every row name present, including the literal '/' one
    assert "wing.fr.fd.device/host" in out
    for n in ("wing.fr.pbng_csr", "wing.fr.pbng_csr_hostfd",
              "wing.pl120.pbng_csr_vmapped_pallas",
              "scaling.wing.dev4.csr_pal"):
        assert n in out, n


def test_ab_ratio_rows_synthesized(bench_file):
    report = _load_report()
    rows = {r["name"]: float(r["us_per_call"])
            for r in json.load(open(bench_file))["rows"]}
    ab = dict(report.ab_rows(rows))
    assert ab["wing.fr.fd.device/host"] == pytest.approx(2.0 / 2.5)
    assert ab["wing.fr.fd.vmapped/device"] == pytest.approx(3.0 / 2.0)
    assert ab["wing.pl120.fd.pallas/segsum"] == pytest.approx(4.0)
    assert ab["scaling.wing.dev4.cd.pair_aligned/wedge"] == pytest.approx(
        0.45 / 0.5)
    # and the rendered table carries them
    out = report.bench_table([bench_file])
    assert "fd.vmapped/device" in out
    assert "cd.pair_aligned/wedge" in out


def test_ab_half_missing_pair_emits_na_row(bench_file):
    """One side of an A/B pair missing ⇒ a marked n/a row, never a
    silent skip (a dropped sibling must be a visible gap)."""
    report = _load_report()
    rows = {r["name"]: float(r["us_per_call"])
            for r in json.load(open(bench_file))["rows"]}
    ab = dict(report.ab_rows(rows))
    # pl120 has the vmapped row but its expected device sibling
    # (pbng_csr) is absent — must surface as None
    assert "wing.pl120.fd.vmapped/device" in ab
    assert ab["wing.pl120.fd.vmapped/device"] is None
    # ...but variants a family never benchmarks BY DESIGN must not
    # produce structural n/a noise: fr has no pallas pair, scaling has
    # no hostfd pair
    assert "wing.fr.fd.pallas/segsum" not in ab
    assert "scaling.wing.dev4.fd.device/host" not in ab
    # each synthesized label appears exactly once even though both
    # siblings of a complete pair match the suffix scan
    names = [n for n, _ in report.ab_rows(rows)]
    assert len(names) == len(set(names))
    out = report.bench_table([bench_file])
    assert "n/a (pair side missing)" in out


def test_ab_tip_scaling_pair():
    report = _load_report()
    rows = {
        "scaling.tip.dev4.tip_csr": 500_000.0,
        "scaling.tip.dev4.tip_aligned": 400_000.0,
        "scaling.tip.dev8.tip_aligned": 350_000.0,  # half-missing pair
    }
    ab = dict(report.ab_rows(rows))
    assert ab["scaling.tip.dev4.cd.aligned/roundrobin"] == pytest.approx(
        0.4 / 0.5)
    assert ab["scaling.tip.dev8.cd.aligned/roundrobin"] is None


def test_bench_table_missing_file():
    report = _load_report()
    assert "not found" in report.bench_table(["/nonexistent/BENCH.json"])


def test_run_only_unknown_module_exits_with_menu(capsys, monkeypatch):
    """--only with a typo must die up front (exit 2) listing every
    valid module name — not minutes later with a raw KeyError."""
    from benchmarks import run as bench_run

    monkeypatch.setattr(
        sys, "argv", ["run.py", "--only", "hierachy,serve,bogus"])
    with pytest.raises(SystemExit) as e:
        bench_run.main()
    assert e.value.code == 2
    err = capsys.readouterr().err
    assert "bogus" in err and "hierachy" in err
    for name in bench_run.MODULES:
        assert name in err          # the menu names every module
    assert "serve" in bench_run.MODULES
