"""Differential harness for the streaming updater.

The streaming contract is *bit-identity*: after EVERY micro-epoch, the
incrementally maintained state — θ, the Phase-1 partition assignment,
⋈init, the full PeelStats row, and every packed-forest array — must
equal a from-scratch re-peel + rebuild of the materialized graph.
These tests check the contract three ways:

* deterministic seeded replays across engines (csr + dense), kinds
  (wing + tip, both tip sides), and event mixes (inserts, deletes,
  duplicates, self-cancelling batches, varying micro-epoch sizes);
* a hypothesis property test drawing arbitrary insert/delete
  sequences (1000-example budget under the ``nightly`` profile);
* golden replays (``tests/goldens/stream_goldens.json``) that lock the
  digests across refactors, plus jaxpr goldens proving the localized
  FD re-runs dispatch the byte-identical per-partition programs.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graph import random_bipartite
from repro.core.peel import tip_decomposition, wing_decomposition
from repro.core.peelspec import run_fd
from repro.hierarchy import build_hierarchy
from repro.hierarchy.repair import dirty_subtrees
from repro.streaming import (EdgeEvent, StreamConfig, StreamState,
                             apply_events, coalesce, make_random_events)
from repro.streaming.delta import support_delta, wing_sup0_new


def _load_recorder(name):
    import importlib.util

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "goldens", name)
    spec = importlib.util.spec_from_file_location(name[:-3], path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


_REC = _load_recorder("record_stream_goldens.py")


def _scratch(g, cfg):
    """From-scratch reference for the materialized graph."""
    if cfg.kind == "wing":
        res = wing_decomposition(g, P=cfg.P, engine=cfg.engine,
                                 fd_driver=cfg.fd_driver)
    else:
        res = tip_decomposition(g, side=cfg.side, P=cfg.P,
                                engine=cfg.engine,
                                fd_driver=cfg.fd_driver,
                                batch_recount=cfg.batch_recount)
    h = build_hierarchy(g, res, kind=cfg.kind, side=cfg.side)
    return res, h


def _assert_identical(st_state, msg):
    """The whole contract: θ / part / ⋈init / stats row / forest."""
    ref, h_ref = _scratch(st_state.g, st_state.config)
    res = st_state.result
    assert np.array_equal(res.theta, ref.theta), f"{msg}: theta"
    assert np.array_equal(res.part, ref.part), f"{msg}: part"
    assert np.array_equal(res.support_init, ref.support_init), \
        f"{msg}: support_init"
    assert np.array_equal(res.ranges, ref.ranges), f"{msg}: ranges"
    assert res.stats.as_dict() == ref.stats.as_dict(), f"{msg}: stats"
    h = st_state.hierarchy
    for f in _REC.FOREST_FIELDS:
        assert np.array_equal(getattr(h, f), getattr(h_ref, f)), \
            f"{msg}: forest.{f}"
    assert np.allclose(h.density, h_ref.density), f"{msg}: density"


# ------------------------------------------------------- deterministic sweep
@pytest.mark.parametrize("kind,engine,fd_driver,side", [
    ("wing", "csr", "device", "u"),
    ("wing", "csr", "host", "u"),
    ("wing", "csr", "vmapped", "u"),
    ("wing", "dense", "host", "u"),
    ("tip", "csr", "device", "u"),
    ("tip", "csr", "device", "v"),
    ("tip", "dense", "host", "u"),
])
def test_differential_stream(kind, engine, fd_driver, side):
    g = random_bipartite(24, 18, 90, seed=11)
    cfg = StreamConfig(kind=kind, side=side, engine=engine, P=6,
                       fd_driver=fd_driver)
    state = StreamState.initial(g, cfg)
    _assert_identical(state, f"{kind}/{engine} epoch0")
    # mixed micro-epoch sizes, insert/delete mixes
    for e, (n_ev, p_del) in enumerate([(9, 0.3), (1, 0.0), (16, 0.7),
                                       (5, 0.5)]):
        events = make_random_events(state.g, n_ev, seed=50 + e,
                                    p_delete=p_del)
        state.apply_epoch(events)
        _assert_identical(state, f"{kind}/{engine} epoch{e + 1}")


def test_duplicate_and_self_cancelling_events():
    g = random_bipartite(20, 15, 70, seed=4)
    state = StreamState.initial(
        g, StreamConfig(kind="wing", engine="csr", P=4))
    u0, v0 = map(int, g.edges[0])
    # delete+reinsert an existing edge (net no-op), duplicate inserts of
    # a new edge, insert+delete of an absent edge (net no-op)
    events = [
        EdgeEvent("-", u0, v0), EdgeEvent("+", u0, v0),
        EdgeEvent("+", 19, 14), EdgeEvent("+", 19, 14),
        EdgeEvent("+", 0, 14), EdgeEvent("-", 0, 14),
    ]
    rep = state.apply_epoch(events)
    assert (rep.n_inserts, rep.n_deletes) in {(1, 0), (0, 0)}
    _assert_identical(state, "dup/cancel epoch")


def test_noop_epoch_serves_unchanged():
    g = random_bipartite(20, 15, 70, seed=4)
    state = StreamState.initial(
        g, StreamConfig(kind="wing", engine="csr", P=4))
    res0, h0 = state.result, state.hierarchy
    u0, v0 = map(int, g.edges[0])
    rep = state.apply_epoch([EdgeEvent("-", u0, v0),
                             EdgeEvent("+", u0, v0)])
    assert rep.noop and rep.partitions_dirty == 0
    assert state.result is res0 and state.hierarchy is h0


# ------------------------------------------------------------ hypothesis
_EXAMPLES = 1000 if os.environ.get("HYPOTHESIS_PROFILE") == "nightly" \
    else 8


# mixed micro-epoch sizes (1-7 events) over 1-2 epochs; plain
# combinators, NOT @st.composite — the conftest stand-in for missing
# hypothesis skips @given tests but cannot emulate composite()
_EVENT_EPOCHS = st.lists(
    st.lists(st.tuples(st.booleans(), st.integers(0, 11),
                       st.integers(0, 8)),
             min_size=1, max_size=7),
    min_size=1, max_size=2)


@settings(max_examples=_EXAMPLES, deadline=None)
@given(_EVENT_EPOCHS,
       st.sampled_from([("wing", "csr"), ("wing", "dense"),
                        ("tip", "csr"), ("tip", "dense")]))
def test_property_incremental_equals_scratch(epochs, kind_engine):
    kind, engine = kind_engine
    g = random_bipartite(12, 9, 30, seed=2)
    cfg = StreamConfig(kind=kind, engine=engine, P=4,
                       fd_driver="device" if engine == "csr" else "host")
    state = StreamState.initial(g, cfg)
    for i, evs in enumerate(epochs):
        events = [EdgeEvent("+" if ins else "-", u, v)
                  for ins, u, v in evs]
        state.apply_epoch(events)
        _assert_identical(state, f"property {kind}/{engine} epoch{i}")


# ------------------------------------------------------------ delta layer
def test_coalesce_semantics():
    g = random_bipartite(10, 8, 25, seed=1)
    u0, v0 = map(int, g.edges[0])
    absent = next((u, v) for u in range(10) for v in range(8)
                  if not any((u, v) == (int(a), int(b))
                             for a, b in g.edges))
    ins, dels = coalesce([
        EdgeEvent("+", u0, v0),              # already present -> drop
        EdgeEvent("-", *absent),             # absent delete  -> drop
        EdgeEvent("+", *absent),             # last op wins   -> insert
        EdgeEvent("-", u0, v0),              # net delete
    ], g)
    assert [tuple(r) for r in ins] == [absent]
    assert [tuple(r) for r in dels] == [(u0, v0)]
    with pytest.raises(ValueError):
        coalesce([EdgeEvent("+", 10, 0)], g)
    with pytest.raises(ValueError):
        EdgeEvent("x", 0, 0)


def test_support_delta_matches_recount():
    from repro.core import csr

    g = random_bipartite(16, 12, 60, seed=9)
    events = make_random_events(g, 12, seed=3, p_delete=0.5)
    ins, dels = coalesce(events, g)
    g_new = apply_events(g, ins, dels)

    # wing: carried + delta == fresh global count on the new graph
    sup_old = csr.edge_butterflies0(csr.build_wedges(g)).astype(np.int64)
    dlt, touched = support_delta(g, ins, dels, "wing")
    got = wing_sup0_new(g, sup_old, g_new, dlt)
    want = csr.edge_butterflies0(csr.build_wedges(g_new)).astype(np.int64)
    assert np.array_equal(got, want)
    assert all(k in touched for k in dlt)  # touched ⊇ nonzero-delta keys

    # tip: per-vertex delta against the fresh vertex count
    sup_tip = csr.vertex_butterflies_csr(
        csr.build_wedges(g)).astype(np.int64)
    dlt_t, _ = support_delta(g, ins, dels, "tip")
    got_t = sup_tip.copy()
    for u, d in dlt_t.items():
        got_t[u] += d
    want_t = csr.vertex_butterflies_csr(
        csr.build_wedges(g_new)).astype(np.int64)
    assert np.array_equal(got_t, want_t)


# ----------------------------------------------------------- config / run_fd
def test_stream_config_validation():
    with pytest.raises(ValueError):
        StreamConfig(engine="beindex")
    with pytest.raises(ValueError):
        StreamConfig(fd_driver="fused")
    with pytest.raises(ValueError):
        # vmapped is the csr single-dispatch Phase 2 — dense has none
        StreamConfig(engine="dense", fd_driver="vmapped")
    with pytest.raises(ValueError):
        StreamConfig(kind="wing", side="v")
    # reachable since the vmapped plumb-through (single device, csr)
    assert StreamConfig(fd_driver="vmapped").fd_driver == "vmapped"


def test_run_fd_only_validation():
    from repro.core.peel import PeelStats, build_peel_spec
    from repro.core.peelspec import cd_loop

    g = random_bipartite(16, 12, 60, seed=9)
    stats = PeelStats(engine="csr", fd_driver="vmapped")
    spec = build_peel_spec(g, "wing", stats, engine="csr")
    part, sup_init, ranges, p_eff = cd_loop(spec, 4, stats)
    theta = np.zeros(spec.n, dtype=np.int64)
    with pytest.raises(ValueError):
        run_fd(spec, part, sup_init, theta, p_eff, stats,
               fd_driver="vmapped", only=np.array([0]))
    stats2 = PeelStats(engine="csr", fd_driver="device")
    with pytest.raises(ValueError):
        run_fd(spec, part, sup_init, theta, p_eff, stats2,
               fd_driver="device", only=np.array([p_eff + 3]))


# ------------------------------------------------------------ obs coupling
def test_obs_off_on_theta_identity_and_spans():
    from repro import obs

    g = random_bipartite(20, 15, 70, seed=6)
    cfg = StreamConfig(kind="wing", engine="csr", P=4)
    state_off = StreamState.initial(g, cfg)
    ev = make_random_events(g, 8, seed=77)
    state_off.apply_epoch(list(ev))

    obs.enable()
    try:
        state_on = StreamState.initial(g, cfg)
        state_on.apply_epoch(list(ev))
        tracer = obs.get_tracer()
        names = {e.get("name") for e in tracer.events}
        for want in ("stream.epoch", "stream.cd", "stream.fd",
                     "stream.repair", "hierarchy.repair"):
            assert want in names, f"missing span {want}"
    finally:
        obs.disable()
    assert np.array_equal(state_on.result.theta, state_off.result.theta)
    assert state_on.result.stats.as_dict() == \
        state_off.result.stats.as_dict()
    # serving metrics populated
    snap = state_on.metrics.snapshot()
    assert snap["stream.epochs"]["value"] >= 1
    assert "stream.repair_ms" in snap


def test_localized_fd_jaxprs_byte_identical(obs_golden):
    """The per-partition FD programs streaming re-dispatches via
    ``run_fd(only=...)`` are the byte-identical telemetry-off jaxprs."""
    mod, jaxprs = obs_golden
    for case in ("device_wing", "device_tip"):
        assert mod.CASES[case]() == jaxprs[case], case


# ------------------------------------------------------------- golden lock
@pytest.mark.parametrize("case", sorted(_REC.CASES))
def test_stream_goldens_replay(case):
    import json

    with open(_REC.GOLDEN_PATH) as f:
        golden = json.load(f)["cases"]
    want = golden[case]
    got = list(_REC.replay(case))
    assert len(got) == len(want)
    for g_rec, w_rec in zip(got, want):
        assert g_rec == w_rec, (
            f"{case} epoch {w_rec['epoch']}: streaming digests diverged "
            f"from the recorded goldens")


# ------------------------------------------------------- serving-side bound
def test_dirty_subtrees_slices_are_contiguous_and_cover():
    g = random_bipartite(24, 18, 90, seed=11)
    res = wing_decomposition(g, P=6, engine="csr")
    h = build_hierarchy(g, res)
    ids = np.arange(0, g.m, 7)
    nodes, slices = dirty_subtrees(h, ids)
    assert all(lo < hi for lo, hi in slices)
    assert all(hi <= lo2 for (_, hi), (lo2, _) in zip(slices, slices[1:]))
    covered = set()
    for lo, hi in slices:
        covered.update(range(lo, hi))
    # every affected entity's packed position falls inside the slices
    pos = {int(e): i for i, e in enumerate(h.ent_order.tolist())}
    for e in ids.tolist():
        if int(h.theta[e]) > 0:
            assert pos[e] in covered
    # and empty input -> empty bound
    n2, s2 = dirty_subtrees(h, np.zeros(0, dtype=np.int64))
    assert n2.size == 0 and s2 == []


def test_stale_bound_reported():
    g = random_bipartite(24, 18, 90, seed=11)
    state = StreamState.initial(
        g, StreamConfig(kind="wing", engine="csr", P=6))
    rep = state.apply_epoch(make_random_events(g, 6, seed=8))
    if not rep.noop:
        assert rep.stale_nodes >= 0
        assert rep.stale_entities <= state.g.m + 64
        assert rep.epoch_ms >= rep.repair_ms >= 0.0
