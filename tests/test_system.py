"""End-to-end behaviour tests: the paper's analytic + the LM framework
working together through the public API."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.models as M
from repro.configs import ARCHS, get_config
from repro.core import (
    paper_proxy_dataset,
    powerlaw_bipartite,
    ref,
    tip_decomposition,
    wing_decomposition,
)
from repro.models.config import reduced
from repro.train import TrainConfig, make_train_step
from repro.train.optimizer import AdamWConfig, adamw_init


def test_full_decomposition_pipeline():
    """PBNG on a paper-proxy dataset: hierarchy invariants hold."""
    g = paper_proxy_dataset("di_af")
    res = wing_decomposition(g, P=12, engine="beindex")
    theta = res.theta
    # hierarchy: every edge at the densest level participates in >= kmax
    # butterflies inside that level's induced subgraph
    kmax = int(theta.max())
    from repro.core.graph import BipartiteGraph
    top = BipartiteGraph.from_edges(g.n_u, g.n_v, g.edges[theta == kmax])
    if top.m:
        cnt = ref.edge_butterflies_ref(top)
        assert cnt.min() >= kmax, (kmax, cnt.min())
    # partitions ordered by range
    assert (np.diff(res.ranges) >= 0).all()
    # massive sync reduction vs level-by-level (the headline claim)
    assert res.stats.rho_cd < res.stats.rho_fd_total


def test_tip_and_wing_consistency():
    g = powerlaw_bipartite(100, 60, 500, seed=2)
    tips_u = tip_decomposition(g, side="u", P=6).theta
    wings = wing_decomposition(g, P=6).theta
    top_edges = g.edges[wings == wings.max()]
    if wings.max() > 0 and top_edges.size:
        assert tips_u[top_edges[:, 0]].min() > 0


def test_graph_to_lm_training():
    """The paper's application: decomposition-ordered link-prediction
    training converges."""
    from repro.data import curriculum_sequences, sequence_batches

    g = powerlaw_bipartite(80, 40, 400, seed=5)
    seqs = curriculum_sequences(g, n_levels=3, P=4, max_len=16)
    assert len(seqs) > 10
    cfg = reduced(get_config("tinyllama_1_1b"),
                  vocab=g.n_u + g.n_v, n_layers=2, max_seq=16)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, TrainConfig(opt=AdamWConfig(lr=1e-2, total_steps=60))))
    losses = []
    for _ in range(2):
        for batch in sequence_batches(seqs, batch=8, seq_len=15):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])


def test_serve_generates():
    cfg = reduced(get_config("gemma_2b"), n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    b, total = 2, 12
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, b, total, dtype=jnp.float32))
    tok = jnp.zeros((b,), jnp.int32)
    outs = []
    for i in range(total):
        logits, cache = M.serve_step(params, cache, tok, jnp.int32(i), cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    arr = np.stack(outs)
    assert arr.shape == (total, b)
    assert (arr >= 0).all() and (arr < cfg.vocab).all()


def test_moe_affinity_analysis():
    from repro.core.analysis import moe_affinity

    rng = np.random.default_rng(0)
    a = rng.integers(0, 4, (50, 2))
    b = rng.integers(4, 8, (50, 2))
    assignments = np.concatenate([a, b])
    tips = moe_affinity(assignments, 8, P=4)
    assert tips.shape == (8,)
    assert tips.max() > 0
