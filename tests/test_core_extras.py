"""BE_PC baseline, KONECT loader, approximate counting, curriculum data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import counting, ref
from repro.core.graph import from_tsv, powerlaw_bipartite, random_bipartite
from repro.core.peel import wing_decomposition_bepc


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 5000), st.sampled_from([0.2, 0.4]))
def test_bepc_matches_oracle(seed, tau):
    g = random_bipartite(16, 12, 48, seed=seed)
    want = ref.bup_wing_ref(g)
    got, _ = wing_decomposition_bepc(g, tau=tau)
    assert np.array_equal(got, want)


def test_bepc_medium_matches_pbng():
    from repro.core.peel import wing_decomposition
    g = powerlaw_bipartite(120, 60, 520, seed=3)
    a, _ = wing_decomposition_bepc(g)
    b = wing_decomposition(g, P=8, engine="beindex").theta
    assert np.array_equal(a, b)


def test_from_tsv_roundtrip():
    with tempfile.NamedTemporaryFile(
            "w", suffix=".tsv", delete=False) as f:
        f.write("% KONECT header\n")
        f.write("1\t10\n1\t20\n2\t10\n2\t20\n7\t99\n")
        path = f.name
    try:
        g = from_tsv(path)
        assert g.m == 5
        assert ref.butterfly_count_total(g) == 1
    finally:
        os.unlink(path)


def test_approx_counting_mean_unbiased():
    g = powerlaw_bipartite(150, 300, 2200, seed=4)
    A = jnp.asarray(g.adjacency())
    exact = float(np.asarray(counting.vertex_butterflies(A)).sum())
    ests = [
        float(np.asarray(counting.approx_vertex_butterflies(
            A, 150, jax.random.PRNGKey(s))).sum())
        for s in range(5)
    ]
    assert abs(np.mean(ests) / exact - 1) < 0.35, (np.mean(ests), exact)
    # full sample = exact
    full = np.asarray(counting.approx_vertex_butterflies(
        A, 300, jax.random.PRNGKey(0), n_rounds=1))
    np.testing.assert_allclose(
        full, np.asarray(counting.vertex_butterflies(A)), rtol=1e-4)


def test_curriculum_orders_dense_first():
    from repro.data import curriculum_sequences
    from repro.core.peel import wing_decomposition
    g = powerlaw_bipartite(60, 30, 300, seed=8)
    seqs = curriculum_sequences(g, n_levels=3, P=4, max_len=8)
    assert seqs, "no sequences generated"
    # every interaction appears in some sequence exactly once
    total_items = sum(s.size - 1 for s in seqs)
    assert total_items == g.m
