"""Test-suite wiring.

Property tests use ``hypothesis``; when it is not installed (minimal
containers) the suite must still *collect* — property tests are skipped
instead of erroring at import.  We register a tiny stand-in module whose
``@given`` marks the test skipped; strategy calls return placeholders
that are never executed.

With hypothesis installed, two profiles are registered: the default
stays at hypothesis's stock budget (push CI), and ``nightly`` runs a
10x example budget with no deadline — CI's scheduled slow tier selects
it via ``HYPOTHESIS_PROFILE=nightly``.
"""
import os
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401 — probe only

    hypothesis.settings.register_profile(
        "nightly", max_examples=1000, deadline=None)
    hypothesis.settings.register_profile("default", hypothesis.settings())
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # pragma: no cover - exercised on minimal containers

    def _identity_decorator(*_a, **_k):
        def wrap(fn):
            return fn

        return wrap

    def _skip_decorator(*_a, **_k):
        def wrap(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped"
            )(fn)

        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return _identity_decorator

        def __call__(self, *a, **k):
            return None

    stub = types.ModuleType("hypothesis")
    stub.given = _skip_decorator
    stub.settings = _identity_decorator
    stub.assume = lambda *a, **k: True
    stub.note = lambda *a, **k: None
    stub.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _AnyStrategy()
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
