"""Test-suite wiring.

Property tests use ``hypothesis``; when it is not installed (minimal
containers) the suite must still *collect* — property tests are skipped
instead of erroring at import.  We register a tiny stand-in module whose
``@given`` marks the test skipped; strategy calls return placeholders
that are never executed.

With hypothesis installed, two profiles are registered: the default
stays at hypothesis's stock budget (push CI), and ``nightly`` runs a
10x example budget with no deadline — CI's scheduled slow tier selects
it via ``HYPOTHESIS_PROFILE=nightly``.
"""
import os
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401 — probe only

    hypothesis.settings.register_profile(
        "nightly", max_examples=1000, deadline=None)
    hypothesis.settings.register_profile("default", hypothesis.settings())
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "default"))
except ImportError:  # pragma: no cover - exercised on minimal containers

    def _identity_decorator(*_a, **_k):
        def wrap(fn):
            return fn

        return wrap

    def _skip_decorator(*_a, **_k):
        def wrap(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped"
            )(fn)

        return wrap

    class _AnyStrategy:
        def __getattr__(self, name):
            return _identity_decorator

        def __call__(self, *a, **k):
            return None

    stub = types.ModuleType("hypothesis")
    stub.given = _skip_decorator
    stub.settings = _identity_decorator
    stub.assume = lambda *a, **k: True
    stub.note = lambda *a, **k: None
    stub.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.__getattr__ = lambda name: _AnyStrategy()
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies


def pytest_collection_modifyitems(config, items):
    """Opt-in order shuffling (``PYTEST_ORDER_SEED=<int>``): the tier-1
    suite must be order-independent — CI runs a shuffled pass so
    inter-test state leaks (a tracer left enabled, a shared registry)
    surface instead of hiding behind file order."""
    seed = os.environ.get("PYTEST_ORDER_SEED")
    if not seed:
        return
    import random

    random.Random(int(seed)).shuffle(items)
    rep = config.pluginmanager.get_plugin("terminalreporter")
    if rep is not None:
        rep.write_line(
            f"test order shuffled with PYTEST_ORDER_SEED={seed}")


@pytest.fixture(autouse=True)
def _isolate_global_obs_state():
    """Restore the process-wide observability switches after every test.

    ``obs.enable()`` flips a module-level gate and the timeline
    collector is a module global; a test that enables tracing and then
    fails (or simply forgets to disable) must not leak telemetry-on
    into whichever test the shuffled order runs next — the
    zero-overhead-off jaxpr goldens would spuriously mismatch."""
    from repro.obs import timeline, trace

    tracer_before = trace._tracer
    collector_before = timeline._collector
    yield
    trace._tracer = tracer_before
    timeline._collector = collector_before


@pytest.fixture(scope="session")
def obs_golden():
    """The telemetry-off reference jaxprs (zero-overhead-off oracle).

    Loads ``tests/goldens/record_obs_jaxprs.py`` (the case builders)
    and ``obs_jaxprs.json`` (the texts recorded at the
    pre-instrumentation tree).  The consuming suites re-derive each
    jaxpr from the instrumented tree with telemetry disabled and assert
    byte-equality — proving the obs layer is a trace-time branch whose
    off path changes no compiled program.
    """
    import importlib.util
    import json

    import jax

    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "goldens", "record_obs_jaxprs.py")
    spec = importlib.util.spec_from_file_location("record_obs_jaxprs", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    with open(mod.GOLDEN_PATH) as f:
        golden = json.load(f)
    if golden.get("jax") != jax.__version__:
        pytest.skip(
            f"obs jaxprs recorded on jax {golden.get('jax')}, running "
            f"{jax.__version__} — re-record via the script's docstring")
    return mod, golden["jaxprs"]
