"""Sharding rules: logical-axis resolution, divisibility fallback, batch
and cache specs."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import repro.models as M
from repro.configs import get_config
from repro.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    resolve_spec,
)


def _mesh22():
    dev = np.array(jax.devices() * 4)[:4].reshape(2, 2)
    return Mesh(dev, ("data", "model"))


def test_resolve_basic():
    mesh = _mesh22()
    spec = resolve_spec(("embed", "heads"), (64, 64), mesh)
    assert spec == P("data", "model")


def test_resolve_divisibility_fallback():
    mesh = _mesh22()
    # 1 kv head cannot shard over model=2 -> replicated (gemma MQA case)
    spec = resolve_spec(("embed", "kv"), (64, 1), mesh)
    assert spec == P("data")
    # odd dim cannot shard
    spec = resolve_spec(("embed", "mlp"), (63, 64), mesh)
    assert spec == P(None, "model")


def test_resolve_no_axis_reuse():
    mesh = _mesh22()
    spec = resolve_spec(("heads", "mlp"), (64, 64), mesh)
    # both want "model"; only the first gets it
    assert spec == P("model")


def test_layers_never_sharded():
    mesh = _mesh22()
    spec = resolve_spec(("layers", "embed", "heads"), (22, 64, 64), mesh)
    assert spec == P(None, "data", "model")


def test_param_shardings_cover_all_archs():
    mesh = _mesh22()
    for arch in ("tinyllama_1_1b", "deepseek_v2_236b", "xlstm_1_3b",
                 "zamba2_7b", "whisper_large_v3"):
        cfg = get_config(arch)
        axes = M.logical_axes(cfg)
        pabs = M.abstract_params(cfg)
        sh = param_shardings(axes, pabs, mesh)
        n = len(jax.tree.leaves(sh))
        assert n == len(jax.tree.leaves(pabs))


def test_batch_shardings():
    mesh = _mesh22()
    cfg = get_config("tinyllama_1_1b")
    specs = M.input_specs(cfg, "train_4k")
    sh = batch_shardings(specs, mesh)
    assert sh["tokens"].spec[0] in ("data", ("data",))


def test_cache_shardings_decode():
    mesh = _mesh22()
    cfg = get_config("tinyllama_1_1b")
    cache = M.cache_specs(cfg, batch=128, seq=1024)
    sh = cache_shardings(cache, mesh, cfg)
    # [L, B, KV, S, hd]: batch over data, seq over model
    assert sh["k"].spec[1] in ("data", ("data",))
    assert sh["k"].spec[3] == "model"


def test_cache_shardings_long_context_batch1():
    mesh = _mesh22()
    cfg = get_config("zamba2_7b")
    cache = M.cache_specs(cfg, batch=1, seq=2048)
    sh = cache_shardings(cache, mesh, cfg)
    # batch=1 cannot shard; attn cache seq still shards over model
    spec = sh["attn_k"].spec
    assert len(spec) < 2 or spec[1] is None
    assert spec[3] == "model" if len(spec) > 3 else True


def test_hlo_collective_parser():
    from repro.launch.hlo_analysis import collective_bytes
    txt = """
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[8,256]{1,0} all-gather(%y), dimensions={1}
  %not-a-coll = f32[4]{0} add(%a, %b)
  %rs.2 = (f32[64]{0}, f32[64]{0}) reduce-scatter(%c, %d), dimensions={0}
"""
    out = collective_bytes(txt)
    assert out["all-reduce"] == 16 * 128 * 4
    assert out["all-gather"] == 8 * 256 * 2
    assert out["reduce-scatter"] == 2 * 64 * 4
    assert "add" not in out
