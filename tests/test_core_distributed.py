"""Multi-device PBNG (shard_map) — run in a subprocess with forced host
device count so the main test process keeps a single device."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(src: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(src)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_distributed_wing_matches_oracle():
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite
        from repro.core import ref
        from repro.core.distributed import distributed_wing_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        for seed in (0, 1, 2):
            g = random_bipartite(16, 12, 48, seed=seed)
            want = ref.bup_wing_ref(g)
            theta, stats = distributed_wing_decomposition(
                g, mesh, axis="peel", P_parts=4)
            assert np.array_equal(theta, want), seed
        print("OK")
    """)
    assert "OK" in out


def test_distributed_matches_single_device_engine():
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core.distributed import distributed_wing_decomposition
        from repro.core.peel import wing_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(100, 50, 420, seed=5)
        theta, stats = distributed_wing_decomposition(
            g, mesh, axis="peel", P_parts=6)
        ref_theta = wing_decomposition(g, P=6, engine="beindex").theta
        assert np.array_equal(theta, ref_theta)
        assert stats["rho_cd"] > 0 and stats["rho_fd_max"] > 0
        print("OK", stats)
    """)
    assert "OK" in out


def test_fd_hlo_has_no_collectives():
    """The paper's 'no global synchronization' claim, checked structurally:
    the FD phase HLO must contain no collective ops."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.graph import random_bipartite
        from repro.core.beindex import build_beindex
        from repro.core.peel import wing_decomposition
        from repro.core import distributed as D
        g = random_bipartite(20, 16, 64, seed=3)
        be = build_beindex(g)
        res = wing_decomposition(g, P=4, engine="beindex", be=be)
        packed = D.pack_fd_partitions(
            g, be, res.part, res.support_init, res.stats.p_effective)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        n_parts = packed["le"].shape[0]
        pad = (-n_parts) % 8
        def padp(x):
            if pad == 0: return jnp.asarray(x)
            fill = np.zeros((pad,)+x.shape[1:], dtype=x.dtype)
            return jnp.asarray(np.concatenate([x, fill], 0))
        args = tuple(padp(packed[k]) for k in
                     ("le","lt","lb","alive0","canon","k0","sup0","mine"))
        from repro.sharding.compat import shard_map
        vb = jax.vmap(D._fd_body_one_partition)
        fn = shard_map(vb, mesh=mesh,
                       in_specs=tuple(P("peel") for _ in args),
                       out_specs=(P("peel"), P("peel")))
        txt = jax.jit(fn).lower(*args).compile().as_text()
        bad = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute")
               if w in txt]
        assert not bad, bad
        print("OK no collectives in FD")
    """)
    assert "OK" in out


def test_cd_round_single_psum_pair():
    """CD rounds synchronize via psum only (one c + one loss reduction)."""
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite
        from repro.core.beindex import build_beindex
        from repro.core import distributed as D
        import jax.numpy as jnp
        g = random_bipartite(20, 16, 64, seed=3)
        be = build_beindex(g)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        st = D.shard_links(be, g.m, 8)
        fn = D.make_cd_round(mesh, "peel", st.nb, g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
        txt = fn.lower(peeled, st.alive_link, st.k_alive, sup,
                       st.le, st.lt, st.lb).compile().as_text()
        n_ar = txt.count("all-reduce-start") or txt.count("all-reduce(")
        assert n_ar <= 3, f"too many collectives per CD round: {n_ar}"
        print("OK", n_ar)
    """)
    assert "OK" in out


def test_distributed_wing_csr_matches_oracle():
    """csr engine on a mesh: wedge-sharded CD + wedge-packed FD."""
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite, powerlaw_bipartite
        from repro.core import ref
        from repro.core.distributed import distributed_wing_decomposition
        from repro.core.peel import wing_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        for seed in (0, 1, 2):
            g = random_bipartite(16, 12, 48, seed=seed)
            want = ref.bup_wing_ref(g)
            theta, stats = distributed_wing_decomposition(
                g, mesh, axis="peel", P_parts=4, engine="csr")
            assert np.array_equal(theta, want), seed
            assert stats["engine"] == "csr"
        g = powerlaw_bipartite(100, 50, 420, seed=5)
        theta, stats = distributed_wing_decomposition(
            g, mesh, axis="peel", P_parts=6, engine="csr")
        ref_theta = wing_decomposition(g, P=6, engine="csr").theta
        assert np.array_equal(theta, ref_theta)
        print("OK", stats)
    """)
    assert "OK" in out


def test_csr_fd_hlo_has_no_collectives():
    """csr FD partitions peel under shard_map with zero collectives —
    the paper's Phase-2 claim for the engine that scales."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.graph import random_bipartite
        from repro.core import csr
        from repro.core.peel import wing_decomposition
        from repro.core import distributed as D
        from repro.sharding.compat import shard_map
        g = random_bipartite(20, 16, 64, seed=3)
        wed = csr.build_wedges(g)
        res = wing_decomposition(g, P=4, engine="csr")
        packed = D.pack_fd_partitions_csr(
            wed, res.part, res.support_init, res.stats.p_effective)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        n_parts = packed["we1"].shape[0]
        pad = (-n_parts) % 8
        def padp(x):
            if pad == 0: return jnp.asarray(x)
            fill = np.zeros((pad,)+x.shape[1:], dtype=x.dtype)
            return jnp.asarray(np.concatenate([x, fill], 0))
        args = tuple(padp(packed[k]) for k in
                     ("we1","we2","wp","alive0","W0","sup0","mine"))
        fn = shard_map(jax.vmap(D._fd_body_one_partition_csr), mesh=mesh,
                       in_specs=tuple(P("peel") for _ in args),
                       out_specs=(P("peel"), P("peel")))
        txt = jax.jit(fn).lower(*args).compile().as_text()
        bad = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute")
               if w in txt]
        assert not bad, bad
        print("OK no collectives in csr FD")
    """)
    assert "OK" in out


def test_csr_cd_round_two_psums():
    """csr CD rounds synchronize via psum only (one c + one loss)."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite
        from repro.core import csr
        from repro.core import distributed as D
        g = random_bipartite(20, 16, 64, seed=3)
        wed = csr.build_wedges(g)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        st = D.shard_wedges(wed, 8)
        fn = D.make_cd_round_csr(mesh, "peel", st.n_pairs, g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.concatenate([st.support, jnp.zeros((1,), jnp.int32)])
        txt = fn.lower(peeled, st.alive_w, st.W_pad, sup,
                       st.we1, st.we2, st.wp).compile().as_text()
        n_ar = txt.count("all-reduce-start") or txt.count("all-reduce(")
        assert n_ar <= 3, f"too many collectives per csr CD round: {n_ar}"
        print("OK", n_ar)
    """)
    assert "OK" in out


def test_pair_aligned_single_psum():
    """Pair-aligned csr CD round must contain exactly one all-reduce —
    c_p and W_p are shard-local once every pair's wedges live on one
    device — and θ must stay bit-identical to the oracle."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite, powerlaw_bipartite
        from repro.core import csr, ref
        from repro.core import distributed as D
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(80, 40, 350, seed=2)
        wed = csr.build_wedges(g)
        packed = D.shard_wedges_pair_aligned(wed, 8)
        fn = D.make_cd_round_csr_pair_aligned(
            mesh, "peel", packed["Pmax"], g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.zeros((g.m + 1,), jnp.int32)
        txt = fn.lower(peeled, jnp.asarray(packed["alive"]),
                       jnp.asarray(packed["W0"]), sup,
                       jnp.asarray(packed["we1"]), jnp.asarray(packed["we2"]),
                       jnp.asarray(packed["wp"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 1, n
        for seed in (0, 1, 2):
            g = random_bipartite(16, 12, 48, seed=seed)
            want = ref.bup_wing_ref(g)
            theta, stats = D.distributed_wing_decomposition(
                g, mesh, axis="peel", P_parts=4, engine="csr",
                pair_aligned=True)
            assert np.array_equal(theta, want), seed
            assert stats["cd_sharding"] == "pair_aligned"
        print("OK", n)
    """)
    assert "OK" in out


def test_pair_aligned_single_device_matches_engine():
    """Degenerate 1-device mesh: pair-aligned CD must still agree with
    the single-device csr engine (same algebra, no collectives to
    save)."""
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core.distributed import distributed_wing_decomposition
        from repro.core.peel import wing_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(1), ("peel",))
        g = powerlaw_bipartite(100, 50, 420, seed=5)
        theta, stats = distributed_wing_decomposition(
            g, mesh, axis="peel", P_parts=6, engine="csr",
            pair_aligned=True)
        ref_theta = wing_decomposition(g, P=6, engine="csr").theta
        assert np.array_equal(theta, ref_theta)
        assert stats["n_dev"] == 1
        print("OK", stats)
    """, n_dev=1)
    assert "OK" in out


def test_pair_aligned_cd_512dev_single_psum():
    """The production-mesh shape: ONE all-reduce per pair-aligned CD
    round at 512 dry-run devices (the same lowering `launch.peel
    --dryrun` asserts, kept in the suite so regressions fail fast)."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core import csr
        from repro.core import distributed as D
        mesh = Mesh(np.array(jax.devices()).reshape(512), ("peel",))
        g = powerlaw_bipartite(100, 50, 500, seed=1)
        wed = csr.build_wedges(g)
        packed = D.shard_wedges_pair_aligned(wed, 512)
        fn = D.make_cd_round_csr_pair_aligned(
            mesh, "peel", packed["Pmax"], g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.zeros((g.m + 1,), jnp.int32)
        txt = fn.lower(peeled, jnp.asarray(packed["alive"]),
                       jnp.asarray(packed["W0"]), sup,
                       jnp.asarray(packed["we1"]), jnp.asarray(packed["we2"]),
                       jnp.asarray(packed["wp"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 1, n
        print("OK", n)
    """, n_dev=512)
    assert "OK" in out


def test_distributed_tip_matches_oracle():
    """Every distributed tip path — csr (default), csr aligned, csr
    vmapped-FD, and the explicit dense fallback — must be θ-bit-identical
    to the BUP oracle and to each other."""
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite
        from repro.core import ref
        from repro.core.distributed import distributed_tip_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        for seed in (0, 1):
            g = random_bipartite(16, 12, 48, seed=seed)
            for side in ("u", "v"):
                want = ref.bup_tip_ref(g, side)
                theta, stats = distributed_tip_decomposition(
                    g, mesh, side=side, P_parts=4)
                assert np.array_equal(theta, want), (seed, side)
                assert stats["engine"] == "csr"
                assert stats["side"] == side
                for kw in (dict(engine="dense"),
                           dict(engine="csr", aligned=True),
                           dict(engine="csr", aligned=True,
                                fd_driver="vmapped")):
                    t2, s2 = distributed_tip_decomposition(
                        g, mesh, side=side, P_parts=4, **kw)
                    assert np.array_equal(t2, want), (seed, side, kw)
        print("OK")
    """)
    assert "OK" in out


def test_distributed_tip_csr_matches_single_device_and_dense():
    """csr tip on a mesh == single-device csr engine == the dense
    distributed fallback, θ bit-for-bit; provenance rides along when
    asked for."""
    out = _run("""
        import numpy as np, jax
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core.distributed import distributed_tip_decomposition
        from repro.core.peel import tip_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(100, 50, 420, seed=5)
        theta, stats, res = distributed_tip_decomposition(
            g, mesh, side="u", P_parts=6, engine="csr", aligned=True,
            return_result=True)
        ref_theta = tip_decomposition(g, side="u", P=6, engine="csr").theta
        assert np.array_equal(theta, ref_theta)
        td, _ = distributed_tip_decomposition(
            g, mesh, side="u", P_parts=6, engine="dense")
        assert np.array_equal(td, theta)
        assert stats["cd_sharding"] == "vertex_aligned"
        assert stats["rho_cd"] > 0 and stats["rho_fd_max"] > 0
        prov = res.provenance()
        assert prov["stats"]["engine"] == "csr"
        assert prov["stats"]["side"] == "u"
        assert prov["part"].shape == theta.shape
        assert prov["ranges"].size == stats["p_effective"] + 1
        print("OK", stats)
    """)
    assert "OK" in out


def test_tip_csr_cd_single_psum():
    """Tip csr CD rounds pay exactly ONE psum — pair butterflies are
    static, so there is no dying-count collective at all; aligned and
    round-robin layouts share the guarantee, and aligned θ is
    oracle-exact."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite, powerlaw_bipartite
        from repro.core import csr, ref
        from repro.core import distributed as D
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(80, 40, 350, seed=2)
        wed = csr.build_wedges(g)
        bf0 = wed.pair_butterflies0()
        fn = D.make_cd_round_tip_csr(mesh, "peel", g.n_u)
        peeled = jnp.zeros((g.n_u + 1,), bool)
        sup = jnp.zeros((g.n_u + 1,), jnp.int32)
        for aligned in (False, True):
            bl = D.shard_tip_pairs(wed, bf0, 8, aligned=aligned)
            txt = fn.lower(peeled, sup, jnp.asarray(bl["dst"]),
                           jnp.asarray(bl["src"]),
                           jnp.asarray(bl["bf"])).compile().as_text()
            n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
            assert n == 1, (aligned, n)
        for seed in (0, 1, 2):
            g = random_bipartite(16, 12, 48, seed=seed)
            want = ref.bup_tip_ref(g, "u")
            theta, stats = D.distributed_tip_decomposition(
                g, mesh, side="u", P_parts=4, engine="csr", aligned=True)
            assert np.array_equal(theta, want), seed
        print("OK")
    """)
    assert "OK" in out


def test_tip_csr_single_device_matches_engine():
    """Degenerate 1-device mesh: distributed tip csr must still agree
    with the single-device csr engine, and the aligned CD round still
    lowers to its single psum."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core import csr
        from repro.core import distributed as D
        from repro.core.peel import tip_decomposition
        mesh = Mesh(np.array(jax.devices()).reshape(1), ("peel",))
        g = powerlaw_bipartite(100, 50, 420, seed=5)
        theta, stats = D.distributed_tip_decomposition(
            g, mesh, side="u", P_parts=6, engine="csr", aligned=True)
        ref_theta = tip_decomposition(g, side="u", P=6, engine="csr").theta
        assert np.array_equal(theta, ref_theta)
        assert stats["n_dev"] == 1
        wed = csr.build_wedges(g)
        bl = D.shard_tip_pairs(wed, wed.pair_butterflies0(), 1,
                               aligned=True)
        fn = D.make_cd_round_tip_csr(mesh, "peel", g.n_u)
        txt = fn.lower(jnp.zeros((g.n_u + 1,), bool),
                       jnp.zeros((g.n_u + 1,), jnp.int32),
                       jnp.asarray(bl["dst"]), jnp.asarray(bl["src"]),
                       jnp.asarray(bl["bf"])).compile().as_text()
        print("OK", stats["rho_cd"])
    """, n_dev=1)
    assert "OK" in out


def test_tip_csr_cd_512dev_single_psum_and_vmapped_fd():
    """Production-mesh shape for tip: ONE all-reduce per aligned CD
    round at 512 dry-run devices, plus the single-`while` collective-free
    vmapped FD jaxpr (the same lowerings `launch.peel --dryrun`
    asserts)."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core import csr
        from repro.core import distributed as D
        from repro.core.peel import tip_decomposition, _fd_tip_vmapped
        mesh = Mesh(np.array(jax.devices()).reshape(512), ("peel",))
        g = powerlaw_bipartite(100, 50, 500, seed=1)
        wed = csr.build_wedges(g)
        bf0 = wed.pair_butterflies0()
        bl = D.shard_tip_pairs(wed, bf0, 512, aligned=True)
        fn = D.make_cd_round_tip_csr(mesh, "peel", g.n_u)
        txt = fn.lower(jnp.zeros((g.n_u + 1,), bool),
                       jnp.zeros((g.n_u + 1,), jnp.int32),
                       jnp.asarray(bl["dst"]), jnp.asarray(bl["src"]),
                       jnp.asarray(bl["bf"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 1, n
        res = tip_decomposition(g, side="u", P=8, engine="csr")
        packed = D.pack_fd_partitions_tip_csr(
            wed, bf0, res.part, res.support_init,
            res.stats.p_effective, bucket=True)
        jaxpr = str(jax.make_jaxpr(_fd_tip_vmapped)(
            jnp.asarray(packed["pa"]), jnp.asarray(packed["pb"]),
            jnp.asarray(packed["bf"]), jnp.asarray(packed["mine"]),
            jnp.asarray(packed["sup0"])))
        nw = jaxpr.count("while[")
        assert nw == 1, nw
        assert not any(c in jaxpr for c in
                       ("psum", "all_gather", "ppermute"))
        print("OK", n, nw)
    """, n_dev=512)
    assert "OK" in out


def test_tip_csr_fd_hlo_has_no_collectives():
    """Tip csr FD partitions peel under shard_map with zero collectives
    — the Phase-2 claim for the entity-agnostic core's second
    instantiation."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core.graph import random_bipartite
        from repro.core import csr
        from repro.core.peel import tip_decomposition
        from repro.core import distributed as D
        from repro.sharding.compat import shard_map
        g = random_bipartite(20, 16, 64, seed=3)
        wed = csr.build_wedges(g)
        bf0 = wed.pair_butterflies0()
        res = tip_decomposition(g, side="u", P=4, engine="csr")
        packed = D.pack_fd_partitions_tip_csr(
            wed, bf0, res.part, res.support_init,
            res.stats.p_effective, stacked=True)
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        n_parts = packed["st_pa"].shape[0]
        pad = (-n_parts) % 8
        def padp(x):
            if pad == 0: return jnp.asarray(x)
            fill = np.zeros((pad,)+x.shape[1:], dtype=x.dtype)
            return jnp.asarray(np.concatenate([x, fill], 0))
        args = tuple(padp(packed[k]) for k in
                     ("st_pa","st_pb","st_bf","mine","sup0"))
        fn = shard_map(jax.vmap(D._fd_body_one_partition_tip_csr),
                       mesh=mesh,
                       in_specs=tuple(P("peel") for _ in args),
                       out_specs=(P("peel"), P("peel")))
        txt = jax.jit(fn).lower(*args).compile().as_text()
        bad = [w for w in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute")
               if w in txt]
        assert not bad, bad
        print("OK no collectives in tip csr FD")
    """)
    assert "OK" in out


def test_emit_hierarchy_distributed_tip_wing_parity(tmp_path):
    """--emit-hierarchy on the distributed tip csr path must attach the
    SAME provenance the wing path attaches: engine/side-tagged PeelStats
    plus the CD partition/ranges/⋈init arrays (satellite of the
    entity-agnostic core refactor)."""
    wing_art = tmp_path / "wing.npz"
    tip_art = tmp_path / "tip.npz"
    out = _run(f"""
        import numpy as np, jax
        from repro.core.graph import powerlaw_bipartite
        from repro.core.distributed import (
            distributed_tip_decomposition, distributed_wing_decomposition)
        from repro.hierarchy import build_hierarchy, save_hierarchy
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(60, 40, 260, seed=7)
        _, _, res_w = distributed_wing_decomposition(
            g, mesh, P_parts=4, engine="csr", pair_aligned=True,
            return_result=True)
        _, _, res_t = distributed_tip_decomposition(
            g, mesh, side="u", P_parts=4, engine="csr", aligned=True,
            return_result=True)
        save_hierarchy({str(wing_art)!r},
                       build_hierarchy(g, res_w, kind="wing"))
        save_hierarchy({str(tip_art)!r},
                       build_hierarchy(g, res_t, kind="tip", side="u"))
        print("OK")
    """)
    assert "OK" in out
    from repro.hierarchy import load_hierarchy

    hw = load_hierarchy(str(wing_art))
    ht = load_hierarchy(str(tip_art))
    for h, side in ((hw, ""), (ht, "u")):
        assert h.meta["stats"]["engine"] == "csr"
        assert h.meta["stats"]["side"] == side
        for key in ("part", "ranges", "support_init"):
            assert key in h.meta, (side, key)
            assert np.asarray(h.meta[key]).size > 0
    # parity: identical provenance key sets on both paths
    assert set(hw.meta) == set(ht.meta)


def test_bloom_aligned_single_psum():
    """Bloom-aligned CD round must contain exactly one all-reduce."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import powerlaw_bipartite
        from repro.core.beindex import build_beindex
        from repro.core import distributed as D
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        g = powerlaw_bipartite(80, 40, 350, seed=2)
        be = build_beindex(g)
        packed = D.shard_links_bloom_aligned(be, g.m, 8)
        fn = D.make_cd_round_bloom(mesh, "peel", packed["Bmax"], g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.zeros((g.m + 1,), jnp.int32)
        txt = fn.lower(peeled, jnp.asarray(packed["alive"]),
                       jnp.asarray(packed["k0"]), sup,
                       jnp.asarray(packed["le"]), jnp.asarray(packed["lt"]),
                       jnp.asarray(packed["lb"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 1, n
        print("OK", n)
    """)
    assert "OK" in out

def test_hierarchical_cd_8dev_staged_psum_replica_groups():
    """Hierarchical CD on a 2-D ("grp", "loc") mesh: the round's single
    logical psum lowers to exactly TWO staged all-reduces with nested
    replica groups — reduce within each group of co-located devices
    first ({{0,1,2,3},{4,5,6,7}} for the 2x4 mesh), then across groups
    ({{0,4},{1,5},{2,6},{3,7}}) — and θ stays bit-identical to both the
    flat 1-D mesh and the BUP oracle (int32 sums are exact under any
    grouping)."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.graph import random_bipartite, powerlaw_bipartite
        from repro.core import csr, ref
        from repro.core import distributed as D
        from repro.launch.mesh import make_peel_mesh_2d
        mesh2 = make_peel_mesh_2d(8)
        assert mesh2.devices.shape == (2, 4), mesh2.devices.shape
        g = powerlaw_bipartite(80, 40, 350, seed=2)
        wed = csr.build_wedges(g)
        packed = D.shard_wedges_pair_aligned(wed, 8)
        fn = D.make_cd_round_csr_pair_aligned(
            mesh2, ("grp", "loc"), packed["Pmax"], g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.zeros((g.m + 1,), jnp.int32)
        txt = fn.lower(peeled, jnp.asarray(packed["alive"]),
                       jnp.asarray(packed["W0"]), sup,
                       jnp.asarray(packed["we1"]), jnp.asarray(packed["we2"]),
                       jnp.asarray(packed["wp"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 2, n
        flat = txt.replace(" ", "")
        assert "{{0,1,2,3},{4,5,6,7}}" in flat, "missing intra-group stage"
        assert "{{0,4},{1,5},{2,6},{3,7}}" in flat, "missing cross-group stage"
        mesh1 = Mesh(np.array(jax.devices()).reshape(8), ("peel",))
        for seed in (0, 1, 2):
            g = random_bipartite(16, 12, 48, seed=seed)
            want = ref.bup_wing_ref(g)
            th, _ = D.distributed_wing_decomposition(
                g, mesh2, axis=("grp", "loc"), P_parts=4, engine="csr",
                pair_aligned=True)
            tf, _ = D.distributed_wing_decomposition(
                g, mesh1, axis="peel", P_parts=4, engine="csr",
                pair_aligned=True)
            assert np.array_equal(th, want), seed
            assert np.array_equal(th, tf), seed
        print("OK", n)
    """)
    assert "OK" in out


def test_hierarchical_tip_cd_8dev():
    """The same two-stage lowering for the tip CD round, and θ parity
    for the full hierarchical distributed tip decomposition."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from repro.core.graph import random_bipartite, powerlaw_bipartite
        from repro.core import csr, ref
        from repro.core import distributed as D
        from repro.launch.mesh import make_peel_mesh_2d
        mesh2 = make_peel_mesh_2d(8)
        g = powerlaw_bipartite(80, 40, 350, seed=2)
        wed = csr.build_wedges(g)
        bl = D.shard_tip_pairs(wed, wed.pair_butterflies0(), 8,
                               aligned=True)
        fn = D.make_cd_round_tip_csr(mesh2, ("grp", "loc"), g.n_u)
        txt = fn.lower(jnp.zeros((g.n_u + 1,), bool),
                       jnp.zeros((g.n_u + 1,), jnp.int32),
                       jnp.asarray(bl["dst"]), jnp.asarray(bl["src"]),
                       jnp.asarray(bl["bf"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 2, n
        flat = txt.replace(" ", "")
        assert "{{0,1,2,3},{4,5,6,7}}" in flat
        assert "{{0,4},{1,5},{2,6},{3,7}}" in flat
        for seed in (0, 1, 2):
            g = random_bipartite(16, 12, 48, seed=seed)
            want = ref.bup_tip_ref(g, "u")
            th, _ = D.distributed_tip_decomposition(
                g, mesh2, axis=("grp", "loc"), side="u", P_parts=4,
                engine="csr", aligned=True)
            assert np.array_equal(th, want), seed
        print("OK", n)
    """)
    assert "OK" in out


def test_hierarchical_cd_single_device_degenerate():
    """make_peel_mesh_2d(1) degenerates to a (1, 1) mesh; the staged
    psum pair is a no-op and θ still matches the single-device csr
    engine."""
    out = _run("""
        import numpy as np
        from repro.core.graph import powerlaw_bipartite
        from repro.core.distributed import distributed_wing_decomposition
        from repro.core.peel import wing_decomposition
        from repro.launch.mesh import make_peel_mesh_2d
        mesh2 = make_peel_mesh_2d(1)
        assert mesh2.devices.shape == (1, 1), mesh2.devices.shape
        g = powerlaw_bipartite(100, 50, 420, seed=5)
        theta, stats = distributed_wing_decomposition(
            g, mesh2, axis=("grp", "loc"), P_parts=6, engine="csr",
            pair_aligned=True)
        ref_theta = wing_decomposition(g, P=6, engine="csr").theta
        assert np.array_equal(theta, ref_theta)
        assert stats["n_dev"] == 1
        print("OK")
    """, n_dev=1)
    assert "OK" in out


def test_hierarchical_cd_512dev_two_staged_allreduces():
    """Production-mesh shape: make_peel_mesh_2d(512) → 16 groups x 32
    local devices; the pair-aligned CD round lowers to exactly two
    staged all-reduces whose replica groups are the 32-wide local rings
    ({0,...,31}, ...) and the 16-wide cross-group combs ({0,32,64,...})
    — the same lowering `launch.peel --dryrun` asserts."""
    out = _run("""
        import numpy as np, jax
        import jax.numpy as jnp
        from repro.core.graph import powerlaw_bipartite
        from repro.core import csr
        from repro.core import distributed as D
        from repro.launch.mesh import make_peel_mesh_2d
        mesh2 = make_peel_mesh_2d(512)
        assert mesh2.devices.shape == (16, 32), mesh2.devices.shape
        g = powerlaw_bipartite(100, 50, 500, seed=1)
        wed = csr.build_wedges(g)
        packed = D.shard_wedges_pair_aligned(wed, 512)
        fn = D.make_cd_round_csr_pair_aligned(
            mesh2, ("grp", "loc"), packed["Pmax"], g.m)
        peeled = jnp.zeros((g.m + 1,), bool)
        sup = jnp.zeros((g.m + 1,), jnp.int32)
        txt = fn.lower(peeled, jnp.asarray(packed["alive"]),
                       jnp.asarray(packed["W0"]), sup,
                       jnp.asarray(packed["we1"]), jnp.asarray(packed["we2"]),
                       jnp.asarray(packed["wp"])).compile().as_text()
        n = txt.count("all-reduce(") + txt.count("all-reduce-start(")
        assert n == 2, n
        flat = txt.replace(" ", "")
        assert "{0,1,2,3" in flat, "missing 32-wide local stage"
        assert "{0,32,64," in flat, "missing 16-wide cross-group stage"
        print("OK", n)
    """, n_dev=512)
    assert "OK" in out


def test_obs_off_cd_pair_aligned_jaxpr_byte_identical(obs_golden):
    """Zero-overhead-off at mesh scale: the one-psum pair-aligned CD
    round jaxpr (8 devices) re-derived with telemetry disabled equals
    the pre-instrumentation golden byte-for-byte.  CD instrumentation
    is host-side span bookkeeping around ``cd_step`` — the shard_map
    program itself must be untouched."""
    rec, golden = obs_golden
    out = _run(rec.CD_PAIR_ALIGNED_SRC)
    assert out.strip() == golden["cd_pair_aligned_8dev"]
