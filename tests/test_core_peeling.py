"""PBNG two-phased peeling ≡ sequential bottom-up peeling (theorems 1-2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ref
from repro.core.graph import BipartiteGraph, powerlaw_bipartite, random_bipartite
from repro.core.peel import bup_levels, tip_decomposition, wing_decomposition


def graphs(max_u=16, max_v=14, max_m=50):
    return st.builds(
        lambda nu, nv, m, seed: random_bipartite(nu, nv, m, seed=seed),
        st.integers(2, max_u), st.integers(2, max_v),
        st.integers(0, max_m), st.integers(0, 10_000),
    )


@settings(max_examples=25, deadline=None)
@given(graphs(), st.integers(1, 6), st.sampled_from(["u", "v"]))
def test_tip_matches_bup(g, P, side):
    want = ref.bup_tip_ref(g, side)
    got = tip_decomposition(g, side=side, P=P).theta
    assert np.array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(graphs(), st.integers(1, 5))
def test_wing_beindex_matches_bup(g, P):
    want = ref.bup_wing_ref(g)
    got = wing_decomposition(g, P=P, engine="beindex").theta
    assert np.array_equal(got, want)


@settings(max_examples=12, deadline=None)
@given(graphs(), st.integers(1, 4))
def test_wing_dense_matches_bup(g, P):
    want = ref.bup_wing_ref(g)
    got = wing_decomposition(g, P=P, engine="dense").theta
    assert np.array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(graphs(), st.integers(2, 5))
def test_partitions_respect_ranges(g, P):
    """Theorem 1: every entity's θ lies inside its partition's range."""
    res = wing_decomposition(g, P=P, engine="beindex")
    for e in range(g.m):
        i = res.part[e]
        lo = res.ranges[i]
        hi = res.ranges[i + 1]
        assert lo <= res.theta[e] < hi, (e, i, lo, res.theta[e], hi)


@settings(max_examples=15, deadline=None)
@given(graphs())
def test_tip_no_batch_ablation(g):
    """§5.1 ablation path (incremental updates) must stay exact."""
    want = ref.bup_tip_ref(g, "u")
    got = tip_decomposition(g, side="u", P=3, batch_recount=False).theta
    assert np.array_equal(got, want)


def test_engines_agree_medium():
    g = powerlaw_bipartite(120, 60, 520, seed=3)
    a = wing_decomposition(g, P=6, engine="beindex")
    b = wing_decomposition(g, P=6, engine="dense")
    assert np.array_equal(a.theta, b.theta)


def test_sync_reduction_claim():
    """The paper's headline: PBNG needs far fewer synchronizations than
    level-by-level peeling (table 3/4, ρ column)."""
    g = powerlaw_bipartite(150, 80, 700, seed=9)
    res = wing_decomposition(g, P=8, engine="beindex")
    levels = bup_levels(res.theta)
    # CD rounds must be well below the number of distinct support levels
    assert res.stats.rho_cd < levels
    # ... and the FD total (ParB's per-level rounds) dwarfs CD rounds
    assert res.stats.rho_fd_total > res.stats.rho_cd


def test_support_init_is_recount():
    """⋈init recorded by CD == butterflies among entities in partitions ≥ i
    (sec 3.1.1) — cross-check against a fresh recount."""
    g = random_bipartite(25, 20, 90, seed=4)
    res = wing_decomposition(g, P=4, engine="beindex")
    for i in range(res.stats.p_effective):
        keep = res.part >= i
        sub = BipartiteGraph.from_edges(g.n_u, g.n_v, g.edges[keep])
        sub_cnt = ref.edge_butterflies_ref(sub)
        # map back
        idx = np.where(keep)[0]
        for j, e in enumerate(idx):
            if res.part[e] == i:
                assert res.support_init[e] == sub_cnt[j], (i, e)


def test_tip_both_sides_powerlaw():
    g = powerlaw_bipartite(90, 50, 380, seed=6)
    for side in ("u", "v"):
        want = ref.bup_tip_ref(g, side)
        got = tip_decomposition(g, side=side, P=5).theta
        assert np.array_equal(got, want)


def test_empty_and_degenerate():
    g = BipartiteGraph.from_edges(3, 3, np.zeros((0, 2), np.int32))
    assert wing_decomposition(g, P=2).theta.size == 0
    assert np.array_equal(tip_decomposition(g, P=2).theta, np.zeros(3))
    # a single edge has no butterflies: all thetas zero
    g = BipartiteGraph.from_edges(2, 2, [[0, 0]])
    assert np.array_equal(wing_decomposition(g, P=2).theta, [0])


def test_p_one_equals_pure_bup():
    """P=1 degenerates to (batched) bottom-up peeling — same output."""
    g = random_bipartite(20, 16, 60, seed=12)
    assert np.array_equal(
        wing_decomposition(g, P=1).theta, ref.bup_wing_ref(g)
    )
