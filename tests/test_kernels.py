"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps, interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.graph import random_bipartite
from repro.core.beindex import build_beindex
from repro.core import ref as gref
from repro.kernels import ops, ref


@pytest.mark.parametrize("n_u,n_v,m", [(40, 30, 200), (130, 70, 700), (257, 129, 1500)])
@pytest.mark.parametrize("bm,bn", [(128, 128), (256, 128)])
def test_vertex_count_kernel_sweep(n_u, n_v, m, bm, bn):
    g = random_bipartite(n_u, n_v, m, seed=n_u + m)
    A = jnp.asarray(g.adjacency())
    got = ops.vertex_butterflies(A, bm=bm, bn=bn, interpret=True)
    want = ref.vertex_butterflies_ref(A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0.5)
    # ... and against the pure-python oracle
    bu, _ = gref.vertex_butterflies_ref(g)
    np.testing.assert_array_equal(np.rint(np.asarray(got)).astype(np.int64), bu)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vertex_count_kernel_dtypes(dtype):
    g = random_bipartite(64, 48, 300, seed=9)
    A = jnp.asarray(g.adjacency()).astype(dtype)
    got = ops.vertex_butterflies(A, interpret=True)
    want = ref.vertex_butterflies_ref(A.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0.5)


@pytest.mark.parametrize("n_u,n_v,m", [(50, 40, 260), (200, 100, 1100)])
def test_edge_wedge_matrix_kernel(n_u, n_v, m):
    g = random_bipartite(n_u, n_v, m, seed=m)
    A = jnp.asarray(g.adjacency())
    got = ops.edge_wedge_matrix(A, interpret=True)
    want = ref.edge_wedge_matrix_ref(A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-2)
    # gathered per-edge counts must equal the oracle
    du = np.asarray(A.sum(axis=1))
    e = g.edges
    cnt = np.asarray(got)[e[:, 0], e[:, 1]] - (du[e[:, 0]] - 1)
    np.testing.assert_array_equal(
        np.rint(cnt).astype(np.int64), gref.edge_butterflies_ref(g)
    )


def test_bloom_update_kernel_matches_ref():
    g = random_bipartite(40, 30, 180, seed=4)
    be = build_beindex(g)
    packed = ops.pack_blooms(be.link_edge, be.link_twin, be.link_bloom, be.nb)
    nbp, K = packed["le"].shape
    rng = np.random.default_rng(0)
    peeled = np.zeros(g.m + 1, bool)
    peeled[rng.choice(g.m, size=g.m // 5, replace=False)] = True

    le = jnp.asarray(packed["le"])
    lt = jnp.asarray(packed["lt"])
    sent = g.m
    pe = jnp.asarray(peeled)[jnp.where(le < 0, sent, le)]
    pt = jnp.asarray(peeled)[jnp.where(lt < 0, sent, lt)]
    alive = jnp.asarray(packed["valid"])
    canon = jnp.asarray(packed["canon"])
    k_alive = jnp.zeros(nbp, jnp.float32).at[: be.nb].set(
        jnp.asarray(be.bloom_k.astype(np.float32))
    )
    want_contrib, want_c = ref.bloom_update_ref(pe, pt, alive, canon, k_alive)
    loss, c, new_alive = ops.bloom_update(
        jnp.asarray(peeled), alive, k_alive, le, lt, canon, interpret=True
    )
    np.testing.assert_allclose(np.asarray(c), np.asarray(want_c))
    want_loss = jax.ops.segment_sum(
        want_contrib.reshape(-1),
        jnp.where(le < 0, sent, le).reshape(-1),
        num_segments=sent + 1,
    )[:-1]
    np.testing.assert_allclose(np.asarray(loss), np.asarray(want_loss))


def test_bloom_update_kernel_equals_peeling_round():
    """One kernel round == one round of the segment-sum engine update."""
    from repro.core.peel import _wing_update

    g = random_bipartite(30, 24, 140, seed=8)
    be = build_beindex(g)
    m = g.m
    rng = np.random.default_rng(3)
    peeled = np.zeros(m, bool)
    peeled[rng.choice(m, size=m // 6, replace=False)] = True

    # engine update
    le_, lt_, lb_ = (jnp.asarray(be.link_edge), jnp.asarray(be.link_twin),
                     jnp.asarray(be.link_bloom))
    sup0 = jnp.asarray(be.edge_support(m).astype(np.int32))
    alive_link = jnp.ones((be.n_links,), bool)
    k_alive = jnp.asarray(be.bloom_k.astype(np.int32))
    _, _, sup_engine, _ = _wing_update(
        jnp.asarray(peeled), alive_link, k_alive, sup0,
        le_, lt_, lb_, max(be.nb, 1), m,
    )

    # kernel round
    packed = ops.pack_blooms(be.link_edge, be.link_twin, be.link_bloom, be.nb)
    nbp = packed["le"].shape[0]
    kk = jnp.zeros(nbp, jnp.float32).at[: be.nb].set(
        jnp.asarray(be.bloom_k.astype(np.float32)))
    loss, c, _ = ops.bloom_update(
        jnp.asarray(np.concatenate([peeled, [False]])),
        jnp.asarray(packed["valid"]), kk,
        jnp.asarray(packed["le"]), jnp.asarray(packed["lt"]),
        jnp.asarray(packed["canon"]), interpret=True,
    )
    sup_kernel = np.asarray(sup0) - np.asarray(loss).astype(np.int64)
    np.testing.assert_array_equal(
        np.asarray(sup_engine), sup_kernel.astype(np.int32)
    )


@pytest.mark.parametrize("sq,sk,d,causal", [
    (128, 128, 64, True),
    (256, 256, 64, True),
    (128, 384, 64, True),   # prefill-style: cache longer than queries
    (128, 128, 128, False),
    (256, 128, 64, True),   # sq > sk degenerate (still must not crash)
])
def test_flash_attention_sweep(sq, sk, d, causal):
    if sq > sk and causal:
        pytest.skip("queries beyond cache not defined")
    key = jax.random.PRNGKey(sq + sk + d)
    q = jax.random.normal(key, (2, 2, sq, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, sk, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, sk, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want), atol=3e-2)


@pytest.mark.parametrize("n_u,n_v,m,seed", [
    (40, 30, 180, 4), (64, 48, 320, 11), (100, 40, 450, 7),
])
@pytest.mark.parametrize("frac", [0.0, 0.2, 1.0])
@pytest.mark.parametrize("bb", [128, 256])
def test_bloom_update_interpret_parity_sweep(n_u, n_v, m, seed, frac, bb):
    """docs/KERNELS.md recipe for bloom_update: interpret-mode kernel vs
    the pure-jnp oracle across graph shapes, peel fractions (including
    the peel-none and peel-all edge cases) and block sizes."""
    g = random_bipartite(n_u, n_v, m, seed=seed)
    be = build_beindex(g)
    packed = ops.pack_blooms(be.link_edge, be.link_twin, be.link_bloom, be.nb)
    nbp = packed["le"].shape[0]
    rng = np.random.default_rng(seed)
    peeled = np.zeros(g.m + 1, bool)
    n_peel = int(g.m * frac)
    if n_peel:
        peeled[rng.choice(g.m, size=n_peel, replace=False)] = True

    le = jnp.asarray(packed["le"])
    lt = jnp.asarray(packed["lt"])
    sent = g.m
    pe = jnp.asarray(peeled)[jnp.where(le < 0, sent, le)]
    pt = jnp.asarray(peeled)[jnp.where(lt < 0, sent, lt)]
    alive = jnp.asarray(packed["valid"])
    canon = jnp.asarray(packed["canon"])
    k_alive = jnp.zeros(nbp, jnp.float32).at[: be.nb].set(
        jnp.asarray(be.bloom_k.astype(np.float32)))
    want_contrib, want_c = ref.bloom_update_ref(pe, pt, alive, canon, k_alive)
    loss, c, new_alive = ops.bloom_update(
        jnp.asarray(peeled), alive, k_alive, le, lt, canon, bb=bb,
        interpret=True)
    np.testing.assert_allclose(np.asarray(c), np.asarray(want_c))
    want_loss = jax.ops.segment_sum(
        want_contrib.reshape(-1),
        jnp.where(le < 0, sent, le).reshape(-1),
        num_segments=sent + 1,
    )[:-1]
    np.testing.assert_allclose(np.asarray(loss), np.asarray(want_loss))
    # alive-pair update: pairs die exactly when either endpoint peeled
    want_alive = np.asarray(alive) & ~(np.asarray(alive)
                                       & (np.asarray(pe) | np.asarray(pt)))
    np.testing.assert_array_equal(np.asarray(new_alive), want_alive)


@pytest.mark.parametrize("sq,sk,d,bq,bk", [
    (192, 192, 64, 128, 64),   # ragged causal: sq % bq != 0 (padded tail)
    (128, 256, 64, 64, 128),   # narrow query blocks, wide key blocks
    (256, 256, 32, 128, 64),   # small head dim
])
def test_flash_attention_interpret_parity_block_sweep(sq, sk, d, bq, bk):
    """docs/KERNELS.md recipe for flash_attention: interpret-mode kernel
    vs the dense-softmax oracle across block shapes, including the
    padded-tail causal case where sq is not a block multiple."""
    q = jax.random.normal(jax.random.PRNGKey(sq + d), (2, 2, sq, d),
                          jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2, sk, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2, sk, d), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)
