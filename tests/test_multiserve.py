"""Multi-tenant serving: pooled-dispatch oracle parity, the one-compile-
per-bucket / zero-retrace invariants, LRU + pinned/queued eviction
semantics, and artifact format-version compatibility."""
import os

import numpy as np
import pytest

from repro.core.graph import powerlaw_bipartite
from repro.core.peel import wing_decomposition
from repro.hierarchy import (
    FORMAT_VERSION,
    ForestPool,
    HierarchyService,
    MTQuery,
    MultiTenantService,
    PoolFull,
    build_hierarchy,
    load_hierarchy,
    pack_forest,
    save_hierarchy,
)
from repro.hierarchy import multiserve
from repro.hierarchy.serve import OPS


# ------------------------------------------------------------------ helpers
def _hier(nu=40, nv=28, m=120, seed=0):
    g = powerlaw_bipartite(nu, nv, m, seed=seed)
    return build_hierarchy(g, wing_decomposition(g, P=4, engine="csr"))


@pytest.fixture(scope="module")
def tenant_dir(tmp_path_factory):
    """Six artifacts over two shape buckets: big0..big3 (40x28/120,
    one bucket) and small0..small1 (12x8/24, another)."""
    d = tmp_path_factory.mktemp("tenants")
    for i in range(4):
        save_hierarchy(str(d / f"big{i}.npz"), _hier(seed=i))
    for i in range(2):
        save_hierarchy(str(d / f"small{i}.npz"),
                       _hier(nu=12, nv=8, m=24, seed=10 + i))
    return str(d)


def _workload(pool, tenants, n, seed=0):
    rng = np.random.default_rng(seed)
    t_col = [tenants[i % len(tenants)] for i in range(n)]
    ops = rng.integers(0, 5, n).astype(np.int32)
    a = np.zeros(n, np.int32)
    b = np.zeros(n, np.int32)
    for i, t in enumerate(t_col):
        m = pool.meta[t]
        lim = m.n_nodes if ops[i] == OPS["subtree_size"] else m.n_entities
        a[i] = rng.integers(0, lim)
        b[i] = rng.integers(0, m.n_entities)
    return t_col, ops, a, b


def _oracle_answers(artifact_dir, tenants, ops, a, b):
    """Per-tenant HierarchyService answers, slot by slot."""
    svcs = {}
    out = np.zeros(len(tenants), np.int32)
    for i, t in enumerate(tenants):
        if t not in svcs:
            h = load_hierarchy(os.path.join(artifact_dir, f"{t}.npz"))
            svcs[t] = HierarchyService(h, batch=8)
        out[i] = svcs[t].query_batch(
            ops[i:i + 1], a[i:i + 1], b[i:i + 1])[0]
    return out


# ------------------------------------------------------------ oracle parity
def test_mixed_tenant_batch_matches_per_tenant_service(tenant_dir):
    """The tentpole claim: slot-batched pooled dispatch is bit-identical
    to running each query through its own single-tenant service."""
    pool = ForestPool(slots=8, artifact_dir=tenant_dir)
    svc = MultiTenantService(pool, batch=64)
    active = ["big0", "big1", "big2", "small0", "small1"]
    tenants, ops, a, b = _workload_all(pool, active)
    got = svc.query_batch(tenants, ops, a, b)
    want = _oracle_answers(tenant_dir, tenants, ops, a, b)
    np.testing.assert_array_equal(got, want)


def _workload_all(pool, active, n=400, seed=1):
    for t in active:
        pool.ensure(t)
    return _workload(pool, active, n, seed=seed)


def test_submit_run_roundtrip(tenant_dir):
    pool = ForestPool(slots=8, artifact_dir=tenant_dir)
    svc = MultiTenantService(pool, batch=32)
    h = load_hierarchy(os.path.join(tenant_dir, "big0.npz"))
    oracle = HierarchyService(h, batch=8)
    svc.submit(MTQuery(uid=7, tenant="big0", op="max_k", a=3))
    svc.submit(MTQuery(uid=1, tenant="big0", op="lca_level", a=1, b=5))
    assert svc.pending() == 2
    done = svc.run()
    assert [q.uid for q in done] == [1, 7] and all(q.done for q in done)
    want = oracle.query_batch(
        np.asarray([OPS["lca_level"], OPS["max_k"]], np.int32),
        np.asarray([1, 3], np.int32), np.asarray([5, 0], np.int32))
    assert [q.result for q in done] == list(want)
    # the batch retired: queued refcounts drained back to zero
    assert all(m.queued == 0 for m in pool.meta.values())


def test_validation_uses_true_dims_not_bucket_shape(tenant_dir):
    """An id inside the padded bucket but past the tenant's real range
    must be rejected host-side (the jitted gather would clamp and
    answer confidently wrong)."""
    pool = ForestPool(slots=8, artifact_dir=tenant_dir)
    svc = MultiTenantService(pool, batch=32)
    pool.ensure("small0")
    n_ent = pool.meta["small0"].n_entities
    with pytest.raises(ValueError, match="out of range"):
        svc.query_batch(["small0"], np.asarray([OPS["max_k"]], np.int32),
                        np.asarray([n_ent], np.int32))
    with pytest.raises(ValueError, match="unknown op"):
        svc.submit(MTQuery(uid=0, tenant="small0", op="nope", a=0))


# ---------------------------------------------- compile-count invariants
def test_one_compile_per_bucket_and_zero_retrace_cold_load(tenant_dir):
    """Exactly one compiled dispatch per shape bucket, and admitting a
    cold tenant into an existing bucket must not add one (values
    change, shapes don't)."""
    multiserve._answer_batch_multi._clear_cache()
    pool = ForestPool(slots=8, artifact_dir=tenant_dir)
    svc = MultiTenantService(pool, batch=64)
    tenants, ops, a, b = _workload_all(pool, ["big0", "big1", "small0"])
    svc.query_batch(tenants, ops, a, b)
    assert multiserve.compiled_dispatch_count() == len(pool.buckets)
    # cold admissions + more traffic: the cache tracks the BUCKET
    # count, never the tenant count
    tenants, ops, a, b = _workload_all(
        pool, ["big0", "big1", "big2", "big3", "small0", "small1"], seed=2)
    svc.query_batch(tenants, ops, a, b)
    assert multiserve.compiled_dispatch_count() == len(pool.buckets)


# ------------------------------------------------------- LRU + eviction
def test_lru_order_under_interleaved_query_and_load(tenant_dir):
    """With 2 slots in the big bucket's budget, the least-recently-
    QUERIED tenant is the one evicted — interleaved traffic reorders
    the victim choice."""
    pool = ForestPool(slots=2, artifact_dir=tenant_dir)
    svc = MultiTenantService(pool, batch=16)
    pool.ensure("big0")
    pool.ensure("big1")
    # traffic touches big0 AFTER big1's admission → big1 is now LRU
    svc.query_batch(["big0"], np.asarray([OPS["max_k"]], np.int32),
                    np.asarray([0], np.int32))
    pool.ensure("big2")                      # must evict big1, not big0
    assert pool.resident("big0") and pool.resident("big2")
    assert not pool.resident("big1")
    assert pool.stats()["evictions"] == 1


def test_pinned_tenant_never_evicted(tenant_dir):
    pool = ForestPool(slots=2, artifact_dir=tenant_dir)
    pool.pin("big0")
    for t in ("big1", "big2", "big3"):
        pool.ensure(t)
    assert pool.resident("big0")
    with pytest.raises(ValueError, match="pinned"):
        pool.evict("big0")
    pool.unpin("big0")
    pool.ensure("small0")                    # now big0 is fair game
    assert not pool.resident("big0")


def test_queued_tenant_never_evicted_and_poolfull(tenant_dir):
    pool = ForestPool(slots=1, artifact_dir=tenant_dir)
    pool.ensure("big0")
    pool.note_queued("big0", +1)
    with pytest.raises(PoolFull):
        pool.ensure("big1")
    with pytest.raises(ValueError, match="queued"):
        pool.evict("big0")
    pool.note_queued("big0", -1)
    pool.ensure("big1")                      # retired batch → evictable
    assert not pool.resident("big0")


def test_evict_reload_answers_bit_identical(tenant_dir):
    """A tenant evicted and later re-admitted (different slot, possibly
    grown bucket) answers exactly as a pool that never evicted it."""
    tenants_ops = None
    answers = []
    for slots in (8, 3):                     # never-evicts vs thrashes
        pool = ForestPool(slots=slots, artifact_dir=tenant_dir)
        svc = MultiTenantService(pool, batch=32)
        if tenants_ops is None:
            for t in ("big0", "big1", "big2"):
                pool.ensure(t)
            tenants_ops = _workload(pool, ["big0", "big1", "big2"], 120,
                                    seed=3)
        t_col, ops, a, b = tenants_ops
        if slots == 3:                       # force churn before serving
            for t in ("big0", "big1", "big2", "big3", "big0"):
                pool.ensure(t)
            assert pool.stats()["evictions"] >= 2
        answers.append(svc.query_batch(t_col, ops, a, b))
    np.testing.assert_array_equal(answers[0], answers[1])


def test_admission_cannot_evict_tenant_of_same_batch(tenant_dir):
    """A batch referencing a resident tenant plus a cold one, on a pool
    with no headroom: the cold load must not evict the co-batched
    resident tenant (it raises PoolFull instead of serving wrong)."""
    pool = ForestPool(slots=1, artifact_dir=tenant_dir)
    svc = MultiTenantService(pool, batch=16)
    pool.ensure("big0")
    ops = np.asarray([OPS["max_k"]] * 2, np.int32)
    z = np.zeros(2, np.int32)
    with pytest.raises(PoolFull):
        svc.query_batch(["big0", "big1"], ops, z, z)
    assert pool.resident("big0")
    assert all(m.queued == 0 for m in pool.meta.values())  # pins released


# --------------------------------------------------- artifact versions
def test_v1_artifact_loads_through_loader_branch(tenant_dir, tmp_path):
    """Old-format artifacts written before the pack cache existed must
    keep loading (and serving) through the v1 loader branch."""
    h = _hier(seed=0)
    p1 = str(tmp_path / "old.npz")
    save_hierarchy(p1, h, version=1)
    h1 = load_hierarchy(p1)
    assert "pack_up" not in h1.meta          # v1 carries no pack cache
    np.testing.assert_array_equal(h1.theta, h.theta)

    p2 = str(tmp_path / "new.npz")
    save_hierarchy(p2, h)                    # current version
    h2 = load_hierarchy(p2)
    assert h2.meta["pack_up"].shape[0] == h.n_nodes
    # both versions produce identical packed forests
    f1, f2 = pack_forest(h1), pack_forest(h2)
    np.testing.assert_array_equal(np.asarray(f1.up), np.asarray(f2.up))
    np.testing.assert_array_equal(np.asarray(f1.depth),
                                  np.asarray(f2.depth))


def test_pool_serves_v1_and_v2_tenants_identically(tmp_path):
    d = str(tmp_path)
    h = _hier(seed=5)
    save_hierarchy(os.path.join(d, "v1t.npz"), h, version=1)
    save_hierarchy(os.path.join(d, "v2t.npz"), h)
    pool = ForestPool(slots=4, artifact_dir=d)
    svc = MultiTenantService(pool, batch=16)
    t_col, ops, a, b = _workload_all(pool, ["v1t"], n=60, seed=4)
    got1 = svc.query_batch(t_col, ops, a, b)
    got2 = svc.query_batch(["v2t"] * len(t_col), ops, a, b)
    np.testing.assert_array_equal(got1, got2)


def test_format_version_bumped_for_pack_cache():
    assert FORMAT_VERSION == 2


def test_unwritable_version_rejected(tmp_path):
    with pytest.raises(ValueError, match="cannot write"):
        save_hierarchy(str(tmp_path / "x.npz"), _hier(nu=12, nv=8, m=24),
                       version=99)


def test_obs_off_dispatch_jaxpr_byte_identical(obs_golden):
    """Zero-overhead-off for the serving layer: the batched multi-tenant
    dispatch jaxpr with telemetry disabled equals the
    pre-instrumentation golden byte-for-byte — the serve spans/metrics
    are host-side only and must never enter the compiled program."""
    from repro import obs

    rec, golden = obs_golden
    assert not obs.enabled()
    got = rec.CASES["multiserve_dispatch"]()
    assert got == golden["multiserve_dispatch"], \
        "dispatch jaxpr drifted from the telemetry-off golden"
