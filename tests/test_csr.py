"""CSR wedge-list engine ≡ BUP oracle, plus wedge-count kernel parity."""
import os

import jax
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import csr, ref
from repro.core.graph import BipartiteGraph, powerlaw_bipartite, random_bipartite
from repro.core.peel import tip_decomposition, wing_decomposition
from repro.kernels import ops
from repro.kernels import ref as kref


def graphs(max_u=16, max_v=14, max_m=50):
    return st.builds(
        lambda nu, nv, m, seed: random_bipartite(nu, nv, m, seed=seed),
        st.integers(2, max_u), st.integers(2, max_v),
        st.integers(0, max_m), st.integers(0, 10_000),
    )


# ------------------------------------------------------------- counting
@pytest.mark.parametrize("seed", range(6))
def test_csr_counts_match_oracle(seed):
    g = random_bipartite(30, 24, 140, seed=seed)
    w = csr.build_wedges(g)
    bu, _ = ref.vertex_butterflies_ref(g)
    assert np.array_equal(csr.vertex_butterflies_csr(w), bu)
    got_e = np.asarray(csr.edge_butterflies_csr(w)).astype(np.int64)
    assert np.array_equal(got_e, ref.edge_butterflies_ref(g))
    assert np.array_equal(csr.edge_butterflies0(w), got_e)
    assert csr.total_butterflies_csr(w) == ref.butterfly_count_total(g)
    wu, wv = csr.wedge_workload(g)
    ru, rv = ref.wedge_count_ref(g)
    assert np.array_equal(wu, ru) and np.array_equal(wv, rv)


@pytest.mark.parametrize("seed", range(4))
def test_csr_masked_recount_matches_subgraph_oracle(seed):
    g = random_bipartite(24, 20, 110, seed=seed)
    w = csr.build_wedges(g)
    rng = np.random.default_rng(seed)
    alive = rng.random(g.m) > 0.35
    sub = BipartiteGraph.from_edges(g.n_u, g.n_v, g.edges[alive])
    got = np.asarray(csr.edge_butterflies_csr(w, jnp.asarray(alive)))[alive]
    assert np.array_equal(got.astype(np.int64), ref.edge_butterflies_ref(sub))


@pytest.mark.parametrize("seed", range(4))
def test_csr_incremental_update_equals_recount(seed):
    """One wing_update_csr round == recount on the shrunken subgraph."""
    g = random_bipartite(22, 18, 100, seed=seed)
    w = csr.build_wedges(g)
    rng = np.random.default_rng(seed + 100)
    peeled = rng.random(g.m) < 0.3
    alive = ~peeled
    we1, we2, wp = map(jnp.asarray, (w.wedge_e1, w.wedge_e2, w.wedge_pair))
    _, _, sup, _ = csr.wing_update_csr(
        jnp.asarray(peeled),
        jnp.ones((w.n_wedges,), bool),
        csr.pair_wedge_counts(w),
        csr.edge_butterflies_csr(w),
        we1, we2, wp, w.n_pairs, g.m,
    )
    want = np.asarray(csr.edge_butterflies_csr(w, jnp.asarray(alive)))
    assert np.array_equal(np.asarray(sup)[alive], want[alive])


def test_empty_and_tiny_graphs():
    for edges in ([], [[0, 0]], [[0, 0], [1, 1]]):
        g = BipartiteGraph.from_edges(2, 2, np.asarray(edges, np.int32).reshape(-1, 2))
        w = csr.build_wedges(g)
        assert csr.total_butterflies_csr(w) == ref.butterfly_count_total(g)
        res = wing_decomposition(g, P=2, engine="csr")
        assert np.array_equal(res.theta, ref.bup_wing_ref(g))


# ------------------------------------------------------------- peeling
@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("P", [1, 4])
def test_tip_csr_matches_bup(seed, P):
    g = random_bipartite(16, 13, 48, seed=seed)
    for side in ("u", "v"):
        want = ref.bup_tip_ref(g, side)
        got = tip_decomposition(g, side=side, P=P, engine="csr").theta
        assert np.array_equal(got, want)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("P", [1, 4])
def test_wing_csr_matches_bup(seed, P):
    g = random_bipartite(16, 13, 48, seed=seed)
    want = ref.bup_wing_ref(g)
    got = wing_decomposition(g, P=P, engine="csr").theta
    assert np.array_equal(got, want)


def test_wing_csr_matches_beindex_on_skewed_graph():
    g = powerlaw_bipartite(80, 50, 420, seed=11)
    r_csr = wing_decomposition(g, P=8, engine="csr")
    r_be = wing_decomposition(g, P=8, engine="beindex")
    assert np.array_equal(r_csr.theta, r_be.theta)
    assert r_csr.stats.rho_cd > 0 and r_csr.stats.updates > 0


@settings(max_examples=20, deadline=None)
@given(graphs(), st.integers(1, 5), st.sampled_from(["u", "v"]))
def test_tip_csr_matches_bup_property(g, P, side):
    want = ref.bup_tip_ref(g, side)
    got = tip_decomposition(g, side=side, P=P, engine="csr").theta
    assert np.array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(graphs(), st.integers(1, 4))
def test_wing_csr_matches_bup_property(g, P):
    want = ref.bup_wing_ref(g)
    got = wing_decomposition(g, P=P, engine="csr").theta
    assert np.array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(graphs(max_u=12, max_v=10, max_m=40), st.integers(1, 4))
def test_wing_engines_and_fd_drivers_agree_property(g, P):
    """csr (device while_loop FD), csr (vmapped single-dispatch FD), csr
    (host-loop FD) and dense must all produce identical theta — and the
    three FD drivers identical round / update counts (same cascade,
    different residency)."""
    dev = wing_decomposition(g, P=P, engine="csr", fd_driver="device")
    vm = wing_decomposition(g, P=P, engine="csr", fd_driver="vmapped")
    host = wing_decomposition(g, P=P, engine="csr", fd_driver="host")
    dense = wing_decomposition(g, P=P, engine="dense")
    assert np.array_equal(dev.theta, host.theta)
    assert np.array_equal(dev.theta, vm.theta)
    assert np.array_equal(dev.theta, dense.theta)
    assert dev.stats.rho_fd_total == host.stats.rho_fd_total
    assert dev.stats.rho_fd_total == vm.stats.rho_fd_total
    assert dev.stats.rho_fd_max == vm.stats.rho_fd_max
    assert dev.stats.updates == host.stats.updates
    assert dev.stats.updates == vm.stats.updates
    assert dev.stats.fd_driver == "device"
    assert vm.stats.fd_driver == "vmapped"
    assert host.stats.fd_driver == "host"


@settings(max_examples=15, deadline=None)
@given(graphs(max_u=12, max_v=10, max_m=40), st.integers(1, 4),
       st.sampled_from(["u", "v"]))
def test_tip_engines_and_fd_drivers_agree_property(g, P, side):
    dev = tip_decomposition(g, side=side, P=P, engine="csr",
                            fd_driver="device")
    host = tip_decomposition(g, side=side, P=P, engine="csr",
                             fd_driver="host")
    dense = tip_decomposition(g, side=side, P=P, engine="dense")
    assert np.array_equal(dev.theta, host.theta)
    assert np.array_equal(dev.theta, dense.theta)
    assert dev.stats.rho_fd_total == host.stats.rho_fd_total


# -------------------------------------------------------- scale / guard
def test_dense_engine_guarded_csr_peels_50k_graph():
    """The acceptance graph: 50k×50k, avg degree 8.

    The dense engine must refuse it up front (its adjacency alone is
    10 GB); the csr engine must peel it."""
    g = random_bipartite(50_000, 50_000, 400_000, seed=0)
    with pytest.raises(MemoryError):
        tip_decomposition(g, P=4, engine="dense")
    with pytest.raises(MemoryError):
        wing_decomposition(g, P=4, engine="dense")
    res = tip_decomposition(g, P=4, engine="csr")
    assert res.theta.shape == (g.n_u,)
    assert res.stats.rho_cd > 0
    resw = wing_decomposition(g, P=4, engine="csr")
    assert resw.theta.shape == (g.m,)


def test_dense_guard_env_override(monkeypatch):
    g = random_bipartite(40, 30, 150, seed=1)
    monkeypatch.setitem(os.environ, "REPRO_DENSE_MAX_ELEMS", "100")
    with pytest.raises(MemoryError):
        tip_decomposition(g, P=2, engine="dense")


# ------------------------------------------------------------- kernels
@pytest.mark.parametrize("shape", [(7, 30), (64, 128), (130, 260)])
def test_wedge_count_kernel_matches_ref(shape):
    rng = np.random.default_rng(shape[0])
    slots = jnp.asarray(rng.random(shape) > 0.4)
    W, bf = ops.pair_wedge_counts(slots, interpret=True)
    Wr, bfr = kref.pair_wedge_counts_ref(slots)
    np.testing.assert_array_equal(np.asarray(W), np.asarray(Wr))
    np.testing.assert_array_equal(np.asarray(bf), np.asarray(bfr))


@pytest.mark.parametrize("seed", range(3))
def test_wedge_count_kernel_matches_segment_sum(seed):
    g = random_bipartite(60, 45, 350, seed=seed)
    w = csr.build_wedges(g)
    rng = np.random.default_rng(seed)
    for alive in (None, jnp.asarray(rng.random(g.m) > 0.25)):
        Wseg = np.asarray(csr.pair_wedge_counts(w, alive))
        Wpal = np.asarray(
            csr.pair_wedge_counts(w, alive, use_pallas=True, interpret=True)
        )
        assert np.array_equal(Wseg, Wpal)
        s_seg = np.asarray(csr.edge_butterflies_csr(w, alive))
        s_pal = np.asarray(
            csr.edge_butterflies_csr(w, alive, use_pallas=True, interpret=True)
        )
        assert np.array_equal(s_seg, s_pal)


@pytest.mark.parametrize("shape", [(7, 30), (64, 128), (130, 260)])
def test_support_update_kernel_matches_ref(shape):
    """Interpret-mode parity: blocked support-update kernel vs oracle."""
    rng = np.random.default_rng(shape[1])
    alive = rng.random(shape) > 0.3
    pe1 = rng.random(shape) > 0.6
    pe2 = rng.random(shape) > 0.6
    W = rng.integers(0, 40, shape[0])
    args = (jnp.asarray(pe1), jnp.asarray(pe2), jnp.asarray(alive),
            jnp.asarray(W.astype(np.float32)))
    c1, c2, c = ops.support_update(*args, interpret=True)
    r1, r2, rc = kref.support_update_ref(*args)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(r1))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))


@pytest.mark.parametrize("seed", range(3))
def test_wing_update_slots_matches_segment_sum(seed):
    """One Pallas slot-layout update round == one flat wing_update_csr
    round (support, W, alive, and update counts all identical)."""
    g = random_bipartite(22, 18, 100, seed=seed)
    w = csr.build_wedges(g)
    rng = np.random.default_rng(seed + 500)
    peeled = rng.random(g.m) < 0.3
    we1, we2, wp = map(jnp.asarray, (w.wedge_e1, w.wedge_e2, w.wedge_pair))
    W0 = csr.pair_wedge_counts(w)
    sup0 = csr.edge_butterflies_csr(w)
    a_f, W_f, s_f, n_f = csr.wing_update_csr(
        jnp.asarray(peeled), jnp.ones((w.n_wedges,), bool), W0, sup0,
        we1, we2, wp, w.n_pairs, g.m)
    slots = csr.pack_update_slots(w)
    a_s, W_s, s_s, n_s = csr.wing_update_slots(
        jnp.asarray(peeled), jnp.asarray(slots["valid"]), W0, sup0,
        jnp.asarray(slots["e1"]), jnp.asarray(slots["e2"]),
        w.n_pairs, g.m, interpret=True)
    assert np.array_equal(np.asarray(W_f), np.asarray(W_s))
    assert np.array_equal(np.asarray(s_f), np.asarray(s_s))
    assert int(n_f) == int(n_s)
    packed = csr.pack_wedge_slots(w)
    flat_alive = np.zeros(w.n_wedges, bool)
    flat_alive[np.maximum(packed.idx, 0)[packed.valid]] = np.asarray(
        a_s)[packed.valid]
    assert np.array_equal(flat_alive, np.asarray(a_f))


def test_wing_csr_pallas_cd_matches():
    """Full decomposition with the Pallas CD update path ≡ segment_sum."""
    g = powerlaw_bipartite(60, 40, 260, seed=3)
    r0 = wing_decomposition(g, P=6, engine="csr")
    r1 = wing_decomposition(g, P=6, engine="csr", use_pallas=True)
    assert np.array_equal(r0.theta, r1.theta)
    assert r0.stats.rho_cd == r1.stats.rho_cd
    assert r0.stats.updates == r1.stats.updates


def test_fd_device_driver_is_single_while_loop():
    """The acceptance property: one partition's csr FD cascade lowers to
    exactly one while op — zero host round-trips inside a partition."""
    from repro.core.peel import _fd_tip_device, _fd_wing_device

    g = random_bipartite(16, 13, 48, seed=0)
    w = csr.build_wedges(g)
    mine = jnp.ones((g.n_u,), bool)
    sup0 = jnp.asarray(csr.vertex_butterflies_csr(w).astype(np.int32))
    jaxpr = jax.make_jaxpr(
        lambda *a: _fd_tip_device(*a, n=g.n_u)
    )(mine, sup0, jnp.asarray(w.pair_a), jnp.asarray(w.pair_b),
      jnp.asarray(w.pair_butterflies0().astype(np.int32)))
    assert str(jaxpr).count("while[") == 1

    mine_e = jnp.ones((g.m,), bool)
    sup_e = jnp.asarray(csr.edge_butterflies0(w).astype(np.int32))
    jaxpr_w = jax.make_jaxpr(
        lambda *a: _fd_wing_device(*a, n_pairs=w.n_pairs, m=g.m)
    )(mine_e, sup_e, jnp.ones((w.n_wedges,), bool),
      jnp.asarray(w.W0.astype(np.int32)),
      jnp.asarray(w.wedge_e1), jnp.asarray(w.wedge_e2),
      jnp.asarray(w.wedge_pair))
    assert str(jaxpr_w).count("while[") == 1


def test_vmapped_fd_single_while_zero_collectives():
    """The acceptance property of the single-dispatch FD: the FULL csr
    Phase 2 — every partition — lowers to exactly ONE while op with zero
    collectives, for both the segment-sum and the in-loop Pallas body
    (not one while per partition: one, total)."""
    from repro.core import distributed as D
    from repro.core.peel import _fd_wing_vmapped, _fd_wing_vmapped_pallas

    g = powerlaw_bipartite(60, 40, 260, seed=3)
    wed = csr.build_wedges(g)
    res = wing_decomposition(g, P=6, engine="csr")
    assert res.stats.p_effective > 1  # a real multi-partition cascade
    packed = D.pack_fd_partitions_csr(
        wed, res.part, res.support_init, res.stats.p_effective,
        bucket=True, flat=True, slots=True,
    )
    args = tuple(jnp.asarray(packed[k]) for k in
                 ("flat_we1", "flat_we2", "flat_wp", "flat_alive0",
                  "flat_W0", "mine", "sup0"))
    n_pairs = int(packed["flat_W0"].shape[0])
    jaxpr = str(jax.make_jaxpr(
        lambda *a: _fd_wing_vmapped(*a, n_pairs=n_pairs))(*args))
    assert jaxpr.count("while[") == 1
    for coll in ("psum", "all_reduce", "all_gather", "ppermute",
                 "all_to_all"):
        assert coll not in jaxpr, coll

    R, _ = packed["slot_sizes"]
    W_rows = np.zeros((packed["W0"].shape[0], R), np.int32)
    w = min(R, packed["W0"].shape[1])
    W_rows[:, :w] = packed["W0"][:, :w]
    argsp = (jnp.asarray(packed["slot_e1"]), jnp.asarray(packed["slot_e2"]),
             jnp.asarray(packed["slot_valid"]), jnp.asarray(W_rows),
             jnp.asarray(packed["mine"]), jnp.asarray(packed["sup0"]))
    jaxpr_p = str(jax.make_jaxpr(
        lambda *a: _fd_wing_vmapped_pallas(*a, interpret=True))(*argsp))
    assert jaxpr_p.count("while[") == 1
    for coll in ("psum", "all_reduce", "all_gather", "ppermute",
                 "all_to_all"):
        assert coll not in jaxpr_p, coll


@pytest.mark.parametrize("seed", range(3))
def test_wing_fd_vmapped_pallas_matches(seed):
    """vmapped FD with the in-loop Pallas support_update kernel ≡ the
    segment-sum body AND the per-partition driver: bit-identical θ,
    identical round/update counts (interpret-mode parity)."""
    g = powerlaw_bipartite(40, 30, 180, seed=seed)
    dev = wing_decomposition(g, P=4, engine="csr", fd_driver="device")
    vm = wing_decomposition(g, P=4, engine="csr", fd_driver="vmapped")
    vmp = wing_decomposition(g, P=4, engine="csr", fd_driver="vmapped",
                             use_pallas=True)
    assert np.array_equal(dev.theta, vm.theta)
    assert np.array_equal(dev.theta, vmp.theta)
    assert dev.stats.rho_fd_total == vm.stats.rho_fd_total \
        == vmp.stats.rho_fd_total
    assert dev.stats.rho_fd_max == vm.stats.rho_fd_max \
        == vmp.stats.rho_fd_max
    assert dev.stats.updates == vm.stats.updates == vmp.stats.updates


@settings(max_examples=10, deadline=None)
@given(graphs(max_u=12, max_v=10, max_m=40), st.integers(1, 4),
       st.sampled_from(["u", "v"]))
def test_tip_fd_vmapped_matches_property(g, P, side):
    dev = tip_decomposition(g, side=side, P=P, engine="csr",
                            fd_driver="device")
    vm = tip_decomposition(g, side=side, P=P, engine="csr",
                           fd_driver="vmapped")
    assert np.array_equal(dev.theta, vm.theta)
    assert dev.stats.rho_fd_total == vm.stats.rho_fd_total
    assert dev.stats.rho_fd_max == vm.stats.rho_fd_max


def test_vmapped_fd_mixed_shape_buckets():
    """Partitions whose individual sizes straddle different quarter-pow2
    buckets must still land in ONE stacked layout and one while_loop —
    and peel exactly.  A dense blob + a sparse tail forces a large and a
    small partition."""
    from repro.core import distributed as D
    from repro.core.peel import _bucket_pad

    rng = np.random.default_rng(7)
    # dense 8×8 complete blob (huge uniform supports) + a moderate
    # 30×20 block at 0.3 density on a DISJOINT V block: CD puts the
    # moderate block in partition 0 and the blob in partition 1, with
    # wedge-list sizes in different quarter-pow2 buckets
    blob = [(u, v) for u in range(8) for v in range(8)]
    mid = [(8 + u, 8 + v) for u in range(30) for v in range(20)
           if rng.random() < 0.3]
    edges = np.asarray(blob + mid, dtype=np.int32)
    g = BipartiteGraph.from_edges(38, 28, edges)
    res = wing_decomposition(g, P=4, engine="csr")
    n_parts = res.stats.p_effective
    assert n_parts > 1
    wed = csr.build_wedges(g)
    # per-partition touching-wedge list sizes must fall in distinct
    # quarter-pow2 buckets (the per-partition launcher would compile one
    # while_loop per bucket; the vmapped driver still gets ONE layout)
    pe1 = res.part[wed.wedge_e1]
    pe2 = res.part[wed.wedge_e2]
    pmin = np.minimum(pe1, pe2)
    sizes = [int(((pe1 >= i) & (pe2 >= i) & (pmin == i)).sum())
             for i in range(n_parts)]
    buckets = {_bucket_pad(s) for s in sizes}
    assert len(buckets) > 1, (sizes, buckets)

    packed = D.pack_fd_partitions_csr(
        wed, res.part, res.support_init, n_parts, bucket=True, flat=True)
    # one stacked layout: the rectangular stack pads every partition to
    # the SAME bucketed slot count; the flat concat holds all real
    # wedges in one bucketed run
    assert packed["we1"].shape[1] == _bucket_pad(max(sizes))
    assert packed["flat_we1"].shape[0] == _bucket_pad(sum(sizes))
    assert int(packed["flat_alive0"].sum()) == sum(sizes)
    for drv in ("vmapped",):
        r = wing_decomposition(g, P=4, engine="csr", fd_driver=drv)
        assert np.array_equal(r.theta, res.theta)
        assert r.stats.rho_fd_total == res.stats.rho_fd_total
    rp = wing_decomposition(g, P=4, engine="csr", fd_driver="vmapped",
                            use_pallas=True)
    assert np.array_equal(rp.theta, res.theta)


def test_peel_stats_per_engine_rho():
    """sync_reduction / as_dict must reflect the engine that actually
    ran — csr and dense report their own rho, tagged with the engine."""
    g = random_bipartite(20, 16, 70, seed=2)
    rc = wing_decomposition(g, P=4, engine="csr")
    rd = wing_decomposition(g, P=4, engine="dense")
    assert rc.stats.engine == "csr" and rd.stats.engine == "dense"
    dc = rc.stats.as_dict()
    assert dc["rho"] == rc.stats.rho_cd
    assert dc["sync_reduction"] == round(
        rc.stats.rho_fd_total / max(rc.stats.rho_cd, 1), 3)
    assert dc["fd_driver"] == "device"


def test_pad_segments_roundtrip():
    ids = np.asarray([0, 0, 2, 2, 2, 4], np.int32)
    p = csr.pad_segments(ids, 5)
    assert p.width % 128 == 0 and p.n_rows_pad % 8 == 0
    counts = p.valid.sum(axis=1)
    assert list(counts[:5]) == [2, 0, 3, 0, 1]
    # every original item appears exactly once
    got = np.sort(p.idx[p.valid])
    assert np.array_equal(got, np.arange(ids.size))
