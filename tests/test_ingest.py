"""Out-of-core ingestion + bounded-tile counting correctness.

Three contracts:

* **Chunk/order invariance** (hypothesis): the chunked streaming
  dedup + degree-ordered relabel must produce the bit-identical
  :class:`IngestedGraph` for ANY chunk size and ANY line order —
  presence is the sign-net of inserts/deletes, so duplicates,
  self-cancelling lines, isolated vertices and non-contiguous raw ids
  all reduce the same way.  A dict-based oracle defines the semantics.
* **Tiled ≡ untiled ⋈init**: ``csr.tiled_butterfly_init`` must be
  bit-identical to the flat wedge-list counts on the paper proxies,
  host and Pallas tile paths alike.
* **End-to-end golden**: the committed real dataset ingests, counts
  and peels to the θ checksums recorded in
  ``tests/goldens/real_graphs.json``.
"""
import hashlib
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import csr
from repro.core.graph import paper_proxy_dataset, powerlaw_bipartite
from repro.data.ingest import ingest_edges

HERE = os.path.dirname(os.path.abspath(__file__))
DATASET = os.path.join(HERE, "..", "datasets", "southern_women.tsv")


# ---------------------------------------------------------------- oracle
def _oracle(ops):
    """Reference semantics for a list of (u_raw, v_raw, sign) lines."""
    net = {}
    for u, v, s in ops:
        net[(u, v)] = net.get((u, v), 0) + s
    present = sorted(k for k, n in net.items() if n > 0)
    vocab_u = sorted({u for u, _, _ in ops})
    vocab_v = sorted({v for _, v, _ in ops})
    deg_u, deg_v = {}, {}
    for u, v in present:
        deg_u[u] = deg_u.get(u, 0) + 1
        deg_v[v] = deg_v.get(v, 0) + 1

    def ranks(vocab, deg):
        order = sorted(vocab, key=lambda r: (-deg.get(r, 0), r))
        return {r: i for i, r in enumerate(order) if deg.get(r, 0) > 0}

    ru, rv = ranks(vocab_u, deg_u), ranks(vocab_v, deg_v)
    edges = sorted((ru[u], rv[v]) for u, v in present)
    return edges, len(ru), len(rv)


def _write(path, ops, order=None, header=True):
    lines = [f"{u}\t{v}" if s > 0 else f"{u}\t{v}\t-1" for u, v, s in ops]
    if order is not None:
        lines = [lines[i] for i in order]
    with open(path, "w") as f:
        if header:
            f.write("% bip unweighted\n")
        f.write("\n".join(lines) + ("\n" if lines else ""))


def _assert_graph(ig, ops):
    edges, n_u, n_v = _oracle(ops)
    assert (ig.n_u, ig.n_v, ig.m) == (n_u, n_v, len(edges))
    got = [tuple(map(int, e)) for e in np.asarray(ig.edges)]
    assert got == edges
    du, dv = ig.degrees()
    # degree-ordered relabel: ranks are decreasing-degree on both sides
    assert all(du[i] >= du[i + 1] for i in range(n_u - 1))
    assert all(dv[i] >= dv[i + 1] for i in range(n_v - 1))
    # V-CSR view consistent with the edge list
    off, nbr, eid = ig.csr_v()
    assert np.array_equal(np.sort(eid), np.arange(ig.m))
    u_of = np.asarray(ig.edges)[:, 0]
    v_of = np.asarray(ig.edges)[:, 1]
    centers = np.repeat(np.arange(n_v), np.diff(off))
    assert np.array_equal(v_of[eid], centers)
    assert np.array_equal(u_of[eid], nbr)


# non-contiguous raw ids exercise the vocab compaction
def _raw(u, v):
    return 7 * u + 3, 1_000_000 + 13 * v


_OPS = st.lists(
    st.tuples(st.booleans(), st.integers(0, 9), st.integers(0, 7)),
    min_size=1, max_size=40)


@settings(max_examples=60, deadline=None)
@given(_OPS, st.randoms(use_true_random=False))
def test_ingest_invariant_to_chunks_and_order(raw_ops, rng, tmp_path_factory):
    tmp = tmp_path_factory.mktemp("ing")
    ops = [(*_raw(u, v), 1 if ins else -1) for ins, u, v in raw_ops]
    p0 = str(tmp / "a.tsv")
    _write(p0, ops)
    ig0 = ingest_edges(p0, out_dir=str(tmp / "a.ing"))
    _assert_graph(ig0, ops)
    # chunk-size invariance, including chunk=1 (one edge resident)
    for ce in (1, 3):
        igc = ingest_edges(p0, out_dir=str(tmp / f"c{ce}.ing"),
                           chunk_edges=ce)
        assert np.array_equal(np.asarray(igc.edges), np.asarray(ig0.edges))
        assert (igc.n_u, igc.n_v, igc.m) == (ig0.n_u, ig0.n_v, ig0.m)
    # line-order invariance (net semantics are order-free)
    order = list(range(len(ops)))
    rng.shuffle(order)
    p1 = str(tmp / "b.tsv")
    _write(p1, ops, order=order)
    ig1 = ingest_edges(p1, out_dir=str(tmp / "b.ing"), chunk_edges=5)
    assert np.array_equal(np.asarray(ig1.edges), np.asarray(ig0.edges))
    assert (ig1.n_u, ig1.n_v, ig1.m) == (ig0.n_u, ig0.n_v, ig0.m)


def test_ingest_edge_cases(tmp_path):
    # self-cancelling pair + duplicate inserts + isolated-by-deletion
    ops = [(5, 100, 1), (5, 100, -1),       # cancels: u=5 isolated
           (7, 100, 1), (7, 100, 1),        # duplicate insert (net 2)
           (9, 200, 1)]
    p = str(tmp_path / "e.tsv")
    _write(p, ops)
    ig = ingest_edges(p, out_dir=str(tmp_path / "e.ing"))
    _assert_graph(ig, ops)
    assert ig.m == 2 and ig.n_u == 2  # raw u=5 dropped entirely
    assert ig.meta["n_dropped_u"] == 1

    # cache hit returns without re-ingesting; refresh rebuilds
    ig2 = ingest_edges(p, out_dir=str(tmp_path / "e.ing"))
    assert np.array_equal(np.asarray(ig2.edges), np.asarray(ig.edges))

    # everything cancels -> empty graph
    p0 = str(tmp_path / "z.tsv")
    _write(p0, [(1, 2, 1), (1, 2, -1)])
    igz = ingest_edges(p0, out_dir=str(tmp_path / "z.ing"))
    assert (igz.n_u, igz.n_v, igz.m) == (0, 0, 0)


# ------------------------------------------------- tiled ≡ untiled ⋈init
@pytest.mark.parametrize("tile_wedges,use_pallas,width", [
    (700, False, 512),
    (10 ** 9, False, 512),    # single tile == whole graph
    (2500, True, 64),         # Pallas rows, hub pairs split across rows
])
def test_tiled_init_bit_identical_fr(tile_wedges, use_pallas, width):
    g = paper_proxy_dataset("fr")
    w = csr.build_wedges(g)
    sup_e, sup_u, total, stats = csr.tiled_butterfly_init(
        g, tile_wedges=tile_wedges, use_pallas=use_pallas, width=width)
    assert np.array_equal(sup_e, csr.edge_butterflies0(w))
    assert np.array_equal(sup_u, csr.vertex_butterflies_csr(w))
    assert total == csr.total_butterflies_csr(w)
    assert stats.n_wedges == w.n_wedges
    assert stats.n_pairs == w.n_pairs
    if tile_wedges < w.n_wedges:
        assert stats.n_tiles > 1
        # the bounded-memory claim: peak ≈ tile budget, not Σ deg²
        assert stats.peak_tile_wedges < w.n_wedges


def test_tiled_init_peak_bounded_by_budget():
    g = powerlaw_bipartite(300, 200, 2400, seed=5)
    w = csr.build_wedges(g)
    per_u = np.zeros(g.n_u, dtype=np.int64)
    np.add.at(per_u, np.minimum(w.pair_a, w.pair_b)[w.wedge_pair], 1)
    budget = 512
    _, _, _, stats = csr.tiled_butterfly_init(g, tile_wedges=budget)
    # a tile only exceeds the budget via one hub vertex's own wedges
    assert stats.peak_tile_wedges <= budget + int(per_u.max())


# -------------------------------------------------- end-to-end real graph
def _sha(theta):
    return hashlib.sha256(
        np.asarray(theta, dtype=np.int64).tobytes()).hexdigest()


def test_real_graph_end_to_end_golden(tmp_path):
    from repro.core.peel import tip_decomposition, wing_decomposition

    with open(os.path.join(HERE, "goldens", "real_graphs.json")) as f:
        want = json.load(f)["southern_women"]
    ig = ingest_edges(DATASET, out_dir=str(tmp_path / "sw.ing"))
    assert (ig.n_u, ig.n_v, ig.m) == (want["n_u"], want["n_v"], want["m"])
    sup_e, sup_u, total, _ = csr.tiled_butterfly_init(ig, tile_wedges=64)
    assert total == want["total_butterflies"]
    g = ig.as_graph()
    wing = wing_decomposition(g, engine="csr", sup0=sup_e)
    assert _sha(wing.theta) == want["theta_wing_sha256"]
    tip = tip_decomposition(g, side="u", engine="csr", sup0=sup_u)
    assert _sha(tip.theta) == want["theta_tip_u_sha256"]
