"""Continuous-batching engine: correctness vs single-request decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models as M
from repro.configs import get_config
from repro.models.config import reduced
from repro.serve import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("tinyllama_1_1b"), n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _single_decode(cfg, params, prompt, max_new, max_seq=64):
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype),
        M.cache_specs(cfg, 1, max_seq, dtype=jnp.float32))
    out = []
    tok = jnp.asarray([prompt[0]], jnp.int32)
    pos = 0
    todo = list(prompt[1:])
    while len(out) < max_new:
        logits, cache = M.serve_step(params, cache, tok, jnp.int32(pos), cfg)
        pos += 1
        if todo:
            tok = jnp.asarray([todo.pop(0)], jnp.int32)
        else:
            nxt = int(jnp.argmax(logits[0]))
            out.append(nxt)
            tok = jnp.asarray([nxt], jnp.int32)
    return out


def test_batched_equals_single(model):
    cfg, params = model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=5).tolist()
               for _ in range(3)]
    want = [_single_decode(cfg, params, p, 6) for p in prompts]

    eng = ContinuousBatcher(cfg, params, n_slots=3, max_seq=64)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new=6))
    done = eng.run()
    assert len(done) == 3
    for i, r in enumerate(done):
        assert r.output == want[i], (i, r.output, want[i])


def test_queue_drains_with_fewer_slots_than_requests(model):
    cfg, params = model
    rng = np.random.default_rng(1)
    eng = ContinuousBatcher(cfg, params, n_slots=2, max_seq=64)
    for i in range(5):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab, size=4).tolist(),
            max_new=4))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.output) == 4 for r in done)
    assert eng.pending() == 0


def test_eos_early_stop(model):
    """Generation stops at the FIRST eos occurrence, eos included.

    The seed version of this test hard-coded ``ref_out[:2]`` as the
    expectation after probing ``eos = ref_out[1]`` — an off-by-one in
    the *expected output construction*: greedy decode repeats the same
    argmax token here, so the probed value's first occurrence is at
    index 0 and the engine (correctly) stops one token earlier than the
    hard-coded prefix.  The expectation now derives the stop point from
    the first occurrence, and probes several positions so both the
    "repeated token" and "unique token" shapes are covered.
    """
    cfg, params = model
    prompt = [5, 6, 7]
    ref_out = _single_decode(cfg, params, prompt, 8)
    for probe in (1, 3, 5):
        eos = ref_out[probe]
        stop = ref_out.index(eos)  # first occurrence is where we stop
        eng = ContinuousBatcher(cfg, params, n_slots=1, max_seq=64)
        eng.submit(Request(uid=0, prompt=prompt, max_new=8, eos=eos))
        done = eng.run()
        assert done[0].output == ref_out[:stop + 1], (probe, stop)


def test_eos_in_prompt_does_not_stop(model):
    """Teacher-forced prefill tokens must never trigger the eos check —
    only *generated* tokens end a request."""
    cfg, params = model
    prompt = [5, 6, 7]
    ref_out = _single_decode(cfg, params, prompt, 4)
    eos = prompt[1]
    assert eos not in ref_out[:4]  # probe stays meaningful
    eng = ContinuousBatcher(cfg, params, n_slots=1, max_seq=64)
    eng.submit(Request(uid=0, prompt=prompt, max_new=4, eos=eos))
    done = eng.run()
    assert done[0].output == ref_out[:4]
