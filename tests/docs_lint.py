#!/usr/bin/env python
"""Docs lint: every module path named in the layout tables of
docs/ARCHITECTURE.md and docs/KERNELS.md must exist on disk, and every
CLI flag quoted in README/docs must exist in an argparse definition
under ``src/repro/launch/`` or ``benchmarks/`` — so the paper-to-code
map and the documented invocations can't silently rot.  Run directly
(CI) — exits 1 listing any stale references."""
import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

basenames = {
    f for d in ("src", "tests", "benchmarks", "examples", ".github")
    for _, _, files in os.walk(os.path.join(ROOT, d)) for f in files
}

missing = []
for doc in ("docs/ARCHITECTURE.md", "docs/KERNELS.md",
            "docs/OBSERVABILITY.md"):
    text = open(os.path.join(ROOT, doc)).read()
    for ref in set(re.findall(r"`([\w./-]+\.(?:py|yml|json))(?:::[\w.]+)?`", text)):
        candidates = (ref, f"src/repro/{ref}", f"src/{ref}")
        if any(os.path.exists(os.path.join(ROOT, c)) for c in candidates):
            continue
        if "/" not in ref and ref in basenames:
            continue
        missing.append(f"{doc}: `{ref}`")

# ---------------------------------------------------------------- CLI flags
# every --flag defined anywhere in the launchers / bench harness; a
# documented --foo is also satisfied by a BooleanOptionalAction --no-foo
defined = set()
for src in glob.glob(os.path.join(ROOT, "src/repro/launch/*.py")) + \
        glob.glob(os.path.join(ROOT, "benchmarks/*.py")):
    for m in re.finditer(
            r'add_argument\(\s*"(--[\w-]+)"(?:\s*,\s*"(--[\w-]+)")?',
            open(src).read()):
        for flag in m.groups():
            if flag:
                defined.add(flag)
defined |= {f"--no-{f[2:]}" for f in tuple(defined)}

def _code_spans(md):
    """Inline backtick spans + fenced code blocks — the only places a
    flag is a *claimed invocation* (link anchors like #phase-1--cd
    merely look like flags and are skipped by construction)."""
    fences = re.findall(r"```.*?```", md, flags=re.S)
    inline = re.findall(r"`[^`\n]+`", md)
    return "\n".join(fences + inline)

docs = [os.path.join(ROOT, "README.md")] + sorted(
    glob.glob(os.path.join(ROOT, "docs", "*.md")))
for doc in docs:
    code = _code_spans(open(doc).read())
    for flag in sorted(set(re.findall(r"(?<![\w-])--[a-z][\w-]*", code))):
        if flag not in defined:
            missing.append(
                f"{os.path.relpath(doc, ROOT)}: flag `{flag}` not defined "
                "by any src/repro/launch/ or benchmarks/ argparse")

# ---------------------------------------------------------- pycache hygiene
# committed bytecode shadows renamed modules (a sourceless .pyc imports
# fine but runs pre-rename code — benchmarks/run.py purges them at
# runtime); the lint stops them from ever entering the tree
import subprocess  # noqa: E402

try:
    tracked = subprocess.run(
        ["git", "ls-files"], cwd=ROOT, capture_output=True, text=True,
        timeout=30).stdout.splitlines()
except Exception:
    tracked = []
for path in tracked:
    if path.endswith((".pyc", ".pyo")) or "__pycache__" in path.split("/"):
        missing.append(f"git-tracked compiled artifact: {path}")

if missing:
    print("stale references in docs:", *sorted(missing), sep="\n  ")
    sys.exit(1)
print("docs lint OK")
