#!/usr/bin/env python
"""Docs lint: every module path named in the layout tables of
docs/ARCHITECTURE.md and docs/KERNELS.md must exist on disk, so the
paper-to-code map can't silently rot.  Run directly (CI) — exits 1
listing any stale references."""
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

basenames = {
    f for d in ("src", "tests", "benchmarks", "examples", ".github")
    for _, _, files in os.walk(os.path.join(ROOT, d)) for f in files
}

missing = []
for doc in ("docs/ARCHITECTURE.md", "docs/KERNELS.md"):
    text = open(os.path.join(ROOT, doc)).read()
    for ref in set(re.findall(r"`([\w./-]+\.(?:py|yml|json))(?:::[\w.]+)?`", text)):
        candidates = (ref, f"src/repro/{ref}", f"src/{ref}")
        if any(os.path.exists(os.path.join(ROOT, c)) for c in candidates):
            continue
        if "/" not in ref and ref in basenames:
            continue
        missing.append(f"{doc}: `{ref}`")

if missing:
    print("stale module references in docs:", *sorted(missing), sep="\n  ")
    sys.exit(1)
print("docs lint OK")
