"""Fused FD round kernel (``kernels/fd_round.py``) — the zero-per-round-
dispatch tentpole.

Three layers of lock:
  * kernel ↔ pure-jnp oracle (``kernels/ref.py``) parity in interpret
    mode, single-shot and iterated to the fixed point;
  * structural jaxpr assertions — the ops-layer round wrapper is exactly
    ONE ``pallas_call`` at top level, and the whole fused Phase 2 is ONE
    ``while`` whose body holds one ``pallas_call`` and no segment-sum /
    gather / argmin / compaction tail;
  * end-to-end bit-identity — every csr golden cell (device + vmapped)
    re-run with ``fused=True`` must match ``tests/goldens/
    peel_goldens.json`` field-for-field (θ, partitioning, round/update
    counts), plus a hypothesis property on random graphs.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ref as core_ref
from repro.core.graph import powerlaw_bipartite, random_bipartite
from repro.core.peel import (
    _fd_tip_fused_impl,
    _fd_wing_fused_impl,
    tip_decomposition,
    wing_decomposition,
)
from repro.kernels import ops, ref

GOLDENS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "goldens", "peel_goldens.json")

_BANNED = {"scatter", "scatter-add", "scatter_add", "gather", "argmin",
           "reduce_min", "cumsum", "sort", "segment_sum"}


# ---------------------------------------------------------------------
# packed-state builders (the same layouts the peel drivers feed)
# ---------------------------------------------------------------------
def _wing_state(seed=0, n_u=30, n_v=24, m=140, P=4):
    from repro.core import csr
    from repro.core.distributed import pack_fd_partitions_csr

    g = random_bipartite(n_u, n_v, m, seed=seed)
    wed = csr.build_wedges(g)
    res = wing_decomposition(g, P=P, engine="csr")
    n_parts = int(res.part.max()) + 1
    p = pack_fd_partitions_csr(
        wed, res.part, res.support_init, n_parts, bucket=True, slots=True)
    R, _ = p["slot_sizes"]
    W_rows = np.zeros((n_parts, R), np.int32)
    w = min(R, p["W0"].shape[1])
    W_rows[:, :w] = p["W0"][:, :w]
    z = jnp.asarray(p["sup0"]).astype(jnp.int32) * 0
    z1 = z[:, :1]
    state = (jnp.asarray(p["sup0"]).astype(jnp.int32),
             jnp.asarray(p["mine"]).astype(jnp.int32), z, z1, z1, z1,
             jnp.asarray(p["slot_valid"]).astype(jnp.int32),
             jnp.asarray(W_rows).astype(jnp.float32))
    statics = (jnp.asarray(p["slot_e1"]), jnp.asarray(p["slot_e2"]))
    return state, statics, p


def _tip_state(seed=0, n_u=30, n_v=24, m=140, P=4):
    from repro.core import csr
    from repro.core.distributed import pack_fd_partitions_tip_csr

    g = random_bipartite(n_u, n_v, m, seed=seed)
    wed = csr.build_wedges(g)
    res = tip_decomposition(g, side="u", P=P, engine="csr")
    n_parts = int(res.part.max()) + 1
    p = pack_fd_partitions_tip_csr(
        wed, wed.pair_butterflies0(), res.part, res.support_init,
        n_parts, bucket=True, stacked=True)
    z = jnp.asarray(p["sup0"]).astype(jnp.int32) * 0
    z1 = z[:, :1]
    state = (jnp.asarray(p["sup0"]).astype(jnp.int32),
             jnp.asarray(p["mine"]).astype(jnp.int32), z, z1, z1)
    statics = (jnp.asarray(p["st_pa"]), jnp.asarray(p["st_pb"]),
               jnp.asarray(p["st_bf"]))
    return state, statics, p


# ---------------------------------------------------------------------
# kernel ↔ oracle parity (interpret mode, the KERNELS.md recipe)
# ---------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fd_round_wing_kernel_matches_ref(seed):
    state, statics, _ = _wing_state(seed=seed)
    # iterate to the fixed point: every round's full 8-tuple must agree
    for _ in range(40):
        got = ops.fd_round_wing(*state, *statics, interpret=True)
        want = ref.fd_round_wing_ref(*state, *statics)
        for i, (a, b) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"output {i}")
        state = got
        if not np.asarray(state[1]).any():
            break
    assert not np.asarray(state[1]).any(), "cascade did not converge"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fd_round_tip_kernel_matches_ref(seed):
    state, statics, _ = _tip_state(seed=seed)
    for _ in range(40):
        got = ops.fd_round_tip(*state, *statics, interpret=True)
        want = ref.fd_round_tip_ref(*state, *statics)
        for i, (a, b) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b), err_msg=f"output {i}")
        state = got
        if not np.asarray(state[1]).any():
            break
    assert not np.asarray(state[1]).any(), "cascade did not converge"


# ---------------------------------------------------------------------
# structural jaxpr locks
# ---------------------------------------------------------------------
def test_wing_round_wrapper_is_single_pallas_call():
    """The ops-layer round body must trace to exactly ONE top-level
    pallas_call — nothing before it, nothing after it (this is why the
    wrapper is deliberately unjitted)."""
    state, statics, _ = _wing_state()
    jx = jax.make_jaxpr(
        lambda *a: ops.fd_round_wing(*a, interpret=True))(*state, *statics)
    prims = [e.primitive.name for e in jx.jaxpr.eqns]
    assert prims == ["pallas_call"], prims


def test_tip_round_wrapper_is_single_pallas_call():
    state, statics, _ = _tip_state()
    jx = jax.make_jaxpr(
        lambda *a: ops.fd_round_tip(*a, interpret=True))(*state, *statics)
    prims = [e.primitive.name for e in jx.jaxpr.eqns]
    assert prims == ["pallas_call"], prims


def _assert_fused_phase_structure(jx):
    whiles = [e for e in jx.jaxpr.eqns if e.primitive.name == "while"]
    assert len(whiles) == 1, [e.primitive.name for e in jx.jaxpr.eqns]
    body = [e.primitive.name
            for e in whiles[0].params["body_jaxpr"].jaxpr.eqns]
    assert body.count("pallas_call") == 1, body
    assert not _BANNED & set(body), body


def test_fused_wing_phase_is_one_while_one_pallas_call():
    """Whole fused wing Phase 2: ONE while_loop whose body is ONE
    pallas_call — the zero-per-round-dispatch claim, stated on the
    jaxpr."""
    state, statics, p = _wing_state()
    _assert_fused_phase_structure(jax.make_jaxpr(
        lambda e1, e2, v, w, mi, s: _fd_wing_fused_impl(
            e1, e2, v, w, mi, s, interpret=True))(
        statics[0], statics[1], jnp.asarray(p["slot_valid"]),
        state[7].astype(jnp.int32), jnp.asarray(p["mine"]),
        jnp.asarray(p["sup0"])))


def test_fused_tip_phase_is_one_while_one_pallas_call():
    state, statics, p = _tip_state()
    _assert_fused_phase_structure(jax.make_jaxpr(
        lambda pa, pb, bf, mi, s: _fd_tip_fused_impl(
            pa, pb, bf, mi, s, interpret=True))(
        *statics, jnp.asarray(p["mine"]), jnp.asarray(p["sup0"])))


# ---------------------------------------------------------------------
# end-to-end bit-identity vs the pre-refactor goldens
# ---------------------------------------------------------------------
_GRAPHS = {
    "rb30": lambda: random_bipartite(30, 24, 140, seed=0),
    "rb25": lambda: random_bipartite(25, 20, 100, seed=1),
    "pl80": lambda: powerlaw_bipartite(80, 40, 350, seed=2),
    "pl60": lambda: powerlaw_bipartite(60, 50, 300, seed=3),
}

_FIELDS = ("theta", "part", "ranges", "support_init", "rho_cd",
           "rho_fd_total", "rho_fd_max", "updates", "recounts",
           "p_effective")


def _snapshot(res) -> dict:
    s = res.stats
    return dict(
        theta=np.asarray(res.theta).tolist(),
        part=np.asarray(res.part).tolist(),
        ranges=np.asarray(res.ranges).tolist(),
        support_init=np.asarray(res.support_init).tolist(),
        rho_cd=s.rho_cd, rho_fd_total=s.rho_fd_total,
        rho_fd_max=s.rho_fd_max, updates=s.updates,
        recounts=s.recounts, p_effective=s.p_effective,
    )


@pytest.fixture(scope="module")
def goldens():
    with open(GOLDENS) as f:
        return json.load(f)


@pytest.mark.parametrize("gname", sorted(_GRAPHS))
def test_fused_wing_matches_csr_goldens(goldens, gname):
    """fused=True against the SAME goldens the unfused drivers lock to —
    a mismatch means the fusion changed peeling semantics."""
    g = _GRAPHS[gname]()
    cases = [k for k in goldens if k.startswith(f"wing.{gname}.")
             and k.split(".")[3] == "csr"
             and k.split(".")[4] in ("device", "vmapped")]
    assert cases, "golden file lost its csr wing cases"
    for key in cases:
        _, _, Ps, engine, fd = key.split(".")
        res = wing_decomposition(
            g, P=int(Ps[1:]), engine=engine, fd_driver=fd, fused=True)
        got = _snapshot(res)
        for f in _FIELDS:
            assert got[f] == goldens[key][f], (key, f)


@pytest.mark.parametrize("gname", sorted(_GRAPHS))
def test_fused_tip_matches_csr_goldens(goldens, gname):
    g = _GRAPHS[gname]()
    cases = [k for k in goldens if k.startswith(f"tip.{gname}.")
             and k.split(".")[4] == "csr"
             and k.split(".")[5] in ("device", "vmapped")]
    assert cases, "golden file lost its csr tip cases"
    for key in cases:
        _, _, Ps, side, engine, fd = key.split(".")
        res = tip_decomposition(
            g, side=side, P=int(Ps[1:]), engine=engine, fd_driver=fd,
            fused=True)
        got = _snapshot(res)
        for f in _FIELDS:
            assert got[f] == goldens[key][f], (key, f)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5))
def test_fused_unfused_parity_property(seed, P):
    """Property: fused and unfused drivers agree bit-for-bit on random
    graphs — θ, partitioning AND round/update counts — and match the
    BUP oracle."""
    g = random_bipartite(18, 14, 60, seed=seed)

    base = wing_decomposition(g, P=P, engine="csr")
    assert np.array_equal(base.theta, core_ref.bup_wing_ref(g))
    for fd in ("device", "vmapped"):
        other = wing_decomposition(g, P=P, engine="csr", fd_driver=fd,
                                   fused=True)
        assert np.array_equal(other.theta, base.theta), fd
        assert np.array_equal(other.part, base.part), fd
        assert other.stats.rho_fd_total == base.stats.rho_fd_total, fd
        assert other.stats.rho_fd_max == base.stats.rho_fd_max, fd
        assert other.stats.updates == base.stats.updates, fd

    tbase = tip_decomposition(g, side="u", P=P, engine="csr")
    assert np.array_equal(tbase.theta, core_ref.bup_tip_ref(g, "u"))
    for fd in ("device", "vmapped"):
        other = tip_decomposition(g, side="u", P=P, engine="csr",
                                  fd_driver=fd, fused=True)
        assert np.array_equal(other.theta, tbase.theta), fd
        assert np.array_equal(other.part, tbase.part), fd
        assert other.stats.rho_fd_total == tbase.stats.rho_fd_total, fd
        assert other.stats.rho_fd_max == tbase.stats.rho_fd_max, fd


def test_fused_rejects_unsupported_combinations():
    g = random_bipartite(10, 8, 24, seed=0)
    with pytest.raises(ValueError):
        wing_decomposition(g, engine="beindex", fused=True)
    with pytest.raises(ValueError):
        wing_decomposition(g, engine="csr", fd_driver="host", fused=True)
    with pytest.raises(ValueError):
        tip_decomposition(g, engine="dense", fused=True)
    with pytest.raises(ValueError):
        tip_decomposition(g, engine="csr", fd_driver="host", fused=True)


def test_obs_off_fd_jaxprs_byte_identical(obs_golden):
    """Zero-overhead-off: with telemetry disabled (the default), the
    fused and vmapped FD programs re-derived from the instrumented tree
    are byte-identical to the pre-instrumentation goldens
    (``tests/goldens/obs_jaxprs.json``).  The counter rings the obs
    layer threads through the FD loop carries live in separate
    ``*_rings`` jit twins — the default entries may not trace a single
    extra op."""
    from repro import obs

    rec, golden = obs_golden
    assert not obs.enabled()
    for name in ("fused_wing", "fused_tip", "vmapped_wing",
                 "vmapped_tip"):
        assert rec.CASES[name]() == golden[name], \
            f"{name}: default-path jaxpr drifted from the telemetry-off " \
            f"golden (re-record ONLY for intentional kernel changes)"
